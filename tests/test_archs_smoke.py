"""Per-architecture smoke tests: reduced config, one train + decode step on CPU.

Each assigned arch instantiates a family-preserving reduction (same layer
pattern, MoE/SSD/enc-dec structure, frontend stubs — tiny dims) and runs:
  1. loss + grads (train step shape/NaN check),
  2. prefill + one decode step (serving path shape/NaN check).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import common, transformer

ARCHS = configs.list_archs()


def _batch(cfg, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.frontend == "patches":
        batch["extra_embeds"] = jax.random.normal(ks[2], (b, cfg.frontend_len, cfg.d_model))
        batch["tokens"] = batch["tokens"][:, : s - cfg.frontend_len]
        batch["targets"] = batch["targets"][:, : s - cfg.frontend_len]
    elif cfg.n_enc_layers:
        batch["extra_embeds"] = jax.random.normal(ks[2], (b, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.smoke_config(arch)
    params = common.init_params(transformer.model_defs(cfg), jax.random.PRNGKey(1))
    batch = _batch(cfg)

    def lf(p):
        return transformer.loss_fn(p, batch, cfg, remat=True)

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # Reasonable xent at random init: ~ln(vocab) +- slack.
    assert 1.0 < float(metrics["xent"]) < 3 * np.log(cfg.vocab)
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = configs.smoke_config(arch)
    params = common.init_params(transformer.model_defs(cfg), jax.random.PRNGKey(2))
    batch = _batch(cfg, key=3)
    toks = batch["tokens"]
    extra = batch.get("extra_embeds")

    last, cache = transformer.prefill(params, toks[:, :-1], cfg, max_len=24, extra_embeds=extra)
    assert last.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(last, np.float32)).all(), arch

    cur = jnp.int32(toks.shape[1] - 1)
    logits, cache2 = transformer.decode_step(params, cache, cur, toks[:, -1:], cfg)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # Cache structure unchanged.
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_defs_consistent(arch):
    """Full configs: defs build, param counts positive, cache defs well-formed.

    (No allocation — ParamDef trees and ShapeDtypeStructs only.)
    """
    cfg = configs.get_config(arch)
    defs = transformer.model_defs(cfg)
    n = transformer.count(cfg)
    assert n > 100e6, (arch, n)
    ab = common.abstract_params(defs)
    assert jax.tree.leaves(ab)
    cache = transformer.abstract_cache(cfg, batch=2, max_len=64)
    assert jax.tree.leaves(cache)
    for shape in configs.SHAPES:
        if configs.skip_reason(cfg, shape) is None:
            specs = configs.input_specs(cfg, shape)
            assert "tokens" in specs


def test_shape_skips_documented():
    """Exactly the DESIGN.md skip set: 6 long_500k skips, 34 runnable cells."""
    runnable, skipped = 0, []
    for arch in ARCHS:
        cfg = configs.get_config(arch)
        for shape in configs.SHAPES:
            r = configs.skip_reason(cfg, shape)
            if r is None:
                runnable += 1
            else:
                skipped.append((arch, shape))
    assert runnable == 34, runnable
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s in skipped)
    long_runners = {a for a in ARCHS if configs.skip_reason(configs.get_config(a), "long_500k") is None}
    assert long_runners == {"mamba2-370m", "jamba-1.5-large-398b", "gemma3-27b", "h2o-danube-1.8b"}
