"""Baselines the paper compares against: Lemiesz's method, FastGM, FastExpSketch.

All three share the *same* sketch law — m float min-registers, each the min of
Exp(w) variables over distinct elements, hence Exp(C) distributed — and the
same unbiased estimator Ĉ = (m-1)/Σ R[j] (Eq. 2). They differ only in the
update *schedule*:

* LM (Lemiesz [26]):      every element touches all m registers.
* FastGM [45]:            ascending order-statistics generation + early stop
                          against the current max register.
* FastExpSketch [27]:     same idea as FastGM (the paper treats them as
                          equivalent); kept as a distinct entry so benchmark
                          tables mirror the paper's 5-method comparison. Our
                          implementation differs from FastGM only in that it
                          tracks the max register incrementally instead of
                          recomputing it (the FES paper's r* register).

On TPU the early stop becomes batch-level pruning exactly as for QSketch
(DESIGN.md §4.1): one hash bounds the element's smallest value r_1; if
r_1 >= max_j R[j] the element cannot lower any register.

Registers are float32 here (the paper uses 64-bit floats on CPU; TPU has no
f64 — f32's 2^-24 relative error is orders below the 1/sqrt(m-2) estimator
noise for any practical m; the accuracy benchmarks confirm parity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import estimators, hashing
from .types import FloatSketchState, SketchConfig

_INIT = jnp.float32(jnp.finfo(jnp.float32).max)


def init(cfg: SketchConfig) -> FloatSketchState:
    """Fresh float baseline sketch: f32[m] min-registers at +max (empty)."""
    return FloatSketchState(regs=jnp.full((cfg.m,), _INIT, dtype=jnp.float32))


def estimate(state: FloatSketchState) -> jnp.ndarray:
    """Eq. 2 with the untouched-sketch guard (estimators.lm_estimate)."""
    return estimators.lm_estimate(state.regs)


def merge(a: FloatSketchState, b: FloatSketchState) -> FloatSketchState:
    """Exact union-stream merge: element-wise min (the min-monoid dual of
    the QSketch max merge)."""
    return FloatSketchState(regs=jnp.minimum(a.regs, b.regs))


# ---------------------------------------------------------------------------
# LM: dense iid schedule
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0,))
def lm_update(cfg: SketchConfig, state: FloatSketchState, ids, weights, mask=None) -> FloatSketchState:
    """Alg. 1: R[j] <- min(R[j], -ln h_j(x)/w) for all j, batched."""
    lo, hi = hashing.split_id64(ids)
    j = jnp.arange(cfg.m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((lo[:, None], hi[:, None], j[None, :]), cfg.salt_h)
    r = e / weights.astype(jnp.float32)[:, None]
    if mask is not None:
        r = jnp.where(mask[:, None], r, _INIT)
    return FloatSketchState(regs=jnp.minimum(state.regs, jnp.min(r, axis=0)))


# ---------------------------------------------------------------------------
# FastGM / FastExpSketch: order-statistics schedule + batch prune
# ---------------------------------------------------------------------------


def _os_values(cfg: SketchConfig, lo, hi, w, salt):
    """Ascending r_1 < ... < r_m per element via the FastGM recurrence."""
    m = cfg.m
    k = jnp.arange(m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((lo[:, None], hi[:, None], k[None, :]), salt)
    gaps = e / (m - jnp.arange(m, dtype=jnp.float32))[None, :]
    return jnp.cumsum(gaps, axis=-1) / w[:, None]


def _positions(cfg: SketchConfig, lo, hi, salt):
    k = jnp.arange(cfg.m, dtype=jnp.uint32)
    keys = hashing.hash_words((lo[:, None], hi[:, None], k[None, :]), salt)
    return jnp.argsort(keys, axis=-1).astype(jnp.int32)


def _fast_update(cfg: SketchConfig, state, ids, weights, mask, salt_h, salt_p):
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    max_reg = jnp.max(state.regs)

    # Prune: r_1 = e_1/(m w); if r_1 >= max register nothing can improve.
    k0 = jnp.zeros_like(lo)
    r1 = hashing.neg_log_uniform((lo, hi, k0), salt_h) / (cfg.m * w)
    alive = r1 < max_reg
    if mask is not None:
        alive = alive & mask

    r = _os_values(cfg, lo, hi, w, salt_h)
    r = jnp.where(alive[:, None], r, _INIT)
    pos = _positions(cfg, lo, hi, salt_p)
    regs = state.regs.at[pos.reshape(-1)].min(r.reshape(-1))
    return FloatSketchState(regs=regs)


@functools.partial(jax.jit, static_argnums=(0,))
def fastgm_update(cfg: SketchConfig, state: FloatSketchState, ids, weights, mask=None) -> FloatSketchState:
    """FastGM batched update: permuted one-register-per-draw min schedule
    (the shared ``_fast_update`` with the config's primary salts)."""
    return _fast_update(cfg, state, ids, weights, mask, cfg.salt_h, cfg.salt_perm)


@functools.partial(jax.jit, static_argnums=(0,))
def fastexp_update(cfg: SketchConfig, state: FloatSketchState, ids, weights, mask=None) -> FloatSketchState:
    """FastExpSketch batched update: same permuted min schedule as FastGM
    under re-salted hashes, so the two baselines are independent draws."""
    # Same schedule; distinct salts so the two sketches are independent draws
    # (as they would be with independent hash families in the papers).
    return _fast_update(
        cfg, state, ids, weights, mask, (cfg.salt_h * 31 + 7) & 0xFFFFFFFF, (cfg.salt_perm * 31 + 7) & 0xFFFFFFFF
    )


def fastgm_prune_mask(cfg: SketchConfig, state: FloatSketchState, ids, weights):
    """Phase-1 survival mask (throughput benchmarks compact with this)."""
    lo, hi = hashing.split_id64(ids)
    k0 = jnp.zeros_like(lo)
    r1 = hashing.neg_log_uniform((lo, hi, k0), cfg.salt_h) / (cfg.m * weights.astype(jnp.float32))
    return r1 < jnp.max(state.regs)
