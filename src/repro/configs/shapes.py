"""Assigned input shapes and ShapeDtypeStruct builders (dry-run inputs).

Four shapes per architecture (the brief's cell grid):

  train_4k     seq=4096    global_batch=256   -> lowers train_step
  prefill_32k  seq=32768   global_batch=32    -> lowers prefill
  decode_32k   seq=32768   global_batch=128   -> lowers serve_step (1 token)
  long_500k    seq=524288  global_batch=1     -> lowers serve_step (1 token)

long_500k only runs for sub-quadratic archs (cfg.sub_quadratic); whisper
additionally skips it (448-token decoder). Skips carry machine-readable
reasons so the dry-run report lists all 40 cells.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    """None = runnable; else the documented skip reason (DESIGN.md §5)."""
    if shape == "long_500k":
        if cfg.n_enc_layers:
            return "enc-dec: decoder context is 448; 500k decode not meaningful"
        if not cfg.sub_quadratic:
            return "pure full-attention arch: no sub-quadratic 500k state"
    return None


def _token_struct(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {"tokens", "targets", ["extra_embeds"], ["loss_mask"]}
    prefill-> {"tokens", ["extra_embeds"]}
    decode -> {"tokens" (B,1), "cur_len" scalar, "cache" pytree}
    """
    ss = SHAPES[shape]
    e = cfg.d_model
    emb_dt = jnp.bfloat16

    if ss.kind == "train":
        if cfg.frontend == "patches":
            text = ss.seq - cfg.frontend_len
            return {
                "tokens": _token_struct(ss.batch, text),
                "targets": _token_struct(ss.batch, text),
                "extra_embeds": jax.ShapeDtypeStruct((ss.batch, cfg.frontend_len, e), emb_dt),
            }
        if cfg.n_enc_layers:
            return {
                "tokens": _token_struct(ss.batch, ss.seq),
                "targets": _token_struct(ss.batch, ss.seq),
                "extra_embeds": jax.ShapeDtypeStruct((ss.batch, cfg.enc_seq, e), emb_dt),
            }
        return {
            "tokens": _token_struct(ss.batch, ss.seq),
            "targets": _token_struct(ss.batch, ss.seq),
        }

    if ss.kind == "prefill":
        out = {"tokens": _token_struct(ss.batch, ss.seq)}
        if cfg.frontend == "patches":
            out["tokens"] = _token_struct(ss.batch, ss.seq - cfg.frontend_len)
            out["extra_embeds"] = jax.ShapeDtypeStruct((ss.batch, cfg.frontend_len, e), emb_dt)
        elif cfg.n_enc_layers:
            out["extra_embeds"] = jax.ShapeDtypeStruct((ss.batch, cfg.enc_seq, e), emb_dt)
        return out

    # decode: one new token against a seq-length cache.
    cache = transformer.abstract_cache(cfg, ss.batch, ss.seq)
    return {
        "tokens": _token_struct(ss.batch, 1),
        "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }
