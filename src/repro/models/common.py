"""Shared model machinery: ParamDef trees, norms, RoPE, activations.

Every module declares its parameters ONCE as a nested dict of ``ParamDef``
(shape, dtype, logical axis names). Three consumers derive from that tree:

  * ``init_params``     — materialize real arrays (smoke tests / real training)
  * ``abstract_params`` — ShapeDtypeStruct stand-ins (multi-pod dry-run;
                          nothing is allocated)
  * ``spec_tree``       — PartitionSpec tree for pjit in_shardings, resolved
                          against whatever mesh axes actually exist
                          (see sharding.py)

Logical axis vocabulary (resolved by sharding.resolve):
  "model"-class: heads, kv_heads, ffn, vocab, experts, d_inner
  "fsdp"-class:  embed  (sharded over ("pod","data") when present)
  replicated:    None, plus tiny norm scales
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis name (or None) per dim; len == len(shape)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0  # stddev multiplier for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, ParamDef):
        yield prefix, tree
        return
    for k in sorted(tree.keys()):
        yield from _leaf_paths(tree[k], prefix + (k,))


def _map_defs(tree, fn):
    if isinstance(tree, ParamDef):
        return fn(tree)
    return {k: _map_defs(v, fn) for k, v in tree.items()}


def init_params(defs, key):
    """Materialize real parameter arrays (for smoke tests / small training)."""
    paths = list(_leaf_paths(defs))
    keys = jax.random.split(key, max(len(paths), 1))
    out = {}
    for (path, d), k in zip(paths, keys):
        if d.init == "zeros":
            v = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, d.dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            std = d.scale / np.sqrt(fan_in)
            v = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = v
    return out


def abstract_params(defs):
    """ShapeDtypeStruct tree — the dry-run's no-allocation stand-in."""
    return _map_defs(defs, lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype))


def axes_tree(defs):
    """Tree of logical-axes tuples (same structure as params)."""
    return _map_defs(defs, lambda d: d.axes)


def count_params(defs) -> int:
    return sum(int(np.prod(d.shape)) for _, d in _leaf_paths(defs))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(x @ gate) * (x @ up) )."""
    g = silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def rope_tables(positions, d_head: int, theta: float = 10000.0):
    """(sin, cos) tables for rotary embeddings; positions: (..., S) int32."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., S, H, D). sin/cos: (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # sin/cos arrive as (..., S, half): insert a head axis before last.
    s = jnp.expand_dims(sin, axis=-2)
    c = jnp.expand_dims(cos, axis=-2)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def softmax_xent(logits, targets, mask=None):
    """Token-mean cross entropy in f32; targets: int32, mask optional bool."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def softmax_xent_sharded(logits, targets, mesh, mask=None):
    """Vocab-shard-friendly xent (§Perf hillclimb).

    take_along_axis over a model-sharded vocab axis makes GSPMD all-gather
    the full (B,S,V) f32 logits per device (68 GiB at vocab=262k) — the
    dominant memory/collective cost of the big-vocab train cells. This
    variant constrains logits to stay vocab-sharded and extracts the gold
    logit with a masked sum (shard-local compare + tiny all-reduce).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from . import sharding as msharding

    logits = logits.astype(jnp.float32)
    if mesh is not None:
        spec = msharding.resolve(("batch", None, "vocab"), mesh, logits.shape)
        logits = jax.lax.with_sharding_constraint(logits, NamedSharding(mesh, spec))
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
