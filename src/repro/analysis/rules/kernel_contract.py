"""kernel-contract — Pallas kernels keep their Ref/BlockSpec discipline.

A ``pl.pallas_call`` kernel body executes on-device per grid step; its
contract in this repo (DESIGN.md §6, /opt guides) is:

1. **Ref params only** — every positional parameter is a ``Ref`` (named
   ``*_ref`` by repo convention; operands, outputs, and VMEM scratch all
   follow it). Static scalars ride keyword-only, bound via
   ``functools.partial`` before the ``pallas_call``.
2. **No host-fallback ops** — ``np.*`` inside the body runs at trace time
   on concrete shapes only (and at all on padded tracers it just breaks);
   data-dependent jnp ops (``nonzero``, ``unique``, ``sort``, ``argsort``,
   ``searchsorted``, ``median``, ``percentile``) have no Mosaic lowering
   and force interpret-only kernels; ``print`` is a trace-time ghost.
3. **Consistent ranks** — each literal ``pl.BlockSpec((shape...), index_map)``
   must have ``len(shape) == len(index_map(...)'s returned tuple)``; every
   index_map takes exactly ``len(grid)`` arguments; a literal
   ``dimension_semantics`` tuple must match the grid rank; and inside the
   kernel, a literal tuple subscript on an operand Ref must match its
   BlockSpec rank.

Only literal specs are checked — computed specs are skipped, not guessed.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import ImportMap, call_keyword, dotted
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

SCOPE = ("src/repro/kernels/",)

BANNED_JNP = {
    "nonzero", "unique", "sort", "argsort", "searchsorted", "median",
    "percentile", "quantile",
}


def _spec_list(node: ast.expr | None) -> list[ast.expr]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _block_rank(spec: ast.expr, imap: ImportMap) -> int | None:
    """Rank of a literal pl.BlockSpec((d0, d1, ...), ...), else None."""
    if not isinstance(spec, ast.Call):
        return None
    qual = imap.resolve(spec.func) or ""
    if not qual.endswith("BlockSpec"):
        return None
    if spec.args and isinstance(spec.args[0], ast.Tuple):
        return len(spec.args[0].elts)
    return None


def _index_map(spec: ast.expr) -> ast.Lambda | None:
    if isinstance(spec, ast.Call) and len(spec.args) >= 2 and isinstance(
        spec.args[1], ast.Lambda
    ):
        return spec.args[1]
    return None


def _lambda_out_rank(lam: ast.Lambda) -> int | None:
    if isinstance(lam.body, ast.Tuple):
        return len(lam.body.elts)
    return 1


@register
class KernelContractRule(Rule):
    """Flag Ref-naming, host-fallback, and rank-consistency breaches in
    Pallas kernels."""

    name = "kernel-contract"
    description = (
        "Pallas kernels: Ref params only, no host-fallback ops in the body, "
        "BlockSpec/grid/index_map/indexing ranks consistent"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        findings: list[Finding] = []
        for mod in ctx.iter_modules(SCOPE):
            if not ctx.is_selected(mod.rel):
                continue
            imap = ImportMap(mod.tree, mod.name)
            defs = {
                n.name: n
                for n in ast.walk(mod.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                qual = imap.resolve(node.func) or ""
                if not qual.endswith("pallas_call"):
                    continue
                findings += self._check_call(node, defs, mod, imap)
        return findings

    def _check_call(self, call: ast.Call, defs, mod, imap) -> list[Finding]:
        out: list[Finding] = []

        # Resolve the kernel def (direct name or functools.partial(name, ...)).
        kernel = call.args[0] if call.args else None
        if isinstance(kernel, ast.Call):
            kernel = kernel.args[0] if kernel.args else None
        kfn = defs.get(kernel.id) if isinstance(kernel, ast.Name) else None

        grid = call_keyword(call, "grid")
        grid_rank = len(grid.elts) if isinstance(grid, ast.Tuple) else None

        in_specs = _spec_list(call_keyword(call, "in_specs"))
        out_specs = _spec_list(call_keyword(call, "out_specs"))
        ranks: list[int | None] = []
        for label, spec in [("in_specs", s) for s in in_specs] + [
            ("out_specs", s) for s in out_specs
        ]:
            rank = _block_rank(spec, imap)
            if label == "in_specs":
                ranks.append(rank)
            lam = _index_map(spec)
            if lam is None:
                continue
            lam_rank = _lambda_out_rank(lam)
            if rank is not None and lam_rank is not None and rank != lam_rank:
                out.append(
                    Finding(
                        self.name,
                        mod.rel,
                        spec.lineno,
                        f"BlockSpec rank {rank} != index_map output rank "
                        f"{lam_rank} in {label}",
                    )
                )
            if grid_rank is not None and len(lam.args.args) != grid_rank:
                out.append(
                    Finding(
                        self.name,
                        mod.rel,
                        spec.lineno,
                        f"index_map takes {len(lam.args.args)} grid indices "
                        f"but grid rank is {grid_rank} in {label}",
                    )
                )

        # dimension_semantics vs grid rank.
        for kw_call in ast.walk(call):
            if isinstance(kw_call, ast.Call):
                sem = call_keyword(kw_call, "dimension_semantics")
                if isinstance(sem, ast.Tuple) and grid_rank is not None:
                    if len(sem.elts) != grid_rank:
                        out.append(
                            Finding(
                                self.name,
                                mod.rel,
                                sem.lineno,
                                f"dimension_semantics has {len(sem.elts)} "
                                f"entries but grid rank is {grid_rank}",
                            )
                        )

        if kfn is None:
            return out

        # 1. Ref-only positional params.
        for arg in kfn.args.posonlyargs + kfn.args.args:
            if not arg.arg.endswith("_ref"):
                out.append(
                    Finding(
                        self.name,
                        mod.rel,
                        kfn.lineno,
                        f"kernel '{kfn.name}' positional param '{arg.arg}' is "
                        "not a Ref ('*_ref') — statics go keyword-only via "
                        "functools.partial",
                    )
                )

        # 2. Banned ops in the body.
        for node in ast.walk(kfn):
            if not isinstance(node, ast.Call):
                continue
            q = imap.resolve(node.func) or dotted(node.func) or ""
            leaf = q.rsplit(".", 1)[-1]
            if q.startswith(("numpy.", "np.")):
                out.append(
                    Finding(
                        self.name, mod.rel, node.lineno,
                        f"np.{leaf} inside kernel '{kfn.name}' runs at trace "
                        "time on the host — use jnp",
                    )
                )
            elif leaf in BANNED_JNP and q.split(".")[0] in ("jnp", "jax") or (
                q.startswith("jax.numpy.") and leaf in BANNED_JNP
            ):
                out.append(
                    Finding(
                        self.name, mod.rel, node.lineno,
                        f"jnp.{leaf} inside kernel '{kfn.name}' has no Mosaic "
                        "lowering (forces interpret-only)",
                    )
                )
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                out.append(
                    Finding(
                        self.name, mod.rel, node.lineno,
                        f"print() inside kernel '{kfn.name}' — use "
                        "pl.debug_print",
                    )
                )

        # 3. Operand-Ref indexing rank vs BlockSpec rank.
        kparams = [a.arg for a in kfn.args.posonlyargs + kfn.args.args]
        rank_by_param = {
            p: r for p, r in zip(kparams, ranks) if r is not None
        }
        for node in ast.walk(kfn):
            if not isinstance(node, ast.Subscript):
                continue
            base = node.value
            if not (isinstance(base, ast.Name) and base.id in rank_by_param):
                continue
            idx = node.slice
            if isinstance(idx, ast.Tuple) and not any(
                isinstance(e, ast.Constant) and e.value is Ellipsis
                for e in idx.elts
            ):
                if any(isinstance(e, ast.Starred) for e in idx.elts):
                    continue
                want = rank_by_param[base.id]
                if len(idx.elts) != want:
                    out.append(
                        Finding(
                            self.name,
                            mod.rel,
                            node.lineno,
                            f"'{base.id}' indexed with {len(idx.elts)} "
                            f"dims but its BlockSpec rank is {want} in "
                            f"kernel '{kfn.name}'",
                        )
                    )
        return out
