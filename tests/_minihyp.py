"""Deterministic fallback for the hypothesis API subset the suite uses.

``hypothesis`` is an optional test extra (requirements-test.txt). When it is
absent the property suites would otherwise skip wholesale; this shim keeps
them RUNNING by replaying each ``@given`` test over a fixed number of
seeded pseudo-random examples instead. It is intentionally tiny: no
shrinking, no database, no health checks — just enough of ``given`` /
``settings`` / ``strategies`` that ``tests/test_property.py`` and
``tests/test_differential.py`` execute identically-shaped cases under both
engines. Examples are derandomized (seeded from the test name), so a
failure reproduces exactly.

Profiles mirror the real API: ``conftest.py`` registers ``quick`` and
``deep`` and loads one from ``HYPOTHESIS_PROFILE``, exactly as it does for
real hypothesis — only the example counts differ (the shim explores less
per example, so it runs more of them cheaply).
"""

from __future__ import annotations

import functools
import inspect
import math
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        u = rng.random()
        if u < 0.08:  # hypothesis-style boundary pressure
            return lo
        if u < 0.16:
            return hi
        if lo > 0:  # log-uniform across positive decades
            return float(math.exp(rng.uniform(math.log(lo), math.log(hi))))
        return float(rng.uniform(lo, hi))

    return _Strategy(draw)


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements, min_size=0, max_size=10, unique=False):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        out, seen, tries = [], set(), 0
        while len(out) < n and tries < 50 * (n + 1):
            tries += 1
            v = elements.draw(rng)
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out

    return _Strategy(draw)


class settings:
    """Profile registry + per-test example-count override (decorator)."""

    _profiles: dict = {"default": {"max_examples": 10}}
    _current: dict = {"max_examples": 10}

    def __init__(self, max_examples=None, deadline=None, derandomize=None,
                 suppress_health_check=None):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._minihyp_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, max_examples=10, **_ignored):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name):
        cls._current = cls._profiles.get(name, cls._profiles["default"])


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_minihyp_max_examples", None) \
                or settings._current["max_examples"]
            base = zlib.crc32(fn.__qualname__.encode())
            for ex in range(n):
                rng = np.random.default_rng((base, ex))
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"minihyp falsifying example #{ex} for "
                        f"{fn.__qualname__}: {drawn!r}"
                    ) from e

        # Hide the strategy-drawn params from pytest's fixture resolution
        # (real hypothesis does the same); parametrize args pass through.
        run.__signature__ = inspect.Signature(
            [p for name, p in inspect.signature(fn).parameters.items()
             if name not in strats]
        )
        run.hypothesis = types.SimpleNamespace(inner_test=fn)
        return run

    return deco


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    booleans=booleans,
    sampled_from=sampled_from,
    lists=lists,
)
