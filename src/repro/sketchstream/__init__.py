"""Sketch-based streaming telemetry for training/serving (DESIGN.md §2),
plus per-tenant anomaly scoring over the windowed estimates (§8.5)."""

from . import anomaly, monitor

__all__ = ["monitor", "anomaly"]
