"""WindowArray: fused windowed update vs the K-loop oracle, and the windowed
read — union + one MLE pass (cached for the full ring, Pallas-fused for
sub-rings) vs E independent per-epoch Newton reads.

Two questions this suite answers:

  * update — the windowed update runs TWO fused DynArray updates per batch
    (head epoch + union cache). What does the temporal axis cost per element
    against (a) the K-loop of per-epoch single-Dyn updates (dispatch-bound)
    and (b) the plain cumulative DynArray it wraps (the ~2x check)?
  * estimate — at K ∈ {2^10 .. 2^18} and E ∈ {4, 16, 64}: the full-ring
    cached read (MLE on the maintained union histograms, no union pass), the
    sub-ring read (w = E/2: epoch-union + bincount + MLE), and the naive
    alternative — E independent per-epoch Newton passes (what you'd pay
    without the union algebra, and it still can't answer the window: the
    per-epoch estimates don't sum, DESIGN.md §8.5).

The sweep is cumulative over (k, e) cells (common.merge_save): quick/smoke
runs re-measure only the small cells and MERGE into
experiments/bench/window_array.json, preserving the paper-scale rows from
``--full``. Rows are stored sorted; scripts/check_bench_schema.py asserts the
schema so a broken merge fails CI loudly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, dyn_array, window_array

from . import common


def run(quick=True):
    rows = []

    # --- fused windowed update vs K-loop oracle vs cumulative DynArray -----
    n_keys, m, e_up, batch = 256, 128, 4, 4096
    n_batches = 4 if quick else 10
    cfg = SketchConfig(m=m, b=8, seed=5)
    batches = common.keyed_batches(n_keys, n_batches, batch, seed=7)

    eps_win, st_win = common.keyed_throughput(
        lambda s, k, i, w: window_array.update_batch(cfg, s, k, i, w),
        window_array.init(cfg, n_keys, e_up),
        batches,
    )
    eps_loop, st_loop = common.keyed_throughput(
        lambda s, k, i, w: window_array.update_reference(cfg, s, k, i, w),
        window_array.init(cfg, n_keys, e_up),
        batches,
    )
    eps_dyn, _ = common.keyed_throughput(
        lambda s, k, i, w: dyn_array.update_batch(cfg, s, k, i, w),
        dyn_array.init(cfg, n_keys),
        batches,
    )
    # The schedules must agree: registers bitwise, chats to f32 noise.
    if not np.array_equal(np.asarray(st_win.regs), np.asarray(st_loop.regs)):
        raise AssertionError("fused and K-loop WindowArray registers diverged")
    if not np.allclose(
        np.asarray(st_win.union_chats), np.asarray(st_loop.union_chats), rtol=1e-4
    ):
        raise AssertionError("fused and K-loop WindowArray union chats diverged")

    for method, eps in (("fused", eps_win), ("k_loop", eps_loop), ("dyn_cumulative", eps_dyn)):
        rows.append({"figure": "window_array_throughput", "method": method,
                     "k": n_keys, "e": e_up, "m": m, "mops": eps / 1e6})
        common.csv_row(f"window_array/K{n_keys}/E{e_up}/{method}", 1e6 / eps, f"mops={eps/1e6:.3f}")
    rows.append({"figure": "window_array_throughput", "method": "speedup",
                 "k": n_keys, "e": e_up, "m": m, "x": eps_win / eps_loop})
    common.csv_row(
        f"window_array/K{n_keys}/E{e_up}/speedup", 0.0,
        f"fused/loop={eps_win / eps_loop:.1f}x window/cumulative={eps_win / eps_dyn:.2f}x",
    )

    # --- windowed reads vs E independent Newton passes, (K, E) sweep -------
    # Ring-state budget: hists alone are int32[E, K, 2^b] = 1 KiB x E x K,
    # so cells beyond E*K = 2^22 (~4 GiB of state) are skipped — logged, not
    # silently dropped — rather than OOMing the sweep host.
    m_est, batch_est, cell_cap = 64, 8192, 2**22
    ks = [2**10, 2**13] if quick else [2**10, 2**14, 2**17, 2**18]
    es = [4, 8] if quick else [4, 16, 64]
    swept = {(n_keys, e_up)}
    for k in ks:
        for e in es:
            if e * k > cell_cap:
                print(f"# window_array: skipping K={k} E={e} (ring state "
                      f"E*K*2^b*4 = {e * k // 256} MiB exceeds the cell cap)",
                      flush=True)
                continue
            swept.add((k, e))
            cfg_k = SketchConfig(m=m_est, b=8, seed=17)
            st = window_array.init(cfg_k, k, e)
            rng = np.random.default_rng(k + e)
            # Donate the ring state through the load loop: without donation
            # every update/rotate call copies the full [E, K, ...] state.
            upd = jax.jit(
                lambda s, keys, ids, w: window_array.update_batch(cfg_k, s, keys, ids, w),
                donate_argnums=(0,),
            )
            rot = jax.jit(
                lambda s: window_array.rotate(cfg_k, s), donate_argnums=(0,)
            )
            # Load every epoch with enough traffic that rows are live.
            n_load = max(2 * k, batch_est)
            for _ in range(e):
                for _ in range(0, n_load, batch_est):
                    keys = jnp.asarray(rng.integers(0, k, batch_est, dtype=np.int32))
                    ids = jnp.asarray(rng.integers(0, 2**32, batch_est, dtype=np.uint32))
                    w = jnp.asarray((rng.gamma(1.0, 2.0, batch_est) + 1e-5).astype(np.float32))
                    st = upd(st, keys, ids, w)
                st = rot(st)
            jax.block_until_ready(st.union_chats)

            iters = 3 if k <= 2**13 else 1
            t_any = common.time_fn(
                lambda s: np.asarray(window_array.estimate_ring_anytime(s)), st,
                warmup=1, iters=iters,
            )
            t_ring = common.time_fn(
                lambda s: window_array.estimate_window(cfg_k, s, e), st,
                warmup=1, iters=iters,
            )
            t_sub = common.time_fn(
                lambda s: window_array.estimate_window(cfg_k, s, max(e // 2, 1)), st,
                warmup=1, iters=iters,
            )
            t_epochs = common.time_fn(
                lambda s: window_array.estimate_epochs_all(cfg_k, s), st,
                warmup=1, iters=iters,
            )
            x = t_epochs / max(t_ring, 1e-9)
            rows += [
                {"figure": "window_array_estimate", "method": "anytime_read", "k": k, "e": e, "m": m_est, "ms": t_any * 1e3},
                {"figure": "window_array_estimate", "method": "full_ring_cached", "k": k, "e": e, "m": m_est, "ms": t_ring * 1e3},
                {"figure": "window_array_estimate", "method": "subring_union", "k": k, "e": e, "m": m_est, "ms": t_sub * 1e3},
                {"figure": "window_array_estimate", "method": "per_epoch_newton", "k": k, "e": e, "m": m_est, "ms": t_epochs * 1e3},
                {"figure": "window_array_estimate", "method": "speedup", "k": k, "e": e, "m": m_est, "x": x},
            ]
            common.csv_row(f"window_array_estimate/K{k}/E{e}/anytime_read", t_any * 1e6, f"ms={t_any*1e3:.3f}")
            common.csv_row(f"window_array_estimate/K{k}/E{e}/full_ring_cached", t_ring * 1e6, f"ms={t_ring*1e3:.3f}")
            common.csv_row(f"window_array_estimate/K{k}/E{e}/subring_union", t_sub * 1e6, f"ms={t_sub*1e3:.3f}")
            common.csv_row(f"window_array_estimate/K{k}/E{e}/per_epoch_newton", t_epochs * 1e6, f"ms={t_epochs*1e3:.1f}")
            common.csv_row(f"window_array_estimate/K{k}/E{e}/speedup", 0.0, f"epochs/ring={x:.0f}x anytime={t_any*1e3:.3f}ms")

    common.merge_save("window_array", rows, swept, sweep_keys=("k", "e"))
    return rows


def run_sharded(quick=True):
    """Sharded WindowArray vs the single-host ring: windowed update
    throughput, shard-local rotation, and the windowed reads as (K, E)
    grow past one host.

    Uses every visible device as a shard of the ``sketch`` mesh axis. Both
    schedules see identical batches and rotations, and every ring/union
    leaf is asserted bit-identical per cell (the epoch-plane max-union
    commutes with row sharding, DESIGN.md §8.6). Cumulative over (k, e)
    cells into experiments/bench/window_array_sharded.json
    (common.merge_save), so smoke runs never erase paper-scale rows.
    """
    from repro.core import sharded_window_array, sharding
    from repro.launch.mesh import make_sketch_mesh

    mesh = make_sketch_mesh()
    n_dev = sharding.num_shards(mesh)
    m, batch = 64, 8192
    n_batches = 4 if quick else 8
    cells = [(2**10, 4), (2**13, 4)] if quick else [(2**10, 4), (2**14, 4), (2**17, 8)]

    rows = []
    for k, e in cells:
        cfg = SketchConfig(m=m, b=8, seed=17)
        batches = common.keyed_batches(k, n_batches, batch, seed=k + e)

        eps_single, st_single = common.keyed_throughput(
            lambda s, keys, i, w: window_array.update_batch(cfg, s, keys, i, w),
            window_array.init(cfg, k, e),
            batches,
        )
        eps_shard, st_shard = common.keyed_throughput(
            lambda s, keys, i, w: sharded_window_array.update_batch(cfg, mesh, s, keys, i, w),
            sharded_window_array.init(cfg, k, e, mesh),
            batches,
        )
        # One rotation each (same clock), then assert bit-identity leafwise.
        st_single = window_array.rotate(cfg, st_single)
        st_shard = sharded_window_array.rotate(cfg, mesh, st_shard)
        for name in ("regs", "hists", "chats", "union_regs", "union_hists", "union_chats"):
            if not np.array_equal(
                np.asarray(getattr(st_shard, name)), np.asarray(getattr(st_single, name))
            ):
                raise AssertionError(
                    f"sharded and single-host WindowArray {name} diverged at K={k} E={e}"
                )

        t_rot = common.time_fn(
            lambda s: sharded_window_array.rotate(cfg, mesh, s), st_shard,
            warmup=1, iters=3,
        )
        t_ring = common.time_fn(
            lambda s: sharded_window_array.estimate_window(cfg, mesh, s, e), st_shard,
            warmup=1, iters=3,
        )
        t_ring_single = common.time_fn(
            lambda s: window_array.estimate_window(cfg, s, e), st_single,
            warmup=1, iters=3,
        )
        t_sub = common.time_fn(
            lambda s: sharded_window_array.estimate_window(cfg, mesh, s, max(e // 2, 1)),
            st_shard, warmup=1, iters=3,
        )
        rows += [
            {"figure": "window_array_sharded_throughput", "method": "single_host", "k": k, "e": e, "m": m, "mops": eps_single / 1e6},
            {"figure": "window_array_sharded_throughput", "method": f"sharded_x{n_dev}", "k": k, "e": e, "m": m, "shards": n_dev, "mops": eps_shard / 1e6},
            {"figure": "window_array_sharded_throughput", "method": "speedup", "k": k, "e": e, "m": m, "x": eps_shard / eps_single},
            {"figure": "window_array_sharded_estimate", "method": "rotate", "k": k, "e": e, "m": m, "ms": t_rot * 1e3},
            {"figure": "window_array_sharded_estimate", "method": "full_ring_cached", "k": k, "e": e, "m": m, "ms": t_ring * 1e3},
            {"figure": "window_array_sharded_estimate", "method": "full_ring_single_host", "k": k, "e": e, "m": m, "ms": t_ring_single * 1e3},
            {"figure": "window_array_sharded_estimate", "method": "subring_union", "k": k, "e": e, "m": m, "ms": t_sub * 1e3},
            {"figure": "window_array_sharded_estimate", "method": "speedup", "k": k, "e": e, "m": m, "x": t_ring_single / max(t_ring, 1e-9)},
        ]
        common.csv_row(f"window_array_sharded/K{k}/E{e}/single_host", 1e6 / eps_single, f"mops={eps_single/1e6:.3f}")
        common.csv_row(f"window_array_sharded/K{k}/E{e}/sharded_x{n_dev}", 1e6 / eps_shard, f"mops={eps_shard/1e6:.3f}")
        common.csv_row(
            f"window_array_sharded/K{k}/E{e}/reads", t_ring * 1e6,
            f"ring={t_ring*1e3:.2f}ms sub={t_sub*1e3:.2f}ms rotate={t_rot*1e3:.2f}ms",
        )

    common.merge_save("window_array_sharded", rows, set(cells), sweep_keys=("k", "e"))
    return rows
