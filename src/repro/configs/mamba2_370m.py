"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified].
Pure Mamba2 blocks (no FFN, no attention): d_inner = 2*1024, head_dim 64 ->
32 SSD heads. O(1) decode state -> the flagship long_500k architecture.
Embeddings tied (the 370m budget requires it, as in the released model).
"""

from repro.models import LayerSpec, ModelConfig, SSMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        n_layers=48,
        d_model=1024,
        n_heads=32,
        n_kv_heads=32,
        d_ff=0,
        vocab=50280,
        pattern=(LayerSpec(mixer="mamba", ffn="none"),),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
        tie_embeddings=True,
        max_seq=8192,
        sub_quadratic=True,
    )
