"""End-to-end driver: train an LM with QSketch token-coverage telemetry.

Runs the full production train loop (launch/train.py): AdamW, atomic
checkpoints + auto-resume, straggler watchdog, and the in-step QSketch
monitor whose 'distinct_tokens_est' metric tracks how much of the vocab the
model has actually seen — the sketch costs 512 int8 registers and merges
across any fleet by max.

Default: a 16M-param LM for 40 steps (CPU-friendly). The assignment-scale
run is one flag away:

    PYTHONPATH=src python examples/train_lm_monitored.py            # 16M demo
    PYTHONPATH=src python examples/train_lm_monitored.py --full     # ~100M, 300 steps
"""

import argparse
import json
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params, 300 steps")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.full:
        arch, steps, batch, seq = "small-lm-100m", 300, 8, 512
    else:
        arch, steps, batch, seq = "small-lm-16m", 40, 4, 128
    steps = args.steps or steps

    mfile = "experiments/train_lm_monitored.metrics.jsonl"
    final = train_mod.main([
        "--arch", arch, "--steps", str(steps), "--batch", str(batch), "--seq", str(seq),
        "--ckpt-dir", f"checkpoints/{arch}", "--ckpt-every", "20",
        "--log-every", "5", "--metrics-file", mfile, "--lr", "1e-3",
    ])

    lines = [json.loads(l) for l in open(mfile)]
    print("\nstep   loss     distinct-tokens-est (sketch)")
    for l in lines:
        print(f"{l['step']:>4}  {l['loss']:7.3f}  {l.get('distinct_tokens_est', float('nan')):12.0f}")
    print(f"\ntrained to step {final}; checkpoints in checkpoints/{arch}/ "
          f"(restart this script to watch auto-resume).")


if __name__ == "__main__":
    main()
