"""WindowArray: sliding-window weighted cardinality over K tenants.

Every estimate the repo produced so far is *cumulative* — "weighted distinct
traffic since init". The paper's headline application (real-time anomaly
detection) consumes the *time-scoped* form: "weighted distinct traffic in the
last W minutes". This module adds the temporal axis as a ring of E epoch
sub-states layered on the DynArray (Wang et al. 2018 in PAPERS.md shows the
register-sharing machinery extends to time-scoped estimates; we get the same
effect from plain epoch rings because register max-merge is lossless).

State (``WindowArrayState``): ``int8[E, K, m]`` registers + per-epoch DynArray
histograms/chats, a ``head`` ring pointer, and a cached *union* sub-state
(max over all E epochs, with DynArray histogram + martingale maintenance on
top). Semantics:

* ``update_batch`` folds a keyed batch into the CURRENT epoch — one fused
  DynArray update on the head sub-state, and the same elements through the
  union sub-state (2x the DynArray update cost, still independent of K and E).
* ``rotate()`` closes the current epoch: O(1) ring bookkeeping (advance
  ``head``, reset the slot it lands on — evicting the oldest epoch once the
  ring is full) plus a rebuild of the union cache from the surviving epochs
  (O(E·K·m), paid at rotation cadence, amortized over an epoch of updates).
* ``estimate_window(w)`` answers "weighted cardinality over the last
  w <= E epochs": all-max union of the w epoch register planes — EXACT,
  the union of epoch streams is sketched by the register-wise max — read out
  with the vmapped histogram MLE. Per-epoch chats can NOT be summed across
  epochs (an element alive in two epochs would double-count; DESIGN.md §8.5),
  which is why sub-ring windows pay the MLE. The full-ring window w == E
  skips the union+bincount entirely: the cached ``union_hists`` are
  maintained incrementally and the read is bit-identical to the from-scratch
  path. ``ops.window_union_estimate_op`` is the fused kernel form of the
  sub-ring read (no [w, K, m] intermediate).
* ``estimate_ring_anytime`` is the O(K) fast path for the full-ring window:
  a pure read of the running union martingales (exact §4.3 chain within the
  current epoch, MLE re-based at each rotation) — what a per-step anomaly
  detector consumes (sketchstream/anomaly.py).

Window semantics: epochs are closed by the caller's clock (``rotate`` per
wall-time tick / N batches), so "the last w epochs" is a tumbling-grain
sliding window with grain = one epoch. ``filled`` tracks how many ring slots
have ever been active; w beyond it clamps harmlessly (unfilled slots hold
r_min everywhere and are no-ops in the union).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dyn_array, hashing, key_directory, qsketch_dyn
from .types import DynArrayState, SketchConfig, WindowArrayState


def init(cfg: SketchConfig, k: int, e: int) -> WindowArrayState:
    """K tenants x E ring epochs; epoch 0 starts as the current epoch."""
    if k < 1:
        raise ValueError("WindowArray needs k >= 1 sketches")
    if e < 2:
        raise ValueError("WindowArray needs e >= 2 epochs (e == 1 is a DynArray)")
    return WindowArrayState(
        regs=jnp.full((e, k, cfg.m), cfg.r_min, dtype=jnp.int8),
        hists=jnp.zeros((e, k, cfg.num_bins), dtype=jnp.int32),
        chats=jnp.zeros((e, k), dtype=jnp.float32),
        union_regs=jnp.full((k, cfg.m), cfg.r_min, dtype=jnp.int8),
        union_hists=jnp.zeros((k, cfg.num_bins), dtype=jnp.int32),
        union_chats=jnp.zeros((k,), dtype=jnp.float32),
        head=jnp.int32(0),
        filled=jnp.int32(1),
        epoch_id=jnp.int32(0),
    )


def num_epochs(state: WindowArrayState) -> int:
    """Ring size E (the epoch-plane count of every per-epoch leaf)."""
    return state.regs.shape[0]


def num_sketches(state: WindowArrayState) -> int:
    """Tenant capacity K (the row count within each epoch plane)."""
    return state.regs.shape[1]


def epoch_substate(state: WindowArrayState, e) -> DynArrayState:
    """Epoch slot e's sub-state as a DynArray (a view, not a copy under jit)."""
    return DynArrayState(
        regs=state.regs[e], hists=state.hists[e], chats=state.chats[e]
    )


def union_substate(state: WindowArrayState) -> DynArrayState:
    """The cached full-ring union as a DynArray (a view, not a copy)."""
    return DynArrayState(
        regs=state.union_regs, hists=state.union_hists, chats=state.union_chats
    )


def _apply_update(cfg: SketchConfig, state: WindowArrayState, keys, lo, hi, w, live):
    """Shared tail of the single-host and sharded windowed updates: two fused
    DynArray updates on the same dedup'd elements — the head epoch sub-state
    and the union cache. ``keys`` are in-range row indices and ``live`` is
    the final element mask (padding, degenerate weights and — in the sharded
    form — foreign shards' elements already dropped)."""
    ep = epoch_substate(state, state.head)
    q_ep = qsketch_dyn._q_update_prob(cfg, ep.hists[keys], w)
    ep = dyn_array._apply_update(cfg, ep, keys, lo, hi, w, live, q_ep)

    un = union_substate(state)
    q_un = qsketch_dyn._q_update_prob(cfg, un.hists[keys], w)
    un = dyn_array._apply_update(cfg, un, keys, lo, hi, w, live, q_un)

    return state._replace(
        regs=state.regs.at[state.head].set(ep.regs),
        hists=state.hists.at[state.head].set(ep.hists),
        chats=state.chats.at[state.head].set(ep.chats),
        union_regs=un.regs,
        union_hists=un.hists,
        union_chats=un.chats,
    )


def _update_batch_impl(
    cfg: SketchConfig, state: WindowArrayState, keys, ids, weights, mask=None
) -> WindowArrayState:
    k = state.regs.shape[1]
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    live = qsketch_dyn._live_weight_mask(w, mask)
    return _apply_update(cfg, state, keys, lo, hi, w, live)


_update_batch_jit = jax.jit(_update_batch_impl, static_argnums=(0,))
_update_batch_donated = jax.jit(
    _update_batch_impl, static_argnums=(0,), donate_argnums=(1,)
)


def update_batch(
    cfg: SketchConfig, state: WindowArrayState, keys, ids, weights, mask=None,
    *, donate: bool = False,
) -> WindowArrayState:
    """Fold one keyed batch into the current epoch (and the union cache).

    Same contract as ``dyn_array.update_batch`` (keys clipped to [0, K),
    masked / degenerate-weight rows dropped before dedup). Two fused DynArray
    updates run on the same dedup'd elements:

    * the head epoch sub-state — its registers/hists/chats stay bit-identical
      to a standalone DynArray fed only this epoch's sub-stream;
    * the union sub-state — q_R and change-indicators against the UNION
      batch-start state, advancing the full-ring anytime martingale.

    The union-regs invariant (union == max over epochs) is preserved exactly:
    an element raises union[k, j] iff its y exceeds the union register, which
    already dominates the epoch register it also raises.

    ``donate=True`` hands the (large: int8[E, K, m] + int32[E, K, 2^b]) ring
    state to XLA for in-place reuse — the steady-state ingest mode; the
    caller's ``state`` is dead afterwards (``dyn_array.update_batch`` has the
    full contract).
    """
    fn = _update_batch_donated if donate else _update_batch_jit
    return fn(cfg, state, keys, ids, weights, mask)


def _rotate_impl(cfg: SketchConfig, state: WindowArrayState) -> WindowArrayState:
    """Close the current epoch and open the next ring slot.

    Ring bookkeeping is O(1): advance ``head`` and reset the slot it lands on
    — once the ring is full that slot holds the OLDEST epoch, which is
    thereby evicted (its elements leave every window). The union cache is
    then rebuilt from the surviving epoch planes (O(E·K·m) + histogram
    rebuild + one vmapped MLE pass, rotation-cadence cost) and the running
    union martingale re-bases to the MLE of the surviving union — eviction
    can lower the union, which no running martingale can track (DESIGN.md
    §8.5). ``epoch_id`` advances monotonically: it is the clock fed to
    ``key_directory.evict_older_than`` for cold-tenant aging.
    """
    e, k, m = state.regs.shape
    head = (state.head + 1) % e
    regs = state.regs.at[head].set(jnp.full((k, m), cfg.r_min, jnp.int8))
    hists = state.hists.at[head].set(jnp.zeros((k, cfg.num_bins), jnp.int32))
    chats = state.chats.at[head].set(jnp.zeros((k,), jnp.float32))
    union_regs = jnp.max(regs, axis=0)
    union_hists = dyn_array.rebuild_hists(cfg, union_regs)
    return WindowArrayState(
        regs=regs,
        hists=hists,
        chats=chats,
        union_regs=union_regs,
        union_hists=union_hists,
        union_chats=_chats_from_touched_hists(cfg, union_hists),
        head=head,
        filled=jnp.minimum(state.filled + 1, e),
        epoch_id=state.epoch_id + 1,
    )


_rotate_jit = jax.jit(_rotate_impl, static_argnums=(0,))
_rotate_donated = jax.jit(_rotate_impl, static_argnums=(0,), donate_argnums=(1,))


def rotate(
    cfg: SketchConfig, state: WindowArrayState, *, donate: bool = False
) -> WindowArrayState:
    """Close the current epoch and open the next ring slot (see
    ``_rotate_impl`` for the full semantics: O(1) ring bookkeeping, oldest-
    epoch eviction, union-cache rebuild, martingale re-base, monotone
    ``epoch_id``). ``donate=True`` reuses the ring buffers in place — safe
    whenever the pre-rotation state is not read again (the ingest layer's
    retire barrier guarantees exactly that)."""
    fn = _rotate_donated if donate else _rotate_jit
    return fn(cfg, state)


def _chats_from_touched_hists(cfg: SketchConfig, hists, solver: str = "newton") -> jnp.ndarray:
    """Per-row MLE Ĉ from touched-register histograms (bin 0 pinned to 0,
    the stored convention): fill bin 0 with the untouched count and run the
    shared histogram MLE — bit-identical to walking the registers again,
    without the second O(K·m) histogram pass."""
    full = hists.at[:, 0].set(cfg.m - jnp.sum(hists, axis=1))
    return dyn_array.estimate_mle_hists(cfg, full, solver=solver)


def _window_slots(state: WindowArrayState, w: int) -> jnp.ndarray:
    """Ring slots of the last w epochs, newest first: head, head-1, ..."""
    e = state.regs.shape[0]
    return (state.head - jnp.arange(w, dtype=jnp.int32)) % e


def window_union_regs(state: WindowArrayState, w: int) -> jnp.ndarray:
    """Exact union registers of the last w epochs, int8[K, m] (pure-JAX path;
    materializes the [w, K, m] gather — the Pallas op streams instead)."""
    return jnp.max(state.regs[_window_slots(state, w)], axis=0)


def _check_w(state: WindowArrayState, w: int) -> int:
    e = state.regs.shape[0]
    w = int(w)
    if not 1 <= w <= e:
        raise ValueError(f"window w={w} out of range [1, E={e}]")
    return w


@functools.partial(jax.jit, static_argnums=(0, 2), static_argnames=("solver",))
def _estimate_subring(cfg: SketchConfig, state: WindowArrayState, w: int, *, solver: str = "newton"):
    return dyn_array.estimate_mle_rows(cfg, window_union_regs(state, w), solver=solver)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def _estimate_full_ring(cfg: SketchConfig, state: WindowArrayState, *, solver: str = "newton"):
    """Cached path: the union histograms are maintained incrementally, so the
    full-ring read skips union + bincount and goes straight to the MLE."""
    return _chats_from_touched_hists(cfg, state.union_hists, solver=solver)


def estimate_window(
    cfg: SketchConfig, state: WindowArrayState, w: int, *, solver: str = "newton"
) -> jnp.ndarray:
    """Ĉ[K] over the last w <= E epochs (w static, host-side int).

    Union-of-epochs registers -> batched histogram MLE. Bit-identical to
    rebuilding the retained epochs from their element logs (registers are
    max-monoid, estimation is a pure function of the union histogram). The
    full-ring window reads the cached union histograms — same bits, no
    union/bincount pass. Epochs beyond ``filled`` hold r_min everywhere, so
    w > filled clamps harmlessly; untouched windows report Ĉ = 0.
    ``solver`` picks newton / lut / fused (core/estimation.py; the full-ring
    path is histogram-fed, so "fused" applies to sub-ring reads only).
    """
    w = _check_w(state, w)
    if w == state.regs.shape[0]:
        return _estimate_full_ring(cfg, state, solver=solver)
    return _estimate_subring(cfg, state, w, solver=solver)


def estimate_ring_anytime(state: WindowArrayState) -> jnp.ndarray:
    """O(K) anytime read of the full-ring window: the running union
    martingales. Exact §4.3 semantics within the current epoch; re-based to
    the union MLE at every rotation (== ``estimate_window(E)`` at that
    instant). The per-step fast path anomaly scoring consumes."""
    return state.union_chats


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_epochs_all(
    cfg: SketchConfig, state: WindowArrayState, *, solver: str = "newton"
) -> jnp.ndarray:
    """Per-epoch MLE re-estimates, Ĉ[E, K] — the naive alternative the
    windowed read replaces (E independent solve passes; benchmarked in
    benchmarks/window_array.py). Per-epoch anytime reads are ``state.chats``.
    """
    e, k, m = state.regs.shape
    return dyn_array.estimate_mle_rows(
        cfg, state.regs.reshape(e * k, m), solver=solver
    ).reshape(e, k)


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    state: WindowArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
):
    """Sparse-tenant entry: route 64-bit tenant ids through the key directory
    (stamping each routed slot with the window's monotone ``epoch_id`` so
    cold-tenant aging can use the ring as its clock), then run the fused
    keyed update. Returns (state, directory telemetry).
    """
    if dcfg.capacity != state.regs.shape[1]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != WindowArray rows {state.regs.shape[1]}"
        )
    slots, dir_state = key_directory.route(
        dcfg, dir_state, tenant_keys, mask=mask, epoch=state.epoch_id
    )
    return update_batch(cfg, state, slots, ids, weights, mask=mask), dir_state


def check_ring_aligned(a: WindowArrayState, b: WindowArrayState) -> None:
    """Shared merge validation (single-host AND sharded fronts): two windows
    combine only with matching geometry and an aligned ring clock. Host-side
    entry — head/filled/epoch_id must be concrete."""
    if a.regs.shape != b.regs.shape:
        raise ValueError(
            f"WindowArray merge needs matching (E, K, m), got {a.regs.shape} vs {b.regs.shape}"
        )
    if (int(a.head), int(a.filled), int(a.epoch_id)) != (
        int(b.head),
        int(b.filled),
        int(b.epoch_id),
    ):
        raise ValueError(
            "WindowArray merge needs ring-aligned states (same head/filled/"
            "epoch_id): pods must rotate on a shared clock"
        )


def _merged_arrays(cfg: SketchConfig, regs_a, regs_b):
    """Array tail of the ring-aligned merge, shared with the sharded front
    (runs shard-local there): per-epoch register max, histogram rebuilds,
    MLE re-estimated chats, union-cache rebuild. Returns the six array
    fields of the merged state (ring scalars are the caller's)."""
    e, k, m = regs_a.shape
    regs = jnp.maximum(regs_a, regs_b)
    flat_hists = dyn_array.rebuild_hists(cfg, regs.reshape(e * k, m))
    union_regs = jnp.max(regs, axis=0)
    union_hists = dyn_array.rebuild_hists(cfg, union_regs)
    return (
        regs,
        flat_hists.reshape(e, k, cfg.num_bins),
        _chats_from_touched_hists(cfg, flat_hists).reshape(e, k),
        union_regs,
        union_hists,
        _chats_from_touched_hists(cfg, union_hists),
    )


def merge(cfg: SketchConfig, a: WindowArrayState, b: WindowArrayState) -> WindowArrayState:
    """Cross-pod merge of ring-ALIGNED windows (same E/K/m, same head/filled/
    epoch_id — pods rotate on a shared clock).

    Per-epoch registers max-merge (exact union of that epoch's streams);
    per-epoch histograms rebuild and chats re-estimate via the MLE (running
    martingales are not additive across pods that may share elements, exactly
    as ``dyn_array.merge``); the union cache rebuilds from the merged epochs.
    Host-side entry (concrete head/filled): alignment is checked eagerly.
    """
    check_ring_aligned(a, b)
    regs, hists, chats, union_regs, union_hists, union_chats = _merged_arrays(
        cfg, a.regs, b.regs
    )
    return WindowArrayState(
        regs=regs,
        hists=hists,
        chats=chats,
        union_regs=union_regs,
        union_hists=union_hists,
        union_chats=union_chats,
        head=a.head,
        filled=a.filled,
        epoch_id=a.epoch_id,
    )


def update_reference(
    cfg: SketchConfig, state: WindowArrayState, keys, ids, weights, mask=None
) -> WindowArrayState:
    """Oracle: the K-loop ``dyn_array.update_reference`` applied to the head
    epoch AND the union sub-state (each is a DynArray fed the same keyed
    batch). O(K) dispatches — tests/benchmarks only, never the hot path.
    Host-side entry: ``state.head`` must be concrete.
    """
    head = int(state.head)
    ep = dyn_array.update_reference(
        cfg, epoch_substate(state, head), keys, ids, weights, mask=mask
    )
    un = dyn_array.update_reference(
        cfg, union_substate(state), keys, ids, weights, mask=mask
    )
    return state._replace(
        regs=state.regs.at[head].set(ep.regs),
        hists=state.hists.at[head].set(ep.hists),
        chats=state.chats.at[head].set(ep.chats),
        union_regs=un.regs,
        union_hists=un.hists,
        union_chats=un.chats,
    )
