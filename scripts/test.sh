#!/usr/bin/env bash
# Tier-1 test entry: one command, correct env.
#
#   scripts/test.sh                 # full tier-1 suite
#   scripts/test.sh tests/test_kernels.py -k qsketch   # pass-through args
#
# - PYTHONPATH=src so `repro` imports without an install step.
# - XLA_FLAGS exposes 8 host devices (per SNIPPETS.md) so mesh/sharding tests
#   exercise multi-device code paths on a CPU-only box; an existing
#   XLA_FLAGS setting is preserved and extended.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

exec python -m pytest -x -q "$@"
