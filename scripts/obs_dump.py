#!/usr/bin/env python
"""obs_dump — inspect and assert on qobs observability artifacts.

Subcommands (all read host-side artifacts the obs layer writes — see
DESIGN.md §10):

  jsonl PATH  [--require NAME ...]   summarize a --obs-jsonl metrics log:
                                     record count, series of the last
                                     snapshot; --require fails (exit 1) if a
                                     metric family is absent (CI smoke).
  trace PATH  [--require SPAN ...]   summarize a --obs-trace Chrome trace:
                                     per-span counts and total seconds;
                                     --require fails if a span is absent.
  prom PATH   [--require NAME ...]   summarize a --obs-prom textfile:
                                     family list; --require as above.
  health      [--container qsketch]  build a healthy and a synthetically
                                     top-bin-saturated sketch, print both
                                     health reports, and fail unless the
                                     saturated one warns while the healthy
                                     one stays quiet (the acceptance probe).

Usage:
  PYTHONPATH=src python scripts/obs_dump.py jsonl /tmp/obs.jsonl \
      --require ingest_elements_pushed tenant_slots_claimed
  PYTHONPATH=src python scripts/obs_dump.py health
"""

from __future__ import annotations

import argparse
import json
import re
import sys


def _family(series_name: str) -> str:
    """``name{a="x"}`` -> ``name`` (a bare name maps to itself)."""
    return series_name.split("{", 1)[0]


def _check_required(present: set, required: list, what: str) -> int:
    missing = [r for r in required if r not in present]
    if missing:
        print(f"obs_dump: MISSING {what}: {', '.join(missing)}", file=sys.stderr)
        return 1
    if required:
        print(f"obs_dump: all {len(required)} required {what} present")
    return 0


def cmd_jsonl(args) -> int:
    """Summarize a JSONL metrics log; enforce --require families."""
    recs = []
    with open(args.path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    if not recs:
        print("obs_dump: empty JSONL log", file=sys.stderr)
        return 1
    last = recs[-1].get("metrics", {})
    fams = sorted({_family(k) for k in last})
    print(f"{args.path}: {len(recs)} records, last snapshot has "
          f"{len(last)} series over {len(fams)} families")
    for k in sorted(last):
        v = last[k]
        if isinstance(v, dict):  # histogram payload
            v = f"histogram(count={v.get('count')}, sum={v.get('sum')})"
        print(f"  {k} = {v}")
    return _check_required(set(fams), args.require, "metric families")


def cmd_trace(args) -> int:
    """Summarize a Chrome trace JSON; enforce --require span names."""
    with open(args.path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", [])
    totals: dict[str, list] = {}
    for ev in events:
        agg = totals.setdefault(ev["name"], [0, 0.0])
        agg[0] += 1
        agg[1] += ev.get("dur", 0.0) / 1e6
    print(f"{args.path}: {len(events)} events over {len(totals)} span names")
    for name in sorted(totals):
        n, secs = totals[name]
        print(f"  {name}: n={n} total={secs:.4f}s")
    return _check_required(set(totals), args.require, "spans")


def cmd_prom(args) -> int:
    """Summarize a Prometheus textfile; enforce --require family names."""
    fams = []
    with open(args.path) as f:
        for line in f:
            m = re.match(r"# TYPE (\S+) (\S+)", line)
            if m:
                fams.append((m.group(1), m.group(2)))
    print(f"{args.path}: {len(fams)} families")
    for name, kind in fams:
        print(f"  {name} ({kind})")
    return _check_required({n for n, _ in fams}, args.require, "families")


def cmd_health(args) -> int:
    """Acceptance probe: saturated sketch warns, healthy sketch is quiet."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import qsketch
    from repro.core.types import QSketchState, SketchConfig
    from repro.obs import health

    cfg = SketchConfig(m=128)
    rng = np.random.default_rng(7)
    ids = jnp.asarray(rng.integers(0, 2**63, 800, dtype=np.int64))
    w = jnp.asarray(rng.uniform(0.1, 2.0, 800), jnp.float32)
    healthy = qsketch.update(cfg, qsketch.init(cfg), ids, w)
    saturated = QSketchState(
        regs=jnp.full((cfg.m,), cfg.r_max, dtype=jnp.int8)
    )

    ok = 0
    for label, state in (("healthy", healthy), ("saturated", saturated)):
        rep = health.health_report(cfg, state)
        print(f"[{label}] ok={rep['ok']} warnings={rep['warnings']}")
        for name, c in rep["checks"].items():
            print(f"  {name}: value={c['value']:.4g} "
                  f"threshold={c['threshold']} warn={c['warn']}")
        if label == "healthy" and not rep["ok"]:
            print("obs_dump: healthy sketch raised warnings", file=sys.stderr)
            ok = 1
        if label == "saturated" and (
            rep["ok"] or "register_saturation_frac" not in rep["warnings"]
        ):
            print("obs_dump: saturated sketch did not warn", file=sys.stderr)
            ok = 1
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, fn in (("jsonl", cmd_jsonl), ("trace", cmd_trace),
                     ("prom", cmd_prom)):
        p = sub.add_parser(name)
        p.add_argument("path")
        p.add_argument("--require", nargs="*", default=[])
        p.set_defaults(fn=fn)
    ph = sub.add_parser("health")
    ph.set_defaults(fn=cmd_health)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
