"""The qlint baseline: checked-in suppressions for grandfathered findings.

Policy (DESIGN.md §9): a finding lands in the baseline only with a written
justification — either the flagged code is deliberately outside the
contract (e.g. a documented host-side entry point the purity rule's
conservative reachability over-approximates) or fixing it is tracked
elsewhere. Entries match on the finding's line-number-free key
(``rule::path::message``), so they survive unrelated edits but die with the
code they excuse: rename the symbol or fix the site and the entry goes
stale (``--prune-baseline`` drops stale entries).

File format (``scripts/qlint_baseline.json``)::

    {"entries": [{"key": "...", "justification": "..."}]}

Inline escape hatch: a ``# qlint: disable=<rule>`` comment on the finding
line suppresses it without a baseline entry — for single sites where the
justification reads best next to the code.
"""

from __future__ import annotations

import json
import os
import re

from repro.analysis.findings import Finding

_INLINE = re.compile(r"#\s*qlint:\s*disable=([\w,\- ]+)")


class Baseline:
    """In-memory view of the suppression file (missing file = empty)."""

    def __init__(self, path: str | None):
        self.path = path
        self.entries: dict[str, str] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                data = json.load(f)
            for entry in data.get("entries", []):
                self.entries[entry["key"]] = entry.get("justification", "")

    def justification(self, finding: Finding) -> str | None:
        """The entry's justification if ``finding`` is baselined, else None."""
        return self.entries.get(finding.key)

    def stale_keys(self, findings: list[Finding]) -> list[str]:
        """Baseline entries no current finding matches (candidates to prune)."""
        live = {f.key for f in findings}
        return [k for k in self.entries if k not in live]

    def save(self, path: str | None = None) -> None:
        """Write the entries back out, sorted by key."""
        path = path or self.path
        assert path is not None
        data = {
            "_policy": (
                "Every entry needs a justification (DESIGN.md §9). Keys are "
                "rule::path::message (no line numbers)."
            ),
            "entries": [
                {"key": k, "justification": v}
                for k, v in sorted(self.entries.items())
            ],
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")


def inline_suppressed(finding: Finding, source_lines: list[str]) -> bool:
    """True if ``# qlint: disable=<rule>`` sits on the finding's line or on
    a comment-only line immediately above it."""
    if not 1 <= finding.line <= len(source_lines):
        return False
    candidates = [source_lines[finding.line - 1]]
    prev = source_lines[finding.line - 2] if finding.line >= 2 else ""
    if prev.lstrip().startswith("#"):
        candidates.append(prev)
    for text in candidates:
        m = _INLINE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",")}
            if finding.rule in rules or "all" in rules:
                return True
    return False
