"""Shared benchmark utilities: timing, method registry plumbing, output."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = "experiments/bench"


def save(name: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def merge_save(name: str, rows, swept, sweep_keys=("k",)):
    """Cumulative save for sweep suites: keep prior rows whose sweep cell was
    NOT re-measured, so quick/smoke runs never erase the paper-scale rows a
    ``--full`` run paid for.

    ``swept`` is the set of sweep-cell tuples this run measured (e.g.
    {(1024,), (16384,)} for sweep_keys=("k",), or (k, e) pairs for the window
    suite). Rows are stored sorted by (figure, method, *sweep cell) — the
    schema scripts/check_bench_schema.py asserts (monotone k within a group),
    so a broken merge fails CI loudly instead of silently dropping or
    duplicating cells.
    """
    swept = {t if isinstance(t, tuple) else (t,) for t in swept}

    def cell(r):
        return tuple(r.get(k) for k in sweep_keys)

    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        rows = [r for r in old if cell(r) not in swept] + rows
    rows = sorted(
        rows,
        key=lambda r: (
            str(r.get("figure")),
            str(r.get("method")),
            tuple((v is None, v) for v in cell(r)),
        ),
    )
    return save(name, rows)


def keyed_batches(n_keys, n_batches, batch, seed=0):
    """(keys, ids, gamma weights) batches for the keyed-update suites."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        keys = jnp.asarray(rng.integers(0, n_keys, batch, dtype=np.int32))
        ids = jnp.asarray(rng.integers(0, 2**32, batch, dtype=np.uint32))
        w = jnp.asarray((rng.gamma(1.0, 2.0, batch) + 1e-5).astype(np.float32))
        out.append((keys, ids, w))
    return out


def keyed_throughput(update_fn, state, batches):
    """Elements/s of a keyed update over pre-built batches (first batch is
    the warmup: compile + occupancy). Returns (eps, final state)."""
    state = update_fn(state, *batches[0])
    jax.block_until_ready(jax.tree.leaves(state))
    t0 = time.perf_counter()
    n = 0
    for keys, ids, w in batches[1:]:
        state = update_fn(state, keys, ids, w)
        n += len(ids)
    jax.block_until_ready(jax.tree.leaves(state))
    return n / (time.perf_counter() - t0), state


def time_fn(fn, *args, warmup=2, iters=5):
    """Median wall time of a jitted fn (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def rrmse(estimates, true_c):
    e = np.asarray(estimates, dtype=np.float64)
    return float(np.sqrt(np.mean(((e - true_c) / true_c) ** 2)))


def aare(estimates, trues):
    e = np.asarray(estimates, np.float64)
    t = np.asarray(trues, np.float64)
    return float(np.mean(np.abs(e - t) / np.abs(t)))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)
