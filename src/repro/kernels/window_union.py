"""Pallas TPU kernel: fused epoch-union + per-row register bincount.

The windowed read ``window_array.estimate_window(w)`` needs, per tenant row,
the FULL value histogram of the max-union of the last w epoch register
planes. The pure-JAX path gathers ``regs[idx]`` — an HBM-resident
``[w, K, m]`` intermediate — before reducing. This kernel streams the epoch
planes through VMEM instead:

  grid = (k_block, E), epochs innermost ("arbitrary"): the (K_blk × m) union
  accumulator tile lives in the output ref across the epoch sweep; each epoch
  contributes ``max`` if an SMEM-free per-epoch include flag (computed from
  ``head`` and w by the wrapper) selects it, else r_min. On the LAST epoch
  step the resident union tile is bincounted into the second output — a
  fori_loop over the 2^b bins, each a masked lane-reduction — so neither the
  ``[w, K, m]`` gather nor a second HBM pass over the union ever exists.

Bin semantics: the histogram is FULL (bin 0 counts r_min = untouched
registers among the REAL m lanes; padded lanes are excluded by an iota mask),
rows sum to m — exactly ``estimators.histogram`` of the union row, which is
what the vmapped MLE consumes. Padded bins beyond 2^b count values no int8
register can hold and come out exactly 0.

Layout: registers on the lane axis (m padded to 128), tenant rows on
sublanes (K padded to the block), epoch include flags as (E, 1) int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat

DEFAULT_BLOCK_K = 256


def _window_union_kernel(
    inc_ref, regs_ref, union_ref, hist_ref, *, n_epochs, m, nb_padded, r_min
):
    ei = pl.program_id(1)  # epoch step (innermost)
    inc = inc_ref[0, 0]  # 1 if this epoch is inside the window
    plane = regs_ref[0]  # (K_blk, m_pad) int8, this epoch's registers
    contrib = jnp.where(inc > 0, plane, jnp.int8(r_min))

    @pl.when(ei == 0)
    def _init():
        union_ref[...] = contrib

    @pl.when(ei > 0)
    def _accum():
        union_ref[...] = jnp.maximum(union_ref[...], contrib)

    @pl.when(ei == n_epochs - 1)
    def _bincount():
        # Widen per block only — the HBM arrays stay int8.
        u = union_ref[...].astype(jnp.int32)
        lane_valid = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1) < m

        def bin_body(v, _):
            cnt = jnp.sum(
                jnp.where(lane_valid & (u == v + r_min), 1, 0),
                axis=1,
                keepdims=True,
            ).astype(jnp.int32)
            hist_ref[:, pl.ds(v, 1)] = cnt
            return _

        jax.lax.fori_loop(0, nb_padded, bin_body, None)


@functools.partial(
    jax.jit, static_argnames=("m", "nb_padded", "r_min", "block_k", "interpret")
)
def window_union_padded(
    regs,
    include,
    *,
    m: int,
    nb_padded: int,
    r_min: int,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """Kernel entry on pre-padded operands.

    regs: (E, K_pad, m_pad) int8, K_pad % block_k == 0, m_pad % 128 == 0,
      pad rows/lanes at r_min. int8 end to end: the ring is streamed at its
      native register width (the only HBM intermediate the wrapper creates
      is the padded int8 copy, and none when K and m are already aligned).
    include: (E, 1) int32 — 1 for epochs inside the window, 0 outside.
    Returns (union (K_pad, m_pad) int8, hist (K_pad, nb_padded) int32) with
    ``hist`` the full per-row histogram over the real m lanes only.
    """
    e, kp, mp = regs.shape
    kernel = functools.partial(
        _window_union_kernel, n_epochs=e, m=m, nb_padded=nb_padded, r_min=r_min
    )
    return pl.pallas_call(
        kernel,
        grid=(kp // block_k, e),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ki, ei: (ei, 0)),
            pl.BlockSpec((1, block_k, mp), lambda ki, ei: (ei, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_k, mp), lambda ki, ei: (ki, 0)),
            pl.BlockSpec((block_k, nb_padded), lambda ki, ei: (ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, mp), jnp.int8),
            jax.ShapeDtypeStruct((kp, nb_padded), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(include, regs)
