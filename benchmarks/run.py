"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and writes
JSON result files under experiments/bench/. ``--full`` runs the paper-scale
sweeps (much slower); default is the quick profile used by bench_output.txt.
``--smoke`` is the CI tier-2 entry (scripts/test.sh --tier2): the quick
profile restricted to the fast suites, just enough to prove every exercised
benchmark path still runs end to end.

  python -m benchmarks.run [--full | --smoke] [--only accuracy,throughput,...]
"""

from __future__ import annotations

import argparse
import time

# Fast enough for CI while still covering the fused + sharded + Dyn +
# sliding-window paths (cumulative sweeps included so their JSON schema is
# exercised every run).
SMOKE_SUITES = (
    "sketch_array",
    "sketch_array_sharded",
    "dyn_array",
    "dyn_array_sharded",
    "estimation",
    "window_array",
    "window_array_sharded",
    "ingest",
    "virtual_dyn_array",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick profile over the fast suite subset")
    ap.add_argument("--only", default="", help="comma list of benchmark names")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    from . import (
        accuracy,
        batch_bias,
        dyn_array,
        estimation,
        ingest,
        kernels,
        netflow,
        register_size,
        sketch_array,
        throughput,
        virtual_dyn_array,
        window_array,
    )

    suite = {
        "accuracy": accuracy.run,  # Figs 2-4
        "register_size": register_size.run,  # Fig 5 / Thm 1
        "throughput": throughput.run,  # Figs 6-8
        "batch_bias": batch_bias.run,  # beyond-paper
        "netflow": netflow.run,  # App A.4 (CAIDA analogue)
        "kernels": kernels.run,  # kernel block sweep + core throughput
        "sketch_array": sketch_array.run,  # fused K-sketch vs naive loop
        "sketch_array_sharded": sketch_array.run_sharded,  # mesh-sharded K sweep
        "dyn_array": dyn_array.run,  # anytime reads vs Newton estimate_all
        "estimation": estimation.run,  # solver sweep: newton vs lut vs fused
        "dyn_array_sharded": dyn_array.run_sharded,  # sharded Dyn K sweep
        "window_array": window_array.run,  # sliding-window reads vs per-epoch Newton
        "window_array_sharded": window_array.run_sharded,  # sharded ring (K, E) sweep
        "ingest": ingest.run,  # sustained_mops headline: pipelined vs sync
        "virtual_dyn_array": virtual_dyn_array.run,  # register-sharing memory/accuracy headline
    }
    only = [s for s in args.only.split(",") if s]
    names = only or (list(SMOKE_SUITES) if args.smoke else list(suite))

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        print(f"# --- {name} ---", flush=True)
        t = time.time()
        suite[name](quick=not args.full)
        print(f"# {name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
