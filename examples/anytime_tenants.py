"""Anytime per-tenant DAU-weight tracking (the paper's motivating metric,
multi-tenant form): a DynArrayMonitor follows EVERY tenant's weighted
engagement with O(1)-anytime reads.

The serving fleet emits (tenant id, session id, engagement weight) triples;
tenant t's weighted cardinality = total engagement across its *distinct*
sessions — re-connecting sessions must not double-count. A SketchArray
answers this with an O(K·2^b) vmapped Newton per query (55 s at K = 2^20 on
the host mesh — fine at logging cadence, not per batch). The DynArray keeps
the paper's §4.3 martingale PER TENANT, so after every batch the whole
estimate vector is simply read: dashboards and quota checks can watch every
tenant every step.

Tenant ids are sparse 64-bit org ids routed through the key directory
(collision telemetry included); a quota alert fires the moment a tenant's
anytime estimate crosses its contract — no estimation pass, just a compare
on the running chats.

    PYTHONPATH=src python examples/anytime_tenants.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, dyn_array, key_directory
from repro.core.types import DynArrayState
from repro.sketchstream import monitor


def main():
    cfg = SketchConfig(m=128, b=8, seed=11)
    capacity, n_tenants = 4096, 1500
    mon = monitor.DynArrayMonitor.for_capacity(cfg, capacity)

    rng = np.random.default_rng(3)
    tenant_ids = rng.integers(0, 2**64, n_tenants, dtype=np.uint64)
    # Zipf-ish tenant sizes: a few whales, a long tail.
    tenant_popularity = 1.0 / np.arange(1, n_tenants + 1) ** 0.8
    tenant_popularity /= tenant_popularity.sum()

    quota = 3_000.0  # engagement-weight contract per tenant

    # Stateless routing is a pure function of (dcfg, tenant id): precompute
    # every tenant's slot once for the quota compares below.
    all_lo = jnp.asarray((tenant_ids & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    all_hi = jnp.asarray((tenant_ids >> np.uint64(32)).astype(np.uint32))
    slots = np.asarray(key_directory.route_slots(mon.dcfg, (all_lo, all_hi)))

    st = mon.init()
    bs, n_batches = 8192, 40
    truth = {}  # (tenant, session) -> weight, for the final accuracy check
    alerted = set()
    print(f"{'batch':>6} {'events':>9} {'total est.':>12} {'read ms':>8}  quota alerts")
    for step in range(n_batches):
        t_idx = rng.choice(n_tenants, bs, p=tenant_popularity)
        sessions = rng.integers(0, 50_000, bs).astype(np.uint32)
        weights = (rng.gamma(2.0, 1.0, bs) + 0.1).astype(np.float32)
        for ti, s, w in zip(t_idx, sessions, weights):
            truth.setdefault((ti, int(s)), float(w))

        lo = (tenant_ids[t_idx] & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (tenant_ids[t_idx] >> np.uint64(32)).astype(np.uint32)
        st = mon.update(
            st, (jnp.asarray(lo), jnp.asarray(hi)),
            jnp.asarray(sessions), jnp.asarray(weights),
        )

        t0 = time.perf_counter()
        est = np.asarray(mon.estimate(st))  # the O(K) anytime read
        read_ms = (time.perf_counter() - t0) * 1e3

        # Quota check: compare EVERY tenant's running estimate, every batch.
        over = [t for t in np.nonzero(est[slots] > quota)[0] if t not in alerted]
        alerted.update(over)
        tag = f"  <-- tenants {[int(t) for t in over]} over {quota:,.0f}" if over else ""
        if step % 8 == 0 or tag:
            print(f"{step:>6} {(step + 1) * bs:>9} {est.sum():>12,.0f} {read_ms:>8.3f}{tag}")

    # Accuracy on the busiest tenants vs exact distinct-session truth.
    true_by_tenant = np.zeros(n_tenants)
    for (ti, _), w in truth.items():
        true_by_tenant[ti] += w
    top = np.argsort(-true_by_tenant)[:10]
    print(f"\n{'tenant':>7} {'true':>10} {'anytime est.':>13} {'rel.err':>8}")
    for t in top:
        e = est[slots[t]]
        print(f"{t:>7} {true_by_tenant[t]:>10,.0f} {e:>13,.0f} {abs(e - true_by_tenant[t]) / true_by_tenant[t]:>8.1%}")

    # The same registers support the Newton re-estimate (merge-time path) —
    # time it once to show what the anytime read avoids per query.
    t0 = time.perf_counter()
    mle = np.asarray(dyn_array.estimate_mle_all(
        cfg, DynArrayState(regs=st.regs, hists=st.hists, chats=st.chats)
    ))
    mle_ms = (time.perf_counter() - t0) * 1e3
    print(f"\nanytime read:      {read_ms:.3f} ms for all {capacity} tenants, every batch")
    print(f"MLE re-estimate:   {mle_ms:.1f} ms (merge-time only; first call includes compile)")
    print(f"quota alerts:      {len(alerted)} tenants crossed {quota:,.0f}")
    print(
        f"state memory:      {capacity} x (m={cfg.m} regs + 2^{cfg.b} hist + chat) = "
        f"{(capacity * (cfg.m + 4 * cfg.num_bins + 4)) / 2**20:.1f} MiB"
    )


if __name__ == "__main__":
    main()
