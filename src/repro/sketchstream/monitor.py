"""In-step stream telemetry: a QSketch threaded through train/serve steps,
merged across the mesh by max.

Design choice (vs QSketch-Dyn, documented in DESIGN.md §4.3): the in-step
monitor uses the FULL QSketch construction — every element updates all m
registers — rather than Dyn's one-register-per-element route, because:

  1. Exact mergeability. Dyn's running Ĉ is a per-shard martingale; shards
     that see the same element (token streams always do) can't just add
     their Ĉ's, and the register-histogram MLE fallback is misspecified
     whenever m ≳ n_distinct (an untouched Dyn register means "empty
     sub-stream", probability e^{-n/m}, which the quantized-Exp(C/m)
     likelihood cannot express — it drives the MLE to 0). QSketch registers
     are plain max-monoid elements: merge is exact at any scale.
  2. On TPU the m-wide update is ONE fused VPU kernel over the (batch, m)
     tile (kernels/qsketch_update.py) — at telemetry sizes (m=256) it costs
     ~1e9 integer lane-ops per 1M-token step, noise against the model's
     1e13+ FLOPs. The paper's O(1)-vs-O(m) distinction prices scalar CPUs,
     not 8x128 vector lanes; Dyn's O(1) update stays the right choice for
     the single-stream CPU setting and is benchmarked as such.
  3. Estimation stays O(2^b) via the histogram MLE (beyond-paper trick),
     cheap enough to log every step.

Streams monitored:
  * token coverage:   element = token id, weight 1 (distinct vocab touched)
  * weighted coverage: element = token id, weight supplied by the pipeline
  * MoE routing:      element = expert id, weight = routed prob mass
  * serving DAU:      element = session id, weight = engagement weight

Padding: pipeline tails carry dead rows. ``update`` takes an optional
boolean ``mask`` (same leading shape as ``ids``); masked-off rows neither
touch the sketch nor count toward ``n_seen``.

Per-key telemetry (the multi-tenant upgrade): ``ArrayMonitorState`` tracks K
independent sketches — one per expert / session bucket / flow — via
``core.sketch_array``. One ``update_array`` call folds a whole keyed batch
in a single fused segment scatter-max, and ``estimate_array`` returns all K
weighted cardinalities from one vmapped histogram-MLE. Merge stays the exact
max monoid row-wise, so per-key telemetry crosses the mesh the same way the
single sketch does.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import SketchConfig, estimators, qsketch, sketch_array
from repro.core.types import QSketchState, SketchArrayState


class MonitorState(NamedTuple):
    regs: jnp.ndarray  # int8[m]
    n_seen: jnp.ndarray  # int32 element counter (occurrences, not distinct)


def init(cfg: SketchConfig) -> MonitorState:
    return MonitorState(regs=qsketch.init(cfg).regs, n_seen=jnp.int32(0))


def _flatten(ids, weights, mask):
    ids = ids.reshape(-1)
    w = (
        jnp.ones(ids.shape, jnp.float32)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    mask = None if mask is None else mask.reshape(-1)
    n_live = ids.shape[0] if mask is None else jnp.sum(mask.astype(jnp.int32))
    return ids, w, mask, n_live


def update(cfg: SketchConfig, state: MonitorState, ids, weights=None, mask=None) -> MonitorState:
    """Batched full-QSketch update (ids flattened; weight 1.0 if not given).

    ``mask`` (bool, same leading shape as ids) drops padding rows: they are
    no-ops in the sketch AND excluded from the ``n_seen`` occurrence count.
    """
    ids, w, mask, n_live = _flatten(ids, weights, mask)
    st = qsketch.update(cfg, QSketchState(regs=state.regs), ids, w, mask=mask)
    return MonitorState(regs=st.regs, n_seen=state.n_seen + n_live)


def estimate(cfg: SketchConfig, state: MonitorState) -> jnp.ndarray:
    """Weighted cardinality via the O(2^b) histogram MLE."""
    hist = estimators.histogram(cfg, state.regs)
    chat, _, _ = estimators.qsketch_mle(cfg, hist)
    return chat


def merge(cfg: SketchConfig, a: MonitorState, b: MonitorState) -> MonitorState:
    """Exact union-stream merge (max monoid) — the cross-pod collective."""
    return MonitorState(regs=jnp.maximum(a.regs, b.regs), n_seen=a.n_seen + b.n_seen)


# ---------------------------------------------------------------------------
# Per-key telemetry: K sketches (experts / session buckets / flows) at once
# ---------------------------------------------------------------------------


class ArrayMonitorState(NamedTuple):
    regs: jnp.ndarray  # int8[K, m]
    n_seen: jnp.ndarray  # int32 live-element counter across all keys


def init_array(cfg: SketchConfig, k: int) -> ArrayMonitorState:
    return ArrayMonitorState(
        regs=sketch_array.init(cfg, k).regs, n_seen=jnp.int32(0)
    )


def update_array(
    cfg: SketchConfig, state: ArrayMonitorState, keys, ids, weights=None, mask=None
) -> ArrayMonitorState:
    """One fused keyed update: element i lands in sketch row keys[i].

    keys/ids/weights/mask share a leading shape and are flattened, so MoE
    routing tensors ((batch, experts) ids + prob-mass weights) drop in
    directly.
    """
    keys = keys.reshape(-1)
    ids, w, mask, n_live = _flatten(ids, weights, mask)
    st = sketch_array.update(
        cfg, SketchArrayState(regs=state.regs), keys, ids, w, mask=mask
    )
    return ArrayMonitorState(regs=st.regs, n_seen=state.n_seen + n_live)


def estimate_array(cfg: SketchConfig, state: ArrayMonitorState) -> jnp.ndarray:
    """All K weighted cardinalities: one vmapped histogram-MLE, Ĉ[K]."""
    return sketch_array.estimate_all(cfg, SketchArrayState(regs=state.regs))


def merge_array(cfg: SketchConfig, a: ArrayMonitorState, b: ArrayMonitorState) -> ArrayMonitorState:
    """Row-wise exact union merge across shards/pods."""
    return ArrayMonitorState(
        regs=jnp.maximum(a.regs, b.regs), n_seen=a.n_seen + b.n_seen
    )
