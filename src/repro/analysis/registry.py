"""qlint rule registry.

A rule is a class with ``name`` / ``description`` attributes and a
``run(ctx) -> list[Finding]`` method; ``@register`` adds it to the global
table the runner iterates. Rules receive the full parsed Context (so
cross-module facts — import graphs, jit reachability — are available) and
are responsible for restricting findings to ``ctx.is_selected`` paths so
``--changed-only`` stays cheap and precise.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Type

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.analysis.findings import Finding
    from repro.analysis.runner import Context

_RULES: dict[str, "Rule"] = {}


class Rule:
    """Base class for qlint rules (subclass, set ``name``/``description``,
    implement ``run``)."""

    name: str = ""
    description: str = ""

    def run(self, ctx: "Context") -> "list[Finding]":
        """Analyze the context and return findings (selected files only)."""
        raise NotImplementedError


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding one instance of ``cls`` to the registry."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if inst.name in _RULES:
        raise ValueError(f"duplicate rule name {inst.name!r}")
    _RULES[inst.name] = inst
    return cls


def _ensure_loaded() -> None:
    # Importing the rules package populates the registry as a side effect.
    from repro.analysis import rules  # noqa: F401


def all_rules() -> list[Rule]:
    """Every registered rule, in registration order."""
    _ensure_loaded()
    return list(_RULES.values())


def rule_names() -> list[str]:
    """Names of every registered rule."""
    _ensure_loaded()
    return list(_RULES)


def get_rule(name: str) -> Rule:
    """Look up one rule by name (KeyError on unknown)."""
    _ensure_loaded()
    return _RULES[name]
