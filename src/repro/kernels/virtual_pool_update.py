"""Pallas TPU kernel: per-element pool placement of the VirtualDynArray.

The virtual tier's dense inner stage is pure per-element hashing: register
choice j = g(x), value quantization y = floor(log2 w − log2 e) (Eq. 5), and
the pool slot p = hash(tenant, j; salt_pool) mod M. None of it reads sketch
state — the randomness is regenerated in VMEM with the repo's integer hash
family (``core/hashing.py``, the same jnp ops the reference path runs, so the
kernel is bit-exact vs ``qsketch_dyn._choose_and_quantize`` +
``virtual_dyn_array.pool_slots`` by construction).

The data-dependent tail (slot-grouping lexsort, segment scatter-max, the
incremental full-histogram move) stays in XLA and is SHARED with the core
path via ``virtual_dyn_array._apply_update``; ``ops.virtual_dyn_update_op``
fuses kernel placement + core tail and is bit-identical to
``core.virtual_dyn_array.update_tenants``.

Layout: (B, 1) operand columns on sublanes (batch) with a broadcast lane,
matching the id/weight column convention of ``qsketch_update.py``. Padding
rows carry log2w = −inf: their y quantizes to the r_min no-op floor, and the
wrapper slices them off before the tail anyway.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing

from . import compat

DEFAULT_BLOCK_B = 512


def _pool_route_kernel(
    lo_ref, hi_ref, tlo_ref, thi_ref, log2w_ref, p_ref, y_ref,
    *, salt_g, salt_h, salt_pool, m, pool_size, r_min, r_max,
):
    lo = lo_ref[...]  # (B_blk, 1) uint32 element id words
    hi = hi_ref[...]
    t_lo = tlo_ref[...]  # (B_blk, 1) uint32 tenant id words
    t_hi = thi_ref[...]
    log2w = log2w_ref[...]  # (B_blk, 1) f32

    j = hashing.hash_mod((lo, hi), salt_g, m)
    e = hashing.neg_log_uniform((lo, hi, j.astype(jnp.uint32)), salt_h)
    y = jnp.floor(log2w - jnp.log2(e))
    y = jnp.minimum(y, float(r_max))
    y = jnp.where(jnp.isfinite(y), y, float(r_min))

    p_ref[...] = hashing.hash_mod((t_lo, t_hi, j.astype(jnp.uint32)), salt_pool, pool_size)
    y_ref[...] = y.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "salt_g", "salt_h", "salt_pool", "m", "pool_size", "r_min", "r_max",
        "block_b", "interpret",
    ),
)
def virtual_pool_route_padded(
    lo, hi, t_lo, t_hi, log2w,
    *, salt_g: int, salt_h: int, salt_pool: int, m: int, pool_size: int,
    r_min: int, r_max: int, block_b: int = DEFAULT_BLOCK_B, interpret: bool = False,
):
    """(p, y) per element on pre-padded operands.

    lo/hi, t_lo/t_hi: (B, 1) uint32 element / tenant id words, B % block_b
    == 0; log2w: (B, 1) f32 with −inf on padding rows (y floors to r_min).
    Returns (p int32[B, 1] pool slots, y int32[B, 1] quantized values) —
    bit-exact vs the jnp reference helpers.
    """
    b = lo.shape[0]
    kernel = functools.partial(
        _pool_route_kernel,
        salt_g=salt_g, salt_h=salt_h, salt_pool=salt_pool,
        m=m, pool_size=pool_size, r_min=r_min, r_max=r_max,
    )
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(lo, hi, t_lo, t_hi, log2w)
