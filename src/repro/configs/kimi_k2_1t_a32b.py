"""kimi-k2-1t-a32b [moe] — trillion-param fine-grained MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, 384 experts top-8 +
one always-on shared expert [arXiv:2501.kimi2; unverified]. The d_ff=2048
experts are DeepSeek-V3-style fine-grained slices; with top-8 of 384 the
EP all-to-all dominates the roofline — this is the designated
most-collective-bound hillclimb cell (EXPERIMENTS.md §Perf). Full attention
-> long_500k skipped. head_dim = 7168/64 = 112 (the real model widens heads
via q/k up-projection; we keep the backbone table's dims).
"""

from repro.models import LayerSpec, MoEConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,
        vocab=163840,
        pattern=(LayerSpec(ffn="moe"),),
        moe=MoEConfig(num_experts=384, top_k=8, shared_expert=True, d_ff=2048),
        rope_theta=50_000.0,
        max_seq=131_072,
    )
