"""qlint — the repo's AST-based static-analysis suite (DESIGN.md §9).

QSketch's correctness story rests on contracts a unit test can't see from
the outside: int8 register arithmetic must upcast before any additive op,
donated buffers must never be read after the donating call, nothing
host-impure may hide inside a jit region, Pallas kernels must keep their
Ref/BlockSpec discipline, and only ``core/estimation.py`` may touch the raw
Newton solver. qlint machine-checks those contracts over the source tree:

* ``registry``   — rule registration + lookup,
* ``findings``   — the Finding record (rule, file, line, message) and its
  stable baseline key,
* ``astutil``    — shared AST helpers (module naming, import/alias
  resolution, dotted-name chains),
* ``baseline``   — the checked-in suppression file for grandfathered
  findings (``scripts/qlint_baseline.json``),
* ``runner``     — file collection (full-repo / changed-only), rule
  execution, JSON report writing,
* ``rules/``     — the rule implementations (layering, int8-overflow,
  donation-safety, jit-purity, kernel-contract, docstrings, bench-schema).

Entry point: ``scripts/check_static.py`` (wired into
``scripts/test.sh --tier2``); exits non-zero on any non-baselined finding.
"""

from repro.analysis.findings import Finding
from repro.analysis.registry import all_rules, get_rule, rule_names
from repro.analysis.runner import build_context, run_qlint

__all__ = [
    "Finding",
    "all_rules",
    "get_rule",
    "rule_names",
    "build_context",
    "run_qlint",
]
