"""Error-feedback int8 gradient compression (distributed-optimization trick).

Semantics (1-bit-Adam/PowerSGD family, specialized to int8):

    g_tilde = g + e_prev          # add back residual from last step
    q       = Q(g_tilde)          # int8 blockwise quantization
    e_new   = g_tilde - Q^-1(q)   # residual carried forward
    g_out   = Q^-1(q)             # what the optimizer sees

On TPU/XLA there is no user-programmable collective payload, so the
*reduction itself* still runs at full width here — the quantization models
the wire format and provides the exact gradient statistics a real
int8-compressed all-reduce would deliver (the error-feedback loop makes the
long-run bias vanish). The roofline analysis credits the collective term
with the 4x byte reduction analytically and flags it as modeled, not
measured (EXPERIMENTS.md §Roofline notes).

The quantizer is shared with the int8 optimizer state (optimizer.py) — the
paper's registers, the optimizer moments, and the gradient wire format all
ride the same "quantize + principled reconstruction" move.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import optimizer as _opt


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error_state):
    """Returns (dequantized grads, new error state, wire-bytes metrics)."""

    def leaf(g, e):
        gt = g.astype(jnp.float32) + e
        q, s = _opt.quantize_blockwise(gt)
        deq = _opt.dequantize_blockwise(q, s, gt.shape)
        return deq, gt - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in out])
    new_e = tdef.unflatten([o[1] for o in out])
    return new_g, new_e


def wire_bytes(params, compressed: bool) -> int:
    """Analytic all-reduce payload per step (for the roofline's collective term)."""
    total = 0
    for p in jax.tree.leaves(params):
        n = 1
        for d in p.shape:
            n *= d
        total += n * (1 if compressed else 4)  # int8 vs f32 wire words
    return total
