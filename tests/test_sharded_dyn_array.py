"""ShardedDynArray tests.

Acceptance: every state leaf — registers, histograms AND the running
martingale chats — is bit-identical to the single-host DynArray fed the
same stream on the 8-device host mesh (scripts/test.sh exports
XLA_FLAGS=--xla_force_host_platform_device_count=8), including masked
batches, sparse 64-bit tenants through the directory, the kernel-backed
update op, all-max / disjoint merges, and the monitor/train threading.
Also covers the merge_disjoint overlap rejection (both the sharded default
and the single-host opt-in).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    dyn_array,
    key_directory,
    sharded_dyn_array,
    sharding,
)
from repro.core.key_directory import DirectoryConfig
from repro.kernels import ops
from repro.launch.mesh import make_sketch_mesh
from repro.sketchstream import monitor


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()  # 8 shards under scripts/test.sh


def _stream(n, k, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, k, n, dtype=np.int32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray((rng.gamma(1.0, 2.0, n) + 1e-5).astype(np.float32))
    return keys, ids, w


def _assert_states_equal(sh, ref):
    """Every leaf bitwise — the acceptance bar, chats included."""
    np.testing.assert_array_equal(np.asarray(sh.regs), np.asarray(ref.regs))
    np.testing.assert_array_equal(np.asarray(sh.hists), np.asarray(ref.hists))
    np.testing.assert_array_equal(np.asarray(sh.chats), np.asarray(ref.chats))


# ---------------------------------------------------------------------------
# acceptance: update -> estimate vs the single-host DynArray, bitwise
# ---------------------------------------------------------------------------


def test_update_bit_identical_all_leaves(mesh):
    cfg = SketchConfig(m=96, b=8, seed=31)  # ragged m: not a lane multiple
    k = sharding.padded_k(100, mesh)  # ragged K rounded to the shards
    sh = sharded_dyn_array.init(cfg, k, mesh)
    ref = dyn_array.init(cfg, k)
    for i in range(3):  # multi-batch: batch-start q_R state must track too
        keys, ids, w = _stream(700, k, seed=i)
        sh = sharded_dyn_array.update_batch(cfg, mesh, sh, keys, ids, w)
        ref = dyn_array.update_batch(cfg, ref, keys, ids, w)
    _assert_states_equal(sh, ref)
    np.testing.assert_array_equal(
        np.asarray(sharded_dyn_array.estimate_all(sh)), np.asarray(ref.chats)
    )
    np.testing.assert_array_equal(
        np.asarray(sharded_dyn_array.estimate_mle_all(cfg, mesh, sh)),
        np.asarray(dyn_array.estimate_mle_all(cfg, ref)),
    )


def test_masked_and_degenerate_rows_are_noops(mesh):
    cfg = SketchConfig(m=64, b=8, seed=33)
    k = sharding.padded_k(40, mesh)
    keys, ids, w = _stream(400, k, seed=5)
    w = w.at[::7].set(-1.0)  # degenerate weights dropped like masked rows
    mask = jnp.asarray(np.random.default_rng(3).random(400) < 0.5)
    sh = sharded_dyn_array.update_batch(
        cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), keys, ids, w, mask=mask
    )
    ref = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w, mask=mask)
    _assert_states_equal(sh, ref)


def test_reshard_roundtrip_and_geometry(mesh):
    cfg = SketchConfig(m=64, b=8, seed=35)
    k = sharding.padded_k(48, mesh)
    keys, ids, w = _stream(300, k, seed=9)
    ref = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w)
    sh = sharded_dyn_array.from_array(ref, mesh)
    _assert_states_equal(sharded_dyn_array.to_array(sh), ref)
    assert sharded_dyn_array.num_sketches(sh) == k
    if sharding.num_shards(mesh) > 1:
        with pytest.raises(ValueError, match="divisible"):
            sharded_dyn_array.init(cfg, sharding.num_shards(mesh) + 1, mesh)


# ---------------------------------------------------------------------------
# merges: overlapping (MLE re-estimate) and key-partitioned (chats add)
# ---------------------------------------------------------------------------


def test_merge_overlapping_matches_single_host(mesh):
    cfg = SketchConfig(m=64, b=8, seed=41)
    k = sharding.padded_k(32, mesh)
    ka, ia, wa = _stream(900, k, seed=11)
    kb, ib, wb = _stream(700, k, seed=12)
    sh_a = sharded_dyn_array.update_batch(cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), ka, ia, wa)
    sh_b = sharded_dyn_array.update_batch(cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), kb, ib, wb)
    ref_a = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), ka, ia, wa)
    ref_b = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), kb, ib, wb)
    _assert_states_equal(
        sharded_dyn_array.merge(cfg, mesh, sh_a, sh_b), dyn_array.merge(cfg, ref_a, ref_b)
    )
    with pytest.raises(ValueError, match="matching"):
        sharded_dyn_array.merge(
            cfg, mesh, sh_a, sharded_dyn_array.init(cfg, 2 * k, mesh)
        )


def test_merge_disjoint_key_partitioned_fleets(mesh):
    """Key-partitioned fleets: fleet A owns rows [0, K/2), fleet B the rest.
    Chats ADD exactly and match the single-host disjoint merge bitwise."""
    cfg = SketchConfig(m=64, b=8, seed=43)
    k = sharding.padded_k(32, mesh)
    keys, ids, w = _stream(1200, k, seed=13)
    in_a = keys < k // 2
    sh_a = sharded_dyn_array.update_batch(
        cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), keys, ids, w, mask=in_a
    )
    sh_b = sharded_dyn_array.update_batch(
        cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), keys, ids, w, mask=~in_a
    )
    ref_a = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w, mask=in_a)
    ref_b = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w, mask=~in_a)
    merged = sharded_dyn_array.merge_disjoint(cfg, mesh, sh_a, sh_b)
    _assert_states_equal(merged, dyn_array.merge_disjoint(cfg, ref_a, ref_b))
    np.testing.assert_array_equal(
        np.asarray(merged.chats), np.asarray(sh_a.chats) + np.asarray(sh_b.chats)
    )


def test_merge_disjoint_rejects_overlapping_partitions(mesh):
    """A key row live in BOTH fleets breaks the partition contract: the
    sharded fleet merge rejects it by default; the single-host container
    rejects it under check_partition=True (and still allows the weaker
    element-disjoint use without it)."""
    cfg = SketchConfig(m=64, b=8, seed=45)
    k = sharding.padded_k(16, mesh)
    ka, ia, wa = _stream(400, k, seed=17)
    kb, ib, wb = _stream(400, k, seed=18)  # same key space: partitions overlap
    sh_a = sharded_dyn_array.update_batch(cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), ka, ia, wa)
    sh_b = sharded_dyn_array.update_batch(cfg, mesh, sharded_dyn_array.init(cfg, k, mesh), kb, ib, wb)
    with pytest.raises(ValueError, match="live in BOTH"):
        sharded_dyn_array.merge_disjoint(cfg, mesh, sh_a, sh_b)
    # Explicit opt-out for element-disjoint-but-key-shared fleets.
    out = sharded_dyn_array.merge_disjoint(
        cfg, mesh, sh_a, sh_b, check_partition=False
    )
    np.testing.assert_array_equal(
        np.asarray(out.chats), np.asarray(sh_a.chats) + np.asarray(sh_b.chats)
    )

    ref_a = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), ka, ia, wa)
    ref_b = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), kb, ib, wb)
    with pytest.raises(ValueError, match="live in BOTH"):
        dyn_array.merge_disjoint(cfg, ref_a, ref_b, check_partition=True)
    # Under jit tracing the host-side guard CANNOT run: asking for it must
    # fail loudly (at trace time), never silently skip the check.
    with pytest.raises(ValueError, match="under\\s+jit tracing"):
        jax.jit(
            lambda x, y: dyn_array.merge_disjoint(cfg, x, y, check_partition=True)
        )(ref_a, ref_b)


# ---------------------------------------------------------------------------
# sparse 64-bit tenants + kernel-backed op
# ---------------------------------------------------------------------------


def test_sparse_tenants_end_to_end(mesh):
    cfg = SketchConfig(m=64, b=8, seed=47)
    dcfg = DirectoryConfig(capacity=sharding.padded_k(512, mesh), seed=49)
    rng = np.random.default_rng(19)
    tenants = rng.integers(2**33, 2**64, 600, dtype=np.uint64)
    keys = key_directory.split_uint64(tenants)
    ids = jnp.asarray(rng.integers(0, 2**32, 600, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 600).astype(np.float32))

    sh = sharded_dyn_array.init(cfg, dcfg.capacity, mesh)
    dstate = key_directory.init(dcfg)
    sh, dstate = sharded_dyn_array.update_tenants(
        cfg, dcfg, mesh, sh, dstate, keys, ids, w
    )
    assert int(dstate.n_routed) == 600

    slots = key_directory.route_slots(dcfg, keys)
    ref = dyn_array.update_batch(cfg, dyn_array.init(cfg, dcfg.capacity), slots, ids, w)
    _assert_states_equal(sh, ref)

    with pytest.raises(ValueError, match="capacity"):
        sharded_dyn_array.update_tenants(
            cfg, DirectoryConfig(capacity=2 * dcfg.capacity), mesh, sh,
            dstate, keys, ids, w,
        )


def test_kernel_op_bit_identity(mesh):
    cfg = SketchConfig(m=64, b=8, seed=51)
    k = sharding.padded_k(24, mesh)
    sh = sharded_dyn_array.init(cfg, k, mesh)
    ref = dyn_array.init(cfg, k)
    for i in range(2):
        keys, ids, w = _stream(300, k, seed=20 + i)
        mask = jnp.asarray(np.random.default_rng(21 + i).random(300) < 0.8)
        sh = ops.sharded_dyn_array_update_op(cfg, mesh, sh, keys, ids, w, mask=mask)
        ref = dyn_array.update_batch(cfg, ref, keys, ids, w, mask=mask)
    _assert_states_equal(sh, ref)


# ---------------------------------------------------------------------------
# monitor + train threading
# ---------------------------------------------------------------------------


def test_sharded_dyn_monitor_roundtrip(mesh):
    cfg = SketchConfig(m=64, b=8, seed=61)
    mon = monitor.ShardedDynMonitor.for_mesh(cfg, 500, mesh)
    ref_mon = monitor.DynArrayMonitor(cfg, mon.dcfg)
    rng = np.random.default_rng(25)
    tkeys = jnp.asarray(rng.integers(0, 2**32, 300, dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, 2**32, 300, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 300).astype(np.float32))
    mask = jnp.asarray(np.arange(300) < 250)

    st = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    ref = ref_mon.update(ref_mon.init(), tkeys, ids, w, mask=mask)
    assert int(st.n_seen) == 250
    np.testing.assert_array_equal(np.asarray(mon.estimate(st)), np.asarray(ref_mon.estimate(ref)))

    st2 = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    merged = mon.merge(st, st2)
    assert int(merged.n_seen) == 500
    m = mon.metrics(st)
    assert int(m["tenant_elements_seen"]) == 250
    assert float(m["tenant_weight_total"]) == pytest.approx(
        float(np.asarray(mon.estimate(st)).sum()), rel=1e-6
    )
    with pytest.raises(ValueError, match="divisible"):
        monitor.ShardedDynMonitor(
            cfg, DirectoryConfig(capacity=sharding.num_shards(mesh) * 8 + 1), mesh
        )


def test_train_step_threads_sharded_dyn_telemetry(mesh):
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import optimizer, train_step as ts

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(27)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "doc_ids": jnp.asarray(rng.integers(0, 2**32, (4,), dtype=np.uint32)),
    }
    skc = SketchConfig(m=64, b=8, seed=63)
    mon = monitor.ShardedDynMonitor.for_mesh(skc, 256, mesh)
    ocfg = optimizer.OptConfig(lr=1e-3, warmup_steps=0)
    step = jax.jit(ts.make_train_step(mcfg, ocfg, None, sketch_cfg=skc, tenant_monitor=mon))
    opt, comp, sk = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)

    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(sk.tenants.n_seen) == 64  # 4 x 16 tokens through the array
    assert "tenant_weight_total" in metrics
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 4  # 4 documents -> exactly 4 live rows
