"""QSketch-Dyn (paper §4.3): O(1)-update anytime weighted-cardinality tracking.

Per element (x, w):
  1. pick ONE register j = g(x)                      (hash, not RandInt: the
     choice must be consistent per element or duplicates double-count);
  2. y = floor(-log2(-ln h_j(x) / w));
  3. if y > R[j]: move histogram mass T[R[j]] -> T[y'], set R[j] = y';
  4. Ĉ += 1(changed) * w / q_R, with the update probability
         q_R = 1 - (1/m) Σ_k T[k] e^{-w 2^{-(k+r_min+1)}}
     computed from the state BEFORE the update (Eq. 12 / Thm. 2).

NOTE on the paper's Alg. 3: lines 14–17 as printed compute q_R *after* the
register/histogram update and add w/q_R unconditionally. That contradicts
Eq. (12) and the unbiasedness proof of Thm. 2 (which conditions q_R^{(t)} on
R^{(t-1)} and carries the indicator). We implement Eq. (12); the accuracy
benchmarks reproduce the paper's reported behaviour with this reading.

Two execution modes (DESIGN.md §4.2):

* ``update_scan``  — exact sequential semantics via ``lax.scan`` (the
                     paper-faithful baseline; also the accuracy-benchmark path).
* ``update_batch`` — TPU-native: all q_R from the batch-start histogram,
                     one scatter-max + histogram rebuild. Within-batch
                     duplicates are removed exactly; the only deviation from
                     the exact chain is ≤B-element staleness of q_R, measured
                     in benchmarks/batch_bias.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import estimation, estimators, hashing
from .types import DynState, SketchConfig

_QR_FLOOR = 1e-12  # q_R guard; only reachable when sketch is fully saturated


def init(cfg: SketchConfig) -> DynState:
    """Fresh QSketch-Dyn: int8[m] registers at r_min, zero touched-register
    histogram, zero running martingale estimate."""
    return DynState(
        regs=jnp.full((cfg.m,), cfg.r_min, dtype=jnp.int8),
        hist=jnp.zeros((cfg.num_bins,), dtype=jnp.int32),
        chat=jnp.float32(0.0),
    )


def _choose_and_quantize(cfg: SketchConfig, lo, hi, w):
    """(j, y) per element: register choice g(x) and quantized value."""
    j = hashing.hash_mod((lo, hi), cfg.salt_g, cfg.m)
    e = hashing.neg_log_uniform((lo, hi, j.astype(jnp.uint32)), cfg.salt_h)
    y = jnp.floor(jnp.log2(w) - jnp.log2(e))
    # No r_min clip needed: y must exceed R[j] >= r_min to matter. Cap at r_max.
    y = jnp.minimum(y, float(cfg.r_max))
    # Guard against -inf/NaN from degenerate w; quantize to a harmless floor.
    y = jnp.where(jnp.isfinite(y), y, float(cfg.r_min))
    return j, y.astype(jnp.int32)


def _q_update_prob(cfg: SketchConfig, hist, w):
    """q_R for weight(s) w given histogram T (paper §4.3, O(2^b)).

    Untouched registers (still r_min) are intentionally absent from T: their
    e^{-w 2^{-(r_min+1)}} term is ~0 (Alg. 3 inits T to zeros), so
    q_R = 1 - (1/m) Σ_k T[k] e^{-w s_k} automatically treats them as
    always-updatable.
    """
    s = jnp.asarray(estimators._bin_scales(cfg))  # 2^{-(k+r_min+1)}
    w = jnp.asarray(w, jnp.float32)
    expo = jnp.exp(-w[..., None] * s)  # (..., 2^b)
    q = 1.0 - (hist.astype(jnp.float32) * expo).sum(-1) / cfg.m
    return jnp.maximum(q, _QR_FLOOR)


@functools.partial(jax.jit, static_argnums=(0,))
def update_scan(cfg: SketchConfig, state: DynState, ids, weights, mask=None) -> DynState:
    """Exact sequential update of a batch (Alg. 3 semantics, Eq. 12 estimator).

    Degenerate (non-positive / non-finite) weights are dropped as if masked —
    same contract as ``update_batch``.
    """
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    mask = _live_weight_mask(w, mask)

    def step(carry, inp):
        regs, hist, chat = carry
        elo, ehi, ew, em = inp
        j, y = _choose_and_quantize(cfg, elo, ehi, ew)
        q = _q_update_prob(cfg, hist, ew)
        old = regs[j].astype(jnp.int32)
        changed = em & (y > old)
        # Histogram move: decrement old bin if tracked, increment new bin.
        old_bin = old - cfg.r_min
        new_bin = y - cfg.r_min
        dec = changed & (hist[old_bin] > 0)
        hist = hist.at[old_bin].add(jnp.where(dec, -1, 0))
        hist = hist.at[new_bin].add(jnp.where(changed, 1, 0))
        regs = regs.at[j].set(jnp.where(changed, y, old).astype(jnp.int8))
        chat = chat + jnp.where(changed, ew / q, 0.0)
        return (regs, hist, chat), None

    (regs, hist, chat), _ = jax.lax.scan(step, (state.regs, state.hist, state.chat), (lo, hi, w, mask))
    return DynState(regs=regs, hist=hist, chat=chat)


def _live_weight_mask(w, mask):
    """Rows that may touch the sketch: caller mask AND a usable weight.

    Non-positive / non-finite weights are *dropped as if masked* rather than
    quantized to a silent r_min floor: a degenerate w can never raise a
    register, but before this guard it still competed in the within-batch
    dedup, where a w=0 duplicate sorting first would shadow a live positive
    row of the same id out of the batch entirely.
    """
    live = jnp.isfinite(w) & (w > 0)
    return live if mask is None else live & mask


def _dedup_mask(lo, hi, live=None):
    """Exact within-batch first-occurrence mask via sort on the id pair.

    ``live`` joins the sort as the LAST lexsort key (after the id pair), so
    live rows order ahead of dead (padding / degenerate-weight) rows sharing
    their id: the first-occurrence winner of any id group that contains a
    live row is itself live. Computing first-occurrence over all rows and
    intersecting with the mask afterwards — the pre-fix behaviour — let a
    padded duplicate claim the slot and silently drop the live row's weight.
    Ties among live rows keep batch order (lexsort is stable).
    """
    dead = (
        jnp.zeros(lo.shape, jnp.uint32) if live is None else (~live).astype(jnp.uint32)
    )
    order = jnp.lexsort((dead, lo, hi))
    slo, shi = lo[order], hi[order]
    first = jnp.concatenate(
        [jnp.array([True]), (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])]
    )
    mask = jnp.zeros_like(first).at[order].set(first)
    return mask


@functools.partial(jax.jit, static_argnums=(0,))
def update_batch(cfg: SketchConfig, state: DynState, ids, weights, mask=None) -> DynState:
    """Batch-stale update: q_R and change-indicators from the batch-start state.

    Exact within-batch dedup; register scatter-max; histogram rebuilt from
    registers (equivalent to the incremental moves because untouched
    registers hold r_min and bin 0 is pinned to zero).

    Dedup/mask ordering contract (DESIGN.md §4.2): first-occurrence is
    decided among *live* rows only — ``mask=False`` padding rows and
    degenerate (non-positive / non-finite) weights are dropped before they
    can shadow a live row sharing their id. Within-batch duplicates are
    assumed to carry the element's weight (weight is a function of the id,
    the paper's weighted-stream model); the first live occurrence wins.
    """
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    j, y = _choose_and_quantize(cfg, lo, hi, w)

    live = _live_weight_mask(w, mask)
    alive = _dedup_mask(lo, hi, live) & live

    old = state.regs[j].astype(jnp.int32)
    changed = alive & (y > old)
    q = _q_update_prob(cfg, state.hist, w)
    chat = state.chat + jnp.sum(jnp.where(changed, w / q, 0.0))

    y_eff = jnp.where(changed, y, jnp.int32(cfg.r_min))
    regs = state.regs.astype(jnp.int32).at[j].max(y_eff).astype(jnp.int8)

    # Rebuild histogram of touched registers (R > r_min); bin 0 stays 0.
    hist = jnp.zeros((cfg.num_bins,), jnp.int32).at[
        regs.astype(jnp.int32) - cfg.r_min
    ].add(1)
    hist = hist.at[0].set(0)
    return DynState(regs=regs, hist=hist, chat=chat)


def estimate(state: DynState) -> jnp.ndarray:
    """Anytime estimate: it's just the running martingale (O(0) per query)."""
    return state.chat


@functools.partial(jax.jit, static_argnums=(0,))
def estimate_mle(cfg: SketchConfig, state: DynState):
    """Histogram-MLE re-estimate from the registers.

    Used (a) after cross-shard merges, where local running Ĉ's can't just be
    added (shared elements would double-count), and (b) as a self-check.

    Unlike QSketch — where every element feeds every register, making each
    register quantized-Exp(C) — a Dyn register only hears the 1/m sub-stream
    g(x) routes to it, so its law is quantized-Exp(C_j) with C_j ≈ C/m
    (stochastic averaging over the multinomial split, the same argument
    HyperLogLog's analysis uses). The QSketch MLE therefore recovers C/m and
    is scaled by m. An r_min register is the 'sub-stream produced nothing
    above r_min' event, whose probability e^{-C_j 2^{-(r_min+1)}} is exactly
    the truncated-low bin of the same likelihood (empty sub-stream -> C_j=0
    -> probability 1), so untouched registers need no special-casing.

    Fully untouched state (all registers at r_min, hist all zero): Ĉ = 0 by
    contract. The ×m scaling and the untouched guard are the estimation
    layer's ``kind="routed"`` convention (core/estimation.py) — one home for
    a guard that used to be repeated here, in ``merge`` and in
    ``dyn_array.estimate_mle_hists``.
    """
    hist = estimators.histogram(cfg, state.regs)
    return estimation.estimate_hist(cfg, hist, kind="routed")


def merge(cfg: SketchConfig, a: DynState, b: DynState) -> DynState:
    """Merge sketches of disjoint/overlapping sub-streams.

    Registers: element-wise max (exact union semantics).
    Histogram: rebuilt. Running Ĉ: re-estimated via MLE — the local running
    estimates are NOT additive when sub-streams may share elements. Merging
    two fully untouched states yields Ĉ = 0 (empty union), not an MLE
    iteration on an empty histogram.
    """
    regs = jnp.maximum(a.regs, b.regs)
    hist = jnp.zeros((cfg.num_bins,), jnp.int32).at[
        regs.astype(jnp.int32) - cfg.r_min
    ].add(1)
    hist = hist.at[0].set(0)
    # Full histogram (including untouched registers in bin 0) for the MLE;
    # the stored hist keeps the Alg.-3 'touched only' convention.
    full_hist = hist.at[0].set(cfg.m - jnp.sum(hist))
    chat = estimation.estimate_hist(cfg, full_hist, kind="routed")
    return DynState(regs=regs, hist=hist, chat=chat)


# ---------------------------------------------------------------------------
# numpy oracle (exact Alg. 3 / Eq. 12 semantics) for tests
# ---------------------------------------------------------------------------


def update_numpy(cfg: SketchConfig, ids_lo, ids_hi, weights, mask=None):
    """Pure-numpy sequential reference; returns (regs, hist, chat).

    ``mask`` mirrors the jit'd paths; degenerate (non-positive / non-finite)
    weights are likewise dropped, so the oracle verifies the live-row
    contract and never evaluates log2 of a non-positive w.
    """
    regs = np.full(cfg.m, cfg.r_min, dtype=np.int64)
    hist = np.zeros(cfg.num_bins, dtype=np.int64)
    chat = 0.0
    ks = np.arange(cfg.num_bins, dtype=np.float64) + cfg.r_min + 1.0
    s = np.exp2(-ks)
    live = np.ones(np.asarray(ids_lo).shape, bool) if mask is None else np.asarray(mask)
    for xlo, xhi, w, lv in zip(
        np.asarray(ids_lo), np.asarray(ids_hi), np.asarray(weights), live
    ):
        if not (lv and np.isfinite(w) and w > 0):
            continue
        jl = hashing.hash_mod(
            (jnp.uint32(int(xlo)), jnp.uint32(int(xhi))), cfg.salt_g, cfg.m
        )
        j = int(jl)
        e = float(
            hashing.neg_log_uniform(
                (jnp.uint32(int(xlo)), jnp.uint32(int(xhi)), jnp.uint32(j)), cfg.salt_h
            )
        )
        y = int(np.floor(np.log2(w) - np.log2(e)))
        y = min(y, cfg.r_max)
        q = max(1.0 - float(np.sum(hist * np.exp(-w * s))) / cfg.m, _QR_FLOOR)
        if y > regs[j]:
            ob = regs[j] - cfg.r_min
            if hist[ob] > 0:
                hist[ob] -= 1
            hist[y - cfg.r_min] += 1
            regs[j] = y
            chat += w / q
    return regs, hist, chat
