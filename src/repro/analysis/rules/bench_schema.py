"""bench-schema — the cumulative bench JSONs keep their merge contract.

The sweep suites merge quick/smoke re-measurements into their JSON so cheap
runs never erase the paper-scale rows a ``--full`` run paid for
(``benchmarks/common.merge_save``). A broken merge fails SILENTLY at bench
time — duplicate cells, dropped rows, unsorted output — and only shows up
when someone plots stale data. This rule makes it fail loudly:

* every row carries the required keys ("figure", "method", and a numeric
  payload among mops/ms/x/us/sustained_mops),
* within each (figure, method[, e][, bsz]) group the swept "k" values are
  unique and strictly increasing (merge_save sorts; a duplicate k means two
  merges claimed the same cell, out-of-order means someone bypassed
  merge_save),
* in a full run, every cumulative file the smoke suite maintains must
  exist.

This rule absorbs the former standalone ``scripts/check_bench_schema.py``
(which now delegates here). It is a repo-level (non-AST) rule: in
``--changed-only`` mode it only runs when a bench JSON is in the selection.
"""

from __future__ import annotations

import json
import os

from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

BENCH_DIR = "experiments/bench"

# Files written through common.merge_save — the cumulative-merge contract.
CUMULATIVE = (
    "dyn_array.json",
    "dyn_array_sharded.json",
    "estimation.json",
    "ingest.json",
    "window_array.json",
    "window_array_sharded.json",
)
PAYLOAD_KEYS = ("mops", "ms", "x", "us", "sustained_mops")


def check_rows(rel: str, rows, rule_name: str = "bench-schema") -> list[Finding]:
    """Schema findings for one bench JSON's row list."""
    findings = []
    if not isinstance(rows, list) or not rows:
        return [Finding(rule_name, rel, 1, "expected a non-empty list of row dicts")]
    groups: dict[tuple, list] = {}
    for i, r in enumerate(rows):
        for key in ("figure", "method"):
            if not isinstance(r.get(key), str):
                findings.append(
                    Finding(rule_name, rel, 1, f"row missing/non-string '{key}': {r}")
                )
        if not any(isinstance(r.get(p), (int, float)) for p in PAYLOAD_KEYS):
            findings.append(
                Finding(
                    rule_name, rel, 1,
                    f"row has no numeric payload among {PAYLOAD_KEYS}: {r}",
                )
            )
        if "k" in r and not isinstance(r["k"], int):
            findings.append(
                Finding(rule_name, rel, 1, f"non-integer sweep key 'k': {r}")
            )
        # "e" splits the window-suite ring sweeps; "bsz" splits the ingest
        # batch-size sweep — within each group k must stay unique + monotone.
        groups.setdefault(
            (r.get("figure"), r.get("method"), r.get("e"), r.get("bsz")), []
        ).append(r)
    for (figure, method, e, bsz), rs in groups.items():
        ks = [r["k"] for r in rs if "k" in r]
        tag = (
            f"{figure}/{method}"
            + (f"/e={e}" if e is not None else "")
            + (f"/bsz={bsz}" if bsz is not None else "")
        )
        if len(ks) != len(set(ks)):
            dupes = sorted({k for k in ks if ks.count(k) > 1})
            findings.append(
                Finding(
                    rule_name, rel, 1,
                    f"{tag}: duplicate k cells {dupes} (broken cumulative merge)",
                )
            )
        if ks != sorted(ks):
            findings.append(
                Finding(rule_name, rel, 1, f"{tag}: k not monotone increasing: {ks}")
            )
    return findings


@register
class BenchSchemaRule(Rule):
    """Validate the cumulative bench JSONs under experiments/bench."""

    name = "bench-schema"
    description = (
        "cumulative bench JSONs: required keys, numeric payload, unique + "
        "monotone k per (figure, method, e, bsz) group"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        bench_dir = os.path.join(ctx.root, BENCH_DIR)
        findings: list[Finding] = []
        if ctx.selected is not None:
            # Same scope as a full run: only the merge_save-maintained files
            # carry this contract (other bench JSONs use their own payloads).
            targets = sorted(
                p for p in ctx.selected
                if p.startswith(BENCH_DIR + "/")
                and os.path.basename(p) in CUMULATIVE
            )
            # Nothing bench-related changed: the rule has nothing to say.
        else:
            targets = [
                f"{BENCH_DIR}/{f}"
                for f in CUMULATIVE
                if os.path.exists(os.path.join(bench_dir, f))
            ]
            for f in CUMULATIVE:
                if not os.path.exists(os.path.join(bench_dir, f)):
                    findings.append(
                        Finding(
                            self.name, f"{BENCH_DIR}/{f}", 1,
                            "expected cumulative bench file is missing",
                        )
                    )
        for rel in targets:
            path = os.path.join(ctx.root, rel)
            if not os.path.exists(path):
                continue
            with open(path) as f:
                try:
                    rows = json.load(f)
                except json.JSONDecodeError as e:
                    findings.append(
                        Finding(self.name, rel, 1, f"invalid JSON: {e.msg}")
                    )
                    continue
            findings += check_rows(rel, rows, self.name)
        return findings
