"""Roofline terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs_global    / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes_global    / (chips * HBM_BW)
    collective = collective_bytes_gl / (chips * ICI_LINK_BW)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module
(verified empirically: flops == analytic_global / n_devices), so globals are
per_device * chips and the formulas above reduce to per_device / peak —
both views are recorded in the cell JSON.

collective_bytes comes from parsing ``compiled.as_text()``: the sum of
result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op (async '-start' variants counted once,
'-done' skipped). Shapes in the partitioned HLO are per-device, so the sum
is per-device wire bytes — matching the formula's per-chip-link denominator.
"""

from __future__ import annotations

import re
from typing import Dict

from . import hw

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/]*?\)?)\s*"
    r"(all-reduce-start|all-gather-start|reduce-scatter-start|all-to-all-start|"
    r"collective-permute-start|all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes by collective kind, from partitioned HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        out[op] = out.get(op, 0) + _shape_bytes(shape_text)
    return out


def roofline_terms(
    per_device_flops: float,
    per_device_bytes: float,
    per_device_coll_bytes: float,
    chips: int,
):
    """The three time terms (seconds) + bottleneck label.

    Globals = per_device * chips; the chips in numerator and denominator
    cancel, so each term is just the per-device quantity over per-chip
    bandwidth — reported this way to keep the arithmetic auditable.
    """
    compute = per_device_flops / hw.PEAK_FLOPS_BF16
    memory = per_device_bytes / hw.HBM_BW
    collective = per_device_coll_bytes / hw.ICI_LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    bottleneck = max(terms, key=terms.get)
    return terms, bottleneck.replace("_s", "")


def model_flops(cfg, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D (train), 2·N·D (fwd-only), N = active params."""
    from repro.models import transformer

    n_active = transformer.count(cfg, active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens


def summarize_cell(record: dict) -> str:
    """One roofline table row from a dry-run cell JSON record."""
    t = record["roofline"]
    return (
        f"{record['arch']:24s} {record['shape']:12s} "
        f"C={t['compute_s']:9.3e}s M={t['memory_s']:9.3e}s X={t['collective_s']:9.3e}s "
        f"-> {record['bottleneck']:10s} useful={record.get('useful_flops_ratio', 0):5.2f}"
    )
