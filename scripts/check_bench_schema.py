"""Thin shim: the bench-JSON schema check now lives in qlint (DESIGN.md §9).

The full suite runs via ``scripts/check_static.py`` (wired into
``scripts/test.sh --tier2``); this entry point is kept for muscle memory
and for checking individual files:

Usage:  python scripts/check_bench_schema.py [file.json ...]
        (no args: the cumulative sweep files under experiments/bench/,
        requiring the ones the smoke suite maintains)
"""

from __future__ import annotations

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.rules.bench_schema import check_rows  # noqa: E402


def main(paths=None) -> int:
    """Validate explicit bench JSONs, or run the full rule via qlint."""
    if paths:
        errors = []
        for path in paths:
            with open(path) as f:
                rows = json.load(f)
            errors += [
                f"{f_.message}" for f_ in check_rows(os.path.basename(path), rows)
            ]
        if errors:
            print("check_bench_schema: FAIL")
            for e in errors:
                print(f"  - {e}")
            return 1
        print(
            f"check_bench_schema: OK ({', '.join(os.path.basename(p) for p in paths)})"
        )
        return 0
    from check_static import main as qlint_main

    return qlint_main(["--rules", "bench-schema", "--json", ""])


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
