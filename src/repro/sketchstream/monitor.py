"""In-step stream telemetry: a QSketch threaded through train/serve steps,
merged across the mesh by max.

Design choice (vs QSketch-Dyn, documented in DESIGN.md §4.3): the in-step
monitor uses the FULL QSketch construction — every element updates all m
registers — rather than Dyn's one-register-per-element route, because:

  1. Exact mergeability. Dyn's running Ĉ is a per-shard martingale; shards
     that see the same element (token streams always do) can't just add
     their Ĉ's, and the register-histogram MLE fallback is misspecified
     whenever m ≳ n_distinct (an untouched Dyn register means "empty
     sub-stream", probability e^{-n/m}, which the quantized-Exp(C/m)
     likelihood cannot express — it drives the MLE to 0). QSketch registers
     are plain max-monoid elements: merge is exact at any scale.
  2. On TPU the m-wide update is ONE fused VPU kernel over the (batch, m)
     tile (kernels/qsketch_update.py) — at telemetry sizes (m=256) it costs
     ~1e9 integer lane-ops per 1M-token step, noise against the model's
     1e13+ FLOPs. The paper's O(1)-vs-O(m) distinction prices scalar CPUs,
     not 8x128 vector lanes; Dyn's O(1) update stays the right choice for
     the single-stream CPU setting and is benchmarked as such.
  3. Estimation stays O(2^b) via the histogram MLE (beyond-paper trick),
     cheap enough to log every step.

Streams monitored:
  * token coverage:   element = token id, weight 1 (distinct vocab touched)
  * weighted coverage: element = token id, weight supplied by the pipeline
  * MoE routing:      element = expert id, weight = routed prob mass
  * serving DAU:      element = session id, weight = engagement weight
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import SketchConfig, estimators, qsketch
from repro.core.types import QSketchState


class MonitorState(NamedTuple):
    regs: jnp.ndarray  # int8[m]
    n_seen: jnp.ndarray  # int32 element counter (occurrences, not distinct)


def init(cfg: SketchConfig) -> MonitorState:
    return MonitorState(regs=qsketch.init(cfg).regs, n_seen=jnp.int32(0))


def update(cfg: SketchConfig, state: MonitorState, ids, weights=None) -> MonitorState:
    """Batched full-QSketch update (ids flattened; weight 1.0 if not given)."""
    ids = ids.reshape(-1)
    w = (
        jnp.ones(ids.shape, jnp.float32)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    st = qsketch.update(cfg, QSketchState(regs=state.regs), ids, w)
    return MonitorState(regs=st.regs, n_seen=state.n_seen + ids.shape[0])


def estimate(cfg: SketchConfig, state: MonitorState) -> jnp.ndarray:
    """Weighted cardinality via the O(2^b) histogram MLE."""
    hist = estimators.histogram(cfg, state.regs)
    chat, _, _ = estimators.qsketch_mle(cfg, hist)
    return chat


def merge(cfg: SketchConfig, a: MonitorState, b: MonitorState) -> MonitorState:
    """Exact union-stream merge (max monoid) — the cross-pod collective."""
    return MonitorState(regs=jnp.maximum(a.regs, b.regs), n_seen=a.n_seen + b.n_seen)
