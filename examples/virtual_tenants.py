"""Register sharing for the long tail: a VirtualDynMonitor tracks per-tenant
weighted cardinality with memory INDEPENDENT of the tenant count.

The multi-tenant examples so far spend a dedicated row per tenant —
`anytime_tenants.py` pays ~4.6 MiB for 4096 of them, and at the K = 10^7
tenants a real fleet sees that is ~11 GiB of Dyn state for a workload where
most tenants send a handful of events. The virtual tier (DESIGN.md §8.9)
flips the trade: a few pinned whales keep exact dense rows + anytime
martingales, and EVERY other tenant shares one fixed-size register pool —
(tenant, register) pairs hash straight into it, no routing table, no
per-tenant state at all. Tail reads are statistical: a compound-Poisson
solve of the tenant's pooled registers with the expected cross-tenant noise
cancelled, resolved down to the pool's noise floor.

The demo streams a Zipf workload, then:
  * reads whales exactly (hot martingales) and the tail statistically,
    reporting error against exact truth relative to the noise floor;
  * promotes a tenant that outgrew the tail mid-stream (`promote` — re-keys
    nobody, unlike `key_directory.pin` on a dense directory);
  * prints the memory ledger vs the dense-row alternative at fleet scale.

    PYTHONPATH=src python examples/virtual_tenants.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, virtual_dyn_array as vda
from repro.sketchstream import monitor


def _pair(ids64):
    ids64 = np.asarray(ids64, dtype=np.uint64)
    return (
        jnp.asarray((ids64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        jnp.asarray((ids64 >> np.uint64(32)).astype(np.uint32)),
    )


def main():
    cfg = SketchConfig(m=128, b=8, seed=3)
    n_tenants, n_pin, pool_size = 2048, 32, 2**16

    rng = np.random.default_rng(7)
    tenant_ids = rng.integers(0, 2**63, n_tenants, dtype=np.uint64)
    # Zipf sizes by rank: a few whales, a long tail of ~8-event tenants.
    sizes = np.maximum(6000.0 / np.arange(1, n_tenants + 1) ** 1.05, 8).astype(int)

    mon = monitor.VirtualDynMonitor.for_pool(
        cfg, pool_size, pinned=tuple(int(t) for t in tenant_ids[:n_pin])
    )
    st = mon.init()

    # One flat shuffled stream of (tenant, event id, weight), fed in batches.
    tidx = np.repeat(np.arange(n_tenants), sizes)
    rng.shuffle(tidx)
    n = tidx.shape[0]
    ids = rng.permutation(np.arange(n, dtype=np.uint32))
    w = rng.uniform(0.5, 1.5, n).astype(np.float32)
    truth = np.zeros(n_tenants)
    np.add.at(truth, tidx, w)

    bs = 8192
    for lo in range(0, n, bs):
        sl = slice(lo, min(lo + bs, n))
        st = mon.update(
            st, _pair(tenant_ids[tidx[sl]]), jnp.asarray(ids[sl]), jnp.asarray(w[sl])
        )

    m = mon.metrics(st)
    floor = float(vda.noise_floor(cfg, mon.vcfg, st.array))
    print(f"stream:            {n:,} events over {n_tenants:,} tenants "
          f"({n_pin} pinned)")
    print(f"pool load factor:  {m['virtual_pool_load_factor']:.2f}  "
          f"(health warns past 0.50)")
    print(f"tail noise floor:  {floor:.1f} weight  "
          f"(tenants under it read as pool noise)\n")

    est = np.asarray(mon.estimate(st, _pair(tenant_ids)))
    rel = np.abs(est - truth) / truth
    print(f"{'tenant rank':>11} {'tier':>7} {'true':>9} {'estimate':>9} {'rel.err':>8}")
    for r in (0, 8, 31, 64, 256, 1024, 2047):
        tier = "hot" if r < n_pin else "tail"
        print(f"{r:>11} {tier:>7} {truth[r]:>9,.0f} {est[r]:>9,.0f} {rel[r]:>8.1%}")
    above = truth >= 2 * floor
    tail_above = above & (np.arange(n_tenants) >= n_pin)
    print(f"\nhot tenants:          exact martingale reads (mean rel.err "
          f"{rel[:n_pin].mean():.1%})")
    print(f"tail above 2x floor:  mean rel.err {rel[tail_above].mean():.1%} "
          f"over {tail_above.sum()} tenants")
    print(f"tail below floor:     noise-dominated by design "
          f"({(~above)[n_pin:].sum()} tenants)\n")

    # A tenant outgrew the tail: promote it to an exact hot row. Pool
    # placement hashes (tenant, register) directly, so nobody else moves.
    # The default is the epoch fence — the new row starts empty and every
    # event from here on is tracked exactly (migrate=True instead carries
    # the virtual row's registers over; see promote's docstring).
    riser = int(tenant_ids[n_pin])  # rank 32: the biggest unpinned tenant
    mon, st = mon.promote(st, riser)
    w2 = rng.uniform(0.5, 1.5, 4096).astype(np.float32)
    st = mon.update(
        st, _pair(np.full(4096, riser, np.uint64)),
        jnp.asarray(np.arange(n, n + 4096, dtype=np.uint32)), jnp.asarray(w2),
    )
    resumed = np.asarray(mon.estimate(st, _pair([riser])))[0]
    print(f"promoted rank {n_pin} (epoch fence), then {len(w2):,} new events: "
          f"hot estimate {resumed:,.0f} vs exact post-promotion truth "
          f"{w2.sum():,.0f} ({abs(resumed - w2.sum()) / w2.sum():.1%} err, "
          f"martingale-exact from here on)")

    # The memory ledger at fleet scale: the virtual state never grows with K.
    v_bytes = vda.memory_bytes(cfg, mon.vcfg)
    print(f"\nvirtual state:     {v_bytes / 2**10:,.0f} KiB "
          f"(pool + hot table), for ANY tail size")
    for k in (10**5, 10**7):
        d = vda.dense_memory_bytes(cfg, k)
        print(f"dense rows K={k:.0e}: {d / 2**20:,.0f} MiB  "
              f"-> {d / v_bytes:,.0f}x the virtual state")


if __name__ == "__main__":
    main()
