"""Dry-run cell machinery (importable; no jax env mutation — dryrun.py owns
the XLA_FLAGS lines).

One "cell" = (architecture × input shape × mesh). For each cell this module
builds the abstract inputs (ShapeDtypeStructs only — nothing allocated),
jits the appropriate step with explicit in/out shardings, ``.lower()``s,
``.compile()``s, and extracts:

  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — per-device HLO FLOPs / bytes accessed
  * collective bytes   — parsed from the partitioned HLO text
  * roofline terms + bottleneck + MODEL_FLOPS/HLO_FLOPs usefulness ratio

Records are JSON files under experiments/dryrun/ — EXPERIMENTS.md §Dry-run
and §Roofline are generated from them.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import paper_qsketch
from repro.models import common as mcommon, sharding as msharding, transformer
from repro.roofline import analysis as ra, hlo_stats, hw
from repro.sketchstream import monitor
from repro.train import optimizer, serve_step, train_step

DEFAULT_OUT = "experiments/dryrun"


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _replicated_like(mesh, tree):
    return jax.tree.map(lambda _: _ns(mesh, P()), tree)


def _batch_shardings(mesh, batch_abs):
    def leaf(x):
        if x.ndim >= 1 and x.shape and x.shape[0] > 1:
            return _ns(mesh, msharding.resolve(("batch",) + (None,) * (x.ndim - 1), mesh, x.shape))
        return _ns(mesh, P())

    return jax.tree.map(leaf, batch_abs)


@dataclasses.dataclass
class CellOptions:
    quantized_opt: bool = True
    compress: bool = False
    sketch: bool = True
    microbatches: int = 1
    remat: object = True  # True/"full" | "dots" | False
    donate: bool = True
    # §Perf hillclimb knobs (baseline = defaults):
    sharded_xent: bool = False
    moe_impl: str = ""  # "" = config default; "shard_map_a2a" | "scatter"
    ssm_chunk: int = 0  # 0 = config default
    ssm_intra_dtype: str = ""  # "" = config default; "bfloat16"
    variant_tag: str = ""  # suffix for saved artifacts (e.g. "_opt1")


def _apply_overrides(cfg, opts: CellOptions):
    """Hillclimb knobs -> config replace (leaves baseline untouched)."""
    if opts.moe_impl and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl=opts.moe_impl))
    if cfg.ssm is not None and (opts.ssm_chunk or opts.ssm_intra_dtype):
        ssm = cfg.ssm
        if opts.ssm_chunk:
            ssm = dataclasses.replace(ssm, chunk=opts.ssm_chunk)
        if opts.ssm_intra_dtype:
            ssm = dataclasses.replace(ssm, intra_dtype=opts.ssm_intra_dtype)
        cfg = dataclasses.replace(cfg, ssm=ssm)
    return cfg


def build_cell(arch: str, shape: str, mesh, opts: CellOptions = CellOptions()):
    """Returns (lower_fn, meta). lower_fn() -> jax.stages.Lowered."""
    cfg = _apply_overrides(configs.get_config(arch), opts)
    ss = configs.SHAPES[shape]
    defs = transformer.model_defs(cfg)
    params_abs = mcommon.abstract_params(defs)
    param_sh = jax.tree.map(lambda s: _ns(mesh, s), msharding.spec_tree(defs, mesh))
    sketch_cfg = paper_qsketch.telemetry_default() if opts.sketch else None

    meta = {
        "arch": arch,
        "shape": shape,
        "kind": ss.kind,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "chips": int(mesh.size),
        "params_total": transformer.count(cfg),
        "params_active": transformer.count(cfg, active_only=True),
        "options": dataclasses.asdict(opts),
    }

    if ss.kind == "train":
        ocfg = optimizer.OptConfig(quantized=opts.quantized_opt)
        opt_abs = jax.eval_shape(lambda p: optimizer.init(p, ocfg), params_abs)
        opt_sh = jax.tree.map(
            lambda s: _ns(mesh, s), optimizer.spec_tree(defs, mesh, ocfg)
        )
        comp_abs = (
            jax.eval_shape(lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p), params_abs)
            if opts.compress
            else {}
        )
        comp_sh = param_sh if opts.compress else {}
        sk_abs = jax.eval_shape(lambda: monitor.init(sketch_cfg)) if opts.sketch else {}
        sk_sh = _replicated_like(mesh, sk_abs)
        batch_abs = configs.input_specs(cfg, shape)
        batch_sh = _batch_shardings(mesh, batch_abs)

        fn = train_step.make_train_step(
            cfg,
            ocfg,
            mesh,
            sketch_cfg=sketch_cfg,
            compress=opts.compress,
            microbatches=opts.microbatches,
            remat=opts.remat,
            sharded_xent=opts.sharded_xent,
        )
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, opt_sh, comp_sh, sk_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, comp_sh, sk_sh, None),
            donate_argnums=(0, 1, 2, 3) if opts.donate else (),
        )
        meta["tokens_per_step"] = ss.batch * ss.seq
        return lambda: jitted.lower(params_abs, opt_abs, comp_abs, sk_abs, batch_abs), (cfg, meta)

    if ss.kind == "prefill":
        batch_abs = configs.input_specs(cfg, shape)
        batch_sh = _batch_shardings(mesh, batch_abs)
        fn = serve_step.make_prefill(cfg, mesh, max_len=ss.seq)
        cache_sh = jax.tree.map(
            lambda s: _ns(mesh, s),
            msharding.spec_tree(transformer.cache_defs(cfg, ss.batch, ss.seq), mesh),
        )
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, batch_sh["tokens"])
            + ((batch_sh["extra_embeds"],) if "extra_embeds" in batch_abs else ()),
            out_shardings=(None, cache_sh),
        )
        args = (params_abs, batch_abs["tokens"]) + (
            (batch_abs["extra_embeds"],) if "extra_embeds" in batch_abs else ()
        )
        meta["tokens_per_step"] = ss.batch * ss.seq
        return lambda: jitted.lower(*args), (cfg, meta)

    # decode
    batch_abs = configs.input_specs(cfg, shape)
    cache_abs = batch_abs["cache"]
    cache_sh = jax.tree.map(
        lambda s: _ns(mesh, s),
        msharding.spec_tree(transformer.cache_defs(cfg, ss.batch, ss.seq), mesh),
    )
    sk_abs = jax.eval_shape(lambda: monitor.init(sketch_cfg)) if opts.sketch else None
    sk_sh = _replicated_like(mesh, sk_abs) if opts.sketch else None
    tok_sh = _ns(mesh, msharding.resolve(("batch", None), mesh, (ss.batch, 1)))
    sid_abs = jax.ShapeDtypeStruct((ss.batch,), jnp.uint32)
    sw_abs = jax.ShapeDtypeStruct((ss.batch,), jnp.float32)
    sid_sh = _ns(mesh, msharding.resolve(("batch",), mesh, (ss.batch,)))

    fn = serve_step.make_decode_step(cfg, mesh, sketch_cfg=sketch_cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(param_sh, cache_sh, _ns(mesh, P()), tok_sh, sk_sh, sid_sh, sid_sh),
        out_shardings=(tok_sh, cache_sh, sk_sh),
        donate_argnums=(1,) if opts.donate else (),
    )
    meta["tokens_per_step"] = ss.batch  # one new token per sequence
    args = (
        params_abs,
        cache_abs,
        batch_abs["cur_len"],
        batch_abs["tokens"],
        sk_abs,
        sid_abs,
        sw_abs,
    )
    return lambda: jitted.lower(*args), (cfg, meta)


def run_cell(arch: str, shape: str, mesh, opts: CellOptions = CellOptions(), parse_hlo: bool = True) -> dict:
    cfg = configs.get_config(arch)
    reason = configs.skip_reason(cfg, shape)
    base = {"arch": arch, "shape": shape, "status": "skip", "skip_reason": reason}
    if reason is not None:
        return base

    try:
        lower_fn, (cfg, meta) = build_cell(arch, shape, mesh, opts)
        t0 = time.time()
        lowered = lower_fn()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        cost = compiled.cost_analysis()
        # Older JAX returns one properties-dict per device instead of a dict.
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        mem = compiled.memory_analysis()
        chips = meta["chips"]
        # Loop-aware structural stats (while bodies x trip count). The raw
        # cost_analysis numbers count loop bodies ONCE and are kept as a
        # per-iteration diagnostic (EXPERIMENTS.md §Numerics-notes). The
        # compiled HLO text is persisted (zstd) so the analyzer can be
        # improved offline without recompiling (launch/reanalyze.py).
        hlo_text = compiled.as_text() if parse_hlo else ""
        if hlo_text:
            _save_hlo(meta, hlo_text, variant=getattr(opts, "variant_tag", ""))
        stats = (
            hlo_stats.analyze(hlo_text) if parse_hlo else
            {"dot_flops": 0.0, "hbm_bytes": 0.0, "collective_by_op": {},
             "collective_bytes": 0.0, "unknown_trip_whiles": -1}
        )
        coll = stats["collective_by_op"]
        pd_flops = float(stats["dot_flops"])
        pd_bytes = float(stats["hbm_bytes"])
        pd_coll = float(stats["collective_bytes"])
        terms, bottleneck = ra.roofline_terms(pd_flops, pd_bytes, pd_coll, chips)
        mf = ra.model_flops(cfg, meta["tokens_per_step"], meta["kind"])
        hlo_global = pd_flops * chips
        record = {
            **meta,
            "status": "ok",
            "lower_s": t1 - t0,
            "compile_s": t2 - t1,
            "per_device": {
                "flops": pd_flops,
                "bytes_accessed": pd_bytes,
                "collective_bytes": pd_coll,
                "collective_by_op": coll,
                "unknown_trip_whiles": stats.get("unknown_trip_whiles", 0),
                "cost_analysis_flops_periter": float(cost.get("flops", 0.0)),
                "cost_analysis_bytes_periter": float(cost.get("bytes accessed", 0.0)),
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
            },
            "hbm_fit": {
                "peak_bytes_est": int(
                    mem.argument_size_in_bytes
                    + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes
                    - mem.alias_size_in_bytes
                ),
                "chip_hbm_bytes": hw.CHIP_HBM_BYTES,
            },
            "roofline": terms,
            "bottleneck": bottleneck,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_global,
            "useful_flops_ratio": (mf / hlo_global) if hlo_global else 0.0,
        }
        return record
    except Exception as e:
        return {**base, "status": "error", "error": f"{type(e).__name__}: {e}", "traceback": traceback.format_exc()[-4000:]}


def _save_hlo(meta, text: str, out_dir: str = DEFAULT_OUT, variant: str = ""):
    """Persist the optimized HLO, zstd if available, stdlib gzip otherwise.

    ``zstandard`` is an optional dep (not in every container); the HLO
    artifact is a side-channel for reanalyze.py, so a missing codec must
    never fail the dry-run cell itself.
    """
    os.makedirs(out_dir, exist_ok=True)
    tag = ("_multipod" if "pod" in meta["mesh"] else "_singlepod") + variant
    stem = os.path.join(out_dir, f"{meta['arch']}_{meta['shape']}{tag}")
    try:
        import zstandard

        with open(stem + ".hlo.zst", "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(text.encode()))
    except ImportError:
        import gzip

        with gzip.open(stem + ".hlo.gz", "wb", compresslevel=6) as f:
            f.write(text.encode())


def parse_collective_bytes_safe(compiled):
    try:
        return ra.parse_collective_bytes(compiled.as_text())
    except Exception:
        return {}


def save_record(record: dict, out_dir: str = DEFAULT_OUT, tag: str = ""):
    """tag examples: _singlepod, _multipod, _singlepod_opt1."""
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}_{record['shape']}{tag}.json"
    path = os.path.join(out_dir, name)
    slim = {k: v for k, v in record.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)
    return path
