"""Synthetic weighted-element streams (paper §5.1 datasets).

Names follow the paper: "<distribution>-<#elements>", e.g. Uniform-10k.
Weights: Uniform(0,1), Gauss N(1, 0.1) (clipped positive), Gamma(1, 2).
``with_repeats`` emulates real streams (CAIDA-like): element occurrences
follow a Zipf law, so the same (id, weight) pair arrives many times — the
dedup/idempotence properties of the sketches are what keep the estimate
unbiased under repeats.
"""

from __future__ import annotations

import numpy as np

DISTRIBUTIONS = ("uniform", "gauss", "gamma")


def weights(dist: str, n: int, rng: np.random.Generator) -> np.ndarray:
    if dist == "uniform":
        w = rng.uniform(0.0, 1.0, n) + 1e-6
    elif dist == "gauss":
        w = np.abs(rng.normal(1.0, 0.1, n)) + 1e-6
    elif dist == "gamma":
        w = rng.gamma(1.0, 2.0, n) + 1e-6
    else:
        raise ValueError(dist)
    return w.astype(np.float32)


def stream(dist: str, n_elements: int, seed: int = 0):
    """Distinct elements only: (ids uint32, weights f32, true_C float)."""
    rng = np.random.default_rng(seed)
    ids = rng.choice(np.iinfo(np.uint32).max, size=n_elements, replace=False).astype(
        np.uint32
    )
    w = weights(dist, n_elements, rng)
    return ids, w, float(w.astype(np.float64).sum())


def with_repeats(dist: str, n_elements: int, n_stream: int, seed: int = 0, zipf_a: float = 1.3):
    """Zipf-repeated stream over n_elements distincts, length n_stream.

    true_C counts only elements that actually APPEAR in the stream (a Zipf
    draw touches a strict subset of the candidate pool).
    """
    ids, w, _ = stream(dist, n_elements, seed)
    rng = np.random.default_rng(seed + 1)
    ranks = rng.zipf(zipf_a, n_stream) % n_elements
    true_c = float(w[np.unique(ranks)].astype(np.float64).sum())
    return ids[ranks], w[ranks], true_c


def netflow(n_flows: int, n_packets: int, seed: int = 0):
    """CAIDA-like: (src,dst) flow ids weighted by (fixed) flow packet size."""
    rng = np.random.default_rng(seed)
    flow_ids = rng.choice(np.iinfo(np.uint32).max, size=n_flows, replace=False).astype(np.uint32)
    sizes = np.clip(rng.lognormal(6.0, 1.0, n_flows), 40, 65535).astype(np.float32)
    ranks = rng.zipf(1.2, n_packets) % n_flows
    true_c = float(sizes[np.unique(ranks)].astype(np.float64).sum())
    return flow_ids[ranks], sizes[ranks], true_c


def netflow_keyed(n_keys: int, n_flows: int, n_packets: int, seed: int = 0):
    """Keyed CAIDA-like stream for per-key monitoring (SketchArray workload).

    Each packet carries (key, flow id, size): ``key`` is the monitored entity
    (destination host / user bucket) drawn Zipf over n_keys, the flow id is
    drawn Zipf from a shared pool, and the weight is the flow's fixed size.
    Returns (keys int32, flow ids uint32, sizes f32, true_c float64[n_keys])
    where true_c[k] sums the sizes of DISTINCT flows seen under key k.
    """
    rng = np.random.default_rng(seed)
    flow_ids = rng.choice(np.iinfo(np.uint32).max, size=n_flows, replace=False).astype(np.uint32)
    sizes = np.clip(rng.lognormal(6.0, 1.0, n_flows), 40, 65535).astype(np.float32)
    keys = (rng.zipf(1.3, n_packets) % n_keys).astype(np.int32)
    ranks = rng.zipf(1.2, n_packets) % n_flows
    pairs = np.unique(np.stack([keys, ranks], axis=1), axis=0)
    true_c = np.zeros(n_keys, dtype=np.float64)
    np.add.at(true_c, pairs[:, 0], sizes[pairs[:, 1]].astype(np.float64))
    return keys, flow_ids[ranks], sizes[ranks], true_c
