"""Streaming ingest pipeline (sketchstream/ingest.py) tests.

Acceptance contracts:

1. **Bit-identity** — any interleaving of pushes (random sizes), flushes and
   rotations through the pipeline produces container states bit-identical to
   a synchronous element-log oracle driven over the SAME micro-batch
   partition (the partition is deterministic: FIFO fill of the fixed
   ``batch_size`` staging shape; a flush/rotate seals the partial batch).
   This includes a FORCED-backpressure schedule (the readiness probe pinned
   to "never ready", so every dispatch beyond ``queue_depth`` blocks), the
   Pallas kernel route, and the sharded fronts on the 8-device host mesh.
2. **Drop determinism** — with policy="drop" and a never-ready queue,
   exactly the first ``queue_depth`` batches are admitted, everything after
   is counted in ``dropped`` (never silently lost), and the settled state
   equals the oracle over the admitted prefix.
3. **Donation is real** — the ``donate=True`` update/rotate entry points
   reuse the input state buffers in place (``unsafe_buffer_pointer``
   equality), the no-copy guarantee the sustained-Mops headline rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    dyn_array,
    key_directory,
    sharded_dyn_array,
    sharded_window_array,
    window_array,
)
from repro.core.key_directory import DirectoryConfig
from repro.kernels import ops
from repro.launch.mesh import make_sketch_mesh
from repro.sketchstream import ingest

CFG = SketchConfig(m=64, b=6, seed=3)
K = 64


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()  # 8 shards under scripts/test.sh


def _elements(n, seed, k=K):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, k, n, dtype=np.int32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n) + 1e-5).astype(np.float32)
    return keys, ids, w


def _partition(keys, ids, w, bsz):
    """The micro-batch partition the pipeline's FIFO fill induces on a
    contiguous element log (unpadded tail — the mask no-op contract makes
    the pipeline's mask-padded tail equivalent)."""
    return [
        (keys[i : i + bsz], ids[i : i + bsz], w[i : i + bsz])
        for i in range(0, len(keys), bsz)
    ]


def _oracle_dyn(cfg, k, batches):
    st = dyn_array.init(cfg, k)
    for keys, ids, w in batches:
        st = dyn_array.update_batch(
            cfg, st, jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w)
        )
    return st

def _assert_dyn_equal(a, b):
    for leaf in ("regs", "hists", "chats"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, leaf)), np.asarray(getattr(b, leaf)),
            err_msg=f"leaf {leaf} diverged",
        )


def _assert_window_equal(a, b):
    for leaf in ("regs", "hists", "chats", "union_regs", "union_hists",
                 "union_chats"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, leaf)), np.asarray(getattr(b, leaf)),
            err_msg=f"leaf {leaf} diverged",
        )
    assert (int(a.head), int(a.filled), int(a.epoch_id)) == (
        int(b.head), int(b.filled), int(b.epoch_id),
    )


# ---------------------------------------------------------------------------
# bit-identity vs the synchronous element-log oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz", [64, 97])
def test_random_push_interleaving_bit_identical(bsz):
    """Random push sizes (including > batch_size and size-1) through a
    depth-4 queue land bit-identically to the oracle over the induced
    partition — the headline property test, and the regression test for the
    staging-buffer reuse race (queue_depth > #staging buffers)."""
    rng = np.random.default_rng(11)
    logs = []
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, K),
        ingest.IngestConfig(batch_size=bsz, queue_depth=4),
    )
    for i in range(17):
        n = int(rng.integers(1, 3 * bsz))
        trip = _elements(n, seed=100 + i)
        logs.append(trip)
        pipe.push(*trip)
    got = pipe.result()

    keys, ids, w = (np.concatenate([t[j] for t in logs]) for j in range(3))
    ref = _oracle_dyn(CFG, K, _partition(keys, ids, w, bsz))
    _assert_dyn_equal(got, ref)
    assert pipe.stats.pushed == len(keys)
    assert pipe.stats.batches == -(-len(keys) // bsz)
    assert pipe.stats.dropped == 0


def test_flush_seals_batch_boundaries():
    """Explicit flush() seals a partial batch — the oracle must see the SAME
    boundary or chats (partition-dependent martingales) would diverge."""
    a = _elements(40, seed=1)
    b = _elements(50, seed=2)
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, K), ingest.IngestConfig(batch_size=64)
    )
    pipe.push(*a)
    pipe.flush()  # seals [40], next batch starts empty
    pipe.push(*b)
    got = pipe.result()  # seals [50]

    ref = _oracle_dyn(CFG, K, [a, b])
    _assert_dyn_equal(got, ref)
    assert pipe.stats.batches == 2
    assert pipe.stats.partial_batches == 2


def test_kernel_route_bit_identical():
    trip = _elements(300, seed=5)
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, K),
        ingest.IngestConfig(batch_size=128), use_kernel=True,
    )
    pipe.push(*trip)
    _assert_dyn_equal(pipe.result(), _oracle_dyn(CFG, K, _partition(*trip, 128)))


def test_forced_backpressure_block_bit_identical():
    """Readiness pinned to 'never ready': every dispatch past queue_depth
    must take the block path (stall counters move), and the result is STILL
    bit-identical — backpressure may delay, never reorder or corrupt."""
    bsz, depth = 64, 2
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, K),
        ingest.IngestConfig(batch_size=bsz, queue_depth=depth, policy="block"),
    )
    pipe._ready = lambda t: False  # force the full-queue path deterministically
    trip = _elements(6 * bsz, seed=21)
    pipe.push(*trip)
    got = pipe.result()

    _assert_dyn_equal(got, _oracle_dyn(CFG, K, _partition(*trip, bsz)))
    assert pipe.stats.stalls == 6 - depth
    assert pipe.stats.stall_s >= 0.0
    assert pipe.stats.max_in_flight <= depth
    assert pipe.stats.dropped == 0


def test_drop_policy_deterministic_prefix():
    """Never-ready + policy='drop': exactly the first queue_depth batches
    are admitted; later seals (including the result() flush of the partial
    tail) are shed and counted."""
    bsz, depth = 64, 2
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, K),
        ingest.IngestConfig(batch_size=bsz, queue_depth=depth, policy="drop"),
    )
    pipe._ready = lambda t: False
    trip = _elements(5 * bsz + 17, seed=22)
    pipe.push(*trip)
    got = pipe.result()

    keys, ids, w = trip
    admitted = _partition(keys[: depth * bsz], ids[: depth * bsz],
                          w[: depth * bsz], bsz)
    _assert_dyn_equal(got, _oracle_dyn(CFG, K, admitted))
    assert pipe.stats.batches == depth
    assert pipe.stats.dropped == 3 * bsz + 17
    assert pipe.stats.pushed == 5 * bsz + 17


def test_window_rotation_interleaving_bit_identical():
    """Pushes interleaved with rotations: the retire barrier must order every
    earlier element into the pre-rotation epoch, matching the synchronous
    schedule on every ring/union leaf and the epoch clock."""
    bsz = 64
    rng = np.random.default_rng(31)
    pipe = ingest.window_pipeline(
        CFG, window_array.init(CFG, K, 4),
        ingest.IngestConfig(batch_size=bsz, queue_depth=3),
    )
    ref = window_array.init(CFG, K, 4)
    for ep in range(6):
        pending = []
        for i in range(int(rng.integers(1, 4))):
            trip = _elements(int(rng.integers(1, 2 * bsz)), seed=500 + 7 * ep + i)
            pipe.push(*trip)
            pending.append(trip)
        # Oracle: same element log, same partition, sealed at the rotate.
        keys, ids, w = (np.concatenate([t[j] for t in pending]) for j in range(3))
        for batch in _partition(keys, ids, w, bsz):
            ref = window_array.update_batch(
                CFG, ref, *(jnp.asarray(x) for x in batch)
            )
        pipe.rotate()
        ref = window_array.rotate(CFG, ref)
    _assert_window_equal(pipe.result(), ref)
    assert pipe.stats.rotations == 6


def test_rotate_requires_rotatable_container():
    pipe = ingest.dyn_pipeline(CFG, dyn_array.init(CFG, K))
    with pytest.raises(ValueError, match="without rotate"):
        pipe.rotate()


def test_push_validates_lane_lengths():
    pipe = ingest.dyn_pipeline(CFG, dyn_array.init(CFG, K))
    with pytest.raises(ValueError, match="equal-length"):
        pipe.push(np.zeros(3, np.int32), np.zeros(2, np.uint32))


def test_ingest_config_validation():
    with pytest.raises(ValueError):
        ingest.IngestConfig(batch_size=0)
    with pytest.raises(ValueError):
        ingest.IngestConfig(queue_depth=0)
    with pytest.raises(ValueError):
        ingest.IngestConfig(policy="spill")


# ---------------------------------------------------------------------------
# donation audit: in-place buffer reuse is real, not aspirational
# ---------------------------------------------------------------------------


def _ptrs(state):
    return {
        name: getattr(state, name).unsafe_buffer_pointer()
        for name in ("regs", "hists", "chats")
    }


def test_dyn_update_donation_reuses_buffers():
    keys, ids, w = (jnp.asarray(x) for x in _elements(256, seed=41))
    st = dyn_array.init(CFG, K)
    st = dyn_array.update_batch(CFG, st, keys, ids, w)  # settle shapes
    jax.block_until_ready(st.chats)
    before = _ptrs(st)
    ref = dyn_array.update_batch(CFG, st, keys, ids, w)  # non-donating copy
    out = dyn_array.update_batch(CFG, st, keys, ids, w, donate=True)
    after = _ptrs(out)
    for name, ptr in before.items():
        assert after[name] == ptr, f"{name} was copied despite donation"
    _assert_dyn_equal(out, ref)


def test_window_rotate_donation_reuses_buffers():
    keys, ids, w = (jnp.asarray(x) for x in _elements(256, seed=42))
    st = window_array.update_batch(CFG, window_array.init(CFG, K, 4), keys, ids, w)
    jax.block_until_ready(st.union_chats)
    before = st.regs.unsafe_buffer_pointer()
    ref = window_array.rotate(CFG, st)
    out = window_array.rotate(CFG, st, donate=True)
    assert out.regs.unsafe_buffer_pointer() == before
    _assert_window_equal(out, ref)


def test_kernel_op_donation_matches_core_path():
    keys, ids, w = (jnp.asarray(x) for x in _elements(256, seed=43))
    st = dyn_array.init(CFG, K)
    ref = dyn_array.update_batch(CFG, st, keys, ids, w)
    out = ops.dyn_array_update_op(CFG, st, keys, ids, w, donate=True)
    _assert_dyn_equal(out, ref)


# ---------------------------------------------------------------------------
# sharded fronts: same contracts on the 8-device host mesh
# ---------------------------------------------------------------------------


def test_sharded_dyn_pipeline_bit_identical(mesh):
    bsz = 64
    trip = _elements(5 * bsz + 13, seed=51)
    pipe = ingest.sharded_dyn_pipeline(
        CFG, mesh, sharded_dyn_array.init(CFG, K, mesh),
        ingest.IngestConfig(batch_size=bsz, queue_depth=3),
    )
    pipe.push(*trip)
    got = pipe.result()

    ref = sharded_dyn_array.init(CFG, K, mesh)
    for batch in _partition(*trip, bsz):
        ref = sharded_dyn_array.update_batch(
            CFG, mesh, ref, *(jnp.asarray(x) for x in batch)
        )
    _assert_dyn_equal(got, ref)


def test_sharded_window_pipeline_rotation_bit_identical(mesh):
    bsz = 64
    pipe = ingest.sharded_window_pipeline(
        CFG, mesh, sharded_window_array.init(CFG, K, 3, mesh),
        ingest.IngestConfig(batch_size=bsz),
    )
    ref = sharded_window_array.init(CFG, K, 3, mesh)
    for ep in range(4):
        trip = _elements(2 * bsz + 9, seed=600 + ep)
        pipe.push(*trip)
        for batch in _partition(*trip, bsz):
            ref = sharded_window_array.update_batch(
                CFG, mesh, ref, *(jnp.asarray(x) for x in batch)
            )
        pipe.rotate()
        ref = sharded_window_array.rotate(CFG, mesh, ref)
    _assert_window_equal(pipe.result(), ref)


# ---------------------------------------------------------------------------
# tenant front: routed ingest == synchronous route + update + rotate + evict
# ---------------------------------------------------------------------------


def test_tenant_window_ingest_matches_synchronous_routing():
    dcfg = DirectoryConfig(capacity=K, seed=CFG.seed)
    # Push size == batch_size so both schedules induce the same partition.
    bsz = 128
    tw = ingest.TenantWindowIngest(
        CFG, dcfg, n_epochs=3,
        icfg=ingest.IngestConfig(batch_size=bsz), evict_after=2,
    )
    ref_dir = key_directory.init(dcfg)
    ref = window_array.init(CFG, K, 3)
    rng = np.random.default_rng(71)
    for ep in range(4):
        tenants = rng.integers(0, 2**32, bsz, dtype=np.uint32)
        ids = rng.integers(0, 2**32, bsz, dtype=np.uint32)
        w = (rng.gamma(1.0, 2.0, bsz) + 1e-5).astype(np.float32)
        tw.push(tenants, ids, w)
        slots, ref_dir = key_directory.route(
            dcfg, ref_dir, tenants, epoch=jnp.int32(ep)
        )
        ref = window_array.update_batch(
            CFG, ref, slots, jnp.asarray(ids), jnp.asarray(w)
        )
        tw.rotate()
        ref = window_array.rotate(CFG, ref)
        ref_dir, _ = key_directory.evict_older_than(
            dcfg, ref_dir, jnp.int32(ep + 1 - 2)
        )
    _assert_window_equal(tw.result(), ref)
    np.testing.assert_array_equal(
        np.asarray(tw.directory.fingerprints), np.asarray(ref_dir.fingerprints)
    )
    met = tw.metrics()
    assert met["ingest_rotations"] == 4
    assert met["tenant_slots_claimed"] == int(
        jnp.sum((ref_dir.fingerprints != 0).astype(jnp.int32))
    )
    assert 0.0 <= met["tenant_collision_rate"] <= 1.0
