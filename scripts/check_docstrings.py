"""Thin shim: the docstring audit now lives in qlint (DESIGN.md §9).

The full suite runs via ``scripts/check_static.py`` (wired into
``scripts/test.sh --tier2``); this entry point is kept for muscle memory
and for checking individual files:

Usage:  python scripts/check_docstrings.py [path ...]
        (no args: the rule's default scope — core/, sketchstream/,
        kernels/, analysis/)
"""

from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.rules.docstrings import check_tree  # noqa: E402


def check_file(path: str) -> list[str]:
    """Return one error string per missing docstring in ``path``."""
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, REPO)
    return [f"{f_.path}:{f_.line}: {f_.message}" for f_ in check_tree(tree, rel)]


def main(paths=None) -> int:
    """Run the docstrings rule (explicit files, or the default scope)."""
    if paths:
        errors = []
        for path in paths:
            errors += check_file(path)
        if errors:
            print("check_docstrings: FAIL")
            for e in errors:
                print(f"  - {e}")
            return 1
        print(f"check_docstrings: OK ({len(paths)} files)")
        return 0
    from check_static import main as qlint_main

    return qlint_main(["--rules", "docstrings", "--json", ""])


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
