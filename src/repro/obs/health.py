"""Sketch self-introspection: one ``health_report`` over every container.

The failure modes an operator must see are implied by the paper's own
design (QSketch, arXiv 2406.19143) and the repo's extensions on top of it:

* **Top-bin saturation.** Registers are b-bit quantized with a truncation
  ceiling r_max; once a register clamps at the top bin the sketch can no
  longer distinguish further weight on that slot and the MLE biases low.
  A rising ``register_saturation_frac`` means the deployment outgrew its
  register width (raise b or re-scale weights).
* **Occupancy.** The MLE's variance contract assumes untouched registers
  remain (the routed-kind guard); near-full occupancy with the top bins
  filling is the saturation precursor, near-zero occupancy means the
  container is oversized for its traffic.
* **Anytime-vs-MLE drift.** The Dyn-family anytime martingale (§4.3) and
  the histogram MLE estimate the same quantity; their relative drift is a
  live consistency probe — a blowup flags a bug or an abused merge (chats
  added across overlapping streams, DESIGN.md §8.4). The routed MLE is
  *misspecified* when a row still has untouched registers (m ≳ n_distinct
  drives it to 0 — DESIGN.md §4), so drift is measured only over
  well-specified rows (every register touched) and the report carries the
  in-regime fraction as an informational check.
* **Union-cache staleness.** The window ring maintains a cached epoch
  union whose invariant (union_regs == max over live epoch planes) is
  cheap to verify; any mismatch is corruption.
* **Directory pressure.** Load factor and collision rate of the key
  directory — collisions silently merge tenants, so the warn threshold is
  tight.
* **CI width.** The estimator's own confidence interval
  (``estimate_*_with_ci``): a wide relative CI means the geometry (m) is
  too small for the observed cardinalities.

* **Pool pressure (virtual tier).** The shared tail pool's load factor
  drives cross-tenant collision noise, and the noise floor α·w_tail/(1−α)
  is the smallest tail weight a virtual read can resolve — past the load
  bound, grow the pool or pin the heaviest tail tenants (DESIGN.md §8.9).

``health_report(cfg, state)`` computes all applicable checks for any of
the 9 container state types and returns a plain dict with per-check
values, thresholds, and warn flags. It is host-only and on-demand — it
may sync the device and (for the drift/CI checks) run a solve, so call it
at health-probe cadence, never per batch.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import estimation, key_directory
from repro.core.types import (
    DynArrayState,
    DynState,
    QSketchState,
    ShardedArrayState,
    ShardedDynArrayState,
    ShardedWindowArrayState,
    SketchArrayState,
    SketchConfig,
    VirtualDynArrayState,
    WindowArrayState,
)
from repro.obs import trace


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Warn thresholds (a check warns when its value EXCEEDS the bound).

    Defaults are deliberately loose enough that a healthy fresh container
    is quiet; tighten per deployment via ``health_report(thresholds=...)``.
    """

    register_saturation_frac: float = 0.05
    # Occupancy is informational by default: with enough distinct items a
    # healthy sketch legitimately touches every register, so a warn bound
    # only makes sense per deployment (set it to e.g. 0.99 when the
    # workload is known-sparse).
    occupancy_frac: float | None = None
    union_staleness_frac: float = 0.0
    # Both estimators are ~1/sqrt(m)-noisy and batch-mode chats carry a
    # documented bias, so healthy drift runs tens of percent at small m;
    # the check exists to catch catastrophic inconsistency (abused merges,
    # corrupted hists — order-of-magnitude drift), not sampling noise.
    anytime_mle_drift: float = 1.0
    ci_rel_width: float = 0.5
    directory_load_factor: float = 0.9
    directory_collision_rate: float = 0.01
    # Virtual tier (VirtualDynArrayState): past ~0.5 pool load the per-slot
    # collision noise grows toward the signal and the cancellation's
    # variance bound degrades (DESIGN.md §8.9) — size the pool, or pin the
    # heaviest tail tenants.
    pool_load_factor: float = 0.5
    # The noise floor is workload-scaled (α·W_pool/(1−α) is an absolute
    # weight), so a universal default would be meaningless — set a bound
    # per deployment at the smallest tail weight the operator must resolve.
    pool_noise_floor: float | None = None


DEFAULT_THRESHOLDS = Thresholds()

_CONTAINER_NAMES = {
    QSketchState: "qsketch",
    DynState: "qsketch_dyn",
    SketchArrayState: "sketch_array",
    ShardedArrayState: "sharded_array",
    DynArrayState: "dyn_array",
    ShardedDynArrayState: "sharded_dyn_array",
    WindowArrayState: "window_array",
    ShardedWindowArrayState: "sharded_window_array",
    VirtualDynArrayState: "virtual_dyn_array",
}

_DYN_LIKE = (DynState, DynArrayState, ShardedDynArrayState)
_WINDOW_LIKE = (WindowArrayState, ShardedWindowArrayState)
_FULL_KIND = (QSketchState, SketchArrayState, ShardedArrayState)


def _full_hists(cfg: SketchConfig, hists) -> jnp.ndarray:
    """Maintained touched-register hists (bin 0 pinned to 0) -> full hists
    whose rows sum to m (the estimation layer's routed input contract)."""
    return hists.at[:, 0].set(cfg.m - jnp.sum(hists, axis=1))


def _check(checks, warnings, name, value, threshold):
    value = float(value)
    warn = threshold is not None and value > threshold
    checks[name] = {"value": value, "threshold": threshold, "warn": warn}
    if warn:
        warnings.append(name)


def _info(checks, name, value):
    checks[name] = {"value": float(value), "threshold": None, "warn": False}


def directory_health(dcfg, state, checks, warnings, thresholds) -> None:
    """Fold directory load-factor + collision-rate checks into a report."""
    _check(
        checks, warnings, "directory_load_factor",
        key_directory.occupancy(state), thresholds.directory_load_factor,
    )
    _check(
        checks, warnings, "directory_collision_rate",
        key_directory.collision_rate(state), thresholds.directory_collision_rate,
    )


def health_report(
    cfg: SketchConfig,
    state,
    *,
    directory=None,
    dcfg=None,
    vcfg=None,
    thresholds: Thresholds = DEFAULT_THRESHOLDS,
    solver: str = "newton",
) -> dict:
    """Uniform health report over any of the 9 container state types.

    Args:
      cfg: the container's SketchConfig (geometry of the estimation checks).
      state: one of QSketchState / DynState / SketchArrayState /
        ShardedArrayState / DynArrayState / ShardedDynArrayState /
        WindowArrayState / ShardedWindowArrayState / VirtualDynArrayState
        (monitor wrappers: pass the container leaf, plus ``directory=`` for
        the routing telemetry).
      directory: optional ``DirectoryState`` for load/collision checks
        (``dcfg`` is accepted for symmetry but not required).
      vcfg: optional ``VirtualConfig`` — only read for
        ``VirtualDynArrayState``, where the noise-floor check needs the
        virtual row width m_v (defaults to cfg.m when omitted).
      thresholds: warn bounds; every check warns when value > threshold.
      solver: estimation solver for the drift/CI checks ("newton" is the
        bit-exact default; pass "lut" at large K).

    Returns a plain dict: ``{"container", "checks": {name: {"value",
    "threshold", "warn"}}, "warnings": [...], "ok": bool}``. Host-only —
    raises if called under an active jax trace.
    """
    if not jax.core.trace_state_clean():
        raise RuntimeError(
            "health_report is host-only (it syncs device values and runs "
            "solves) — never call it inside jit/shard_map"
        )
    name = _CONTAINER_NAMES.get(type(state))
    if name is None:
        raise TypeError(
            f"health_report: unsupported state type {type(state).__name__}; "
            f"expected one of {sorted(c.__name__ for c in _CONTAINER_NAMES)}"
        )
    checks: dict[str, dict] = {}
    warnings: list[str] = []

    # ---- virtual tier: pool-plane checks + the hot tier's dense report ---
    if isinstance(state, VirtualDynArrayState):
        pool_size = state.pool.shape[0]
        _check(
            checks, warnings, "pool_load_factor",
            1.0 - state.pool_hist[0].astype(jnp.float32) / pool_size,
            thresholds.pool_load_factor,
        )
        _check(
            checks, warnings, "register_saturation_frac",
            jnp.mean((state.pool == cfg.r_max).astype(jnp.float32)),
            thresholds.register_saturation_frac,
        )
        # Noise floor at the VIRTUAL row geometry: α = m_v/M with m_v from
        # vcfg when given (``virtual_dyn_array.noise_floor``), else the
        # dense cfg.m — callers with a widened tail row pass vcfg.
        m_v = cfg.m if vcfg is None else (vcfg.m_virtual or cfg.m)
        alpha = m_v / pool_size
        _check(
            checks, warnings, "pool_noise_floor",
            jnp.float32(alpha / (1.0 - alpha)) * state.w_tail,
            thresholds.pool_noise_floor,
        )
        _info(checks, "pool_weight_total", state.w_tail)
        _info(checks, "pool_tail_elements", state.n_tail)
        # The hot tier is a dense DynArray — reuse its full report with
        # every check folded in under a hot_ prefix. Directory telemetry is
        # routing-level, not tier-level, so it stays unprefixed here.
        hot = health_report(
            cfg, state.hot, thresholds=thresholds, solver=solver,
        )
        for cname, c in hot["checks"].items():
            checks[f"hot_{cname}"] = c
            if c["warn"]:
                warnings.append(f"hot_{cname}")
        if directory is not None:
            directory_health(dcfg, directory, checks, warnings, thresholds)
        return {
            "container": name,
            "checks": checks,
            "warnings": warnings,
            "ok": not warnings,
        }

    # ---- register-plane checks (every container has regs) ----------------
    if isinstance(state, _WINDOW_LIKE):
        regs = state.union_regs  # the headline plane: the full-ring union
        stale = jnp.mean(
            (jnp.max(state.regs, axis=0) != state.union_regs).astype(jnp.float32)
        )
        _check(checks, warnings, "union_staleness_frac", stale,
               thresholds.union_staleness_frac)
        _info(checks, "ring_fill_frac",
              state.filled.astype(jnp.float32) / state.regs.shape[0])
        _info(checks, "epoch_id", state.epoch_id)
    else:
        regs = state.regs
    rows = regs if regs.ndim == 2 else regs[None, :]
    _check(
        checks, warnings, "register_saturation_frac",
        jnp.mean((rows == cfg.r_max).astype(jnp.float32)),
        thresholds.register_saturation_frac,
    )
    _check(
        checks, warnings, "occupancy_frac",
        jnp.mean((rows > cfg.r_min).astype(jnp.float32)),
        thresholds.occupancy_frac,
    )

    # ---- estimation checks ----------------------------------------------
    with trace.span("health/solve", container=name):
        if isinstance(state, _DYN_LIKE) or isinstance(state, _WINDOW_LIKE):
            if isinstance(state, _WINDOW_LIKE):
                hists, chats = state.union_hists, state.union_chats
            elif isinstance(state, DynState):
                hists, chats = state.hist[None, :], state.chat[None]
            else:
                hists, chats = state.hists, state.chats
            full = _full_hists(cfg, hists)
            est, stddev, _ = estimation.estimate_hists_with_ci(
                cfg, full, kind="routed", solver=solver
            )
            # The routed MLE is misspecified while a row has untouched
            # registers (module docstring): drift and CI are only read over
            # well-specified rows; their fraction is reported alongside.
            well = full[:, 0] == 0
            drift_rows = jnp.where(
                well, jnp.abs(chats - est) / jnp.maximum(jnp.abs(est), 1.0), 0.0
            )
            _check(checks, warnings, "anytime_mle_drift",
                   jnp.max(drift_rows), thresholds.anytime_mle_drift)
            _info(checks, "mle_wellspec_rows_frac",
                  jnp.mean(well.astype(jnp.float32)))
            measurable = well
        else:
            kind = "full" if isinstance(state, _FULL_KIND) else "routed"
            est, stddev, _ = estimation.estimate_rows_with_ci(
                cfg, rows, kind=kind, solver=solver
            )
            measurable = jnp.ones(est.shape, dtype=bool)
        active = measurable & (est > 0)
        rel = jnp.where(active, stddev / jnp.maximum(est, 1.0), 0.0)
        n_active = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
        _check(
            checks, warnings, "ci_rel_width",
            jnp.sum(rel) / n_active, thresholds.ci_rel_width,
        )
        _info(checks, "active_rows_frac",
              jnp.mean((est > 0).astype(jnp.float32)))

    # ---- directory checks ------------------------------------------------
    if directory is not None:
        directory_health(dcfg, directory, checks, warnings, thresholds)

    return {
        "container": name,
        "checks": checks,
        "warnings": warnings,
        "ok": not warnings,
    }
