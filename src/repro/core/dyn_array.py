"""DynArray: K independent QSketch-Dyn sketches with O(1)-anytime reads.

``core/sketch_array.py`` gives K QSketches one fused keyed update, but every
``estimate_all`` query still pays the O(K·2^b) vmapped Newton — 55 s at
K = 2^20 on the host mesh (ROADMAP). ``qsketch_dyn`` already carries the
paper's §4.3 martingale, which makes the estimate a running scalar that is
simply *read*. This module lifts that to the keyed array: per-tenant
weighted cardinality becomes an O(K) device read (``estimate_all`` returns
``state.chats``), paid for by a slightly heavier update that maintains
per-key histograms and martingales.

State (``DynArrayState``): ``int8[K, m]`` registers + ``int32[K, 2^b]``
touched-register histograms + ``f32[K]`` running estimates. Row k is
bit-identical to a standalone ``DynState`` fed the key-k sub-stream — the
register choice g(x) and quantized value y(x, w) never see the key, dedup is
per (key, id), and each element's update probability q_R comes from ITS
key's batch-start histogram (Eq. 12 semantics per row). The K-loop oracle
``update_reference`` verifies this (registers/histograms bitwise; chats
accumulate the same per-key terms in a different — but fixed — float32
association order, equal to the loop within rounding).

Update cost is O(B log B) (dedup sort) + O(B·2^b) (q_R) + O(B) scatters —
independent of K. The histogram is maintained *incrementally*: each register
changed by the batch moves one unit of mass old-bin -> new-bin, counted once
via a per-(key, register) dedup — exactly equivalent to the single sketch's
rebuild-from-registers because untouched registers hold r_min and bin 0 is
pinned to zero (asserted against ``rebuild_hists`` in tests).

Keyed martingale semantics (DESIGN.md §8.4): per-key chats ARE additive
across disjoint batches of one stream (the martingale telescopes), but NOT
across shards/pods that may have seen the same element — cross-shard
``merge`` therefore max-merges registers and re-estimates every chat with
the per-key histogram MLE, mirroring ``qsketch_dyn.merge``.
"""

from __future__ import annotations

import functools
import typing

import jax
import jax.numpy as jnp

from . import estimation, estimators, hashing, key_directory, qsketch_dyn
from .types import DynArrayState, DynState, SketchConfig


def init(cfg: SketchConfig, k: int) -> DynArrayState:
    """K fresh Dyn sketches; K is carried by the state shape, cfg stays shared."""
    if k < 1:
        raise ValueError("DynArray needs k >= 1 sketches")
    return DynArrayState(
        regs=jnp.full((k, cfg.m), cfg.r_min, dtype=jnp.int8),
        hists=jnp.zeros((k, cfg.num_bins), dtype=jnp.int32),
        chats=jnp.zeros((k,), dtype=jnp.float32),
    )


def num_sketches(state: DynArrayState) -> int:
    """Tenant capacity K (the row count of every state leaf)."""
    return state.regs.shape[0]


def row(state: DynArrayState, k: int) -> DynState:
    """Extract sketch k as a standalone (bit-identical) DynState.

    Host-side API: ``k`` must be a concrete int in [0, K).
    """
    n = state.regs.shape[0]
    if not 0 <= k < n:
        raise IndexError(f"dyn sketch row {k} out of range for K={n}")
    return DynState(regs=state.regs[k], hist=state.hists[k], chat=state.chats[k])


def _keyed_dedup_mask(keys, lo, hi, live):
    """First live occurrence per (key, id): the per-key form of
    ``qsketch_dyn._dedup_mask``. Same id under two keys is two distinct
    elements (one per sketch); live rows sort ahead of dead rows of the same
    (key, id) so padding can never shadow a live element (the fixed
    dedup/mask ordering contract, DESIGN.md §4.2)."""
    dead = (~live).astype(jnp.uint32)
    order = jnp.lexsort((dead, lo, hi, keys))
    sk, slo, shi = keys[order], lo[order], hi[order]
    first = jnp.concatenate(
        [
            jnp.array([True]),
            (sk[1:] != sk[:-1]) | (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1]),
        ]
    )
    mask = jnp.zeros_like(first).at[order].set(first)
    return mask


class UpdatePlan(typing.NamedTuple):
    """B-sized scatter payloads from the read-only half of one batch update.

    Produced by ``_plan_scatters`` (gathers + per-element math), consumed by
    ``_commit_scatters`` (pure scatters). The split exists for the donated
    hot path: when the gathers and the scatters of the same state buffer
    share one executable, XLA's copy-insertion refuses to alias the donated
    input and materialises full copies of the int32[K, 2^b] histograms
    (~1 GiB per batch at K = 2^20) — compiling the halves as SEPARATE
    executables keeps the commit scatter-only, which XLA updates in place.
    """

    keys: jax.Array  # int32[B] clipped row routes
    j: jax.Array  # int32[B] register choice g(x)
    y_eff: jax.Array  # int8[B] scatter-max payload (r_min where unchanged)
    chat_add: jax.Array  # f32[B] martingale increments w/q (0 where unchanged)
    old_bin: jax.Array  # int32[B] batch-start histogram bin of regs[key, j]
    final_bin: jax.Array  # int32[B] post-batch histogram bin of regs[key, j]
    hist_dec: jax.Array  # int32[B] -1 where this element retires old_bin mass
    hist_inc: jax.Array  # int32[B] +1 where this element deposits final_bin


def _plan_scatters(
    cfg: SketchConfig, state: DynArrayState, keys, lo, hi, w, live, q
) -> UpdatePlan:
    """Read-only half of the update: dedup, batch-start change indicators,
    incremental-histogram bookkeeping — every output is B-sized and state
    is only gathered, never written. ``q`` is the per-element update
    probability from the element's key's batch-start histogram."""
    j, y = qsketch_dyn._choose_and_quantize(cfg, lo, hi, w)

    alive = _keyed_dedup_mask(keys, lo, hi, live) & live
    old = state.regs[keys, j].astype(jnp.int32)
    changed = alive & (y > old)

    chat_add = jnp.where(changed, w / q, 0.0)

    # y_eff is r_min (unchanged) or in (old, r_max] (changed), so the
    # scatter-max runs on int8 directly — no int32 round-trip of the whole
    # [K, m] matrix on the hot path.
    y_eff = jnp.where(changed, y, jnp.int32(cfg.r_min))

    # Incremental histogram: every register the batch changed moves one unit
    # of mass old-bin -> final-bin, counted ONCE per (key, register).
    # ``final`` — the register's post-batch value — is the segment max of
    # y_eff over the element's (key, register) group, floored by ``old``:
    # integer max, so EXACTLY the value the commit's scatter-max leaves
    # there, computed without re-gathering the scattered matrix (which
    # would drag the [K, m] buffer back into a gather-after-write live
    # range). Equivalent to a full rebuild (bin 0 pinned to zero) at O(B)
    # instead of O(K·m).
    reg_order = jnp.lexsort((j, keys))
    rk, rj = keys[reg_order], j[reg_order]
    starts = jnp.concatenate(
        [jnp.array([True]), (rk[1:] != rk[:-1]) | (rj[1:] != rj[:-1])]
    )
    seg = jnp.cumsum(starts) - 1
    smax = jax.ops.segment_max(
        y_eff[reg_order], seg, num_segments=y_eff.shape[0], indices_are_sorted=True
    )
    final_sorted = jnp.maximum(old[reg_order], smax[seg])
    final = jnp.zeros_like(final_sorted).at[reg_order].set(final_sorted)
    reg_first = jnp.zeros_like(starts).at[reg_order].set(starts)
    reg_changed = reg_first & (final > old)
    dec = reg_changed & (old > cfg.r_min)  # old at r_min was never tracked
    return UpdatePlan(
        keys=keys,
        j=j,
        y_eff=y_eff.astype(jnp.int8),
        chat_add=chat_add,
        old_bin=old - cfg.r_min,
        final_bin=final - cfg.r_min,
        hist_dec=jnp.where(dec, -1, 0),
        hist_inc=jnp.where(reg_changed, 1, 0),
    )


def _commit_scatters(state: DynArrayState, plan: UpdatePlan) -> DynArrayState:
    """Scatter-only half of the update: register scatter-max, histogram
    mass moves, martingale accumulation. Every state leaf is written, never
    gathered — the shape XLA aliases in place under donation."""
    regs = state.regs.at[plan.keys, plan.j].max(plan.y_eff)
    hists = state.hists.at[plan.keys, plan.old_bin].add(plan.hist_dec)
    hists = hists.at[plan.keys, plan.final_bin].add(plan.hist_inc)
    chats = state.chats.at[plan.keys].add(plan.chat_add)
    return DynArrayState(regs=regs, hists=hists, chats=chats)


def _apply_update(cfg: SketchConfig, state: DynArrayState, keys, lo, hi, w, live, q):
    """Shared tail of the jnp and Pallas-backed update paths: the plan and
    commit halves fused back into one trace. The sharded/window/kernel
    routes and the non-donated ``update_batch`` all come through here, so
    every route runs the identical math as the split donated path."""
    return _commit_scatters(
        state, _plan_scatters(cfg, state, keys, lo, hi, w, live, q)
    )


def _plan_batch(
    cfg: SketchConfig, state: DynArrayState, keys, ids, weights, mask=None
) -> UpdatePlan:
    k = state.regs.shape[0]
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    live = qsketch_dyn._live_weight_mask(w, mask)
    # Per-element q_R against the element's key's batch-start histogram —
    # the same expression as the single sketch, broadcast over gathered rows.
    q = qsketch_dyn._q_update_prob(cfg, state.hists[keys], w)
    return _plan_scatters(cfg, state, keys, lo, hi, w, live, q)


def _update_batch_impl(
    cfg: SketchConfig, state: DynArrayState, keys, ids, weights, mask=None
) -> DynArrayState:
    return _commit_scatters(state, _plan_batch(cfg, state, keys, ids, weights, mask))


_update_batch_jit = jax.jit(_update_batch_impl, static_argnums=(0,))
_plan_batch_jit = jax.jit(_plan_batch, static_argnums=(0,))
_commit_donated = jax.jit(_commit_scatters, donate_argnums=(0,))


def update_batch(
    cfg: SketchConfig, state: DynArrayState, keys, ids, weights, mask=None,
    *, donate: bool = False,
) -> DynArrayState:
    """One fused keyed batch, batch-stale per row (qsketch_dyn.update_batch
    semantics lifted to K rows).

    keys: int[B] in [0, K) routing each element to its sketch row;
      out-of-range keys are clipped (callers pad with key 0 + mask=False).
    mask: optional bool[B]; masked rows and degenerate (non-positive /
      non-finite) weights are dropped before dedup — they neither shadow a
      live duplicate nor enter the martingale.
    donate: run the update as TWO executables — a read-only plan (gathers +
      per-element math) and a scatter-only commit that donates ``state``
      (``donate_argnums``) — so the scatters reuse the state buffers
      instead of allocating a fresh int8[K, m] + int32[K, 2^b] + f32[K]
      copy per batch: the steady-state ingest mode (sketchstream/ingest.py).
      The split matters because a single executable that both gathers and
      scatters a donated buffer makes XLA's copy-insertion bail out of
      aliasing and COPY the histograms anyway (measured ~10x slower at
      K = 2^20). The caller's ``state`` is DEAD afterwards (same values
      live on in the returned state); keep ``donate=False`` anywhere the
      old state is still read (oracles, merges, A/B tests). Both modes are
      bit-identical: the plan/commit math is one trace, split or fused.
    """
    if donate:
        return _commit_donated(state, _plan_batch_jit(cfg, state, keys, ids, weights, mask))
    return _update_batch_jit(cfg, state, keys, ids, weights, mask)


def rebuild_hists(cfg: SketchConfig, regs) -> jnp.ndarray:
    """Per-key touched-register histograms from scratch (bin 0 pinned to 0).

    O(K·m) — the reference the incremental maintenance is tested against,
    and the rebuild used by ``merge``.
    """
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    return hists.at[:, 0].set(0)


def estimate_all(state: DynArrayState) -> jnp.ndarray:
    """Ĉ for every sketch: a pure O(K) read of the running martingales.

    This is the whole point of the Dyn array — no Newton, no histogram walk;
    at K = 2^20 this is a device read where ``sketch_array.estimate_all``
    pays an O(K·2^b) vmapped solve (benchmarks/dyn_array.py).
    """
    return state.chats


def estimate_mle_rows(cfg: SketchConfig, regs, *, solver: str = "newton") -> jnp.ndarray:
    """Per-row histogram-MLE Ĉ from an ``int8[K, m]`` register matrix.

    The regs-only core of ``estimate_mle_all``, shared with the windowed
    union reads (core/window_array.py): each row's MLE recovers C_k/m and is
    scaled by m; untouched rows report 0. Thin shim over
    ``estimation.estimate_rows(kind="routed")`` — the solve (and the
    untouched-row guard) lives in the estimation layer; ``solver`` picks
    newton / lut / fused (DESIGN.md §8.7).
    """
    return estimation.estimate_rows(cfg, regs, kind="routed", solver=solver)


def estimate_mle_hists(cfg: SketchConfig, full_hists, *, solver: str = "newton") -> jnp.ndarray:
    """Per-row histogram-MLE Ĉ from FULL histograms ``int32[K, 2^b]`` (bin 0
    counts untouched r_min registers, rows sum to m).

    Bit-identical to ``estimate_mle_rows`` on the registers the histograms
    were counted from — the likelihood sees registers only through their
    value histogram (DESIGN.md §8.3) — which is what lets the window array's
    cached union histograms skip the register walk entirely. Thin shim over
    ``estimation.estimate_hists(kind="routed")``.
    """
    return estimation.estimate_hists(cfg, full_hists, kind="routed", solver=solver)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_mle_all(
    cfg: SketchConfig, state: DynArrayState, *, solver: str = "newton"
) -> jnp.ndarray:
    """Per-key histogram-MLE re-estimate, Ĉ[K].

    The vmapped form of ``qsketch_dyn.estimate_mle`` (each row's MLE recovers
    C_k/m and is scaled by m); untouched rows report 0. Use after cross-shard
    merges or as a self-check — the hot path reads ``estimate_all``.

    ``solver="lut"`` reads the maintained ``state.hists`` (bin 0 re-derived
    from the row sums, an invariant tested against ``rebuild_hists``) instead
    of bincounting the registers — the whole O(K·m) register walk disappears
    along with the Newton loop. ``"fused"`` streams the registers through the
    Pallas estimate kernel (TPU).
    """
    if solver == "lut":
        full = state.hists.at[:, 0].set(cfg.m - jnp.sum(state.hists, axis=1))
        return estimation.estimate_hists(cfg, full, kind="routed", solver="lut")
    return estimation.estimate_rows(cfg, state.regs, kind="routed", solver=solver)


def merge(cfg: SketchConfig, a: DynArrayState, b: DynArrayState) -> DynArrayState:
    """Merge two fleets sketching (possibly overlapping) sub-streams.

    Registers: row-wise max (exact union). Histograms: rebuilt. Chats:
    re-estimated per key via the histogram MLE — running martingales are NOT
    additive across shards that may share elements (DESIGN.md §8.4), exactly
    as in ``qsketch_dyn.merge``. Shapes must agree: a (K, m) mismatch means
    different tenant spaces / register geometries.
    """
    if a.regs.shape != b.regs.shape:
        raise ValueError(
            f"DynArray merge needs matching (K, m), got {a.regs.shape} vs {b.regs.shape}"
        )
    regs = jnp.maximum(a.regs, b.regs)
    merged = DynArrayState(
        regs=regs, hists=rebuild_hists(cfg, regs), chats=a.chats
    )
    return merged._replace(chats=estimate_mle_all(cfg, merged))


def check_disjoint_rows(a, b) -> None:
    """Eagerly reject overlapping key partitions before a disjoint merge.

    A row touched in BOTH states (nonzero histogram mass on each side) means
    the two fleets both saw that key's traffic — the key-partition contract
    ``merge_disjoint`` relies on is broken and adding chats would
    double-count any shared element. The check is host-side: under jit
    tracing it CANNOT run, and rather than silently dropping a guard the
    caller asked for, it raises — run the merge eagerly, or pass
    ``check_partition=False`` when the pipeline owns the invariant by
    construction. Shared by the single-host and sharded
    (``sharded_dyn_array``) disjoint merges.
    """
    both = (jnp.sum(a.hists, axis=1) > 0) & (jnp.sum(b.hists, axis=1) > 0)
    if isinstance(both, jax.core.Tracer):
        raise ValueError(
            "merge_disjoint: cannot verify key-partition disjointness under "
            "jit tracing — run the merge eagerly, or pass "
            "check_partition=False if the caller owns the invariant"
        )
    n = int(jnp.sum(both))
    if n:
        raise ValueError(
            f"merge_disjoint: {n} key rows are live in BOTH states — the "
            "streams are not key-partitioned; use merge() for overlapping "
            "streams (chats re-estimate via the MLE instead of adding)"
        )


def merge_disjoint(
    cfg: SketchConfig, a: DynArrayState, b: DynArrayState,
    check_partition: bool = False,
) -> DynArrayState:
    """Merge fleets whose streams are known element-disjoint: chats ADD.

    The production sharding is BY KEY — a tenant's stream lands on exactly
    one shard — so two shards never see the same element and the per-key
    martingales telescope across them: Ĉ_merged = Ĉ_a + Ĉ_b, exactly and
    with no MLE (which ``merge`` needs for possibly-overlapping streams and
    which is misspecified for lightly-loaded rows, DESIGN.md §8.4).
    Registers still max-merge (the union sketch) and histograms rebuild, so
    subsequent batches see correct q_R state.

    Element-disjointness is the true precondition (two streams with shared
    key rows but disjoint element ids still add exactly); key-partitioning
    is the production contract that *guarantees* it. ``check_partition=True``
    enforces the stricter contract eagerly via ``check_disjoint_rows`` — a
    row live in both fleets is rejected, and a traced (jit) call raises
    rather than silently skipping the requested guard. The sharded fleet
    merge (``sharded_dyn_array.merge_disjoint``) enforces it by default;
    here the caller owns the disjointness invariant.
    """
    if a.regs.shape != b.regs.shape:
        raise ValueError(
            f"DynArray merge needs matching (K, m), got {a.regs.shape} vs {b.regs.shape}"
        )
    if check_partition:
        check_disjoint_rows(a, b)
    regs = jnp.maximum(a.regs, b.regs)
    return DynArrayState(
        regs=regs, hists=rebuild_hists(cfg, regs), chats=a.chats + b.chats
    )


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    state: DynArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
):
    """Sparse-tenant entry: route 64-bit tenant ids through the key directory,
    then run the fused keyed update. Returns (state, directory telemetry) —
    the same production contract as ``sketch_array.update_tenants``.
    """
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != DynArray rows {state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    return update_batch(cfg, state, slots, ids, weights, mask=mask), dir_state


def update_reference(
    cfg: SketchConfig, state: DynArrayState, keys, ids, weights, mask=None
) -> DynArrayState:
    """Oracle: partition the stream by key (order preserved), run K
    independent ``qsketch_dyn.update_batch`` calls. O(K) dispatches —
    tests/benchmarks only, never the hot path. ``mask`` rows are dropped from
    their key's sub-stream entirely, so padded batches are verified too.
    ``ids`` follows the usual contract: a uint32 array or a (lo, hi) pair.
    """
    import numpy as np

    keys_np = np.asarray(jnp.clip(keys.astype(jnp.int32), 0, state.regs.shape[0] - 1))
    live = np.ones(keys_np.shape, bool) if mask is None else np.asarray(mask)
    lo, hi = hashing.split_id64(ids)
    lo_np, hi_np, w_np = np.asarray(lo), np.asarray(hi), np.asarray(weights)
    rows = []
    for k in range(state.regs.shape[0]):
        st_k = DynState(regs=state.regs[k], hist=state.hists[k], chat=state.chats[k])
        sel = (keys_np == k) & live
        if sel.any():
            st_k = qsketch_dyn.update_batch(
                cfg, st_k,
                (jnp.asarray(lo_np[sel]), jnp.asarray(hi_np[sel])),
                jnp.asarray(w_np[sel]),
            )
        rows.append(st_k)
    return DynArrayState(
        regs=jnp.stack([r.regs for r in rows]),
        hists=jnp.stack([r.hist for r in rows]),
        chats=jnp.stack([r.chat for r in rows]),
    )
