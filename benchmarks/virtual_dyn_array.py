"""VirtualDynArray: the register-sharing headline — tail memory independent
of K, at a quantified accuracy cost vs dedicated dense rows.

Two questions this suite answers (ROADMAP: register sharing for the tail;
DESIGN.md §8.9):

  * memory — the virtual tier's state is pool + hot table, INDEPENDENT of
    the tail tenant count. Against dense DynArray rows
    (``vda.dense_memory_bytes``) the ratio is analytic and exact; the
    acceptance bar is >= 10x at K = 10^7 tail tenants (measured: ~10^4x —
    the pool is ~140 KB where dense Dyn state is ~11.6 GB).
  * accuracy — what does sharing cost on a Zipf tail? One stream (sizes
    ~ 8000/rank^1.05, weights U(0.5, 1.5), top tenants pinned) feeds a
    VirtualDynArray and a dedicated dense DynArray; per-tenant estimates are
    compared to exact truth, bucketed by the noise floor
    (``vda.noise_floor`` — the resolution limit register sharing buys the
    memory with). The bar: above 2x the floor, the virtual tail's mean
    relative error stays within 2x of the dense REGISTER-ONLY read (the
    honest baseline — a dedicated noise-free row at the same m, solved
    through the same compound-Poisson estimator; the dense martingale is
    also reported, but it holds per-element state the virtual tier
    deliberately does not).

The sweep is cumulative into experiments/bench/virtual_dyn_array.json
(common.merge_save), so smoke runs never erase the ``--full`` cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SketchConfig,
    dyn_array,
    estimation,
    virtual_dyn_array as vda,
)
from repro.core.virtual_dyn_array import VirtualConfig

from . import common

_BATCH = 4096


def _zipf_stream(n_tenants, base, seed):
    """Per-tenant element counts ~ base/rank^1.05 (rank = tenant index),
    globally unique uint32 element ids, weights U(0.5, 1.5), shuffled into
    one flat stream. Returns (tenant 64-bit ids, per-element tenant index,
    ids, weights, per-tenant true weight)."""
    rng = np.random.default_rng(seed)
    tids = rng.integers(0, 1 << 63, n_tenants, dtype=np.uint64)
    sizes = np.maximum(base / (np.arange(n_tenants) + 1.0) ** 1.05, 4.0).astype(np.int64)
    tidx = np.repeat(np.arange(n_tenants, dtype=np.int32), sizes)
    n = tidx.shape[0]
    ids = rng.permutation(np.arange(n, dtype=np.uint32))
    w = rng.uniform(0.5, 1.5, n).astype(np.float32)
    truth = np.zeros(n_tenants, np.float64)
    np.add.at(truth, tidx, w)
    order = rng.permutation(n)
    return tids, tidx[order], ids[order], w[order], truth


def _batches(tids, tidx, ids, w):
    """Fixed-shape (tenant (lo,hi), keys, ids, weights, mask) batches so each
    container compiles once; the last batch pads with the mask."""
    n = tidx.shape[0]
    out = []
    for lo in range(0, n, _BATCH):
        sl = slice(lo, min(lo + _BATCH, n))
        pad = _BATCH - (sl.stop - sl.start)
        ti = np.pad(tidx[sl], (0, pad))
        tk = tids[ti]
        out.append((
            (jnp.asarray((tk & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
             jnp.asarray((tk >> np.uint64(32)).astype(np.uint32))),
            jnp.asarray(ti),
            jnp.asarray(np.pad(ids[sl], (0, pad))),
            jnp.asarray(np.pad(w[sl], (0, pad))),
            jnp.asarray(np.pad(np.ones(sl.stop - sl.start, bool), (0, pad))),
        ))
    return out


def _bucket_err(truth, est, floor, lo, hi):
    """Mean relative error over tenants whose truth lies in [lo, hi)×floor;
    (nan, 0) when the bucket is empty."""
    sel = (truth >= lo * floor) & (truth < hi * floor)
    if not sel.any():
        return float("nan"), 0
    rel = np.abs(est[sel] - truth[sel]) / truth[sel]
    return float(rel.mean()), int(sel.sum())


def run(quick=True):
    rows = []
    cfg = SketchConfig(m=128, b=8, seed=3)

    if quick:
        n_tenants, base, pool_size, n_pin = 256, 2000.0, 2**14, 32
    else:
        n_tenants, base, pool_size, n_pin = 1024, 8000.0, 2**16, 64

    tids, tidx, ids, w, truth = _zipf_stream(n_tenants, base, seed=3)
    # Ranks are element counts in this stream: pin the top-n_pin elephants.
    vcfg = VirtualConfig(pool_size=pool_size, pinned=tuple(int(t) for t in tids[:n_pin]))

    # --- memory: analytic, exact, K-independent ----------------------------
    v_bytes = vda.memory_bytes(cfg, vcfg)
    for k in (10**5, 10**6, 10**7):
        d_bytes = vda.dense_memory_bytes(cfg, k)
        ratio = d_bytes / v_bytes
        rows += [
            {"figure": "virtual_dyn_memory", "method": "dense_bytes", "k": k, "m": cfg.m, "bytes": d_bytes},
            {"figure": "virtual_dyn_memory", "method": "virtual_bytes", "k": k, "m": cfg.m, "bytes": v_bytes},
            {"figure": "virtual_dyn_memory", "method": "ratio", "k": k, "m": cfg.m, "x": ratio},
        ]
        common.csv_row(
            f"virtual_dyn/memory/K{k}", 0.0,
            f"dense={d_bytes/2**20:.0f}MiB virtual={v_bytes/2**10:.0f}KiB "
            f"ratio={ratio:.0f}x (>=10x required at K=1e7)",
        )
    if vda.dense_memory_bytes(cfg, 10**7) / v_bytes < 10:
        raise AssertionError("virtual tier lost the >=10x memory bar at K=1e7")

    # --- accuracy: one Zipf stream through both tiers ----------------------
    st_v = vda.init(cfg, vcfg)
    st_d = dyn_array.init(cfg, n_tenants)
    for t, keys, i, ww, mask in _batches(tids, tidx, ids, w):
        st_v = vda.update_tenants(cfg, vcfg, st_v, t, i, ww, mask)
        st_d = dyn_array.update_batch(cfg, st_d, keys, i, ww, mask)
    jax.block_until_ready((st_v.pool, st_d.chats))

    tq = (
        jnp.asarray((tids & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        jnp.asarray((tids >> np.uint64(32)).astype(np.uint32)),
    )
    est_v = np.asarray(vda.estimate_tenants(cfg, vcfg, st_v, tq), np.float64)
    est_read = np.asarray(dyn_array.estimate_all(st_d), np.float64)
    # Register-only baseline: the SAME light-load-safe compound-Poisson
    # solve the virtual tier uses, on dedicated noise-free rows — tail
    # tenants load m registers with a handful of elements, the regime where
    # the plain routed MLE collapses on bin-0 mass (estimation.py). This
    # isolates the cost of SHARING (pool noise + cancellation) from the
    # estimator itself.
    est_mle = np.asarray(
        estimation.estimate_rows_virtual(cfg, st_d.regs), np.float64
    )
    # Pinned tenants are exact by construction: the hot tier IS a dense
    # DynArray fed the same batch partition.
    if not np.array_equal(est_v[:n_pin], est_read[:n_pin]):
        raise AssertionError("hot-tier estimates diverged from the dense martingale")

    floor = float(vda.noise_floor(cfg, vcfg, st_v))
    load = float(vda.pool_load_factor(st_v))
    tail = np.arange(n_tenants) >= n_pin
    for blo, bhi in ((0.0, 1.0), (1.0, 2.0), (2.0, 4.0), (4.0, np.inf)):
        tag = f"{blo:g}-{bhi:g}xfloor"
        for method, est in (
            ("virtual", est_v), ("dense_read", est_read), ("dense_register_mle", est_mle),
        ):
            err, n_b = _bucket_err(truth[tail], est[tail], floor, blo, bhi)
            rows.append({
                "figure": "virtual_dyn_accuracy", "method": f"{method}/{tag}",
                "k": n_tenants, "m": cfg.m, "rel_err": err, "n_tenants": n_b,
            })
        ve, nb = _bucket_err(truth[tail], est_v[tail], floor, blo, bhi)
        de, _ = _bucket_err(truth[tail], est_mle[tail], floor, blo, bhi)
        common.csv_row(
            f"virtual_dyn/accuracy/{tag}", 0.0,
            f"n={nb} virtual={ve:.3f} dense_mle={de:.3f}",
        )

    # Headline: above 2x the noise floor, within 2x of the dense
    # register-only read.
    v_err, n_above = _bucket_err(truth[tail], est_v[tail], floor, 2.0, np.inf)
    d_err, _ = _bucket_err(truth[tail], est_mle[tail], floor, 2.0, np.inf)
    within = v_err <= 2.0 * max(d_err, 1e-3)
    rows.append({
        "figure": "virtual_dyn_accuracy", "method": "headline_above_2xfloor",
        "k": n_tenants, "m": cfg.m, "rel_err": v_err, "dense_rel_err": d_err,
        "within_2x_of_dense": bool(within), "noise_floor": floor,
        "pool_load_factor": load, "n_tenants": n_above,
    })
    common.csv_row(
        f"virtual_dyn/accuracy/K{n_tenants}/headline", 0.0,
        f"above_2xfloor rel_err virtual={v_err:.3f} dense_mle={d_err:.3f} "
        f"within_2x={within} load={load:.2f} floor={floor:.1f}",
    )
    if not within:
        raise AssertionError(
            f"virtual tail error {v_err:.3f} exceeded 2x dense MLE {d_err:.3f}"
        )

    # Ghost read: tenants that never sent traffic must sit at/under the floor
    # (the cancellation clamps residual pool noise at zero from below).
    rng = np.random.default_rng(99)
    ghosts = rng.integers(0, 1 << 63, 64, dtype=np.uint64)
    gq = (
        jnp.asarray((ghosts & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        jnp.asarray((ghosts >> np.uint64(32)).astype(np.uint32)),
    )
    ghost_med = float(np.median(np.asarray(vda.estimate_tenants(cfg, vcfg, st_v, gq))))
    rows.append({
        "figure": "virtual_dyn_accuracy", "method": "ghost_median",
        "k": n_tenants, "m": cfg.m, "estimate": ghost_med, "noise_floor": floor,
    })
    common.csv_row(
        f"virtual_dyn/accuracy/K{n_tenants}/ghost", 0.0,
        f"median={ghost_med:.2f} floor={floor:.1f}",
    )

    common.merge_save("virtual_dyn_array", rows, {10**5, 10**6, 10**7, n_tenants})
    return rows
