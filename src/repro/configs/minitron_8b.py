"""minitron-8b [dense] — width-pruned Nemotron-4.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000 [arXiv:2407.14679; hf].
The 256k vocab makes embedding + logits the sharding stress case (vocab on
"model"; the xent all-reduce shows up in the dry-run HLO). Full attention ->
long_500k skipped.
"""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
        pattern=(LayerSpec(),),
        rope_theta=10_000.0,
        max_seq=4096,
    )
