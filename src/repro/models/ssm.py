"""Mamba2 SSD (state-space duality) mixer layer.

Chunked SSD algorithm (Dao & Gu 2024, §6): split the sequence into chunks of
length L; within a chunk the recurrence is materialized as a (masked)
attention-like quadratic form; across chunks a tiny (H, N, P) state is
carried by a scan. Total work O(S·L·H·P + S·H·N·P) — linear in S, matmul-
heavy inside chunks (MXU-friendly: the TPU adaptation is exactly "pick L so
the intra-chunk einsums are 128-aligned", DESIGN.md §5).

Decode keeps an O(1)-per-token state: h <- h * exp(dt·A) + dt · B ⊗ x. This
is why mamba2 / jamba run the long_500k shape while pure-attention archs
skip it.

The depthwise causal conv (width 4) is implemented with shifted adds; its
decode state is the last (width-1) inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import ParamDef


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.d_state  # x, B, C get the conv (G=1 groups)
    return d_inner, n_heads, conv_ch


def defs(cfg):
    s = cfg.ssm
    e = cfg.d_model
    d_inner, h, conv_ch = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.d_state + h  # z, x, B, C, dt
    return {
        "in_proj": ParamDef((e, d_in_proj), ("embed", "d_inner")),
        "conv_w": ParamDef((s.conv_width, conv_ch), (None, "d_inner"), scale=0.5),
        "a_log": ParamDef((h,), (None,), init="zeros"),
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "norm": ParamDef((d_inner,), (None,), init="zeros"),
        "out_proj": ParamDef((d_inner, e), ("d_inner", "embed")),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_inner, h, _ = dims(cfg)
    z, xs, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + s.d_state, 2 * d_inner + 2 * s.d_state], axis=-1
    )
    return z, xs, b, c, dt


def _causal_conv(x, w):
    """Depthwise causal conv via shifted adds. x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[k - 1 - i]
    return out


def apply(params, x, cfg, *, return_state=False):
    """Full-sequence SSD. x: (B, S, E) -> (B, S, E).

    If return_state, also returns (h_final, conv_tail) for decode handoff.
    """
    s = cfg.ssm
    d_inner, h, conv_ch = dims(cfg)
    p_dim = s.head_dim
    n = s.d_state
    b_, seq, _ = x.shape
    l = min(s.chunk, seq)
    # Pad sequence to a chunk multiple (padded tail has dt=0 -> no state drift).
    pad = (-seq) % l
    nc = (seq + pad) // l

    proj = jnp.einsum("bse,ed->bsd", x, params["in_proj"])
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = common.silu(_causal_conv(conv_in, params["conv_w"]))
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,) negative

    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    xh = xs.reshape(b_, nc, l, h, p_dim).astype(jnp.float32)
    bh = bmat.reshape(b_, nc, l, n).astype(jnp.float32)  # G=1 group shared
    ch = cmat.reshape(b_, nc, l, n).astype(jnp.float32)
    dth = dt.reshape(b_, nc, l, h)

    da = dth * a  # (B,nc,L,H) log-decay per step
    cum = jnp.cumsum(da, axis=2)  # inclusive (f32 for stability)
    idt = jnp.dtype(s.intra_dtype)  # §Perf knob: big L×L tensors in bf16
    # intra-chunk: scores[i,j] = C_i·B_j * exp(cum_i - cum_j) * dt_j,  j <= i
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0).astype(idt)
    cb = jnp.einsum("bcin,bcjn->bcij", ch.astype(idt), bh.astype(idt))  # (B,nc,L,L)
    w_ij = cb[..., None] * decay * dth[:, :, None, :, :].astype(idt)  # (B,nc,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xh.astype(idt)).astype(jnp.float32)

    # chunk states: h_c = sum_j exp(cum_last - cum_j) * dt_j * B_j ⊗ x_j
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    decay_to_end = jnp.exp(last - cum)  # (B,nc,L,H)
    hc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end * dth, bh, xh)

    # inter-chunk carry
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

    def carry_step(hprev, inp):
        hc_i, cd_i = inp
        hnew = hprev * cd_i[..., None, None] + hc_i
        return hnew, hprev

    h0 = jnp.zeros((b_, h, n, p_dim), jnp.float32)
    hfin, hprevs = jax.lax.scan(
        carry_step,
        h0,
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)  # (B,nc,H,N,P) state entering chunk
    in_decay = jnp.exp(cum)  # (B,nc,L,H): decay from chunk start to i
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", ch, hprevs, in_decay)

    y = (y_intra + y_inter).reshape(b_, nc * l, h, p_dim)[:, :seq]
    y = y + xh.reshape(b_, nc * l, h, p_dim)[:, :seq] * params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b_, seq, d_inner).astype(x.dtype)
    y = y * common.silu(z)
    y = common.rms_norm(y, params["norm"])
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    if return_state:
        k = cfg.ssm.conv_width - 1
        conv_tail = conv_in[:, -k:] if seq >= k else jnp.pad(conv_in, ((0, 0), (k - seq, 0), (0, 0)))
        return out, (hfin, conv_tail)
    return out


def decode(params, x, cfg, *, h_state, conv_tail):
    """One-token step. x: (B, 1, E); h_state: (B,H,N,P); conv_tail: (B,K-1,C).

    Returns (out (B,1,E), h_state, conv_tail).
    """
    s = cfg.ssm
    d_inner, h, conv_ch = dims(cfg)
    n, p_dim = s.d_state, s.head_dim

    proj = jnp.einsum("bse,ed->bsd", x, params["in_proj"])
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)  # (B,1,C)
    window = jnp.concatenate([conv_tail, conv_in], axis=1)  # (B,K,C)
    conv_out = common.silu(jnp.einsum("bkc,kc->bc", window, params["conv_w"]))[:, None]
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B,H)

    xh = xs[:, 0].reshape(-1, h, p_dim).astype(jnp.float32)
    bh = bmat[:, 0].astype(jnp.float32)  # (B,N)
    chh = cmat[:, 0].astype(jnp.float32)

    h_state = h_state * da[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bh, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", chh, h_state) + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = y * common.silu(z)
    y = common.rms_norm(y, params["norm"])
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return out, h_state, window[:, 1:]


def state_defs(cfg, batch: int):
    """Decode-state ParamDefs (h and conv tail) for one SSD layer."""
    s = cfg.ssm
    d_inner, h, conv_ch = dims(cfg)
    return {
        "h": ParamDef((batch, h, s.d_state, s.head_dim), ("batch", "d_inner", None, None), dtype=jnp.float32, init="zeros"),
        "conv": ParamDef((batch, s.conv_width - 1, conv_ch), ("batch", None, "d_inner"), dtype=jnp.bfloat16, init="zeros"),
    }
