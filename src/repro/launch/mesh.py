"""Production mesh builders (MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the platform/device count at first backend init, and
the dry-run must set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int | None = None):
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_sketch_mesh(n_shards: int | None = None):
    """1-D mesh over the ``"sketch"`` axis: tenant rows of a sharded sketch
    container (ShardedSketchArray, ShardedDynArray, sharded WindowArray).

    Every sharded front in ``core/`` partitions its per-tenant state
    row-wise over this axis via the shared layer (core/sharding.py);
    K ~ 1e7 tenants then cost K·state/n_shards bytes per device instead of
    one host's worth. Defaults to every visible device; an explicit
    ``n_shards`` must not exceed the host's device count (shard_map needs
    one device per shard). Telemetry embedded in a training step can
    instead reuse an existing mesh axis (``axis="data"`` on any sharded
    container) — this builder is for the standalone monitoring fleet /
    examples / benchmarks.
    """
    n_avail = len(jax.devices())
    n = n_shards or n_avail
    if n > n_avail:
        raise ValueError(
            f"sketch mesh wants {n} shards but only {n_avail} devices are "
            "visible (set XLA_FLAGS=--xla_force_host_platform_device_count "
            "for host-device smoke runs)"
        )
    return jax.make_mesh((n,), ("sketch",))
