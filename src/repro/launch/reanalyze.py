"""Offline re-analysis of persisted dry-run HLO: recompute the loop-aware
stats and roofline terms in every cell JSON without recompiling.

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

try:
    import zstandard
except ImportError:  # optional codec; .hlo.gz fallback still readable
    zstandard = None

from repro import configs
from repro.roofline import analysis as ra, hlo_stats


def reanalyze_record(json_path: str) -> bool:
    rec = json.load(open(json_path))
    if rec.get("status") != "ok":
        return False
    zst_path = json_path.replace(".json", ".hlo.zst")
    gz_path = json_path.replace(".json", ".hlo.gz")
    if zstandard is not None and os.path.exists(zst_path):
        text = zstandard.ZstdDecompressor().decompress(open(zst_path, "rb").read(), max_output_size=2**33).decode()
    elif os.path.exists(gz_path):
        text = gzip.open(gz_path, "rb").read().decode()
    else:
        return False
    stats = hlo_stats.analyze(text)
    pd = rec["per_device"]
    pd.update({
        "flops": float(stats["dot_flops"]),
        "bytes_accessed": float(stats["hbm_bytes"]),
        "collective_bytes": float(stats["collective_bytes"]),
        "collective_by_op": stats["collective_by_op"],
        "unknown_trip_whiles": stats["unknown_trip_whiles"],
    })
    terms, bottleneck = ra.roofline_terms(pd["flops"], pd["bytes_accessed"], pd["collective_bytes"], rec["chips"])
    cfg = configs.get_config(rec["arch"])
    mf = ra.model_flops(cfg, rec["tokens_per_step"], rec["kind"])
    rec["roofline"] = terms
    rec["bottleneck"] = bottleneck
    rec["model_flops_global"] = mf
    rec["hlo_flops_global"] = pd["flops"] * rec["chips"]
    rec["useful_flops_ratio"] = mf / rec["hlo_flops_global"] if rec["hlo_flops_global"] else 0.0
    json.dump(rec, open(json_path, "w"), indent=1)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    n = 0
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if reanalyze_record(path):
            n += 1
            print("reanalyzed", os.path.basename(path), flush=True)
    print(f"{n} records updated")


if __name__ == "__main__":
    main()
