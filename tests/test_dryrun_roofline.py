"""Dry-run machinery + roofline analyzer tests (8-device subprocess mesh)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_hlo_stats_loop_aware():
    """dot FLOPs and collective bytes must scale with scan trip count."""
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline import hlo_stats
        mesh = jax.make_mesh((4,2), ('data','model'))
        def make(n):
            def f(x, w):
                def body(c, wi):
                    return jnp.einsum('bm,mn->bn', c, wi).astype(c.dtype), None
                out, _ = jax.lax.scan(body, x, w)
                return out.sum()
            xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
            ws = jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)
            co = jax.jit(f, in_shardings=(NamedSharding(mesh, P('data', None)),
                                          NamedSharding(mesh, P(None, None, 'model')))).lower(xs, ws).compile()
            return hlo_stats.analyze(co.as_text())
        s7, s14 = make(7), make(14)
        assert abs(s7['dot_flops'] - 2*16*256*128*7) < 1e-6, s7['dot_flops']
        assert abs(s14['dot_flops'] - 2*s7['dot_flops']) < 1e-6
        ag7 = s7['collective_by_op'].get('all-gather', 0)
        ag14 = s14['collective_by_op'].get('all-gather', 0)
        assert abs(ag14 - 2*ag7) < 1e-6 and ag7 > 0
        print('HLO-STATS-OK')
    """)
    r = subprocess.run([sys.executable, "-c", script], env=ENV, capture_output=True, text=True, timeout=600)
    assert "HLO-STATS-OK" in r.stdout, r.stderr[-2000:]


def test_roofline_terms_and_bottleneck():
    from repro.roofline import analysis as ra

    terms, b = ra.roofline_terms(197e12, 819e9, 0.0, 256)
    assert abs(terms["compute_s"] - 1.0) < 1e-9
    assert abs(terms["memory_s"] - 1.0) < 1e-9
    assert b in ("compute", "memory")
    terms, b = ra.roofline_terms(1e12, 1e9, 500e9, 256)
    assert b == "collective"


def test_collective_regex_variants():
    from repro.roofline import analysis as ra

    hlo = """
      %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
      %ag = (bf16[2,128]{1,0}, bf16[2,128]{1,0}) all-gather-start(%y, %z), dimensions={0}
      %d = f32[8] all-reduce-done(%ar2)
      %cp = u8[4096]{0} collective-permute(%w), source_target_pairs={{0,1}}
    """
    got = ra.parse_collective_bytes(hlo)
    assert got["all-reduce"] == 4096
    assert got["all-gather"] == 2 * 2 * 128 * 2
    assert got["collective-permute"] == 4096


def test_dryrun_cell_smoke_mesh():
    """run_cell end-to-end on an 8-device mesh with a reduced config."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json, jax
        from repro import configs as C
        from repro.launch import dryrun_lib as dl
        smoke = {n: C.smoke_config(n) for n in C.list_archs()}
        C.get_config = lambda n: smoke[n]
        C.SHAPES.update({
            'train_4k': dataclasses.replace(C.SHAPES['train_4k'], seq=64, batch=8),
            'decode_32k': dataclasses.replace(C.SHAPES['decode_32k'], seq=64, batch=8),
        })
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        for arch, shape in [('qwen3-8b', 'train_4k'), ('kimi-k2-1t-a32b', 'train_4k'),
                            ('whisper-large-v3', 'decode_32k')]:
            rec = dl.run_cell(arch, shape, mesh)
            assert rec['status'] == 'ok', (arch, shape, rec.get('error'))
            assert rec['per_device']['flops'] > 0
            assert rec['roofline']['compute_s'] >= 0
            assert rec['bottleneck'] in ('compute', 'memory', 'collective')
            # The sketch monitor's Newton solve is a legitimately dynamic
            # while loop (convergence-bounded, tiny); everything structural
            # (layer scans, microbatches) must carry known trip counts.
            assert rec['per_device']['unknown_trip_whiles'] <= 2
        print('DRYRUN-CELL-OK')
    """)
    r = subprocess.run([sys.executable, "-c", script], env=ENV, capture_output=True, text=True, timeout=1200)
    assert "DRYRUN-CELL-OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


def test_production_records_exist_and_pass():
    """The committed dry-run artifacts: every non-skip cell is status ok,
    single-pod AND multi-pod, and the cell grid is complete (40 cells)."""
    import glob

    for tag, chips in [("_singlepod", 256), ("_multipod", 512)]:
        paths = glob.glob(os.path.join(REPO, "experiments/dryrun", f"*{tag}.json"))
        if not paths:
            pytest.skip("dry-run artifacts not generated yet")
        recs = [json.load(open(p)) for p in paths]
        assert len(recs) == 40, (tag, len(recs))
        ok = [r for r in recs if r["status"] == "ok"]
        skip = [r for r in recs if r["status"] == "skip"]
        assert len(ok) == 34 and len(skip) == 6, (tag, len(ok), len(skip))
        for r in ok:
            assert r["chips"] == chips
            assert r["per_device"]["flops"] > 0, (r["arch"], r["shape"])
