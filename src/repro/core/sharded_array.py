"""ShardedSketchArray: the [K, m] register matrix sharded over a mesh axis.

``core/sketch_array.py`` stops at a single host: one int8[K, m] matrix, one
device. The paper's headline settings (per-flow anomaly detection, per-user
DAU) want K ~ 1e7 tenants, which is where this module picks up — the row
axis is sharded over a ``"sketch"`` mesh axis with ``shard_map``, and every
operation stays shard-local:

* **update** — the batch (slots, ids, weights) is visible to all shards;
  each shard hash-routes by ``slot // rows_per_shard`` and folds ONLY its
  own rows with the same fused segment scatter-max as the single-host path.
  Row k receives exactly the contributions it would receive unsharded (the
  y-table is key-independent), so the result is BIT-identical to
  ``sketch_array.update`` — the max-monoid argument, verified bitwise in
  tests/test_sharded_array.py.
* **merge** — element-wise max, the cross-pod collective. Exact at any
  scale because every register is a plain max-monoid element; two pods that
  saw overlapping streams merge without double counting.
* **estimate_all** — the vmapped histogram-MLE runs *inside* shard_map on
  each shard's K/S rows: no register gather, no cross-shard traffic, and the
  O(K·2^b) Newton cost is divided by the shard count.

The mesh machinery itself (row specs, shard_map wrapping, hash-routed
dispatch) lives in ``core/sharding.py`` and is shared with the Dyn and
Window sharded fronts (``sharded_dyn_array``, ``sharded_window_array``);
this module is the thinnest instantiation — a single sharded leaf.

Slots come from ``core/key_directory.py`` (sparse 64-bit tenant ids,
collision telemetry, pinned hot keys); ``update_tenants`` fuses routing and
update. Dense in-range slots remain valid inputs, so the single-host tests'
contract embeds unchanged.

The shard axis name is a parameter (default ``"sketch"``): telemetry inside
a training step can reuse an existing mesh axis (e.g. ``"data"``) instead of
building a second mesh over the same devices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import key_directory, sharding, sketch_array
from .types import SketchArrayState, ShardedArrayState, SketchConfig

AXIS = sharding.AXIS

# Shared-layer geometry helpers, re-exported for existing callers/tests.
num_shards = sharding.num_shards
padded_k = sharding.padded_k


def init(cfg: SketchConfig, k: int, mesh, axis: str = AXIS) -> ShardedArrayState:
    """K fresh sketches, rows sharded over ``axis`` of ``mesh``."""
    sharding.check_divisible(k, mesh, axis)
    regs = jnp.full((k, cfg.m), cfg.r_min, dtype=jnp.int8)
    return ShardedArrayState(
        regs=sharding.device_put_rows(regs, mesh, 0, axis)
    )


def from_array(state: SketchArrayState, mesh, axis: str = AXIS) -> ShardedArrayState:
    """Reshard a single-host SketchArray (pure data movement, same values)."""
    return ShardedArrayState(
        regs=sharding.device_put_rows(state.regs, mesh, 0, axis)
    )


def to_array(state: ShardedArrayState) -> SketchArrayState:
    """Gather back to the single-host form (tests / row extraction)."""
    return SketchArrayState(regs=jax.device_get(state.regs))


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _update(cfg: SketchConfig, mesh, axis: str, regs, slots, ids, weights, mask):
    rows = regs.shape[0] // sharding.num_shards(mesh, axis)

    def local(regs_l, slots, ids, w, m):
        # Hash-routed dispatch: this shard owns slot range [lo, lo + rows).
        local_slots, own = sharding.own_slots(slots, rows, axis, m)
        st = sketch_array.update(
            cfg, SketchArrayState(regs=regs_l), local_slots, ids, w, mask=own
        )
        return st.regs

    return sharding.shard_map_rows(
        local,
        mesh,
        in_dims=(0, None, None, None, None),
        out_dims=0,
        axis=axis,
    )(regs, slots, ids, weights, mask)


def update(
    cfg: SketchConfig, mesh, state: ShardedArrayState, slots, ids, weights,
    mask=None, axis: str = AXIS,
) -> ShardedArrayState:
    """One keyed batch into the sharded matrix; bit-identical to unsharded.

    ``slots`` are dense row indices in [0, K) — the output of
    ``key_directory.route`` (or legacy dense keys). Each element updates
    exactly the shard owning its slot; no collective is needed, the register
    state never leaves its shard.
    """
    sharding.check_divisible(state.regs.shape[0], mesh, axis)
    slots = slots.astype(jnp.int32)
    mask = jnp.ones(slots.shape, bool) if mask is None else mask
    regs = _update(cfg, mesh, axis, state.regs, slots, ids, weights, mask)
    return ShardedArrayState(regs=regs)


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    mesh,
    state: ShardedArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
    axis: str = AXIS,
):
    """Sparse 64-bit tenant ids in, (sharded state, directory telemetry) out.

    ``tenant_keys`` is a uint32 array or a (lo, hi) uint32 pair (64-bit ids
    pre-split host-side via ``key_directory.split_uint64``).
    """
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != sharded rows {state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    return update(cfg, mesh, state, slots, ids, weights, mask=mask, axis=axis), dir_state


@functools.partial(jax.jit, static_argnums=(0, 1, 2), static_argnames=("solver",))
def _estimate_with_ci(cfg: SketchConfig, mesh, axis: str, regs, *, solver: str = "newton"):
    def local(regs_l):
        return sketch_array.estimate_all_with_ci(
            cfg, SketchArrayState(regs=regs_l), solver=solver
        )

    # check_rep=False on the newton path only: its lax.while_loop has no
    # replication rule on current JAX (everything here is shard-local so the
    # check is vacuous). The lut solver is while_loop-free, so it keeps the
    # replication check on.
    return sharding.shard_map_rows(
        local,
        mesh,
        in_dims=(0,),
        out_dims=(0, 0, 0),
        axis=axis,
        check_rep=(solver == "lut"),
    )(regs)


def estimate_all_with_ci(
    cfg: SketchConfig, mesh, state: ShardedArrayState, axis: str = AXIS,
    *, solver: str = "newton",
):
    """(Ĉ[K], stddev[K], converged[K]); the solve stays local to each shard
    (``solver`` picks newton / lut, DESIGN.md §8.7 — with lut each shard
    anchors its own grid, so lut results can differ from the single-host
    call within the documented tolerance; newton stays bit-identical)."""
    sharding.check_divisible(state.regs.shape[0], mesh, axis)
    return _estimate_with_ci(cfg, mesh, axis, state.regs, solver=solver)


def estimate_all(
    cfg: SketchConfig, mesh, state: ShardedArrayState, axis: str = AXIS,
    *, solver: str = "newton",
) -> jnp.ndarray:
    """Ĉ for every slot — the sharded form of ``sketch_array.estimate_all``."""
    return estimate_all_with_ci(cfg, mesh, state, axis=axis, solver=solver)[0]


def merge(a: ShardedArrayState, b: ShardedArrayState) -> ShardedArrayState:
    """All-max cross-shard merge: exact union of two sharded sketch fleets.

    Row-wise max monoid, so pods/hosts that built their states independently
    (even over overlapping streams) combine without bias. Shapes must agree —
    same capacity, same m — or the row algebra is meaningless.
    """
    sharding.check_same_shape(a, b, "ShardedSketchArray")
    return ShardedArrayState(regs=jnp.maximum(a.regs, b.regs))
