"""Serving driver: batched prefill + decode loop with weighted-DAU telemetry.

Each request batch carries (session_id, engagement_weight); the decode loop
updates the QSketch-Dyn DAU monitor every step, so "weighted distinct
sessions served" — the paper's motivating metric — is available at any time
for O(2^b) work without touching request logs.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 12 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--obs-jsonl", default="",
                    help="append one final registry snapshot (JSONL) here")
    ap.add_argument("--obs-prom", default="",
                    help="write a Prometheus textfile snapshot here at exit")
    ap.add_argument("--obs-trace", default="",
                    help="record prefill/decode spans and save a Perfetto-"
                         "loadable Chrome trace JSON here at exit")
    args = ap.parse_args(argv)

    from repro import configs
    from repro.configs import paper_qsketch
    from repro.launch.mesh import make_local_mesh
    from repro.models import common as mcommon, transformer
    from repro.obs import export as obs_export, trace as obs_trace
    from repro.sketchstream import monitor
    from repro.train import serve_step

    if args.obs_trace:
        obs_trace.configure(enabled=True)

    mesh = make_local_mesh()
    cfg = configs.smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    sketch_cfg = paper_qsketch.telemetry_default()

    rng = np.random.default_rng(args.seed)
    params = mcommon.init_params(transformer.model_defs(cfg), jax.random.PRNGKey(args.seed))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))
    extra = None
    if cfg.frontend == "patches":
        extra = jnp.asarray(rng.normal(size=(args.batch, cfg.frontend_len, cfg.d_model)), jnp.float32)
    elif cfg.n_enc_layers:
        extra = jnp.asarray(rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)), jnp.float32)

    session_ids = jnp.asarray(rng.integers(0, 2**32, args.batch, dtype=np.uint32))
    session_w = jnp.asarray(rng.uniform(0.5, 2.0, args.batch), jnp.float32)

    prefill_fn = jax.jit(serve_step.make_prefill(cfg, mesh, max_len=args.max_len))
    decode_fn = jax.jit(
        serve_step.make_decode_step(cfg, mesh, sketch_cfg=sketch_cfg, temperature=args.temperature),
        donate_argnums=(1,),
    )

    sk_state = monitor.init(sketch_cfg)
    t0 = time.time()
    with obs_trace.span("serve/prefill", batch=args.batch):
        if extra is not None:
            last_logits, cache = prefill_fn(params, prompts, extra)
        else:
            last_logits, cache = prefill_fn(params, prompts)
        last_logits = jax.block_until_ready(last_logits)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [tok]
    cur = args.prompt_len + (cfg.frontend_len if cfg.frontend == "patches" else 0)
    with obs_trace.span("serve/decode", steps=args.gen - 1):
        for i in range(args.gen - 1):
            tok, cache, sk_state = decode_fn(
                params, cache, jnp.int32(cur + i), tok, sk_state, session_ids, session_w
            )
            generated.append(tok)
    toks = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    with obs_trace.span("serve/estimate"):
        dau = float(monitor.estimate(sketch_cfg, sk_state))
    true_dau = float(session_w.sum())
    print(f"[serve] {args.batch} sessions x {args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(f"[serve] weighted-DAU sketch estimate: {dau:.2f} (true {true_dau:.2f})")
    print(f"[serve] sample continuation ids: {np.asarray(toks[0])[:12].tolist()}")
    if args.obs_jsonl:
        obs_export.append_snapshot(
            args.obs_jsonl, dau_estimate=dau, tokens=args.batch * args.gen
        )
    if args.obs_prom:
        obs_export.write_prometheus(args.obs_prom)
    if args.obs_trace:
        obs_trace.save(args.obs_trace)
        print(f"[serve] obs trace saved to {args.obs_trace}", flush=True)
    return toks


if __name__ == "__main__":
    main()
