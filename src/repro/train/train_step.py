"""The jit'd training step: loss -> grads -> (compress) -> AdamW -> telemetry.

``make_train_step`` closes over static config and returns the function the
launcher jits (and the dry-run lowers). State threading is explicit — every
piece (params, optimizer moments, compression residuals, sketch telemetry)
is a pytree in/out, so checkpointing and elastic re-sharding see one uniform
state object.

Microbatching: grad accumulation via lax.scan over a reshaped batch
(global_batch = microbatches x micro_size). This is the standard memory/
throughput knob for the train_4k cells of the big MoE archs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import SketchConfig
from repro.models import transformer
from repro.sketchstream import monitor

from . import compression, optimizer


def make_train_step(
    mcfg,
    ocfg: optimizer.OptConfig,
    mesh=None,
    *,
    sketch_cfg: SketchConfig | None = None,
    tenant_monitor: monitor.ShardedArrayMonitor | monitor.DynArrayMonitor | monitor.WindowMonitor | monitor.ShardedDynMonitor | monitor.ShardedWindowMonitor | None = None,
    compress: bool = False,
    microbatches: int = 1,
    remat=True,
    sharded_xent: bool = False,
):
    """Build the step fn. With ``tenant_monitor`` set, ``sk_state`` is a
    ``monitor.TelemetryState`` (scalar sketch + sharded per-tenant array) and
    batches may carry a ``doc_ids`` field — sparse document/source ids (one
    per sequence) routed through the tenant key directory, giving per-
    document distinct-token coverage next to the global sketch. 64-bit ids
    arrive as two uint32 words: ``doc_ids`` (lo) + optional ``doc_ids_hi``
    (JAX x64 is off, a single field would silently truncate the high word).
    Any tenant monitor drops in: ``ShardedArrayMonitor`` (mesh-sharded
    registers, Newton estimation at logging cadence), ``DynArrayMonitor``
    (single-host Dyn martingales, O(K)-anytime per-tenant reads), or
    ``WindowMonitor`` (sliding-window estimates; the outer loop owns the
    epoch clock and calls ``monitor.rotate`` between steps) — the step only
    touches the shared update/metrics surface."""
    def _loss(params, mb):
        return transformer.loss_fn(params, mb, mcfg, mesh, remat=remat, sharded_xent=sharded_xent)

    def train_step(params, opt_state, comp_state, sk_state, batch):
        if microbatches > 1:

            def reshape_mb(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb_batch = jax.tree.map(reshape_mb, batch)

            def body(acc, mb):
                gsum, lsum = acc
                (l, metrics), g = jax.value_and_grad(_loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics = jax.lax.scan(body, (g0, jnp.float32(0.0)), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(params, batch)

        if compress:
            grads, comp_state = compression.compress(grads, comp_state)

        params, opt_state, om = optimizer.apply(params, grads, opt_state, ocfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss

        scalar_state, tenant_state = (
            (sk_state.scalar, sk_state.tenants) if tenant_monitor is not None else (sk_state, {})
        )

        if sketch_cfg is not None:
            # Token-coverage telemetry: distinct token ids, weight 1. A
            # "tokens_mask" batch field (pipeline-tail padding) gates which
            # rows reach the sketch and the occurrence counter.
            scalar_state = monitor.update(
                sketch_cfg,
                scalar_state,
                batch["tokens"].astype(jnp.uint32),
                mask=batch.get("tokens_mask"),
            )
            metrics["distinct_tokens_est"] = monitor.estimate(sketch_cfg, scalar_state)

        if tenant_monitor is not None and "doc_ids" in batch:
            # Per-document coverage: tenant key = sparse doc/source id (one
            # per sequence, lo + optional hi uint32 word), element = token
            # id. Estimation is NOT run here — O(K·2^b) is a logging-cadence
            # cost, the update is not.
            tokens = batch["tokens"]

            def per_token(word):
                return jnp.broadcast_to(word.astype(jnp.uint32)[:, None], tokens.shape)

            doc_keys = per_token(batch["doc_ids"])
            if "doc_ids_hi" in batch:
                doc_keys = (doc_keys, per_token(batch["doc_ids_hi"]))
            tenant_state = tenant_monitor.update(
                tenant_state,
                doc_keys,
                tokens.astype(jnp.uint32),
                mask=batch.get("tokens_mask"),
            )
            metrics.update(tenant_monitor.metrics(tenant_state))

        sk_state = (
            monitor.TelemetryState(scalar=scalar_state, tenants=tenant_state)
            if tenant_monitor is not None
            else scalar_state
        )
        return params, opt_state, comp_state, sk_state, metrics

    return train_step


def init_states(mcfg, ocfg, params, *, sketch_cfg=None, tenant_monitor=None, compress=False):
    """(opt_state, comp_state, sketch_state) matching make_train_step."""
    opt_state = optimizer.init(params, ocfg)
    comp_state = compression.init_error_state(params) if compress else {}
    sk_state = monitor.init(sketch_cfg) if sketch_cfg is not None else {}
    if tenant_monitor is not None:
        sk_state = monitor.TelemetryState(scalar=sk_state, tenants=tenant_monitor.init())
    return opt_state, comp_state, sk_state
