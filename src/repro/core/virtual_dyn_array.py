"""VirtualDynArray: register-sharing virtual sketches for the long tail.

Dense keyed containers pay ``int8[m] + int32[2^b] + f32`` *per tenant*, which
caps a single host near K = 2^20 rows (ROADMAP). Following the virtual-sketch
construction of Wang et al. (arXiv 1811.09126) — the same paper the Dyn
variant's dynamic-properties estimator draws on — this container shares ONE
physical register pool ``int8[M]`` across the entire tail: tail tenant t's
logical register j lives at

    p(t, j) = hash(t_lo, t_hi, j; salt_pool) mod M,

so per-tenant marginal cost drops from ~m + 4·2^b bytes to ZERO (the pool is
sized once for aggregate traffic, not per tenant) and a single host pushes
past K = 1e7 tenants (benchmarks/virtual_dyn_array.py).

The price is exactness: a pool slot is max-shared by every tenant whose
(t, j) lands on it, so a tenant's gathered virtual row estimates the union of
its own stream with a ~(m_v/M) sample of everyone else's. Estimates
therefore run a *noise-cancellation pre-pass* (DESIGN.md §8.9): with
α = m_v/M,

    Ŵ_v ≈ W_t + α · (W_pool − W_t)      ⇒      Ŵ_t = (ρ·Ŵ_v − α·W_pool) / (1 − α)

clamped at 0, where Ŵ_v is the compound-Poisson profile solve of the
tenant's m_v gathered pool registers
(``estimation.estimate_rows_virtual`` — light-load-safe where the plain
routed MLE collapses), W_pool the total tail weight in the pool — read from
the exact ``w_tail`` accumulator the updates maintain — and ρ the in-vivo
calibration factor (``pool_calibration``): the ratio of the pool plane's
exact total to its own profile solve, correcting the solve's
weight-dispersion contraction at the live workload. m_v is the VIRTUAL row
width (``VirtualConfig.m_virtual``, default cfg.m) — virtual registers are
hash ranges, not storage, so the tail row width is a free statistical knob.
This trades the dense containers' bit-identity for a variance bound — the
statistical contract the property suite (tests/test_property.py) checks
instead of equality.

Hot tenants opt OUT of sharing: ``VirtualConfig.pinned`` tenants keep
dedicated dense ``DynArray`` rows (exact registers, exact O(1) martingale
reads), routed by the same ``key_directory`` machinery as every other keyed
container. ``promote`` moves a tail tenant into the hot tier after traffic
has already landed in the pool — see its docstring for the residue
semantics (estimates never double-count: a hot tenant reads its dense row
ONLY, never the pool).

Update cost is O(B log B) (slot grouping sort) + O(B) scatters, independent
of both K and M. The pool histogram is FULL (bin 0 counts untouched r_min
slots; bins always sum to M) and maintained incrementally — each slot the
batch raises moves one unit of mass old-bin -> new-bin, verified against
``rebuild_pool_hist`` in tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import typing

from . import dyn_array, estimation, estimators, hashing, key_directory, qsketch_dyn
from .types import DynArrayState, SketchConfig, VirtualDynArrayState


@dataclasses.dataclass(frozen=True)
class VirtualConfig:
    """Frozen (hashable) virtual-tier config — a valid ``jax.jit`` static arg.

    Attributes:
      pool_size: M, the shared physical register pool slots. Must exceed the
        virtual register count (noise cancellation divides by 1 − m_v/M) —
        in practice M ≫ m_v: the pool is sized for aggregate tail traffic,
        e.g. 2^26 slots = 64 MiB serves 1e7 tenants (benchmarks).
      m_virtual: m_v, registers per VIRTUAL (tail) row — None means cfg.m.
        Virtual registers are free: they are a hash range, not storage, so
        the tail can run much wider rows than the dense tier at zero memory
        cost (the vHLL decoupling). Wider rows cut estimation variance
        (∝ 1/√m_v) but raise the noise floor (α = m_v/M) — size m_v near
        the typical above-floor tail tenant's cardinality (DESIGN.md §8.9).
      pinned: static tuple of 64-bit tenant ids in the hot tier, each with a
        dedicated dense DynArray row [0, len(pinned)); everyone else shares
        the pool. Order is the row order.
      seed: base salt; the pool-placement role derives its own sub-salt so it
        is independent of the register-choice and routing roles.
    """

    pool_size: int
    m_virtual: int | None = None
    pinned: tuple = ()
    seed: int = 0x5EED

    def __post_init__(self):
        if self.pool_size < 3:
            raise ValueError("virtual pool needs pool_size >= 3 slots")
        if self.m_virtual is not None and self.m_virtual < 2:
            raise ValueError("m_virtual must be >= 2 virtual registers")
        if len(set(self.pinned)) != len(self.pinned):
            raise ValueError("pinned tenant ids must be distinct")
        for t in self.pinned:
            if not 0 <= int(t) < 2**64:
                raise ValueError(f"pinned tenant id out of 64-bit range: {t}")

    @property
    def num_hot(self) -> int:
        """Dedicated dense rows (== len(pinned))."""
        return len(self.pinned)

    @property
    def salt_pool(self) -> int:
        """Derived salt of the (tenant, register) -> pool-slot placement role."""
        return (self.seed * 0x9E3779B1 + 21) & 0xFFFFFFFF

    @property
    def directory(self) -> key_directory.DirectoryConfig:
        """The hot/tail routing directory: pinned tenants own slots
        [0, num_hot); every hashed (tail) tenant collapses onto the single
        sentinel slot num_hot. Tail membership is the test
        ``route_slots(...) < num_hot`` — the virtual tier needs no dense
        row per tail tenant, so one sentinel slot suffices and pinning
        never re-keys the tail (unlike dense directories, see
        ``key_directory.pin``)."""
        return key_directory.DirectoryConfig(
            capacity=self.num_hot + 1, seed=self.seed, pinned=self.pinned
        )


def tail_m(cfg: SketchConfig, vcfg: VirtualConfig) -> int:
    """m_v, the virtual (tail) row width: ``vcfg.m_virtual`` or cfg.m."""
    return cfg.m if vcfg.m_virtual is None else vcfg.m_virtual


def tail_config(cfg: SketchConfig, vcfg: VirtualConfig) -> SketchConfig:
    """Tail-geometry config: the dense register family (b, hence
    r_min/r_max/num_bins) at the VIRTUAL row width m_v. Register choice,
    value quantization and the row solve for tail tenants all run under
    this geometry; the hot tier keeps the dense ``cfg`` untouched."""
    m_v = tail_m(cfg, vcfg)
    if m_v == cfg.m:
        return cfg
    return SketchConfig(m=m_v, b=cfg.b, seed=cfg.seed)


def _check_pool(cfg: SketchConfig, vcfg: VirtualConfig) -> None:
    if vcfg.pool_size <= tail_m(cfg, vcfg):
        raise ValueError(
            f"pool_size {vcfg.pool_size} must exceed m_v {tail_m(cfg, vcfg)}: "
            "noise cancellation divides by 1 - m_v/M"
        )


def init(cfg: SketchConfig, vcfg: VirtualConfig) -> VirtualDynArrayState:
    """Fresh virtual tier: empty pool (all r_min, full hist mass in bin 0),
    plus one dense DynArray row per pinned tenant (at least one placeholder
    row so the hot leaves keep static shapes when nothing is pinned — the
    placeholder never receives traffic)."""
    _check_pool(cfg, vcfg)
    pool_hist = jnp.zeros((cfg.num_bins,), jnp.int32).at[0].set(vcfg.pool_size)
    return VirtualDynArrayState(
        pool=jnp.full((vcfg.pool_size,), cfg.r_min, dtype=jnp.int8),
        pool_hist=pool_hist,
        n_tail=jnp.int32(0),
        w_tail=jnp.float32(0.0),
        hot=dyn_array.init(cfg, max(1, vcfg.num_hot)),
    )


def pool_slots(cfg: SketchConfig, vcfg: VirtualConfig, t_lo, t_hi, j) -> jnp.ndarray:
    """Physical pool slot of (tenant, register j): int32 in [0, M).

    Pure function of (tenant id words, register index, salt_pool) — the same
    stateless-hash contract as ``key_directory.route_slots``, so every host
    (and the Pallas kernel) places identically. Broadcasts: feeding
    ``t_lo[:, None]`` against ``j[None, :]`` yields a [T, m_v] gather map.
    """
    return hashing.hash_mod(
        (t_lo, t_hi, j.astype(jnp.uint32)), vcfg.salt_pool, vcfg.pool_size
    )


def virtual_rows(cfg: SketchConfig, vcfg: VirtualConfig, state, t_lo, t_hi) -> jnp.ndarray:
    """Gather the virtual register rows ``int8[T, m_v]`` of T tenants.

    Row t is the tenant's logical sketch as seen through the shared pool —
    its own stream max-merged with whatever other tail traffic landed on the
    same slots (the noise the estimate-time cancellation removes).
    """
    j = jnp.arange(tail_m(cfg, vcfg), dtype=jnp.int32)
    p = pool_slots(cfg, vcfg, t_lo[:, None], t_hi[:, None], j[None, :])
    return state.pool[p]


class PoolPlan(typing.NamedTuple):
    """B-sized scatter payloads of one pool batch update (read-only half).

    The pooled analogue of ``dyn_array.UpdatePlan``, with two differences:
    grouping is by pool slot alone (no per-tenant dedup — duplicates map to
    the same (p, y) and the scatter-max is idempotent, and there is no tail
    martingale to protect), and the histogram is FULL, so a raised slot
    always retires one unit from its old bin — including bin 0, which
    carries the untouched r_min mass.
    """

    p: jax.Array  # int32[B] pool slots
    y_eff: jax.Array  # int8[B] scatter-max payload (r_min where unchanged)
    old_bin: jax.Array  # int32[B] batch-start bin of pool[p]
    final_bin: jax.Array  # int32[B] post-batch bin of pool[p]
    hist_dec: jax.Array  # int32[B] -1 where this element retires old_bin mass
    hist_inc: jax.Array  # int32[B] +1 where this element deposits final_bin


def _plan_pool(cfg: SketchConfig, pool, p, y, live) -> PoolPlan:
    """Read-only half of the pool update: batch-start change indicators and
    incremental full-histogram bookkeeping, all B-sized. Mirrors
    ``dyn_array._plan_scatters``' segment-max construction so the committed
    scatter-max and the histogram move agree exactly."""
    old = pool[p].astype(jnp.int32)
    changed = live & (y > old)
    y_eff = jnp.where(changed, y, jnp.int32(cfg.r_min))

    # Post-batch slot value = max(old, segment max of y_eff over the slot's
    # group): exactly what the commit's scatter-max leaves there, computed
    # without re-gathering the scattered pool.
    order = jnp.lexsort((p,))
    sp = p[order]
    starts = jnp.concatenate([jnp.array([True]), sp[1:] != sp[:-1]])
    seg = jnp.cumsum(starts) - 1
    smax = jax.ops.segment_max(
        y_eff[order], seg, num_segments=y_eff.shape[0], indices_are_sorted=True
    )
    final_sorted = jnp.maximum(old[order], smax[seg])
    final = jnp.zeros_like(final_sorted).at[order].set(final_sorted)
    slot_first = jnp.zeros_like(starts).at[order].set(starts)
    slot_changed = slot_first & (final > old)
    return PoolPlan(
        p=p,
        y_eff=y_eff.astype(jnp.int8),
        old_bin=old - cfg.r_min,
        final_bin=final - cfg.r_min,
        hist_dec=jnp.where(slot_changed, -1, 0),
        hist_inc=jnp.where(slot_changed, 1, 0),
    )


def _apply_pool_update(cfg: SketchConfig, state: VirtualDynArrayState, p, y, w, live):
    """Shared tail of the jnp and Pallas-backed pool updates: plan + commit
    fused in one trace, so ``ops.virtual_dyn_update_op`` is bit-identical to
    ``update_tenants`` by construction (the kernel only computes (p, y))."""
    plan = _plan_pool(cfg, state.pool, p, y, live)
    pool = state.pool.at[plan.p].max(plan.y_eff)
    pool_hist = state.pool_hist.at[plan.old_bin].add(plan.hist_dec)
    pool_hist = pool_hist.at[plan.final_bin].add(plan.hist_inc)
    n_tail = state.n_tail + jnp.sum(live).astype(jnp.int32)
    w_tail = state.w_tail + jnp.sum(jnp.where(live, w, 0.0)).astype(jnp.float32)
    return state._replace(
        pool=pool, pool_hist=pool_hist, n_tail=n_tail, w_tail=w_tail
    )


def _apply_update(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    t_lo, t_hi, lo, hi, w, live, p, y,
) -> VirtualDynArrayState:
    """Hot/tail split on pre-computed pool placement (p, y): the common,
    data-dependent tail of the jnp and Pallas-backed entries. Hot traffic
    runs the exact dense DynArray update on the pinned rows (bit-identical
    to a dedicated DynArray fed the hot sub-stream); tail traffic
    scatter-maxes into the shared pool."""
    slots = key_directory.route_slots(vcfg.directory, (t_lo, t_hi))
    is_hot = slots < vcfg.num_hot

    hot_keys = jnp.clip(slots, 0, state.hot.regs.shape[0] - 1)
    hot_live = live & is_hot
    q = qsketch_dyn._q_update_prob(cfg, state.hot.hists[hot_keys], w)
    hot = dyn_array._apply_update(cfg, state.hot, hot_keys, lo, hi, w, hot_live, q)

    return _apply_pool_update(cfg, state._replace(hot=hot), p, y, w, live & ~is_hot)


def _update_tenants_impl(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    tenant_keys, ids, weights, mask=None,
) -> VirtualDynArrayState:
    t_lo, t_hi = hashing.split_id64(tenant_keys)
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    live = qsketch_dyn._live_weight_mask(w, mask)
    # Tail geometry: register choice j ∈ [0, m_v) AND the value draw (whose
    # hash includes j) run under the virtual row width. The hot path below
    # recomputes its own (j, y) under the dense cfg inside
    # dyn_array._apply_update — the two geometries never mix.
    j, y = qsketch_dyn._choose_and_quantize(tail_config(cfg, vcfg), lo, hi, w)
    p = pool_slots(cfg, vcfg, t_lo, t_hi, j)
    return _apply_update(cfg, vcfg, state, t_lo, t_hi, lo, hi, w, live, p, y)


_update_tenants_jit = jax.jit(_update_tenants_impl, static_argnums=(0, 1))


def update_tenants(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    tenant_keys, ids, weights, mask=None,
) -> VirtualDynArrayState:
    """One fused batch over sparse 64-bit tenant ids: -> state'.

    Pinned (hot) tenants update their dedicated dense rows with the full
    DynArray semantics — per-(tenant, id) dedup, incremental histograms, the
    batch-stale martingale — bit-identical to a dedicated ``DynArray`` fed
    the hot sub-stream. Tail tenants scatter-max into the shared pool (no
    dedup needed: a duplicate maps to the same (slot, value) and max is
    idempotent; there is no per-tail-tenant running estimate — tail reads
    solve at query time via ``estimate_tenants``).

    mask: optional bool[B]; masked rows and degenerate weights are dropped
    (``qsketch_dyn`` contract). Routing is stateless (``route_slots``), so
    no directory state threads through — collision telemetry is meaningless
    when every tail tenant shares one sentinel slot by design.
    """
    return _update_tenants_jit(cfg, vcfg, state, tenant_keys, ids, weights, mask)


def estimate_pool_total(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    *, solver: str = "newton",
) -> jnp.ndarray:
    """Ŵ_pool: total tail weight folded into the pool, from the maintained
    full pool histogram — an O(2^b) read, no register walk.

    The pool plane IS one routed-convention sketch of the whole tail stream
    under the pool geometry (M slots, same register family): each tail
    element raises exactly one pool slot. Solved through the estimation
    layer under ``estimation.pool_config``; ``solver="fused"`` falls back to
    newton (the fused kernel streams registers, not histograms).
    """
    return estimation.estimate_pool_hist(
        cfg, state.pool_hist, vcfg.pool_size, solver=solver
    )


@functools.partial(jax.jit, static_argnums=(0, 1), static_argnames=("solver",))
def estimate_tenants(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    tenant_keys, *, solver: str = "newton",
) -> jnp.ndarray:
    """Ŵ per queried tenant, f32[T] — the noise-cancelled virtual read.

    Hot (pinned) tenants return their dense row's running martingale ONLY —
    the pool never contributes, which is what makes ``promote`` residue-safe
    (no double count by construction). Tail tenants gather their m pool
    registers, solve the occupancy-scaled routed MLE
    (``estimation.estimate_rows_virtual`` — light-load-safe where the plain
    routed read collapses), scale by the in-vivo calibration ρ
    (``pool_calibration``), and cancel the expected cross-tenant noise:  Ŵ_t = max(0, (ρ·Ŵ_v − α·W_pool) / (1 − α)),  α = m_v/M
    (Wang et al. 1811.09126; derivation in DESIGN.md §8.9), with W_pool the
    exact ``w_tail`` weight accumulator — not the pooled histogram MLE,
    which inherits the same weight-dispersion contraction ρ corrects.
    Unknown tail tenants (no traffic) read ≈0 — their slots are mostly
    untouched and the cancellation clamps the residual noise at zero from
    below.
    """
    _check_pool(cfg, vcfg)
    t_lo, t_hi = hashing.split_id64(tenant_keys)
    slots = key_directory.route_slots(vcfg.directory, (t_lo, t_hi))
    is_hot = slots < vcfg.num_hot

    tcfg = tail_config(cfg, vcfg)
    rows = virtual_rows(cfg, vcfg, state, t_lo, t_hi)
    chat_v = estimation.estimate_rows_virtual(tcfg, rows, solver=solver)
    rho = pool_calibration(cfg, vcfg, state, solver=solver)
    cancelled = estimation.cancel_pool_noise(
        tcfg, rho * chat_v, state.w_tail, vcfg.pool_size
    )

    hot_chats = state.hot.chats[jnp.clip(slots, 0, state.hot.regs.shape[0] - 1)]
    return jnp.where(is_hot, hot_chats, cancelled)


def pool_calibration(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    *, solver: str = "newton",
) -> jnp.ndarray:
    """ρ = w_tail / Ŵ_pool: the self-calibration factor of the virtual row
    solve (f32 scalar, clamped to [0.5, 2]; 1.0 on an empty pool).

    The compound-Poisson profile solve is exactly unbiased when element
    weights are constant, but dispersed weights contract its effective mean
    (Jensen against the Laplace transform — DESIGN.md §8.9), by a factor
    that depends on the unknown weight distribution. The pool plane measures
    that factor in vivo: it is one giant row under the SAME register family
    and a comparable per-slot load law, and the sketch knows its total
    weight EXACTLY (``w_tail``). The ratio of exact to solved pool total
    therefore calibrates the family solve at the live workload's weight
    distribution and load, and ``estimate_tenants`` scales each row solve
    by it before noise cancellation. The clamp bounds the correction when
    the pool is too empty to measure (few touched slots → noisy Ŵ_pool).
    """
    chat_pool = estimate_pool_total(cfg, vcfg, state, solver=solver)
    rho = jnp.where(chat_pool > 0.0, state.w_tail / chat_pool, jnp.float32(1.0))
    return jnp.clip(rho, 0.5, 2.0)


def pool_load_factor(state: VirtualDynArrayState) -> jnp.ndarray:
    """Fraction of pool slots ever raised above r_min (f32 scalar).

    The saturation signal: past ~0.5 the per-slot collision noise grows
    toward the signal and the cancellation's variance bound degrades —
    ``obs/health.py`` warns on it (DESIGN.md §8.9 sizing policy).
    """
    m_size = state.pool.shape[0]
    return 1.0 - state.pool_hist[0].astype(jnp.float32) / m_size


def noise_floor(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState
) -> jnp.ndarray:
    """Expected cross-tenant noise weight on ONE tenant's virtual row:
    α·W_pool / (1 − α), f32 scalar — the quantity the cancellation
    subtracts, from the exact ``w_tail`` accumulator. Tail estimates below
    this floor are dominated by noise variance; ``obs/health.py`` exposes
    it as a warning threshold."""
    _check_pool(cfg, vcfg)
    alpha = tail_m(cfg, vcfg) / vcfg.pool_size
    return jnp.float32(alpha / (1.0 - alpha)) * state.w_tail


def rebuild_pool_hist(cfg: SketchConfig, pool) -> jnp.ndarray:
    """Full pool histogram from scratch (bins sum to M) — the O(M) reference
    the incremental maintenance is tested against, and the rebuild ``merge``
    uses."""
    return jnp.bincount(
        pool.astype(jnp.int32) - cfg.r_min, length=cfg.num_bins
    ).astype(jnp.int32)


def merge(
    cfg: SketchConfig, vcfg: VirtualConfig,
    a: VirtualDynArrayState, b: VirtualDynArrayState,
) -> VirtualDynArrayState:
    """Merge two fleets sketching (possibly overlapping) tail streams.

    Pool: element-wise max (exact union — the same max monoid as every
    register plane in the repo), histogram rebuilt. Hot tier: dense
    ``dyn_array.merge`` (registers max, chats re-estimated via the MLE).
    ``n_tail`` and ``w_tail`` add — exact for the repo's disjoint-shard
    convention; overlapping streams inflate ``w_tail`` (the registers
    max-dedup, the scalars cannot) and the cancelled tail reads go
    conservative. Both states must come from the same (cfg, vcfg): shapes
    and hash salts must agree or the slot spaces are incompatible.
    """
    if a.pool.shape != b.pool.shape:
        raise ValueError(
            f"virtual merge needs matching pools, got {a.pool.shape} vs {b.pool.shape}"
        )
    pool = jnp.maximum(a.pool, b.pool)
    return VirtualDynArrayState(
        pool=pool,
        pool_hist=rebuild_pool_hist(cfg, pool),
        n_tail=a.n_tail + b.n_tail,
        w_tail=a.w_tail + b.w_tail,
        hot=dyn_array.merge(cfg, a.hot, b.hot),
    )


def promote(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    tenant, *, migrate: bool = False,
) -> tuple[VirtualConfig, VirtualDynArrayState]:
    """Pin a tail tenant into the hot tier: -> (vcfg', state').

    The returned config has ``tenant`` appended to ``pinned`` (a NEW frozen
    config — jitted callees recompile once, as with any static-arg change);
    the returned state has one more dense row. Subsequent traffic for the
    tenant updates that row; subsequent estimates read it ONLY — pool
    residue from the tenant's pre-promotion traffic is never added to its
    estimate, so promotion cannot double-count (tested in
    tests/test_virtual_dyn_array.py). Other tail tenants are unaffected:
    pool placement hashes (tenant, j) directly and never sees the pinned
    set, so promotion re-keys nobody (contrast ``key_directory.pin`` for
    dense directories).

    Two residue semantics (the documented choice of satellite #3):

    migrate=False (default) — *epoch fence*: the dense row starts EMPTY.
      The tenant's history stays behind in the pool (it keeps inflating the
      pool total and noise floor until the pool is rebuilt/aged, exactly
      like any departed tail tenant's traffic) and the tenant's estimate
      restarts from 0. Choose this when promotion coincides with an epoch
      boundary (window rotation) or when the history is untrusted.

    migrate=True — *carry the virtual row over*: the dense row seeds from
      the tenant's gathered pool registers, with a rebuilt histogram and
      chat re-estimated via the routed histogram MLE (the ``merge``
      convention — registers and chat stay consistent for health drift
      checks). The seed inherits the virtual row's cross-tenant noise (an
      overestimate bounded by ``noise_floor``; the noise-cancelled read is
      deliberately NOT used because a dense row's chat must be the MLE of
      its own registers). Duplicates of already-seen elements re-sent after
      migration find their register already at their y and leave the chat
      unchanged — the no-double-count property the tests pin down.

    The pool is untouched in both modes (residue removal would need per-slot
    ownership the pool deliberately does not store).
    """
    t = int(tenant)
    if t in tuple(int(x) for x in vcfg.pinned):
        raise ValueError(f"tenant {tenant} is already pinned")
    if migrate and tail_m(cfg, vcfg) != cfg.m:
        raise ValueError(
            "promote(migrate=True) needs m_virtual == cfg.m: a virtual row "
            "under a different register modulus cannot seed a dense row "
            "(register j of each geometry indexes a different element "
            "subset) — use migrate=False (epoch fence) instead"
        )
    vcfg2 = dataclasses.replace(vcfg, pinned=vcfg.pinned + (t,))

    num_hot = vcfg.num_hot
    if migrate:
        t_lo, t_hi = key_directory.split_uint64([t])
        row_regs = virtual_rows(cfg, vcfg, state, t_lo, t_hi)[0]
        row_hist = estimators.histogram(cfg, row_regs).at[0].set(0)
        full = row_hist.at[0].set(cfg.m - jnp.sum(row_hist))
        row_chat = estimation.estimate_hist(cfg, full, kind="routed")
    else:
        row_regs = jnp.full((cfg.m,), cfg.r_min, jnp.int8)
        row_hist = jnp.zeros((cfg.num_bins,), jnp.int32)
        row_chat = jnp.float32(0.0)

    # Drop the unpinned placeholder row when the hot tier was empty.
    hot = state.hot
    regs, hists, chats = hot.regs[:num_hot], hot.hists[:num_hot], hot.chats[:num_hot]
    hot2 = DynArrayState(
        regs=jnp.concatenate([regs, row_regs[None, :].astype(jnp.int8)]),
        hists=jnp.concatenate([hists, row_hist[None, :].astype(jnp.int32)]),
        chats=jnp.concatenate([chats, jnp.reshape(row_chat, (1,)).astype(jnp.float32)]),
    )
    return vcfg2, state._replace(hot=hot2)


def memory_bytes(cfg: SketchConfig, vcfg: VirtualConfig) -> int:
    """Device bytes of one VirtualDynArrayState: pool + pool hist + counters
    + the pinned hot rows. Independent of the tail tenant count — the whole
    point (compare ``dense_memory_bytes``)."""
    pool = vcfg.pool_size + 4 * cfg.num_bins + 4 + 4
    hot_rows = max(1, vcfg.num_hot)
    return pool + hot_rows * (cfg.m + 4 * cfg.num_bins + 4)


def dense_memory_bytes(cfg: SketchConfig, k: int) -> int:
    """Device bytes of a dense ``DynArrayState`` with k tenant rows — the
    baseline the benchmark's memory-reduction headline divides by."""
    return k * (cfg.m + 4 * cfg.num_bins + 4)


def update_reference(
    cfg: SketchConfig, vcfg: VirtualConfig, state: VirtualDynArrayState,
    tenant_keys, ids, weights, mask=None,
) -> VirtualDynArrayState:
    """Oracle: sequential numpy application of the hot/tail semantics.

    Hot sub-stream runs through ``dyn_array.update_reference`` (itself the
    K-loop of single Dyn sketches); the pool applies each live element's
    (p, y) one at a time with full-histogram mass moves. Tests/benchmarks
    only — O(B) python, never the hot path.
    """
    import numpy as np

    t_lo, t_hi = hashing.split_id64(tenant_keys)
    lo, hi = hashing.split_id64(ids)
    w = jnp.asarray(weights).astype(jnp.float32)
    live = np.asarray(qsketch_dyn._live_weight_mask(w, mask))
    slots = np.asarray(key_directory.route_slots(vcfg.directory, (t_lo, t_hi)))
    is_hot = slots < vcfg.num_hot

    j, y = qsketch_dyn._choose_and_quantize(tail_config(cfg, vcfg), lo, hi, w)
    p = np.asarray(pool_slots(cfg, vcfg, t_lo, t_hi, j))
    y_np = np.asarray(y)

    hot = dyn_array.update_reference(
        cfg, state.hot,
        jnp.asarray(np.clip(slots, 0, state.hot.regs.shape[0] - 1)),
        ids, weights,
        mask=jnp.asarray(live & is_hot),
    )

    pool = np.asarray(state.pool).copy()
    hist = np.asarray(state.pool_hist).copy()
    n_tail = int(state.n_tail)
    # Same batch-sum expression (and reduction order) as _apply_pool_update,
    # so the f32 scalar is bit-identical, not just close.
    live_tail = jnp.asarray(live & ~is_hot)
    w_tail = state.w_tail + jnp.sum(jnp.where(live_tail, w, 0.0)).astype(jnp.float32)
    for i in range(p.shape[0]):
        if not live[i] or is_hot[i]:
            continue
        n_tail += 1
        old = int(pool[p[i]])
        if y_np[i] > old:
            hist[old - cfg.r_min] -= 1
            hist[y_np[i] - cfg.r_min] += 1
            pool[p[i]] = y_np[i]
    return VirtualDynArrayState(
        pool=jnp.asarray(pool),
        pool_hist=jnp.asarray(hist),
        n_tail=jnp.int32(n_tail),
        w_tail=w_tail,
        hot=hot,
    )
