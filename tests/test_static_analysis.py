"""qlint test suite (DESIGN.md §9): the analysis rules, baseline, and CLI.

Each rule gets a positive fixture (a seeded violation in a throwaway
mini-tree laid out like the repo: ``src/repro/...``) and a negative one
(the idiomatic clean form). On top of the per-rule coverage:

* the aliased-import regression the old tier-2 grep could not catch
  (``test_layering_catches_aliased_import_the_grep_missed``),
* the baseline round-trip: suppress -> clean -> unsuppress -> dirty,
  plus stale-entry detection and the inline ``# qlint: disable=`` hatch,
* ``--changed-only`` / explicit-path selection,
* CLI exit codes: every rule's seeded violation makes
  ``scripts/check_static.py`` exit non-zero (the acceptance criterion),
* lock-in tests for the two suppressed findings in the real tree
  (``check_disjoint_rows`` tracer guard, ``lm_estimate`` f32 semantics),
* and a full run over the actual repo, which must be clean.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import build_context, run_qlint
from repro.analysis.baseline import Baseline

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

import check_static  # noqa: E402  (scripts/ entry point, path-injected above)


def write(root: Path, rel: str, text: str) -> Path:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))
    return p


def qlint(root, rules, **kw):
    kw.setdefault("baseline_path", None)
    return run_qlint(str(root), rule_subset=list(rules), **kw)


def rows_for(report, rule):
    return [r for r in report["findings"] if r["rule"] == rule]


# ---------------------------------------------------------------------------
# Seeded violations, one per rule — shared by the per-rule tests and the
# CLI exit-code sweep.
# ---------------------------------------------------------------------------

VIOLATIONS = {
    "layering": (
        "src/repro/sketchstream/bad_layer.py",
        '''
        """Out-of-layer solve."""
        from repro.core.estimators import qsketch_mle as _fast

        def solve(hist):
            """Solve a histogram without going through core/estimation."""
            return _fast(hist)
        ''',
    ),
    "int8-overflow": (
        "src/repro/core/regs_math.py",
        '''
        """Arithmetic on int8 registers without an upcast."""
        import jax.numpy as jnp

        def total(regs):
            """Sum registers (wraps silently at +-127)."""
            return jnp.sum(regs)
        ''',
    ),
    "donation-safety": (
        "src/repro/core/donate_bad.py",
        '''
        """Read-after-donate."""
        import jax

        def _upd(state, xs):
            """Pure update."""
            return state + xs

        upd = jax.jit(_upd, donate_argnums=(0,))

        def caller(state, xs):
            """Donates state, then reads the dead buffer."""
            new = upd(state, xs)
            return new, state.sum()
        ''',
    ),
    "jit-purity": (
        "src/repro/core/jit_impure.py",
        '''
        """Side effect inside a jitted function."""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def traced(x):
            """Prints at trace time, silent thereafter."""
            print("tracing", x)
            return jnp.sum(x)
        ''',
    ),
    "kernel-contract": (
        "src/repro/kernels/bad_kernel.py",
        '''
        """Kernel param not named *_ref."""
        import jax
        from jax.experimental import pallas as pl

        def _copy_kernel(x, o_ref):
            """Copy block."""
            o_ref[...] = x[...]

        def run(x):
            """Launch the copy kernel."""
            return pl.pallas_call(
                _copy_kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)
        ''',
    ),
    "docstrings": (
        "src/repro/core/nodoc.py",
        '''
        """Module documented, function not."""

        def public_fn(x):
            return x
        ''',
    ),
}


@pytest.fixture
def root(tmp_path):
    return tmp_path


# ---------------------------------------------------------------------------
# layering
# ---------------------------------------------------------------------------


def test_layering_flags_from_import_alias(root):
    write(root, *VIOLATIONS["layering"])
    report = qlint(root, ["layering"])
    got = rows_for(report, "layering")
    assert got and all(r["path"] == "src/repro/sketchstream/bad_layer.py" for r in got)
    assert not report["ok"]


def test_layering_catches_aliased_import_the_grep_missed(root):
    """The regression the AST rule exists for: the old tier-2 grep scanned
    a fixed file list for the literal token ``qsketch_mle``, so (a) a
    module-alias use in kernels/ was invisible to it (kernels/ was excluded
    because docstrings there legitimately mention the symbol), and (b) a
    docstring mention would have been a false positive. The AST rule
    resolves the alias chain to the use site and ignores prose."""
    write(
        root,
        "src/repro/kernels/alias_use.py",
        '''
        """Sneaky direct solve from kernels/ via a module alias."""
        from repro.core import estimators as _e

        def solve(hist):
            """Bypass core/estimation through the alias."""
            return _e.qsketch_mle(hist)
        ''',
    )
    write(
        root,
        "src/repro/sketchstream/prose_only.py",
        '''
        """Routes solves to estimation (which wraps qsketch_mle internally).

        Mentioning qsketch_mle in prose must NOT be a finding.
        """
        from repro.core import estimation

        def solve(cfg, hist):
            """Solve through the sanctioned layer."""
            return estimation.estimate(cfg, hist)
        ''',
    )
    report = qlint(root, ["layering"])
    got = rows_for(report, "layering")
    assert got, "aliased module-attribute use must be flagged"
    assert {r["path"] for r in got} == {"src/repro/kernels/alias_use.py"}


def test_layering_allows_the_estimation_layer(root):
    write(
        root,
        "src/repro/core/estimation.py",
        '''
        """The one sanctioned import site."""
        from repro.core.estimators import qsketch_mle

        def estimate(hist):
            """Routed solve."""
            return qsketch_mle(hist)
        ''',
    )
    assert qlint(root, ["layering"])["ok"]


# ---------------------------------------------------------------------------
# int8-overflow
# ---------------------------------------------------------------------------


def test_int8_overflow_flags_sum_and_add(root):
    write(root, *VIOLATIONS["int8-overflow"])
    write(
        root,
        "src/repro/core/regs_inc.py",
        '''
        """Scatter-add on int8 registers."""

        def bump(regs, idx):
            """In-place-style increment (wraps at 127)."""
            return regs.at[idx].add(1)
        ''',
    )
    report = qlint(root, ["int8-overflow"])
    paths = {r["path"] for r in rows_for(report, "int8-overflow")}
    assert paths == {"src/repro/core/regs_math.py", "src/repro/core/regs_inc.py"}


def test_int8_overflow_upcast_and_max_monoid_are_clean(root):
    write(
        root,
        "src/repro/core/regs_ok.py",
        '''
        """The sanctioned forms: upcast before arithmetic, max monoid as-is."""
        import jax.numpy as jnp

        def total(regs):
            """Upcast then sum — no wrap."""
            return jnp.sum(regs.astype(jnp.int32))

        def union(regs, other_regs):
            """Max monoid is closed on int8."""
            return jnp.maximum(regs, other_regs)
        ''',
    )
    assert qlint(root, ["int8-overflow"])["ok"]


# ---------------------------------------------------------------------------
# donation-safety
# ---------------------------------------------------------------------------


def test_donation_read_after_donate(root):
    write(root, *VIOLATIONS["donation-safety"])
    report = qlint(root, ["donation-safety"])
    got = rows_for(report, "donation-safety")
    assert got and "state" in got[0]["message"]


def test_donation_rebind_is_clean(root):
    write(
        root,
        "src/repro/core/donate_ok.py",
        '''
        """The sanctioned shape: rebind the donated name to the result."""
        import jax

        def _upd(state, xs):
            """Pure update."""
            return state + xs

        upd = jax.jit(_upd, donate_argnums=(0,))

        def caller(state, xs):
            """Donate and rebind; the old buffer is never read again."""
            state = upd(state, xs)
            return state
        ''',
    )
    assert qlint(root, ["donation-safety"])["ok"]


def test_donation_jit_without_return(root):
    write(
        root,
        "src/repro/core/donate_noreturn.py",
        '''
        """Donating entry point that drops the new buffer."""
        import jax

        def _sink(state):
            """Mutation-style body: the .at result is discarded."""
            state.at[0].set(1)

        sink = jax.jit(_sink, donate_argnums=(0,))
        ''',
    )
    report = qlint(root, ["donation-safety"])
    assert rows_for(report, "donation-safety"), (
        "a donating jit whose fn never returns the new buffer must be flagged"
    )


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------


def test_purity_flags_print_in_jit(root):
    write(root, *VIOLATIONS["jit-purity"])
    report = qlint(root, ["jit-purity"])
    got = rows_for(report, "jit-purity")
    assert got and got[0]["path"] == "src/repro/core/jit_impure.py"


def test_purity_flags_host_sync_reachable_through_helper(root):
    write(
        root,
        "src/repro/core/jit_sync.py",
        '''
        """Host-sync two calls deep under jit."""
        import jax
        import jax.numpy as jnp

        def _helper(x):
            """Syncs the device value back to host."""
            return float(jnp.sum(x))

        @jax.jit
        def traced(x):
            """Reaches the sync through a helper."""
            return _helper(x) * x
        ''',
    )
    report = qlint(root, ["jit-purity"])
    assert rows_for(report, "jit-purity"), "reachability must cross the helper call"


def test_purity_unjitted_host_code_is_clean(root):
    write(
        root,
        "src/repro/core/host_side.py",
        '''
        """Host entry point: prints and syncs freely, never traced."""
        import jax.numpy as jnp

        def report(x):
            """Eager summary."""
            total = float(jnp.sum(x))
            print("total:", total)
            return total
        ''',
    )
    assert qlint(root, ["jit-purity"])["ok"]


# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------


def test_kernel_contract_param_naming(root):
    write(root, *VIOLATIONS["kernel-contract"])
    report = qlint(root, ["kernel-contract"])
    got = rows_for(report, "kernel-contract")
    assert got and got[0]["path"] == "src/repro/kernels/bad_kernel.py"


def test_kernel_contract_blockspec_rank_mismatch(root):
    write(
        root,
        "src/repro/kernels/rank_kernel.py",
        '''
        """BlockSpec block rank vs index_map output rank disagree."""
        import jax
        from jax.experimental import pallas as pl

        def _k(x_ref, o_ref):
            """Copy block."""
            o_ref[...] = x_ref[...]

        def run(x):
            """2-d block, 3-component index map."""
            return pl.pallas_call(
                _k,
                grid=(4,),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
            )(x)
        ''',
    )
    report = qlint(root, ["kernel-contract"])
    assert rows_for(report, "kernel-contract")


def test_kernel_contract_clean_kernel(root):
    write(
        root,
        "src/repro/kernels/good_kernel.py",
        '''
        """Contract-conforming copy kernel."""
        import jax
        from jax.experimental import pallas as pl

        def _copy_kernel(x_ref, o_ref):
            """Copy block."""
            o_ref[...] = x_ref[...]

        def run(x):
            """Launch the copy kernel."""
            return pl.pallas_call(
                _copy_kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)
        ''',
    )
    assert qlint(root, ["kernel-contract"])["ok"]


# ---------------------------------------------------------------------------
# docstrings + bench-schema (the absorbed legacy checks)
# ---------------------------------------------------------------------------


def test_docstrings_rule(root):
    write(root, *VIOLATIONS["docstrings"])
    report = qlint(root, ["docstrings"])
    got = rows_for(report, "docstrings")
    assert got and "public_fn" in got[0]["message"]


def test_bench_schema_selected_mode(root):
    write(
        root,
        "experiments/bench/dyn_array.json",
        json.dumps(
            [
                {"figure": "f7", "method": "qsketch", "k": 12, "mops": 1.0},
                {"figure": "f7", "method": "qsketch", "k": 12, "mops": 2.0},
            ]
        ),
    )
    report = qlint(
        root, ["bench-schema"], selected=["experiments/bench/dyn_array.json"]
    )
    got = rows_for(report, "bench-schema")
    assert got and "duplicate k" in got[0]["message"]


def test_bench_schema_selected_mode_matches_full_scope(root):
    """A non-cumulative bench JSON (its suite uses its own payload keys)
    must not be flagged just because it appears in a --changed-only
    selection — selected mode may not be stricter than a full run."""
    write(
        root,
        "experiments/bench/sketch_array_sharded.json",
        json.dumps([{"figure": "f", "method": "m", "update_mops": 1.0}]),
    )
    report = qlint(
        root,
        ["bench-schema"],
        selected=["experiments/bench/sketch_array_sharded.json"],
    )
    assert report["ok"] and not rows_for(report, "bench-schema")


def test_partial_runs_do_not_report_stale_baseline(root):
    """Baseline staleness is only computable on a full run: a rule-subset
    or file-selected run never produces the other entries' findings."""
    write(root, *VIOLATIONS["int8-overflow"])
    base_path = root / "qlint_baseline.json"
    base = Baseline(str(base_path))
    base.entries["jit-purity::src/elsewhere.py::some message"] = "why"
    base.save()
    partial = qlint(
        root, ["int8-overflow"], baseline_path="qlint_baseline.json"
    )
    assert partial["stale_baseline_keys"] == []


def test_bench_schema_full_mode_requires_cumulative_files(root):
    report = qlint(root, ["bench-schema"])
    msgs = {r["message"] for r in rows_for(report, "bench-schema")}
    assert {"expected cumulative bench file is missing"} == msgs
    assert len(rows_for(report, "bench-schema")) == 6


# ---------------------------------------------------------------------------
# baseline + inline suppression
# ---------------------------------------------------------------------------


def test_baseline_roundtrip(root):
    write(root, *VIOLATIONS["int8-overflow"])
    baseline_rel = "scripts/qlint_baseline.json"

    # Dirty: the violation is new.
    report = qlint(root, ["int8-overflow"], baseline_path=baseline_rel)
    assert not report["ok"] and report["counts"]["new"] == 1
    row = rows_for(report, "int8-overflow")[0]
    key = row["key"]
    # Keys are line-number-free (rule::path::message), so they survive edits
    # elsewhere in the file.
    assert key == f"int8-overflow::src/repro/core/regs_math.py::{row['message']}"

    # Suppress: baseline the key -> clean, with the justification surfaced.
    base_path = root / baseline_rel
    base_path.parent.mkdir(parents=True, exist_ok=True)
    base = Baseline(str(base_path))
    base.entries[key] = "fixture: grandfathered for the round-trip test"
    base.save()
    report = qlint(root, ["int8-overflow"], baseline_path=baseline_rel)
    assert report["ok"] and report["counts"]["baselined"] == 1
    row = rows_for(report, "int8-overflow")[0]
    assert row["baselined"] and "round-trip" in row["justification"]

    # Unsuppress: empty the baseline -> dirty again.
    base.entries.clear()
    base.save()
    report = qlint(root, ["int8-overflow"], baseline_path=baseline_rel)
    assert not report["ok"] and report["counts"]["new"] == 1

    # Stale entries (nothing matches them) are reported for pruning —
    # on a full run only (see test_partial_runs_do_not_report_stale_baseline).
    base.entries["int8-overflow::src/gone.py::stale message"] = "old"
    base.save()
    report = run_qlint(str(root), baseline_path=baseline_rel)
    assert report["stale_baseline_keys"] == [
        "int8-overflow::src/gone.py::stale message"
    ]


def test_inline_suppression(root):
    rel, src = VIOLATIONS["int8-overflow"]
    suppressed = textwrap.dedent(src).replace(
        "    return jnp.sum(regs)",
        "    # qlint: disable=int8-overflow (fixture)\n    return jnp.sum(regs)",
    )
    write(root, rel, suppressed)
    report = qlint(root, ["int8-overflow"])
    assert report["ok"] and report["counts"]["baselined"] == 1
    assert rows_for(report, "int8-overflow")[0]["justification"] == (
        "inline suppression"
    )


# ---------------------------------------------------------------------------
# file selection: explicit paths and --changed-only
# ---------------------------------------------------------------------------


def test_selected_paths_narrow_reporting(root):
    write(root, *VIOLATIONS["int8-overflow"])
    write(
        root,
        "src/repro/core/regs_math2.py",
        VIOLATIONS["int8-overflow"][1].replace("total", "total2"),
    )
    report = qlint(
        root, ["int8-overflow"], selected=["src/repro/core/regs_math2.py"]
    )
    paths = {r["path"] for r in rows_for(report, "int8-overflow")}
    assert paths == {"src/repro/core/regs_math2.py"}
    assert report["mode"] == "selected"


def test_changed_only_uses_git(root):
    def git(*args):
        subprocess.run(
            ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
            cwd=root, check=True, capture_output=True,
        )

    write(root, *VIOLATIONS["int8-overflow"])  # committed -> not "changed"
    git("init", "-q")
    git("add", "-A")
    git("commit", "-qm", "seed")
    write(  # untracked -> changed
        root,
        "src/repro/core/regs_new.py",
        VIOLATIONS["int8-overflow"][1].replace("total", "total_new"),
    )
    report = qlint(root, ["int8-overflow"], changed_only=True)
    paths = {r["path"] for r in rows_for(report, "int8-overflow")}
    assert paths == {"src/repro/core/regs_new.py"}


# ---------------------------------------------------------------------------
# CLI: exit codes, JSON report, baseline maintenance flags
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(VIOLATIONS))
def test_cli_exits_nonzero_on_seeded_violation(root, rule, capsys):
    write(root, *VIOLATIONS[rule])
    rc = check_static.main(
        ["--root", str(root), "--rules", rule, "--json", "", "--baseline", ""]
    )
    assert rc == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and f"[{rule}]" in out


def test_cli_json_report_and_update_baseline(root, capsys):
    write(root, *VIOLATIONS["int8-overflow"])
    args = ["--root", str(root), "--rules", "int8-overflow",
            "--json", "report.json", "--baseline", "qlint_baseline.json"]
    assert check_static.main(args) == 1
    report = json.loads((root / "report.json").read_text())
    assert report["tool"] == "qlint" and report["counts"]["new"] == 1

    # --update-baseline grandfathers the finding; the next run is clean.
    assert check_static.main(args + ["--update-baseline"]) == 0
    assert check_static.main(args) == 0

    # Fix the code -> the entry goes stale. A rule-subset run must NOT
    # prune (it cannot tell stale from unexercised); a full run does.
    write(
        root,
        "src/repro/core/regs_math.py",
        VIOLATIONS["int8-overflow"][1].replace(
            "jnp.sum(regs)", "jnp.sum(regs.astype(jnp.int32))"
        ),
    )
    assert check_static.main(args + ["--prune-baseline"]) == 0
    assert len(Baseline(str(root / "qlint_baseline.json")).entries) == 1
    full_args = ["--root", str(root), "--json", "",
                 "--baseline", "qlint_baseline.json"]
    assert check_static.main(full_args + ["--prune-baseline"]) == 0
    assert Baseline(str(root / "qlint_baseline.json")).entries == {}


def test_cli_list_rules(capsys):
    assert check_static.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in (*VIOLATIONS, "bench-schema"):
        assert rule in out


def test_parse_error_becomes_finding(root):
    write(root, "src/repro/core/broken.py", "def oops(:\n")
    ctx = build_context(str(root))
    assert ctx.parse_errors and ctx.parse_errors[0].rule == "parse-error"
    report = qlint(root, ["layering"])
    assert not report["ok"]
    assert rows_for(report, "parse-error")


def test_real_repo_is_clean():
    """The acceptance gate, API-level: the checked-in tree has zero
    non-baselined findings and analyzes well under the 30s budget."""
    report = run_qlint(str(REPO))
    new = [r for r in report["findings"] if not r["baselined"]]
    assert new == [], f"unexpected qlint findings: {new}"
    assert report["elapsed_s"] < 30.0
    assert report["stale_baseline_keys"] == []


# ---------------------------------------------------------------------------
# Lock-in tests for the two suppressed findings in the real tree.
# ---------------------------------------------------------------------------


def test_check_disjoint_rows_raises_cleanly_under_tracing():
    """The baselined jit-purity finding's justification: under jit the
    host-side int() sync in check_disjoint_rows is unreachable because the
    Tracer guard raises first — and eagerly the guard does its real job."""
    from types import SimpleNamespace

    from repro.core.dyn_array import check_disjoint_rows

    a = SimpleNamespace(hists=jnp.array([[1, 0], [0, 0]], jnp.int32))
    b_ok = SimpleNamespace(hists=jnp.array([[0, 0], [2, 0]], jnp.int32))
    b_bad = SimpleNamespace(hists=jnp.array([[3, 0], [0, 0]], jnp.int32))

    check_disjoint_rows(a, b_ok)  # disjoint partitions: no raise
    with pytest.raises(ValueError, match="live in BOTH"):
        check_disjoint_rows(a, b_bad)

    def traced(ha, hb):
        check_disjoint_rows(SimpleNamespace(hists=ha), SimpleNamespace(hists=hb))
        return ha

    with pytest.raises(ValueError, match="under\\s+jit tracing"):
        jax.jit(traced)(a.hists, b_ok.hists)


def test_lm_estimate_f32_semantics():
    """The inline-suppressed int8-overflow site: lm_estimate's registers
    are f32 min-registers (LM baseline), so the un-upcast jnp.sum is
    correct by design. Lock Eq. 2 and the untouched-sketch guard."""
    from repro.core.estimators import lm_estimate

    regs = jnp.array([1.0, 2.0, 3.0, 4.0], jnp.float32)
    np.testing.assert_allclose(float(lm_estimate(regs)), 3.0 / 10.0, rtol=1e-6)

    untouched = jnp.full((8,), jnp.finfo(jnp.float32).max, jnp.float32)
    assert float(lm_estimate(untouched)) == 0.0
    assert lm_estimate(regs).dtype == jnp.float32
