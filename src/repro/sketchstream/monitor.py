"""In-step stream telemetry: a QSketch threaded through train/serve steps,
merged across the mesh by max.

Design choice (vs QSketch-Dyn, documented in DESIGN.md §4.3): the in-step
monitor uses the FULL QSketch construction — every element updates all m
registers — rather than Dyn's one-register-per-element route, because:

  1. Exact mergeability. Dyn's running Ĉ is a per-shard martingale; shards
     that see the same element (token streams always do) can't just add
     their Ĉ's, and the register-histogram MLE fallback is misspecified
     whenever m ≳ n_distinct (an untouched Dyn register means "empty
     sub-stream", probability e^{-n/m}, which the quantized-Exp(C/m)
     likelihood cannot express — it drives the MLE to 0). QSketch registers
     are plain max-monoid elements: merge is exact at any scale.
  2. On TPU the m-wide update is ONE fused VPU kernel over the (batch, m)
     tile (kernels/qsketch_update.py) — at telemetry sizes (m=256) it costs
     ~1e9 integer lane-ops per 1M-token step, noise against the model's
     1e13+ FLOPs. The paper's O(1)-vs-O(m) distinction prices scalar CPUs,
     not 8x128 vector lanes; Dyn's O(1) update stays the right choice for
     the single-stream CPU setting and is benchmarked as such.
  3. Estimation stays O(2^b) via the histogram MLE (beyond-paper trick),
     cheap enough to log every step.

Streams monitored:
  * token coverage:   element = token id, weight 1 (distinct vocab touched)
  * weighted coverage: element = token id, weight supplied by the pipeline
  * MoE routing:      element = expert id, weight = routed prob mass
  * serving DAU:      element = session id, weight = engagement weight

Padding: pipeline tails carry dead rows. ``update`` takes an optional
boolean ``mask`` (same leading shape as ``ids``); masked-off rows neither
touch the sketch nor count toward ``n_seen``.

Per-key telemetry (the multi-tenant upgrade): ``ArrayMonitorState`` tracks K
independent sketches — one per expert / session bucket / flow — via
``core.sketch_array``. One ``update_array`` call folds a whole keyed batch
in a single fused segment scatter-max, and ``estimate_array`` returns all K
weighted cardinalities from one vmapped histogram-MLE. Merge stays the exact
max monoid row-wise, so per-key telemetry crosses the mesh the same way the
single sketch does.

Production scale (this file's third layer): ``ShardedArrayMonitor`` fronts
sparse 64-bit tenant ids with a key directory (collision telemetry, pinned
hot keys — core/key_directory.py) and shards the [K, m] register matrix over
a mesh axis (core/sharded_array.py), the path to K ~ 1e7 tenants. Train and
serve steps thread a ``TelemetryState`` (scalar sketch + tenant array) when
both monitors are on.

Anytime per-tenant reads (fourth layer): ``DynArrayMonitor`` swaps the
register matrix for ``core/dyn_array.py`` — per-key §4.3 martingales make
``estimate`` an O(K) read instead of the O(K·2^b) vmapped Newton. Same
init/update/estimate/merge/metrics surface, so train/serve steps accept
either tenant monitor unchanged.

Time-scoped per-tenant reads (fifth layer): ``WindowMonitor`` backs the same
sparse-key surface with ``core/window_array.py`` — a ring of E epoch
sub-states whose union answers "weighted distinct traffic in the last
w <= E epochs" instead of "since init". ``rotate`` advances the epoch clock
(evicting the oldest epoch and aging cold directory fingerprints on the same
tick), and the windowed estimate vector feeds ``sketchstream/anomaly.py``'s
per-tenant drift scoring — the paper's real-time anomaly-detection loop,
closed (DESIGN.md §8.5).

Sharded anytime / windowed reads (sixth layer): ``ShardedDynMonitor`` and
``ShardedWindowMonitor`` carry the Dyn and Window surfaces past one host —
the per-tenant state shards row-wise over a mesh axis via the shared
sharding layer (``core/sharding.py``, DESIGN.md §8.6) while the directory
telemetry and (for windows) the ring clock stay replicated. Same
init/update/estimate/merge/metrics (+rotate) surface, bit-identical
estimates to their single-host counterparts, so train/serve steps accept
any tenant monitor unchanged.

Register-sharing per-tenant telemetry (seventh layer): ``VirtualDynMonitor``
backs the sparse-key surface with ``core/virtual_dyn_array.py`` — pinned hot
tenants keep exact dedicated Dyn rows while the long tail shares one
physical register pool, cutting per-tail-tenant memory from O(m + 2^b) to
O(1) amortized (DESIGN.md §8.9). Tail reads are noise-cancelled estimates
(not bit-identical to dedicated sketches), so ``estimate`` takes the tenant
keys to read — the tail is never enumerated.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import (
    SketchConfig,
    dyn_array,
    estimation,
    estimators,
    key_directory,
    qsketch,
    sharded_array,
    sharded_dyn_array,
    sharded_window_array,
    sharding,
    sketch_array,
    virtual_dyn_array,
    window_array,
)
from repro.core.key_directory import DirectoryConfig, DirectoryState
from repro.core.types import (
    DynArrayState,
    QSketchState,
    ShardedArrayState,
    ShardedDynArrayState,
    ShardedWindowArrayState,
    SketchArrayState,
    VirtualDynArrayState,
    WindowArrayState,
)
from repro.core.virtual_dyn_array import VirtualConfig
from repro.obs import metrics as obs_metrics

# Declared tenant-telemetry families, labeled by monitor instance kind — the
# five monitor classes (and the ingest-front TenantWindowIngest) publish
# through these instead of each hand-rolling its own dict plumbing.
_M_TENANT_SEEN = obs_metrics.gauge(
    "tenant_elements_seen", "live elements folded across all tenants",
    labels=("monitor",))
_M_TENANT_SLOTS = obs_metrics.gauge(
    "tenant_slots_claimed", "directory slots holding a fingerprint",
    labels=("monitor",))
_M_TENANT_COLLISIONS = obs_metrics.gauge(
    "tenant_collision_rate", "fraction of routed elements that collided",
    labels=("monitor",))
_M_TENANT_WEIGHT = obs_metrics.gauge(
    "tenant_weight_total", "sum of per-tenant anytime estimates",
    labels=("monitor",))
_M_TENANT_WINDOW_WEIGHT = obs_metrics.gauge(
    "tenant_window_weight", "sum of per-tenant windowed anytime estimates",
    labels=("monitor",))
_M_TENANT_WINDOW_EPOCH = obs_metrics.gauge(
    "tenant_window_epoch", "monotone epoch clock of the window ring",
    labels=("monitor",))
_M_VIRTUAL_POOL_LOAD = obs_metrics.gauge(
    "virtual_pool_load_factor", "fraction of shared-pool slots raised",
    labels=("monitor",))
_M_VIRTUAL_POOL_WEIGHT = obs_metrics.gauge(
    "virtual_pool_weight_total", "exact total live tail weight in the pool",
    labels=("monitor",))
_M_VIRTUAL_TAIL_ELEMENTS = obs_metrics.gauge(
    "virtual_tail_elements", "live tail element-occurrences folded",
    labels=("monitor",))

_TENANT_FAMILIES = {
    "tenant_elements_seen": _M_TENANT_SEEN,
    "tenant_slots_claimed": _M_TENANT_SLOTS,
    "tenant_collision_rate": _M_TENANT_COLLISIONS,
    "tenant_weight_total": _M_TENANT_WEIGHT,
    "tenant_window_weight": _M_TENANT_WINDOW_WEIGHT,
    "tenant_window_epoch": _M_TENANT_WINDOW_EPOCH,
    "virtual_pool_load_factor": _M_VIRTUAL_POOL_LOAD,
    "virtual_pool_weight_total": _M_VIRTUAL_POOL_WEIGHT,
    "virtual_tail_elements": _M_VIRTUAL_TAIL_ELEMENTS,
}


def directory_metrics(directory: DirectoryState) -> dict:
    """The two directory-health scalars every tenant surface reports."""
    return {
        "tenant_slots_claimed": jnp.sum(
            (directory.fingerprints != 0).astype(jnp.int32)
        ),
        "tenant_collision_rate": key_directory.collision_rate(directory),
    }


def publish_tenant_metrics(kind: str, values: dict) -> None:
    """Mirror a tenant ``metrics()`` dict into the obs registry.

    Values are jnp scalars; publication converts to host floats, which
    blocks on those (tiny) device values — fine on the host, fatal under a
    trace. Monitor ``metrics()`` is legitimately called INSIDE jitted train
    steps (launch/train_step.py threads it through the logged aux), so this
    no-ops under any active jax trace: the registry then simply reflects
    the last host-side read.
    """
    if not obs_metrics.enabled() or not jax.core.trace_state_clean():
        return
    for name, v in values.items():
        fam = _TENANT_FAMILIES.get(name)
        if fam is not None:
            fam.labels(monitor=kind).set(float(v))


def tenant_metrics(kind: str, n_seen, directory: DirectoryState, **extras) -> dict:
    """The shared tenant ``metrics()`` body: stream counter + directory
    health + per-backend extras, in the fixed key order the monitor layer
    has always reported, published to the registry under ``monitor=kind``.

    The returned values stay jnp scalars (callers inside jit keep tracing;
    host callers pay one tiny sync only if they convert)."""
    out = {"tenant_elements_seen": n_seen, **directory_metrics(directory)}
    out.update(extras)
    publish_tenant_metrics(kind, out)
    return out


class MonitorState(NamedTuple):
    """Scalar stream monitor: one full QSketch + an occurrence counter."""

    regs: jnp.ndarray  # int8[m]
    n_seen: jnp.ndarray  # int32 element counter (occurrences, not distinct)


def init(cfg: SketchConfig) -> MonitorState:
    """Fresh scalar monitor: empty QSketch, zero elements seen."""
    return MonitorState(regs=qsketch.init(cfg).regs, n_seen=jnp.int32(0))


def _flatten(ids, weights, mask):
    if isinstance(ids, tuple):  # sparse 64-bit element ids as a (lo, hi) pair
        lo, hi = ids
        ids = (lo.reshape(-1), hi.reshape(-1))
        n = ids[0].shape[0]
    else:
        ids = ids.reshape(-1)
        n = ids.shape[0]
    w = (
        jnp.ones((n,), jnp.float32)
        if weights is None
        else weights.reshape(-1).astype(jnp.float32)
    )
    mask = None if mask is None else mask.reshape(-1)
    n_live = n if mask is None else jnp.sum(mask.astype(jnp.int32))
    return ids, w, mask, n_live


def update(cfg: SketchConfig, state: MonitorState, ids, weights=None, mask=None) -> MonitorState:
    """Batched full-QSketch update (ids flattened; weight 1.0 if not given).

    ``mask`` (bool, same leading shape as ids) drops padding rows: they are
    no-ops in the sketch AND excluded from the ``n_seen`` occurrence count.
    """
    ids, w, mask, n_live = _flatten(ids, weights, mask)
    st = qsketch.update(cfg, QSketchState(regs=state.regs), ids, w, mask=mask)
    return MonitorState(regs=st.regs, n_seen=state.n_seen + n_live)


def estimate(cfg: SketchConfig, state: MonitorState) -> jnp.ndarray:
    """Weighted cardinality via the O(2^b) histogram MLE
    (``estimation.estimate_hist``, the in-step monitor's full-kind solve)."""
    hist = estimators.histogram(cfg, state.regs)
    return estimation.estimate_hist(cfg, hist, kind="full")


def merge(cfg: SketchConfig, a: MonitorState, b: MonitorState) -> MonitorState:
    """Exact union-stream merge (max monoid) — the cross-pod collective."""
    return MonitorState(regs=jnp.maximum(a.regs, b.regs), n_seen=a.n_seen + b.n_seen)


# ---------------------------------------------------------------------------
# Per-key telemetry: K sketches (experts / session buckets / flows) at once
# ---------------------------------------------------------------------------


class ArrayMonitorState(NamedTuple):
    """Per-key monitor: K QSketch rows + a live-element counter."""

    regs: jnp.ndarray  # int8[K, m]
    n_seen: jnp.ndarray  # int32 live-element counter across all keys


def init_array(cfg: SketchConfig, k: int) -> ArrayMonitorState:
    """Fresh per-key monitor: K empty sketch rows, zero elements seen."""
    return ArrayMonitorState(
        regs=sketch_array.init(cfg, k).regs, n_seen=jnp.int32(0)
    )


def _flatten_keys(keys):
    """Flatten dense-slot or (lo, hi) sparse tenant keys uniformly."""
    if isinstance(keys, tuple):
        lo, hi = keys
        return lo.reshape(-1), hi.reshape(-1)
    return keys.reshape(-1)


def update_array(
    cfg: SketchConfig,
    state: ArrayMonitorState,
    keys,
    ids,
    weights=None,
    mask=None,
    dcfg: DirectoryConfig | None = None,
) -> ArrayMonitorState:
    """One fused keyed update: element i lands in sketch row keys[i].

    keys/ids/weights/mask share a leading shape and are flattened, so MoE
    routing tensors ((batch, experts) ids + prob-mass weights) drop in
    directly.

    With ``dcfg`` set, ``keys`` are sparse 64-bit tenant ids (uint32 array or
    (lo, hi) pair) routed statelessly through the key directory; without it,
    they follow the dense-slot contract in [0, K). Collision telemetry lives
    in ``ShardedArrayMonitor`` — this path stays a single pytree in/out.
    """
    keys = _flatten_keys(keys)
    if dcfg is not None:
        keys = key_directory.route_slots(dcfg, keys)
    ids, w, mask, n_live = _flatten(ids, weights, mask)
    st = sketch_array.update(
        cfg, SketchArrayState(regs=state.regs), keys, ids, w, mask=mask
    )
    return ArrayMonitorState(regs=st.regs, n_seen=state.n_seen + n_live)


def estimate_array(cfg: SketchConfig, state: ArrayMonitorState) -> jnp.ndarray:
    """All K weighted cardinalities: one vmapped histogram-MLE, Ĉ[K]."""
    return sketch_array.estimate_all(cfg, SketchArrayState(regs=state.regs))


def merge_array(cfg: SketchConfig, a: ArrayMonitorState, b: ArrayMonitorState) -> ArrayMonitorState:
    """Row-wise exact union merge across shards/pods."""
    return ArrayMonitorState(
        regs=jnp.maximum(a.regs, b.regs), n_seen=a.n_seen + b.n_seen
    )


# ---------------------------------------------------------------------------
# Mesh-sharded per-tenant telemetry: sparse 64-bit keys, K beyond one host
# ---------------------------------------------------------------------------


class ShardedArrayMonitorState(NamedTuple):
    """Pytree state of a ShardedArrayMonitor (threads through jit/scan/ckpt)."""

    regs: jnp.ndarray  # int8[K, m], row-sharded over the monitor's mesh axis
    directory: DirectoryState  # key-collision telemetry
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class TelemetryState(NamedTuple):
    """Combined sketch state a train/serve step threads when BOTH the scalar
    stream sketch and the per-tenant sharded array are enabled. Either field
    may be an empty dict when that monitor is off — the tuple stays a valid
    pytree for jit/donation/checkpointing either way."""

    scalar: Any  # MonitorState | {}
    tenants: Any  # ShardedArrayMonitorState | {}


class ShardedArrayMonitor:
    """Per-tenant weighted-cardinality telemetry at production K.

    Wraps the three-layer subsystem — key directory (sparse 64-bit tenant ids
    -> slots, collision counters, pinned hot keys), mesh-sharded register
    matrix (core/sharded_array.py), shard-local vmapped estimation — behind
    the same init/update/estimate/merge surface as the scalar monitor, so
    train/serve steps thread ONE more pytree and nothing else.

    The instance is configuration (closed over by jit); all mutable data
    lives in ``ShardedArrayMonitorState``. ``axis`` names the mesh axis the
    rows shard over: ``"sketch"`` on a dedicated monitoring mesh
    (launch/mesh.make_sketch_mesh), or an existing training-mesh axis (e.g.
    ``"data"``) when telemetry rides inside the train step's jit.
    """

    def __init__(self, cfg: SketchConfig, dcfg: DirectoryConfig, mesh, axis: str = sharded_array.AXIS):
        if dcfg.capacity % sharded_array.num_shards(mesh, axis):
            raise ValueError(
                f"directory capacity {dcfg.capacity} must be divisible by the "
                f"'{axis}' axis shard count ({sharded_array.num_shards(mesh, axis)}); "
                "use ShardedArrayMonitor.for_mesh to round it up"
            )
        self.cfg = cfg
        self.dcfg = dcfg
        self.mesh = mesh
        self.axis = axis

    @classmethod
    def for_mesh(cls, cfg: SketchConfig, capacity: int, mesh, *, axis: str = sharded_array.AXIS, seed: int | None = None, pinned: tuple = ()):
        """Build with ``capacity`` rounded up to a shard multiple."""
        cap = sharded_array.padded_k(capacity, mesh, axis)
        dcfg = DirectoryConfig(capacity=cap, seed=cfg.seed if seed is None else seed, pinned=pinned)
        return cls(cfg, dcfg, mesh, axis=axis)

    def init(self) -> ShardedArrayMonitorState:
        """Fresh sharded register matrix + empty directory telemetry."""
        return ShardedArrayMonitorState(
            regs=sharded_array.init(self.cfg, self.dcfg.capacity, self.mesh, axis=self.axis).regs,
            directory=key_directory.init(self.dcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: ShardedArrayMonitorState, tenant_keys, ids, weights=None, mask=None) -> ShardedArrayMonitorState:
        """Fold a keyed batch: tenant_keys are sparse ids (uint32 or (lo, hi)
        pair), flattened together with ids/weights/mask like ``update``."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        st, dir_state = sharded_array.update_tenants(
            self.cfg, self.dcfg, self.mesh,
            ShardedArrayState(regs=state.regs), state.directory,
            keys, ids, w, mask=mask, axis=self.axis,
        )
        return ShardedArrayMonitorState(
            regs=st.regs, directory=dir_state, n_seen=state.n_seen + n_live
        )

    def estimate(self, state: ShardedArrayMonitorState) -> jnp.ndarray:
        """Ĉ[K] — the vmapped Newton runs shard-local, no register gather."""
        return sharded_array.estimate_all(
            self.cfg, self.mesh, ShardedArrayState(regs=state.regs), axis=self.axis
        )

    def merge(self, a: ShardedArrayMonitorState, b: ShardedArrayMonitorState) -> ShardedArrayMonitorState:
        """Cross-pod union: all-max registers, directory telemetry merge."""
        regs = sharded_array.merge(
            ShardedArrayState(regs=a.regs), ShardedArrayState(regs=b.regs)
        ).regs
        return ShardedArrayMonitorState(
            regs=regs,
            directory=key_directory.merge(a.directory, b.directory),
            n_seen=a.n_seen + b.n_seen,
        )

    def metrics(self, state: ShardedArrayMonitorState) -> dict:
        """Cheap per-step scalars (NO estimation): stream + directory health."""
        return tenant_metrics("sharded_array", state.n_seen, state.directory)


# ---------------------------------------------------------------------------
# Anytime per-tenant telemetry: QSketch-Dyn martingales, O(1) per-key reads
# ---------------------------------------------------------------------------


class DynArrayMonitorState(NamedTuple):
    """Pytree state of a DynArrayMonitor (threads through jit/scan/ckpt)."""

    regs: jnp.ndarray  # int8[K, m]
    hists: jnp.ndarray  # int32[K, 2^b] batch-start q_R histograms
    chats: jnp.ndarray  # f32[K] running per-tenant estimates
    directory: DirectoryState  # key-collision telemetry
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class DynArrayMonitor:
    """Per-tenant weighted-cardinality telemetry with O(1)-anytime reads.

    Same surface as ``ShardedArrayMonitor`` (init/update/estimate/merge/
    metrics, sparse 64-bit tenant ids through the key directory) but backed
    by ``core/dyn_array.py``: every update also advances a per-key §4.3
    martingale, so ``estimate`` is a pure O(K) read of the running chats
    instead of the O(K·2^b) vmapped Newton — the right trade at K ~ 1e6
    when estimates are consumed every step (per-tenant DAU dashboards,
    serving-time quota checks), at the cost of a heavier update (per-element
    q_R + histogram maintenance).

    Caveat (DESIGN.md §8.4): the running chats are per-STREAM martingales.
    They are exact across disjoint batches folded into one state, but two
    monitors that may have seen the same element must ``merge`` (register
    max + per-key MLE re-estimate), never add their chats.

    The instance is configuration (closed over by jit); all mutable data
    lives in ``DynArrayMonitorState``.
    """

    def __init__(self, cfg: SketchConfig, dcfg: DirectoryConfig):
        self.cfg = cfg
        self.dcfg = dcfg

    @classmethod
    def for_capacity(cls, cfg: SketchConfig, capacity: int, *, seed: int | None = None, pinned: tuple = ()):
        """Build with a fresh directory config of ``capacity`` slots."""
        dcfg = DirectoryConfig(capacity=capacity, seed=cfg.seed if seed is None else seed, pinned=pinned)
        return cls(cfg, dcfg)

    def init(self) -> DynArrayMonitorState:
        """Fresh DynArray + empty directory telemetry."""
        st = dyn_array.init(self.cfg, self.dcfg.capacity)
        return DynArrayMonitorState(
            regs=st.regs,
            hists=st.hists,
            chats=st.chats,
            directory=key_directory.init(self.dcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: DynArrayMonitorState, tenant_keys, ids, weights=None, mask=None) -> DynArrayMonitorState:
        """Fold a keyed batch: tenant_keys are sparse ids (uint32 or (lo, hi)
        pair), flattened together with ids/weights/mask like ``update``."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        st, dir_state = dyn_array.update_tenants(
            self.cfg, self.dcfg,
            DynArrayState(regs=state.regs, hists=state.hists, chats=state.chats),
            state.directory, keys, ids, w, mask=mask,
        )
        return DynArrayMonitorState(
            regs=st.regs, hists=st.hists, chats=st.chats,
            directory=dir_state, n_seen=state.n_seen + n_live,
        )

    def estimate(self, state: DynArrayMonitorState) -> jnp.ndarray:
        """Ĉ[K] — the anytime read; no Newton, no histogram walk."""
        return dyn_array.estimate_all(
            DynArrayState(regs=state.regs, hists=state.hists, chats=state.chats)
        )

    def merge(self, a: DynArrayMonitorState, b: DynArrayMonitorState) -> DynArrayMonitorState:
        """Cross-pod union: register max, per-key MLE re-estimated chats,
        directory telemetry merge."""
        st = dyn_array.merge(
            self.cfg,
            DynArrayState(regs=a.regs, hists=a.hists, chats=a.chats),
            DynArrayState(regs=b.regs, hists=b.hists, chats=b.chats),
        )
        return DynArrayMonitorState(
            regs=st.regs, hists=st.hists, chats=st.chats,
            directory=key_directory.merge(a.directory, b.directory),
            n_seen=a.n_seen + b.n_seen,
        )

    def metrics(self, state: DynArrayMonitorState) -> dict:
        """Cheap per-step scalars: stream + directory health, plus the total
        tracked weight — an O(K) sum of the anytime estimates, affordable
        every step precisely because no solve is involved."""
        return tenant_metrics(
            "dyn_array", state.n_seen, state.directory,
            tenant_weight_total=jnp.sum(state.chats),
        )


# ---------------------------------------------------------------------------
# Sliding-window per-tenant telemetry: epoch ring, time-scoped estimates
# ---------------------------------------------------------------------------


class WindowMonitorState(NamedTuple):
    """Pytree state of a WindowMonitor (threads through jit/scan/ckpt)."""

    window: WindowArrayState  # epoch ring + cached union (core/window_array)
    directory: DirectoryState  # key-collision telemetry + aging stamps
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class WindowMonitor:
    """Per-tenant SLIDING-WINDOW weighted-cardinality telemetry.

    Same sparse-64-bit-tenant surface as ``DynArrayMonitor`` (init/update/
    estimate/merge/metrics, key-directory routing) backed by
    ``core/window_array.py``: estimates answer "weighted distinct traffic in
    the last w <= E epochs", not "since init" — what a real-time anomaly
    detector consumes. Two extra verbs beyond the shared surface:

    * ``rotate(state)`` — close the current epoch (the caller's clock: every
      N steps / T seconds). Evicts the oldest epoch once the ring is full and
      optionally ages cold directory fingerprints that have not been touched
      for ``evict_after`` epochs (0 disables aging).
    * ``estimate(state, w=None)`` — ``w=None`` is the O(K) anytime read of
      the full-ring window (running union martingales); an integer w is the
      windowed histogram-MLE read over the last w epochs.

    The instance is configuration (closed over by jit); all mutable data
    lives in ``WindowMonitorState``.
    """

    def __init__(self, cfg: SketchConfig, dcfg: DirectoryConfig, n_epochs: int, *, evict_after: int = 0):
        if evict_after < 0:
            raise ValueError("evict_after must be >= 0 (0 disables aging)")
        self.cfg = cfg
        self.dcfg = dcfg
        self.n_epochs = int(n_epochs)
        self.evict_after = int(evict_after)

    @classmethod
    def for_capacity(cls, cfg: SketchConfig, capacity: int, n_epochs: int, *, seed: int | None = None, pinned: tuple = (), evict_after: int = 0):
        """Build with a fresh directory config of ``capacity`` slots."""
        dcfg = DirectoryConfig(capacity=capacity, seed=cfg.seed if seed is None else seed, pinned=pinned)
        return cls(cfg, dcfg, n_epochs, evict_after=evict_after)

    def init(self) -> WindowMonitorState:
        """Fresh epoch ring + empty directory telemetry."""
        return WindowMonitorState(
            window=window_array.init(self.cfg, self.dcfg.capacity, self.n_epochs),
            directory=key_directory.init(self.dcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: WindowMonitorState, tenant_keys, ids, weights=None, mask=None) -> WindowMonitorState:
        """Fold a keyed batch into the CURRENT epoch: tenant_keys are sparse
        ids (uint32 or (lo, hi) pair), flattened together with ids/weights/
        mask like ``update``. Routed slots are stamped with the window's
        epoch clock for directory aging."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        win, dir_state = window_array.update_tenants(
            self.cfg, self.dcfg, state.window, state.directory,
            keys, ids, w, mask=mask,
        )
        return WindowMonitorState(
            window=win, directory=dir_state, n_seen=state.n_seen + n_live
        )

    def rotate(self, state: WindowMonitorState) -> WindowMonitorState:
        """Advance the epoch clock (evicting the oldest epoch once the ring
        is full); age cold directory fingerprints if configured."""
        win = window_array.rotate(self.cfg, state.window)
        directory = state.directory
        if self.evict_after:
            directory, _ = key_directory.evict_older_than(
                self.dcfg, directory, win.epoch_id - self.evict_after
            )
        return WindowMonitorState(
            window=win, directory=directory, n_seen=state.n_seen
        )

    def estimate(self, state: WindowMonitorState, w: int | None = None) -> jnp.ndarray:
        """Ĉ[K] over the trailing window. ``w=None``: the anytime O(K) read
        of the full-ring window; ``w`` an int in [1, E]: the union MLE read
        over the last w epochs."""
        if w is None:
            return window_array.estimate_ring_anytime(state.window)
        return window_array.estimate_window(self.cfg, state.window, w)

    def merge(self, a: WindowMonitorState, b: WindowMonitorState) -> WindowMonitorState:
        """Cross-pod union of ring-aligned windows (pods rotate on a shared
        clock): per-epoch register max + MLE re-estimates, directory merge."""
        return WindowMonitorState(
            window=window_array.merge(self.cfg, a.window, b.window),
            directory=key_directory.merge(a.directory, b.directory),
            n_seen=a.n_seen + b.n_seen,
        )

    def metrics(self, state: WindowMonitorState) -> dict:
        """Cheap per-step scalars: stream + directory health + the window
        clock and the total windowed weight (an O(K) sum of the anytime
        union reads — no solve)."""
        return tenant_metrics(
            "window", state.n_seen, state.directory,
            tenant_window_weight=jnp.sum(state.window.union_chats),
            tenant_window_epoch=state.window.epoch_id,
        )


# ---------------------------------------------------------------------------
# Sharded anytime / windowed per-tenant telemetry: Dyn + Window past one host
# ---------------------------------------------------------------------------


class ShardedDynMonitorState(NamedTuple):
    """Pytree state of a ShardedDynMonitor (threads through jit/scan/ckpt)."""

    array: ShardedDynArrayState  # row-sharded regs/hists/chats
    directory: DirectoryState  # replicated key-collision telemetry
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class ShardedDynMonitor:
    """Per-tenant O(K)-anytime telemetry with the state sharded over a mesh.

    The ``DynArrayMonitor`` surface (init/update/estimate/merge/metrics,
    sparse 64-bit tenant ids through the key directory) backed by
    ``core/sharded_dyn_array.py``: registers, histograms and the running
    martingales all shard row-wise over ``axis``, so K scales with the
    fleet while ``estimate`` stays a pure O(K) read (of the sharded chats).
    Estimates are bit-identical to the single-host ``DynArrayMonitor`` fed
    the same stream.

    The instance is configuration (closed over by jit); all mutable data
    lives in ``ShardedDynMonitorState``.
    """

    def __init__(self, cfg: SketchConfig, dcfg: DirectoryConfig, mesh, axis: str = sharding.AXIS):
        if dcfg.capacity % sharding.num_shards(mesh, axis):
            raise ValueError(
                f"directory capacity {dcfg.capacity} must be divisible by the "
                f"'{axis}' axis shard count ({sharding.num_shards(mesh, axis)}); "
                "use ShardedDynMonitor.for_mesh to round it up"
            )
        self.cfg = cfg
        self.dcfg = dcfg
        self.mesh = mesh
        self.axis = axis

    @classmethod
    def for_mesh(cls, cfg: SketchConfig, capacity: int, mesh, *, axis: str = sharding.AXIS, seed: int | None = None, pinned: tuple = ()):
        """Build with ``capacity`` rounded up to a shard multiple."""
        cap = sharding.padded_k(capacity, mesh, axis)
        dcfg = DirectoryConfig(capacity=cap, seed=cfg.seed if seed is None else seed, pinned=pinned)
        return cls(cfg, dcfg, mesh, axis=axis)

    def init(self) -> ShardedDynMonitorState:
        """Fresh sharded array + empty directory telemetry."""
        return ShardedDynMonitorState(
            array=sharded_dyn_array.init(self.cfg, self.dcfg.capacity, self.mesh, axis=self.axis),
            directory=key_directory.init(self.dcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: ShardedDynMonitorState, tenant_keys, ids, weights=None, mask=None) -> ShardedDynMonitorState:
        """Fold a keyed batch: tenant_keys are sparse ids (uint32 or (lo, hi)
        pair), flattened together with ids/weights/mask like ``update``."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        st, dir_state = sharded_dyn_array.update_tenants(
            self.cfg, self.dcfg, self.mesh, state.array, state.directory,
            keys, ids, w, mask=mask, axis=self.axis,
        )
        return ShardedDynMonitorState(
            array=st, directory=dir_state, n_seen=state.n_seen + n_live
        )

    def estimate(self, state: ShardedDynMonitorState) -> jnp.ndarray:
        """Ĉ[K] — the anytime read of the sharded martingales."""
        return sharded_dyn_array.estimate_all(state.array)

    def merge(self, a: ShardedDynMonitorState, b: ShardedDynMonitorState) -> ShardedDynMonitorState:
        """Cross-pod union of possibly-overlapping streams: register max,
        shard-local per-key MLE re-estimated chats, directory merge."""
        return ShardedDynMonitorState(
            array=sharded_dyn_array.merge(self.cfg, self.mesh, a.array, b.array, axis=self.axis),
            directory=key_directory.merge(a.directory, b.directory),
            n_seen=a.n_seen + b.n_seen,
        )

    def metrics(self, state: ShardedDynMonitorState) -> dict:
        """Cheap per-step scalars: stream + directory health + total tracked
        weight (an O(K) sum of the sharded anytime estimates)."""
        return tenant_metrics(
            "sharded_dyn", state.n_seen, state.directory,
            tenant_weight_total=jnp.sum(state.array.chats),
        )


class ShardedWindowMonitorState(NamedTuple):
    """Pytree state of a ShardedWindowMonitor (threads through jit/scan/ckpt)."""

    window: ShardedWindowArrayState  # sharded epoch ring + union cache
    directory: DirectoryState  # replicated telemetry + aging stamps
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class ShardedWindowMonitor:
    """Per-tenant SLIDING-WINDOW telemetry with the ring sharded over a mesh.

    The ``WindowMonitor`` surface (init/update/rotate/estimate/merge/
    metrics, key-directory routing with epoch-stamped aging) backed by
    ``core/sharded_window_array.py``: every per-tenant leaf of the epoch
    ring and the union cache shards row-wise over ``axis``; the ring clock
    stays replicated so all shards rotate in lockstep. Estimates are
    bit-identical to the single-host ``WindowMonitor`` fed the same stream
    and rotation schedule.

    The instance is configuration (closed over by jit); all mutable data
    lives in ``ShardedWindowMonitorState``.
    """

    def __init__(self, cfg: SketchConfig, dcfg: DirectoryConfig, n_epochs: int, mesh, *, axis: str = sharding.AXIS, evict_after: int = 0):
        if evict_after < 0:
            raise ValueError("evict_after must be >= 0 (0 disables aging)")
        if dcfg.capacity % sharding.num_shards(mesh, axis):
            raise ValueError(
                f"directory capacity {dcfg.capacity} must be divisible by the "
                f"'{axis}' axis shard count ({sharding.num_shards(mesh, axis)}); "
                "use ShardedWindowMonitor.for_mesh to round it up"
            )
        self.cfg = cfg
        self.dcfg = dcfg
        self.n_epochs = int(n_epochs)
        self.mesh = mesh
        self.axis = axis
        self.evict_after = int(evict_after)

    @classmethod
    def for_mesh(cls, cfg: SketchConfig, capacity: int, n_epochs: int, mesh, *, axis: str = sharding.AXIS, seed: int | None = None, pinned: tuple = (), evict_after: int = 0):
        """Build with ``capacity`` rounded up to a shard multiple."""
        cap = sharding.padded_k(capacity, mesh, axis)
        dcfg = DirectoryConfig(capacity=cap, seed=cfg.seed if seed is None else seed, pinned=pinned)
        return cls(cfg, dcfg, n_epochs, mesh, axis=axis, evict_after=evict_after)

    def init(self) -> ShardedWindowMonitorState:
        """Fresh sharded ring + empty directory telemetry."""
        return ShardedWindowMonitorState(
            window=sharded_window_array.init(
                self.cfg, self.dcfg.capacity, self.n_epochs, self.mesh, axis=self.axis
            ),
            directory=key_directory.init(self.dcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: ShardedWindowMonitorState, tenant_keys, ids, weights=None, mask=None) -> ShardedWindowMonitorState:
        """Fold a keyed batch into the CURRENT epoch; routed slots are
        stamped with the window's epoch clock for directory aging."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        win, dir_state = sharded_window_array.update_tenants(
            self.cfg, self.dcfg, self.mesh, state.window, state.directory,
            keys, ids, w, mask=mask, axis=self.axis,
        )
        return ShardedWindowMonitorState(
            window=win, directory=dir_state, n_seen=state.n_seen + n_live
        )

    def rotate(self, state: ShardedWindowMonitorState) -> ShardedWindowMonitorState:
        """Advance the epoch clock shard-locally (evicting the oldest epoch
        once the ring is full); age cold directory fingerprints if
        configured."""
        win = sharded_window_array.rotate(self.cfg, self.mesh, state.window, axis=self.axis)
        directory = state.directory
        if self.evict_after:
            directory, _ = key_directory.evict_older_than(
                self.dcfg, directory, win.epoch_id - self.evict_after
            )
        return ShardedWindowMonitorState(
            window=win, directory=directory, n_seen=state.n_seen
        )

    def estimate(self, state: ShardedWindowMonitorState, w: int | None = None) -> jnp.ndarray:
        """Ĉ[K] over the trailing window. ``w=None``: the O(K) anytime read
        of the sharded union martingales; ``w`` an int in [1, E]: the
        shard-local windowed histogram-MLE read."""
        if w is None:
            return sharded_window_array.estimate_ring_anytime(state.window)
        return sharded_window_array.estimate_window(
            self.cfg, self.mesh, state.window, w, axis=self.axis
        )

    def merge(self, a: ShardedWindowMonitorState, b: ShardedWindowMonitorState) -> ShardedWindowMonitorState:
        """Cross-pod union of ring-aligned sharded windows (pods rotate on a
        shared clock): shard-local register max + MLE re-estimates,
        directory merge."""
        return ShardedWindowMonitorState(
            window=sharded_window_array.merge(self.cfg, self.mesh, a.window, b.window, axis=self.axis),
            directory=key_directory.merge(a.directory, b.directory),
            n_seen=a.n_seen + b.n_seen,
        )

    def metrics(self, state: ShardedWindowMonitorState) -> dict:
        """Cheap per-step scalars: stream + directory health + the window
        clock and the total windowed weight (O(K) sum of the sharded
        anytime union reads)."""
        return tenant_metrics(
            "sharded_window", state.n_seen, state.directory,
            tenant_window_weight=jnp.sum(state.window.union_chats),
            tenant_window_epoch=state.window.epoch_id,
        )


# ---------------------------------------------------------------------------
# Register-sharing per-tenant telemetry: hot rows exact, long tail pooled
# ---------------------------------------------------------------------------


class VirtualDynMonitorState(NamedTuple):
    """Pytree state of a VirtualDynMonitor (threads through jit/scan/ckpt)."""

    array: VirtualDynArrayState  # shared pool + pinned dense hot rows
    n_seen: jnp.ndarray  # int32 live-element counter across all tenants


class VirtualDynMonitor:
    """Per-tenant telemetry where the long tail shares one register pool.

    Same sparse-64-bit-tenant surface as ``DynArrayMonitor`` (init/update/
    estimate/merge/metrics) backed by ``core/virtual_dyn_array.py``: the
    ``vcfg.pinned`` hot tenants keep dedicated dense Dyn rows — their reads
    are the exact anytime martingales, bit-identical to a dedicated
    ``DynArray`` — while every other tenant hashes its registers into one
    shared ``pool_size``-slot pool, so tail memory is O(pool) regardless of
    how many tenants exist. Tail reads are noise-CANCELLED estimates
    (Wang et al. 1811.09126; DESIGN.md §8.9), not exact sub-sketches, with a
    resolution floor of ``noise_floor()`` — the trade that buys the 10-100x
    memory reduction at matched tail accuracy.

    Two surface deltas against the dense monitors, both forced by pooling:

    * ``estimate(state, tenant_keys)`` takes the tenants to read — the tail
      is a hash range, not an enumerable axis, so there is no ``Ĉ[K]``
      vector read of "all" tenants.
    * No ``DirectoryState`` telemetry threads through: tail routing is
      stateless (every unpinned tenant shares one sentinel slot by design),
      so collision counters are meaningless here. ``metrics()`` reports
      pool pressure instead.

    ``promote(state, tenant)`` pins a tail tenant into the hot tier and
    returns a NEW (monitor, state) pair — the pinned set is static
    configuration, so jitted callees recompile once (semantics and residue
    handling: ``virtual_dyn_array.promote``).

    The instance is configuration (closed over by jit); all mutable data
    lives in ``VirtualDynMonitorState``.
    """

    def __init__(self, cfg: SketchConfig, vcfg: VirtualConfig):
        self.cfg = cfg
        self.vcfg = vcfg

    @classmethod
    def for_pool(cls, cfg: SketchConfig, pool_size: int, *, pinned: tuple = (), m_virtual: int | None = None, seed: int | None = None):
        """Build with a fresh virtual config of ``pool_size`` slots."""
        vcfg = VirtualConfig(
            pool_size=pool_size, m_virtual=m_virtual, pinned=pinned,
            seed=cfg.seed if seed is None else seed,
        )
        return cls(cfg, vcfg)

    def init(self) -> VirtualDynMonitorState:
        """Fresh pool + empty hot rows, zero elements seen."""
        return VirtualDynMonitorState(
            array=virtual_dyn_array.init(self.cfg, self.vcfg),
            n_seen=jnp.int32(0),
        )

    def update(self, state: VirtualDynMonitorState, tenant_keys, ids, weights=None, mask=None) -> VirtualDynMonitorState:
        """Fold a keyed batch: tenant_keys are sparse ids (uint32 or (lo, hi)
        pair), flattened together with ids/weights/mask like ``update``."""
        keys = _flatten_keys(tenant_keys)
        ids, w, mask, n_live = _flatten(ids, weights, mask)
        st = virtual_dyn_array.update_tenants(
            self.cfg, self.vcfg, state.array, keys, ids, w, mask=mask
        )
        return VirtualDynMonitorState(array=st, n_seen=state.n_seen + n_live)

    def estimate(self, state: VirtualDynMonitorState, tenant_keys) -> jnp.ndarray:
        """Ŵ[T] for the QUERIED tenants: exact martingale reads for pinned
        tenants, noise-cancelled virtual reads for the tail."""
        return virtual_dyn_array.estimate_tenants(
            self.cfg, self.vcfg, state.array, _flatten_keys(tenant_keys)
        )

    def merge(self, a: VirtualDynMonitorState, b: VirtualDynMonitorState) -> VirtualDynMonitorState:
        """Cross-pod union: pool max + hot-tier dense merge. Exact for
        disjoint shards; overlapping streams inflate ``w_tail`` and the
        tail reads go conservative (``virtual_dyn_array.merge``)."""
        return VirtualDynMonitorState(
            array=virtual_dyn_array.merge(self.cfg, self.vcfg, a.array, b.array),
            n_seen=a.n_seen + b.n_seen,
        )

    def promote(self, state: VirtualDynMonitorState, tenant, *, migrate: bool = False) -> tuple["VirtualDynMonitor", VirtualDynMonitorState]:
        """Pin ``tenant`` into the hot tier: -> (monitor', state'). The old
        monitor/state pair stays valid for already-traced callees; route new
        traffic through the returned pair."""
        vcfg, array = virtual_dyn_array.promote(
            self.cfg, self.vcfg, state.array, tenant, migrate=migrate
        )
        return (
            VirtualDynMonitor(self.cfg, vcfg),
            VirtualDynMonitorState(array=array, n_seen=state.n_seen),
        )

    def metrics(self, state: VirtualDynMonitorState) -> dict:
        """Cheap per-step scalars (NO solve): stream counter, pool pressure
        (load factor, exact pooled weight, tail occurrences) and the hot
        tier's total tracked weight (O(num_hot) sum of exact martingales)."""
        out = {
            "tenant_elements_seen": state.n_seen,
            "virtual_pool_load_factor": virtual_dyn_array.pool_load_factor(state.array),
            "virtual_pool_weight_total": state.array.w_tail,
            "virtual_tail_elements": state.array.n_tail,
            "tenant_weight_total": jnp.sum(state.array.hot.chats),
        }
        publish_tenant_metrics("virtual_dyn", out)
        return out
