"""Hypothesis property tests on the sketch algebra's invariants.

``hypothesis`` is an optional test extra (requirements-test.txt); without it
this module degrades to a skip rather than a collection error.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SketchConfig, baselines, qsketch, qsketch_dyn

_CFG = SketchConfig(m=64, b=8, seed=99)

ids_strategy = st.lists(
    st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=60
)
w_strategy = st.floats(
    min_value=1e-6, max_value=1e6, allow_nan=False, allow_infinity=False
)


def _arrs(ids, ws):
    n = len(ids)
    ws = (ws * ((n // len(ws)) + 1))[:n]
    return (
        jnp.asarray(np.asarray(ids, dtype=np.uint32)),
        jnp.asarray(np.asarray(ws, dtype=np.float32)),
    )


@settings(max_examples=25, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_merge_commutative_associative_idempotent(ids, ws):
    i, w = _arrs(ids, ws)
    half = max(1, len(ids) // 2)
    a = qsketch.update(_CFG, qsketch.init(_CFG), i[:half], w[:half])
    b = qsketch.update(_CFG, qsketch.init(_CFG), i[half:], w[half:]) if len(ids) > half else a
    ab = qsketch.merge(a, b)
    ba = qsketch.merge(b, a)
    np.testing.assert_array_equal(np.asarray(ab.regs), np.asarray(ba.regs))
    # idempotent
    aa = qsketch.merge(a, a)
    np.testing.assert_array_equal(np.asarray(aa.regs), np.asarray(a.regs))
    # associative with a third part
    c = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    l = qsketch.merge(qsketch.merge(a, b), c)
    r = qsketch.merge(a, qsketch.merge(b, c))
    np.testing.assert_array_equal(np.asarray(l.regs), np.asarray(r.regs))


@settings(max_examples=25, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_update_monotone_and_bounded(ids, ws):
    i, w = _arrs(ids, ws)
    st0 = qsketch.init(_CFG)
    st1 = qsketch.update(_CFG, st0, i, w)
    r0 = np.asarray(st0.regs, np.int32)
    r1 = np.asarray(st1.regs, np.int32)
    assert (r1 >= r0).all()
    assert (r1 >= _CFG.r_min).all() and (r1 <= _CFG.r_max).all()


@settings(max_examples=25, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_estimate_nonnegative_finite(ids, ws):
    i, w = _arrs(ids, ws)
    s = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    est = float(qsketch.estimate(_CFG, s))
    assert est >= 0.0
    assert np.isfinite(est)


@settings(max_examples=20, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_batch_split_equivalence(ids, ws):
    i, w = _arrs(ids, ws)
    whole = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    k = max(1, len(ids) // 3)
    parts = qsketch.init(_CFG)
    for s0 in range(0, len(ids), k):
        parts = qsketch.update(_CFG, parts, i[s0 : s0 + k], w[s0 : s0 + k])
    np.testing.assert_array_equal(np.asarray(whole.regs), np.asarray(parts.regs))


@settings(max_examples=20, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_dyn_duplicate_stability(ids, ws):
    i, w = _arrs(ids, ws)
    d1 = qsketch_dyn.update_scan(_CFG, qsketch_dyn.init(_CFG), i, w)
    d2 = qsketch_dyn.update_scan(_CFG, d1, i, w)
    assert float(d1.chat) == float(d2.chat)
    np.testing.assert_array_equal(np.asarray(d1.regs), np.asarray(d2.regs))
    # Histogram counts never exceed m and stay non-negative.
    h = np.asarray(d2.hist)
    assert (h >= 0).all() and h.sum() <= _CFG.m


@settings(max_examples=20, deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_float_sketch_monotone_decreasing(ids, ws):
    i, w = _arrs(ids, ws)
    s0 = baselines.init(_CFG)
    s1 = baselines.lm_update(_CFG, s0, i, w)
    assert (np.asarray(s1.regs) <= np.asarray(s0.regs)).all()
    assert (np.asarray(s1.regs) > 0).all()
