"""ModelConfig: one dataclass describes every assigned architecture.

A model is a stack of layers built from a repeating ``pattern`` of layer
specs (mixer kind + ffn kind + attention flags). The stack is scanned over
pattern repeats ("superblocks") so the HLO stays compact at 398B/1T scale;
a remainder (n_layers % len(pattern)) is applied unscanned.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Mixer = Literal["attn", "mamba", "none"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"
    window: Optional[int] = None  # sliding-window size; None = full attention
    cross_attn: bool = False  # decoder cross-attention (enc-dec models)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    shared_expert: bool = False  # kimi-style always-on shared expert
    d_ff: int = 0  # expert hidden size (0 -> same as cfg.d_ff)
    # Dispatch implementation (§Perf hillclimb knob):
    #   "scatter"      — GSPMD global scatter (baseline; partitioner falls
    #                    back to replicate+all-reduce of the expert buffer)
    #   "shard_map_a2a"— explicit two-hop all-to-all expert parallelism
    impl: str = "scatter"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256  # SSD intra-chunk length L (§Perf: memory ∝ S·L·H)
    conv_width: int = 4
    # dtype of the (B,nc,L,L,H) intra-chunk tensors (§Perf hillclimb knob;
    # the cumsum/exp stay f32 for stability, only the big tensors drop).
    intra_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper): encoder layer count; 0 = decoder-only.
    n_enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder memory length (1500 whisper frames)
    # modality frontend stub: extra embeddings prepended to the token stream.
    frontend: Literal["none", "patches", "frames"] = "none"
    frontend_len: int = 0  # patches per example (llava anyres: 576 base)
    max_seq: int = 8192  # trained context (informational)
    act_dtype: str = "bfloat16"  # activation dtype ("float32" for debug/smoke)
    # True when every attention layer is windowed/ssm (sub-quadratic decode
    # state) — gates the long_500k shape (DESIGN.md shape skips).
    sub_quadratic: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to a TP-and-lane-friendly multiple
        (2048 = 16-way model axis x 128 lanes). A non-divisible vocab would
        otherwise fall back to a REPLICATED embedding/logits — for
        mamba2 (50280) that was 12 GiB of f32 logits per device (§Perf log).
        Padded columns are masked to -inf in the loss and in decode."""
        return -(-self.vocab // 2048) * 2048

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    def layer_specs(self):
        """Per-layer specs for the full stack (pattern repeated + remainder)."""
        reps = self.pattern * self.n_superblocks + self.pattern[: self.n_remainder]
        return reps

    def param_count(self) -> int:
        from . import transformer

        return transformer.count(self)

    def active_param_count(self) -> int:
        from . import transformer

        return transformer.count(self, active_only=True)
