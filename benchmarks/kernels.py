"""Sketch-kernel microbenchmarks: jnp-core vs Pallas-interpret consistency +
block-shape cost model.

Real Pallas wall-times require a TPU; interpret mode executes the kernel
body in Python, so wall-clock there is meaningless. What IS measurable and
transferable from this box:

  * the jitted jnp path's throughput scaling in (batch, m) — XLA:CPU fuses
    the same hash->quantize->reduce pipeline the TPU kernel implements;
  * the kernel's analytic VMEM footprint per BlockSpec choice (the §Perf
    block-shape hillclimb reads these numbers);
  * bitwise agreement between kernel (interpret) and core on every block
    shape tried (correctness gate for the block sweep).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, qsketch
from repro.data import synthetic
from repro.kernels import ops

from . import common


def vmem_bytes(block_b, block_m):
    """Analytic per-invocation VMEM working set of qsketch_update."""
    tile_f32 = block_b * block_m * 4  # e / y tile
    cols = 3 * block_b * 4  # ids_lo, ids_hi, log2w columns
    regs = 2 * block_m * 4  # in + out register blocks
    return tile_f32 + cols + regs


def run(quick=True):
    rows = []
    ids, w, _ = synthetic.stream("gamma", 32768, seed=5)
    ids_j, w_j = jnp.asarray(ids), jnp.asarray(w)

    for m in ([512, 2048] if quick else [512, 2048, 8192]):
        cfg = SketchConfig(m=m, b=8, seed=6)
        st = qsketch.init(cfg)
        upd = jax.jit(lambda s, i, ww: qsketch.update(cfg, s, i, ww))
        t = common.time_fn(upd, st, ids_j, w_j)
        eps = len(ids) / t
        rows.append({"figure": "kernel_core_throughput", "m": m, "mops": eps / 1e6,
                     "lanes_per_elem": m})
        common.csv_row(f"kernels/core_jnp/m{m}", t * 1e6 / len(ids) * 1e0, f"mops={eps/1e6:.2f}")

    # Block-shape sweep: correctness (bitwise) + VMEM model.
    cfg = SketchConfig(m=1024, b=8, seed=7)
    st = qsketch.init(cfg)
    ref = qsketch.update(cfg, st, ids_j[:2048], w_j[:2048])
    for bb, bm in [(64, 128), (128, 256), (256, 512), (512, 1024)]:
        out = ops.qsketch_update_op(cfg, st, ids_j[:2048], w_j[:2048], block_b=bb, block_m=bm, interpret=True)
        ok = bool(np.array_equal(np.asarray(out.regs), np.asarray(ref.regs)))
        vm = vmem_bytes(bb, bm)
        rows.append({"figure": "kernel_blocks", "block_b": bb, "block_m": bm,
                     "bitwise_ok": ok, "vmem_bytes": vm})
        common.csv_row(f"kernels/block_{bb}x{bm}", 0.0, f"bitwise={ok} vmem={vm/1024:.0f}KiB")
        assert ok, (bb, bm)
    common.save("kernels", rows)
    return rows
