"""Logical-axis -> mesh-axis resolution (GSPMD rules for 2- and 3-axis meshes).

The production meshes are ("data","model") = (16,16) and
("pod","data","model") = (2,16,16). Rules:

  * tensor-parallel class (heads, ffn, vocab, experts, d_inner, kv_heads):
      -> "model"
  * fsdp class (embed on weight tensors; batch on activations):
      -> ("pod","data") — whichever of the two exist in the mesh. This is the
      ZeRO-3 axis: GSPMD all-gathers weights at use and reduce-scatters grads.
  * seq class: sequence-parallel KV/state sharding for long-context decode
      -> "model" ONLY when the tensor has no other model-sharded dim.
  * None: replicated.

``kv_heads`` resolves to "model" only when the head count divides the axis
size — otherwise the dimension is left unsharded and the sequence dimension
picks up the "model" axis instead (see attention.kv_cache_defs).
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_FSDP_CLASS = ("pod", "data")
_MODEL_CLASS = {"heads", "kv_heads", "ffn", "vocab", "experts", "d_inner", "moe_ffn"}


def resolve(axes: tuple, mesh: Mesh, dim_sizes: tuple | None = None) -> P:
    """Logical axes tuple -> PartitionSpec valid on this mesh.

    dim_sizes (optional) enables divisibility checks: a logical model-class
    axis whose dim doesn't divide the mesh axis size falls back to None
    (GSPMD could pad, but padded sharding of tiny dims wastes memory and
    produces confusing collectives — explicit is better).
    """
    names = set(mesh.axis_names)
    model_size = mesh.shape.get("model", 1)
    spec = []
    for i, ax in enumerate(axes):
        if ax is None:
            spec.append(None)
        elif ax == "fsdp" or ax == "batch" or ax == "embed":
            present = tuple(a for a in _FSDP_CLASS if a in names)
            if not present:
                spec.append(None)
                continue
            total = 1
            for a in present:
                total *= mesh.shape[a]
            if dim_sizes is not None and dim_sizes[i] % total != 0:
                # Try the largest prefix that divides (e.g. "pod" alone).
                fallback = None
                for k in range(len(present) - 1, 0, -1):
                    tt = 1
                    for a in present[:k]:
                        tt *= mesh.shape[a]
                    if dim_sizes[i] % tt == 0:
                        fallback = present[:k]
                        break
                spec.append(fallback)
            else:
                spec.append(present)
        elif ax in _MODEL_CLASS:
            if "model" not in names:
                spec.append(None)
            elif dim_sizes is not None and dim_sizes[i] % model_size != 0:
                spec.append(None)
            else:
                spec.append("model")
        elif ax == "seq_model":
            spec.append("model" if "model" in names else None)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*spec)


def resolve_with_sizes(axes: tuple, mesh: Mesh, shape: tuple) -> P:
    return resolve(axes, mesh, dim_sizes=shape)


def spec_tree(defs, mesh: Mesh):
    """ParamDef tree -> PartitionSpec tree (divisibility-checked)."""
    from .common import ParamDef, _map_defs

    return _map_defs(defs, lambda d: resolve(d.axes, mesh, d.shape))


def sharding_tree(defs, mesh: Mesh):
    """ParamDef tree -> NamedSharding tree."""
    from .common import _map_defs

    return _map_defs(defs, lambda d: NamedSharding(mesh, resolve(d.axes, mesh, d.shape)))


def constrain(x, mesh: Mesh, *axes):
    """with_sharding_constraint using logical axes; no-op off-mesh."""
    import jax

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(tuple(axes), mesh, x.shape))
    )
