"""Pallas TPU kernel: keyed multi-sketch (SketchArray) register update.

Same hot loop as kernels/qsketch_update.py — regenerate the hash bits for a
(B_blk × M_blk) tile in VMEM, quantize y = floor(log2 w - log2(-ln u)) — but
instead of max-reducing the batch axis into ONE register row, each batch row
is routed to register row ``keys[i]`` of the resident (K × M_blk) output
block:

  grid = (m_block, batch_block), batch innermost ("arbitrary"): the FULL
  K-row register slab for this m_block stays in VMEM while every batch block
  streams through it. Routing is a fori_loop of dynamic-row scatter-maxes —
  max is commutative/associative, so the sequential loop is bit-identical to
  the core's segment scatter (and to K independent single-sketch updates).

Layout: registers on the 128-wide lane axis (M_blk multiple of 128), sketch
rows K on the sublane axis (padded to a multiple of 8), batch ids/weights/keys
as (B, 1) columns. The VMEM budget is the y tile (B_blk × M_blk f32) plus the
(K_pad × M_blk) int32 slab — the ops.py wrapper shrinks M_blk as K grows to
stay inside ~6 MiB.

Padding contracts (enforced by ops.py): padding batch rows carry
log2w = -inf (y clips to r_min -> scatter is a no-op on whatever row their
key routes to) and key 0; padded register rows/cols are sliced off after.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from . import compat

from .qsketch_update import _tile_y

# Smaller default batch tile than the single-sketch kernel: the register slab
# (K_pad x M_blk) shares VMEM with the y tile.
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_M = 512


def _sketch_array_kernel(
    ids_lo_ref, ids_hi_ref, log2w_ref, keys_ref, regs_ref, out_ref, *, block_b, block_m, salt, r_min, r_max
):
    bi = pl.program_id(1)  # batch-block index (innermost)
    mi = pl.program_id(0)  # register-block index

    @pl.when(bi == 0)
    def _init():
        out_ref[...] = regs_ref[...]

    j0 = (mi * block_m).astype(jnp.uint32)
    y = _tile_y(
        ids_lo_ref[...], ids_hi_ref[...], log2w_ref[...], j0, block_m, salt, r_min, r_max
    )
    keys = keys_ref[...]  # (B_blk, 1) int32

    def route(i, _):
        k = jax.lax.dynamic_slice(keys, (i, 0), (1, 1))[0, 0]
        y_row = jax.lax.dynamic_slice(y, (i, 0), (1, block_m))
        out_ref[pl.ds(k, 1), :] = jnp.maximum(out_ref[pl.ds(k, 1), :], y_row)
        return _

    jax.lax.fori_loop(0, block_b, route, None)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "salt", "r_min", "r_max", "interpret")
)
def sketch_array_update_padded(
    ids_lo,
    ids_hi,
    log2w,
    keys,
    regs,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    salt: int,
    r_min: int,
    r_max: int,
    interpret: bool = False,
):
    """Kernel entry on pre-padded operands.

    ids_lo/ids_hi: (B, 1) uint32, B % block_b == 0. Padding rows must carry
      log2w = -inf and key 0.
    log2w: (B, 1) float32.
    keys: (B, 1) int32 in [0, K) — every key must be a valid row of ``regs``.
    regs: (K, M) int32, M % block_m == 0, K a sublane multiple.
    Returns updated (K, M) int32 registers.
    """
    b = ids_lo.shape[0]
    k, m = regs.shape
    grid = (m // block_m, b // block_b)

    kernel = functools.partial(
        _sketch_array_kernel,
        block_b=block_b,
        block_m=block_m,
        salt=salt,
        r_min=r_min,
        r_max=r_max,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((k, block_m), lambda mi, bi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((k, block_m), lambda mi, bi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((k, m), jnp.int32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ids_lo, ids_hi, log2w, keys, regs)
