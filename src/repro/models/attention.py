"""Attention: GQA / MHA, sliding-window, local:global patterns, qk-norm,
cross-attention — with a chunked online-softmax (flash-style) inner loop.

The KV-chunked ``lax.scan`` keeps peak memory at O(S · chunk) instead of
O(S^2): mandatory for the prefill_32k shape and for gemma3's 500k-token
local-layer prefills. Scores/softmax run in f32; everything else follows the
param dtype (bf16 on TPU).

Sharding: q/k/v projections put heads on "model"; the GQA group dim rides
with q heads. For decode caches see ``kv_cache_defs`` — kv_heads shard on
"model" when divisible, otherwise the sequence dim takes "model" (sequence-
parallel cache; GSPMD inserts the softmax-sum all-reduce).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import common
from .common import ParamDef

_NEG = -1e30

# TP axis size the head padding targets (matches the production mesh and the
# kv_cache_def sharding decision below).
_TP = 16


def padded_heads(h: int) -> int:
    """Megatron-style head padding (§Perf): heads that exceed the TP axis but
    don't divide it (llava 56, whisper 20, arctic 56) would force FULLY
    REPLICATED attention under GSPMD (16x compute/memory). Padding to the
    next multiple of 16 wastes <=12.5%/37% lanes instead; padded heads are
    masked to zero before the output projection, so results are unchanged
    (tests/test_perf_variants.py::test_padded_heads_equivalence)."""
    if h > _TP and h % _TP:
        return -(-h // _TP) * _TP
    return h


def defs(cfg, *, cross=False):
    e, dh = cfg.d_model, cfg.head_dim
    hq, hkv = padded_heads(cfg.n_heads), padded_heads(cfg.n_kv_heads)
    d = {
        "wq": ParamDef((e, hq, dh), ("embed", "heads", None)),
        "wk": ParamDef((e, hkv, dh), ("embed", "kv_heads", None)),
        "wv": ParamDef((e, hkv, dh), ("embed", "kv_heads", None)),
        "wo": ParamDef((hq, dh, e), ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        d["q_norm"] = ParamDef((dh,), (None,), init="zeros")
        d["k_norm"] = ParamDef((dh,), (None,), init="zeros")
    return d


def _mask_pad_heads(out, cfg):
    """Zero the padded q-head outputs so wo sees no garbage (and its padded
    rows receive zero gradient).

    Padded-head layout is INTERLEAVED, not appended: q head h belongs to kv
    group h // g_pad at slot h % g_pad, and is real iff its kv group is a
    real kv head AND its slot index < g_real. This keeps every real q head
    attached to its original kv head (a tail-appended layout would remap
    llava's q heads 49-55 from kv 7 to kv 6 and leave kv 7 serving only
    padding)."""
    real = cfg.n_heads
    hq_pad = out.shape[-2]
    if hq_pad == real:
        return out
    hkv_real = cfg.n_kv_heads
    hkv_pad = padded_heads(hkv_real)
    g_real = real // hkv_real
    g_pad = hq_pad // hkv_pad
    hi = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 2)
    ok = ((hi // g_pad) < hkv_real) & ((hi % g_pad) < g_real)
    return jnp.where(ok, out, jnp.zeros((), out.dtype))


def _pick_chunk(t: int, target: int = 1024) -> int:
    """Largest divisor of t that is <= target (KV chunking needs exactness)."""
    if t <= target:
        return t
    for c in range(target, 0, -1):
        if t % c == 0:
            return c
    return t


def _qkv(params, x, cfg, *, rope_sin=None, rope_cos=None, cross_memory=None):
    kv_src = cross_memory if cross_memory is not None else x
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
    k = jnp.einsum("bte,ehd->bthd", kv_src, params["wk"])
    v = jnp.einsum("bte,ehd->bthd", kv_src, params["wv"])
    if "q_norm" in params:  # qwen3-style per-head RMS norm on q/k
        q = common.rms_norm(q, params["q_norm"])
        k = common.rms_norm(k, params["k_norm"])
    if rope_sin is not None:
        q = common.apply_rope(q, rope_sin, rope_cos)
        k = common.apply_rope(k, rope_sin, rope_cos)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window, q_offset=0, kv_valid_len=None):
    """Online-softmax attention, scanning KV in chunks.

    q: (B, S, Hq, D); k/v: (B, T, Hkv, D) with Hq % Hkv == 0.
    window: sliding-window size or None.
    q_offset: absolute position of q[0] (decode: current length).
    kv_valid_len: mask out cache positions >= this (decode with preallocated
      cache); None = all T valid.
    Returns (B, S, Hq, D).
    """
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, dh).astype(jnp.float32)
    scale = dh**-0.5

    chunk = _pick_chunk(t)
    nchunk = t // chunk
    kc = k.reshape(b, nchunk, chunk, hkv, dh)
    vc = v.reshape(b, nchunk, chunk, hkv, dh)
    kc = jnp.moveaxis(kc, 1, 0)  # (nc, B, chunk, Hkv, D)
    vc = jnp.moveaxis(vc, 1, 0)

    q_pos = q_offset + jnp.arange(s)  # (S,)

    def step(carry, inp):
        m, l, acc = carry
        ci, k_i, v_i = inp
        scores = jnp.einsum("bshgd,bchd->bshgc", qg, k_i.astype(jnp.float32)) * scale
        k_pos = ci * chunk + jnp.arange(chunk)  # (chunk,)
        mask = jnp.ones((s, chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        if kv_valid_len is not None:
            mask &= (k_pos < kv_valid_len)[None, :]
        scores = jnp.where(mask[None, :, None, None, :], scores, _NEG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, hkv, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, s, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, s, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(nchunk), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, hq, dh).astype(q.dtype)


def apply(params, x, cfg, spec, *, positions, cross_memory=None, mask_len=None, causal=True):
    """Full-sequence attention (training / prefill). Returns (out, (k, v)).

    spec: the LayerSpec (window / cross_attn flags).
    positions: (S,) absolute positions for RoPE + masking.
    causal: False for encoder self-attention; cross-attention is never causal.
    """
    use_rope = cross_memory is None
    sin = cos = None
    if use_rope:
        sin, cos = common.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q, k, v = _qkv(params, x, cfg, rope_sin=sin, rope_cos=cos, cross_memory=cross_memory)
    out = chunked_attention(
        q,
        k,
        v,
        causal=causal and cross_memory is None,
        window=spec.window,
        q_offset=0,
        kv_valid_len=mask_len,
    )
    out = _mask_pad_heads(out, cfg)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"])
    return y, (k, v)


def decode(params, x, cfg, spec, *, cache_k, cache_v, cur_len, cross_memory=None):
    """Single-token decode. x: (B, 1, E). cache_[kv]: (B, T, Hkv, D).

    Returns (out, new_cache_k, new_cache_v). For cross-attention layers the
    cache holds the (fixed) encoder memory projection and is not updated.
    """
    if cross_memory is not None:
        q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
        out = _mask_pad_heads(
            chunked_attention(q, cache_k, cache_v, causal=False, window=None, kv_valid_len=None),
            cfg,
        )
        return jnp.einsum("bshd,hde->bse", out, params["wo"]), cache_k, cache_v

    pos = jnp.asarray(cur_len, jnp.int32)[None]  # (1,)
    sin, cos = common.rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    q, k, v = _qkv(params, x, cfg, rope_sin=sin, rope_cos=cos)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cur_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cur_len, axis=1)
    out = chunked_attention(
        q,
        cache_k,
        cache_v,
        causal=True,
        window=spec.window,
        q_offset=cur_len,
        kv_valid_len=cur_len + 1,
    )
    out = _mask_pad_heads(out, cfg)
    return jnp.einsum("bshd,hde->bse", out, params["wo"]), cache_k, cache_v


def kv_cache_def(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ParamDef for one layer's K (or V) cache.

    kv_heads shard on "model" when they divide it; otherwise the sequence dim
    takes "model" (sequence-parallel cache — the softmax all-reduce this
    induces is the roofline-visible cost of small-kv GQA at high TP).
    """
    hkv, dh = padded_heads(cfg.n_kv_heads), cfg.head_dim
    return ParamDef(
        (batch, max_len, hkv, dh),
        ("batch", "seq_model", None, None) if hkv % _TP else ("batch", None, "kv_heads", None),
        dtype=dtype,
        init="zeros",
    )
