"""Pallas TPU kernel: QSketch-Dyn batch q_R computation.

q_R(w) = 1 - (1/m) Σ_k T[k] · exp(-w · s_k),  s_k = 2^{-(k + r_min + 1)}

is the per-element update probability (paper §4.3). For a batch of B weights
this is a (B × 2^b) dense exp + a row reduction against the histogram — small
but on the serving hot path (it runs per decoded batch). The kernel keeps the
histogram block resident in VMEM and streams weight blocks through it, fusing
exp/multiply/reduce so the (B × 2^b) intermediate never exists in HBM.

The histogram axis (2^b <= 256) lives on the lane axis padded to 128/256;
weights on sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from . import compat

DEFAULT_BLOCK_B = 512


def _qr_kernel(w_ref, hist_ref, scales_ref, out_ref, *, m):
    w = w_ref[...]  # (B_blk, 1)
    t = hist_ref[...]  # (1, NB)
    s = scales_ref[...]  # (1, NB)
    # exp(-w * s): (B_blk, NB) lives only in VMEM/VREGs.
    expo = jnp.exp(-w * s)
    acc = jnp.sum(t * expo, axis=1, keepdims=True)  # (B_blk, 1)
    out_ref[...] = 1.0 - acc / m


@functools.partial(jax.jit, static_argnames=("m", "block_b", "interpret"))
def qdyn_qr_padded(weights, hist, scales, *, m: int, block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """q_R per weight. weights: (B,1) f32 (B % block_b == 0); hist/scales: (1, NB)
    f32 with NB a multiple of 128 (pad with zero counts)."""
    b = weights.shape[0]
    nb = hist.shape[1]
    kernel = functools.partial(_qr_kernel, m=float(m))
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((1, nb), lambda bi: (0, 0)),
            pl.BlockSpec((1, nb), lambda bi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        compiler_params=compat.CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(weights, hist, scales)
