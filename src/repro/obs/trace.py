"""Span-based stage tracing with Chrome trace-event / Perfetto export.

Spans mark host-side pipeline stages (push/seal/dispatch/retire/rotate/
estimate/solve). Each ``span(name)`` context manager records one Chrome
"complete" event (``ph: "X"``) with microsecond start/duration; nesting is
tracked via ``contextvars`` so a span opened inside another carries its
full ``path`` in the event args and renders nested in Perfetto (load the
saved JSON at https://ui.perfetto.dev or chrome://tracing).

Two rules keep tracing honest in an async-dispatch JAX program:

* **Strictly outside jit.** A span inside a traced region would time the
  *trace*, not the run, and record exactly once. When tracing is enabled,
  ``span`` checks ``jax.core.trace_state_clean()`` and degrades to a no-op
  under any active trace — so host helpers that are occasionally called
  from jitted code stay safe.
* **Host wall-time is not device time.** Dispatch returns before the
  device finishes, so a "dispatch" span measures enqueue cost only. The
  sampled sync hook (``maybe_sync``) closes the gap: every
  ``sync_every``-th tick it runs ``jax.block_until_ready`` under its own
  span, attributing accumulated device time to that point WITHOUT paying a
  pipeline-draining sync on every batch (the tradeoff is documented in
  DESIGN.md §10 — the sampled batch itself loses its overlap).

Disabled (the default), ``span`` returns a shared no-op context manager:
one function call + one branch per instrumentation point.
"""

from __future__ import annotations

import contextvars
import json
import threading
import time

import jax

# Nesting stack of span names for the current (context-local) execution.
_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "qobs_span_stack", default=()
)


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    """One live span: records a Chrome 'X' event on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._token = _STACK.set(_STACK.get() + (self.name,))
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur_ns = time.perf_counter_ns() - self._t0
        stack = _STACK.get()
        _STACK.reset(self._token)
        self._tracer._record(
            self.name, self._t0, dur_ns, "/".join(stack), self.args
        )
        return False


class Tracer:
    """A span recorder: configuration + the accumulated event list."""

    def __init__(self, enabled: bool = False, sync_every: int = 0):
        self._enabled = bool(enabled)
        self.sync_every = int(sync_every)
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()

    @property
    def enabled(self) -> bool:
        """Whether spans record events."""
        return self._enabled

    def configure(self, *, enabled: bool | None = None,
                  sync_every: int | None = None) -> None:
        """Toggle recording and/or set the sampled-sync period (0 = never
        sync; N = block_until_ready every N-th ``maybe_sync`` tick)."""
        if enabled is not None:
            self._enabled = bool(enabled)
        if sync_every is not None:
            self.sync_every = int(sync_every)

    def span(self, name: str, **args):
        """Context manager timing one stage. No-op while disabled or while
        any jax trace is active (see module docstring)."""
        if not self._enabled or not jax.core.trace_state_clean():
            return _NULL
        return _Span(self, name, args)

    def maybe_sync(self, name: str, value, tick: int) -> bool:
        """Sampled device-time attribution: every ``sync_every``-th tick,
        ``block_until_ready(value)`` under a span named ``name`` (with
        ``sampled: True`` in its args). Returns True iff it synced."""
        if (
            not self._enabled
            or self.sync_every <= 0
            or tick % self.sync_every
            or not jax.core.trace_state_clean()
        ):
            return False
        with self.span(name, sampled=True, tick=tick):
            jax.block_until_ready(value)
        return True

    def _record(self, name, t0_ns, dur_ns, path, args) -> None:
        ev = {
            "name": name,
            "cat": "qobs",
            "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,  # µs, Chrome's unit
            "dur": dur_ns / 1e3,
            "pid": 0,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": {"path": path, **args},
        }
        with self._lock:
            self._events.append(ev)

    # -- export -----------------------------------------------------------

    def events(self) -> list[dict]:
        """The recorded Chrome trace events (copy)."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        """Drop all recorded events."""
        with self._lock:
            self._events.clear()

    def chrome_trace(self) -> dict:
        """The full Chrome trace-event JSON object Perfetto loads."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def stage_totals(self) -> dict:
        """Total seconds per span name — the per-stage profile the ingest
        benchmark folds into its cumulative JSON."""
        out: dict[str, float] = {}
        for ev in self.events():
            out[ev["name"]] = out.get(ev["name"], 0.0) + ev["dur"] / 1e6
        return out


_DEFAULT = Tracer()


def default_tracer() -> Tracer:
    """The process-default tracer the library instrumentation targets."""
    return _DEFAULT


def configure(*, enabled: bool | None = None, sync_every: int | None = None) -> None:
    """Configure the default tracer (see ``Tracer.configure``)."""
    _DEFAULT.configure(enabled=enabled, sync_every=sync_every)


def enabled() -> bool:
    """Whether the default tracer records."""
    return _DEFAULT.enabled


def span(name: str, **args):
    """A span on the default tracer (see ``Tracer.span``)."""
    return _DEFAULT.span(name, **args)


def maybe_sync(name: str, value, tick: int) -> bool:
    """Sampled sync on the default tracer (see ``Tracer.maybe_sync``)."""
    return _DEFAULT.maybe_sync(name, value, tick)


def events() -> list[dict]:
    """Events recorded by the default tracer."""
    return _DEFAULT.events()


def clear() -> None:
    """Drop the default tracer's events."""
    return _DEFAULT.clear()


def save(path: str) -> str:
    """Save the default tracer's Chrome trace JSON to ``path``."""
    return _DEFAULT.save(path)


def stage_totals() -> dict:
    """Per-stage total seconds from the default tracer."""
    return _DEFAULT.stage_totals()
