"""Pure-jnp oracles for every Pallas kernel, operand-for-operand identical.

Each function mirrors the corresponding ``*_padded`` kernel entry exactly
(same pre-padded operands, same dtypes, same clipping), so kernel-vs-ref
tests can assert bitwise equality — the hashing is shared integer code, and
floor/log2/exp are required to round identically in interpret mode.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing


def qsketch_update_ref(ids_lo, ids_hi, log2w, regs, *, salt: int, r_min: int, r_max: int):
    """Oracle for qsketch_update_padded: (1, M) int32 updated registers."""
    m = regs.shape[1]
    j = jnp.arange(m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((ids_lo, ids_hi, j[None, :]), salt)  # (B, M)
    y = jnp.floor(log2w - jnp.log2(e))
    y = jnp.clip(y, float(r_min), float(r_max)).astype(jnp.int32)
    return jnp.maximum(regs, jnp.max(y, axis=0, keepdims=True))


def float_sketch_update_ref(ids_lo, ids_hi, w, regs, *, salt: int):
    """Oracle for float_sketch_update_padded: (1, M) float32 registers."""
    m = regs.shape[1]
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    j = jnp.arange(m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((ids_lo, ids_hi, j[None, :]), salt)
    r = jnp.where(w > 0, e / w, big)
    return jnp.minimum(regs, jnp.min(r, axis=0, keepdims=True))


def qdyn_qr_ref(weights, hist, scales, *, m: int):
    """Oracle for qdyn_qr_padded: (B, 1) float32 q_R values."""
    expo = jnp.exp(-weights * scales)  # (B, NB)
    acc = jnp.sum(hist * expo, axis=1, keepdims=True)
    return 1.0 - acc / float(m)
