"""Structural HLO-text analyzer: loop-aware FLOPs / bytes / collective bytes.

Why this exists: ``Compiled.cost_analysis()`` and naive HLO-text scans count
a ``while`` body ONCE, but a scanned transformer executes its superblock
body n times (verified empirically: flops are trip-count-invariant; see
EXPERIMENTS.md §Numerics-notes). This module parses the partitioned HLO
into computations, propagates execution multipliers through the call graph
(ENTRY=1; while bodies x known_trip_count; fusions/calls inherit), and
accumulates:

  * dot_flops   — 2 * prod(result dims) * prod(contracting dims), from the
                  instruction shapes (matmuls dominate these workloads;
                  elementwise transcendentals are ignored -> compute term is
                  a slight underestimate, stated in the report);
  * hbm_bytes   — Σ (operand + result bytes) of top-level ops in sequential
                  computations (ENTRY / loop bodies / branches), fusion
                  internals excluded — the standard coarse HBM-traffic model;
  * coll_bytes  — Σ result bytes of collective ops, by kind.

All values are PER-DEVICE (the input is the SPMD-partitioned module).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_NAME = re.compile(r"^\(?[a-z0-9\[\],{}\s/]*?\)?\s*([a-z][a-z0-9\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|to_apply|calls|condition)=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_dims(text: str):
    m = _SHAPE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    defn: str  # everything right of '='
    op: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_fusion: bool = False


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                name = m.group(2)
                cur = Computation(name=name, is_fusion="fused" in name)
                comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, defn = m.group(1), m.group(2)
        opm = _OP_NAME.match(defn)
        op = opm.group(1) if opm else ""
        cur.instrs.append(Instr(name=name, defn=defn, op=op))
    return comps


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry not in comps:
        return mult
    mult[entry] = 1.0
    # Propagate in passes (call graph is a DAG; few levels deep).
    for _ in range(12):
        changed = False
        snapshot = dict(mult)
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for cname, comp in comps.items():
            m = snapshot.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                called = _CALLED.findall(ins.defn)
                if not called:
                    bm = _BRANCHES.search(ins.defn)
                    if bm:
                        called = _OPERANDS.findall(bm.group(1))
                if not called:
                    continue
                trip = 1.0
                if " while(" in ins.defn or ins.defn.startswith("while("):
                    tm = _TRIP.search(ins.defn)
                    trip = float(tm.group(1)) if tm else 1.0
                for c in called:
                    if c in new:
                        new[c] = new.get(c, 0.0) + m * trip
        new[entry] = 1.0
        if any(abs(new[k] - mult[k]) > 1e-9 for k in mult):
            changed = True
        mult = new
        if not changed:
            break
    return mult


def _find_entry(comps: Dict[str, Computation], hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else next(iter(comps))


def _fusion_param_slice_bytes(comps, fusion_comp: str, param_idx: int):
    """If fusion parameter ``param_idx`` is only consumed via dynamic-slice
    inside the fusion body, return the slice bytes; else None (= count full).
    Caches on the computation object."""
    comp = comps.get(fusion_comp)
    if comp is None:
        return None
    cache = getattr(comp, "_param_slice_cache", None)
    if cache is None:
        cache = {}
        pnames = {}
        for ins in comp.instrs:
            m = re.search(r"parameter\((\d+)\)", ins.defn)
            if m:
                pnames[ins.name] = int(m.group(1))
        # Map param index -> slice bytes if ALL consumers are dynamic-slice.
        consumers: Dict[int, list] = {}
        for ins in comp.instrs:
            if "(" not in ins.defn:
                continue
            for oname in _OPERANDS.findall(ins.defn.split("(", 1)[1]):
                if oname in pnames:
                    consumers.setdefault(pnames[oname], []).append(ins)
        for idx, uses in consumers.items():
            if uses and all(u.op == "dynamic-slice" for u in uses):
                cache[idx] = sum(
                    _shape_bytes(u.defn.split("(", 1)[0]) for u in uses
                )
        comp._param_slice_cache = cache  # type: ignore[attr-defined]
    return cache.get(param_idx)


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = _find_entry(comps, hlo)
    mult = _multipliers(comps, entry)

    # Shape lookup: per-computation first (instruction names can repeat
    # across computations), global as fallback.
    global_shapes: Dict[str, str] = {}
    comp_shapes: Dict[str, Dict[str, str]] = {}
    for comp in comps.values():
        local = {}
        for ins in comp.instrs:
            local[ins.name] = ins.defn
            global_shapes.setdefault(ins.name, ins.defn)
        comp_shapes[comp.name] = local

    dot_flops = 0.0
    hbm_bytes = 0.0
    coll: Dict[str, float] = {}
    unknown_trips = 0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = dict(global_shapes)
        shapes.update(comp_shapes[cname])
        for ins in comp.instrs:
            op = ins.op
            # --- dot flops (counted everywhere, incl. fusion outputs) ---
            if op == "dot":
                dims = _result_dims(ins.defn) or []
                out_elems = 1
                for d in dims:
                    out_elems *= d
                cdim = 1
                cm = _CONTRACT.search(ins.defn)
                ops_ = _OPERANDS.findall(ins.defn.split("dot(", 1)[1])
                if cm and ops_:
                    lhs_shape = _result_dims(shapes.get(ops_[0], "") or "")
                    if lhs_shape:
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(lhs_shape):
                                cdim *= lhs_shape[int(idx)]
                dot_flops += m * 2.0 * out_elems * cdim
            # --- collectives ---
            for kind in COLLECTIVES:
                if op == kind or op == kind + "-start":
                    b = _shape_bytes(ins.defn.split(op + "(", 1)[0])
                    coll[kind] = coll.get(kind, 0.0) + m * b
                    break
            # --- bytes: top-level sequential computations only ---
            # Per-op traffic semantics (avoids the classic scan pitfall where
            # dynamic-slice would count the whole stacked-params array as an
            # operand on EVERY loop iteration):
            #   dynamic-slice / gather:        result bytes only (read slice)
            #   dynamic-update-slice / scatter: 2x update-operand (read+write)
            #   bitcast / reshape / tuple plumbing: free
            #   everything else: operands read + result written
            if not comp.is_fusion and op not in (
                "parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "reshape", "after-all",
            ):
                res = _shape_bytes(ins.defn.split("(", 1)[0] if "(" in ins.defn else ins.defn)
                inner = ins.defn.split("(", 1)[1] if "(" in ins.defn else ""
                onames = _OPERANDS.findall(inner)[:8]

                def obytes(i):
                    if i < len(onames) and onames[i] in shapes:
                        return _shape_bytes(shapes[onames[i]].split("(", 1)[0])
                    return 0

                if op in ("dynamic-slice", "gather"):
                    traffic = 2 * res
                elif op == "dynamic-update-slice":
                    traffic = 2 * obytes(1)
                elif op == "scatter":
                    traffic = 2 * obytes(2) + res  # updates rw + indices-ish
                elif op in ("copy", "transpose", "broadcast"):
                    traffic = 2 * res
                elif op == "fusion":
                    # Operands that the fusion merely dynamic-slices (the
                    # stacked-residual pattern of scanned backward passes)
                    # cost only the slice, not the full buffer.
                    called = _CALLED.findall(ins.defn)
                    traffic = res
                    for i in range(len(onames)):
                        full = obytes(i)
                        sliced = _fusion_param_slice_bytes(comps, called[0] if called else "", i) if full > 2**20 else None
                        traffic += sliced if sliced is not None else full
                elif op in ("dot", "custom-call", "convolution"):
                    # Compute ops genuinely stream operands from HBM.
                    traffic = res + sum(obytes(i) for i in range(len(onames)))
                else:
                    # Elementwise/misc: result write + one read's worth.
                    # Counting every operand of every chained op multiplies
                    # the same buffer through its consumers and over-states
                    # traffic 10-100x on elementwise-heavy (SSD) models.
                    traffic = 2 * res
                hbm_bytes += m * traffic
            if (" while(" in ins.defn or ins.defn.startswith("while(")) and not _TRIP.search(ins.defn):
                unknown_trips += 1

    return {
        "dot_flops": dot_flops,
        "hbm_bytes": hbm_bytes,
        "collective_by_op": coll,
        "collective_bytes": sum(coll.values()),
        "n_computations": len(comps),
        "unknown_trip_whiles": unknown_trips,
    }
