"""Shared benchmark utilities: timing, method registry plumbing, output."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = "experiments/bench"


def save(name: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def time_fn(fn, *args, warmup=2, iters=5):
    """Median wall time of a jitted fn (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def rrmse(estimates, true_c):
    e = np.asarray(estimates, dtype=np.float64)
    return float(np.sqrt(np.mean(((e - true_c) / true_c) ** 2)))


def aare(estimates, trues):
    e = np.asarray(estimates, np.float64)
    t = np.asarray(trues, np.float64)
    return float(np.mean(np.abs(e - t) / np.abs(t)))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)
