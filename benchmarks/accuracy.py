"""Paper Figs. 2-4: estimation accuracy of the 5 methods.

Fig 2/3 analogue: RRMSE vs number of registers m, per weight distribution.
Fig 4 analogue:   RRMSE vs dataset size at fixed m.

Validated claims (EXPERIMENTS.md §Repro):
  * QSketch tracks LM/FastGM/FastExpSketch accuracy at 1/8 the register
    memory (8-bit vs 64-bit registers in the paper; f32 here — see
    baselines.py docstring).
  * All errors scale ~ 1/sqrt(m-2) (the CR bound of Eq. 2).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import METHODS, SketchConfig
from repro.data import synthetic

from . import common


def _run_once(method: str, cfg: SketchConfig, ids, w):
    meth = METHODS[method]
    st = meth["init"](cfg)
    st = meth["update"](cfg, st, jnp.asarray(ids), jnp.asarray(w))
    return float(meth["estimate"](cfg, st))


def sweep_registers(quick=True):
    ms = [64, 256, 1024] if quick else [64, 128, 256, 512, 1024, 2048, 4096]
    n = 20_000 if quick else 50_000
    runs = 20 if quick else 100
    rows = []
    for dist in synthetic.DISTRIBUTIONS:
        for m in ms:
            for method in METHODS:
                ests, trues = [], None
                for r in range(runs):
                    ids, w, true_c = synthetic.stream(dist, n, seed=r)
                    cfg = SketchConfig(m=m, b=8, seed=1000 + r)
                    ests.append(_run_once(method, cfg, ids, w))
                    trues = true_c
                rows.append({
                    "figure": "fig2_3_rrmse_vs_m",
                    "dist": dist,
                    "m": m,
                    "method": method,
                    "rrmse": common.rrmse(ests, trues),
                    "runs": runs,
                    "n": n,
                    "register_bits": METHODS[method]["register_bits"] or 8,
                })
    return rows


def sweep_sizes(quick=True):
    sizes = [100, 1000, 10_000] if quick else [100, 1000, 10_000, 100_000, 1_000_000]
    runs = 20 if quick else 100
    m = 256
    rows = []
    for dist in synthetic.DISTRIBUTIONS:
        for n in sizes:
            for method in METHODS:
                ests, true_c = [], None
                for r in range(runs):
                    ids, w, true_c = synthetic.stream(dist, n, seed=10_000 + r)
                    cfg = SketchConfig(m=m, b=8, seed=50 + r)
                    ests.append(_run_once(method, cfg, ids, w))
                rows.append({
                    "figure": "fig4_rrmse_vs_n",
                    "dist": dist,
                    "n": n,
                    "m": m,
                    "method": method,
                    "rrmse": common.rrmse(ests, true_c),
                    "runs": runs,
                })
    return rows


def run(quick=True):
    rows = sweep_registers(quick) + sweep_sizes(quick)
    common.save("accuracy", rows)
    # Headline CSV: m=256 gamma rows (the paper's main operating point).
    for r in rows:
        if r["figure"] == "fig2_3_rrmse_vs_m" and r["m"] == 256 and r["dist"] == "gamma":
            common.csv_row(f"accuracy/rrmse_m256_gamma/{r['method']}", 0.0, f"rrmse={r['rrmse']:.4f}")
    return rows
