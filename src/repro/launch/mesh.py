"""Production mesh builders (MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the platform/device count at first backend init, and
the dry-run must set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int | None = None):
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))
