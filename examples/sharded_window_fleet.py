"""ShardedDynArray + sharded WindowArray: ONE MILLION tenants, bit-exact.

PR 2's ``distributed_merge.py`` showed the plain register matrix sharding
over 8 devices. This demo does the same for the two newest containers — the
O(K)-anytime DynArray and the sliding-window epoch ring — whose state (per-
key histograms, chats, E epoch planes) is far bigger than registers alone
and is exactly what outgrows one host first. Everything runs at K = 2^20
slots on the 8-device host mesh, and every claim is CHECKED bitwise against
the single-host containers fed the identical stream (DESIGN.md §8.6):

  1. sharded DynArray updates — registers/histograms/chats bit-identical,
     so the O(K)-anytime read is exact while the state lives /8 per device;
  2. key-partitioned fleet merge (``merge_disjoint``) — chats ADD, and an
     overlapping partition is rejected loudly;
  3. sharded WindowArray — updates + rotations (ring wrap = eviction) stay
     bit-identical on every ring/union leaf; windowed MLE reads and the
     anytime union read match the single-host bits; ring-aligned all-max
     pod merge matches too.

b = 4 keeps the demo's histogram planes small (16 bins: the ring histograms
are int32[E, K, 2^b] — the repo's biggest state, and the reason to shard).

    PYTHONPATH=src python examples/sharded_window_fleet.py
    (re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SketchConfig,
    dyn_array,
    sharded_dyn_array,
    sharded_window_array,
    sharding,
    window_array,
)
from repro.launch.mesh import make_sketch_mesh

K = 2**20
E = 4
BATCH = 131_072


def batches(k, n, seed):
    """Uniform keyed gamma-weighted batches (the hard all-tenants regime)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append((
            jnp.asarray(rng.integers(0, k, BATCH, dtype=np.int32)),
            jnp.asarray(rng.integers(0, 2**32, BATCH, dtype=np.uint32)),
            jnp.asarray((rng.gamma(1.0, 2.0, BATCH) + 1e-5).astype(np.float32)),
        ))
    return out


def check(name, a, b):
    if not np.array_equal(np.asarray(a), np.asarray(b)):
        raise AssertionError(f"BIT-IDENTITY FAILED: {name}")
    print(f"    {name}: bit-identical ✓")


def main():
    mesh = make_sketch_mesh()
    n_dev = sharding.num_shards(mesh)
    cfg = SketchConfig(m=64, b=4, seed=7)
    print(f"[fleet] K={K} tenants, {n_dev} shards, m={cfg.m}, b={cfg.b} "
          f"({K // n_dev} rows/device)")

    # -- 1. sharded DynArray: anytime per-tenant estimates, state /8 --------
    print("[fleet] DynArray: 4 x 131k keyed elements into sharded + single-host")
    sh = sharded_dyn_array.init(cfg, K, mesh)
    ref = dyn_array.init(cfg, K)
    t0 = time.time()
    for keys, ids, w in batches(K, 4, seed=1):
        sh = sharded_dyn_array.update_batch(cfg, mesh, sh, keys, ids, w)
        ref = dyn_array.update_batch(cfg, ref, keys, ids, w)
    jax.block_until_ready((sh.chats, ref.chats))
    print(f"    folded in {time.time() - t0:.1f}s")
    check("dyn regs", sh.regs, ref.regs)
    check("dyn hists", sh.hists, ref.hists)
    check("dyn chats (the anytime read)", sh.chats, ref.chats)
    t0 = time.time()
    est = np.asarray(sharded_dyn_array.estimate_all(sh))
    print(f"    anytime read of all {K} tenants: {(time.time()-t0)*1e3:.1f} ms, "
          f"total tracked weight {est.sum():.3e}")

    # -- 2. key-partitioned fleet merge: chats ADD exactly ------------------
    print("[fleet] merge_disjoint: two fleets partitioning the key space")
    keys, ids, w = batches(K, 1, seed=2)[0]
    in_a = keys < K // 2
    fa = sharded_dyn_array.update_batch(
        cfg, mesh, sharded_dyn_array.init(cfg, K, mesh), keys, ids, w, mask=in_a)
    fb = sharded_dyn_array.update_batch(
        cfg, mesh, sharded_dyn_array.init(cfg, K, mesh), keys, ids, w, mask=~in_a)
    merged = sharded_dyn_array.merge_disjoint(cfg, mesh, fa, fb)
    check("disjoint-merged chats == chats_a + chats_b",
          merged.chats, jnp.asarray(np.asarray(fa.chats) + np.asarray(fb.chats)))
    try:
        sharded_dyn_array.merge_disjoint(cfg, mesh, sh, fa)
        raise AssertionError("overlapping partition was NOT rejected")
    except ValueError as e:
        print(f"    overlapping partition rejected ✓ ({str(e)[:58]}...)")

    # -- 3. sharded WindowArray: ring + union, rotations, windowed reads ----
    print(f"[fleet] WindowArray: E={E} ring, {E + 1} epochs (the ring wraps: "
          "eviction on-path)")
    shw = sharded_window_array.init(cfg, K, E, mesh)
    refw = window_array.init(cfg, K, E)
    t0 = time.time()
    for ep in range(E + 1):
        for keys, ids, w in batches(K, 1, seed=100 + ep):
            shw = sharded_window_array.update_batch(cfg, mesh, shw, keys, ids, w)
            refw = window_array.update_batch(cfg, refw, keys, ids, w)
        shw = sharded_window_array.rotate(cfg, mesh, shw)
        refw = window_array.rotate(cfg, refw)
    jax.block_until_ready((shw.union_chats, refw.union_chats))
    print(f"    {E + 1} epochs folded+rotated in {time.time() - t0:.1f}s "
          f"(epoch_id={int(shw.epoch_id)}, ring full)")
    for leaf in ("regs", "hists", "chats", "union_regs", "union_hists", "union_chats"):
        check(f"window {leaf}", getattr(shw, leaf), getattr(refw, leaf))

    for wspan in (1, E // 2, E):
        t0 = time.time()
        got = sharded_window_array.estimate_window(cfg, mesh, shw, wspan)
        jax.block_until_ready(got)
        dt = (time.time() - t0) * 1e3
        check(f"estimate_window(w={wspan}) [{dt:.0f} ms sharded]",
              got, window_array.estimate_window(cfg, refw, wspan))
    t0 = time.time()
    anytime = np.asarray(sharded_window_array.estimate_ring_anytime(shw))
    dt = (time.time() - t0) * 1e3
    check(f"anytime union read [{dt:.1f} ms]",
          anytime, window_array.estimate_ring_anytime(refw))

    # Ring-aligned pod merge: drive a second pod on the same clock.
    print("[fleet] ring-aligned all-max pod merge")
    shw2 = sharded_window_array.init(cfg, K, E, mesh)
    refw2 = window_array.init(cfg, K, E)
    for ep in range(E + 1):
        keys, ids, w = batches(K, 1, seed=500 + ep)[0]
        shw2 = sharded_window_array.update_batch(cfg, mesh, shw2, keys, ids, w)
        refw2 = window_array.update_batch(cfg, refw2, keys, ids, w)
        shw2 = sharded_window_array.rotate(cfg, mesh, shw2)
        refw2 = window_array.rotate(cfg, refw2)
    t0 = time.time()
    pm = sharded_window_array.merge(cfg, mesh, shw, shw2)
    jax.block_until_ready(pm.union_chats)
    print(f"    sharded pod merge in {time.time() - t0:.1f}s")
    pr = window_array.merge(cfg, refw, refw2)
    for leaf in ("regs", "union_hists", "union_chats"):
        check(f"merged {leaf}", getattr(pm, leaf), getattr(pr, leaf))

    print("[fleet] OK — sharded Dyn + Window are bit-exact at K = 2^20; "
          "per-device state is 1/8 of the single-host containers")


if __name__ == "__main__":
    main()
