"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]. Superblock of 6: five local layers
(window 1024) + one global. 62 = 10*6 + 2 -> two unscanned remainder (local)
layers exercise the remainder path. head_dim pinned to 128 (gemma's attn dim
is decoupled from d_model). Mostly-local -> runs long_500k (global-layer KV
at 500k stays linear-per-step for decode; see DESIGN.md shape notes).
"""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    pattern = tuple(
        LayerSpec(window=1024 if i < 5 else None) for i in range(6)
    )
    return ModelConfig(
        name="gemma3-27b",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab=262144,
        d_head=128,
        pattern=pattern,
        rope_theta=1_000_000.0,
        max_seq=131_072,
        sub_quadratic=True,
    )
