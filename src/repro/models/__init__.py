"""Unified model stack for the assigned architectures (DESIGN.md §5)."""

from . import attention, common, config, moe, sharding, ssm, transformer
from .config import LayerSpec, MoEConfig, ModelConfig, SSMConfig

__all__ = [
    "ModelConfig",
    "LayerSpec",
    "MoEConfig",
    "SSMConfig",
    "attention",
    "common",
    "config",
    "moe",
    "sharding",
    "ssm",
    "transformer",
]
