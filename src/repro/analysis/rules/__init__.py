"""qlint rule implementations — importing this package registers them all.

Order here is report order: contract rules first (layering, int8-overflow,
donation-safety, jit-purity, kernel-contract, metric-names), then the
folded-in legacy audits (docstrings, bench-schema).
"""

from repro.analysis.rules import (  # noqa: F401
    layering,
    int8_overflow,
    donation,
    purity,
    kernel_contract,
    metric_names,
    docstrings,
    bench_schema,
)
