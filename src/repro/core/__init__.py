"""Core sketch library: the paper's contribution as composable JAX modules.

Public API:

    cfg   = SketchConfig(m=256, b=8, seed=1)
    state = qsketch.init(cfg)
    state = qsketch.update(cfg, state, ids, weights)   # batched, exact
    chat  = qsketch.estimate(cfg, state)               # MLE (Newton)

    dyn   = qsketch_dyn.init(cfg)
    dyn   = qsketch_dyn.update_batch(cfg, dyn, ids, weights)
    chat  = qsketch_dyn.estimate(dyn)                  # anytime, O(0)

Baselines (LM / FastGM / FastExpSketch) live in ``baselines``; the uniform
``METHODS`` registry below drives benchmarks and examples.
"""

from . import (
    baselines,
    dyn_array,
    estimation,
    estimators,
    hashing,
    key_directory,
    qsketch,
    qsketch_dyn,
    sharded_array,
    sharded_dyn_array,
    sharded_window_array,
    sharding,
    sketch_array,
    virtual_dyn_array,
    window_array,
)
from .key_directory import DirectoryConfig, DirectoryState
from .virtual_dyn_array import VirtualConfig
from .types import (
    DynArrayState,
    DynState,
    FloatSketchState,
    QSketchState,
    ShardedArrayState,
    ShardedDynArrayState,
    ShardedWindowArrayState,
    SketchArrayState,
    SketchConfig,
    VirtualDynArrayState,
    WindowArrayState,
)

# Uniform method registry: name -> dict of the five standard operations.
# Signatures: init(cfg); update(cfg, state, ids, weights, mask=None);
# estimate(cfg, state); merge(cfg, a, b).
METHODS = {
    "LM": dict(
        init=baselines.init,
        update=baselines.lm_update,
        estimate=lambda cfg, s: baselines.estimate(s),
        merge=lambda cfg, a, b: baselines.merge(a, b),
        register_bits=32,
    ),
    "FastGM": dict(
        init=baselines.init,
        update=baselines.fastgm_update,
        estimate=lambda cfg, s: baselines.estimate(s),
        merge=lambda cfg, a, b: baselines.merge(a, b),
        register_bits=32,
    ),
    "FastExpSketch": dict(
        init=baselines.init,
        update=baselines.fastexp_update,
        estimate=lambda cfg, s: baselines.estimate(s),
        merge=lambda cfg, a, b: baselines.merge(a, b),
        register_bits=32,
    ),
    "QSketch": dict(
        init=qsketch.init,
        update=qsketch.update,
        estimate=qsketch.estimate,
        merge=lambda cfg, a, b: qsketch.merge(a, b),
        register_bits=None,  # = cfg.b
    ),
    "QSketch-Dyn": dict(
        init=qsketch_dyn.init,
        update=qsketch_dyn.update_batch,
        estimate=lambda cfg, s: qsketch_dyn.estimate(s),
        merge=qsketch_dyn.merge,
        register_bits=None,  # = cfg.b (+ histogram)
    ),
}

__all__ = [
    "SketchConfig",
    "QSketchState",
    "SketchArrayState",
    "ShardedArrayState",
    "DirectoryConfig",
    "DirectoryState",
    "DynArrayState",
    "DynState",
    "FloatSketchState",
    "WindowArrayState",
    "ShardedDynArrayState",
    "ShardedWindowArrayState",
    "VirtualConfig",
    "VirtualDynArrayState",
    "qsketch",
    "qsketch_dyn",
    "sketch_array",
    "sharded_array",
    "sharded_dyn_array",
    "sharded_window_array",
    "sharding",
    "dyn_array",
    "virtual_dyn_array",
    "window_array",
    "key_directory",
    "baselines",
    "estimation",
    "estimators",
    "hashing",
    "METHODS",
]
