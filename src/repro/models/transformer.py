"""Unified decoder/enc-dec LM assembly for all 10 assigned architectures.

The layer stack is organized as ``n_superblocks`` repeats of ``cfg.pattern``
(scanned; params stacked on a leading axis) plus an unscanned remainder.
Scanning keeps the HLO size O(pattern) instead of O(n_layers) — at jamba-398B
/ kimi-1T scale this is what makes the 512-device dry-run compile tractable.

Three entry points:
  forward      — training/teacher-forcing logits (+ MoE aux losses)
  prefill      — forward that also returns decode caches (KV / SSD states)
  decode_step  — one-token step over preallocated caches

Caches are pytrees shaped like the layer stack: {"sb": {pos: ...}, "rem":
{pos: ...}} with superblock-stacked leading dims so decode scans over them in
lockstep with the params.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, common, moe, sharding, ssm
from .common import ParamDef
from .config import LayerSpec, ModelConfig


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def _dense_ffn_defs(cfg):
    e, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((e, f), ("embed", "ffn")),
        "w_up": ParamDef((e, f), ("embed", "ffn")),
        "w_down": ParamDef((f, e), ("ffn", "embed")),
    }


def _layer_defs(cfg, spec: LayerSpec):
    d: dict = {"mixer_norm": ParamDef((cfg.d_model,), (None,), init="zeros")}
    if spec.mixer == "attn":
        d["mixer"] = attention.defs(cfg)
    elif spec.mixer == "mamba":
        d["mixer"] = ssm.defs(cfg)
    if spec.cross_attn:
        d["cross"] = attention.defs(cfg, cross=True)
        d["cross_norm"] = ParamDef((cfg.d_model,), (None,), init="zeros")
    if spec.ffn == "dense":
        d["ffn"] = _dense_ffn_defs(cfg)
        d["ffn_norm"] = ParamDef((cfg.d_model,), (None,), init="zeros")
    elif spec.ffn == "moe":
        d["ffn"] = moe.defs(cfg)
        d["ffn_norm"] = ParamDef((cfg.d_model,), (None,), init="zeros")
    return d


def _stack(defs_tree, n: int):
    return common._map_defs(
        defs_tree,
        lambda d: ParamDef((n,) + d.shape, (None,) + d.axes, d.dtype, d.init, d.scale),
    )


def model_defs(cfg: ModelConfig):
    e, v = cfg.d_model, cfg.vocab_padded
    d: dict = {"embed": ParamDef((v, e), ("vocab", "embed"), scale=1.0)}
    if cfg.frontend != "none":
        d["front_proj"] = ParamDef((e, e), ("embed", None))
    if cfg.n_enc_layers:
        enc_spec = LayerSpec(mixer="attn", ffn="dense")
        d["enc"] = {
            "blocks": _stack(_layer_defs(cfg, enc_spec), cfg.n_enc_layers),
            "norm": ParamDef((e,), (None,), init="zeros"),
        }
    sb = {str(i): _layer_defs(cfg, s) for i, s in enumerate(cfg.pattern)}
    d["sb"] = _stack(sb, cfg.n_superblocks)
    if cfg.n_remainder:
        d["rem"] = {
            str(i): _layer_defs(cfg, cfg.pattern[i]) for i in range(cfg.n_remainder)
        }
    d["final_norm"] = ParamDef((e,), (None,), init="zeros")
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((e, v), ("embed", "vocab"))
    return d


def count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = common.count_params(model_defs(cfg))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        f = m.d_ff or cfg.d_ff
        expert_params_per_layer = 3 * cfg.d_model * f * m.num_experts
        n_moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
        inactive = n_moe_layers * expert_params_per_layer * (1 - m.top_k / m.num_experts)
        total -= int(inactive)
    return total


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ffn_apply(p, x, cfg, spec, mesh):
    if spec.ffn == "none":
        return x, {}
    h = common.rms_norm(x, p["ffn_norm"])
    if spec.ffn == "dense":
        f = p["ffn"]
        y = common.swiglu(h, f["w_gate"], f["w_up"], f["w_down"])
        aux = {}
    else:
        b, s, e = h.shape
        y, aux = moe.apply(p["ffn"], h.reshape(b * s, e), cfg, mesh)
        y = y.reshape(b, s, e)
    return x + y, aux


def mask_vocab(logits, cfg: ModelConfig):
    """-inf the padded vocab columns (softmax/argmax never pick them)."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    vi = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(vi < cfg.vocab, logits, jnp.asarray(-1e30, logits.dtype))


def _zero_aux(cfg):
    """Aux-loss accumulator structure (must be static across scan steps)."""
    if any(s.ffn == "moe" for s in cfg.pattern):
        return {
            "load_balance": jnp.float32(0.0),
            "router_z": jnp.float32(0.0),
            "drop_fraction": jnp.float32(0.0),
        }
    return {}


def _acc_aux(acc, aux):
    if not acc:
        return acc
    if not aux:
        return acc
    return {k: acc[k] + aux[k] for k in acc}


def block_apply(p, x, cfg, spec, mesh, *, positions, memory=None, causal=True, collect=False):
    """One layer. Returns (x, cache_entry, aux). Dtype-stable in cfg.act_dtype."""
    in_dtype = x.dtype
    h = common.rms_norm(x, p["mixer_norm"])
    cache = {}
    if spec.mixer == "attn":
        y, (k, v) = attention.apply(p["mixer"], h, cfg, spec, positions=positions, causal=causal)
        x = x + y
        if collect:
            cache = {"k": k, "v": v}
    elif spec.mixer == "mamba":
        if collect:
            y, (hst, conv) = ssm.apply(p["mixer"], h, cfg, return_state=True)
            cache = {"h": hst, "conv": conv}
        else:
            y = ssm.apply(p["mixer"], h, cfg)
        x = x + y
    if spec.cross_attn and memory is not None:
        hc = common.rms_norm(x, p["cross_norm"])
        y, (ck, cv) = attention.apply(p["cross"], hc, cfg, spec, positions=positions, cross_memory=memory)
        x = x + y
        if collect:
            cache.update({"ck": ck, "cv": cv})
    x, aux = _ffn_apply(p, x, cfg, spec, mesh)
    return x.astype(in_dtype), cache, aux


def _encoder_forward(params, frames, cfg, mesh):
    enc_spec = LayerSpec(mixer="attn", ffn="dense")
    positions = jnp.arange(frames.shape[1])

    def body(x, p):
        x, _, _ = block_apply(p, x, cfg, enc_spec, mesh, positions=positions, causal=False)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), frames, params["blocks"])
    return common.rms_norm(x, params["norm"])


# ---------------------------------------------------------------------------
# Forward / prefill
# ---------------------------------------------------------------------------


def embed_inputs(params, tokens, cfg, mesh, extra_embeds=None):
    """Token embedding (+ modality-frontend embeddings prepended)."""
    adt = jnp.dtype(cfg.act_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    if extra_embeds is not None and cfg.frontend != "none" and cfg.n_enc_layers == 0:
        fe = jnp.einsum("bpe,ef->bpf", extra_embeds.astype(adt), params["front_proj"]).astype(adt)
        x = jnp.concatenate([fe, x], axis=1)
    if mesh is not None:
        x = sharding.constrain(x, mesh, "batch", None, None)
    return x


def _remat_wrap(body, remat):
    """remat: True/"full" -> save nothing; "dots" -> save matmul outputs
    (recompute elementwise only); False/"none" -> no remat."""
    if remat is True or remat == "full":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(body, policy=jax.checkpoint_policies.dots_saveable)
    return body


def forward(params, tokens, cfg: ModelConfig, mesh=None, *, extra_embeds=None, collect=False, remat=True):
    """Teacher-forcing forward. tokens: (B, S_text).

    extra_embeds: (B, P, E) modality-stub embeddings (llava patches) or
    (B, enc_seq, E) whisper frames (routed to the encoder).
    Returns (logits, caches_or_None, aux).
    """
    memory = None
    if cfg.n_enc_layers:
        memory = _encoder_forward(params["enc"], extra_embeds.astype(jnp.dtype(cfg.act_dtype)), cfg, mesh)
        x = embed_inputs(params, tokens, cfg, mesh)
    else:
        x = embed_inputs(params, tokens, cfg, mesh, extra_embeds)
    seq = x.shape[1]
    positions = jnp.arange(seq)

    def sb_body(carry, p_sb):
        x, aux_acc = carry
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, c, aux = block_apply(
                p_sb[str(i)], x, cfg, spec, mesh, positions=positions, memory=memory, collect=collect
            )
            caches[str(i)] = c
            aux_acc = _acc_aux(aux_acc, aux)
        return (x, aux_acc), caches

    body = _remat_wrap(sb_body, remat)
    (x, aux), sb_caches = jax.lax.scan(body, (x, _zero_aux(cfg)), params["sb"])

    rem_caches = {}
    if cfg.n_remainder:
        for i in range(cfg.n_remainder):
            spec = cfg.pattern[i]
            x, c, aux_i = block_apply(
                params["rem"][str(i)], x, cfg, spec, mesh, positions=positions, memory=memory, collect=collect
            )
            rem_caches[str(i)] = c
            aux = _acc_aux(aux, aux_i)

    x = common.rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bse,ve->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bse,ev->bsv", x, params["lm_head"])
    logits = mask_vocab(logits, cfg)
    caches = {"sb": sb_caches, "rem": rem_caches, "memory": memory} if collect else None
    return logits, caches, aux


def loss_fn(params, batch, cfg: ModelConfig, mesh=None, *, aux_coefs=(0.01, 1e-4), remat=True,
            sharded_xent=False):
    """Token-mean xent + MoE aux. batch: {tokens, targets, [extra_embeds, loss_mask]}."""
    logits, _, aux = forward(
        params, batch["tokens"], cfg, mesh, extra_embeds=batch.get("extra_embeds"), remat=remat
    )
    mask = batch.get("loss_mask")
    targets = batch["targets"]
    if logits.shape[1] != targets.shape[1]:  # frontend prepended P positions
        p = logits.shape[1] - targets.shape[1]
        logits = logits[:, p:]
    if sharded_xent:
        loss = common.softmax_xent_sharded(logits, targets, mesh, mask)
    else:
        loss = common.softmax_xent(logits, targets, mask)
    metrics = {"xent": loss}
    if aux:
        lb = aux["load_balance"] / cfg.n_superblocks if cfg.n_superblocks else aux["load_balance"]
        zl = aux["router_z"] / cfg.n_superblocks if cfg.n_superblocks else aux["router_z"]
        loss = loss + aux_coefs[0] * lb + aux_coefs[1] * zl
        metrics.update({"load_balance": lb, "router_z": zl, "drop_fraction": aux["drop_fraction"] / max(cfg.n_superblocks, 1)})
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: cache defs, prefill, decode
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int):
    """ParamDef tree of decode caches matching the layer stack."""

    def one_layer(spec: LayerSpec):
        d = {}
        if spec.mixer == "attn":
            kv = attention.kv_cache_def(cfg, batch, max_len)
            d.update({"k": kv, "v": kv})
        elif spec.mixer == "mamba":
            d.update(ssm.state_defs(cfg, batch))
        if spec.cross_attn:
            ck = ParamDef(
                (batch, cfg.enc_seq, attention.padded_heads(cfg.n_kv_heads), cfg.head_dim),
                ("batch", None, None, None),
                dtype=jnp.bfloat16,
                init="zeros",
            )
            d.update({"ck": ck, "cv": ck})
        return d

    sb = {str(i): one_layer(s) for i, s in enumerate(cfg.pattern)}
    out = {"sb": _stack(sb, cfg.n_superblocks)}
    if cfg.n_remainder:
        out["rem"] = {str(i): one_layer(cfg.pattern[i]) for i in range(cfg.n_remainder)}
    if cfg.n_enc_layers:
        out["memory"] = ParamDef(
            (batch, cfg.enc_seq, cfg.d_model), ("batch", None, None), dtype=jnp.bfloat16, init="zeros"
        )
    return out


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree of the decode cache (dry-run stand-in)."""
    return common.abstract_params(cache_defs(cfg, batch, max_len))


def _pad_cache_entry(x, max_len):
    """Pad collected K/V (..., S, Hkv, Dh) to the preallocated (..., T, Hkv, Dh).

    The seq axis is ndim-3 (superblock-stacked entries carry a leading NSB
    dim, remainder entries don't — counting from the right is layout-proof).
    """
    ax = x.ndim - 3
    if x.shape[ax] == max_len:
        return x
    pads = [(0, 0)] * x.ndim
    pads[ax] = (0, max_len - x.shape[ax])
    return jnp.pad(x, pads)


def prefill(params, tokens, cfg: ModelConfig, mesh=None, *, max_len: int, extra_embeds=None):
    """Run the prompt, return (last_logits, caches) with K/V padded to max_len."""
    logits, caches, _ = forward(
        params, tokens, cfg, mesh, extra_embeds=extra_embeds, collect=True, remat=False
    )

    def fix(tree):
        out = {}
        for i, entry in tree.items():
            e = dict(entry)
            for key in ("k", "v"):
                if key in e:
                    e[key] = _pad_cache_entry(e[key], max_len)
            out[i] = e
        return out

    # Structure must match cache_defs exactly (pjit out_shardings compare
    # pytree structure): rem/memory keys exist only when the config has them.
    out = {"sb": fix(caches["sb"])}
    if cfg.n_remainder:
        out["rem"] = fix(caches["rem"])
    if cfg.n_enc_layers:
        out["memory"] = caches["memory"]
    return logits[:, -1, : cfg.vocab], out


def decode_step(params, cache, cur_len, tokens, cfg: ModelConfig, mesh=None):
    """One decode step. tokens: (B, 1) int32; cur_len: scalar int32 (tokens
    already in the cache). Returns (logits (B, V), new_cache)."""
    x = embed_inputs(params, tokens, cfg, mesh)
    memory = cache.get("memory")

    def layer_decode(p, c, spec, x):
        new_c = dict(c)
        h = common.rms_norm(x, p["mixer_norm"])
        if spec.mixer == "attn":
            y, nk, nv = attention.decode(
                p["mixer"], h, cfg, spec, cache_k=c["k"], cache_v=c["v"], cur_len=cur_len
            )
            x = x + y
            new_c.update({"k": nk, "v": nv})
        elif spec.mixer == "mamba":
            y, hst, conv = ssm.decode(p["mixer"], h, cfg, h_state=c["h"], conv_tail=c["conv"])
            x = x + y
            new_c.update({"h": hst, "conv": conv})
        if spec.cross_attn and memory is not None:
            hc = common.rms_norm(x, p["cross_norm"])
            y, _, _ = attention.decode(
                p["cross"], hc, cfg, spec, cache_k=c["ck"], cache_v=c["cv"], cur_len=cur_len, cross_memory=memory
            )
            x = x + y
        x, _ = _ffn_apply(p, x, cfg, spec, mesh)
        return x.astype(jnp.dtype(cfg.act_dtype)), new_c

    def sb_body(x, inp):
        p_sb, c_sb = inp
        new_c = {}
        for i, spec in enumerate(cfg.pattern):
            x, new_c[str(i)] = layer_decode(p_sb[str(i)], c_sb[str(i)], spec, x)
        return x, new_c

    x, new_sb = jax.lax.scan(sb_body, x, (params["sb"], cache["sb"]))
    new_cache = dict(cache)
    new_cache["sb"] = new_sb
    if cfg.n_remainder:
        new_rem = {}
        for i in range(cfg.n_remainder):
            x, new_rem[str(i)] = layer_decode(
                params["rem"][str(i)], cache["rem"][str(i)], cfg.pattern[i], x
            )
        new_cache["rem"] = new_rem

    x = common.rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bse,ve->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bse,ev->bsv", x, params["lm_head"])
    return logits[:, 0, : cfg.vocab], new_cache
