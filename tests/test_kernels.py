"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp ref oracle.

Sweeps shapes (block-divisible and ragged), dtypes of ids, and register
widths. The integer kernel must match the oracle BITWISE (shared integer
hashing + identical float ops); the float kernels allclose at f32.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, baselines, qsketch, qsketch_dyn
from repro.kernels import ops, ref

SHAPES = [
    # (batch, m, block_b, block_m)
    (64, 128, 64, 128),
    (256, 512, 128, 256),
    (100, 384, 64, 128),  # ragged batch
    (513, 130, 256, 128),  # ragged both
    (8, 128, 8, 128),  # minimal tile
]


def _stream(n, seed, wscale=1.0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n) * wscale).astype(np.float32) + 1e-5
    return jnp.asarray(ids), jnp.asarray(w)


@pytest.mark.parametrize("batch,m,bb,bm", SHAPES)
@pytest.mark.parametrize("b", [4, 8])
def test_qsketch_kernel_vs_ref(batch, m, bb, bm, b):
    cfg = SketchConfig(m=m, b=b, seed=batch + m)
    ids, w = _stream(batch, seed=batch * 7 + m)
    st = qsketch.init(cfg)
    # Warm the sketch so clipping paths both hit.
    st = qsketch.update(cfg, st, *_stream(batch, seed=1))
    out_kernel = ops.qsketch_update_op(cfg, st, ids, w, block_b=bb, block_m=bm, interpret=True)
    out_core = qsketch.update(cfg, st, ids, w)
    np.testing.assert_array_equal(np.asarray(out_kernel.regs), np.asarray(out_core.regs))


@pytest.mark.parametrize("batch,m,bb,bm", SHAPES)
@pytest.mark.parametrize("wscale", [1e-6, 1.0, 1e6])
def test_float_kernel_vs_ref(batch, m, bb, bm, wscale):
    cfg = SketchConfig(m=m, b=8, seed=batch + 3 * m)
    ids, w = _stream(batch, seed=batch * 3 + m, wscale=wscale)
    st = baselines.init(cfg)
    out_kernel = ops.float_sketch_update_op(cfg, st, ids, w, block_b=bb, block_m=bm, interpret=True)
    out_core = baselines.lm_update(cfg, st, ids, w)
    np.testing.assert_array_equal(np.asarray(out_kernel.regs), np.asarray(out_core.regs))


@pytest.mark.parametrize("batch", [8, 100, 512, 700])
@pytest.mark.parametrize("b", [4, 6, 8])
def test_qr_kernel_vs_ref(batch, b):
    cfg = SketchConfig(m=256, b=b, seed=batch + b)
    ids, w = _stream(2000, seed=batch)
    d = qsketch_dyn.init(cfg)
    d = qsketch_dyn.update_batch(cfg, d, ids, w)
    wq = _stream(batch, seed=batch + 1)[1]
    q_kernel = ops.qdyn_qr_op(cfg, d.hist, wq, interpret=True)
    q_core = qsketch_dyn._q_update_prob(cfg, d.hist, wq)
    np.testing.assert_allclose(np.asarray(q_kernel), np.asarray(q_core), rtol=2e-6, atol=2e-7)


def test_padded_entries_match_ref_oracles():
    """Direct padded-operand comparison against ref.py (both code paths)."""
    from repro.kernels import qsketch_update as K

    rng = np.random.default_rng(0)
    bsz, m = 128, 256
    lo = jnp.asarray(rng.integers(0, 2**32, (bsz, 1), dtype=np.uint32))
    hi = jnp.zeros_like(lo)
    w = jnp.asarray(rng.gamma(1.0, 1.0, (bsz, 1)).astype(np.float32) + 1e-4)
    log2w = jnp.log2(w)
    regs_i = jnp.full((1, m), -127, dtype=jnp.int32)
    regs_f = jnp.full((1, m), np.finfo(np.float32).max, dtype=jnp.float32)

    out_k = K.qsketch_update_padded(
        lo, hi, log2w, regs_i, block_b=64, block_m=128, salt=77, r_min=-127, r_max=127, interpret=True
    )
    out_r = ref.qsketch_update_ref(lo, hi, log2w, regs_i, salt=77, r_min=-127, r_max=127)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    out_kf = K.float_sketch_update_padded(lo, hi, w, regs_f, block_b=64, block_m=128, salt=78, interpret=True)
    out_rf = ref.float_sketch_update_ref(lo, hi, w, regs_f, salt=78)
    np.testing.assert_array_equal(np.asarray(out_kf), np.asarray(out_rf))


def test_kernel_batch_accumulation_order():
    """Multi-batch-block grids must accumulate identically to single-block."""
    cfg = SketchConfig(m=128, b=8, seed=9)
    ids, w = _stream(512, seed=4)
    st = qsketch.init(cfg)
    small = ops.qsketch_update_op(cfg, st, ids, w, block_b=64, block_m=128, interpret=True)
    big = ops.qsketch_update_op(cfg, st, ids, w, block_b=512, block_m=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(small.regs), np.asarray(big.regs))


def test_int8_roundtrip():
    cfg = SketchConfig(m=128, b=8, seed=10)
    ids, w = _stream(64, seed=5)
    out = ops.qsketch_update_op(cfg, qsketch.init(cfg), ids, w, interpret=True)
    assert out.regs.dtype == jnp.int8
    assert int(jnp.min(out.regs)) >= cfg.r_min
    assert int(jnp.max(out.regs)) <= cfg.r_max
