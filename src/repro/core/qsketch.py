"""QSketch (paper §4.2): quantized max-sketch for weighted cardinality.

Update rule per element (x, w), for registers j = 1..m:

    r_j = -ln(h_j(x)) / w            (Exp(w) variable)
    y_j = floor(-log2(r_j))          (quantization, Eq. 5)
    R[j] <- max(R[j], clip(y_j, r_min, r_max))   (Eq. 6)

Because max is commutative/associative, batched updates are *bit-identical*
to the paper's sequential Alg. 2 — the Fisher–Yates + early-stop machinery
only changes the work schedule, never the result (DESIGN.md §4.1). Two
batched schedules are provided:

* ``update``        — direct iid schedule: hash every (element, register)
                      pair, columnwise max. Embarrassingly parallel; this is
                      what the Pallas kernel (kernels/qsketch_update.py)
                      implements for TPU.
* ``update_pruned`` — order-statistics schedule (the TPU-native analogue of
                      the paper's early stop): ONE hash per element bounds its
                      best possible y exactly; elements that cannot touch the
                      sketch are pruned before the expensive m-wide pass. As
                      the sketch saturates the surviving fraction decays like
                      O(m log n / n) — the paper's asymptotic saving, in SIMD
                      form.

``y = floor(-log2 r)`` is computed in the log2 domain as
``floor(log2 w - log2 e_j)`` with ``e_j = -ln h_j(x)``, avoiding the division
and keeping everything inside comfortable f32 range (DESIGN.md §4.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import estimation, estimators, hashing
from .types import QSketchState, SketchConfig


def init(cfg: SketchConfig) -> QSketchState:
    """Fresh QSketch: int8[m] registers at r_min (the empty-sketch value)."""
    return QSketchState(regs=jnp.full((cfg.m,), cfg.r_min, dtype=jnp.int8))


def _quantize(cfg: SketchConfig, log2w, log2e):
    """y' = clip(floor(log2 w - log2 e), r_min, r_max) as int8."""
    y = jnp.floor(log2w - log2e)
    y = jnp.clip(y, float(cfg.r_min), float(cfg.r_max))
    return y.astype(jnp.int8)


def quantized_values(cfg: SketchConfig, ids, weights):
    """The full (B, m) table of quantized values y'_{ij} (iid schedule)."""
    lo, hi = hashing.split_id64(ids)
    j = jnp.arange(cfg.m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((lo[:, None], hi[:, None], j[None, :]), cfg.salt_h)
    log2w = jnp.log2(weights.astype(jnp.float32))[:, None]
    return _quantize(cfg, log2w, jnp.log2(e))


@functools.partial(jax.jit, static_argnums=(0,))
def update(cfg: SketchConfig, state: QSketchState, ids, weights, mask=None) -> QSketchState:
    """Batched exact update: R <- max(R, max_i y'_{ij}).

    ``mask`` (bool[B]) disables padding rows (common in pipeline tails).
    """
    y = quantized_values(cfg, ids, weights)
    if mask is not None:
        y = jnp.where(mask[:, None], y, jnp.int8(cfg.r_min))
    batch_max = jnp.max(y, axis=0)
    return QSketchState(regs=jnp.maximum(state.regs, batch_max))


# ---------------------------------------------------------------------------
# Order-statistics (pruned) schedule
# ---------------------------------------------------------------------------


def _os_sequence(cfg: SketchConfig, lo, hi, weights):
    """Ascending exponential order statistics r_1 < ... < r_m per element.

    FastGM / Alg. 2 recurrence:  r_k = r_{k-1} + e_k / (w * (m - k + 1)),
    e_k iid Exp(1). Vectorized as a cumulative sum over k (axis -1).
    Returns log2(r_k) of shape (B, m).
    """
    m = cfg.m
    k = jnp.arange(m, dtype=jnp.uint32)
    e = hashing.neg_log_uniform((lo[:, None], hi[:, None], k[None, :]), cfg.salt_h)
    gaps = e / (m - jnp.arange(m, dtype=jnp.float32))[None, :]
    r = jnp.cumsum(gaps, axis=-1) / weights.astype(jnp.float32)[:, None]
    return jnp.log2(r)


def _os_first(cfg: SketchConfig, lo, hi, weights):
    """log2 of the smallest order statistic r_1 = e_1/(m*w): one hash."""
    k0 = jnp.zeros_like(lo)
    e1 = hashing.neg_log_uniform((lo, hi, k0), cfg.salt_h)
    return jnp.log2(e1 / (cfg.m * weights.astype(jnp.float32)))


def _random_positions(cfg: SketchConfig, lo, hi):
    """A uniform random permutation of registers per element.

    Replaces Fisher–Yates: argsort of per-(element, slot) hash keys. Ties are
    broken by slot index (keys are 32-bit; collisions only perturb toward a
    near-uniform permutation, which the statistical tests bound).
    """
    k = jnp.arange(cfg.m, dtype=jnp.uint32)
    keys = hashing.hash_words((lo[:, None], hi[:, None], k[None, :]), cfg.salt_perm)
    return jnp.argsort(keys, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(0,))
def update_pruned(cfg: SketchConfig, state: QSketchState, ids, weights, mask=None) -> QSketchState:
    """Exact update with batch-level pruning (the paper's early stop, SIMD form).

    Phase 1 (cheap): y_best(i) = floor(-log2 r_1(i)) from ONE hash. If
    y_best <= min_j R[j], element i cannot raise any register — drop it.
    Phase 2: surviving elements generate the full ascending sequence, map the
    k-th smallest r (= k-th largest y) to a random register, and scatter-max.

    The (r_k, position) joint law equals the iid law, so the resulting sketch
    *distribution* matches ``update`` exactly (statistically — not bitwise,
    since the randomness is consumed differently; tests/test_qsketch.py checks
    distributional equality).
    """
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    min_reg = jnp.min(state.regs).astype(jnp.float32)

    y_best = jnp.floor(-_os_first(cfg, lo, hi, w))
    alive = y_best > min_reg
    if mask is not None:
        alive = alive & mask

    # Phase 2 runs on all rows but dead rows contribute r_min (no-ops in max).
    log2r = _os_sequence(cfg, lo, hi, w)  # ascending r -> descending y
    y = _quantize(cfg, 0.0, log2r)  # log2w folded into r already
    y = jnp.where(alive[:, None], y, jnp.int8(cfg.r_min))
    pos = _random_positions(cfg, lo, hi)

    flat_pos = pos.reshape(-1)
    flat_y = y.reshape(-1)
    regs = state.regs.astype(jnp.int32)
    regs = regs.at[flat_pos].max(flat_y.astype(jnp.int32))
    return QSketchState(regs=regs.astype(jnp.int8))


def prune_mask(cfg: SketchConfig, state: QSketchState, ids, weights):
    """Standalone phase-1 prune test (used by the throughput benchmark to
    compact batches with ``jnp.where``/gather before the m-wide phase)."""
    lo, hi = hashing.split_id64(ids)
    y_best = jnp.floor(-_os_first(cfg, lo, hi, weights.astype(jnp.float32)))
    return y_best > jnp.min(state.regs).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Estimation + algebra
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate(cfg: SketchConfig, state: QSketchState, *, solver: str = "newton"):
    """MLE estimate Ĉ (paper §4.2) — O(m) bincount + O(2^b) solve.

    Thin shim over ``estimation.estimate_hist(kind="full")``; ``solver``
    picks newton / lut (DESIGN.md §8.7).
    """
    hist = estimators.histogram(cfg, state.regs)
    return estimation.estimate_hist(cfg, hist, kind="full", solver=solver)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_with_ci(cfg: SketchConfig, state: QSketchState, *, solver: str = "newton"):
    """(Ĉ, approximate stddev) via the observed-Fisher variance (paper §4.2)."""
    hist = estimators.histogram(cfg, state.regs)
    return estimation.estimate_hist_with_ci(cfg, hist, kind="full", solver=solver)


def merge(a: QSketchState, b: QSketchState) -> QSketchState:
    """Union-stream sketch: element-wise max (commutative monoid)."""
    return QSketchState(regs=jnp.maximum(a.regs, b.regs))
