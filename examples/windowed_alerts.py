"""Real-time per-tenant anomaly alerts over sliding-window weighted
cardinality — the paper's motivating application, end to end.

A monitored edge sees (tenant, flow id, flow size) packets. The signal an
anomaly detector wants is TIME-SCOPED distinct weighted traffic: "how much
distinct flow volume did tenant t generate in the last W epochs?" — a
distinct-flow flood (many fresh flows, normal per-flow sizes) barely moves a
byte counter but explodes exactly this number. The pipeline, per epoch:

  packets -> WindowMonitor.update   (fused keyed update, current epoch ring
                                     slot + cached union, key-directory
                                     routed sparse 64-bit tenant ids)
  estimate = monitor.estimate(st)   (O(K) anytime read of the full-ring
                                     window — no solve, every epoch)
  bank, scores = anomaly.step(...)  (per-tenant EWMA baseline + CUSUM drift)
  alerts = anomaly.top_alerts(...)  (ranked alert set)
  st = monitor.rotate(st)           (oldest epoch evicted; cold directory
                                     fingerprints aged on the same clock)

Traffic is ``synthetic.netflow_keyed`` (Zipf tenants, Zipf flows, lognormal
sizes). Mid-run, one mid-rank tenant is hit with a distinct-flow flood; it
must surface in the top-5 ranked alerts while no baseline tenant
false-positives — at K = 2^14 directory slots.

    PYTHONPATH=src python examples/windowed_alerts.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, key_directory
from repro.data import synthetic
from repro.sketchstream import anomaly, monitor


def main():
    cfg = SketchConfig(m=64, b=8, seed=11)
    capacity = 2**14  # K: tenant slots (sparse 64-bit ids hash into these)
    n_tenants, n_flows = 48, 20000
    n_epochs, window = 16, 6  # ring of E = 6 epochs
    packets_per_epoch = 30000
    spike_epoch, spike_packets = 13, 4000

    mon = monitor.WindowMonitor.for_capacity(cfg, capacity, window, evict_after=window)
    bcfg = anomaly.AnomalyConfig(
        warmup=window + 2,  # cover the ring fill: every window grows then
        min_weight=2000.0,  # ignore dust tenants (windowed MLE noise floor)
        cusum_h=8.0,
    )

    rng = np.random.default_rng(7)
    tenant_ids = rng.integers(0, 2**64, n_tenants, dtype=np.uint64)
    spike_tenant = 11  # mid-rank: neither the whale nor dust

    # One long keyed stream, sliced into epochs.
    keys, flows, sizes, _ = synthetic.netflow_keyed(
        n_tenants, n_flows, n_epochs * packets_per_epoch, seed=3
    )

    st = mon.init()
    bank = anomaly.init(capacity)
    slots = np.asarray(
        key_directory.route_slots(mon.dcfg, key_directory.split_uint64(tenant_ids))
    )
    spike_slot = int(slots[spike_tenant])

    print(f"{n_tenants} tenants over K={capacity} slots, ring E={window}, "
          f"{packets_per_epoch} packets/epoch; flood hits tenant "
          f"{spike_tenant} (slot {spike_slot}) at epoch {spike_epoch}")
    print(f"{'epoch':>5} {'window est.':>12} {'read µs':>8}  ranked alerts (slot:score)")

    false_positive = spiked = False
    for ep in range(n_epochs):
        lo = ep * packets_per_epoch
        ep_keys = keys[lo : lo + packets_per_epoch]
        ep_flows = flows[lo : lo + packets_per_epoch]
        ep_sizes = sizes[lo : lo + packets_per_epoch]
        if ep == spike_epoch:
            # Distinct-flow flood: fresh flow ids, ordinary sizes. A byte
            # counter barely notices; distinct weighted cardinality explodes.
            ep_keys = np.concatenate([ep_keys, np.full(spike_packets, spike_tenant, np.int32)])
            ep_flows = np.concatenate([
                ep_flows,
                rng.integers(0, 2**32, spike_packets, dtype=np.uint32),
            ])
            ep_sizes = np.concatenate([
                ep_sizes,
                np.clip(rng.lognormal(6.0, 1.0, spike_packets), 40, 65535).astype(np.float32),
            ])

        st = mon.update(
            st,
            key_directory.split_uint64(tenant_ids[ep_keys]),
            jnp.asarray(ep_flows),
            jnp.asarray(ep_sizes),
        )

        # Drain the async epoch update first so the timed read is the read.
        jax.block_until_ready(st.window.union_chats)
        t0 = time.perf_counter()
        est = np.asarray(mon.estimate(st))  # O(K) anytime full-ring read
        read_us = (time.perf_counter() - t0) * 1e6
        bank, scores = anomaly.step(bcfg, bank, est)
        alerts = anomaly.top_alerts(bcfg, scores, n=5)

        tag = " ".join(f"{s}:{sc:.1f}" for s, sc in alerts) or "-"
        print(f"{ep:>5} {est.sum():>12,.0f} {read_us:>8.1f}  {tag}")

        alert_slots = [s for s, _ in alerts]
        if ep >= spike_epoch and spike_slot in alert_slots:
            spiked = True
        if any(s != spike_slot for s in alert_slots):
            false_positive = True
        st = mon.rotate(st)

    print()
    m = mon.metrics(st)
    print(f"directory: {int(m['tenant_slots_claimed'])} slots claimed after aging, "
          f"collision rate {float(m['tenant_collision_rate']):.4%}")
    print(f"flood tenant flagged in top-5: {spiked}; "
          f"baseline false positives: {false_positive}")
    if not spiked or false_positive:
        raise SystemExit("anomaly acceptance check FAILED")
    print("acceptance check OK: flood flagged, zero baseline false positives")


if __name__ == "__main__":
    main()
