"""Sketch-based streaming telemetry for training/serving (DESIGN.md §2)."""

from . import monitor

__all__ = ["monitor"]
