"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and writes
JSON result files under experiments/bench/. ``--full`` runs the paper-scale
sweeps (much slower); default is the quick profile used by bench_output.txt.

  python -m benchmarks.run [--full] [--only accuracy,throughput,...]
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", default="", help="comma list of benchmark names")
    args = ap.parse_args()

    from . import (
        accuracy,
        batch_bias,
        kernels,
        netflow,
        register_size,
        sketch_array,
        throughput,
    )

    suite = {
        "accuracy": accuracy.run,  # Figs 2-4
        "register_size": register_size.run,  # Fig 5 / Thm 1
        "throughput": throughput.run,  # Figs 6-8
        "batch_bias": batch_bias.run,  # beyond-paper
        "netflow": netflow.run,  # App A.4 (CAIDA analogue)
        "kernels": kernels.run,  # kernel block sweep + core throughput
        "sketch_array": sketch_array.run,  # fused K-sketch vs naive loop
    }
    only = [s for s in args.only.split(",") if s]
    names = only or list(suite)

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        print(f"# --- {name} ---", flush=True)
        t = time.time()
        suite[name](quick=not args.full)
        print(f"# {name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
