"""Registry exporters: Prometheus text format and JSONL snapshots.

Two sinks, both pull-free (this repo has no HTTP server dependency and
adds none):

* ``prometheus_text(registry)`` renders the classic Prometheus exposition
  format (text/plain version 0.0.4): ``# HELP`` / ``# TYPE`` per family,
  one line per series, histograms expanded to cumulative
  ``_bucket{le=...}`` lines plus ``_sum`` / ``_count``. Write it to a file
  (``write_prometheus``) and let node_exporter's textfile collector — or a
  test's golden comparison — pick it up.
* ``JsonlWriter`` appends one JSON object per ``write()`` call to a
  ``.jsonl`` file: ``{"ts": <unix seconds>, "metrics": {series: value}}``
  plus any caller-supplied extras (step number, health report). Delta mode
  reports per-interval change, which is what a training-loop log wants.

Both render from a registry snapshot on the host; with the registry
disabled the snapshot is empty and the writers emit empty payloads rather
than erroring, so ``--obs-jsonl`` composes with ``QOBS_DISABLED``.
"""

from __future__ import annotations

import json
import time

from repro.obs import metrics as obs_metrics
from repro.obs.metrics import Registry


def _fmt(v) -> str:
    """Prometheus sample value: integers render bare, floats as repr."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == float("-inf"):
        return "-Inf"
    return repr(f)


def _labels(pairs: dict) -> str:
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in pairs.items())
    return "{" + inner + "}"


def _fmt_le(bound: float) -> str:
    if bound == float("inf"):
        return "+Inf"
    return repr(bound) if bound != int(bound) else str(int(bound))


def prometheus_text(registry: Registry | None = None) -> str:
    """Render every family of ``registry`` (default: the process default)
    in Prometheus text exposition format. Histogram buckets are emitted
    cumulatively per the format's contract (our storage is per-bucket)."""
    reg = registry if registry is not None else obs_metrics.default_registry()
    if not reg.enabled:
        return ""
    lines: list[str] = []
    for fam in reg.families():
        series = fam.series()
        if not series:
            continue
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for s in series:
            if fam.kind == "histogram":
                payload = s.read()
                cum = 0
                for bucket_n, le in zip(payload["buckets"], payload["le"]):
                    cum += bucket_n
                    lbl = _labels({**s.labels, "le": _fmt_le(le)})
                    lines.append(f"{fam.name}_bucket{lbl} {cum}")
                base = _labels(s.labels)
                lines.append(f"{fam.name}_sum{base} {_fmt(payload['sum'])}")
                lines.append(f"{fam.name}_count{base} {payload['count']}")
            else:
                lines.append(f"{fam.name}{_labels(s.labels)} {_fmt(s.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str, registry: Registry | None = None) -> str:
    """Write the Prometheus text rendering to ``path``; returns the path.

    Overwrites in place — the textfile-collector convention is one file
    holding the latest scrape, not an append log (that's ``JsonlWriter``).
    """
    with open(path, "w") as f:
        f.write(prometheus_text(registry))
    return path


class JsonlWriter:
    """Append-mode JSONL metrics log: one snapshot object per ``write``."""

    def __init__(self, path: str, registry: Registry | None = None,
                 delta: bool = False):
        self.path = path
        self.registry = (
            registry if registry is not None else obs_metrics.default_registry()
        )
        self.delta = delta
        # Truncate at open so each run's log stands alone.
        with open(path, "w"):
            pass

    def write(self, **extra) -> dict:
        """Append one snapshot record (plus ``extra`` key/values, e.g.
        ``step=12``) and return it."""
        rec = {
            "ts": time.time(),
            "metrics": self.registry.snapshot(delta=self.delta),
        }
        rec.update(extra)
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        return rec


def append_snapshot(path: str, registry: Registry | None = None,
                    delta: bool = False, **extra) -> dict:
    """One-shot JSONL append without holding a writer (truncates nothing)."""
    reg = registry if registry is not None else obs_metrics.default_registry()
    rec = {"ts": time.time(), "metrics": reg.snapshot(delta=delta)}
    rec.update(extra)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec
