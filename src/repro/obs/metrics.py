"""Process-local metrics registry: counters, gauges, log2 histograms.

The naming contract (machine-checked by qlint's ``metric-names`` rule,
DESIGN.md §10): every metric is *declared* once, at module level, through
the module functions ``counter`` / ``gauge`` / ``histogram`` with a LITERAL
snake_case name unique across the repo — no stringly-typed ad-hoc
emissions. Call sites then emit through the returned handle, so the full
metric surface of the process is enumerable from the source alone.

Semantics:

* **Families and series.** A declaration creates a *family* (name, kind,
  help, label names). Emitting through ``family.labels(pipe="3")`` creates
  (memoizes) one *series* per label-value tuple — the Prometheus data
  model, which is how five monitor instances or N ingest pipelines share
  one declared name without colliding. A family with no label names has a
  single implicit series and the handle itself accepts ``inc``/``set``/
  ``observe``.
* **Histograms are log2-bucketed** — the same quantization idiom the
  sketch applies to register values (PAPER.md §4): bucket upper bounds are
  powers of two over a configurable exponent range, so a histogram costs a
  handful of ints however wide the value distribution is.
* **Snapshots are cumulative or delta.** ``snapshot()`` returns current
  values; ``snapshot(delta=True)`` returns the change since the *previous
  delta snapshot* (each series keeps its own baseline), which is what a
  scrape loop or a per-epoch report wants. ``reset()`` zeroes everything.
* **Disabled mode is a no-op path.** With ``enabled=False`` (constructor,
  ``configure``, or the ``QOBS_DISABLED`` env var for the default
  registry) every emission is one attribute load + branch and snapshots
  are empty. Components whose counters feed control flow must therefore
  keep them OUT of the registry (see ``sketchstream/ingest.py``'s local
  fallback).
* **Strictly outside jit.** Values are host Python numbers; handles must
  never receive traced values. Callers that may sit under a ``jax.jit``
  trace guard emissions with ``jax.core.trace_state_clean()`` (the
  monitor layer does this for you).
"""

from __future__ import annotations

import os
import re
import threading

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
KINDS = ("counter", "gauge", "histogram")

# Default log2 bucket exponent range: 2^-10 (~1 ms if seconds) .. 2^20 (~1M
# if counts). Histogram declarations override per-metric.
DEFAULT_LOW_EXP = -10
DEFAULT_HIGH_EXP = 20


def _check_name(name: str, what: str = "metric") -> None:
    if not isinstance(name, str) or not _NAME_RE.match(name):
        raise ValueError(
            f"{what} name {name!r} must be snake_case "
            "(lowercase letters, digits, underscores; starts with a letter)"
        )


class Series:
    """One (family, label-values) time series: a mutable host-side value.

    Counters/gauges hold one number; histograms hold per-bucket counts plus
    a running sum and count. All mutation methods are cheap no-ops while
    the owning registry is disabled.
    """

    __slots__ = ("_reg", "kind", "labels", "value", "buckets", "sum", "count",
                 "_d_value", "_d_buckets", "_d_sum", "_d_count", "_bounds")

    def __init__(self, reg: "Registry", kind: str, labels: dict, bounds=None):
        self._reg = reg
        self.kind = kind
        self.labels = labels
        self.value = 0
        self._bounds = bounds  # histogram bucket upper bounds (powers of 2)
        self.buckets = [0] * (len(bounds) + 1) if bounds is not None else None
        self.sum = 0.0
        self.count = 0
        # Baselines of the previous delta snapshot.
        self._d_value = 0
        self._d_buckets = list(self.buckets) if self.buckets else None
        self._d_sum = 0.0
        self._d_count = 0

    # -- emission ---------------------------------------------------------

    def inc(self, n=1) -> None:
        """Counter increment by ``n`` (must be >= 0)."""
        if not self._reg._enabled:
            return
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def set(self, v) -> None:
        """Gauge assignment (last-write-wins)."""
        if not self._reg._enabled:
            return
        self.value = v

    def set_max(self, v) -> None:
        """Gauge high-water update: keep the max of the current value and
        ``v`` (the ``max_in_flight`` idiom)."""
        if not self._reg._enabled:
            return
        if v > self.value:
            self.value = v

    def observe(self, v) -> None:
        """Histogram observation: lands in the first log2 bucket whose
        upper bound is >= v (the overflow bucket catches the rest)."""
        if not self._reg._enabled:
            return
        i = 0
        bounds = self._bounds
        while i < len(bounds) and v > bounds[i]:
            i += 1
        self.buckets[i] += 1
        self.sum += v
        self.count += 1

    # -- reads ------------------------------------------------------------

    def read(self, delta: bool = False):
        """Snapshot payload of this series; ``delta=True`` additionally
        advances this series' delta baseline."""
        if self.kind == "histogram":
            if delta:
                out = {
                    "buckets": [a - b for a, b in zip(self.buckets, self._d_buckets)],
                    "sum": self.sum - self._d_sum,
                    "count": self.count - self._d_count,
                }
                self._d_buckets = list(self.buckets)
                self._d_sum, self._d_count = self.sum, self.count
            else:
                out = {
                    "buckets": list(self.buckets),
                    "sum": self.sum,
                    "count": self.count,
                }
            out["le"] = [float(b) for b in self._bounds] + [float("inf")]
            return out
        if delta and self.kind == "counter":
            out = self.value - self._d_value
            self._d_value = self.value
            return out
        if delta and self.kind == "gauge":
            # Gauges are point-in-time: a delta snapshot reports the current
            # value (set_max users re-arm their high-water with reset()).
            return self.value
        return self.value

    def reset(self) -> None:
        """Zero the series and its delta baseline."""
        self.value = 0
        self._d_value = 0
        if self.buckets is not None:
            self.buckets = [0] * len(self.buckets)
            self._d_buckets = list(self.buckets)
        self.sum = self._d_sum = 0.0
        self.count = self._d_count = 0


class Metric:
    """One declared family: name, kind, help text, label names, series."""

    def __init__(self, reg: "Registry", name: str, kind: str, help: str,
                 label_names: tuple, bounds=None):
        self.registry = reg
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._bounds = bounds
        self._series: dict[tuple, Series] = {}
        if not label_names:
            self._default = self._make(())
        else:
            self._default = None

    def _make(self, key: tuple) -> Series:
        s = Series(self.registry, self.kind,
                   dict(zip(self.label_names, key)), self._bounds)
        self._series[key] = s
        return s

    def labels(self, **kv) -> Series:
        """The series for one label-value assignment (memoized). Every
        declared label name must be given; values are stringified."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}"
            )
        key = tuple(str(kv[n]) for n in self.label_names)
        s = self._series.get(key)
        return s if s is not None else self._make(key)

    def series(self) -> list[Series]:
        """Every live series of this family, declaration-ordered."""
        return list(self._series.values())

    # Unlabeled convenience: delegate to the implicit series.
    def inc(self, n=1) -> None:
        """Counter increment on the label-less series."""
        self._default.inc(n)

    def set(self, v) -> None:
        """Gauge assignment on the label-less series."""
        self._default.set(v)

    def set_max(self, v) -> None:
        """Gauge high-water update on the label-less series."""
        self._default.set_max(v)

    def observe(self, v) -> None:
        """Histogram observation on the label-less series."""
        self._default.observe(v)

    @property
    def value(self):
        """Current value of the label-less series."""
        return self._default.value


def render_series_name(name: str, labels: dict) -> str:
    """Prometheus-style rendered series id: ``name{a="x",b="y"}``."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
    return f"{name}{{{inner}}}"


class Registry:
    """A process-local set of metric families (see module docstring).

    Thread-safe for declaration; emission is plain attribute mutation (the
    GIL makes int += atomic enough for telemetry — these are not
    correctness counters).
    """

    def __init__(self, enabled: bool = True):
        self._enabled = bool(enabled)
        self._families: dict[str, Metric] = {}
        self._lock = threading.Lock()

    # -- configuration ----------------------------------------------------

    @property
    def enabled(self) -> bool:
        """Whether emissions record and snapshots report."""
        return self._enabled

    def configure(self, *, enabled: bool) -> None:
        """Toggle the registry. Disabling mid-process freezes values in
        place (emissions no-op); re-enabling resumes from them."""
        self._enabled = bool(enabled)

    # -- declaration ------------------------------------------------------

    def _declare(self, name, kind, help, labels, bounds=None) -> Metric:
        _check_name(name)
        for ln in labels:
            _check_name(ln, "label")
        labels = tuple(labels)
        with self._lock:
            existing = self._families.get(name)
            if existing is not None:
                if existing.kind != kind or existing.label_names != labels:
                    raise ValueError(
                        f"metric {name!r} already declared as "
                        f"{existing.kind}{existing.label_names}, cannot "
                        f"redeclare as {kind}{labels}"
                    )
                return existing
            fam = Metric(self, name, kind, help, labels, bounds)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels: tuple = ()) -> Metric:
        """Declare (or fetch) a monotone counter family."""
        return self._declare(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "", labels: tuple = ()) -> Metric:
        """Declare (or fetch) a last-write-wins gauge family."""
        return self._declare(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  low_exp: int = DEFAULT_LOW_EXP,
                  high_exp: int = DEFAULT_HIGH_EXP) -> Metric:
        """Declare (or fetch) a log2-bucketed histogram family with bucket
        upper bounds ``2^low_exp .. 2^high_exp`` plus an overflow bucket."""
        if high_exp <= low_exp:
            raise ValueError("histogram needs high_exp > low_exp")
        bounds = [2.0 ** e for e in range(low_exp, high_exp + 1)]
        return self._declare(name, "histogram", help, labels, bounds)

    # -- introspection ----------------------------------------------------

    def families(self) -> list[Metric]:
        """Every declared family, declaration-ordered."""
        return list(self._families.values())

    def get(self, name: str) -> Metric | None:
        """Family by name (None if undeclared)."""
        return self._families.get(name)

    def snapshot(self, delta: bool = False) -> dict:
        """``{rendered series name: value}`` over every live series.

        Counters/gauges map to numbers; histograms to ``{"buckets": [...],
        "le": [...], "sum": s, "count": c}``. ``delta=True`` reports change
        since the previous delta snapshot and advances each series'
        baseline. Disabled registries snapshot empty.
        """
        if not self._enabled:
            return {}
        out = {}
        for fam in self._families.values():
            for s in fam.series():
                out[render_series_name(fam.name, s.labels)] = s.read(delta)
        return out

    def reset(self) -> None:
        """Zero every series and every delta baseline."""
        for fam in self._families.values():
            for s in fam.series():
                s.reset()


_DEFAULT = Registry(enabled=not os.environ.get("QOBS_DISABLED"))


def default_registry() -> Registry:
    """The process-default registry every library declaration lands in."""
    return _DEFAULT


def configure(*, enabled: bool) -> None:
    """Toggle the default registry (see ``Registry.configure``)."""
    _DEFAULT.configure(enabled=enabled)


def enabled() -> bool:
    """Whether the default registry records emissions."""
    return _DEFAULT.enabled


def counter(name: str, help: str = "", labels: tuple = ()) -> Metric:
    """Declare a counter on the default registry (the sanctioned, qlint-
    checked declaration point — literal snake_case name, unique repo-wide)."""
    return _DEFAULT.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: tuple = ()) -> Metric:
    """Declare a gauge on the default registry (qlint-checked)."""
    return _DEFAULT.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: tuple = (),
              low_exp: int = DEFAULT_LOW_EXP,
              high_exp: int = DEFAULT_HIGH_EXP) -> Metric:
    """Declare a log2 histogram on the default registry (qlint-checked)."""
    return _DEFAULT.histogram(name, help, labels, low_exp, high_exp)


def snapshot(delta: bool = False) -> dict:
    """Snapshot the default registry (see ``Registry.snapshot``)."""
    return _DEFAULT.snapshot(delta)


def reset() -> None:
    """Zero the default registry."""
    return _DEFAULT.reset()
