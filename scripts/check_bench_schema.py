"""CI guard for the cumulative bench-JSON files (scripts/test.sh --tier2).

The sweep suites (benchmarks/dyn_array.py, benchmarks/window_array.py) merge
quick/smoke re-measurements into their JSON so cheap runs never erase the
paper-scale rows a ``--full`` run paid for (common.merge_save). A broken
merge fails SILENTLY at bench time — duplicate cells, dropped rows, unsorted
output — and only shows up when someone plots stale data. This script makes
it fail loudly instead:

  * every row carries the required keys ("figure", "method", and a payload
    of at least one of mops/ms/x);
  * within each (figure, method[, e]) group the swept "k" values are unique
    and stored in strictly increasing order (merge_save sorts; a duplicate k
    means two merges claimed the same cell, out-of-order means someone
    bypassed merge_save).

Usage:  python scripts/check_bench_schema.py [file.json ...]
        (no args: checks the cumulative sweep files that exist under
        experiments/bench/, requiring the ones the smoke suite just wrote)
"""

from __future__ import annotations

import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

# Files written through common.merge_save — the cumulative-merge contract.
CUMULATIVE = (
    "dyn_array.json",
    "dyn_array_sharded.json",
    "estimation.json",
    "ingest.json",
    "window_array.json",
    "window_array_sharded.json",
)
PAYLOAD_KEYS = ("mops", "ms", "x", "us", "sustained_mops")


def check_rows(name: str, rows) -> list[str]:
    errors = []
    if not isinstance(rows, list) or not rows:
        return [f"{name}: expected a non-empty list of row dicts"]
    groups: dict[tuple, list] = {}
    for i, r in enumerate(rows):
        for key in ("figure", "method"):
            if not isinstance(r.get(key), str):
                errors.append(f"{name}[{i}]: missing/non-string '{key}': {r}")
        if not any(isinstance(r.get(p), (int, float)) for p in PAYLOAD_KEYS):
            errors.append(
                f"{name}[{i}]: no numeric payload among {PAYLOAD_KEYS}: {r}"
            )
        if "k" in r and not isinstance(r["k"], int):
            errors.append(f"{name}[{i}]: non-integer sweep key 'k': {r}")
        groups.setdefault(
            # "e" splits the window-suite ring sweeps; "bsz" splits the
            # ingest batch-size sweep — within each group the k axis must
            # stay unique + monotone.
            (r.get("figure"), r.get("method"), r.get("e"), r.get("bsz")), []
        ).append(r)
    for (figure, method, e, bsz), rs in groups.items():
        ks = [r["k"] for r in rs if "k" in r]
        tag = (
            f"{name}:{figure}/{method}"
            + (f"/e={e}" if e is not None else "")
            + (f"/bsz={bsz}" if bsz is not None else "")
        )
        if len(ks) != len(set(ks)):
            dupes = sorted({k for k in ks if ks.count(k) > 1})
            errors.append(f"{tag}: duplicate k cells {dupes} (broken cumulative merge)")
        if ks != sorted(ks):
            errors.append(f"{tag}: k not monotone increasing: {ks}")
    return errors


def main(paths=None) -> int:
    if not paths:
        paths = [
            os.path.join(RESULTS_DIR, f)
            for f in CUMULATIVE
            if os.path.exists(os.path.join(RESULTS_DIR, f))
        ]
        missing = [f for f in CUMULATIVE if not os.path.exists(os.path.join(RESULTS_DIR, f))]
        if missing:
            print(f"check_bench_schema: FAIL — expected cumulative files missing: {missing}")
            return 1
    errors = []
    for path in paths:
        with open(path) as f:
            rows = json.load(f)
        errors += check_rows(os.path.basename(path), rows)
    if errors:
        print("check_bench_schema: FAIL")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_bench_schema: OK ({', '.join(os.path.basename(p) for p in paths)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
