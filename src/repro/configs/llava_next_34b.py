"""llava-next-34b [vlm] — anyres tiling; Yi-34B-class dense decoder backbone.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. The vision tower is a
STUB: input_specs supplies precomputed patch embeddings (B, 576, d_model)
prepended to the text stream through a learned projection (DESIGN.md §5).
Pure full attention -> long_500k skipped.
"""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        pattern=(LayerSpec(),),
        frontend="patches",
        frontend_len=576,
        rope_theta=5_000_000.0,
        max_seq=32768,
    )
