"""Shared pytest fixtures.

The suite compiles several hundred distinct XLA programs (every container
x solver x mesh combination is jitted). On the CPU backend that much
accumulated compile state has crashed the compiler mid-suite — a native
segfault in a late module's first `pjit` cache miss that no single module
reproduces in isolation. Dropping the caches at module boundaries keeps
each module's compile session small; the only cost is re-tracing shared
helpers, which is noise next to the solves themselves.
"""

import os

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches_per_module():
    yield
    jax.clear_caches()


# Property-test profiles (DESIGN.md §8.9 testing policy): tier-1 runs the
# cheap derandomized "quick" profile; `scripts/test.sh --tier2` re-runs the
# property/differential suites under "deep" (more examples, fresh seeds).
# Falls back to tests/_minihyp.py when hypothesis isn't installed, so the
# suites execute either way.
try:
    from hypothesis import HealthCheck, settings as _hyp_settings

    _hyp_settings.register_profile(
        "quick", max_examples=10, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    _hyp_settings.register_profile("deep", max_examples=75, deadline=None)
except ImportError:
    from _minihyp import settings as _hyp_settings

    _hyp_settings.register_profile("quick", max_examples=6)
    _hyp_settings.register_profile("deep", max_examples=30)
_hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "quick"))
