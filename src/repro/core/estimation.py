"""Unified estimation layer: the histogram→Ĉ solve behind one API.

Every container in the repo ultimately answers the same question — "given
this sketch row's register-value histogram, what is the ML weighted
cardinality?" — but before this module the solve was copy-threaded through
nine call sites (single sketch, SketchArray, DynArray, WindowArray, the
three sharded fronts, the monitors, and ``kernels/ops.py``). This layer owns
that solve behind one API with a pluggable solver registry (DESIGN.md §8.7):

* ``estimate_rows(cfg, regs)``   — ``int8[K, m]`` register rows → ``Ĉ[K]``;
* ``estimate_hists(cfg, hists)`` — ``int32[K, 2^b]`` FULL histograms → ``Ĉ[K]``;
* ``estimate_with_ci(...)``      — the same plus the §4.2 observed-Fisher
  stddev and a converged flag (single-histogram and batched forms).

Solvers (``solver=`` on every entry point, default ``"newton"``):

``newton``
    The safeguarded Newton–Raphson from ``estimators.qsketch_mle``,
    unchanged — the bit-identity reference. A ``lax.while_loop`` per row;
    vmapped rows all run to the slowest row's iteration count, which is the
    ~65 s K=2^20 wall the ROADMAP records.
``lut``
    The batched precomputed solver exploiting the int8 register domain. The
    shift-invariance (R → R−Δ, C → C·2^Δ) documented in ``estimators.py``
    means the score's every histogram-bin term factors through ONE bounded
    function H(z) = z/expm1(z) of z = C·2^{-(v+1)} — and because register
    values are integers, rebasing each row by the integer octave of its own
    LM seed reduces every row to ONE fixed log₂C grid, where evaluating all
    scores is a single (K, W)×(W, G) matmul against a compile-time H
    lattice (H saturates to 1/0 outside a W = 30-octave window, so W ≪ 2^b
    columns suffice). The root is then bracketed per row by a binary sign
    search and polished on a 4-point cubic interpolant of the score — a
    fixed, fully unrolled recurrence with **no lax.while_loop**, so the
    sharded fronts keep ``check_rep=True`` on this path. O(2^b) work per
    row, all of it in BLAS-shaped ops, and a row's answer is independent of
    the batch it rides in (the grid is per-row, not per-batch).
``fused``
    The Pallas kernel ``kernels/estimate.py`` via ``ops.estimate_rows_op``:
    streams register rows through VMEM and emits bincount + a fixed-count
    vectorized Newton in one pass, never materializing the ``[K, 2^b]``
    histogram in HBM. Registers-only — ``estimate_hists(solver="fused")``
    raises (the kernel's whole point is fusing the bincount). Built for
    TPU; on CPU it runs in interpret mode (slow — use ``lut`` there).

Scaling conventions (``kind=``): ``"full"`` — every element feeds all m
registers (QSketch / SketchArray / the in-step monitor); the MLE *is* Ĉ.
``"routed"`` — one register per element (Dyn / Window rows); the MLE
recovers Ĉ/m, is scaled ×m, and untouched rows (full-histogram bin 0 == m)
report exactly 0.0. That untouched-row guard — previously repeated in
``qsketch_dyn.estimate_mle``, ``qsketch_dyn.merge`` and
``dyn_array.estimate_mle_hists`` — lives here and only here.

Tolerance semantics (tests/test_estimation.py enforces): ``lut``/``fused``
match the float64 reference MLE within ``LUT_RTOL`` relative error OR
``ATOL_FLOOR`` absolute. The absolute floor covers rows whose MLE
legitimately collapses toward 0 — any bin-0 mass alongside high-value mass
drives the score negative at every meaningful C, and the solvers land on
different denormal-scale representations of "zero". The relative bound
holds for rows whose MLE sits within ``GRID_MARGIN`` octaves of their LM
seed (true for max-stable register rows, i.e. every reachable sketch);
roots outside the grid clamp to its edge (documented saturation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import estimators
from .types import SketchConfig

# Documented agreement bound of the lut/fused solvers vs the float64
# reference MLE: relative wherever the estimate is meaningful, absolute
# below the collapse floor.
LUT_RTOL = 2e-3
ATOL_FLOOR = 1e-6

SOLVERS = ("newton", "lut", "fused")

# LUT geometry. Every row is rebased by the integer octave of its own LM
# seed (the shift-invariance R → R−Δ, C → C·2^Δ), so ONE fixed grid
# u' ∈ [−GRID_MARGIN, +GRID_MARGIN] with GRID_POINTS samples serves every
# row: because register values are integers, an integer shift of log₂C is
# exactly a shift of the histogram window, and the H lattice over the grid
# is a compile-time table. The lattice rows cover the integer exponents
# ε = log₂z where H transitions (outside [_H_SAT_LO, _H_SAT_HI] ± the grid
# half-width H is saturated: 1 below — a ≤ 2^(_H_SAT_LO−1) ≈ 6e-5 relative
# error per count, far inside LUT_RTOL — and 0 above). Cubic interpolation
# error scales as the 4th power of the grid step (~0.53 octaves): ≲ 2e-4,
# independent of how heterogeneous the batch is, because the step never
# widens with the seed spread (tests/test_estimation.py measures this
# against the float64 reference). GRID_POINTS must be a power of two — the
# bracketing binary search descends through exact powers.
GRID_POINTS = 16
GRID_MARGIN = 4.0
_H_SAT_LO = -13.0  # log2 z below which H(z) is taken as 1
_H_SAT_HI = 6.0  # log2 z above which H(z) is taken as 0
# Lattice rows: integer ε from _EPS_HI down to _EPS_LO, one octave apart.
_EPS_HI = int(np.ceil(_H_SAT_HI + GRID_MARGIN)) + 1
_EPS_LO = int(np.floor(_H_SAT_LO - GRID_MARGIN)) - 1
WINDOW_BINS = _EPS_HI - _EPS_LO + 1
# The H == 1 saturation tail Σ_{k ≥ thresh} T_k·act_k is read from coarse
# per-group partial sums (folded into the constants GEMM as _TAIL_GROUP-lane
# indicator columns) plus one boundary-group gather — never a full-width
# masked reduction over the histogram block.
_TAIL_GROUP = 16
# Newton-on-cubic refinements after the bracketing search. Convergence is
# superlinear: measured worst error vs the float64 oracle is 1.7e-4 at two
# iterations and 1.8e-4 at three (the third is a no-op), so two buys the
# full accuracy the interpolation error floor allows.
_REFINE_ITERS = 2


@functools.lru_cache(maxsize=16)
def _lut_consts(num_bins: int, r_min: int, top_bin: int):
    """Per-config constants of the LUT solver (tabulated once).

    Returns (w_mat, h_tab) as numpy f32 arrays. ``w_mat`` (2^b, 3 + G_t)
    holds the per-row reductions the solver takes in ONE histogram GEMM:
    columns 0/1 the disjoint split-scaled weights whose two inner products
    reassemble the score's linear coefficient B = Σ_{k<top} T_k·2^{−v−1}
    without f32 overflow (column 0 carries 2^96), column 2 the indicator
    ``act`` of bins that contribute an H term (1..top), and columns 3+ the
    ``_TAIL_GROUP``-lane partial sums of ``act`` minus the top bin, from
    which the H == 1 saturation tail is assembled. ``h_tab`` is the
    (WINDOW_BINS, GRID_POINTS) lattice H(2^{ε_w + u'_g}) with integer rows
    ε_w = _EPS_HI − w and the fixed rebased grid u' — evaluated in float64
    so the f32 table is correctly rounded.
    """
    v = np.arange(num_bins, dtype=np.float64) + r_min
    lane = np.arange(num_bins)
    w_expo = -(v + 1.0)
    in_b = (lane < top_bin)  # interior bins AND bin 0 (its f-term is −T₀s₀)
    big = in_b & (w_expo > 30.0)
    sml = in_b & ~big
    w_big = np.where(big, np.exp2(w_expo - 96.0), 0.0)
    w_sml = np.where(sml, np.exp2(np.clip(w_expo, -149.0, 30.0)), 0.0)
    act = ((lane >= 1) & (lane <= top_bin)).astype(np.float64)
    act_nt = act * (lane != top_bin)
    n_groups = -(-num_bins // _TAIL_GROUP)
    groups = np.zeros((num_bins, n_groups))
    groups[lane, lane // _TAIL_GROUP] = act_nt
    w_mat = np.concatenate(
        [np.stack([w_big, w_sml, act], axis=1), groups], axis=1
    )
    up = -GRID_MARGIN + (2.0 * GRID_MARGIN / (GRID_POINTS - 1)) * np.arange(
        GRID_POINTS, dtype=np.float64
    )
    eps = _EPS_HI - np.arange(WINDOW_BINS, dtype=np.float64)
    z = np.exp2(eps[:, None] + up[None, :])
    with np.errstate(over="ignore"):
        h_tab = np.where(z < 1e-9, 1.0, z / np.expm1(np.minimum(z, 700.0)))
        h_tab = np.where(z > 700.0, 0.0, h_tab)
    return w_mat.astype(np.float32), h_tab.astype(np.float32)


@functools.lru_cache(maxsize=16)
def lut_family_consts(num_bins: int, r_min: int, top_bin: int):
    """Device-resident LUT tables, shared across a whole (m, b) config FAMILY.

    The solver tables depend on the sketch geometry only through
    (num_bins, r_min, top_bin) — the constants an (m, b) pair fixes — never
    on the seed or on which container instance is asking. Caching the
    ``jnp`` arrays at that key means every DynArray / WindowArray / monitor
    built from the same family reuses ONE tabulation and ONE device upload
    (the returned arrays are the literal same buffers, asserted by
    tests/test_estimation.py), instead of re-materializing the table per
    instance/trace. Values are exactly ``_lut_consts``' (the float64-
    evaluated, correctly-rounded f32 tables), so the LUT tolerance contract
    (``LUT_RTOL``) is untouched.
    """
    w_mat_np, h_np = _lut_consts(num_bins, r_min, top_bin)
    # Concrete even when first populated under a jit trace — a traced
    # asarray would cache a tracer and leak it into later traces.
    with jax.ensure_compile_time_eval():
        return jnp.asarray(w_mat_np), jnp.asarray(h_np)


def _log2_add(a, b):
    """log2(2^a + 2^b), finite for mismatched magnitudes (−inf allowed)."""
    hi = jnp.maximum(a, b)
    lo = jnp.minimum(a, b)
    d = jnp.clip(lo - hi, -60.0, 0.0)
    out = hi + jnp.log2(1.0 + jnp.exp2(d))
    return jnp.where(jnp.isfinite(hi), out, hi)


# Rows per LUT chunk: chunks are solved sequentially (lax.map) so the f32
# conversion and every GEMV/GEMM intermediate stays cache-resident — the
# only DRAM traffic is one pass over the int32 histogram block. Chunking is
# purely a residency optimization: the grid is per-row (seed-rebased), so a
# row's answer does not depend on its chunk.
_LUT_CHUNK = 8192


def _lut_hists_with_ci(cfg: SketchConfig, hists):
    """Batched LUT solve: (chat[K], stddev[K], converged[K]) from FULL
    histograms ``int*[K, 2^b]`` (rows sum to m). Unscaled — the MLE itself;
    callers apply the kind convention. Large batches are solved in
    ``_LUT_CHUNK``-row chunks (cache residency; batch-invariant results)."""
    k = hists.shape[0]
    if k <= _LUT_CHUNK:
        return _lut_chunk_solve(cfg, hists)
    nc = -(-k // _LUT_CHUNK)
    kp = nc * _LUT_CHUNK
    hp = hists if kp == k else jnp.pad(hists, ((0, kp - k), (0, 0)), mode="edge")
    out = jax.lax.map(
        lambda hc: _lut_chunk_solve(cfg, hc),
        hp.reshape(nc, _LUT_CHUNK, hists.shape[1]),
    )
    return jax.tree_util.tree_map(lambda x: x.reshape(kp)[:k], out)


def _lut_chunk_solve(cfg: SketchConfig, hists):
    """One-chunk LUT solve (see ``_lut_hists_with_ci``).

    Each row is rebased by the integer octave of its own LM seed,
    n = round(log₂Ĉ0): with u = n + u', the score c·f(c) = A(u) − B·2^u
    has A(n + u') = Σ_k T_k·H(2^{u' + e_k + n}), and because e_k = −(v+1)
    is an integer lattice, e_k + n indexes the SAME compile-time H table
    for every row — only the histogram window shifts (a per-row gather).
    A over the fixed u' grid is then one (K, W)×(W, G) matmul. Bracket by
    a binary sign search, polish with Newton on the cubic through the 4
    bracketing grid samples. Everything is fixed-trip-count, and a row's
    answer does not depend on which batch/chunk it rides in.
    """
    nb = cfg.num_bins
    m = cfg.m
    top = cfg.top_bin
    w_mat, h = lut_family_consts(nb, cfg.r_min, top)  # (nb, 3+G_t), (W, G)

    t = hists.astype(jnp.float32)  # (K, nb)

    # --- per-row constants: B (split-scaled), A0, seed, tail groups -------
    # One (K, nb) @ (nb, 3 + G_t) GEMM — a single pass over the histogram
    # block instead of a reduction per constant (at K = 2^20 the block is
    # ~1 GB; traffic, not FLOPs, dominates on hosts).
    g3 = t @ w_mat
    b_big, b_sml, a0 = g3[:, 0], g3[:, 1], g3[:, 2]
    gsum = g3[:, 3:]  # (K, G_t) coarse partial sums of T·act (minus top)
    l2_big = jnp.where(b_big > 0, jnp.log2(jnp.maximum(b_big, 1e-38)) + 96.0, -jnp.inf)
    l2_sml = jnp.where(b_sml > 0, jnp.log2(jnp.maximum(b_sml, 1e-38)), -jnp.inf)
    l2b = _log2_add(l2_big, l2_sml)  # log2 B, −inf when B == 0
    l2b_safe = jnp.where(jnp.isfinite(l2b), l2b, jnp.float32(-126.0))
    # LM seed Ĉ0 = (m−1)/(2·Σ_k T_k 2^{−v−1}) in log2 — the grid anchor and
    # the degenerate-high fallback (matches estimators.qsketch_init up to
    # the log-domain evaluation). Unlike B, the seed denominator includes
    # the top bin; fold it in as a log-domain correction.
    tt_f = t[:, top]
    l2_top_term = jnp.where(
        tt_f > 0, jnp.log2(jnp.maximum(tt_f, 1e-38)) - (top + cfg.r_min + 1.0), -jnp.inf
    )
    l2b_seed = _log2_add(l2b, l2_top_term)
    l2b_seed = jnp.where(jnp.isfinite(l2b_seed), l2b_seed, jnp.float32(-126.0))
    l2c0 = jnp.log2(jnp.float32(m - 1.0)) - 1.0 - l2b_seed

    # --- per-row rebase onto the fixed grid -------------------------------
    n_f = jnp.round(jnp.clip(l2c0, -126.0, 126.0))
    n_i = n_f.astype(jnp.int32)
    du = jnp.float32(2.0 * GRID_MARGIN / (GRID_POINTS - 1))
    lo = jnp.float32(-GRID_MARGIN)

    # --- A(u'_g) from the shifted histogram window ------------------------
    # Lattice row w holds ε_w = _EPS_HI − w; lane k lands on it when
    # ε_w = n + e_k with e_k = −(k + r_min + 1), i.e. k = n + w + c_off.
    # Lane 0 (act == 0) and the top lane (its e carries a +1 — the term
    # uses a = 2·s_top) are excluded from the generic gather; bins shifted
    # past the low-ε window edge are in H == 1 saturation → a constant.
    c_off = -cfg.r_min - 1 - _EPS_HI
    cols = n_i[:, None] + (jnp.arange(WINDOW_BINS, dtype=jnp.int32) + c_off)[None, :]
    valid = (cols >= 1) & (cols < nb) & (cols != top)
    t_w = jnp.where(
        valid, jnp.take_along_axis(t, jnp.clip(cols, 0, nb - 1), axis=1), 0.0
    )  # (K, W)
    # H == 1 tail Σ_{k ≥ thresh} T_k·act_k (minus top): the coarse group
    # suffix from the constants GEMM plus one boundary-group gather — no
    # full-width masked reduction.
    thresh = jnp.clip(n_i + c_off + WINDOW_BINS, 0, nb)
    n_groups = gsum.shape[1]
    g_t = thresh // _TAIL_GROUP  # in [0, n_groups]
    prefix = jnp.cumsum(gsum, axis=1)  # inclusive per-group prefix
    tot = prefix[:, -1]
    pre_g = jnp.take_along_axis(prefix, jnp.clip(g_t, 0, n_groups - 1)[:, None], axis=1)[:, 0]
    suffix = jnp.where(g_t >= n_groups, 0.0, tot - pre_g)  # groups past g_t
    bcols = g_t[:, None] * _TAIL_GROUP + jnp.arange(_TAIL_GROUP, dtype=jnp.int32)[None, :]
    bval = (bcols >= thresh[:, None]) & (bcols < nb) & (bcols >= 1) & (bcols != top)
    boundary = jnp.sum(
        jnp.where(bval, jnp.take_along_axis(t, jnp.clip(bcols, 0, nb - 1), axis=1), 0.0),
        axis=1,
    )
    a_const = suffix + boundary
    # Top-bin term: ε_top = n − (top + r_min) → lattice row per row of K.
    w_top = _EPS_HI + top + cfg.r_min - n_i
    h_top = h[jnp.clip(w_top, 0, WINDOW_BINS - 1), :]  # (K, G) row gather
    a_const = a_const + jnp.where(w_top >= WINDOW_BINS, tt_f, 0.0)
    in_w = (w_top >= 0) & (w_top < WINDOW_BINS)
    a = t_w @ h + jnp.where(in_w, tt_f, 0.0)[:, None] * h_top + a_const[:, None]

    # --- bracket + cubic polish ------------------------------------------
    # The score G(u) = A(u)·2^{−u} − B is strictly decreasing in u (A is
    # non-increasing, 2^{−u} strictly decreasing), so its sign over the grid
    # is a single crossing: bracket it by binary search with log2(G) probes
    # per row instead of a full (K, G) transcendental sign matrix. A probe
    # compares A(u_i) > B·2^{u_i} with the rhs clipped: A ≤ m, so any
    # log2-rhs above the bound decides the comparison without exp2 overflow.
    bound = jnp.float32(np.log2(max(m, 2)) + 2.0)

    def _probe(idx):
        a_g = jnp.take_along_axis(a, idx[:, None], axis=1)[:, 0]
        s = l2b + n_f + (lo + idx.astype(jnp.float32) * du)
        return (s < bound) & (a_g > jnp.exp2(jnp.minimum(s, bound)))

    j_raw = jnp.zeros(a.shape[:1], jnp.int32)
    step_sz = GRID_POINTS // 2
    while step_sz >= 1:
        cand = j_raw + step_sz
        j_raw = jnp.where(_probe(cand), cand, j_raw)
        step_sz //= 2
    below = ~_probe(jnp.zeros_like(j_raw))  # sign already negative at u[0]

    # Interpolation nodes j−1..j+2 at θ = −1,0,1,2; the root bracket
    # [u_j, u_{j+1}] is θ ∈ [0, 1] except at the clipped edges, where the
    # admissible θ range widens to keep the true bracket inside the nodes.
    j = jnp.clip(j_raw, 1, GRID_POINTS - 3)
    th_lo = jnp.where(j_raw < 1, jnp.float32(-1.0), jnp.float32(0.0))
    th_hi = jnp.where(j_raw > GRID_POINTS - 3, jnp.float32(2.0), jnp.float32(1.0))
    idx = j[:, None] + jnp.arange(-1, 3)[None, :]
    ai = jnp.take_along_axis(a, idx, axis=1)  # (K, 4)
    u_j = n_f + lo + j.astype(jnp.float32) * du  # absolute log2 c at node j

    ln2 = jnp.float32(np.log(2.0))
    # rhs = B·2^{u_j + θdu} = R0·2^{θdu}; near the bracket R0 ≈ A(u_root) ≤ m,
    # so the clip never binds where the value matters.
    r0 = jnp.exp2(jnp.clip(l2b_safe + u_j, -126.0, 30.0))
    theta = 0.5 * (th_lo + th_hi)
    a_th = da_th = jnp.zeros_like(theta)
    for _ in range(_REFINE_ITERS):
        th = theta
        l0 = -th * (th - 1.0) * (th - 2.0) / 6.0
        l1 = (th + 1.0) * (th - 1.0) * (th - 2.0) / 2.0
        l2 = -(th + 1.0) * th * (th - 2.0) / 2.0
        l3 = (th + 1.0) * th * (th - 1.0) / 6.0
        a_th = ai[:, 0] * l0 + ai[:, 1] * l1 + ai[:, 2] * l2 + ai[:, 3] * l3
        d0 = -(3.0 * th * th - 6.0 * th + 2.0) / 6.0
        d1 = (3.0 * th * th - 4.0 * th - 1.0) / 2.0
        d2 = -(3.0 * th * th - 2.0 * th - 2.0) / 2.0
        d3 = (3.0 * th * th - 1.0) / 6.0
        da_th = ai[:, 0] * d0 + ai[:, 1] * d1 + ai[:, 2] * d2 + ai[:, 3] * d3
        rhs = r0 * jnp.exp2(th * du)
        g = a_th - rhs
        gp = da_th - rhs * ln2 * du
        step = g / jnp.where(jnp.abs(gp) > 0, gp, jnp.float32(-1.0))
        theta = jnp.clip(th - step, th_lo, th_hi)
    u_root = u_j + theta * du

    # Root below the grid (score already negative at the left edge): the
    # small-z closed form A0/c = B ⇒ u = log2 A0 − log2 B. Above the grid:
    # clamp to the right edge (saturation, documented above).
    u_small = jnp.log2(jnp.maximum(a0, 1e-38)) - l2b_safe
    u_root = jnp.where(below, jnp.minimum(u_small, n_f + lo), u_root)

    chat = jnp.exp2(jnp.clip(u_root, -126.0, 127.0))

    # --- stddev from the interpolant: f'(c) = (dA/du/ln2 − A)/c² ----------
    c_root = jnp.maximum(chat, jnp.float32(1e-30))
    # dA/dc = (dA/dθ)/(du·ln2·c); f = A/c − B ⇒ f'(c) = (dA/du/ln2 − A)/c².
    fp = (da_th / (du * ln2) - a_th) / (c_root * c_root)
    fp = jnp.minimum(fp, jnp.float32(-1e-30))
    stddev = jnp.sqrt(jnp.maximum(-1.0 / fp, 0.0))

    # --- degenerates (replicating estimators.qsketch_mle) -----------------
    t0 = hists[:, 0]
    tt = hists[:, top]
    degenerate = (t0 == m) | (tt == m)
    c0 = jnp.exp2(jnp.clip(l2c0, -126.0, 127.0))
    chat = jnp.where(tt == m, c0, chat)
    chat = jnp.where(t0 == m, jnp.float32(0.0), chat)
    return chat, stddev, ~degenerate


# ---------------------------------------------------------------------------
# Solver dispatch
# ---------------------------------------------------------------------------


def _check_kind(kind: str) -> None:
    if kind not in ("full", "routed"):
        raise ValueError(f"unknown kind {kind!r}; expected 'full' or 'routed'")


def _check_solver(solver: str, *, hists_input: bool = False) -> None:
    if solver not in SOLVERS:
        raise ValueError(f"unknown solver {solver!r}; expected one of {SOLVERS}")
    if hists_input and solver == "fused":
        raise ValueError(
            "solver='fused' streams register rows (its point is fusing the "
            "bincount) — use estimate_rows, or solver='lut' on histograms"
        )


def _routed_chat(cfg: SketchConfig, hist0, chat):
    """The ×m scaling + untouched-row Ĉ=0 guard of the routed convention."""
    return jnp.where(hist0 == cfg.m, jnp.float32(0.0), chat * cfg.m)


# ---------------------------------------------------------------------------
# Public API — single histogram
# ---------------------------------------------------------------------------


def _hist_with_ci_impl(cfg: SketchConfig, hist, *, kind, solver):
    _check_kind(kind)
    _check_solver(solver, hists_input=True)
    if solver == "newton":
        chat, stddev, ok = estimators.qsketch_mle(cfg, hist)
    else:
        chat, stddev, ok = jax.tree_util.tree_map(
            lambda x: x[0], _lut_hists_with_ci(cfg, hist[None, :])
        )
    if kind == "routed":
        return _routed_chat(cfg, hist[0], chat), stddev * cfg.m, ok
    return chat, stddev, ok


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_hist(cfg: SketchConfig, hist, *, kind: str = "full", solver: str = "newton"):
    """Ĉ from ONE full 2^b-bin histogram (bins sum to m).

    Jitted over the Ĉ output alone so XLA dead-code-eliminates the stddev
    pipeline — callers that don't want the CI don't pay for it.
    """
    return _hist_with_ci_impl(cfg, hist, kind=kind, solver=solver)[0]


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_hist_with_ci(
    cfg: SketchConfig, hist, *, kind: str = "full", solver: str = "newton"
):
    """(Ĉ, stddev, converged) from ONE full histogram.

    kind="full": the MLE is Ĉ. kind="routed": Ĉ = m·MLE (0.0 exactly for an
    untouched row) and the stddev scales by the same m.
    """
    return _hist_with_ci_impl(cfg, hist, kind=kind, solver=solver)


# ---------------------------------------------------------------------------
# Public API — batched
# ---------------------------------------------------------------------------


def _hists_with_ci_impl(cfg: SketchConfig, hists, *, kind, solver):
    _check_kind(kind)
    _check_solver(solver, hists_input=True)
    if solver == "lut":
        chat, stddev, ok = _lut_hists_with_ci(cfg, hists)
        if kind == "routed":
            return _routed_chat(cfg, hists[:, 0], chat), stddev * cfg.m, ok
        return chat, stddev, ok
    if kind == "routed":

        def one(hist):
            chat, stddev, ok = estimators.qsketch_mle(cfg, hist)
            return _routed_chat(cfg, hist[0], chat), stddev * cfg.m, ok

        return jax.vmap(one)(hists)
    return jax.vmap(lambda h: estimators.qsketch_mle(cfg, h))(hists)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_hists(cfg: SketchConfig, hists, *, kind: str = "full", solver: str = "newton"):
    """Ĉ[K] from full histograms ``int32[K, 2^b]``.

    Jitted over the Ĉ output alone so XLA dead-code-eliminates the stddev
    pipeline — at K = 2^20 the CI costs a measurable fraction of the lut
    solve, and most batched readers (dashboards, anomaly scoring) only
    consume Ĉ.
    """
    return _hists_with_ci_impl(cfg, hists, kind=kind, solver=solver)[0]


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_hists_with_ci(
    cfg: SketchConfig, hists, *, kind: str = "full", solver: str = "newton"
):
    """(Ĉ[K], stddev[K], converged[K]) from full histograms.

    The newton forms reproduce the pre-refactor vmap expressions exactly
    (the bit-identity contract): kind="full" vmaps the bare solve;
    kind="routed" vmaps solve+guard as one function, exactly as
    ``dyn_array.estimate_mle_hists`` always did. The lut solver is natively
    batched; its per-row rebased grid makes every answer batch-independent.
    """
    return _hists_with_ci_impl(cfg, hists, kind=kind, solver=solver)


# ---------------------------------------------------------------------------
# Public API — pooled (virtual register sharing) solves
# ---------------------------------------------------------------------------


def pool_config(cfg: SketchConfig, pool_size: int) -> SketchConfig:
    """Pool-geometry config of a shared register pool: the same register
    family (b, and hence r_min/r_max/num_bins/top_bin) with m = M pool
    slots.

    The pool plane of a ``VirtualDynArray`` is itself ONE routed-convention
    sketch of the whole tail stream: each element raises exactly one of M
    slots, so the standard histogram MLE applies under this geometry. The
    LUT tables key on (num_bins, r_min, top_bin) only — a pool config of the
    same b shares the family tabulation with its dense siblings
    (``lut_family_consts``).
    """
    if pool_size <= cfg.m:
        raise ValueError(
            f"pool_size {pool_size} must exceed m {cfg.m} (alpha = m/M < 1)"
        )
    return SketchConfig(m=pool_size, b=cfg.b, seed=cfg.seed)


# Nested log2(u) grid of the compound-Poisson profile solve: a coarse sweep
# of the whole representable octave range, then two refinements around the
# running argmax. Final resolution 0.03125 octaves ≈ 2% in u — below the
# statistical error of any virtual row. Grid search (not Newton) because the
# mixture likelihood is multi-modal for near-empty rows and the solve must
# be deterministic across backends.
_VIRTUAL_GRID_STAGES = ((128, 2.0), (17, 0.25), (17, 0.03125))


def _virtual_loglik(cfg: SketchConfig, h, lam, log2_u):
    """Touched-bin log-likelihood of one full histogram under the
    compound-Poisson register law, for a batch of candidate log2(u).

    With per-slot element count N ~ Poisson(λ) and constant element weight
    u, the Poisson generating function collapses the N-mixture in closed
    form:  P(R ≤ v) = E_N[e^{−N·u·s(v)}] = exp(−λ·(1 − e^{−u·s(v)})),
    s(v) = 2^{−(v+1)}. Bin 0 (value r_min) is exactly the N = 0 mass e^{−λ}
    — constant in u — so it is omitted here and identifies λ separately
    (``_virtual_hists_impl``). Evaluated via expm1 twice: g = −expm1(−u·s)
    keeps small per-slot loads exact, and ln p_k = a_{k−1} +
    ln(expm1(a_k − a_{k−1})) (a_k = −λ·g_k, increasing in k) subtracts the
    two near-unity CDF values without f32 cancellation.
    """
    k = jnp.arange(cfg.num_bins, dtype=jnp.float32)
    log2_s = -(k + cfg.r_min + 1.0)
    us = jnp.exp2(log2_u[:, None] + log2_s[None, :])  # [G, bins]
    g = -jnp.expm1(-us)
    a = -lam * g  # increasing in k, in [−λ, 0]
    da = a[:, 1:] - a[:, :-1]  # ≥ 0
    lnp = a[:, :-1] + jnp.log(jnp.expm1(jnp.maximum(da, 1e-30)))
    hk = h[1:].astype(jnp.float32)
    return jnp.sum(jnp.where(hk[None, :] > 0, hk[None, :] * lnp, 0.0), axis=1)


def _virtual_hist_solve(cfg: SketchConfig, h):
    """Ŵ of ONE full histogram via the compound-Poisson profile MLE.

    λ̂ = ln(m / T₀) from occupancy (exact: bin 0 is the Poisson zero mass),
    clamped to ln(2m) on saturated rows (T₀ = 0 only bounds λ from below —
    the standard linear-counting cap); û from the nested-grid profile
    likelihood over the touched bins; Ŵ = m·λ̂·û estimates the row's total
    load Σ_j c_j.
    """
    t0 = h[0].astype(jnp.float32)
    lam = jnp.log(cfg.m / jnp.clip(t0, 0.5, None))
    center = jnp.float32(0.0)
    for npts, step in _VIRTUAL_GRID_STAGES:
        offs = (jnp.arange(npts, dtype=jnp.float32) - (npts - 1) / 2.0) * step
        grid = center + offs
        ll = _virtual_loglik(cfg, h, lam, grid)
        center = grid[jnp.argmax(ll)]
    u = jnp.exp2(center)
    return jnp.where(t0 >= cfg.m, jnp.float32(0.0), cfg.m * lam * u)


def _virtual_hists_impl(cfg: SketchConfig, hists, *, solver: str):
    """Compound-Poisson profile solve: Ĉ[K] from FULL histograms.

    The plain routed convention is misspecified for lightly-loaded rows
    (DESIGN.md §8.4) twice over. First, the quantized likelihood reads an
    untouched register (bin 0, value r_min) as "the row's whole load
    produced y ≤ r_min", whose probability e^{−C·2^{−(r_min+1)}} forces Ĉ
    toward 0 the moment ANY bin-0 mass coexists with touched registers.
    Second, even restricted to touched registers, a common-scale fit over
    slots whose true loads disperse (few elements per slot — the virtual
    regime) behaves like a geometric mean of the per-slot loads and lands
    well below the arithmetic total. Dense Dyn rows dodge both with the
    running martingale; a virtual row has no martingale, and both it and
    the shared pool plane are lightly loaded BY DESIGN.

    The fix models the dispersion instead of assuming it away (DESIGN.md
    §8.9): per-slot load is compound Poisson — N ~ Poisson(λ) elements of
    weight u — whose register law has the closed form
    P(R ≤ v) = exp(−λ·(1 − e^{−u·2^{−(v+1)}})) (``_virtual_loglik``). The
    joint MLE factorizes exactly: bin 0 is the N = 0 mass e^{−λ}, so
    occupancy identifies λ̂ = ln(m/T₀) alone, and the touched bins profile
    out û. Ĉ = m·λ̂·û. The limits are right: for u·s(v) ≪ 1 the law
    reduces to the plain routed family with c = λu (fully-loaded rows lose
    nothing), and a singleton-loaded row is exactly specified — one
    element of weight w gives its register the law e^{−w·2^{−(v+1)}}, the
    λ → 0 conditional of the mixture, so m·λ̂·û ≈ n·w̄. Untouched rows
    (T₀ = m) report exactly 0.0. The solve is a deterministic nested grid —
    ``solver`` is validated for API uniformity but "newton" and "lut"
    produce identical results here ("fused" is rejected: histogram input).
    """
    _check_solver(solver, hists_input=True)
    return jax.vmap(lambda h: _virtual_hist_solve(cfg, h))(hists)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_hists_virtual(cfg: SketchConfig, hists, *, solver: str = "newton"):
    """Ĉ[K] from FULL histograms via the compound-Poisson profile solve —
    the light-load-safe read of the virtual tier (``_virtual_hists_impl``
    has the derivation). ``solver="fused"`` maps to newton (histogram
    input: nothing to fuse)."""
    solver = "newton" if solver == "fused" else solver
    return _virtual_hists_impl(cfg, hists, solver=solver)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_rows_virtual(cfg: SketchConfig, regs, *, solver: str = "newton"):
    """Ĉ[K] from register rows ``int8[K, m]`` via the compound-Poisson
    profile solve (bincount each row, then ``estimate_hists_virtual``).

    This is the read for register planes WITHOUT maintained martingales or
    full per-row traffic — the virtual tier's gathered tenant rows — where
    the plain routed MLE collapses on bin-0 mass and a touched-only
    common-scale fit under-reads dispersed loads (see
    ``_virtual_hists_impl``). ``solver="fused"`` maps to newton: the fused
    kernel bakes in the plain routed guard, not the mixture law.
    """
    solver = "newton" if solver == "fused" else solver
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    return _virtual_hists_impl(cfg, hists, solver=solver)


def estimate_pool_hist(
    cfg: SketchConfig, pool_hist, pool_size: int, *, solver: str = "newton"
):
    """Ŵ_pool from the FULL pool histogram (bins sum to M): the pooled
    solve — one O(2^b) histogram read, no register walk.

    Runs the compound-Poisson virtual solve under the pool geometry
    (``pool_config``): the pool plane is one routed-convention sketch of
    the whole tail stream, and it is lightly loaded by design (load factor
    is held below ~0.5, obs/health.py), exactly the regime the plain routed
    MLE collapses in. Per-slot jump weights mix every tail tenant's
    register loads, so the constant-jump assumption is coarser here than on
    a single tenant's row — the exact ``w_tail`` accumulator remains the
    authoritative pool total; this solve is the register-only
    cross-check/telemetry read. ``solver="fused"`` maps to newton.
    """
    _check_solver(solver)
    pcfg = pool_config(cfg, pool_size)
    return estimate_hists_virtual(pcfg, pool_hist[None, :], solver=solver)[0]


def cancel_pool_noise(cfg: SketchConfig, chat_virtual, chat_pool, pool_size: int):
    """Noise-cancellation pre-pass of the virtual-sketch estimate
    (Wang et al., arXiv 1811.09126; DESIGN.md §8.9).

    A tail tenant's m gathered pool registers see its own stream plus an
    ~α = m/M sample of every other tenant's traffic, so the routed MLE of
    the gathered row satisfies E[Ŵ_v] ≈ W_t + α·(W_pool − W_t). Inverting:

        Ŵ_t = (Ŵ_v − α·W_pool) / (1 − α),  clamped at 0

    (the clamp: for light tenants the subtraction is noise-dominated and
    may go negative; weight is nonnegative). ``chat_pool`` is the total
    tail weight in the pool — callers should pass the exact ``w_tail``
    accumulator when they have it (``virtual_dyn_array.estimate_tenants``
    does); the pooled histogram MLE is an admissible but low-biased
    fallback under heterogeneous slot loads (DESIGN.md §8.9). Broadcasts
    over batched ``chat_virtual`` against a scalar ``chat_pool``.
    """
    if pool_size <= cfg.m:
        raise ValueError(
            f"pool_size {pool_size} must exceed m {cfg.m} (alpha = m/M < 1)"
        )
    alpha = jnp.float32(cfg.m / pool_size)
    cancelled = (chat_virtual - alpha * chat_pool) / (1.0 - alpha)
    return jnp.maximum(cancelled, 0.0)


def _rows_with_ci_impl(cfg: SketchConfig, regs, *, kind, solver):
    _check_kind(kind)
    _check_solver(solver)
    if solver == "fused":
        from repro.kernels import ops  # deferred: kernels imports core

        return ops.estimate_rows_op(cfg, regs, kind=kind)
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    return _hists_with_ci_impl(cfg, hists, kind=kind, solver=solver)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_rows(cfg: SketchConfig, regs, *, kind: str = "routed", solver: str = "newton"):
    """Ĉ[K] from register rows ``int8[K, m]`` (CI pipeline dead-code-eliminated)."""
    return _rows_with_ci_impl(cfg, regs, kind=kind, solver=solver)[0]


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("kind", "solver"))
def estimate_rows_with_ci(
    cfg: SketchConfig, regs, *, kind: str = "routed", solver: str = "newton"
):
    """(Ĉ[K], stddev[K], converged[K]) from register rows ``int8[K, m]``.

    newton/lut bincount each row (``estimators.histogram``) then solve;
    fused never materializes the histograms — one Pallas pass does bincount
    + solve per VMEM-resident row block (``kernels/estimate.py``). Callers
    holding maintained histograms (DynArray, the window union cache) should
    call ``estimate_hists`` directly and skip the bincount.
    """
    return _rows_with_ci_impl(cfg, regs, kind=kind, solver=solver)
