"""Paper Fig. 5 / Thm. 1: accuracy vs register width b across magnitudes.

Weighted cardinality is swept over ~20 decades by scaling the weight
distribution; 4/5-bit registers saturate outside a narrow band while 7/8-bit
registers hold the CR-bound error across the whole sweep — the paper's
truncation story, reproduced with the f32-safe rebased MLE.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, qsketch
from repro.data import synthetic

from . import common


def run(quick=True):
    scales = [1e-10, 1e-4, 1.0, 1e4, 1e10] if quick else [10.0**k for k in range(-10, 11, 2)]
    widths = [4, 5, 6, 7, 8]
    n = 10_000
    runs = 10 if quick else 50
    m = 256
    rows = []
    for b in widths:
        for scale in scales:
            errs = []
            for r in range(runs):
                ids, w, _ = synthetic.stream("uniform", n, seed=r)
                w = (w * scale).astype(np.float32)
                true_c = float(w.astype(np.float64).sum())
                cfg = SketchConfig(m=m, b=b, seed=77 + r)
                st = qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids), jnp.asarray(w))
                errs.append(float(qsketch.estimate(cfg, st)))
            rows.append({
                "figure": "fig5_register_width",
                "b": b,
                "scale": scale,
                "true_c": true_c,
                "rrmse": common.rrmse(errs, true_c),
                "m": m,
                "runs": runs,
            })
    common.save("register_size", rows)
    for b in widths:
        ok = [r for r in rows if r["b"] == b and r["rrmse"] < 0.2]
        common.csv_row(f"register_size/b{b}", 0.0, f"decades_ok={len(ok)}/{len(scales)}")
    return rows
