"""End-to-end training driver: data -> train_step -> telemetry -> checkpoints.

Fault-tolerance behaviours (exercised by tests/test_train_loop.py):
  * atomic async checkpoints every --ckpt-every steps (+ final),
  * auto-resume from the newest complete checkpoint in --ckpt-dir,
  * SIGTERM/SIGINT trigger a final synchronous save before exit (preemption
    handling — the TPU-pod eviction path),
  * a step watchdog logs straggler steps (> --straggler-factor x EMA),
  * the data pipeline is (seed, step, shard)-keyed, so restarts and elastic
    host-count changes replay the exact global stream.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch small-lm-16m --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke --steps 10
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _extra_presets():
    """Small real-training presets (the assigned archs are dry-run scale)."""
    from repro.models import LayerSpec, ModelConfig

    def small(name, layers, d, heads, ff, vocab=32000):
        return ModelConfig(
            name=name, n_layers=layers, d_model=d, n_heads=heads,
            n_kv_heads=max(heads // 4, 1), d_ff=ff, vocab=vocab,
            pattern=(LayerSpec(),), act_dtype="float32", tie_embeddings=True,
        )

    return {
        "small-lm-16m": lambda: small("small-lm-16m", 4, 256, 4, 1024, vocab=8192),
        "small-lm-100m": lambda: small("small-lm-100m", 12, 768, 12, 3072),
    }


def build_config(arch: str, smoke: bool):
    from repro import configs

    presets = _extra_presets()
    if arch in presets:
        return presets[arch]()
    return configs.smoke_config(arch) if smoke else configs.get_config(arch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-lm-16m")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config of an assigned arch")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/run")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--no-sketch", action="store_true")
    ap.add_argument("--doc-window-capacity", type=int, default=0,
                    help="enable sliding-window per-document coverage telemetry "
                         "with this many tenant slots (0 = off)")
    ap.add_argument("--doc-window-epochs", type=int, default=4,
                    help="ring size E of the per-document window monitor")
    ap.add_argument("--rotate-every", type=int, default=20,
                    help="train steps per window epoch (rotation cadence)")
    ap.add_argument("--doc-window-shards", type=int, default=0,
                    help="shard the doc-window monitor's per-tenant state "
                         "over this many devices of a dedicated 'sketch' "
                         "mesh (0 = single-host WindowMonitor)")
    ap.add_argument("--ingest", action="store_true",
                    help="stream the doc-window telemetry through the async "
                         "micro-batching ingest pipeline (sketchstream/"
                         "ingest.py: donated updates, bounded retire queue) "
                         "instead of updating inside the jitted step; "
                         "requires --doc-window-capacity")
    ap.add_argument("--ingest-batch", type=int, default=32768,
                    help="ingest micro-batch size (fixed staging shape)")
    ap.add_argument("--ingest-queue-depth", type=int, default=4,
                    help="max in-flight ingest batches before backpressure")
    ap.add_argument("--ingest-policy", default="block", choices=("block", "drop"),
                    help="backpressure policy at a full ingest queue")
    ap.add_argument("--n-docs", type=int, default=512,
                    help="distinct document ids the token stream draws from "
                         "when the doc window is enabled")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--metrics-file", default="")
    ap.add_argument("--obs-jsonl", default="",
                    help="append a registry snapshot (delta JSONL) every "
                         "--log-every steps to this path")
    ap.add_argument("--obs-prom", default="",
                    help="write a Prometheus textfile snapshot here every "
                         "--log-every steps (overwritten in place)")
    ap.add_argument("--obs-trace", default="",
                    help="record stage spans and save a Perfetto-loadable "
                         "Chrome trace JSON here at exit")
    ap.add_argument("--obs-sync-every", type=int, default=0,
                    help="sampled block_until_ready cadence for device-time "
                         "attribution in the trace (0 = never sync)")
    ap.add_argument("--abort-after", type=int, default=0,
                    help="simulate preemption: stop after N steps this invocation (tests)")
    args = ap.parse_args(argv)

    from repro.configs import paper_qsketch
    from repro.data.tokens import TokenStream
    from repro.launch.mesh import make_local_mesh, make_sketch_mesh
    from repro.models import common as mcommon, sharding as msharding, transformer
    from repro.obs import export as obs_export, trace as obs_trace
    from repro.sketchstream import monitor
    from repro.train import checkpoint, optimizer, train_step as ts

    # Observability sinks (DESIGN.md §10): spans record only when a trace
    # path is requested; the metrics registry is always live (QOBS_DISABLED
    # turns it off) and the JSONL writer logs per-interval deltas.
    if args.obs_trace or args.obs_sync_every:
        obs_trace.configure(
            enabled=bool(args.obs_trace), sync_every=args.obs_sync_every
        )
    obs_jsonl = (
        obs_export.JsonlWriter(args.obs_jsonl, delta=True)
        if args.obs_jsonl else None
    )

    mesh = make_local_mesh()
    cfg = build_config(args.arch, args.smoke)
    sketch_cfg = None if args.no_sketch else paper_qsketch.telemetry_default()
    # Sliding-window per-document telemetry (DESIGN.md §8.5): the train loop
    # owns the epoch clock — every --rotate-every steps the window rotates,
    # so "distinct tokens per document" is scoped to the trailing E epochs
    # and cold document fingerprints age out of the directory.
    # The monitor only needs a sketch geometry of its own — --no-sketch
    # (scalar token telemetry off) and the doc window compose independently.
    # With --doc-window-shards the same monitor surface runs row-sharded
    # over a dedicated "sketch" mesh (DESIGN.md §8.6): bit-identical
    # estimates, per-tenant state divided across the shard devices.
    # --ingest decouples that telemetry from the step: the jitted train step
    # carries NO tenant state (tenant_monitor=None below), and the per-token
    # (doc, token) elements are pushed host-side into a TenantWindowIngest —
    # micro-batched, donated, asynchronous (DESIGN.md §8.8). Rotation +
    # directory aging run behind the pipeline's retire barrier on the same
    # --rotate-every clock. The ingest window state is telemetry, not model
    # state: it is NOT checkpointed, and a resumed run restarts its window.
    tenant_mon = None
    doc_ingest = None
    if args.doc_window_capacity and args.ingest:
        from repro.core.key_directory import DirectoryConfig
        from repro.sketchstream import ingest as ingest_lib

        tcfg = paper_qsketch.telemetry_default()
        doc_ingest = ingest_lib.TenantWindowIngest(
            tcfg,
            DirectoryConfig(capacity=args.doc_window_capacity, seed=tcfg.seed),
            args.doc_window_epochs,
            ingest_lib.IngestConfig(
                batch_size=args.ingest_batch,
                queue_depth=args.ingest_queue_depth,
                policy=args.ingest_policy,
            ),
            mesh=(make_sketch_mesh(args.doc_window_shards)
                  if args.doc_window_shards else None),
            evict_after=args.doc_window_epochs,
        )
    elif args.doc_window_capacity:
        if args.doc_window_shards:
            tenant_mon = monitor.ShardedWindowMonitor.for_mesh(
                paper_qsketch.telemetry_default(), args.doc_window_capacity,
                args.doc_window_epochs, make_sketch_mesh(args.doc_window_shards),
                evict_after=args.doc_window_epochs,
            )
        else:
            tenant_mon = monitor.WindowMonitor.for_capacity(
                paper_qsketch.telemetry_default(), args.doc_window_capacity,
                args.doc_window_epochs, evict_after=args.doc_window_epochs,
            )
    ocfg = optimizer.OptConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        quantized=args.quantized_opt,
    )

    defs = transformer.model_defs(cfg)
    print(f"[train] arch={cfg.name} params={transformer.count(cfg)/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}", flush=True)

    params = mcommon.init_params(defs, jax.random.PRNGKey(args.seed))
    shardings = msharding.sharding_tree(defs, mesh)
    params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, shardings)
    opt_state, comp_state, sk_state = ts.init_states(
        cfg, ocfg, params, sketch_cfg=sketch_cfg, tenant_monitor=tenant_mon,
        compress=args.compress,
    )

    start_step = 0
    state_tree = {"params": params, "opt": opt_state, "comp": comp_state, "sk": sk_state}
    if not args.no_resume:
        latest = checkpoint.latest_step(args.ckpt_dir)
        if latest is not None:
            state_tree, manifest = checkpoint.restore(args.ckpt_dir, latest, state_tree)
            state_tree = {
                "params": jax.tree.map(lambda x, s: jax.device_put(x, s), state_tree["params"], shardings),
                "opt": jax.tree.map(jnp.asarray, state_tree["opt"]),
                "comp": jax.tree.map(jnp.asarray, state_tree["comp"]),
                "sk": jax.tree.map(jnp.asarray, state_tree["sk"]),
            }
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}", flush=True)

    params, opt_state, comp_state, sk_state = (
        state_tree["params"], state_tree["opt"], state_tree["comp"], state_tree["sk"]
    )

    step_fn = jax.jit(
        ts.make_train_step(
            cfg, ocfg, mesh, sketch_cfg=sketch_cfg, tenant_monitor=tenant_mon,
            compress=args.compress, microbatches=args.microbatches,
        ),
        donate_argnums=(0, 1, 2, 3),
    )

    stream = TokenStream(
        cfg.vocab, args.batch, args.seq, seed=args.seed,
        n_docs=args.n_docs if (tenant_mon is not None or doc_ingest is not None) else 0,
    )
    ckpt = checkpoint.AsyncCheckpointer(args.ckpt_dir)
    metrics_f = open(args.metrics_file, "a") if args.metrics_file else None

    stop = {"flag": False}

    def _sig(_s, _f):
        stop["flag"] = True

    old_handlers = {}
    for s in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[s] = signal.signal(s, _sig)
        except ValueError:
            pass  # non-main thread (tests)

    ema = None
    step = start_step
    try:
        while step < args.steps and not stop["flag"]:
            batch = stream.batch_at(step)
            t0 = time.time()
            with obs_trace.span("train/step", step=step):
                params, opt_state, comp_state, sk_state, metrics = step_fn(
                    params, opt_state, comp_state, sk_state, batch
                )
                metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.time() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > args.straggler_factor * ema and step > start_step + 3:
                print(f"[watchdog] straggler step {step}: {dt:.2f}s vs ema {ema:.2f}s", flush=True)
            if doc_ingest is not None and "doc_ids" in batch:
                # Host-side ingest of the step's (doc, token) elements: one
                # tenant key per token (lo + hi uint32 words), pushed while
                # the NEXT step's device work proceeds — the async overlap
                # the in-step monitor can't have.
                shape = batch["tokens"].shape
                doc_ingest.push(
                    (np.broadcast_to(batch["doc_ids"][:, None], shape).ravel(),
                     np.broadcast_to(batch["doc_ids_hi"][:, None], shape).ravel()),
                    batch["tokens"].astype(np.uint32).ravel(),
                    mask=(batch["tokens_mask"].ravel()
                          if "tokens_mask" in batch else None),
                )
            step += 1
            if doc_ingest is not None and step % args.rotate_every == 0:
                # Epoch tick behind the retire barrier: every earlier element
                # lands in the pre-rotation epoch, then the ring rotates and
                # cold fingerprints age — the synchronous ordering.
                doc_ingest.rotate()
            if tenant_mon is not None and step % args.rotate_every == 0:
                # Epoch tick: rotate the document window (evicting the oldest
                # epoch + aging cold fingerprints) OUTSIDE the jit'd step.
                sk_state = monitor.TelemetryState(
                    scalar=sk_state.scalar,
                    tenants=tenant_mon.rotate(sk_state.tenants),
                )
            if step % args.log_every == 0 or step == args.steps:
                line = {"step": step, "time_s": round(dt, 4), **{k: round(v, 5) for k, v in metrics.items()}}
                if doc_ingest is not None:
                    line.update({
                        k: round(v, 5) if isinstance(v, float) else v
                        for k, v in doc_ingest.metrics().items()
                    })
                print(f"[train] {json.dumps(line)}", flush=True)
                if metrics_f:
                    metrics_f.write(json.dumps(line) + "\n")
                    metrics_f.flush()
                if obs_jsonl is not None:
                    obs_jsonl.write(step=step)
                if args.obs_prom:
                    obs_export.write_prometheus(args.obs_prom)
            if step % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state, "comp": comp_state, "sk": sk_state})
            if args.abort_after and step - start_step >= args.abort_after:
                print(f"[train] simulated preemption at step {step}", flush=True)
                break
    finally:
        # Preemption/exit path: synchronous final save.
        checkpoint.save(args.ckpt_dir, step, jax.device_get(
            {"params": params, "opt": opt_state, "comp": comp_state, "sk": sk_state}
        ))
        ckpt.close()
        if metrics_f:
            metrics_f.close()
        if args.obs_prom:
            obs_export.write_prometheus(args.obs_prom)
        if args.obs_trace:
            obs_trace.save(args.obs_trace)
            print(f"[train] obs trace saved to {args.obs_trace} "
                  "(load at https://ui.perfetto.dev)", flush=True)
        for s, h in old_handlers.items():
            signal.signal(s, h)
    print(f"[train] done at step {step}", flush=True)
    return step


if __name__ == "__main__":
    main()
