"""End-to-end train-loop integration: loss goes down, resume is exact,
elastic re-sharding works, serve loop runs."""

import glob
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_loss_decreases(tmp_path):
    """16M-param LM, 30 steps: loss must drop materially from random init."""
    mfile = str(tmp_path / "metrics.jsonl")
    train_mod.main([
        "--arch", "small-lm-16m", "--steps", "30", "--batch", "4", "--seq", "64",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "100",
        "--log-every", "1", "--metrics-file", mfile, "--lr", "1e-3",
    ])
    import json

    lines = [json.loads(l) for l in open(mfile)]
    first = np.mean([l["loss"] for l in lines[:3]])
    last = np.mean([l["loss"] for l in lines[-3:]])
    assert last < first - 0.5, (first, last)


def test_resume_continues_exactly(tmp_path):
    """Kill after N steps, restart, final state == uninterrupted run."""
    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")
    common = ["--arch", "small-lm-16m", "--batch", "2", "--seq", "32", "--log-every", "100",
              "--ckpt-every", "1000"]
    # Uninterrupted 12 steps.
    train_mod.main(common + ["--steps", "12", "--ckpt-dir", ck_a])
    # Preempted after 6 steps (same --steps so the LR schedule matches),
    # then restarted to completion.
    train_mod.main(common + ["--steps", "12", "--ckpt-dir", ck_b, "--abort-after", "6"])
    train_mod.main(common + ["--steps", "12", "--ckpt-dir", ck_b])

    from repro.train import checkpoint

    sa = checkpoint.latest_step(ck_a)
    sb = checkpoint.latest_step(ck_b)
    assert sa == sb == 12
    # Compare leaf-by-leaf via manifests (structure-free load).
    import json

    ma = json.load(open(os.path.join(ck_a, "step_00000012", "manifest.json")))
    mb = json.load(open(os.path.join(ck_b, "step_00000012", "manifest.json")))
    assert set(ma["leaves"]) == set(mb["leaves"])
    import ml_dtypes

    worst = 0.0
    for key, info in ma["leaves"].items():
        if not key.startswith("params"):
            continue
        a = np.load(os.path.join(ck_a, "step_00000012", info["file"]))
        b = np.load(os.path.join(ck_b, "step_00000012", mb["leaves"][key]["file"]))
        if info["dtype"] == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
            b = b.view(ml_dtypes.bfloat16)
        a, b = a.astype(np.float64), b.astype(np.float64)
        denom = np.abs(a).max() + 1e-9
        worst = max(worst, float(np.abs(a - b).max() / denom))
    # Deterministic data + deterministic math on one device: near-bitwise.
    assert worst < 5e-5, worst


def test_ingest_mode_runs_and_logs_telemetry(tmp_path):
    """--ingest moves the doc-window telemetry out of the jitted step and
    through the async pipeline: the run completes, rotations tick on the
    --rotate-every clock, and every pushed element is accounted for
    (pushed == batch*seq*steps, dropped == 0 under the block policy)."""
    import json

    mfile = str(tmp_path / "metrics.jsonl")
    train_mod.main([
        "--arch", "small-lm-16m", "--smoke", "--steps", "8", "--batch", "2",
        "--seq", "32", "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "100",
        "--log-every", "4", "--metrics-file", mfile,
        "--doc-window-capacity", "64", "--doc-window-epochs", "3",
        "--rotate-every", "4", "--ingest", "--ingest-batch", "128",
    ])
    lines = [json.loads(l) for l in open(mfile)]
    last = lines[-1]
    assert last["ingest_elements_pushed"] == 8 * 2 * 32
    assert last["ingest_elements_dropped"] == 0
    assert last["ingest_rotations"] == 2  # steps 4 and 8
    assert last["tenant_slots_claimed"] > 0
    # The jitted step carries no tenant state in this mode.
    assert "distinct_tokens_est" in last  # scalar telemetry still in-step


def test_elastic_reshard_subprocess(tmp_path):
    """Save under an 8-device mesh, restore+reshard under 4 devices."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp, numpy as np
from repro.models import common as mc, sharding as ms, transformer
from repro.train import checkpoint, elastic
from repro import configs
cfg = configs.smoke_config('qwen3-8b')
defs = transformer.model_defs(cfg)
ck = sys.argv[2]
if sys.argv[3] == 'save':
    mesh = jax.make_mesh((int(sys.argv[1])//2, 2), ('data','model'))
    params = mc.init_params(defs, jax.random.PRNGKey(0))
    params = elastic.reshard_state(params, defs, mesh)
    checkpoint.save(ck, 1, params)
    print('SAVED', len(jax.tree.leaves(params)))
else:
    mesh = jax.make_mesh((int(sys.argv[1])//2, 2), ('data','model'))
    like = mc.init_params(defs, jax.random.PRNGKey(0))
    host, _ = checkpoint.restore(ck, 1, like)
    params = elastic.reshard_state(host, defs, mesh)
    toks = jnp.zeros((2, 16), jnp.int32)
    logits, _, _ = transformer.forward(params, toks, cfg, mesh)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print('RESHARDED-OK', logits.shape)
"""
    ck = str(tmp_path / "ck")
    sf = str(tmp_path / "s.py")
    open(sf, "w").write(script)
    r1 = subprocess.run([sys.executable, sf, "8", ck, "save"], env=ENV, capture_output=True, text=True, timeout=600)
    assert "SAVED" in r1.stdout, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, sf, "4", ck, "load"], env=ENV, capture_output=True, text=True, timeout=600)
    assert "RESHARDED-OK" in r2.stdout, r2.stderr[-2000:]


def test_serve_loop_runs(capsys):
    from repro.launch import serve as serve_mod

    toks = serve_mod.main(["--arch", "qwen3-8b", "--smoke", "--batch", "2", "--prompt-len", "8",
                           "--gen", "4", "--max-len", "16"])
    assert toks.shape == (2, 4)
    out = capsys.readouterr().out
    assert "weighted-DAU" in out
