"""Architecture registry: --arch <id> -> ModelConfig, + reduced smoke variants.

Every assigned architecture has its own module (exact published dims, source
tag in the docstring); ``get_config`` builds the full config, ``smoke_config``
a structurally-identical reduction (same pattern/family/feature flags, tiny
dims) for CPU smoke tests. The FULL configs are only ever lowered via
ShapeDtypeStructs in the dry-run.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import MoEConfig, ModelConfig, SSMConfig

from . import (
    arctic_480b,
    gemma3_27b,
    h2o_danube_1_8b,
    jamba_1_5_large_398b,
    kimi_k2_1t_a32b,
    llava_next_34b,
    mamba2_370m,
    minitron_8b,
    paper_qsketch,
    qwen3_8b,
    shapes,
    whisper_large_v3,
)
from .shapes import SHAPES, input_specs, skip_reason

ARCHS = {
    "jamba-1.5-large-398b": jamba_1_5_large_398b.build,
    "llava-next-34b": llava_next_34b.build,
    "minitron-8b": minitron_8b.build,
    "qwen3-8b": qwen3_8b.build,
    "gemma3-27b": gemma3_27b.build,
    "h2o-danube-1.8b": h2o_danube_1_8b.build,
    "whisper-large-v3": whisper_large_v3.build,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.build,
    "arctic-480b": arctic_480b.build,
    "mamba2-370m": mamba2_370m.build,
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]()


def list_archs():
    return sorted(ARCHS)


def smoke_config(name: str) -> ModelConfig:
    """Family-preserving reduction: same pattern/flags, tiny dims, f32 acts.

    Keeps: layer pattern (incl. a remainder layer when the full config has
    one), MoE routing topology, SSD structure, enc-dec wiring, frontend stubs.
    """
    cfg = get_config(name)
    plen = len(cfg.pattern)
    n_layers = 2 * plen + (1 if cfg.n_remainder else 0)
    moe = cfg.moe and MoEConfig(
        num_experts=min(cfg.moe.num_experts, 4),
        top_k=min(cfg.moe.top_k, 2),
        capacity_factor=2.0,
        dense_residual=cfg.moe.dense_residual,
        shared_expert=cfg.moe.shared_expert,
        d_ff=64 if cfg.moe.d_ff else 0,
    )
    ssm = cfg.ssm and SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        moe=moe,
        ssm=ssm,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        frontend_len=8 if cfg.frontend_len else 0,
        max_seq=64,
        act_dtype="float32",
    )


__all__ = [
    "ARCHS",
    "SHAPES",
    "get_config",
    "smoke_config",
    "list_archs",
    "input_specs",
    "skip_reason",
    "paper_qsketch",
    "shapes",
]
