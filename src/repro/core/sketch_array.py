"""SketchArray: K independent QSketches updated from one keyed stream.

The paper's target settings (per-flow anomaly detection, per-user DAU) need
*many* weighted cardinalities at once — one sketch per flow/user/expert — and
the production-shaped workload is a single stream of ``(key, id, weight)``
triples where ``key`` selects which sketch the element belongs to (Wang et
al., PAPERS.md, make the same observation for user-cardinality monitoring).

Maintaining K ``QSketchState``s in a Python loop costs K dispatches per
batch. ``SketchArray`` instead holds an ``int8[K, m]`` register matrix and
folds a whole keyed batch in ONE fused op:

    y   = quantized_values(cfg, ids, weights)        # (B, m) — same table as
                                                     #   the single-sketch path
    R   = R.at[keys].max(y)                          # segment scatter-max

Because row k only ever receives max-contributions from elements with key k,
and the quantized table is computed by the *same* hash family as
``qsketch.update`` (the key does not enter the hash), row k is bit-identical
to a standalone QSketch fed the key-k sub-stream. All single-sketch algebra
therefore lifts row-wise: merge is element-wise max, estimation is a vmapped
histogram-MLE, and any row can be extracted as a plain ``QSketchState``.

Estimation is "anytime" in the paper's sense but batched: ``estimate_all``
runs the O(2^b) Newton solve for all K sketches as one vmap — O(K·2^b) work
plus a (K, m) bincount, cheap enough to log every step even at K ~ 1e6.

The Pallas path (kernels/sketch_array_update.py via
``kernels.ops.sketch_array_update_op``) computes the identical y-table tile
by tile in VMEM and routes rows with a scatter-max loop; it is bit-identical
to ``update`` here, which is itself bit-identical to the K-loop reference
(tests/test_sketch_array.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import estimation, estimators, key_directory, qsketch
from .types import QSketchState, SketchArrayState, SketchConfig


def init(cfg: SketchConfig, k: int) -> SketchArrayState:
    """K fresh sketches; K is carried by the state shape, cfg stays shared."""
    if k < 1:
        raise ValueError("SketchArray needs k >= 1 sketches")
    return SketchArrayState(regs=jnp.full((k, cfg.m), cfg.r_min, dtype=jnp.int8))


def num_sketches(state: SketchArrayState) -> int:
    """Tenant capacity K (the register matrix's row count)."""
    return state.regs.shape[0]


def row(state: SketchArrayState, k: int) -> QSketchState:
    """Extract sketch k as a standalone (bit-identical) QSketchState.

    Host-side API: ``k`` must be a concrete int in [0, K) — out-of-range
    indices raise instead of silently wrapping python-style.
    """
    n = state.regs.shape[0]
    if not 0 <= k < n:
        raise IndexError(f"sketch row {k} out of range for K={n}")
    return QSketchState(regs=state.regs[k])


@functools.partial(jax.jit, static_argnums=(0,))
def update(
    cfg: SketchConfig, state: SketchArrayState, keys, ids, weights, mask=None
) -> SketchArrayState:
    """One fused pass over a keyed batch: R <- R.at[keys].max(y).

    keys: int[B] in [0, K) routing each element to its sketch row. Out-of-range
      keys are clipped (callers pad with key 0 + mask=False).
    mask: optional bool[B]; False rows contribute r_min everywhere (no-ops),
      exactly as in ``qsketch.update``.
    """
    k = state.regs.shape[0]
    y = qsketch.quantized_values(cfg, ids, weights)
    if mask is not None:
        y = jnp.where(mask[:, None], y, jnp.int8(cfg.r_min))
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    regs = state.regs.astype(jnp.int32).at[keys].max(y.astype(jnp.int32))
    return SketchArrayState(regs=regs.astype(jnp.int8))


def histograms(cfg: SketchConfig, state: SketchArrayState) -> jnp.ndarray:
    """Per-sketch register histograms, int32[K, 2^b]."""
    return jax.vmap(lambda r: estimators.histogram(cfg, r))(state.regs)


def estimate_all(
    cfg: SketchConfig, state: SketchArrayState, *, solver: str = "newton"
) -> jnp.ndarray:
    """Ĉ for every sketch: one batched histogram-MLE, O(K·2^b) + bincount."""
    return estimate_all_with_ci(cfg, state, solver=solver)[0]


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("solver",))
def estimate_all_with_ci(
    cfg: SketchConfig, state: SketchArrayState, *, solver: str = "newton"
):
    """(Ĉ[K], stddev[K], converged[K]) — the batched estimate_with_ci.

    Thin shim over ``estimation.estimate_hists(kind="full")``; ``solver``
    picks newton / lut (DESIGN.md §8.7). Unlike DynArray there is no
    maintained histogram, so every solver pays the vmapped bincount.
    """
    hists = histograms(cfg, state)
    return estimation.estimate_hists_with_ci(cfg, hists, kind="full", solver=solver)


def merge(a: SketchArrayState, b: SketchArrayState) -> SketchArrayState:
    """Row-wise union merge (max monoid) — exact at any scale, as for rows.

    Shapes must agree exactly: a (K, m) mismatch means the operands are not
    sketches of the same tenant space / register geometry, and broadcasting
    would silently cross-contaminate rows.
    """
    if a.regs.shape != b.regs.shape:
        raise ValueError(
            f"SketchArray merge needs matching (K, m), got {a.regs.shape} vs {b.regs.shape}"
        )
    return SketchArrayState(regs=jnp.maximum(a.regs, b.regs))


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    state: SketchArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
):
    """Sparse-tenant entry: route 64-bit tenant ids through the key directory,
    then run the fused keyed update. Returns (state, directory telemetry).

    This is the production-keyed form of ``update`` — raw streams carry
    sparse tenant ids, not dense rows; ``update``'s int[B]-in-[0, K) contract
    is the *slot* contract downstream of ``key_directory.route``.
    """
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != SketchArray rows {state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    return update(cfg, state, slots, ids, weights, mask=mask), dir_state


def update_reference(
    cfg: SketchConfig, state: SketchArrayState, keys, ids, weights, mask=None
) -> SketchArrayState:
    """Oracle: partition the stream by key, run K independent single-sketch
    updates. O(K) dispatches — tests/benchmarks only, never the hot path.

    ``mask`` mirrors the fused path: masked-off rows are dropped from their
    key's sub-stream entirely, so the oracle verifies padded batches too.
    """
    import numpy as np

    keys_np = np.asarray(keys)
    live = np.ones(keys_np.shape, bool) if mask is None else np.asarray(mask)
    regs = [None] * state.regs.shape[0]
    for k in range(state.regs.shape[0]):
        sel = (keys_np == k) & live
        st_k = QSketchState(regs=state.regs[k])
        if sel.any():
            st_k = qsketch.update(cfg, st_k, ids[sel], weights[sel])
        regs[k] = st_k.regs
    return SketchArrayState(regs=jnp.stack(regs))
