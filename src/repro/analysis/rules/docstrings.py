"""docstrings — every public symbol in the sketch library is documented.

The library's contracts live in docstrings — shape/dtype conventions
(int8[K, m] registers, touched-register histograms, replicated ring
scalars), merge semantics (max monoid vs martingale additivity), and
padding/masking rules. A public function without one is an API the next
reader has to reverse-engineer, so tier-2 fails the build instead.

Checked per module: the module docstring, public module-level functions
and classes, and public methods of public classes (dunders and private
helpers exempt — the class docstring owns construction). Scope: ``core/``,
``sketchstream/``, ``kernels/``, ``obs/``, and ``analysis/`` itself (qlint
eats its own dog food).

This rule absorbs the former standalone ``scripts/check_docstrings.py``
(which now delegates here).
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

SCOPE = (
    "src/repro/core/",
    "src/repro/sketchstream/",
    "src/repro/kernels/",
    "src/repro/analysis/",
    "src/repro/obs/",
)


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def check_tree(tree: ast.Module, rel: str, rule_name: str = "docstrings") -> list[Finding]:
    """Findings for every missing docstring in one parsed module."""
    findings = []
    if not ast.get_docstring(tree):
        findings.append(Finding(rule_name, rel, 1, "missing module docstring"))
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name) and not ast.get_docstring(node):
                findings.append(
                    Finding(
                        rule_name, rel, node.lineno,
                        f"function '{node.name}' has no docstring",
                    )
                )
        elif isinstance(node, ast.ClassDef) and _is_public(node.name):
            if not ast.get_docstring(node):
                findings.append(
                    Finding(
                        rule_name, rel, node.lineno,
                        f"class '{node.name}' has no docstring",
                    )
                )
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if item.name.startswith("_"):  # dunders + private helpers
                    continue
                if not ast.get_docstring(item):
                    findings.append(
                        Finding(
                            rule_name, rel, item.lineno,
                            f"method '{node.name}.{item.name}' has no docstring",
                        )
                    )
    return findings


@register
class DocstringsRule(Rule):
    """Flag missing docstrings on public symbols across the library scope."""

    name = "docstrings"
    description = (
        "module, public function/class, and public-method docstrings are "
        "required in core/, sketchstream/, kernels/, analysis/"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        findings: list[Finding] = []
        for mod in ctx.iter_modules(SCOPE):
            if not ctx.is_selected(mod.rel):
                continue
            findings += check_tree(mod.tree, mod.rel, self.name)
        return findings
