"""Pallas TPU kernel: batched QSketch register update (the paper's hot loop).

The paper's Alg. 2 spends its time generating m exponential variables per
element and folding them into m registers. On TPU the natural schedule is a
2-D grid over (register blocks × batch blocks): each kernel invocation

  1. regenerates the hash bits for its (B_blk × M_blk) tile *in VMEM* with
     pure integer VPU ops (no HBM traffic for the randomness — this is the
     fusion win over a materialize-then-reduce XLA schedule),
  2. quantizes y = floor(log2 w - log2(-ln u)) (Eq. 5),
  3. max-reduces over the batch rows, and
  4. accumulates into the output register block across the batch grid axis.

Layout: registers live on the 128-wide lane axis (M_blk a multiple of 128),
batch on the 8-deep sublane axis (B_blk a multiple of 8). The (B,1)-shaped
id/weight columns broadcast along lanes. Registers are int32 in-kernel
(int8 packing happens at the state boundary in ops.py; VMEM cost of the
register block is negligible next to the generation tile).

Grid iteration order is (m_block, batch_block) with the batch axis innermost
("arbitrary" semantics): the output block for a given m_block stays resident
in VMEM while all batch blocks stream through it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from . import compat

from repro.core import hashing

# Default tile: 256 x 512 f32 intermediate = 512 KiB VMEM, well under budget.
DEFAULT_BLOCK_B = 256
DEFAULT_BLOCK_M = 512


def _tile_y(ids_lo, ids_hi, log2w, j0, block_m, salt, r_min, r_max):
    """Quantized values y' for a (B_blk, M_blk) tile; shared by both kernels."""
    bb = ids_lo.shape[0]
    j = jax.lax.broadcasted_iota(jnp.uint32, (bb, block_m), 1) + j0
    e = hashing.neg_log_uniform((ids_lo, ids_hi, j), salt)
    y = jnp.floor(log2w - jnp.log2(e))
    return jnp.clip(y, float(r_min), float(r_max)).astype(jnp.int32)


def _qsketch_kernel(ids_lo_ref, ids_hi_ref, log2w_ref, regs_ref, out_ref, *, block_m, salt, r_min, r_max, nbatch):
    bi = pl.program_id(1)  # batch-block index (innermost)
    mi = pl.program_id(0)  # register-block index

    j0 = (mi * block_m).astype(jnp.uint32)
    y = _tile_y(
        ids_lo_ref[...], ids_hi_ref[...], log2w_ref[...], j0, block_m, salt, r_min, r_max
    )
    tile_max = jnp.max(y, axis=0, keepdims=True)  # (1, M_blk)

    @pl.when(bi == 0)
    def _init():
        out_ref[...] = jnp.maximum(regs_ref[...], tile_max)

    @pl.when(bi > 0)
    def _accum():
        out_ref[...] = jnp.maximum(out_ref[...], tile_max)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "salt", "r_min", "r_max", "interpret")
)
def qsketch_update_padded(
    ids_lo,
    ids_hi,
    log2w,
    regs,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    salt: int,
    r_min: int,
    r_max: int,
    interpret: bool = False,
):
    """Kernel entry on pre-padded operands.

    ids_lo/ids_hi: (B, 1) uint32, B % block_b == 0. Padding rows must carry
      log2w = -inf (their y clips to r_min -> no-ops under max).
    log2w: (B, 1) float32.
    regs: (1, M) int32, M % block_m == 0.
    Returns updated (1, M) int32 registers.
    """
    b = ids_lo.shape[0]
    m = regs.shape[1]
    grid = (m // block_m, b // block_b)

    kernel = functools.partial(
        _qsketch_kernel,
        block_m=block_m,
        salt=salt,
        r_min=r_min,
        r_max=r_max,
        nbatch=b // block_b,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((1, block_m), lambda mi, bi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi, bi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.int32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ids_lo, ids_hi, log2w, regs)


def _float_kernel(ids_lo_ref, ids_hi_ref, w_ref, regs_ref, out_ref, *, block_m, salt, big):
    """LM-family float min-sketch tile: r = -ln(u)/w, min-accumulate.

    Padding rows are flagged with w <= 0 and masked to +big (an e/w division
    rather than e * (1/w) keeps the rounding bit-identical to the jnp core).
    """
    bi = pl.program_id(1)
    mi = pl.program_id(0)
    bb = ids_lo_ref.shape[0]

    j0 = (mi * block_m).astype(jnp.uint32)
    j = jax.lax.broadcasted_iota(jnp.uint32, (bb, block_m), 1) + j0
    e = hashing.neg_log_uniform((ids_lo_ref[...], ids_hi_ref[...], j), salt)
    w = w_ref[...]
    r = jnp.where(w > 0, e / w, big)
    tile_min = jnp.min(r, axis=0, keepdims=True)

    @pl.when(bi == 0)
    def _init():
        out_ref[...] = jnp.minimum(regs_ref[...], tile_min)

    @pl.when(bi > 0)
    def _accum():
        out_ref[...] = jnp.minimum(out_ref[...], tile_min)


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "salt", "interpret"))
def float_sketch_update_padded(
    ids_lo,
    ids_hi,
    w,
    regs,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    salt: int,
    interpret: bool = False,
):
    """LM/FastGM-family fused update (min semantics, float32 registers)."""
    b = ids_lo.shape[0]
    m = regs.shape[1]
    grid = (m // block_m, b // block_b)
    kernel = functools.partial(_float_kernel, block_m=block_m, salt=salt, big=jnp.finfo(jnp.float32).max)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((block_b, 1), lambda mi, bi: (bi, 0)),
            pl.BlockSpec((1, block_m), lambda mi, bi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi, bi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ids_lo, ids_hi, w, regs)
