"""VirtualDynArray tests: jnp/oracle/kernel bit-identity on every state
field, the incremental-full-histogram invariant, merge algebra, promotion
semantics (epoch fence vs migrate, no double count), noise-cancelled
estimator sanity, and the monitor threading.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    dyn_array,
    key_directory,
    virtual_dyn_array as vda,
)
from repro.core.virtual_dyn_array import VirtualConfig
from repro.kernels import ops
from repro.obs import health
from repro.sketchstream import monitor


def _stream(n, n_tenants, seed, wlo=0.5, whi=1.5):
    """Sparse 64-bit tenant keys ((lo, hi) pair) + element ids + weights."""
    rng = np.random.default_rng(seed)
    tids = rng.integers(0, 1 << 63, n_tenants, dtype=np.uint64)
    tk = tids[rng.integers(0, n_tenants, n)]
    ids = rng.integers(0, 1 << 63, n, dtype=np.uint64)
    t = (jnp.asarray(tk & 0xFFFFFFFF, jnp.uint32), jnp.asarray(tk >> 32, jnp.uint32))
    i = (jnp.asarray(ids & 0xFFFFFFFF, jnp.uint32), jnp.asarray(ids >> 32, jnp.uint32))
    w = jnp.asarray(rng.uniform(wlo, whi, n), jnp.float32)
    return tids, t, i, w


def _assert_states_equal(a, b, chat_rtol=0.0):
    np.testing.assert_array_equal(np.asarray(a.pool), np.asarray(b.pool))
    np.testing.assert_array_equal(np.asarray(a.pool_hist), np.asarray(b.pool_hist))
    np.testing.assert_array_equal(np.asarray(a.n_tail), np.asarray(b.n_tail))
    np.testing.assert_array_equal(np.asarray(a.w_tail), np.asarray(b.w_tail))
    np.testing.assert_array_equal(np.asarray(a.hot.regs), np.asarray(b.hot.regs))
    np.testing.assert_array_equal(np.asarray(a.hot.hists), np.asarray(b.hot.hists))
    if chat_rtol:
        np.testing.assert_allclose(
            np.asarray(a.hot.chats), np.asarray(b.hot.chats), rtol=chat_rtol
        )
    else:
        np.testing.assert_array_equal(
            np.asarray(a.hot.chats), np.asarray(b.hot.chats)
        )


@pytest.mark.parametrize("m_virtual", [None, 96])
def test_update_matches_oracle_and_kernel(m_virtual):
    """jnp path == sequential numpy oracle == Pallas-backed op, on ALL five
    state fields, for both the default and a widened virtual row geometry."""
    cfg = SketchConfig(m=64, b=6, seed=11)
    tids, t, i, w = _stream(300, 24, seed=5)
    vcfg = VirtualConfig(
        pool_size=1024, m_virtual=m_virtual, pinned=tuple(int(x) for x in tids[:3])
    )
    st0 = vda.init(cfg, vcfg)

    st = vda.update_tenants(cfg, vcfg, st0, t, i, w)
    ref = vda.update_reference(cfg, vcfg, st0, t, i, w)
    _assert_states_equal(st, ref)
    kst = ops.virtual_dyn_update_op(cfg, vcfg, st0, t, i, w)
    _assert_states_equal(st, kst)

    # Warm-state second batch: hot q_R reads nonzero hists, pool has load.
    _, t2, i2, w2 = _stream(300, 24, seed=6)
    st2 = vda.update_tenants(cfg, vcfg, st, t2, i2, w2)
    _assert_states_equal(st2, vda.update_reference(cfg, vcfg, ref, t2, i2, w2))
    _assert_states_equal(st2, ops.virtual_dyn_update_op(cfg, vcfg, kst, t2, i2, w2))


def test_mask_drops_rows_everywhere():
    """Masked rows touch neither tier nor the n_tail/w_tail accumulators,
    identically across the jnp, oracle, and kernel entries."""
    cfg = SketchConfig(m=32, b=6, seed=2)
    tids, t, i, w = _stream(128, 10, seed=7)
    vcfg = VirtualConfig(pool_size=512, pinned=(int(tids[0]),))
    mask = jnp.asarray(np.random.default_rng(0).random(128) < 0.7)
    st0 = vda.init(cfg, vcfg)

    st = vda.update_tenants(cfg, vcfg, st0, t, i, w, mask=mask)
    _assert_states_equal(st, vda.update_reference(cfg, vcfg, st0, t, i, w, mask=np.asarray(mask)))
    _assert_states_equal(st, ops.virtual_dyn_update_op(cfg, vcfg, st0, t, i, w, mask=mask))
    # Equivalent to dropping the masked rows up front.
    keep = np.asarray(mask)
    tkept = (t[0][keep], t[1][keep])
    ikept = (i[0][keep], i[1][keep])
    _assert_states_equal(
        st, vda.update_tenants(cfg, vcfg, st0, tkept, ikept, w[keep])
    )


def test_pool_hist_invariant_and_load_factor():
    """Incrementally maintained pool_hist == from-scratch rebuild; bins sum
    to M; load factor is the untouched-slot complement."""
    cfg = SketchConfig(m=32, b=5, seed=4)
    vcfg = VirtualConfig(pool_size=256)
    _, t, i, w = _stream(400, 40, seed=8)
    st = vda.update_tenants(cfg, vcfg, vda.init(cfg, vcfg), t, i, w)
    np.testing.assert_array_equal(
        np.asarray(st.pool_hist), np.asarray(vda.rebuild_pool_hist(cfg, st.pool))
    )
    assert int(jnp.sum(st.pool_hist)) == vcfg.pool_size
    lf = float(vda.pool_load_factor(st))
    assert lf == pytest.approx(float(jnp.mean(st.pool > cfg.r_min)))
    assert 0.0 < lf < 1.0


def test_merge_equals_single_stream():
    """Disjoint split-and-merge == one stream: pool/hist/counters/hot all
    agree (chats re-estimated by the dense merge convention)."""
    cfg = SketchConfig(m=32, b=6, seed=9)
    tids, t, i, w = _stream(256, 16, seed=10)
    vcfg = VirtualConfig(pool_size=512, pinned=(int(tids[0]),))
    st0 = vda.init(cfg, vcfg)
    h = 128
    a = vda.update_tenants(cfg, vcfg, st0, (t[0][:h], t[1][:h]), (i[0][:h], i[1][:h]), w[:h])
    b = vda.update_tenants(cfg, vcfg, st0, (t[0][h:], t[1][h:]), (i[0][h:], i[1][h:]), w[h:])
    ab = vda.merge(cfg, vcfg, a, b)
    ba = vda.merge(cfg, vcfg, b, a)
    whole = vda.update_tenants(cfg, vcfg, st0, t, i, w)

    np.testing.assert_array_equal(np.asarray(ab.pool), np.asarray(whole.pool))
    np.testing.assert_array_equal(np.asarray(ab.pool_hist), np.asarray(whole.pool_hist))
    np.testing.assert_array_equal(np.asarray(ab.pool), np.asarray(ba.pool))
    assert int(ab.n_tail) == int(whole.n_tail)
    np.testing.assert_allclose(float(ab.w_tail), float(whole.w_tail), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ab.hot.regs), np.asarray(whole.hot.regs))
    # Self-merge is register-idempotent; the scalars double (documented).
    aa = vda.merge(cfg, vcfg, a, a)
    np.testing.assert_array_equal(np.asarray(aa.pool), np.asarray(a.pool))
    assert float(aa.w_tail) == pytest.approx(2 * float(a.w_tail))


def test_hot_tier_bit_identical_to_dedicated_dyn_array():
    """Pinned tenants' rows and chats match a dedicated DynArray fed only
    the hot sub-stream — the exactness half of the tiering contract."""
    cfg = SketchConfig(m=64, b=6, seed=12)
    tids, t, i, w = _stream(300, 12, seed=13)
    pinned = tuple(int(x) for x in tids[:4])
    vcfg = VirtualConfig(pool_size=512, pinned=pinned)
    st = vda.update_tenants(cfg, vcfg, vda.init(cfg, vcfg), t, i, w)

    # Dedicated dense array fed the hot sub-stream, rows in pinned order.
    tk64 = (np.asarray(t[0], np.uint64) | (np.asarray(t[1], np.uint64) << 32))
    slot_of = {p: s for s, p in enumerate(pinned)}
    sel = np.isin(tk64, np.asarray(pinned, np.uint64))
    keys = jnp.asarray([slot_of[int(x)] for x in tk64[sel]], jnp.int32)
    dst = dyn_array.update_batch(
        cfg, dyn_array.init(cfg, len(pinned)), keys,
        (i[0][sel], i[1][sel]), w[sel],
    )
    np.testing.assert_array_equal(np.asarray(st.hot.regs), np.asarray(dst.regs))
    np.testing.assert_array_equal(np.asarray(st.hot.hists), np.asarray(dst.hists))
    np.testing.assert_array_equal(np.asarray(st.hot.chats), np.asarray(dst.chats))
    # And the estimate read IS the martingale (pool contributes nothing).
    est = vda.estimate_tenants(cfg, vcfg, st, (t[0][sel][:4], t[1][sel][:4]))
    mart = dst.chats[keys[:4]]
    np.testing.assert_array_equal(np.asarray(est), np.asarray(mart))


def test_promote_epoch_fence_and_migrate():
    """Satellite 3: the two documented residue semantics, plus the guards."""
    cfg = SketchConfig(m=32, b=6, seed=14)
    vcfg = VirtualConfig(pool_size=512)
    tids, t, i, w = _stream(200, 8, seed=15)
    st = vda.update_tenants(cfg, vcfg, vda.init(cfg, vcfg), t, i, w)
    tenant = int(tids[0])
    tq = key_directory.split_uint64([tenant])

    # Epoch fence: fresh row, estimate restarts at exactly 0.
    vcfg_f, st_f = vda.promote(cfg, vcfg, st, tenant)
    assert vcfg_f.pinned == (tenant,) and vcfg_f.num_hot == 1
    assert float(vda.estimate_tenants(cfg, vcfg_f, st_f, tq)[0]) == 0.0
    # The pool plane itself is untouched by promotion.
    np.testing.assert_array_equal(np.asarray(st_f.pool), np.asarray(st.pool))

    # Migrate: the dense row seeds from the virtual row, estimate > 0 and
    # bounded by virtual read + noise floor (the seed inherits pool noise).
    vcfg_m, st_m = vda.promote(cfg, vcfg, st, tenant, migrate=True)
    est_m = float(vda.estimate_tenants(cfg, vcfg_m, st_m, tq)[0])
    assert est_m > 0.0
    rows = vda.virtual_rows(cfg, vcfg, st, *tq)
    np.testing.assert_array_equal(np.asarray(st_m.hot.regs[-1]), np.asarray(rows[0]))

    # No double count: re-sending the tenant's own elements after migration
    # leaves registers (max-idempotent) and the chat unchanged.
    tk64 = (np.asarray(t[0], np.uint64) | (np.asarray(t[1], np.uint64) << 32))
    sel = tk64 == np.uint64(tenant)
    st_m2 = vda.update_tenants(
        cfg, vcfg_m, st_m, (t[0][sel], t[1][sel]), (i[0][sel], i[1][sel]), w[sel]
    )
    np.testing.assert_array_equal(
        np.asarray(st_m2.hot.regs[-1]), np.asarray(st_m.hot.regs[-1])
    )
    np.testing.assert_array_equal(
        np.asarray(st_m2.hot.chats[-1]), np.asarray(st_m.hot.chats[-1])
    )

    # Guards: double-pin; migrate under a mismatched virtual geometry.
    with pytest.raises(ValueError, match="already pinned"):
        vda.promote(cfg, vcfg_f, st_f, tenant)
    vcfg_w = VirtualConfig(pool_size=512, m_virtual=16)
    st_w = vda.update_tenants(cfg, vcfg_w, vda.init(cfg, vcfg_w), t, i, w)
    with pytest.raises(ValueError, match="m_virtual"):
        vda.promote(cfg, vcfg_w, st_w, tenant, migrate=True)
    vda.promote(cfg, vcfg_w, st_w, tenant)  # epoch fence still fine


def test_key_directory_pin_semantics():
    """Satellite 3, dense half: ``key_directory.pin`` appends to the hot
    table with the documented re-keying behavior — the pinned tenant gets
    the new dedicated slot, hashed tenants may move (which is exactly why
    the virtual tier's ``promote`` exists)."""
    from repro.core.key_directory import DirectoryConfig

    dcfg = DirectoryConfig(capacity=16, seed=3)
    rng = np.random.default_rng(1)
    tids = rng.integers(0, 1 << 63, 64, dtype=np.uint64)
    t = key_directory.split_uint64([int(x) for x in tids])
    before = np.asarray(key_directory.route_slots(dcfg, t))

    tenant = int(tids[0])
    d2 = key_directory.pin(dcfg, tenant)
    assert d2.pinned == (tenant,) and d2.capacity == 16
    after = np.asarray(key_directory.route_slots(d2, t))
    assert after[0] == 0  # the dedicated hot slot
    # Hashed range shifted to [1, 16): the re-keying footgun is real.
    assert (after[1:] >= 1).all() and (after[1:] < 16).all()
    assert (after[1:] != before[1:]).any()

    # grow=True preserves the hashed modulus: one extra row, nobody moves.
    d3 = key_directory.pin(dcfg, tenant, grow=True)
    assert d3.capacity == 17
    grown = np.asarray(key_directory.route_slots(d3, t))
    hashed = np.asarray([int(x) != tenant for x in tids])
    np.testing.assert_array_equal(grown[hashed], before[hashed] + 1)

    with pytest.raises(ValueError, match="already pinned"):
        key_directory.pin(d2, tenant)


def test_noise_cancelled_estimates_track_truth():
    """Statistical sanity at the validated regime (not bit-exactness): tail
    reads above the noise floor land within 2x of truth on average, and
    unknown tenants read ~0 (at the floor's scale, not the signal's)."""
    cfg = SketchConfig(m=128, b=8, seed=3)
    vcfg = VirtualConfig(pool_size=1 << 14)
    rng = np.random.default_rng(42)
    n_tenants = 64
    sizes = np.clip((800 / (np.arange(1, n_tenants + 1) ** 1.05)).astype(int), 40, None)
    tids = rng.integers(0, 1 << 63, n_tenants, dtype=np.uint64)
    tk = np.repeat(tids, sizes)
    ids = rng.integers(0, 1 << 63, tk.shape[0], dtype=np.uint64)
    w = rng.uniform(0.5, 1.5, tk.shape[0]).astype(np.float32)
    order = rng.permutation(tk.shape[0])
    tk, ids, w = tk[order], ids[order], w[order]
    truth = {int(t): float(w[tk == t].sum()) for t in tids}

    st = vda.update_tenants(
        cfg, vcfg, vda.init(cfg, vcfg),
        (jnp.asarray(tk & 0xFFFFFFFF, jnp.uint32), jnp.asarray(tk >> 32, jnp.uint32)),
        (jnp.asarray(ids & 0xFFFFFFFF, jnp.uint32), jnp.asarray(ids >> 32, jnp.uint32)),
        jnp.asarray(w),
    )
    assert float(st.w_tail) == pytest.approx(w.sum(), rel=1e-4)
    floor = float(vda.noise_floor(cfg, vcfg, st))
    tq = key_directory.split_uint64([int(x) for x in tids])
    est = np.asarray(vda.estimate_tenants(cfg, vcfg, st, tq))
    true = np.asarray([truth[int(x)] for x in tids])
    above = true > 2 * floor
    assert above.sum() >= 8  # the regime actually exercises the claim
    rel = np.abs(est[above] - true[above]) / true[above]
    assert rel.mean() < 0.5
    # Unknown tenants: mostly-untouched rows clamp near zero.
    ghosts = key_directory.split_uint64(
        [int(x) for x in rng.integers(0, 1 << 63, 16, dtype=np.uint64)]
    )
    ghost_est = np.asarray(vda.estimate_tenants(cfg, vcfg, st, ghosts))
    assert np.median(ghost_est) <= floor


def test_memory_accounting_and_config_guards():
    cfg = SketchConfig(m=128, b=8, seed=0)
    vcfg = VirtualConfig(pool_size=1 << 16, pinned=(1, 2))
    st = vda.init(cfg, vcfg)
    assert vda.memory_bytes(cfg, vcfg) == (
        st.pool.nbytes + st.pool_hist.nbytes + 4 + 4
        + st.hot.regs.nbytes + st.hot.hists.nbytes + st.hot.chats.nbytes
    )
    # The point of the tier: virtual bytes are K-independent.
    k = 10**7
    assert vda.dense_memory_bytes(cfg, k) / vda.memory_bytes(cfg, vcfg) > 10
    with pytest.raises(ValueError):
        VirtualConfig(pool_size=2)
    with pytest.raises(ValueError):
        VirtualConfig(pool_size=64, m_virtual=1)
    with pytest.raises(ValueError):
        VirtualConfig(pool_size=64, pinned=(5, 5))
    with pytest.raises(ValueError):
        vda.init(cfg, VirtualConfig(pool_size=64))  # pool smaller than m


def test_monitor_surface_and_health():
    """VirtualDynMonitor threads the usual surface; health_report grows the
    pool checks and folds the hot tier under a hot_ prefix."""
    cfg = SketchConfig(m=32, b=6, seed=21)
    tids, t, i, w = _stream(256, 12, seed=22)
    mon = monitor.VirtualDynMonitor.for_pool(cfg, 512, pinned=(int(tids[0]),))
    st = mon.init()
    st = mon.update(st, t, i, w)
    assert int(st.n_seen) == 256
    est = mon.estimate(st, (t[0][:4], t[1][:4]))
    assert est.shape == (4,) and bool(jnp.all(est >= 0))
    m = mon.metrics(st)
    assert 0 < float(m["virtual_pool_load_factor"]) < 1
    assert float(m["virtual_pool_weight_total"]) == pytest.approx(
        float(st.array.w_tail)
    )
    mon2, st2 = mon.promote(st, int(tids[1]))
    assert mon2.vcfg.num_hot == 2 and st2.array.hot.regs.shape[0] == 2

    rep = health.health_report(cfg, st.array, vcfg=mon.vcfg)
    assert rep["container"] == "virtual_dyn_array"
    assert "pool_load_factor" in rep["checks"]
    assert any(k.startswith("hot_") for k in rep["checks"])
    # Threshold gating both ways.
    tight = health.Thresholds(pool_load_factor=0.0, pool_noise_floor=1e-6)
    assert "pool_load_factor" in health.health_report(
        cfg, st.array, vcfg=mon.vcfg, thresholds=tight
    )["warnings"]
    loose = health.Thresholds(pool_load_factor=1.0, pool_noise_floor=None)
    rep_l = health.health_report(cfg, st.array, vcfg=mon.vcfg, thresholds=loose)
    assert "pool_load_factor" not in rep_l["warnings"]
    assert not rep_l["checks"]["pool_noise_floor"]["warn"]
