"""DynArray: fused keyed Dyn update vs the K-loop oracle, and the headline —
O(K)-anytime estimate reads vs the SketchArray vmapped Newton.

Two questions this suite answers (ROADMAP: "estimate_all at K ~ 1e6"):

  * update — what does maintaining per-key histograms + martingales cost per
    element vs (a) the naive K-loop of single ``qsketch_dyn`` sketches
    (dispatch-bound, like the SketchArray naive loop) and (b) the plain
    ``sketch_array`` update that defers all estimation cost to query time?
  * estimate — at K ∈ {2^10 .. 2^20}, how does reading the running chats
    (``dyn_array.estimate_all``, a device->host transfer of K floats)
    compare to ``sketch_array.estimate_all`` (O(K·2^b) vmapped Newton)? The
    acceptance bar is >= 100x at K = 2^20.

The sweep is cumulative: quick/smoke runs re-measure only the small-K cells
and MERGE into experiments/bench/dyn_array.json, preserving the paper-scale
K = 2^20 rows produced by ``--full`` — otherwise every CI smoke would erase
the expensive evidence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SketchArrayState,
    SketchConfig,
    dyn_array,
    qsketch_dyn,
    sketch_array,
)

from . import common


def run(quick=True):
    rows = []

    # --- fused DynArray vs K-loop of single Dyn sketches -------------------
    n_keys, m, batch = 256, 128, 4096
    n_batches = 4 if quick else 10
    cfg = SketchConfig(m=m, b=8, seed=5)
    batches = common.keyed_batches(n_keys, n_batches, batch, seed=7)

    eps_fused, st_fused = common.keyed_throughput(
        lambda s, k, i, w: dyn_array.update_batch(cfg, s, k, i, w),
        dyn_array.init(cfg, n_keys),
        batches,
    )

    def loop_update(states, keys, ids, w):
        keys_np = np.asarray(keys)
        order = np.argsort(keys_np, kind="stable")
        ids_np, w_np = np.asarray(ids)[order], np.asarray(w)[order]
        bounds = np.searchsorted(keys_np[order], np.arange(n_keys + 1))
        for k in range(n_keys):
            lo, hi = bounds[k], bounds[k + 1]
            if lo == hi:
                continue
            states[k] = qsketch_dyn.update_batch(
                cfg, states[k], jnp.asarray(ids_np[lo:hi]), jnp.asarray(w_np[lo:hi])
            )
        return states

    eps_loop, states_loop = common.keyed_throughput(
        loop_update, [qsketch_dyn.init(cfg) for _ in range(n_keys)], batches
    )
    # The schedules must agree: registers/hists bitwise, chats to f32 noise.
    loop_regs = np.stack([np.asarray(s.regs) for s in states_loop])
    if not np.array_equal(np.asarray(st_fused.regs), loop_regs):
        raise AssertionError("fused and K-loop DynArray registers diverged")
    loop_chats = np.array([float(s.chat) for s in states_loop])
    if not np.allclose(np.asarray(st_fused.chats), loop_chats, rtol=1e-4):
        raise AssertionError("fused and K-loop DynArray chats diverged")

    speedup = eps_fused / eps_loop
    rows += [
        {"figure": "dyn_array_throughput", "method": "fused", "k": n_keys, "m": m, "mops": eps_fused / 1e6},
        {"figure": "dyn_array_throughput", "method": "k_loop", "k": n_keys, "m": m, "mops": eps_loop / 1e6},
        {"figure": "dyn_array_throughput", "method": "speedup", "k": n_keys, "m": m, "x": speedup},
    ]
    common.csv_row(f"dyn_array/K{n_keys}/m{m}/fused", 1e6 / eps_fused, f"mops={eps_fused/1e6:.3f}")
    common.csv_row(f"dyn_array/K{n_keys}/m{m}/k_loop", 1e6 / eps_loop, f"mops={eps_loop/1e6:.3f}")
    common.csv_row(f"dyn_array/K{n_keys}/m{m}/speedup", 0.0, f"fused/loop={speedup:.1f}x")

    # --- anytime read vs vmapped-Newton estimate_all, K sweep --------------
    m_est, batch_est = 128, 65536
    ks = [2**10, 2**14] if quick else [2**10, 2**14, 2**17, 2**20]
    for k in ks:
        cfg_k = SketchConfig(m=m_est, b=8, seed=17)
        # Load enough traffic that most rows are live: Newton on an untouched
        # row exits immediately and would undersell the MLE cost.
        n_load = max(4 * k, batch_est)
        dyn_st = dyn_array.init(cfg_k, k)
        arr_st = sketch_array.init(cfg_k, k)
        rng = np.random.default_rng(k)
        for i in range(0, n_load, batch_est):
            keys = jnp.asarray(rng.integers(0, k, batch_est, dtype=np.int32))
            ids = jnp.asarray(rng.integers(0, 2**32, batch_est, dtype=np.uint32))
            w = jnp.asarray((rng.gamma(1.0, 2.0, batch_est) + 1e-5).astype(np.float32))
            dyn_st = dyn_array.update_batch(cfg_k, dyn_st, keys, ids, w)
            arr_st = sketch_array.update(cfg_k, arr_st, keys, ids, w)
        jax.block_until_ready((dyn_st.chats, arr_st.regs))
        live = float(np.mean(np.asarray(dyn_st.chats) > 0))

        iters = 3 if k <= 2**14 else 1
        t_read = common.time_fn(
            lambda s: np.asarray(dyn_array.estimate_all(s)), dyn_st, warmup=1, iters=iters
        )
        t_newton = common.time_fn(
            lambda r: sketch_array.estimate_all(cfg_k, SketchArrayState(regs=r)),
            arr_st.regs, warmup=1, iters=iters,
        )
        x = t_newton / max(t_read, 1e-9)
        rows += [
            {"figure": "dyn_array_estimate", "method": "anytime_read", "k": k, "m": m_est, "ms": t_read * 1e3, "live_frac": live},
            {"figure": "dyn_array_estimate", "method": "newton_mle", "k": k, "m": m_est, "ms": t_newton * 1e3, "live_frac": live},
            {"figure": "dyn_array_estimate", "method": "speedup", "k": k, "m": m_est, "x": x},
        ]
        common.csv_row(f"dyn_array_estimate/K{k}/anytime_read", t_read * 1e6, f"ms={t_read*1e3:.3f}")
        common.csv_row(f"dyn_array_estimate/K{k}/newton_mle", t_newton * 1e6, f"ms={t_newton*1e3:.1f}")
        common.csv_row(
            f"dyn_array_estimate/K{k}/speedup", 0.0, f"newton/read={x:.0f}x (>=100x required at K=2^20)"
        )

    common.merge_save("dyn_array", rows, {n_keys, *ks})
    return rows


def run_sharded(quick=True):
    """ShardedDynArray vs the single-host DynArray: hash-routed update
    throughput and the O(K)-anytime read as K grows past one host.

    Uses every visible device as a shard of the ``sketch`` mesh axis (run
    under scripts/test.sh / XLA_FLAGS for the 8-device host mesh). The two
    schedules are bit-identical on every leaf — chats included — so the
    deltas are pure shard_map routing overhead vs register/histogram
    residency (DESIGN.md §8.6); bit-identity is asserted per cell. The
    sweep is cumulative over K cells into
    experiments/bench/dyn_array_sharded.json (common.merge_save), so smoke
    runs never erase paper-scale rows.
    """
    from repro.core import sharded_dyn_array
    from repro.launch.mesh import make_sketch_mesh

    mesh = make_sketch_mesh()
    n_dev = sharded_dyn_array.num_shards(mesh)
    m, batch = 128, 8192
    n_batches = 4 if quick else 10
    ks = [2**10, 2**13] if quick else [2**10, 2**14, 2**17, 2**20]

    rows = []
    for k in ks:
        cfg = SketchConfig(m=m, b=8, seed=17)
        batches = common.keyed_batches(k, n_batches, batch, seed=k)

        eps_single, st_single = common.keyed_throughput(
            lambda s, keys, i, w: dyn_array.update_batch(cfg, s, keys, i, w),
            dyn_array.init(cfg, k),
            batches,
        )
        eps_shard, st_shard = common.keyed_throughput(
            lambda s, keys, i, w: sharded_dyn_array.update_batch(cfg, mesh, s, keys, i, w),
            sharded_dyn_array.init(cfg, k, mesh),
            batches,
        )
        for name in ("regs", "hists", "chats"):
            if not np.array_equal(
                np.asarray(getattr(st_shard, name)), np.asarray(getattr(st_single, name))
            ):
                raise AssertionError(
                    f"sharded and single-host DynArray {name} diverged at K={k}"
                )

        iters = 3 if k <= 2**14 else 1
        t_read = common.time_fn(
            lambda s: np.asarray(sharded_dyn_array.estimate_all(s)), st_shard,
            warmup=1, iters=iters,
        )
        t_mle = common.time_fn(
            lambda s: sharded_dyn_array.estimate_mle_all(cfg, mesh, s), st_shard,
            warmup=1, iters=iters,
        )
        rows += [
            {"figure": "dyn_array_sharded_throughput", "method": "single_host", "k": k, "m": m, "mops": eps_single / 1e6},
            {"figure": "dyn_array_sharded_throughput", "method": f"sharded_x{n_dev}", "k": k, "m": m, "shards": n_dev, "mops": eps_shard / 1e6},
            {"figure": "dyn_array_sharded_throughput", "method": "speedup", "k": k, "m": m, "x": eps_shard / eps_single},
            {"figure": "dyn_array_sharded_estimate", "method": "anytime_read", "k": k, "m": m, "ms": t_read * 1e3},
            {"figure": "dyn_array_sharded_estimate", "method": "sharded_newton_mle", "k": k, "m": m, "shards": n_dev, "ms": t_mle * 1e3},
            {"figure": "dyn_array_sharded_estimate", "method": "speedup", "k": k, "m": m, "x": t_mle / max(t_read, 1e-9)},
        ]
        common.csv_row(f"dyn_array_sharded/K{k}/single_host", 1e6 / eps_single, f"mops={eps_single/1e6:.3f}")
        common.csv_row(f"dyn_array_sharded/K{k}/sharded_x{n_dev}", 1e6 / eps_shard, f"mops={eps_shard/1e6:.3f}")
        common.csv_row(
            f"dyn_array_sharded/K{k}/anytime_read", t_read * 1e6,
            f"ms={t_read*1e3:.3f} vs sharded_mle={t_mle*1e3:.1f}ms",
        )

    common.merge_save("dyn_array_sharded", rows, set(ks))
    return rows
