"""Streaming ingest: micro-batched, double-buffered, donated device updates.

Every benchmark before this layer measured synchronous, already-batched
updates — the host blocks on each device step, so the repo had no honest
number for what one host sustains under unbounded traffic (the ROADMAP
"heavy traffic" north star; QSketch's O(1)-per-element claim, arXiv
2406.19143 §5, is only interesting if ingest keeps up). This module closes
the gap with a classic decoupled-pipeline structure (cf. the related repos'
issue-queue/ROB stages, structurally — not their code):

* **Staging (host).** (key, id, weight) triples accumulate into fixed-shape
  preallocated staging buffers — two of them, alternated per batch, so the
  device transfer of batch *i* never races the host filling batch *i+1*
  ("pinned" in the CUDA sense degenerates to ordinary page-locked-by-malloc
  numpy memory on the CPU backend; the double-buffer contract is what
  carries to accelerators).
* **Transfer + update (device).** A sealed batch is shipped as a freshly
  OWNED copy (CPU jax may defer or zero-copy-alias host bytes, and the
  staging buffer is rewritten on wrap-around — the copy is the transfer
  hop) and folded in by a state-DONATING update. The Dyn route runs it as
  two executables — a read-only plan and a scatter-only commit with
  ``donate_argnums`` on the container state (core/dyn_array.py,
  DESIGN.md §8.8) — so the scatters reuse the int8[K, m] + int32[K, 2^b]
  buffers in place instead of copying ~1 GiB per batch at K = 2^20.
  Dispatch is asynchronous — the host returns to staging while the device
  works, which is where the pipelining (and the sustained-Mops headline,
  benchmarks/ingest.py) comes from.
* **Backpressure.** In-flight batches are tracked by tiny per-batch tickets
  (scalars data-dependent on the updated state). When ``queue_depth``
  batches are unretired, ``policy="block"`` waits for the oldest (counting
  stall time), ``policy="drop"`` sheds the sealed batch (counting drops) —
  the load-shedding mode a real collector runs at saturation.
* **Retire barrier.** ``rotate()`` / ``barrier()`` first flush the partial
  staging buffer, then wait until every earlier batch has landed, and only
  then run the (donated) ``WindowArray.rotate`` — so an element pushed
  before the rotate is IN the pre-rotation epoch, an element pushed after
  is in the next one, exactly the synchronous ordering. Eviction clocks
  (``key_directory.evict_older_than``) hang off the same barrier.

Bit-identity: the pipeline partitions the push stream into the same
micro-batches a synchronous loop over ``update_batch`` would see (FIFO
fill, deterministic boundaries), calls the same jitted math, and orders
rotations with the barrier — so every state leaf is bit-identical to the
synchronous element-log oracle (tests/test_ingest.py, including a forced-
backpressure schedule; scatter-max order-insensitivity covers within-batch
permutations). Telemetry counters surface through ``metrics()`` in the
monitor-layer naming style.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    dyn_array,
    key_directory,
    sharded_dyn_array,
    sharded_window_array,
    sharding,
    window_array,
)
from repro.core.types import SketchConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.sketchstream import monitor

POLICIES = ("block", "drop")

# Declared metric families (one per counter, labeled by pipeline instance —
# the Prometheus data model lets N concurrent pipelines share each name).
_M_PUSHED = obs_metrics.counter(
    "ingest_elements_pushed", "elements accepted into staging", labels=("pipe",))
_M_DROPPED = obs_metrics.counter(
    "ingest_elements_dropped", "elements shed by the drop policy", labels=("pipe",))
_M_BATCHES = obs_metrics.counter(
    "ingest_batches", "micro-batches dispatched to the device", labels=("pipe",))
_M_PARTIAL = obs_metrics.counter(
    "ingest_partial_batches", "mask-padded dispatches (flush/rotate seals)",
    labels=("pipe",))
_M_STALLS = obs_metrics.counter(
    "ingest_stalls", "block-policy waits on a full queue", labels=("pipe",))
_M_STALL_S = obs_metrics.counter(
    "ingest_stall_s", "total seconds spent in backpressure waits", labels=("pipe",))
_M_MAX_IN_FLIGHT = obs_metrics.gauge(
    "ingest_max_in_flight", "high-water mark of the retire queue", labels=("pipe",))
_M_ROTATIONS = obs_metrics.counter(
    "ingest_rotations", "epoch rotations behind the retire barrier", labels=("pipe",))
_M_BARRIERS = obs_metrics.counter(
    "ingest_barriers", "retire barriers", labels=("pipe",))
_M_IN_FLIGHT = obs_metrics.gauge(
    "ingest_in_flight", "unretired in-flight batches", labels=("pipe",))

_STAT_FAMILIES = {
    "pushed": _M_PUSHED,
    "dropped": _M_DROPPED,
    "batches": _M_BATCHES,
    "partial_batches": _M_PARTIAL,
    "stalls": _M_STALLS,
    "stall_s": _M_STALL_S,
    "max_in_flight": _M_MAX_IN_FLIGHT,
    "rotations": _M_ROTATIONS,
    "barriers": _M_BARRIERS,
}

_PIPE_SEQ = itertools.count()


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Geometry + backpressure policy of an ingest pipeline.

    batch_size: elements per micro-batch (the fixed staging/device shape —
      one compiled executable serves every batch, partial flushes included
      via mask padding).
    queue_depth: max unretired in-flight batches before backpressure.
    policy: "block" (wait for the oldest in-flight batch; lossless) or
      "drop" (shed the sealed batch; lossy load-shedding — dropped elements
      are counted, never silently lost).
    """

    batch_size: int = 32768
    queue_depth: int = 4
    policy: str = "block"

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("ingest batch_size must be >= 1")
        if self.queue_depth < 1:
            raise ValueError("ingest queue_depth must be >= 1")
        if self.policy not in POLICIES:
            raise ValueError(f"ingest policy must be one of {POLICIES}")


class IngestStats:
    """Mutable telemetry counters of one pipeline (read via ``metrics()``).

    Fields: ``pushed`` (elements accepted into staging), ``dropped`` (shed
    by the drop policy), ``batches`` (micro-batches dispatched),
    ``partial_batches`` (mask-padded flush/rotate seals), ``stalls`` /
    ``stall_s`` (block-policy waits and their total seconds),
    ``max_in_flight`` (retire-queue high-water mark), ``rotations``,
    ``barriers``. All readable and assignable as plain attributes.

    Storage is dual-backend: when the default obs registry is enabled at
    construction, every field lives in a registry series under its declared
    ``ingest_*`` family (labeled ``pipe=<instance>``), so exporters see
    pipeline counters for free; when disabled, fields fall back to plain
    locals — ingest counters feed CONTROL FLOW (rotation cadence in
    ``benchmarks/ingest.py``), so unlike optional telemetry they must keep
    counting with observability off.

    Lifetime semantics (the PR 9 fix): counters no longer accumulate
    forever across runs — construction resets this instance's series, and
    ``snapshot(delta=True)`` / ``reset()`` give interval reads and explicit
    re-arming (the ``max_in_flight`` high-water and ``stall_s`` total are
    per-lifetime, not per-process).
    """

    FIELDS = tuple(_STAT_FAMILIES)

    def __init__(self, pipe: str | None = None):
        self.pipe = str(next(_PIPE_SEQ)) if pipe is None else str(pipe)
        reg = obs_metrics.default_registry()
        if reg.enabled:
            self._series = {
                f: fam.labels(pipe=self.pipe) for f, fam in _STAT_FAMILIES.items()
            }
            # A reused label (explicit pipe= names, or a restarted process
            # registry) must not inherit the previous lifetime's counts.
            for s in self._series.values():
                s.reset()
            self._local = None
        else:
            self._series = None
            self._local = dict.fromkeys(self.FIELDS, 0)
            self._local["stall_s"] = 0.0
            self._delta = dict(self._local)

    def snapshot(self, delta: bool = False) -> dict:
        """``{field: value}``; ``delta=True`` reports change since the
        previous delta snapshot and advances the baseline."""
        if self._series is not None:
            return {f: s.read(delta) for f, s in self._series.items()}
        if delta:
            out = {f: self._local[f] - self._delta[f] for f in self.FIELDS}
            # Gauge semantics match the registry backend: report current.
            out["max_in_flight"] = self._local["max_in_flight"]
            self._delta = dict(self._local)
            return out
        return dict(self._local)

    def reset(self) -> None:
        """Zero every counter, the high-water mark, and delta baselines."""
        if self._series is not None:
            for s in self._series.values():
                s.reset()
        else:
            self._local = dict.fromkeys(self.FIELDS, 0)
            self._local["stall_s"] = 0.0
            self._delta = dict(self._local)


def _stat_property(field: str) -> property:
    def get(self):
        if self._series is not None:
            return self._series[field].value
        return self._local[field]

    def set_(self, v):
        if self._series is not None:
            self._series[field].value = v
        else:
            self._local[field] = v

    return property(get, set_, doc=f"the ``{field}`` counter (see class doc)")


for _f in IngestStats.FIELDS:
    setattr(IngestStats, _f, _stat_property(_f))
del _f


class IngestPipeline:
    """Micro-batching ingest front of one sketch container.

    Built by the module's engine constructors (``dyn_pipeline``,
    ``window_pipeline``, ``sharded_dyn_pipeline``,
    ``sharded_window_pipeline``) — they close the container config (and
    mesh) into a jitted, state-donating ``update_fn(state, keys, ids, w,
    mask) -> (state, ticket)`` plus an optional donated ``rotate_fn``.

    Host API: ``push`` (accumulate + auto-dispatch), ``flush`` (seal the
    partial batch), ``barrier`` (flush + wait for every in-flight batch),
    ``rotate`` (barrier + donated ring rotation), ``result`` (barrier +
    the settled state), ``metrics`` (telemetry counters). The internally
    threaded state is donated batch-to-batch: never retain references to
    ``.state`` across a push.
    """

    def __init__(self, icfg: IngestConfig, state, update_fn, *, rotate_fn=None,
                 name: str | None = None):
        self.icfg = icfg
        self._state = state
        self._update = update_fn
        self._rotate = rotate_fn
        self.stats = IngestStats(pipe=name)
        b = icfg.batch_size
        self._staging = [
            {
                "keys": np.zeros(b, np.int32),
                "ids": np.zeros(b, np.uint32),
                "w": np.ones(b, np.float32),
                "mask": np.zeros(b, bool),
            }
            for _ in range(2)
        ]
        self._cur = 0  # which staging buffer is filling
        self._fill = 0  # elements in the filling buffer
        self._inflight: list = []  # retire queue of per-batch tickets
        # Readiness probe, overridable by tests to force backpressure
        # schedules deterministically.
        self._ready = lambda t: bool(t.is_ready())

    @property
    def state(self):
        """The container state as of the last dispatched batch (device-async;
        staging may still hold unsealed elements — use ``result()`` for the
        settled value)."""
        return self._state

    def push(self, keys, ids, weights=None) -> None:
        """Accept a host batch of (key, id, weight) triples, dispatching a
        micro-batch every time the staging buffer fills.

        keys: int array-like — dense slot indices in [0, K).
        ids: uint32 array-like element ids (64-bit streams pre-split their
          hi word into the key-directory layer; the staging lane is 32-bit).
        weights: float array-like, default 1.0 (unweighted streams).
        """
        keys = np.asarray(keys, np.int32).ravel()
        ids = np.asarray(ids, np.uint32).ravel()
        if weights is None:
            w = np.ones(keys.shape, np.float32)
        else:
            w = np.asarray(weights, np.float32).ravel()
        if not (keys.shape == ids.shape == w.shape):
            raise ValueError(
                f"push needs equal-length keys/ids/weights, got "
                f"{keys.shape}/{ids.shape}/{w.shape}"
            )
        self.stats.pushed += len(keys)
        b = self.icfg.batch_size
        off = 0
        with obs_trace.span("ingest/push", n=len(keys)):
            while off < len(keys):
                take = min(b - self._fill, len(keys) - off)
                buf = self._staging[self._cur]
                sl = slice(self._fill, self._fill + take)
                buf["keys"][sl] = keys[off : off + take]
                buf["ids"][sl] = ids[off : off + take]
                buf["w"][sl] = w[off : off + take]
                buf["mask"][sl] = True
                self._fill += take
                off += take
                if self._fill == b:
                    self._dispatch()

    def flush(self) -> None:
        """Seal and dispatch the partial staging buffer (mask-padded to the
        fixed batch shape — padding rows are no-ops by the mask contract)."""
        if self._fill:
            self._dispatch(partial=True)

    def barrier(self) -> None:
        """Flush, then wait until every dispatched batch has retired.

        This is the in-order retire barrier: after it returns, the threaded
        state reflects every element ever pushed (minus counted drops), and
        host-side consumers (rotation, eviction, checkpointing) may act on
        it without racing in-flight device work.
        """
        self.flush()
        with obs_trace.span("ingest/retire", in_flight=len(self._inflight)):
            if self._inflight:
                jax.block_until_ready(self._inflight)
                self._inflight.clear()
            jax.block_until_ready(jax.tree.leaves(self._state))
        self.stats.barriers += 1

    def rotate(self) -> None:
        """Close the container's current epoch behind the retire barrier.

        Flush + barrier first, so every earlier element lands in the
        pre-rotation epoch and the donated ``rotate_fn`` never aliases a
        buffer an in-flight update still reads — then rotate. Elements
        pushed afterwards open the next epoch: the synchronous ordering,
        by construction.
        """
        if self._rotate is None:
            raise ValueError("this pipeline fronts a container without rotate()")
        self.barrier()
        with obs_trace.span("ingest/rotate"):
            self._state = self._rotate(self._state)
        self.stats.rotations += 1

    def result(self):
        """Barrier, then return the settled container state."""
        self.barrier()
        return self._state

    def metrics(self) -> dict:
        """Telemetry counters in the monitor-layer style (queue depth, stall
        time, drops — the knobs an operator watches under load). Reading
        also refreshes this pipe's ``ingest_in_flight`` gauge, so registry
        exporters see the live queue depth."""
        s = self.stats
        if obs_metrics.enabled():
            _M_IN_FLIGHT.labels(pipe=s.pipe).set(len(self._inflight))
        return {
            "ingest_elements_pushed": s.pushed,
            "ingest_elements_dropped": s.dropped,
            "ingest_batches": s.batches,
            "ingest_partial_batches": s.partial_batches,
            "ingest_stalls": s.stalls,
            "ingest_stall_s": float(s.stall_s),
            "ingest_in_flight": len(self._inflight),
            "ingest_max_in_flight": s.max_in_flight,
            "ingest_rotations": s.rotations,
            "ingest_barriers": s.barriers,
        }

    # ------------------------------------------------------------------ #

    def _reap(self) -> None:
        """Retire completed batches from the head of the in-flight queue
        (in order — a later ticket never retires before an earlier one)."""
        while self._inflight and self._ready(self._inflight[0]):
            self._inflight.pop(0)

    def _admit(self) -> bool:
        """Apply backpressure; True iff the sealed batch may dispatch."""
        self._reap()
        while len(self._inflight) >= self.icfg.queue_depth:
            if self.icfg.policy == "drop":
                return False
            t0 = time.perf_counter()
            with obs_trace.span("ingest/stall", in_flight=len(self._inflight)):
                jax.block_until_ready(self._inflight.pop(0))
            self.stats.stall_s += time.perf_counter() - t0
            self.stats.stalls += 1
            self._reap()
        return True

    def _dispatch(self, partial: bool = False) -> None:
        n, buf = self._fill, self._staging[self._cur]
        # Swap staging buffers BEFORE transfer: the next push fills the other
        # buffer while this one's bytes are (asynchronously) consumed.
        self._cur ^= 1
        self._fill = 0
        if not self._admit():
            self.stats.dropped += n
            buf["mask"][:] = False
            return
        # Hand jax freshly-OWNED copies: the CPU backend may defer (or
        # zero-copy alias) the host bytes passed to asarray until the
        # consuming executable runs, and this buffer is mutated again as
        # soon as push() wraps around to it — with queue_depth > 2 that is
        # before the in-flight batch is guaranteed to have read its inputs.
        # The memcpy IS the staging->transfer hop; jax holds the only
        # reference afterwards, so later staging writes can never race it.
        with obs_trace.span("ingest/seal", n=n, partial=partial):
            keys = jnp.asarray(buf["keys"].copy())
            ids = jnp.asarray(buf["ids"].copy())
            w = jnp.asarray(buf["w"].copy())
            mask = jnp.asarray(buf["mask"].copy())
            buf["mask"][:] = False  # pre-cleared for this buffer's next fill
        with obs_trace.span("ingest/dispatch", n=n):
            self._state, ticket = self._update(self._state, keys, ids, w, mask)
        self._inflight.append(ticket)
        self.stats.batches += 1
        self.stats.partial_batches += bool(partial)
        self.stats.max_in_flight = max(self.stats.max_in_flight, len(self._inflight))
        # Sampled device-time attribution: every sync_every-th batch blocks
        # on its own ticket under a span (obs/trace.py — the sampled batch
        # trades away its overlap for an honest device-side duration).
        obs_trace.maybe_sync("ingest/device_sync", ticket, self.stats.batches)


def _ticketed(update):
    """Wrap a pure state update into the pipeline's (state, ticket) form:
    the ticket is a scalar data-dependent on the new state, so its
    ``is_ready()`` / ``block_until_ready`` observe the whole batch having
    landed without holding a reference to any (donated) state buffer."""

    def fn(state, keys, ids, w, mask):
        out = update(state, keys, ids, w, mask)
        return out, jax.tree.leaves(out)[0].ravel()[0]

    return fn


@functools.lru_cache(maxsize=32)
def _dyn_update_fn(cfg: SketchConfig, use_kernel: bool):
    if use_kernel:
        from repro.kernels import ops

        def upd(st, keys, ids, w, mask):
            return ops.dyn_array_update_op(cfg, st, keys, ids, w, mask=mask)

        return jax.jit(_ticketed(upd), donate_argnums=(0,))

    # The jnp route stays OUTSIDE any enclosing jit on purpose: donate=True
    # runs the update as two executables (read-only plan + scatter-only
    # donating commit, core/dyn_array.py) — wrapping them in one jit would
    # fuse them back into the gather+scatter shape whose copy-insertion
    # re-copies the [K, 2^b] histograms every batch. The ticket is a third,
    # O(1) dispatch chained on the committed state.
    def fn(st, keys, ids, w, mask):
        out = dyn_array.update_batch(cfg, st, keys, ids, w, mask, donate=True)
        return out, out.regs.ravel()[0]

    return fn


def dyn_pipeline(
    cfg: SketchConfig, state, icfg: IngestConfig = IngestConfig(),
    *, use_kernel: bool = False, name: str | None = None,
) -> IngestPipeline:
    """Ingest front of a DynArray: donated fused keyed updates, no rotate.

    ``use_kernel=True`` routes the q_R stage through the Pallas kernel
    (``kernels/ops.dyn_array_update_op``) inside the same donating jit.
    The jitted update closure is cached per cfg, so pipelines over the
    same geometry share one compiled executable.
    """
    return IngestPipeline(icfg, state, _dyn_update_fn(cfg, use_kernel), name=name)


@functools.lru_cache(maxsize=32)
def _window_update_fn(cfg: SketchConfig):
    def upd(st, keys, ids, w, mask):
        return window_array._update_batch_impl(cfg, st, keys, ids, w, mask)

    return jax.jit(_ticketed(upd), donate_argnums=(0,))


def window_pipeline(
    cfg: SketchConfig, state, icfg: IngestConfig = IngestConfig(),
    *, name: str | None = None,
) -> IngestPipeline:
    """Ingest front of a WindowArray: donated epoch+union updates, with
    ``rotate()`` running the donated ring rotation behind the retire
    barrier."""
    rot = lambda st: window_array.rotate(cfg, st, donate=True)
    return IngestPipeline(icfg, state, _window_update_fn(cfg), rotate_fn=rot, name=name)


@functools.lru_cache(maxsize=32)
def _sharded_dyn_update_fn(cfg: SketchConfig, mesh, axis: str):
    def upd(st, keys, ids, w, mask):
        return sharded_dyn_array.update_batch(
            cfg, mesh, st, keys, ids, w, mask=mask, axis=axis
        )

    return jax.jit(_ticketed(upd), donate_argnums=(0,))


def sharded_dyn_pipeline(
    cfg: SketchConfig, mesh, state, icfg: IngestConfig = IngestConfig(),
    *, axis: str = sharding.AXIS, name: str | None = None,
) -> IngestPipeline:
    """Ingest front of a ShardedDynArray: the replicated staging batch is
    hash-routed shard-locally inside one donating jit per micro-batch."""
    return IngestPipeline(icfg, state, _sharded_dyn_update_fn(cfg, mesh, axis), name=name)


@functools.lru_cache(maxsize=32)
def _sharded_window_update_fn(cfg: SketchConfig, mesh, axis: str):
    def upd(st, keys, ids, w, mask):
        return sharded_window_array.update_batch(
            cfg, mesh, st, keys, ids, w, mask=mask, axis=axis
        )

    return jax.jit(_ticketed(upd), donate_argnums=(0,))


def sharded_window_pipeline(
    cfg: SketchConfig, mesh, state, icfg: IngestConfig = IngestConfig(),
    *, axis: str = sharding.AXIS, name: str | None = None,
) -> IngestPipeline:
    """Ingest front of a ShardedWindowArray: hash-routed donated updates
    plus the donated shard-local ring rotation behind the retire barrier."""
    rot = lambda st: sharded_window_array.rotate(cfg, mesh, st, axis=axis, donate=True)
    return IngestPipeline(
        icfg, state, _sharded_window_update_fn(cfg, mesh, axis), rotate_fn=rot,
        name=name,
    )


class TenantWindowIngest:
    """Sparse-tenant window telemetry through the ingest pipeline.

    The monitor layer's WindowMonitor routes + updates synchronously inside
    the caller's step; this front does the routing host-synchronously (the
    directory is tiny) but streams the heavy per-tenant window updates
    through an ``IngestPipeline`` — the ``--ingest`` mode of
    ``launch/train.py``. ``rotate()`` runs the ring rotation AND directory
    aging behind the retire barrier, keeping eviction ordered after every
    earlier element, exactly as the synchronous monitor.
    """

    def __init__(
        self,
        cfg: SketchConfig,
        dcfg: key_directory.DirectoryConfig,
        n_epochs: int,
        icfg: IngestConfig = IngestConfig(),
        *,
        mesh=None,
        axis: str = sharding.AXIS,
        evict_after: int = 0,
    ):
        self.cfg, self.dcfg = cfg, dcfg
        self.evict_after = int(evict_after)
        self.directory = key_directory.init(dcfg)
        self._epoch = 0
        if mesh is None:
            self.pipe = window_pipeline(
                cfg, window_array.init(cfg, dcfg.capacity, n_epochs), icfg
            )
        else:
            self.pipe = sharded_window_pipeline(
                cfg, mesh,
                sharded_window_array.init(cfg, dcfg.capacity, n_epochs, mesh, axis),
                icfg, axis=axis,
            )

    def push(self, tenant_keys, ids, weights=None, mask=None) -> None:
        """Route sparse 64-bit tenant ids (uint32 array or (lo, hi) pair)
        through the key directory, then stage the slot-keyed elements.
        Masked elements are filtered host-side before staging (identical
        results to in-batch masking by the mask no-op contract)."""
        slots, self.directory = key_directory.route(
            self.dcfg, self.directory, tenant_keys, mask=mask,
            epoch=jnp.int32(self._epoch),
        )
        slots = np.asarray(slots).ravel()
        ids = np.asarray(ids).ravel()
        w = None if weights is None else np.asarray(weights).ravel()
        if mask is not None:
            keep = np.asarray(mask).ravel()
            slots, ids = slots[keep], ids[keep]
            w = None if w is None else w[keep]
        self.pipe.push(slots, ids, w)

    def rotate(self) -> None:
        """Barrier + ring rotation + cold-fingerprint aging, in that order."""
        self.pipe.rotate()
        self._epoch += 1
        if self.evict_after:
            self.directory, _ = key_directory.evict_older_than(
                self.dcfg, self.directory,
                jnp.int32(self._epoch - self.evict_after),
            )

    def result(self):
        """Retire every in-flight batch; the settled window state."""
        return self.pipe.result()

    def metrics(self) -> dict:
        """Pipeline counters + directory collision telemetry, merged (same
        directory-health scalars the synchronous monitors report, via the
        shared helper — published under ``monitor="tenant_window_ingest"``)."""
        out = self.pipe.metrics()
        dm = monitor.directory_metrics(self.directory)
        out["tenant_slots_claimed"] = int(dm["tenant_slots_claimed"])
        out["tenant_collision_rate"] = float(dm["tenant_collision_rate"])
        monitor.publish_tenant_metrics(
            "tenant_window_ingest",
            {k: out[k] for k in ("tenant_slots_claimed", "tenant_collision_rate")},
        )
        return out
