"""User-facing jit'd wrappers around the Pallas sketch kernels.

These adapt (SketchConfig, sketch-state, raw id/weight batches) to the padded
2-D operand layout the kernels want, pick interpret mode automatically off
the backend (interpret=True executes the kernel body in Python on CPU — the
validation mode this container uses; on TPU the same code lowers to Mosaic),
and convert between the int8 register state and the kernel's int32 blocks.

Padding contracts:
  * batch rows are padded to a block multiple with log2w = -inf (QSketch) or
    w = -1 (float sketches mask non-positive w): padded rows are no-ops.
  * registers are padded to a block multiple; padded registers evolve
    independently and are sliced off — they never alias real ones because
    each register consumes its own hash lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    dyn_array,
    hashing,
    key_directory,
    qsketch_dyn,
    sharding,
    window_array,
)
from repro.core.types import (
    DynArrayState,
    FloatSketchState,
    QSketchState,
    ShardedDynArrayState,
    ShardedWindowArrayState,
    SketchArrayState,
    SketchConfig,
    WindowArrayState,
)
from repro.obs import metrics as obs_metrics

from . import (
    dyn_array_update,
    estimate,
    qdyn_qr,
    qsketch_update,
    sketch_array_update,
    virtual_pool_update,
    window_union,
)

_NEG_INF = float(np.finfo(np.float32).min)
_POS_INF = float(np.finfo(np.float32).max)

_M_KERNEL_TRACES = obs_metrics.counter(
    "kernel_trace_total",
    help="op-wrapper executions under an active jax trace, per op — growth "
         "at steady state means shape churn is forcing retraces",
    labels=("op",),
)


def _note_trace(op: str) -> None:
    """Count one trace-time execution of an op wrapper (retrace telemetry).

    The wrapper body only re-runs when jit (re)traces, so at steady state
    the per-op counter is flat; a rising count is the recompilation signal
    (shape churn defeating the lru_cache'd executables). Host-side int
    mutation during tracing captures no tracer, so the jitted computation
    is untouched.
    """
    if obs_metrics.enabled() and not jax.core.trace_state_clean():
        _M_KERNEL_TRACES.labels(op=op).inc()


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pick_blocks(b: int, m: int, block_b, block_m):
    """Clamp default blocks to the (padded) problem size."""
    bb = block_b or min(qsketch_update.DEFAULT_BLOCK_B, _round_up(b, 8))
    bm = block_m or min(qsketch_update.DEFAULT_BLOCK_M, _round_up(m, 128))
    return bb, bm


def _pad_batch(arrs, b_padded, fill_values):
    out = []
    for a, fill in zip(arrs, fill_values):
        pad = b_padded - a.shape[0]
        out.append(jnp.pad(a, ((0, pad),), constant_values=fill)[:, None])
    return out


def qsketch_update_op(
    cfg: SketchConfig,
    state: QSketchState,
    ids,
    weights,
    *,
    block_b: int | None = None,
    block_m: int | None = None,
    interpret: bool | None = None,
) -> QSketchState:
    """Kernel-backed equivalent of ``core.qsketch.update`` (bit-identical)."""
    _note_trace("qsketch_update")
    interpret = _interpret_default() if interpret is None else interpret
    lo, hi = hashing.split_id64(ids)
    b = lo.shape[0]
    bb, bm = _pick_blocks(b, cfg.m, block_b, block_m)
    bp, mp = _round_up(b, bb), _round_up(cfg.m, bm)

    log2w = jnp.log2(weights.astype(jnp.float32))
    lo2, hi2, lw2 = _pad_batch([lo, hi, log2w], bp, [0, 0, _NEG_INF])
    regs = jnp.pad(
        state.regs.astype(jnp.int32), ((0, mp - cfg.m),), constant_values=cfg.r_min
    )[None, :]

    out = qsketch_update.qsketch_update_padded(
        lo2,
        hi2,
        lw2,
        regs,
        block_b=bb,
        block_m=bm,
        salt=cfg.salt_h,
        r_min=cfg.r_min,
        r_max=cfg.r_max,
        interpret=interpret,
    )
    return QSketchState(regs=out[0, : cfg.m].astype(jnp.int8))


def sketch_array_update_op(
    cfg: SketchConfig,
    state: SketchArrayState,
    keys,
    ids,
    weights,
    mask=None,
    *,
    block_b: int | None = None,
    block_m: int | None = None,
    interpret: bool | None = None,
) -> SketchArrayState:
    """Kernel-backed equivalent of ``core.sketch_array.update`` (bit-identical).

    ``keys`` follows the *slot* contract: dense int[B] in [0, K), i.e. the
    output of ``core.key_directory.route`` (sparse 64-bit tenant streams go
    through ``sketch_array_update_tenants_op`` below).

    ``mask`` is folded into log2w (masked rows -> -inf -> y = r_min), which is
    exactly the core's post-clip masking, so bit-identity is preserved.
    The register slab (K_pad x block_m, int32) must sit in VMEM next to the
    y tile; block_m is halved until the slab fits a ~6 MiB budget.
    """
    _note_trace("sketch_array_update")
    interpret = _interpret_default() if interpret is None else interpret
    k = state.regs.shape[0]
    lo, hi = hashing.split_id64(ids)
    b = lo.shape[0]

    bb = block_b or min(sketch_array_update.DEFAULT_BLOCK_B, _round_up(b, 8))
    bm = block_m or min(sketch_array_update.DEFAULT_BLOCK_M, _round_up(cfg.m, 128))
    kp = _round_up(k, 8)
    if block_m is None:
        # Halve in 128-aligned steps: M_blk must stay a lane-tile multiple.
        # Residency = regs_ref + out_ref slabs (int32 each) + the y tile.
        while (2 * kp + bb) * bm * 4 > 6 * 2**20 and bm > 128:
            bm = max(128, (bm // 2) // 128 * 128)
    bp, mp = _round_up(b, bb), _round_up(cfg.m, bm)

    log2w = jnp.log2(weights.astype(jnp.float32))
    if mask is not None:
        log2w = jnp.where(mask, log2w, _NEG_INF)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    lo2, hi2, lw2, keys2 = _pad_batch([lo, hi, log2w, keys], bp, [0, 0, _NEG_INF, 0])
    regs = jnp.pad(
        state.regs.astype(jnp.int32),
        ((0, kp - k), (0, mp - cfg.m)),
        constant_values=cfg.r_min,
    )

    out = sketch_array_update.sketch_array_update_padded(
        lo2,
        hi2,
        lw2,
        keys2,
        regs,
        block_b=bb,
        block_m=bm,
        salt=cfg.salt_h,
        r_min=cfg.r_min,
        r_max=cfg.r_max,
        interpret=interpret,
    )
    return SketchArrayState(regs=out[:k, : cfg.m].astype(jnp.int8))


def sketch_array_update_tenants_op(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    state: SketchArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
    **kernel_kwargs,
):
    """Sparse-tenant front of ``sketch_array_update_op``.

    Routes 64-bit tenant ids (uint32 array or pre-split (lo, hi) pair)
    through the key directory — collision telemetry included — then runs the
    Pallas-backed keyed update on the resulting slots. Returns
    (SketchArrayState, DirectoryState).
    """
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != SketchArray rows {state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    out = sketch_array_update_op(cfg, state, slots, ids, weights, mask=mask, **kernel_kwargs)
    return out, dir_state


def dyn_array_update_op(
    cfg: SketchConfig,
    state: DynArrayState,
    keys,
    ids,
    weights,
    mask=None,
    *,
    block_b: int | None = None,
    interpret: bool | None = None,
    donate: bool = False,
) -> DynArrayState:
    """Kernel-backed equivalent of ``core.dyn_array.update_batch`` (bit-identical).

    The dense inner stage — per-element q_R against the element's key's
    batch-start histogram — runs in the Pallas kernel
    (``kernels/dyn_array_update.py``) on gathered rows; the data-dependent
    tail (dedup lexsort, segment scatter-max, incremental histogram moves)
    is shared with the core path via ``dyn_array._apply_update``, so the two
    entries agree bitwise on every state field.

    ``keys`` follows the slot contract (dense int[B], clipped to [0, K));
    sparse 64-bit tenant streams go through ``dyn_array_update_tenants_op``.
    Padding batch rows carry w = 1 against a zero histogram row (q = 1) and
    are sliced off before the tail.

    ``donate=True`` runs the whole op under one jit with the state donated,
    so the scatter tail reuses the state buffers in place instead of copying
    the [K, m] + [K, 2^b] block per batch — the steady-state ingest mode
    (the non-donating call stays un-jitted at top level: its Pallas stage
    compiles per shape and the tail dispatches eagerly, the validation
    configuration the bit-identity tests run). The caller's ``state`` is
    dead after a donating call (``dyn_array.update_batch`` has the full
    donation contract).
    """
    interpret = _interpret_default() if interpret is None else interpret
    if donate:
        return _dyn_array_update_donated(cfg, block_b, interpret)(
            state, keys, ids, weights, mask
        )
    return _dyn_array_update_body(
        cfg, state, keys, ids, weights, mask, block_b=block_b, interpret=interpret
    )


@functools.lru_cache(maxsize=32)
def _dyn_array_update_donated(cfg: SketchConfig, block_b, interpret: bool):
    """Jitted, state-donating closure of ``_dyn_array_update_body`` — one
    cache entry per (cfg, block_b, interpret) so repeated ingest batches hit
    the same executable (and its input-output buffer aliasing)."""

    def fn(state, keys, ids, weights, mask):
        return _dyn_array_update_body(
            cfg, state, keys, ids, weights, mask,
            block_b=block_b, interpret=interpret,
        )

    return jax.jit(fn, donate_argnums=(0,))


def _dyn_array_update_body(
    cfg: SketchConfig, state: DynArrayState, keys, ids, weights, mask,
    *, block_b, interpret,
) -> DynArrayState:
    from repro.core import estimators

    _note_trace("dyn_array_update")
    k = state.regs.shape[0]
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    live = qsketch_dyn._live_weight_mask(w, mask)

    b = lo.shape[0]
    bb = block_b or min(dyn_array_update.DEFAULT_BLOCK_B, _round_up(b, 8))
    bp = _round_up(b, bb)
    nbp = _round_up(cfg.num_bins, 128)

    scales = jnp.pad(
        jnp.asarray(estimators._bin_scales(cfg)), ((0, nbp - cfg.num_bins),)
    )[None, :]
    rows = jnp.pad(
        state.hists[keys].astype(jnp.float32),
        ((0, bp - b), (0, nbp - cfg.num_bins)),
    )
    w2 = jnp.pad(w, ((0, bp - b),), constant_values=1.0)[:, None]

    q = dyn_array_update.dyn_array_qr_padded(
        w2, rows, scales, m=cfg.m, block_b=bb, interpret=interpret
    )
    q = jnp.maximum(q[:b, 0], qsketch_dyn._QR_FLOOR)
    return dyn_array._apply_update(cfg, state, keys, lo, hi, w, live, q)


def dyn_array_update_tenants_op(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    state: DynArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
    **kernel_kwargs,
):
    """Sparse-tenant front of ``dyn_array_update_op`` (key-directory routing,
    collision telemetry included). Returns (DynArrayState, DirectoryState).
    """
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != DynArray rows {state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    out = dyn_array_update_op(cfg, state, slots, ids, weights, mask=mask, **kernel_kwargs)
    return out, dir_state


def virtual_dyn_update_op(
    cfg: SketchConfig,
    vcfg,
    state,
    tenant_keys,
    ids,
    weights,
    mask=None,
    *,
    block_b: int | None = None,
    interpret: bool | None = None,
):
    """Kernel-backed equivalent of ``core.virtual_dyn_array.update_tenants``
    (bit-identical on every state field).

    The dense inner stage — per-element register choice, value quantization,
    and pool-slot placement — runs in the Pallas kernel
    (``kernels/virtual_pool_update.py``), regenerating the hash bits in VMEM
    with the same integer family as the jnp reference; the data-dependent
    tail (hot/tail routing split, dense-row update, slot-grouped scatter-max
    and the incremental full-histogram move) is shared with the core path
    via ``virtual_dyn_array._apply_update``, so the two entries agree
    bitwise. Padding rows carry log2w = −inf (y floors to the r_min no-op)
    and are sliced off before the tail.
    """
    from repro.core import virtual_dyn_array

    _note_trace("virtual_dyn_update")
    interpret = _interpret_default() if interpret is None else interpret
    t_lo, t_hi = hashing.split_id64(tenant_keys)
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    live = qsketch_dyn._live_weight_mask(w, mask)
    log2w = jnp.log2(w)

    b = lo.shape[0]
    bb = block_b or min(virtual_pool_update.DEFAULT_BLOCK_B, _round_up(b, 8))
    bp = _round_up(b, bb)
    lo2, hi2, tlo2, thi2, lw2 = _pad_batch(
        [lo, hi, t_lo, t_hi, log2w], bp, [0, 0, 0, 0, _NEG_INF]
    )

    # Tail geometry: register choice modulus is the VIRTUAL row width m_v
    # (free registers — the vHLL decoupling); the b-derived quantization
    # range and the seed-derived salts are shared with the dense cfg.
    p, y = virtual_pool_update.virtual_pool_route_padded(
        lo2, hi2, tlo2, thi2, lw2,
        salt_g=cfg.salt_g, salt_h=cfg.salt_h, salt_pool=vcfg.salt_pool,
        m=virtual_dyn_array.tail_m(cfg, vcfg), pool_size=vcfg.pool_size,
        r_min=cfg.r_min, r_max=cfg.r_max,
        block_b=bb, interpret=interpret,
    )
    return virtual_dyn_array._apply_update(
        cfg, vcfg, state, t_lo, t_hi, lo, hi, w, live, p[:b, 0], y[:b, 0]
    )


def window_union_estimate_op(
    cfg: SketchConfig,
    state: WindowArrayState,
    w: int,
    *,
    solver: str = "newton",
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed equivalent of ``window_array.estimate_window`` — Ĉ[K]
    over the last w <= E epochs, bit-identical to the pure-JAX union path.

    The union-of-epochs + per-row bincount runs in the Pallas kernel
    (``kernels/window_union.py``) streaming the ring's int8 epoch planes
    through VMEM, so the ``[w, K, m]`` gather the jnp path materializes never
    exists (the ring is read in place at native register width; padding only
    copies when K or m are tile-unaligned); the vmapped histogram MLE then
    runs on the exact same integer histograms, making the two entries agree
    bitwise. Epochs outside the window are masked by an include flag computed
    from the ring head, so the (traced) ``head`` never forces a host sync.
    """
    _note_trace("window_union_estimate")
    interpret = _interpret_default() if interpret is None else interpret
    e, k, m = state.regs.shape
    w = window_array._check_w(state, w)

    bk = block_k or min(window_union.DEFAULT_BLOCK_K, _round_up(k, 8))
    kp, mp = _round_up(k, bk), _round_up(m, 128)
    nbp = _round_up(cfg.num_bins, 128)

    regs = jnp.pad(
        state.regs,
        ((0, 0), (0, kp - k), (0, mp - m)),
        constant_values=cfg.r_min,
    )
    # Epoch slot ei is inside the window iff its age (head - ei) mod E < w.
    age = (state.head - jnp.arange(e, dtype=jnp.int32)) % e
    include = (age < w).astype(jnp.int32)[:, None]

    _, hists = window_union.window_union_padded(
        regs,
        include,
        m=m,
        nb_padded=nbp,
        r_min=cfg.r_min,
        block_k=bk,
        interpret=interpret,
    )
    return dyn_array.estimate_mle_hists(cfg, hists[:k, : cfg.num_bins], solver=solver)


def estimate_rows_op(
    cfg: SketchConfig,
    regs,
    *,
    kind: str = "routed",
    block_k: int | None = None,
    interpret: bool | None = None,
):
    """Kernel-backed fused bincount + MLE over register rows — the
    ``solver="fused"`` backend of ``core.estimation.estimate_rows(_with_ci)``.

    One Pallas pass (``kernels/estimate.py``) streams the int8 rows through
    VMEM and emits (Ĉ[K], stddev[K], converged[K]) without materializing the
    ``[K, 2^b]`` histogram block in HBM. The kind convention matches the
    estimation layer: ``"full"`` returns the MLE, ``"routed"`` scales ×m with
    untouched rows (all registers at r_min) pinned to exactly 0.0 — inside
    the kernel that guard coincides with the degenerate-low fallback.
    """
    from repro.core import estimation

    _note_trace("estimate_rows")
    estimation._check_kind(kind)
    interpret = _interpret_default() if interpret is None else interpret
    k, m = regs.shape

    bk = block_k or min(estimate.DEFAULT_BLOCK_K, _round_up(k, 8))
    kp, mp = _round_up(k, bk), _round_up(m, 128)
    nbp = _round_up(cfg.num_bins, 128)

    regs_p = jnp.pad(
        regs, ((0, kp - k), (0, mp - m)), constant_values=cfg.r_min
    )
    chat, std, conv = estimate.estimate_rows_padded(
        regs_p,
        m=m,
        nb_padded=nbp,
        r_min=cfg.r_min,
        top_bin=cfg.top_bin,
        block_k=bk,
        interpret=interpret,
    )
    chat, std, conv = chat[:k, 0], std[:k, 0], conv[:k, 0] > 0
    if kind == "routed":
        return chat * cfg.m, std * cfg.m, conv
    return chat, std, conv


def sharded_dyn_array_update_op(
    cfg: SketchConfig,
    mesh,
    state: ShardedDynArrayState,
    keys,
    ids,
    weights,
    mask=None,
    *,
    axis: str = sharding.AXIS,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> ShardedDynArrayState:
    """Kernel-backed equivalent of ``sharded_dyn_array.update_batch``
    (bit-identical on every state leaf).

    The per-shard body is exactly ``dyn_array_update_op`` — the Pallas q_R
    kernel streams each shard's gathered histogram rows through VMEM, the
    data-dependent tail stays ``dyn_array._apply_update`` — run under
    ``shard_map`` with the replicated batch hash-routed to the owning shard
    (``sharding.own_slots``), the same dispatch as the jnp-backed sharded
    path. ``check_rep=False`` because pallas_call has no replication rule;
    every operand the kernel touches is shard-local, so the check is
    vacuous.
    """
    _note_trace("sharded_dyn_array_update")
    sharding.check_divisible(state.regs.shape[0], mesh, axis)
    k = state.regs.shape[0]
    rows = k // sharding.num_shards(mesh, axis)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    mask = jnp.ones(keys.shape, bool) if mask is None else mask

    def local(st, keys, ids, w, m):
        local_keys, own = sharding.own_slots(keys, rows, axis, m)
        return tuple(
            dyn_array_update_op(
                cfg, st, local_keys, ids, w, mask=own,
                block_b=block_b, interpret=interpret,
            )
        )

    return ShardedDynArrayState(
        *sharding.shard_map_rows(
            local,
            mesh,
            in_dims=(DynArrayState(0, 0, 0), None, None, None, None),
            out_dims=(0, 0, 0),
            axis=axis,
            check_rep=False,
        )(DynArrayState(*state), keys, ids, weights, mask)
    )


def sharded_window_union_estimate_op(
    cfg: SketchConfig,
    mesh,
    state: ShardedWindowArrayState,
    w: int,
    *,
    axis: str = sharding.AXIS,
    solver: str = "newton",
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed equivalent of ``sharded_window_array.estimate_window``
    for sub-ring windows — Ĉ[K] over the last w <= E epochs, bit-identical
    to both the sharded jnp path and the single-host op.

    Each shard runs the fused union+bincount kernel
    (``kernels/window_union.py``) over its own rows of the epoch planes —
    the epoch-plane max-union commutes with row sharding, so no plane ever
    crosses a shard boundary. The ring head is replicated; w is a static
    host-side int.
    """
    _note_trace("sharded_window_union_estimate")
    sharding.check_divisible(state.regs.shape[1], mesh, axis)
    w = window_array._check_w(state, w)

    def local(regs_l, head):
        st = WindowArrayState(
            regs_l, None, None, None, None, None,
            head=head, filled=jnp.int32(0), epoch_id=jnp.int32(0),
        )
        return window_union_estimate_op(
            cfg, st, w, solver=solver, block_k=block_k, interpret=interpret
        )

    return sharding.shard_map_rows(
        local, mesh, in_dims=(1, None), out_dims=0, axis=axis, check_rep=False
    )(state.regs, state.head)


def float_sketch_update_op(
    cfg: SketchConfig,
    state: FloatSketchState,
    ids,
    weights,
    *,
    block_b: int | None = None,
    block_m: int | None = None,
    interpret: bool | None = None,
) -> FloatSketchState:
    """Kernel-backed equivalent of ``core.baselines.lm_update`` (bit-identical)."""
    _note_trace("float_sketch_update")
    interpret = _interpret_default() if interpret is None else interpret
    lo, hi = hashing.split_id64(ids)
    b = lo.shape[0]
    bb, bm = _pick_blocks(b, cfg.m, block_b, block_m)
    bp, mp = _round_up(b, bb), _round_up(cfg.m, bm)

    # Padding rows are flagged with w = -1 (kernel masks non-positive w).
    lo2, hi2, w2 = _pad_batch([lo, hi, weights.astype(jnp.float32)], bp, [0, 0, -1.0])
    regs = jnp.pad(state.regs, ((0, mp - cfg.m),), constant_values=_POS_INF)[None, :]

    out = qsketch_update.float_sketch_update_padded(
        lo2, hi2, w2, regs, block_b=bb, block_m=bm, salt=cfg.salt_h, interpret=interpret
    )
    return FloatSketchState(regs=out[0, : cfg.m])


def qdyn_qr_op(
    cfg: SketchConfig,
    hist,
    weights,
    *,
    block_b: int | None = None,
    interpret: bool | None = None,
):
    """Kernel-backed q_R batch (matches core.qsketch_dyn._q_update_prob)."""
    _note_trace("qdyn_qr")
    interpret = _interpret_default() if interpret is None else interpret
    b = weights.shape[0]
    bb = block_b or min(qdyn_qr.DEFAULT_BLOCK_B, _round_up(b, 8))
    bp = _round_up(b, bb)
    nbp = _round_up(cfg.num_bins, 128)

    from repro.core import estimators

    scales = jnp.pad(
        jnp.asarray(estimators._bin_scales(cfg)), ((0, nbp - cfg.num_bins),)
    )[None, :]
    histp = jnp.pad(hist.astype(jnp.float32), ((0, nbp - cfg.num_bins),))[None, :]
    w2 = jnp.pad(weights.astype(jnp.float32), ((0, bp - b),), constant_values=1.0)[:, None]

    q = qdyn_qr.qdyn_qr_padded(w2, histp, scales, m=cfg.m, block_b=bb, interpret=interpret)
    return jnp.maximum(q[:b, 0], 1e-12)
