"""metric-names — the observability registry stays greppable and unique.

Metric families are the public interface between this repo and whatever
scrapes it (Prometheus, the JSONL log, dashboards built on either). Two
failure modes silently rot that interface:

* **Stringly-typed ad-hoc emissions.** A name computed at call time
  (``counter(f"ingest_{field}")``) can't be grepped, renamed, or matched
  against a recording rule; and a family declared inside a function body
  re-registers on every call instead of once at import. Both defeat the
  declare-once model ``obs.metrics`` is built around.
* **Name collisions.** ``Registry._declare`` is idempotent for a
  *matching* redeclaration and raises on a mismatched one — but only at
  runtime, and only if both declaring sites actually execute in the same
  process. Two modules independently claiming the same family name is a
  merge-order landmine this rule catches statically.

Checked: every call resolving (via each module's import map) to the
sanctioned declaration points ``repro.obs.metrics.counter`` / ``gauge`` /
``histogram`` must pass a literal ``snake_case`` name, sit at module
scope, and be the name's only declaring site repo-wide. The defining
module itself (``repro.obs.metrics``) is exempt — its ``counter`` et al.
are the forwarding wrappers being policed.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.astutil import ImportMap
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

# The sanctioned declaration points: module-level forwarding functions on
# the default registry. Registry *methods* aren't resolvable statically
# (instance calls), which is fine — the repo's convention is the module
# functions, and a private Registry is a test-local concern.
DECL_FUNCS = {
    "repro.obs.metrics.counter": "counter",
    "repro.obs.metrics.gauge": "gauge",
    "repro.obs.metrics.histogram": "histogram",
}

# Prometheus-compatible snake_case: lowercase start, word chars only.
NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

DEFINING_MODULE = "repro.obs.metrics"


def _function_body_calls(tree: ast.Module) -> set[int]:
    """ids of every Call node nested inside any function/method body."""
    inside: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    inside.add(id(sub))
    return inside


@register
class MetricNamesRule(Rule):
    """Flag non-literal, non-snake_case, function-scoped, or repo-wide
    duplicate metric family declarations."""

    name = "metric-names"
    description = (
        "metric families are declared once, at module scope, with literal "
        "snake_case names unique across the repo"
    )

    def run(self, ctx) -> list[Finding]:
        """Cross-module pass: collect every declaration site, then flag."""
        findings: list[Finding] = []
        # name -> (rel, lineno, kind) of the first declaring site seen, in
        # deterministic module order, so duplicate reports are stable.
        declared: dict[str, tuple[str, int, str]] = {}
        for mod in ctx.iter_modules():
            if mod.name == DEFINING_MODULE:
                continue
            imap = ImportMap(mod.tree, mod.name)
            in_func = _function_body_calls(mod.tree)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                qual = imap.resolve(node.func)
                kind = DECL_FUNCS.get(qual or "")
                if kind is None:
                    continue
                sel = ctx.is_selected(mod.rel)

                def flag(msg: str) -> None:
                    if sel:
                        findings.append(
                            Finding(self.name, mod.rel, node.lineno, msg)
                        )

                name_arg = node.args[0] if node.args else None
                if name_arg is None:
                    for kw in node.keywords:
                        if kw.arg == "name":
                            name_arg = kw.value
                if not (
                    isinstance(name_arg, ast.Constant)
                    and isinstance(name_arg.value, str)
                ):
                    flag(
                        f"{kind}() metric name must be a string literal "
                        "(stringly-typed/ad-hoc names defeat grep, rename, "
                        "and recording rules)"
                    )
                    continue
                metric = name_arg.value
                if not NAME_RE.match(metric):
                    flag(
                        f"metric name {metric!r} is not snake_case "
                        "(expected ^[a-z][a-z0-9_]*$)"
                    )
                if id(node) in in_func:
                    flag(
                        f"metric family {metric!r} declared inside a "
                        "function body — declare once at module scope"
                    )
                prior = declared.get(metric)
                if prior is None:
                    declared[metric] = (mod.rel, node.lineno, kind)
                else:
                    prel, plineno, pkind = prior
                    flag(
                        f"metric name {metric!r} already declared as "
                        f"{pkind} at {prel}:{plineno} — family names must "
                        "be unique repo-wide"
                    )
        return findings
