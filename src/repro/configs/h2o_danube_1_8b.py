"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf].
SWA window 4096 on every layer -> sub-quadratic decode state -> runs
long_500k (cache is a 4096 ring per layer; we keep the full buffer in the
dry-run and mask, the ring optimization is noted in §Perf candidates).
"""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab=32000,
        pattern=(LayerSpec(window=4096),),
        rope_theta=10_000.0,
        max_seq=16384,
        sub_quadratic=True,
    )
