"""LM token pipeline: deterministic, host-sharded, resume-exact.

Batches are generated from a counter-based PRNG keyed on (seed, step,
shard), so (a) every host materializes only its shard, (b) a restart at
step N reproduces the stream exactly, and (c) elastic re-sharding (different
host count) still yields the same global batch — the three properties a
fault-tolerant pipeline needs. Token frequencies are Zipf(1.2) over the
vocab to give the coverage sketch a realistic heavy-tail stream.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0, n_shards: int = 1, shard: int = 0):
        assert batch % n_shards == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.n_shards, self.shard = seed, n_shards, shard
        # Precompute a Zipf CDF over the vocab (rank-frequency law).
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** -1.2
        self._cdf = np.cumsum(p / p.sum())

    def _sample(self, rng, shape):
        u = rng.random(shape)
        return np.searchsorted(self._cdf, u).astype(np.int32)

    def batch_at(self, step: int):
        """Global batch's local shard for this host at a given step."""
        per = self.batch // self.n_shards
        rng = np.random.default_rng((self.seed, step, self.shard))
        toks = self._sample(rng, (per, self.seq + 1))
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
