"""QSketch behaviour: exactness of batching, pruning, merging, duplicates."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, qsketch


def _stream(n, seed=0, dist="gamma"):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    if dist == "gamma":
        w = rng.gamma(1.0, 2.0, n).astype(np.float32) + 1e-4
    elif dist == "uniform":
        w = rng.uniform(0.0, 1.0, n).astype(np.float32) + 1e-4
    else:
        w = np.abs(rng.normal(1.0, 0.1, n)).astype(np.float32) + 1e-4
    return jnp.asarray(ids), jnp.asarray(w)


def test_batch_split_invariance():
    """Updating in one batch == updating in many batches (max is associative)."""
    cfg = SketchConfig(m=128, b=8, seed=1)
    ids, w = _stream(1000)
    whole = qsketch.update(cfg, qsketch.init(cfg), ids, w)
    st = qsketch.init(cfg)
    for i in range(0, 1000, 170):
        st = qsketch.update(cfg, st, ids[i : i + 170], w[i : i + 170])
    np.testing.assert_array_equal(np.asarray(whole.regs), np.asarray(st.regs))


def test_permutation_invariance():
    cfg = SketchConfig(m=128, b=8, seed=1)
    ids, w = _stream(500)
    perm = np.random.default_rng(1).permutation(500)
    a = qsketch.update(cfg, qsketch.init(cfg), ids, w)
    b = qsketch.update(cfg, qsketch.init(cfg), ids[perm], w[perm])
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))


def test_duplicate_idempotence():
    """Sketch of a stream with repeats == sketch of the distinct elements."""
    cfg = SketchConfig(m=128, b=8, seed=2)
    ids, w = _stream(300)
    rep_idx = np.random.default_rng(2).integers(0, 300, 900)
    a = qsketch.update(cfg, qsketch.init(cfg), ids, w)
    b = qsketch.update(cfg, qsketch.init(cfg), ids[rep_idx], w[rep_idx])
    b = qsketch.update(cfg, b, ids, w)  # ensure every distinct appears
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))


def test_registers_monotone():
    cfg = SketchConfig(m=64, b=8, seed=3)
    st = qsketch.init(cfg)
    prev = np.asarray(st.regs, dtype=np.int32)
    for i in range(5):
        ids, w = _stream(200, seed=i)
        st = qsketch.update(cfg, st, ids, w)
        cur = np.asarray(st.regs, dtype=np.int32)
        assert (cur >= prev).all()
        prev = cur


def test_merge_is_union():
    cfg = SketchConfig(m=256, b=8, seed=4)
    ids1, w1 = _stream(400, seed=10)
    ids2, w2 = _stream(400, seed=11)
    a = qsketch.update(cfg, qsketch.init(cfg), ids1, w1)
    b = qsketch.update(cfg, qsketch.init(cfg), ids2, w2)
    merged = qsketch.merge(a, b)
    both = qsketch.update(cfg, qsketch.update(cfg, qsketch.init(cfg), ids1, w1), ids2, w2)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(both.regs))


@pytest.mark.parametrize("dist", ["gamma", "uniform", "gauss"])
def test_estimation_accuracy(dist):
    """RRMSE over trials within ~1.5x of the CR bound 1/sqrt(m-2)."""
    m = 256
    errs = []
    for t in range(20):
        cfg = SketchConfig(m=m, b=8, seed=1000 + t)
        ids, w = _stream(3000, seed=t, dist=dist)
        st = qsketch.update(cfg, qsketch.init(cfg), ids, w)
        true_c = float(np.asarray(w, dtype=np.float64).sum())
        est = float(qsketch.estimate(cfg, st))
        errs.append((est - true_c) / true_c)
    rrmse = float(np.sqrt(np.mean(np.square(errs))))
    assert rrmse < 1.5 / np.sqrt(m - 2), rrmse


def test_pruned_matches_direct_distribution():
    """OS-scheduled (pruned) updates give the same register LAW as direct.

    Compares mean estimates over independent seeds: both must estimate the
    same C within statistical tolerance, and per-register value histograms
    must agree in aggregate.
    """
    m = 128
    ests_d, ests_p = [], []
    all_d, all_p = [], []
    for t in range(15):
        cfg = SketchConfig(m=m, b=8, seed=2000 + t)
        ids, w = _stream(1500, seed=50 + t)
        d = qsketch.update(cfg, qsketch.init(cfg), ids, w)
        p = qsketch.update_pruned(cfg, qsketch.init(cfg), ids, w)
        ests_d.append(float(qsketch.estimate(cfg, d)))
        ests_p.append(float(qsketch.estimate(cfg, p)))
        all_d.append(np.asarray(d.regs, np.int32))
        all_p.append(np.asarray(p.regs, np.int32))
    md, mp = np.mean(ests_d), np.mean(ests_p)
    assert abs(md - mp) / md < 0.08, (md, mp)
    # Aggregate register-value distributions agree (mean within half a bin).
    assert abs(np.mean(all_d) - np.mean(all_p)) < 0.5


def test_pruned_batch_split_consistency():
    """Pruned updates stay exact across batch splits (vs direct sketch law)."""
    cfg = SketchConfig(m=128, b=8, seed=5)
    ids, w = _stream(1200, seed=20)
    whole = qsketch.update_pruned(cfg, qsketch.init(cfg), ids, w)
    st = qsketch.init(cfg)
    for i in range(0, 1200, 300):
        st = qsketch.update_pruned(cfg, st, ids[i : i + 300], w[i : i + 300])
    np.testing.assert_array_equal(np.asarray(whole.regs), np.asarray(st.regs))


def test_prune_mask_is_sound():
    """Pruned-away elements must not be able to change the sketch."""
    cfg = SketchConfig(m=64, b=8, seed=6)
    ids, w = _stream(2000, seed=30)
    st = qsketch.update_pruned(cfg, qsketch.init(cfg), ids[:1500], w[:1500])
    mask = np.asarray(qsketch.prune_mask(cfg, st, ids[1500:], w[1500:]))
    # Feed ONLY the pruned-away elements; sketch must not change.
    dead_ids = ids[1500:][~mask]
    dead_w = w[1500:][~mask]
    if dead_ids.shape[0]:
        st2 = qsketch.update_pruned(cfg, st, dead_ids, dead_w)
        np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(st2.regs))
    # And the mask actually prunes something once the sketch saturates.
    assert (~mask).sum() > 0


def test_mask_rows_ignored():
    cfg = SketchConfig(m=64, b=8, seed=7)
    ids, w = _stream(100, seed=40)
    mask = jnp.asarray(np.arange(100) < 60)
    a = qsketch.update(cfg, qsketch.init(cfg), ids, w, mask=mask)
    b = qsketch.update(cfg, qsketch.init(cfg), ids[:60], w[:60])
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
