"""qobs observability layer tests (src/repro/obs/, DESIGN.md §10).

Coverage per the PR 9 acceptance list:

* registry counter/gauge/histogram semantics (labels, delta snapshots,
  reset, declaration idempotence/mismatch),
* the disabled-mode no-op path (emissions ignored, snapshots empty),
* trace span nesting + the Chrome trace-event JSON contract Perfetto loads,
* ``health_report`` values against hand-built container states, including
  a deliberately top-bin-clamped int8 register plane,
* a Prometheus text-format golden,
* shimmed monitor ``metrics()`` key/value parity for every monitor, and
* the IngestStats lifetime fix: back-to-back pipelines report independent
  numbers, ``snapshot(delta=True)``/``reset()`` semantics.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, dyn_array, key_directory, qsketch, window_array
from repro.core.key_directory import DirectoryConfig
from repro.core.types import QSketchState, WindowArrayState
from repro.launch.mesh import make_sketch_mesh
from repro.obs import export as obs_export
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry
from repro.obs.trace import Tracer
from repro.sketchstream import ingest, monitor

CFG = SketchConfig(m=64, b=6, seed=3)


def _stream(n, seed=0, keys_mod=None):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, keys_mod or 8, n, dtype=np.int32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    return keys, ids, w


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_semantics():
    reg = Registry()
    c = reg.counter("t_requests", help="h")
    g = reg.gauge("t_depth")
    h = reg.histogram("t_lat", low_exp=0, high_exp=3)  # bounds 1,2,4,8 +inf

    c.inc()
    c.inc(4)
    g.set(7)
    g.set(3)
    g.set_max(2)  # below current -> no change
    g.set_max(9)
    for v in (0.5, 3.0, 100.0):
        h.observe(v)

    snap = reg.snapshot()
    assert snap["t_requests"] == 5
    assert snap["t_depth"] == 9
    hist = snap["t_lat"]
    assert hist["count"] == 3 and hist["sum"] == pytest.approx(103.5)
    # 0.5 -> le=1 bucket; 3.0 -> le=4; 100 -> overflow.
    assert hist["buckets"] == [1, 0, 1, 0, 1]
    assert hist["le"] == [1.0, 2.0, 4.0, 8.0, float("inf")]

    with pytest.raises(ValueError):
        c.inc(-1)


def test_labels_and_declaration_contract():
    reg = Registry()
    fam = reg.counter("t_pushed", labels=("pipe",))
    fam.labels(pipe="a").inc(2)
    fam.labels(pipe="b").inc(3)
    snap = reg.snapshot()
    assert snap == {'t_pushed{pipe="a"}': 2, 't_pushed{pipe="b"}': 3}
    # Re-declaration with matching signature is idempotent (same family)...
    assert reg.counter("t_pushed", labels=("pipe",)) is fam
    # ...a mismatched one raises, as do bad names / bad label sets.
    with pytest.raises(ValueError):
        reg.gauge("t_pushed", labels=("pipe",))
    with pytest.raises(ValueError):
        reg.counter("BadName")
    with pytest.raises(ValueError):
        fam.labels(nope="x")


def test_delta_snapshots_and_reset():
    reg = Registry()
    c = reg.counter("t_n")
    g = reg.gauge("t_g")
    c.inc(10)
    g.set(5)
    assert reg.snapshot(delta=True) == {"t_n": 10, "t_g": 5}
    c.inc(3)
    # Counter deltas report the interval; gauges stay point-in-time.
    assert reg.snapshot(delta=True) == {"t_n": 3, "t_g": 5}
    assert reg.snapshot(delta=True) == {"t_n": 0, "t_g": 5}
    assert reg.snapshot() == {"t_n": 13, "t_g": 5}  # cumulative untouched
    reg.reset()
    assert reg.snapshot() == {"t_n": 0, "t_g": 0}


def test_disabled_registry_is_noop():
    reg = Registry(enabled=False)
    c = reg.counter("t_n")
    h = reg.histogram("t_h")
    c.inc(100)
    h.observe(1.0)
    assert c.value == 0 and h._default.count == 0
    assert reg.snapshot() == {}
    # Re-enabling resumes recording from the frozen values.
    reg.configure(enabled=True)
    c.inc(2)
    assert reg.snapshot()["t_n"] == 2


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_trace_nesting_and_chrome_json(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    events = tr.events()
    assert [e["name"] for e in events] == ["inner", "outer"]  # exit order
    inner, outer = events
    assert inner["args"]["path"] == "outer/inner"
    assert outer["args"] == {"path": "outer", "k": 1}
    # Chrome trace-event contract: complete events, µs timestamps, and the
    # inner span nested inside the outer one's [ts, ts+dur) interval.
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3

    path = tmp_path / "trace.json"
    tr.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert {e["name"] for e in doc["traceEvents"]} == {"outer", "inner"}
    assert tr.stage_totals()["outer"] >= tr.stage_totals()["inner"]


def test_trace_disabled_and_under_jit_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x") is obs_trace._NULL
    tr.configure(enabled=True)

    seen = []

    @jax.jit
    def f(x):
        # Under an active trace the span must degrade to the shared no-op.
        seen.append(tr.span("inside_jit"))
        return x + 1

    f(jnp.zeros(())).block_until_ready()
    assert seen[0] is obs_trace._NULL
    assert tr.events() == []
    # maybe_sync only fires on the configured cadence.
    tr.configure(sync_every=2)
    assert not tr.maybe_sync("s", jnp.zeros(()), tick=1)
    assert tr.maybe_sync("s", jnp.zeros(()), tick=2)
    assert tr.events()[0]["args"]["sampled"] is True


# ---------------------------------------------------------------------------
# health reports
# ---------------------------------------------------------------------------


def test_health_saturated_plane_warns_healthy_quiet():
    rng = np.random.default_rng(7)
    ids = jnp.asarray(rng.integers(0, 2**63, 800, dtype=np.int64))
    w = jnp.asarray(rng.uniform(0.1, 2.0, 800), jnp.float32)
    healthy = qsketch.update(CFG, qsketch.init(CFG), ids, w)
    rep = obs_health.health_report(CFG, healthy)
    assert rep["container"] == "qsketch" and rep["ok"], rep["warnings"]

    # Hand-built top-bin-clamped int8 plane: every register at r_max.
    clamped = QSketchState(regs=jnp.full((CFG.m,), CFG.r_max, jnp.int8))
    rep = obs_health.health_report(CFG, clamped)
    assert not rep["ok"] and "register_saturation_frac" in rep["warnings"]
    assert rep["checks"]["register_saturation_frac"]["value"] == 1.0
    # A fresh plane: zero saturation, zero occupancy.
    rep = obs_health.health_report(CFG, qsketch.init(CFG))
    assert rep["checks"]["register_saturation_frac"]["value"] == 0.0
    assert rep["checks"]["occupancy_frac"]["value"] == 0.0


def test_health_dyn_array_and_drift_threshold():
    k, n = 4, 4000
    keys, ids, w = _stream(n, seed=1, keys_mod=k)
    st = dyn_array.update_batch(
        CFG, dyn_array.init(CFG, k),
        jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w),
    )
    rep = obs_health.health_report(CFG, st)
    assert rep["container"] == "dyn_array" and rep["ok"], rep["warnings"]
    # Corrupt the martingales by 100x: the anytime-vs-MLE drift check is
    # exactly the probe that must fire.
    bad = st._replace(chats=st.chats * 100.0)
    rep = obs_health.health_report(CFG, bad)
    assert "anytime_mle_drift" in rep["warnings"]


def test_health_window_staleness_and_directory():
    k, e = 8, 3
    keys, ids, w = _stream(2000, seed=2, keys_mod=k)
    st = window_array.update_batch(
        CFG, window_array.init(CFG, k, e),
        jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w),
    )
    rep = obs_health.health_report(CFG, st)
    assert rep["container"] == "window_array"
    assert rep["checks"]["union_staleness_frac"]["value"] == 0.0
    # Corrupt the union cache: staleness must flag (threshold is 0).
    stale = st._replace(union_regs=jnp.zeros_like(st.union_regs))
    rep = obs_health.health_report(CFG, stale)
    assert "union_staleness_frac" in rep["warnings"]

    # Directory checks ride along when a directory is passed.
    dcfg = DirectoryConfig(capacity=8, seed=3)
    dstate = key_directory.init(dcfg)
    _, dstate = key_directory.route(
        dcfg, dstate, jnp.asarray(np.arange(64, dtype=np.uint32))
    )
    rep = obs_health.health_report(CFG, st, directory=dstate, dcfg=dcfg)
    assert "directory_load_factor" in rep["checks"]
    assert "directory_load_factor" in rep["warnings"]  # 64 keys into 8 slots


def test_health_virtual_pool_thresholds():
    """Satellite #4: the virtual tier's pool checks warn past their bounds
    and stay quiet inside them, and the hot tier folds in under hot_*."""
    from repro.core import virtual_dyn_array as vda
    from repro.core.virtual_dyn_array import VirtualConfig

    rng = np.random.default_rng(5)
    tk = jnp.asarray(rng.integers(0, 2**31, 600, dtype=np.int64), jnp.uint32)
    ids = jnp.asarray(rng.integers(0, 2**31, 600, dtype=np.int64), jnp.uint32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, 600), jnp.float32)

    # Small pool -> load factor blows past the 0.5 default and warns.
    vcfg = VirtualConfig(pool_size=256, pinned=(7,))
    st = vda.update_tenants(CFG, vcfg, vda.init(CFG, vcfg), tk, ids, w)
    rep = obs_health.health_report(CFG, st, vcfg=vcfg)
    assert rep["container"] == "virtual_dyn_array"
    assert "pool_load_factor" in rep["warnings"]
    assert rep["checks"]["pool_load_factor"]["value"] == pytest.approx(
        float(vda.pool_load_factor(st))
    )
    # The reported floor is the estimator's own subtraction term.
    assert rep["checks"]["pool_noise_floor"]["value"] == pytest.approx(
        float(vda.noise_floor(CFG, vcfg, st)), rel=1e-6
    )
    assert not rep["checks"]["pool_noise_floor"]["warn"]  # no default bound
    assert rep["checks"]["pool_weight_total"]["value"] == pytest.approx(
        float(st.w_tail)
    )
    assert any(k.startswith("hot_") for k in rep["checks"])

    # Large pool -> same traffic is healthy; tight floor bound flips it.
    vcfg_big = VirtualConfig(pool_size=1 << 14, pinned=(7,))
    st_big = vda.update_tenants(
        CFG, vcfg_big, vda.init(CFG, vcfg_big), tk, ids, w
    )
    rep = obs_health.health_report(CFG, st_big, vcfg=vcfg_big)
    assert "pool_load_factor" not in rep["warnings"]
    tight = obs_health.Thresholds(pool_noise_floor=1e-3)
    rep = obs_health.health_report(CFG, st_big, vcfg=vcfg_big, thresholds=tight)
    assert "pool_noise_floor" in rep["warnings"]
    # An empty container is quiet under the defaults.
    rep = obs_health.health_report(CFG, vda.init(CFG, vcfg_big), vcfg=vcfg_big)
    assert rep["ok"], rep["warnings"]


def test_health_rejects_unknown_and_traced():
    with pytest.raises(TypeError):
        obs_health.health_report(CFG, object())

    @jax.jit
    def f(x):
        with pytest.raises(RuntimeError):
            obs_health.health_report(CFG, QSketchState(regs=x))
        return x

    f(jnp.zeros((CFG.m,), jnp.int8))


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_prometheus_text_golden(tmp_path):
    reg = Registry()
    reg.counter("t_reqs", help="requests", labels=("pipe",)).labels(pipe="0").inc(3)
    reg.gauge("t_depth").set(2)
    h = reg.histogram("t_lat", low_exp=0, high_exp=1)  # bounds 1, 2, +inf
    h.observe(0.5)
    h.observe(1.5)
    h.observe(9.0)
    golden = (
        '# HELP t_reqs requests\n'
        '# TYPE t_reqs counter\n'
        't_reqs{pipe="0"} 3\n'
        '# TYPE t_depth gauge\n'
        't_depth 2\n'
        '# TYPE t_lat histogram\n'
        't_lat_bucket{le="1"} 1\n'
        't_lat_bucket{le="2"} 2\n'
        't_lat_bucket{le="+Inf"} 3\n'
        't_lat_sum 11.0\n'
        't_lat_count 3\n'
    )
    assert obs_export.prometheus_text(reg) == golden
    path = tmp_path / "metrics.prom"
    obs_export.write_prometheus(str(path), reg)
    assert path.read_text() == golden
    assert obs_export.prometheus_text(Registry(enabled=False)) == ""


def test_jsonl_writer_delta(tmp_path):
    reg = Registry()
    c = reg.counter("t_n")
    path = tmp_path / "obs.jsonl"
    wr = obs_export.JsonlWriter(str(path), reg, delta=True)
    c.inc(5)
    wr.write(step=1)
    c.inc(2)
    wr.write(step=2)
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert [r["metrics"]["t_n"] for r in recs] == [5, 2]
    assert [r["step"] for r in recs] == [1, 2]
    assert all("ts" in r for r in recs)


# ---------------------------------------------------------------------------
# monitor metrics() shims: key/value parity with the historical dicts
# ---------------------------------------------------------------------------


def _tenant_stream(n, seed=0):
    rng = np.random.default_rng(seed)
    tenants = rng.integers(1, 6, n, dtype=np.uint32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    return jnp.asarray(tenants), jnp.asarray(ids), jnp.asarray(w)


def _expect_base(state):
    return {
        "tenant_elements_seen": int(state.n_seen),
        "tenant_slots_claimed": int(
            jnp.sum((state.directory.fingerprints != 0).astype(jnp.int32))
        ),
        "tenant_collision_rate": float(
            key_directory.collision_rate(state.directory)
        ),
    }


@pytest.mark.parametrize("kind", ["dyn", "window", "sharded_array",
                                  "sharded_dyn", "sharded_window", "virtual"])
def test_monitor_metrics_parity(kind):
    tenants, ids, w = _tenant_stream(256, seed=11)
    if kind == "virtual":
        from repro.core import virtual_dyn_array as vda

        mon = monitor.VirtualDynMonitor.for_pool(CFG, 512, pinned=(1,))
        st = mon.update(mon.init(), tenants, ids, w)
        got = mon.metrics(st)
        # No directory telemetry (stateless tail routing) — pool pressure
        # replaces it; key order is the documented dict.
        expect = {
            "tenant_elements_seen": int(st.n_seen),
            "virtual_pool_load_factor": float(vda.pool_load_factor(st.array)),
            "virtual_pool_weight_total": float(st.array.w_tail),
            "virtual_tail_elements": int(st.array.n_tail),
            "tenant_weight_total": float(jnp.sum(st.array.hot.chats)),
        }
        assert list(got) == list(expect)
        for k, v in expect.items():
            assert float(got[k]) == pytest.approx(v), k
        if obs_metrics.enabled():
            snap = obs_metrics.snapshot()
            for k in expect:
                assert f'{k}{{monitor="virtual_dyn"}}' in snap, k
        return
    if kind == "dyn":
        mon = monitor.DynArrayMonitor.for_capacity(CFG, 16)
        expect_extra = lambda st: {
            "tenant_weight_total": float(jnp.sum(st.chats))
        }
    elif kind == "window":
        mon = monitor.WindowMonitor.for_capacity(CFG, 16, 3)
        expect_extra = lambda st: {
            "tenant_window_weight": float(jnp.sum(st.window.union_chats)),
            "tenant_window_epoch": int(st.window.epoch_id),
        }
    elif kind == "sharded_array":
        mon = monitor.ShardedArrayMonitor.for_mesh(CFG, 16, make_sketch_mesh(2))
        expect_extra = lambda st: {}
    elif kind == "sharded_dyn":
        mon = monitor.ShardedDynMonitor.for_mesh(CFG, 16, make_sketch_mesh(2))
        expect_extra = lambda st: {"tenant_weight_total": float(jnp.sum(st.array.chats))}
    else:
        mon = monitor.ShardedWindowMonitor.for_mesh(
            CFG, 16, 3, make_sketch_mesh(2)
        )
        expect_extra = lambda st: {
            "tenant_window_weight": float(jnp.sum(st.window.union_chats)),
            "tenant_window_epoch": int(st.window.epoch_id),
        }
    st = mon.update(mon.init(), tenants, ids, w)
    got = mon.metrics(st)
    expect = {**_expect_base(st), **expect_extra(st)}
    # Exact historical key ORDER and values.
    assert list(got) == list(expect)
    for k, v in expect.items():
        assert float(got[k]) == pytest.approx(v), k
    # The shim also mirrors into the default registry (when enabled).
    if obs_metrics.enabled():
        snap = obs_metrics.snapshot()
        for k in expect:
            key = f'{k}{{monitor="{_kind_label(kind)}"}}'
            assert key in snap, key


def _kind_label(kind):
    return {"dyn": "dyn_array", "window": "window",
            "sharded_array": "sharded_array", "sharded_dyn": "sharded_dyn",
            "sharded_window": "sharded_window"}[kind]


def test_monitor_metrics_traceable_under_jit():
    mon = monitor.DynArrayMonitor.for_capacity(CFG, 16)
    st = mon.init()

    @jax.jit
    def f(s):
        return mon.metrics(s)["tenant_collision_rate"]

    assert float(f(st)) == 0.0


# ---------------------------------------------------------------------------
# IngestStats lifetime semantics
# ---------------------------------------------------------------------------


def _run_pipe(n, seed):
    keys, ids, w = _stream(n, seed=seed, keys_mod=16)
    pipe = ingest.dyn_pipeline(
        CFG, dyn_array.init(CFG, 16), ingest.IngestConfig(batch_size=64)
    )
    pipe.push(keys, ids, w)
    pipe.result()
    return pipe


def test_ingest_stats_back_to_back_independent():
    a = _run_pipe(256, seed=1)
    b = _run_pipe(256, seed=2)
    # The historical bug: a second pipeline's counters continued from the
    # first one's totals. Each run must stand alone.
    assert a.stats.pushed == 256
    assert b.stats.pushed == 256
    assert b.stats.batches == 4
    assert b.metrics()["ingest_elements_pushed"] == 256


def test_ingest_stats_delta_snapshot_and_reset():
    pipe = _run_pipe(128, seed=3)
    s = pipe.stats
    first = s.snapshot(delta=True)
    assert first["pushed"] == 128
    # No traffic since the last delta snapshot -> counters read zero,
    # gauges stay point-in-time.
    second = s.snapshot(delta=True)
    assert second["pushed"] == 0
    assert second["max_in_flight"] == first["max_in_flight"]
    assert s.snapshot()["pushed"] == 128  # cumulative intact
    s.reset()
    assert s.snapshot()["pushed"] == 0
    assert s.pushed == 0


def test_ingest_metrics_dict_shape():
    pipe = _run_pipe(64, seed=4)
    m = pipe.metrics()
    assert list(m) == [
        "ingest_elements_pushed", "ingest_elements_dropped", "ingest_batches",
        "ingest_partial_batches", "ingest_stalls", "ingest_stall_s",
        "ingest_in_flight", "ingest_max_in_flight", "ingest_rotations",
        "ingest_barriers",
    ]
    assert isinstance(m["ingest_stall_s"], float)
    assert m["ingest_elements_pushed"] == 64
