"""Pallas TPU kernel: keyed QSketch-Dyn batch q_R against gathered histograms.

The DynArray update's dense inner stage is the per-element update
probability

    q_i = 1 - (1/m) Σ_k T[key_i, k] · exp(-w_i · s_k),  s_k = 2^{-(k+r_min+1)}

— the keyed generalization of ``kernels/qdyn_qr.py``: instead of ONE
histogram broadcast against every weight, each element brings its own key's
batch-start histogram row. The caller gathers ``hists[keys]`` (an XLA gather
HBM->HBM); the kernel streams (B_blk × NB) row-tiles through VMEM fused with
the exp/multiply/reduce, so the (B × 2^b) f32 intermediate product never
exists in HBM. At serving batch sizes this runs per decoded batch for every
tenant-keyed stream — the DynArray hot path.

The remaining update stages (dedup lexsort, segment scatter-max, incremental
histogram moves) are data-dependent scatters that stay in XLA
(``core/dyn_array._apply_update``); ``ops.dyn_array_update_op`` fuses kernel
q_R + core tail and is bit-identical to ``core.dyn_array.update_batch``.

Layout: histogram bins (NB = 2^b <= 256) on the lane axis padded to a
128-multiple (zero-count pad bins contribute exact 0.0 to the sum); batch on
sublanes. Padding batch rows carry w = 1 against a zero histogram row
(q = 1) and are sliced off by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from . import compat

DEFAULT_BLOCK_B = 512


def _keyed_qr_kernel(w_ref, hist_rows_ref, scales_ref, out_ref, *, m):
    w = w_ref[...]  # (B_blk, 1)
    t = hist_rows_ref[...]  # (B_blk, NB) — this block's gathered rows
    s = scales_ref[...]  # (1, NB)
    expo = jnp.exp(-w * s)  # (B_blk, NB) lives only in VMEM/VREGs
    acc = jnp.sum(t * expo, axis=1, keepdims=True)  # (B_blk, 1)
    out_ref[...] = 1.0 - acc / m


@functools.partial(jax.jit, static_argnames=("m", "block_b", "interpret"))
def dyn_array_qr_padded(
    weights, hist_rows, scales, *, m: int, block_b: int = DEFAULT_BLOCK_B, interpret: bool = False
):
    """q_R per element. weights: (B, 1) f32, B % block_b == 0; hist_rows:
    (B, NB) f32 — row i is element i's key's histogram — with NB a multiple
    of 128 (zero-count pad bins); scales: (1, NB) f32."""
    b = weights.shape[0]
    nb = hist_rows.shape[1]
    kernel = functools.partial(_keyed_qr_kernel, m=float(m))
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((block_b, nb), lambda bi: (bi, 0)),
            pl.BlockSpec((1, nb), lambda bi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        compiler_params=compat.CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(weights, hist_rows, scales)
