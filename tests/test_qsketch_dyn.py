"""QSketch-Dyn: exact-scan vs numpy oracle, unbiasedness, batch-mode bias."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, qsketch_dyn


def _stream(n, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    return ids, w


def test_scan_matches_numpy_oracle():
    cfg = SketchConfig(m=64, b=8, seed=5)
    ids, w = _stream(400, seed=1)
    d = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    regs, hist, chat = qsketch_dyn.update_numpy(cfg, ids, np.zeros_like(ids), w)
    np.testing.assert_array_equal(np.asarray(d.regs, np.int64), regs)
    np.testing.assert_array_equal(np.asarray(d.hist, np.int64), hist)
    assert abs(float(d.chat) - chat) / max(chat, 1e-9) < 1e-4


def test_duplicates_do_not_double_count():
    """Feeding the same stream twice must leave Ĉ unchanged (Thm. 2 premise)."""
    cfg = SketchConfig(m=128, b=8, seed=6)
    ids, w = _stream(500, seed=2)
    d1 = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    d2 = qsketch_dyn.update_scan(cfg, d1, jnp.asarray(ids), jnp.asarray(w))
    assert float(d1.chat) == float(d2.chat)
    np.testing.assert_array_equal(np.asarray(d1.regs), np.asarray(d2.regs))


def test_estimator_unbiased():
    """Mean of Ĉ over trials within a few stderr of true C (Thm. 2)."""
    n = 2000
    ests = []
    true_c = None
    for t in range(25):
        cfg = SketchConfig(m=256, b=8, seed=3000 + t)
        ids, w = _stream(n, seed=t)
        true_c = float(w.astype(np.float64).sum())
        d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        ests.append(float(d.chat))
    mean = np.mean(ests)
    stderr = np.std(ests) / np.sqrt(len(ests))
    assert abs(mean - true_c) < 4 * stderr + 0.01 * true_c, (mean, true_c, stderr)


def test_batch_vs_scan_bias_small():
    """Batch-stale q_R deviates from the exact chain by << sketch noise."""
    cfg = SketchConfig(m=256, b=8, seed=8)
    ids, w = _stream(4000, seed=9)
    exact = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    batched = qsketch_dyn.init(cfg)
    for i in range(0, 4000, 512):
        batched = qsketch_dyn.update_batch(cfg, batched, jnp.asarray(ids[i : i + 512]), jnp.asarray(w[i : i + 512]))
    # Registers identical (same hash randomness, max-scatter).
    np.testing.assert_array_equal(np.asarray(exact.regs), np.asarray(batched.regs))
    c_exact, c_batch = float(exact.chat), float(batched.chat)
    assert abs(c_exact - c_batch) / c_exact < 0.05, (c_exact, c_batch)


def test_within_batch_duplicates_counted_once():
    cfg = SketchConfig(m=128, b=8, seed=10)
    ids, w = _stream(100, seed=11)
    dup_ids = np.concatenate([ids, ids])
    dup_w = np.concatenate([w, w])
    a = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    b = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(dup_ids), jnp.asarray(dup_w))
    assert float(a.chat) == pytest.approx(float(b.chat), rel=1e-6)
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))


def test_merge_reestimates():
    cfg = SketchConfig(m=256, b=8, seed=12)
    ids1, w1 = _stream(1500, seed=20)
    ids2, w2 = _stream(1500, seed=21)
    a = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids1), jnp.asarray(w1))
    b = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids2), jnp.asarray(w2))
    merged = qsketch_dyn.merge(cfg, a, b)
    true_c = float(w1.astype(np.float64).sum() + w2.astype(np.float64).sum())
    # MLE over merged registers: statistical tolerance at m=256.
    assert abs(float(merged.chat) - true_c) / true_c < 0.35
    # Merged registers are the element-wise max.
    np.testing.assert_array_equal(
        np.asarray(merged.regs), np.maximum(np.asarray(a.regs), np.asarray(b.regs))
    )


def test_hist_consistent_with_regs():
    cfg = SketchConfig(m=128, b=8, seed=13)
    ids, w = _stream(2000, seed=22)
    d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    regs = np.asarray(d.regs, np.int64)
    expected = np.bincount(regs[regs > cfg.r_min] - cfg.r_min, minlength=cfg.num_bins)
    np.testing.assert_array_equal(np.asarray(d.hist), expected)


def test_mle_reestimate_close_to_running():
    cfg = SketchConfig(m=512, b=8, seed=14)
    ids, w = _stream(5000, seed=23)
    d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    running = float(d.chat)
    mle = float(qsketch_dyn.estimate_mle(cfg, d))
    true_c = float(w.astype(np.float64).sum())
    assert abs(running - true_c) / true_c < 0.2
    assert abs(mle - true_c) / true_c < 0.2
