"""whisper-large-v3 [audio] — encoder-decoder; conv frontend is a STUB.

32+32L d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified]. input_specs supplies precomputed
(B, 1500, 1280) frame embeddings (post-conv mel frontend). Decoder layers
carry cross-attention to the encoder memory. Adaptations: RoPE replaces the
original learned/sinusoidal positions (noted in DESIGN.md); decode shapes
exercise 32k decoder positions purely as a sharding/shape workload — the
real model's decoder context is 448. long_500k skipped (enc-dec, full attn).
"""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        pattern=(LayerSpec(cross_attn=True),),
        n_enc_layers=32,
        enc_seq=1500,
        frontend="frames",
        rope_theta=10_000.0,
        max_seq=448,
    )
