"""qlint execution: file collection, rule running, report writing.

``build_context`` parses the analysis scope (``src/repro``, ``benchmarks``,
``examples`` — tests and host CLIs under ``scripts/`` are out of scope) into
a Context the rules share; ``run_qlint`` executes the rules, matches
findings against the baseline and inline suppressions, and returns the
report dict the CLI serializes to ``experiments/analysis/report.json``.

``--changed-only`` narrows *reporting* (not parsing — cross-module rules
still see the whole tree) to files touched per git: unstaged + staged
diffs against HEAD plus untracked files.
"""

from __future__ import annotations

import ast
import dataclasses
import subprocess
import time
from pathlib import Path

from repro.analysis.baseline import Baseline, inline_suppressed
from repro.analysis.findings import Finding
from repro.analysis.registry import all_rules

SCOPE_DIRS = ("src/repro", "benchmarks", "examples")
DEFAULT_BASELINE = "scripts/qlint_baseline.json"


@dataclasses.dataclass
class Module:
    """One parsed source file: paths, dotted name, AST, raw lines."""

    path: str  # absolute
    rel: str  # repo-relative, posix
    name: str  # dotted module name
    tree: ast.Module
    source: str
    lines: list[str]


class Context:
    """Everything a rule sees: the parsed module set, the repo root, and
    the reporting selection (None = all files)."""

    def __init__(self, root: str, modules: dict[str, Module], selected: set[str] | None):
        self.root = root
        self.modules = modules
        self.selected = selected
        self.parse_errors: list[Finding] = []
        self._by_name = {m.name: m for m in modules.values()}

    def is_selected(self, rel: str) -> bool:
        """Whether findings in ``rel`` should be reported this run."""
        return self.selected is None or rel in self.selected

    def iter_modules(self, prefix: str | tuple[str, ...] = ()) -> list[Module]:
        """Modules whose repo-relative path starts with ``prefix`` (all if
        empty), sorted by path for deterministic reports."""
        mods = [
            m
            for rel, m in sorted(self.modules.items())
            if not prefix or rel.startswith(prefix)
        ]
        return mods

    def module_by_name(self, dotted: str) -> Module | None:
        """Parsed module for a dotted name (``repro.core.dyn_array``)."""
        return self._by_name.get(dotted)


def _iter_py_files(root: Path) -> list[Path]:
    files = []
    for scope in SCOPE_DIRS:
        base = root / scope
        if base.is_dir():
            files += sorted(base.rglob("*.py"))
    return files


def build_context(root: str, selected: list[str] | None = None) -> Context:
    """Parse the analysis scope under ``root`` into a Context.

    ``selected``: repo-relative paths to *report on* (None = everything).
    Unparseable files become ``parse-error`` findings rather than crashes.
    """
    rootp = Path(root).resolve()
    modules: dict[str, Module] = {}
    errors: list[Finding] = []
    for path in _iter_py_files(rootp):
        rel = path.relative_to(rootp).as_posix()
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as e:
            errors.append(
                Finding("parse-error", rel, e.lineno or 1, f"syntax error: {e.msg}")
            )
            continue
        from repro.analysis.astutil import module_name_for

        modules[rel] = Module(
            path=str(path),
            rel=rel,
            name=module_name_for(rel),
            tree=tree,
            source=source,
            lines=source.splitlines(),
        )
    sel = None
    if selected is not None:
        sel = {Path(s).as_posix() for s in selected}
    ctx = Context(str(rootp), modules, sel)
    ctx.parse_errors = errors
    return ctx


def changed_files(root: str) -> list[str]:
    """Repo-relative paths git considers changed: worktree + index diffs
    against HEAD, plus untracked (non-ignored) files."""
    out: set[str] = set()
    for cmd in (
        ["git", "diff", "--name-only", "HEAD"],
        ["git", "ls-files", "--others", "--exclude-standard"],
    ):
        proc = subprocess.run(cmd, cwd=root, capture_output=True, text=True)
        if proc.returncode == 0:
            out.update(line for line in proc.stdout.splitlines() if line)
    return sorted(out)


def run_qlint(
    root: str,
    rule_subset: list[str] | None = None,
    selected: list[str] | None = None,
    changed_only: bool = False,
    baseline_path: str | None = DEFAULT_BASELINE,
) -> dict:
    """Run the rules and return the report dict (see module docstring).

    ``ok`` in the report is True iff no finding is new (un-baselined, not
    inline-suppressed). ``selected`` and ``changed_only`` compose: explicit
    paths win, else git-changed files, else the full scope.
    """
    t0 = time.monotonic()
    if selected is None and changed_only:
        selected = [p for p in changed_files(root) if p.endswith((".py", ".json"))]
    ctx = build_context(root, selected)

    rules = all_rules()
    if rule_subset is not None:
        wanted = set(rule_subset)
        unknown = wanted - {r.name for r in rules}
        if unknown:
            raise KeyError(f"unknown rule(s): {sorted(unknown)}")
        rules = [r for r in rules if r.name in wanted]

    findings: list[Finding] = list(ctx.parse_errors)
    per_rule: dict[str, int] = {}
    for rule in rules:
        got = sorted(rule.run(ctx))
        per_rule[rule.name] = len(got)
        findings += got

    base = Baseline(str(Path(root) / baseline_path) if baseline_path else None)
    rows = []
    new = 0
    for f in findings:
        mod = ctx.modules.get(f.path)
        just = base.justification(f)
        if just is None and mod is not None and inline_suppressed(f, mod.lines):
            just = "inline suppression"
        row = {
            "rule": f.rule,
            "path": f.path,
            "line": f.line,
            "message": f.message,
            "key": f.key,
            "baselined": just is not None,
        }
        if just is not None:
            row["justification"] = just
        else:
            new += 1
        rows.append(row)

    return {
        "tool": "qlint",
        "mode": "selected" if ctx.selected is not None else "full",
        "rules": [r.name for r in rules],
        "files_analyzed": len(ctx.modules),
        "files_selected": (
            len(ctx.selected) if ctx.selected is not None else len(ctx.modules)
        ),
        "findings": rows,
        "counts": {
            "total": len(rows),
            "baselined": len(rows) - new,
            "new": new,
            "per_rule": per_rule,
        },
        # Staleness is only meaningful for a full run: a partial run (rule
        # subset or file selection) cannot produce the findings the other
        # entries match, so they would all look spuriously stale.
        "stale_baseline_keys": (
            base.stale_keys(findings)
            if ctx.selected is None and rule_subset is None
            else []
        ),
        "elapsed_s": round(time.monotonic() - t0, 3),
        "ok": new == 0,
    }
