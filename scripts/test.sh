#!/usr/bin/env bash
# Tier-1 test entry: one command, correct env.
#
#   scripts/test.sh                 # full tier-1 suite
#   scripts/test.sh --tier2         # tier-1 + benchmark smoke + qlint
#   scripts/test.sh tests/test_kernels.py -k qsketch   # pass-through args
#
# - PYTHONPATH=src so `repro` imports without an install step.
# - XLA_FLAGS exposes 8 host devices (per SNIPPETS.md) so mesh/sharding tests
#   exercise multi-device code paths on a CPU-only box; an existing
#   XLA_FLAGS setting is preserved and extended.
# - --tier2 additionally (0) re-runs the property + differential suites
#   under HYPOTHESIS_PROFILE=deep (tier-1 uses the quick profile; see
#   tests/conftest.py), then (1) runs `python -m benchmarks.run --smoke` (the
#   quick profile over the fast suites, incl. the sharded SketchArray /
#   DynArray / WindowArray sweeps and the estimation solver sweep) so CI
#   catches benchmark-path rot without paying for the paper-scale sweeps,
#   then (2) runs the qlint static-analysis suite (scripts/check_static.py,
#   DESIGN.md §9): the estimation-layering rule (containers solve
#   histograms only through core/estimation.py — this replaced the old
#   qsketch_mle grep, which could not see through import aliases or cover
#   kernels/), int8-overflow, donation-safety, jit-purity, kernel-contract,
#   the public-docstring audit, and the cumulative bench-JSON schema check
#   (which is why qlint runs AFTER the smoke benchmarks). The JSON report
#   lands in experiments/analysis/report.json; any finding that is neither
#   baselined (scripts/qlint_baseline.json) nor inline-suppressed fails
#   the build. Finally (3) an observability smoke: a short ingest-
#   instrumented train run must produce a parseable --obs-jsonl snapshot
#   with the required metric families and a Perfetto-loadable trace with
#   the pipeline stage spans, asserted via scripts/obs_dump.py.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

tier2=0
if [[ "${1:-}" == "--tier2" ]]; then
  tier2=1
  shift
fi

python -m pytest -x -q "$@"

if [[ "$tier2" == 1 ]]; then
  echo "== tier-2: deep property/differential profile =="
  # Tier-1 runs the property suites under the quick profile; tier-2 re-runs
  # them with HYPOTHESIS_PROFILE=deep (more examples per @given test, no
  # derandomization under real hypothesis) so the randomized algebra /
  # oracle / statistical-envelope claims get real exploration in CI.
  HYPOTHESIS_PROFILE=deep python -m pytest -x -q \
    tests/test_property.py tests/test_differential.py
  echo "== tier-2: benchmark smoke paths =="
  python -m benchmarks.run --smoke
  echo "== tier-2: qlint static analysis =="
  python scripts/check_static.py
  echo "== tier-2: observability smoke (DESIGN.md §10) =="
  # A few ingest-instrumented train steps must yield a parseable JSONL
  # snapshot with the required metric families, a Perfetto-loadable trace
  # with the pipeline stage spans, and health_report must flag a saturated
  # sketch while staying quiet on a healthy one (scripts/obs_dump.py exits
  # non-zero on any missing artifact).
  obs_dir="$(mktemp -d)"
  trap 'rm -rf "$obs_dir"' EXIT
  python -m repro.launch.train --arch small-lm-16m --steps 4 --batch 2 \
    --seq 32 --log-every 2 --ckpt-every 100 --ckpt-dir "$obs_dir/ckpt" \
    --doc-window-capacity 64 --ingest --ingest-batch 128 --rotate-every 2 \
    --obs-jsonl "$obs_dir/obs.jsonl" --obs-trace "$obs_dir/trace.json" \
    > /dev/null
  python scripts/obs_dump.py jsonl "$obs_dir/obs.jsonl" --require \
    ingest_elements_pushed ingest_batches tenant_slots_claimed \
    tenant_collision_rate > /dev/null
  python scripts/obs_dump.py trace "$obs_dir/trace.json" --require \
    ingest/push ingest/dispatch ingest/retire ingest/rotate > /dev/null
  python scripts/obs_dump.py health > /dev/null
  echo "obs smoke: OK"
fi
