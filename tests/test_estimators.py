"""MLE estimator tests: f32 kernel vs f64 oracle, degeneracy, Thm.-1 ranges."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, estimators, qsketch
from repro.core.types import QSketchState


def _sketch_regs(cfg, n, scale, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.uniform(0.5, 1.5, n) * scale).astype(np.float32)
    st = qsketch.init(cfg)
    st = qsketch.update(cfg, st, jnp.asarray(ids), jnp.asarray(w))
    return st, float(w.astype(np.float64).sum())


@pytest.mark.parametrize("scale", [1e-33, 1e-20, 1e-6, 1.0, 1e6, 1e20, 1e33])
def test_extreme_magnitudes(scale):
    """The rebased f32 Newton must track the f64 oracle across ~70 decades.

    (Without the rebase, f'(C) ~ -m/C^2 under/overflows f32 beyond ~1e15;
    see DESIGN.md §4.4 and EXPERIMENTS.md §Numerics.)
    """
    cfg = SketchConfig(m=512, b=8, seed=7)
    st, true_c = _sketch_regs(cfg, 2000, scale)
    est32 = float(qsketch.estimate(cfg, st))
    est64 = estimators.mle_numpy(cfg, np.asarray(st.regs))
    assert abs(est32 - est64) / est64 < 1e-4
    assert abs(est32 - true_c) / true_c < 0.35  # statistical bound, m=512


@pytest.mark.parametrize("m", [64, 256, 1024])
def test_f32_matches_f64(m):
    cfg = SketchConfig(m=m, b=8, seed=13)
    st, _ = _sketch_regs(cfg, 5000, 1.0, seed=3)
    est32 = float(qsketch.estimate(cfg, st))
    est64 = estimators.mle_numpy(cfg, np.asarray(st.regs))
    assert abs(est32 - est64) / est64 < 1e-4


def test_empty_sketch_estimates_zero():
    cfg = SketchConfig(m=128, b=8)
    st = qsketch.init(cfg)
    assert float(qsketch.estimate(cfg, st)) == 0.0


def test_saturated_sketch_flagged():
    cfg = SketchConfig(m=128, b=8)
    st = QSketchState(regs=jnp.full((cfg.m,), cfg.r_max, dtype=jnp.int8))
    chat, _, ok = qsketch.estimate_with_ci(cfg, st)
    assert not bool(ok)
    assert float(chat) > 1e30  # falls back to the (huge) seed estimate


def test_fisher_stddev_tracks_empirical():
    """CR bound ~ empirical std over trials (within a loose factor)."""
    cfg = SketchConfig(m=256, b=8, seed=1)
    true_c = None
    ests, stds = [], []
    for t in range(30):
        st, true_c = _sketch_regs(SketchConfig(m=256, b=8, seed=100 + t), 3000, 1.0, seed=t)
        chat, std, _ = qsketch.estimate_with_ci(SketchConfig(m=256, b=8, seed=100 + t), st)
        ests.append(float(chat))
        stds.append(float(std))
    emp_std = np.std(ests)
    mean_cr = np.mean(stds)
    assert 0.3 < emp_std / mean_cr < 3.0, (emp_std, mean_cr)


def test_histogram_matches_bincount():
    cfg = SketchConfig(m=512, b=6, seed=2)
    st, _ = _sketch_regs(cfg, 1000, 1.0, seed=5)
    h = np.asarray(estimators.histogram(cfg, st.regs))
    expected = np.bincount(np.asarray(st.regs).astype(np.int64) - cfg.r_min, minlength=cfg.num_bins)
    np.testing.assert_array_equal(h, expected)
    assert h.sum() == cfg.m


@pytest.mark.parametrize("b", [4, 5, 8])
def test_register_width_truncation(b):
    """Thm. 1 / Fig. 5: narrow registers saturate outside their range."""
    cfg = SketchConfig(m=256, b=b, seed=3)
    st, true_c = _sketch_regs(cfg, 2000, 1e6)  # C ~ 2e9, log2 ~ 31
    est = float(qsketch.estimate(cfg, st))
    rel = abs(est - true_c) / true_c
    if b == 8:
        assert rel < 0.35
    else:
        # b=4 -> r_max=7, b=5 -> r_max=15: saturated, estimate far off.
        assert rel > 0.9


def test_lm_estimator():
    cfg = SketchConfig(m=1024, b=8, seed=4)
    rng = np.random.default_rng(8)
    n = 4000
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = rng.uniform(0.0, 1.0, n).astype(np.float32) + 1e-4
    from repro.core import baselines

    st = baselines.init(cfg)
    st = baselines.lm_update(cfg, st, jnp.asarray(ids), jnp.asarray(w))
    est = float(baselines.estimate(st))
    true_c = float(w.astype(np.float64).sum())
    # Var[Chat/C] = 1/(m-2) -> std ~ 3.1%; allow 5 sigma.
    assert abs(est - true_c) / true_c < 5 / np.sqrt(cfg.m - 2)
