"""Headline figure: sustained ingest→estimate Mops, pipelined vs synchronous.

The ROADMAP's heavy-traffic question: how many stream elements per second
does ONE host sustain end-to-end — staging, device update, and a final
estimate read — when traffic arrives as an unbounded Zipf-bursty stream
instead of pre-built batches? Two methods per (K, batch-size) cell:

* ``sync``      — the repo's historical mode: non-donated
                  ``dyn_array.update_batch`` with the host blocking on every
                  micro-batch (each batch also allocates a fresh
                  int8[K, m] + int32[K, 2^b] state copy).
* ``pipelined`` — ``sketchstream/ingest.py``: double-buffered staging,
                  donated in-place updates, async dispatch with a bounded
                  retire queue (policy="block").

Both paths consume the identical element stream and produce bit-identical
sketches (asserted per cell), so the ratio row (method "speedup") is pure
pipeline/donation win: at paper-scale K the non-donated copy traffic
dominates and the pipelined path must be strictly faster (an acceptance
criterion checked by scripts/check_bench_schema.py readers and the PR
driver). A second figure ("ingest_window") runs the WindowArray under
rotation load through the same harness. Queue telemetry (stall counts/
seconds, high-water in-flight depth, drops) rides on the pipelined rows.

Results merge cumulatively into experiments/bench/ingest.json keyed by
(k, bsz) cells (common.merge_save), schema-checked in tier-2.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SketchConfig, dyn_array, window_array
from repro.obs import trace as obs_trace
from repro.sketchstream import ingest

from . import common

_M, _B = 128, 8
_CHUNK = 4096  # host arrival granularity of the load generator

# Ingest-stage span names -> the bench-row keys their totals land under.
_SPAN_KEYS = {
    "ingest/push": "span_push_s",
    "ingest/seal": "span_seal_s",
    "ingest/dispatch": "span_dispatch_s",
    "ingest/retire": "span_retire_s",
    "ingest/stall": "span_stall_s",
    "ingest/rotate": "span_rotate_s",
}


def _stage_spans(run_fn):
    """Per-stage host seconds for one traced run of ``run_fn``.

    The timed measurement runs stay untraced (the headline sustained_mops
    never pays for span bookkeeping); this extra run re-executes the same
    cell with the default tracer on and folds ``stage_totals()`` into
    ``span_*_s`` row keys.
    """
    was = obs_trace.enabled()
    obs_trace.configure(enabled=True)
    obs_trace.clear()
    try:
        run_fn()
    finally:
        obs_trace.configure(enabled=was)
    totals = obs_trace.stage_totals()
    obs_trace.clear()
    return {
        key: round(totals[name], 4)
        for name, key in _SPAN_KEYS.items()
        if name in totals
    }


def zipf_bursty_chunks(n_keys, n_elements, *, s=1.2, burst_every=4,
                       burst_frac=0.5, n_hot=4, seed=0):
    """Zipf-bursty load: arrival chunks of (keys, ids, weights).

    Key popularity is Zipf(s) over the K slots (heavy skew, as in the
    paper's real streams); every ``burst_every``-th chunk is a BURST —
    ``burst_frac`` of its elements collapse onto ``n_hot`` random hot keys,
    the flash-crowd shape that stresses scatter contention and (in the
    pipelined path) queue depth. Ids draw from a pool of ~n/2 so duplicate
    suppression does real work; weights are gamma (heavy-tailed flows).
    """
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_keys + 1, dtype=np.float64) ** s
    cdf = np.cumsum(p / p.sum())
    pool = rng.integers(0, 2**32, max(n_elements // 2, 16), dtype=np.uint32)
    chunks = []
    for ci in range(-(-n_elements // _CHUNK)):
        b = min(_CHUNK, n_elements - ci * _CHUNK)
        keys = np.searchsorted(cdf, rng.random(b)).astype(np.int32)
        if burst_every and ci % burst_every == burst_every - 1:
            hot = rng.integers(0, n_keys, n_hot).astype(np.int32)
            nb = int(b * burst_frac)
            keys[:nb] = hot[rng.integers(0, n_hot, nb)]
        ids = pool[rng.integers(0, len(pool), b)]
        w = (rng.gamma(1.0, 2.0, b) + 1e-4).astype(np.float32)
        chunks.append((keys, ids, w))
    return chunks


def _flat(chunks):
    return tuple(np.concatenate([c[i] for c in chunks]) for i in range(3))


def _run_sync(cfg, k, keys, ids, w, bsz):
    """Synchronous baseline: blocking non-donated update per micro-batch,
    then the estimate read. Returns (elapsed_s, chats)."""
    state = dyn_array.init(cfg, k)
    t0 = time.perf_counter()
    for i in range(0, len(keys), bsz):
        state = dyn_array.update_batch(
            cfg, state,
            jnp.asarray(keys[i : i + bsz]), jnp.asarray(ids[i : i + bsz]),
            jnp.asarray(w[i : i + bsz]),
        )
        jax.block_until_ready(state.chats)
    est = np.asarray(dyn_array.estimate_all(state))
    return time.perf_counter() - t0, est


def _run_pipelined(cfg, k, chunks, bsz, queue_depth=4):
    """Pipelined ingest: donated updates, async retire queue, one barrier,
    then the estimate read. Returns (elapsed_s, chats, metrics)."""
    icfg = ingest.IngestConfig(batch_size=bsz, queue_depth=queue_depth)
    pipe = ingest.dyn_pipeline(cfg, dyn_array.init(cfg, k), icfg)
    t0 = time.perf_counter()
    for keys, ids, w in chunks:
        pipe.push(keys, ids, w)
    state = pipe.result()
    est = np.asarray(dyn_array.estimate_all(state))
    return time.perf_counter() - t0, est, pipe.metrics()


def run_sustained(quick=True):
    ks = [2**10, 2**14] if quick else [2**14, 2**17, 2**20]
    bszs = [4096, 16384] if quick else [16384, 65536]
    n_batches = 6 if quick else 12
    rows, swept = [], []
    for k in ks:
        cfg = SketchConfig(m=_M, b=_B, seed=7)
        for bsz in bszs:
            n = n_batches * bsz
            chunks = zipf_bursty_chunks(k, n, seed=k % 1009 + bsz)
            keys, ids, w = _flat(chunks)
            # Warm every executable (sync update, pipelined update) on a
            # fresh state of the same shapes so compiles stay out of the
            # timed window.
            _run_sync(cfg, k, keys[:bsz], ids[:bsz], w[:bsz], bsz)
            _run_pipelined(cfg, k, chunks[: -(-bsz // _CHUNK)], bsz)

            t_sync, est_sync = _run_sync(cfg, k, keys, ids, w, bsz)
            t_pipe, est_pipe, met = _run_pipelined(cfg, k, chunks, bsz)
            if not np.array_equal(est_sync, est_pipe):
                raise AssertionError(
                    f"ingest bench: pipelined estimates diverge from sync at "
                    f"k={k} bsz={bsz}"
                )
            mops_s, mops_p = n / t_sync / 1e6, n / t_pipe / 1e6
            spans = _stage_spans(lambda: _run_pipelined(cfg, k, chunks, bsz))
            rows.append({"figure": "ingest_sustained", "method": "sync",
                         "k": k, "bsz": bsz, "sustained_mops": mops_s})
            rows.append({"figure": "ingest_sustained", "method": "pipelined",
                         "k": k, "bsz": bsz, "sustained_mops": mops_p,
                         "stalls": met["ingest_stalls"],
                         "stall_s": round(met["ingest_stall_s"], 4),
                         "max_in_flight": met["ingest_max_in_flight"],
                         "dropped": met["ingest_elements_dropped"],
                         **spans})
            rows.append({"figure": "ingest_sustained", "method": "speedup",
                         "k": k, "bsz": bsz, "x": mops_p / mops_s})
            swept.append((k, bsz))
            common.csv_row(
                f"ingest/k{k}/bsz{bsz}", 1.0 / mops_p,
                f"sustained_mops sync={mops_s:.3f} pipelined={mops_p:.3f} "
                f"x={mops_p/mops_s:.2f} stalls={met['ingest_stalls']} "
                f"stall_s={met['ingest_stall_s']:.3f}",
            )
    return rows, swept


def run_window(quick=True):
    """WindowArray under rotation load: same stream, rotate every 2 batches
    (the retire barrier on the pipelined path). One cell — the figure shows
    pipelining survives rotation barriers, not a second sweep."""
    k, bsz, e = 2**12, 8192, 4
    n_batches = 6 if quick else 12
    cfg = SketchConfig(m=_M, b=_B, seed=9)
    chunks = zipf_bursty_chunks(k, n_batches * bsz, seed=5)
    keys, ids, w = _flat(chunks)
    n = len(keys)

    def sync_run():
        st = window_array.init(cfg, k, e)
        t0 = time.perf_counter()
        nb = 0
        for i in range(0, n, bsz):
            st = window_array.update_batch(
                cfg, st, jnp.asarray(keys[i : i + bsz]),
                jnp.asarray(ids[i : i + bsz]), jnp.asarray(w[i : i + bsz]),
            )
            jax.block_until_ready(st.union_chats)
            nb += 1
            if nb % 2 == 0:
                st = window_array.rotate(cfg, st)
                jax.block_until_ready(st.union_chats)
        return time.perf_counter() - t0, np.asarray(st.union_chats)

    def pipe_run():
        icfg = ingest.IngestConfig(batch_size=bsz, queue_depth=4)
        pipe = ingest.window_pipeline(cfg, window_array.init(cfg, k, e), icfg)
        t0 = time.perf_counter()
        nb = 0
        for keys_c, ids_c, w_c in chunks:
            pipe.push(keys_c, ids_c, w_c)
            nb = pipe.stats.batches
            if nb and nb % 2 == 0 and pipe.stats.rotations < nb // 2:
                pipe.rotate()
        st = pipe.result()
        return time.perf_counter() - t0, np.asarray(st.union_chats)

    sync_run(); pipe_run()  # warm compiles
    t_s, est_s = sync_run()
    t_p, est_p = pipe_run()
    if not np.array_equal(est_s, est_p):
        raise AssertionError("ingest window bench: pipelined diverges from sync")
    spans = _stage_spans(pipe_run)
    rows = [
        {"figure": "ingest_window", "method": "sync", "k": k, "bsz": bsz,
         "e": e, "sustained_mops": n / t_s / 1e6},
        {"figure": "ingest_window", "method": "pipelined", "k": k, "bsz": bsz,
         "e": e, "sustained_mops": n / t_p / 1e6, **spans},
    ]
    common.csv_row(
        f"ingest_window/k{k}", t_p / max(n, 1) * 1e6,
        f"sustained_mops sync={n/t_s/1e6:.3f} pipelined={n/t_p/1e6:.3f} "
        f"(rotations as retire barriers)",
    )
    return rows, [(k, bsz)]


def run(quick=True):
    r1, s1 = run_sustained(quick)
    r2, s2 = run_window(quick)
    common.merge_save("ingest", r1 + r2, s1 + s2, sweep_keys=("k", "bsz"))
    return r1 + r2
