import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count at first backend init (MULTI-POD DRY-RUN step 0).

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402


def main():
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower + compile every (arch x shape) cell "
        "on the production mesh and record memory/cost/roofline."
    )
    ap.add_argument("--arch", default="all", help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--multi-pod", action="store_true", help="use the (2,16,16) 512-chip mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-sketch", action="store_true")
    ap.add_argument("--no-quantized-opt", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default="", help="'dots' to save matmuls")
    ap.add_argument("--skip-hlo-parse", action="store_true")
    ap.add_argument("--sharded-xent", action="store_true")
    ap.add_argument("--moe-impl", default="", help="shard_map_a2a | scatter")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--ssm-intra-dtype", default="")
    ap.add_argument("--tag", default="", help="artifact suffix, e.g. _opt1")
    args = ap.parse_args()

    # Imports deferred until after XLA_FLAGS is set.
    from repro import configs
    from repro.launch import dryrun_lib
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = ("_multipod" if args.multi_pod else "_singlepod") + args.tag
    remat = False if args.no_remat else (args.remat_policy or True)
    opts = dryrun_lib.CellOptions(
        quantized_opt=not args.no_quantized_opt,
        compress=args.compress,
        sketch=not args.no_sketch,
        microbatches=args.microbatches,
        remat=remat,
        sharded_xent=args.sharded_xent,
        moe_impl=args.moe_impl,
        ssm_chunk=args.ssm_chunk,
        ssm_intra_dtype=args.ssm_intra_dtype,
        variant_tag=args.tag,
    )

    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            t0 = time.time()
            rec = dryrun_lib.run_cell(arch, shape, mesh, opts, parse_hlo=not args.skip_hlo_parse)
            path = dryrun_lib.save_record(rec, args.out, tag)
            dt = time.time() - t0
            if rec["status"] == "ok":
                m = rec["per_device"]
                print(
                    f"OK   {arch:24s} {shape:12s} {dt:7.1f}s "
                    f"mem={rec['hbm_fit']['peak_bytes_est']/2**30:7.2f}GiB "
                    f"flops/dev={m['flops']:.3e} coll/dev={m['collective_bytes']:.3e}B "
                    f"-> {rec['bottleneck']}",
                    flush=True,
                )
                # Step-3 requirement: print the analyses verbatim.
                print(f"     memory_analysis: arg={m['argument_bytes']} out={m['output_bytes']} temp={m['temp_bytes']} alias={m['alias_bytes']}", flush=True)
                print(f"     cost_analysis:   flops={m['flops']} bytes={m['bytes_accessed']}", flush=True)
            elif rec["status"] == "skip":
                print(f"SKIP {arch:24s} {shape:12s} ({rec['skip_reason']})", flush=True)
            else:
                failures += 1
                print(f"FAIL {arch:24s} {shape:12s} {dt:7.1f}s {rec['error']}", flush=True)
                if rec.get("traceback"):
                    print(rec["traceback"][-1500:], flush=True)
            print(f"     -> {path}", flush=True)

    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
