"""DynArray tests: K-loop bit-identity (incl. the fixed padded-duplicate
case), incremental-histogram equivalence, kernel-vs-core, anytime reads,
merge algebra, tenant routing, and the monitor / train / serve threading.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, dyn_array, key_directory, qsketch_dyn
from repro.core.key_directory import DirectoryConfig
from repro.core.types import DynArrayState
from repro.kernels import ops
from repro.sketchstream import monitor

# (batch, m, K) — ragged on purpose, matching the SketchArray suite's habit.
SHAPES = [
    (64, 64, 8),
    (100, 130, 7),
    (256, 96, 16),
    (513, 257, 33),
    (8, 64, 1),  # single row degenerates to qsketch_dyn
]


def _keyed_stream(n, k, seed, wscale=1.0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, k, n, dtype=np.int32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n) * wscale).astype(np.float32) + 1e-5
    return jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w)


def _assert_states_match(st, ref, chat_rtol=1e-5):
    """regs/hists bitwise; chats within f32 association-order rounding."""
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))
    np.testing.assert_array_equal(np.asarray(st.hists), np.asarray(ref.hists))
    np.testing.assert_allclose(
        np.asarray(st.chats), np.asarray(ref.chats), rtol=chat_rtol, atol=1e-6
    )


@pytest.mark.parametrize("batch,m,k", SHAPES)
def test_update_matches_k_loop_oracle(batch, m, k):
    """Row r == a standalone qsketch_dyn.update_batch fed the key-r sub-stream."""
    cfg = SketchConfig(m=m, b=8, seed=batch + m + k)
    keys, ids, w = _keyed_stream(batch, k, seed=batch * 7 + k)
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w)
    ref = dyn_array.update_reference(cfg, dyn_array.init(cfg, k), keys, ids, w)
    _assert_states_match(st, ref)
    # Second batch on the warm state: q_R now reads nonzero histograms.
    keys2, ids2, w2 = _keyed_stream(batch, k, seed=batch * 7 + k + 1)
    _assert_states_match(
        dyn_array.update_batch(cfg, st, keys2, ids2, w2),
        dyn_array.update_reference(cfg, ref, keys2, ids2, w2),
    )


def test_padded_duplicate_does_not_shadow_live_row():
    """The fixed dedup/mask contract, keyed form: a masked padding row sharing
    (key, id) with a live row cannot drop the live row's weight."""
    cfg = SketchConfig(m=64, b=8, seed=3)
    k = 5
    keys, ids, w = _keyed_stream(60, k, seed=9)
    pad_keys = jnp.concatenate([keys[:8], keys])
    pad_ids = jnp.concatenate([ids[:8], ids])
    pad_w = jnp.concatenate([jnp.ones(8, jnp.float32), w])
    mask = jnp.asarray(np.concatenate([np.zeros(8, bool), np.ones(60, bool)]))

    st = dyn_array.update_batch(
        cfg, dyn_array.init(cfg, k), pad_keys, pad_ids, pad_w, mask=mask
    )
    ref = dyn_array.update_reference(cfg, dyn_array.init(cfg, k), keys, ids, w)
    _assert_states_match(st, ref)
    # And against the padded K-loop oracle (mask threaded through).
    ref_pad = dyn_array.update_reference(
        cfg, dyn_array.init(cfg, k), pad_keys, pad_ids, pad_w, mask=np.asarray(mask)
    )
    _assert_states_match(st, ref_pad)


def test_same_id_under_two_keys_counts_twice():
    """Dedup is per (key, id): one element id observed under two keys is two
    distinct per-tenant elements and must land in both rows."""
    cfg = SketchConfig(m=64, b=8, seed=4)
    ids = jnp.asarray(np.full(2, 12345, np.uint32))
    keys = jnp.asarray(np.array([0, 1], np.int32))
    w = jnp.ones(2, jnp.float32)
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, 2), keys, ids, w)
    chats = np.asarray(st.chats)
    assert chats[0] > 0 and chats[1] > 0
    np.testing.assert_array_equal(np.asarray(st.regs[0]), np.asarray(st.regs[1]))


def test_incremental_hists_match_rebuild():
    cfg = SketchConfig(m=96, b=8, seed=6)
    k = 9
    st = dyn_array.init(cfg, k)
    for i in range(4):
        keys, ids, w = _keyed_stream(200, k, seed=20 + i)
        st = dyn_array.update_batch(cfg, st, keys, ids, w)
        np.testing.assert_array_equal(
            np.asarray(st.hists), np.asarray(dyn_array.rebuild_hists(cfg, st.regs))
        )


def test_estimate_all_is_anytime_read():
    """estimate_all returns the running chats array itself — no solve."""
    cfg = SketchConfig(m=256, b=8, seed=7)
    k = 6
    keys, ids, w = _keyed_stream(4000, k, seed=31)
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w)
    assert dyn_array.estimate_all(st) is st.chats
    est = np.asarray(dyn_array.estimate_all(st))
    keys_np, w_np = np.asarray(keys), np.asarray(w, dtype=np.float64)
    for r in range(k):
        true_c = w_np[keys_np == r].sum()
        assert abs(est[r] - true_c) / true_c < 0.35  # m=256 statistical bound


def test_untouched_rows_estimate_zero():
    cfg = SketchConfig(m=64, b=8, seed=8)
    st = dyn_array.init(cfg, 4)
    np.testing.assert_array_equal(np.asarray(dyn_array.estimate_all(st)), 0.0)
    np.testing.assert_array_equal(np.asarray(dyn_array.estimate_mle_all(cfg, st)), 0.0)
    keys = jnp.full((400,), 2, jnp.int32)
    ids = jnp.asarray(np.arange(400, dtype=np.uint32))
    st = dyn_array.update_batch(cfg, st, keys, ids, jnp.ones((400,), jnp.float32))
    est = np.asarray(dyn_array.estimate_all(st))
    mle = np.asarray(dyn_array.estimate_mle_all(cfg, st))
    assert est[2] > 0 and mle[2] > 0
    untouched = np.arange(4) != 2
    np.testing.assert_array_equal(est[untouched], 0.0)
    np.testing.assert_array_equal(mle[untouched], 0.0)


def test_degenerate_weights_dropped():
    cfg = SketchConfig(m=64, b=8, seed=10)
    k = 3
    keys, ids, w = _keyed_stream(40, k, seed=11)
    bad_keys = jnp.concatenate([keys[:4], keys])
    bad_ids = jnp.concatenate([ids[:4], ids])
    bad_w = jnp.concatenate(
        [jnp.asarray(np.array([0.0, -2.0, np.nan, np.inf], np.float32)), w]
    )
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), bad_keys, bad_ids, bad_w)
    ref = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w)
    _assert_states_match(st, ref)


def test_merge_matches_single_sketch_merge_rowwise():
    """merge == qsketch_dyn.merge per row, bitwise (chats included — the MLE
    re-estimate is the same vmapped computation)."""
    cfg = SketchConfig(m=64, b=8, seed=12)
    k = 5
    ka, ia, wa = _keyed_stream(2000, k, seed=51)
    kb, ib, wb = _keyed_stream(2000, k, seed=52)
    sa = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), ka, ia, wa)
    sb = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), kb, ib, wb)
    merged = dyn_array.merge(cfg, sa, sb)
    for r in range(k):
        single = qsketch_dyn.merge(cfg, dyn_array.row(sa, r), dyn_array.row(sb, r))
        np.testing.assert_array_equal(np.asarray(merged.regs[r]), np.asarray(single.regs))
        np.testing.assert_array_equal(np.asarray(merged.hists[r]), np.asarray(single.hist))
        assert float(merged.chats[r]) == float(single.chat)
    with pytest.raises(ValueError, match="matching"):
        dyn_array.merge(cfg, sa, dyn_array.init(cfg, k + 1))


def test_merge_disjoint_adds_chats():
    """Key-partitioned fleets: disjoint streams merge by adding martingales —
    exact, no MLE — while registers still max-merge."""
    cfg = SketchConfig(m=128, b=8, seed=13)
    k = 4
    ka, ia, wa = _keyed_stream(1500, k, seed=53)
    kb, ib, wb = _keyed_stream(1500, k, seed=54)  # fresh ids: disjoint w.h.p.
    sa = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), ka, ia, wa)
    sb = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), kb, ib, wb)
    merged = dyn_array.merge_disjoint(cfg, sa, sb)
    np.testing.assert_array_equal(
        np.asarray(merged.regs),
        np.maximum(np.asarray(sa.regs), np.asarray(sb.regs)),
    )
    np.testing.assert_allclose(
        np.asarray(merged.chats), np.asarray(sa.chats) + np.asarray(sb.chats), rtol=1e-6
    )
    with pytest.raises(ValueError, match="matching"):
        dyn_array.merge_disjoint(cfg, sa, dyn_array.init(cfg, k + 1))


def test_chats_additive_across_disjoint_batches():
    """The keyed martingale telescopes: folding one stream in B-sized slices
    equals folding it whole, state-exactly (same chain, same q_R windows)."""
    cfg = SketchConfig(m=128, b=8, seed=14)
    k = 6
    keys, ids, w = _keyed_stream(1024, k, seed=55)
    whole = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), keys, ids, w)
    sliced = dyn_array.init(cfg, k)
    for i in range(0, 1024, 256):
        sliced = dyn_array.update_batch(
            cfg, sliced, keys[i : i + 256], ids[i : i + 256], w[i : i + 256]
        )
    np.testing.assert_array_equal(np.asarray(whole.regs), np.asarray(sliced.regs))
    # Slicing refreshes q_R between slices (LESS stale): chats agree to the
    # staleness bound, not bitwise — ~170 distinct/key against m=128 registers
    # in ONE window is deep staleness, benchmarks/batch_bias.py territory.
    np.testing.assert_allclose(
        np.asarray(whole.chats), np.asarray(sliced.chats), rtol=0.15
    )


def test_row_extraction_and_bounds():
    cfg = SketchConfig(m=64, b=8, seed=15)
    keys, ids, w = _keyed_stream(200, 3, seed=61)
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, 3), keys, ids, w)
    sel = np.asarray(keys) == 1
    solo = qsketch_dyn.update_batch(
        cfg, qsketch_dyn.init(cfg), jnp.asarray(np.asarray(ids)[sel]), jnp.asarray(np.asarray(w)[sel])
    )
    r = dyn_array.row(st, 1)
    np.testing.assert_array_equal(np.asarray(r.regs), np.asarray(solo.regs))
    np.testing.assert_array_equal(np.asarray(r.hist), np.asarray(solo.hist))
    assert float(r.chat) == pytest.approx(float(solo.chat), rel=1e-5)
    with pytest.raises(IndexError):
        dyn_array.row(st, 3)
    with pytest.raises(ValueError, match="k >= 1"):
        dyn_array.init(cfg, 0)


def test_update_tenants_routes_like_directory():
    cfg = SketchConfig(m=64, b=8, seed=16)
    dcfg = DirectoryConfig(capacity=16, seed=17)
    rng = np.random.default_rng(91)
    tkeys = key_directory.split_uint64(rng.integers(0, 2**64, 200, dtype=np.uint64))
    ids = jnp.asarray(rng.integers(0, 2**32, 200, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 200).astype(np.float32))
    st, dstate = dyn_array.update_tenants(
        cfg, dcfg, dyn_array.init(cfg, 16), key_directory.init(dcfg), tkeys, ids, w
    )
    slots = key_directory.route_slots(dcfg, tkeys)
    ref = dyn_array.update_batch(cfg, dyn_array.init(cfg, 16), slots, ids, w)
    _assert_states_match(st, ref)
    assert int(dstate.n_routed) == 200
    with pytest.raises(ValueError, match="capacity"):
        dyn_array.update_tenants(
            cfg, dcfg, dyn_array.init(cfg, 8), key_directory.init(dcfg), tkeys, ids, w
        )


# ---------------------------------------------------------------------------
# kernel path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch,m,k", SHAPES)
@pytest.mark.parametrize("b", [4, 8])
def test_kernel_vs_core_bit_identity(batch, m, k, b):
    """Pallas (interpret) q_R + shared tail vs core: BITWISE equal states."""
    cfg = SketchConfig(m=m, b=b, seed=batch + m)
    keys, ids, w = _keyed_stream(batch, k, seed=batch * 3 + m)
    st = dyn_array.update_batch(cfg, dyn_array.init(cfg, k), *_keyed_stream(batch, k, seed=1))
    out_kernel = ops.dyn_array_update_op(cfg, st, keys, ids, w, block_b=64, interpret=True)
    out_core = dyn_array.update_batch(cfg, st, keys, ids, w)
    np.testing.assert_array_equal(np.asarray(out_kernel.regs), np.asarray(out_core.regs))
    np.testing.assert_array_equal(np.asarray(out_kernel.hists), np.asarray(out_core.hists))
    np.testing.assert_array_equal(np.asarray(out_kernel.chats), np.asarray(out_core.chats))


def test_kernel_mask_and_tenants_bit_identity():
    cfg = SketchConfig(m=128, b=8, seed=22)
    dcfg = DirectoryConfig(capacity=9, seed=23)
    rng = np.random.default_rng(92)
    tkeys = key_directory.split_uint64(rng.integers(0, 2**64, 300, dtype=np.uint64))
    ids = jnp.asarray(rng.integers(0, 2**32, 300, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 300).astype(np.float32))
    mask = jnp.asarray(rng.random(300) < 0.7)
    st_k, dir_k = ops.dyn_array_update_tenants_op(
        cfg, dcfg, dyn_array.init(cfg, 9), key_directory.init(dcfg),
        tkeys, ids, w, mask=mask, interpret=True,
    )
    st_c, dir_c = dyn_array.update_tenants(
        cfg, dcfg, dyn_array.init(cfg, 9), key_directory.init(dcfg),
        tkeys, ids, w, mask=mask,
    )
    np.testing.assert_array_equal(np.asarray(st_k.regs), np.asarray(st_c.regs))
    np.testing.assert_array_equal(np.asarray(st_k.chats), np.asarray(st_c.chats))
    np.testing.assert_array_equal(
        np.asarray(dir_k.fingerprints), np.asarray(dir_c.fingerprints)
    )
    assert int(dir_k.n_routed) == int(dir_c.n_routed)


# ---------------------------------------------------------------------------
# monitor + train/serve threading
# ---------------------------------------------------------------------------


def test_dyn_monitor_roundtrip():
    cfg = SketchConfig(m=64, b=8, seed=61)
    mon = monitor.DynArrayMonitor.for_capacity(cfg, 4)
    rng = np.random.default_rng(26)
    n = 2000
    tkeys = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    mask = jnp.asarray(np.arange(n) < 1800)

    st = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    assert int(st.n_seen) == 1800
    est = np.asarray(mon.estimate(st))
    assert est.shape == (4,)
    true_c = float(np.asarray(w, np.float64)[:1800].sum())
    assert abs(est.sum() - true_c) / true_c < 0.1  # martingale total tracks

    m = mon.metrics(st)
    assert int(m["tenant_elements_seen"]) == 1800
    assert int(m["tenant_slots_claimed"]) > 0
    assert float(m["tenant_weight_total"]) == pytest.approx(float(est.sum()), rel=1e-6)

    # Merge of two copies of the SAME stream must not double (MLE re-estimate,
    # not chat addition). Rows carry ~450 distinct elements against m=64
    # registers, the well-loaded regime where the Dyn MLE is specified
    # (DESIGN.md §8.4 documents the lightly-loaded caveat).
    st2 = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    merged = mon.merge(st, st2)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(st.regs))
    assert int(merged.n_seen) == 3600
    tot = float(np.asarray(mon.estimate(merged)).sum())
    assert abs(tot - true_c) / true_c < 0.35  # per-row MLE noise at m=64


def test_train_step_threads_dyn_tenant_telemetry():
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import optimizer, train_step as ts

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(27)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "doc_ids": jnp.asarray(rng.integers(0, 2**32, (4,), dtype=np.uint32)),
    }
    skc = SketchConfig(m=64, b=8, seed=63)
    mon = monitor.DynArrayMonitor.for_capacity(skc, 256)
    ocfg = optimizer.OptConfig(lr=1e-3, warmup_steps=0)
    step = jax.jit(ts.make_train_step(mcfg, ocfg, None, sketch_cfg=skc, tenant_monitor=mon))
    opt, comp, sk = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)
    assert isinstance(sk, monitor.TelemetryState)

    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(sk.tenants.n_seen) == 64  # 4 x 16 tokens through the array
    assert "tenant_weight_total" in metrics and "distinct_tokens_est" in metrics
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 4  # 4 documents -> exactly 4 live rows


def test_decode_step_threads_dyn_tenant_telemetry():
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import serve_step

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(7))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), transformer.abstract_cache(mcfg, batch=2, max_len=16)
    )
    skc = SketchConfig(m=64, b=8, seed=65)
    mon = monitor.DynArrayMonitor.for_capacity(skc, 128)
    dec = jax.jit(serve_step.make_decode_step(mcfg, None, sketch_cfg=skc, tenant_monitor=mon))

    sk = monitor.TelemetryState(scalar=monitor.init(skc), tenants=mon.init())
    _, _, sk = dec(
        params, cache, jnp.int32(0), jnp.zeros((2, 1), jnp.int32), sk,
        jnp.asarray([101, 202], jnp.uint32),  # session ids
        jnp.asarray([1.0, 3.0], jnp.float32),  # engagement weights
        None, None,
        jnp.asarray([7, 7], jnp.uint32),  # both sessions belong to tenant 7
    )
    assert int(sk.tenants.n_seen) == 2
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 1  # one tenant row live
    assert float(est.sum()) == pytest.approx(4.0, rel=0.5)  # ~1.0 + 3.0
