"""Sharded WindowArray: the sliding-window epoch ring past one host.

``core/window_array.py`` holds a ring of E epoch DynArray sub-states plus a
cached union — ``int8[E, K, m]`` registers and ``int32[E, K, 2^b]``
histograms, which at production K is the biggest state in the repo (the
histograms alone are 1 KiB x E x K at b = 8). This module shards every
per-tenant leaf over the ``"sketch"`` mesh axis at its K dimension
(``core/sharding.py`` row_dim 1 for the epoch planes, 0 for the union
cache) while the ring clock — ``head``/``filled``/``epoch_id`` — stays
replicated, so all shards rotate in lockstep; the ROADMAP follow-on to
PR 4.

Why everything stays shard-local (DESIGN.md §8.6): the epoch-plane
max-union is an element-wise reduction over the epoch axis, which commutes
with any partitioning of the K axis — a shard's union plane is exactly the
union of its epoch-plane rows. So:

* **update_batch** — hash-routed like every sharded front: each shard
  masks the replicated batch to its own rows and runs the same two fused
  DynArray updates (head epoch + union cache) via the shared
  ``window_array._apply_update`` tail. All leaves bit-identical to the
  single-host WindowArray (tests/test_sharded_window_array.py).
* **rotate** — per-shard O(1) ring bookkeeping: each shard advances the
  (replicated) head, resets its slice of the slot the head lands on, and
  rebuilds ITS rows of the union cache + re-bases its anytime martingales
  to the surviving union's MLE — ``window_array.rotate`` verbatim on the
  local state, no collective.
* **estimate_window / estimate_ring_anytime** — the sub-ring union + MLE
  and the cached full-ring read run on each shard's rows; the anytime read
  is the sharded ``union_chats``.
* **merge** — ring-aligned cross-pod merge (alignment checked host-side on
  the replicated clock), array tail (``window_array._merged_arrays``)
  shard-local.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dyn_array, hashing, key_directory, qsketch_dyn, sharding, window_array
from .types import ShardedWindowArrayState, SketchConfig, WindowArrayState

AXIS = sharding.AXIS

# Shared-layer geometry helpers, re-exported like sharded_array's.
num_shards = sharding.num_shards
padded_k = sharding.padded_k

# Row-dim pytree: epoch planes carry K at dim 1, the union cache at dim 0,
# the ring clock is replicated.
DIMS = ShardedWindowArrayState(
    regs=1, hists=1, chats=1,
    union_regs=0, union_hists=0, union_chats=0,
    head=None, filled=None, epoch_id=None,
)
_ARRAY_DIMS = (1, 1, 1, 0, 0, 0)  # the six per-tenant leaves, in state order


def init(cfg: SketchConfig, k: int, e: int, mesh, axis: str = AXIS) -> ShardedWindowArrayState:
    """K tenants x E ring epochs, per-tenant leaves sharded over ``axis``."""
    sharding.check_divisible(k, mesh, axis)
    return ShardedWindowArrayState(
        *sharding.device_put_rows(window_array.init(cfg, k, e), mesh, DIMS, axis)
    )


def from_array(state: WindowArrayState, mesh, axis: str = AXIS) -> ShardedWindowArrayState:
    """Reshard a single-host WindowArray (pure data movement, same values)."""
    return ShardedWindowArrayState(
        *sharding.device_put_rows(state, mesh, DIMS, axis)
    )


def to_array(state: ShardedWindowArrayState) -> WindowArrayState:
    """Gather back to the single-host form (tests / row extraction)."""
    return WindowArrayState(*jax.device_get(tuple(state)))


def num_epochs(state: ShardedWindowArrayState) -> int:
    """Ring size E."""
    return state.regs.shape[0]


def num_sketches(state: ShardedWindowArrayState) -> int:
    """Total tenant capacity K across all shards."""
    return state.regs.shape[1]


def _local_window(st: ShardedWindowArrayState, arrays) -> WindowArrayState:
    """Assemble a shard-local WindowArrayState from local array leaves plus
    the replicated ring clock (used inside shard_map local bodies)."""
    return WindowArrayState(*arrays, head=st.head, filled=st.filled, epoch_id=st.epoch_id)


def _update_impl(cfg: SketchConfig, mesh, axis: str, state, keys, lo, hi, w, mask):
    rows = state.regs.shape[1] // sharding.num_shards(mesh, axis)

    def local(arrays, head, keys, lo, hi, w, m):
        st = WindowArrayState(*arrays, head=head, filled=jnp.int32(0), epoch_id=jnp.int32(0))
        local_keys, own = sharding.own_slots(keys, rows, axis, m)
        live = qsketch_dyn._live_weight_mask(w, own)
        out = window_array._apply_update(cfg, st, local_keys, lo, hi, w, live)
        return tuple(out)[:6]

    arrays = sharding.shard_map_rows(
        local,
        mesh,
        in_dims=(_ARRAY_DIMS, None, None, None, None, None, None),
        out_dims=_ARRAY_DIMS,
        axis=axis,
    )(tuple(state)[:6], state.head, keys, lo, hi, w, mask)
    return ShardedWindowArrayState(
        *arrays, head=state.head, filled=state.filled, epoch_id=state.epoch_id
    )


_update = jax.jit(_update_impl, static_argnums=(0, 1, 2))
_update_donated = jax.jit(
    _update_impl, static_argnums=(0, 1, 2), donate_argnums=(3,)
)


def update_batch(
    cfg: SketchConfig, mesh, state: ShardedWindowArrayState, keys, ids, weights,
    mask=None, axis: str = AXIS, *, donate: bool = False,
) -> ShardedWindowArrayState:
    """Fold one keyed batch into the current epoch (and the union cache),
    hash-routed; bit-identical to ``window_array.update_batch`` on every
    leaf. Same contract: keys clipped to [0, K), masked / degenerate-weight
    rows dropped before dedup. ``donate=True`` donates the sharded epoch
    planes + union cache for in-place reuse (sharding is unchanged, so
    aliasing is legal); the caller's ``state`` is dead afterwards."""
    sharding.check_divisible(state.regs.shape[1], mesh, axis)
    k = state.regs.shape[1]
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    fn = _update_donated if donate else _update
    return fn(cfg, mesh, axis, state, keys, lo, hi, w, mask)


def _rotate_impl(cfg: SketchConfig, mesh, axis: str, state):
    def local(arrays, head, filled, epoch_id):
        st = WindowArrayState(*arrays, head=head, filled=filled, epoch_id=epoch_id)
        return tuple(window_array.rotate(cfg, st))

    # The ring clock comes back out of the local body (replicated out
    # specs): the single-host rotate owns the head/eviction policy, so the
    # sharded wrapper can never desynchronize the clock from the plane the
    # local body actually reset.
    return sharding.shard_map_rows(
        local,
        mesh,
        in_dims=(_ARRAY_DIMS, None, None, None),
        out_dims=_ARRAY_DIMS + (None, None, None),
        axis=axis,
        check_rep=False,  # union-MLE re-base is a lax.while_loop
    )(tuple(state)[:6], state.head, state.filled, state.epoch_id)


_rotate = jax.jit(_rotate_impl, static_argnums=(0, 1, 2))
_rotate_donated = jax.jit(
    _rotate_impl, static_argnums=(0, 1, 2), donate_argnums=(3,)
)


def rotate(
    cfg: SketchConfig, mesh, state: ShardedWindowArrayState, axis: str = AXIS,
    *, donate: bool = False,
) -> ShardedWindowArrayState:
    """Close the current epoch and open the next ring slot, shard-locally.

    Each shard runs ``window_array.rotate`` verbatim on its rows: O(1) ring
    bookkeeping (advance head, reset/evict the slot it lands on), rebuild
    of ITS union-cache rows from the surviving epoch planes, and the MLE
    re-base of its anytime martingales. The replicated ring clock advances
    identically on every shard — no collective, no host sync.
    ``donate=True`` reuses the ring buffers in place; safe once no earlier
    view of the state is read again (the ingest retire barrier's contract).
    """
    fn = _rotate_donated if donate else _rotate
    return ShardedWindowArrayState(*fn(cfg, mesh, axis, state))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3), static_argnames=("solver",))
def _estimate_subring(cfg: SketchConfig, mesh, axis: str, w: int, regs, head, *, solver: str = "newton"):
    def local(regs_l, head):
        st = WindowArrayState(
            regs_l, None, None, None, None, None,
            head=head, filled=jnp.int32(0), epoch_id=jnp.int32(0),
        )
        return dyn_array.estimate_mle_rows(
            cfg, window_array.window_union_regs(st, w), solver=solver
        )

    # check_rep stays off for newton (lax.while_loop, no replication rule)
    # and fused (pallas_call, same); lut is while_loop-free so it keeps the
    # replication check on.
    return sharding.shard_map_rows(
        local, mesh, in_dims=(1, None), out_dims=0, axis=axis,
        check_rep=(solver == "lut"),
    )(regs, head)


@functools.partial(jax.jit, static_argnums=(0, 1, 2), static_argnames=("solver",))
def _estimate_full_ring(cfg: SketchConfig, mesh, axis: str, union_hists, *, solver: str = "newton"):
    def local(hists_l):
        return window_array._chats_from_touched_hists(cfg, hists_l, solver=solver)

    return sharding.shard_map_rows(
        local, mesh, in_dims=(0,), out_dims=0, axis=axis,
        check_rep=(solver == "lut"),
    )(union_hists)


def estimate_window(
    cfg: SketchConfig, mesh, state: ShardedWindowArrayState, w: int, axis: str = AXIS,
    *, solver: str = "newton",
) -> jnp.ndarray:
    """Ĉ[K] over the last w <= E epochs (w static, host-side int), sharded.

    Shard-local epoch-plane union + histogram MLE — the union over epochs
    commutes with row sharding, so each shard's answer is exactly the
    single-host ``window_array.estimate_window`` restricted to its rows
    (bit-identical for the default newton solver; the full-ring w == E reads
    the cached union histograms with no union/bincount pass, same as the
    single-host fast path). ``solver="lut"`` drops the Newton wall — each
    shard anchors its own grid, so lut agreement with the single-host call
    is at the documented tolerance, not bitwise.
    """
    w = window_array._check_w(state, w)
    if w == state.regs.shape[0]:
        return _estimate_full_ring(cfg, mesh, axis, state.union_hists, solver=solver)
    return _estimate_subring(cfg, mesh, axis, w, state.regs, state.head, solver=solver)


def estimate_ring_anytime(state: ShardedWindowArrayState) -> jnp.ndarray:
    """O(K) anytime read of the full-ring window: the running (sharded)
    union martingales — what a per-step anomaly detector consumes."""
    return state.union_chats


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    mesh,
    state: ShardedWindowArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
    axis: str = AXIS,
):
    """Sparse-tenant entry: route 64-bit tenant ids through the (replicated)
    key directory — stamping routed slots with the window's monotone
    ``epoch_id`` so cold-tenant aging can use the ring as its clock — then
    run the hash-routed fused update. Returns (state, directory telemetry).
    """
    if dcfg.capacity != state.regs.shape[1]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != sharded WindowArray rows "
            f"{state.regs.shape[1]}"
        )
    slots, dir_state = key_directory.route(
        dcfg, dir_state, tenant_keys, mask=mask, epoch=state.epoch_id
    )
    return (
        update_batch(cfg, mesh, state, slots, ids, weights, mask=mask, axis=axis),
        dir_state,
    )


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _merge(cfg: SketchConfig, mesh, axis: str, regs_a, regs_b):
    def local(ra, rb):
        return window_array._merged_arrays(cfg, ra, rb)

    return sharding.shard_map_rows(
        local,
        mesh,
        in_dims=(1, 1),
        out_dims=_ARRAY_DIMS,
        axis=axis,
        check_rep=False,  # MLE while_loop in the chat re-estimates
    )(regs_a, regs_b)


def merge(cfg: SketchConfig, mesh, a: ShardedWindowArrayState, b: ShardedWindowArrayState, axis: str = AXIS) -> ShardedWindowArrayState:
    """Cross-pod merge of ring-ALIGNED sharded windows (same E/K/m, same
    head/filled/epoch_id — pods rotate on a shared clock; checked eagerly
    on the replicated ring scalars, exactly as the single-host merge).

    The array tail — per-epoch register max, histogram rebuilds, MLE
    re-estimated chats, union-cache rebuild — is ``window_array``'s own
    ``_merged_arrays``, run shard-local over each shard's rows.
    """
    sharding.check_same_shape(tuple(a)[:6], tuple(b)[:6], "sharded WindowArray")
    window_array.check_ring_aligned(a, b)
    arrays = _merge(cfg, mesh, axis, a.regs, b.regs)
    return ShardedWindowArrayState(
        *arrays, head=a.head, filled=a.filled, epoch_id=a.epoch_id
    )
