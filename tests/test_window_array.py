"""WindowArray + AnomalyBank tests: element-log oracle bit-identity across
rotation boundaries, union-cache invariants, untouched/clamped-window guards,
kernel-vs-core bit-identity, directory aging, anomaly scoring, and the
WindowMonitor / train / serve threading.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, dyn_array, key_directory, window_array
from repro.core.key_directory import DirectoryConfig
from repro.kernels import ops
from repro.sketchstream import anomaly, monitor

# (batch, m, K, E) — ragged on purpose, matching the DynArray suite's habit.
SHAPES = [
    (256, 64, 8, 4),
    (100, 130, 7, 3),
    (513, 96, 16, 5),
]


def _keyed_stream(n, k, seed, wscale=1.0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, k, n, dtype=np.int32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n) * wscale).astype(np.float32) + 1e-5
    return jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w)


def _drive(cfg, k, e, n_epochs, batches_per_epoch=2, batch=512, seed=0):
    """Run n_epochs epochs (rotating between them), returning the final state
    and the per-epoch element logs for oracle rebuilds."""
    st = window_array.init(cfg, k, e)
    logs = []
    for ep in range(n_epochs):
        ep_log = []
        for i in range(batches_per_epoch):
            keys, ids, w = _keyed_stream(batch, k, seed=seed + 31 * ep + i)
            st = window_array.update_batch(cfg, st, keys, ids, w)
            ep_log.append((keys, ids, w))
        logs.append(ep_log)
        if ep < n_epochs - 1:
            st = window_array.rotate(cfg, st)
    return st, logs


def _oracle_window_estimate(cfg, k, logs, w):
    """Rebuild the last w retained epochs from their element logs, union the
    registers, estimate with the shared MLE — the element-log oracle."""
    union = jnp.full((k, cfg.m), cfg.r_min, jnp.int8)
    for ep_log in logs[-w:]:
        d = dyn_array.init(cfg, k)
        for keys, ids, wts in ep_log:
            d = dyn_array.update_batch(cfg, d, keys, ids, wts)
        union = jnp.maximum(union, d.regs)
    return np.asarray(dyn_array.estimate_mle_rows(cfg, union))


@pytest.mark.parametrize("batch,m,k,e", SHAPES)
def test_update_matches_k_loop_oracle(batch, m, k, e):
    """Fused windowed update == K-loop reference on head epoch AND union."""
    cfg = SketchConfig(m=m, b=8, seed=batch + m + k)
    st = window_array.init(cfg, k, e)
    ref = window_array.init(cfg, k, e)
    for i in range(2):  # second batch reads warm histograms
        keys, ids, w = _keyed_stream(batch, k, seed=batch * 7 + k + i)
        st = window_array.update_batch(cfg, st, keys, ids, w)
        ref = window_array.update_reference(cfg, ref, keys, ids, w)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))
    np.testing.assert_array_equal(np.asarray(st.hists), np.asarray(ref.hists))
    np.testing.assert_array_equal(
        np.asarray(st.union_regs), np.asarray(ref.union_regs)
    )
    np.testing.assert_array_equal(
        np.asarray(st.union_hists), np.asarray(ref.union_hists)
    )
    np.testing.assert_allclose(
        np.asarray(st.chats), np.asarray(ref.chats), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(st.union_chats), np.asarray(ref.union_chats), rtol=1e-5, atol=1e-6
    )


def test_union_cache_invariant_across_rotations():
    """union_regs == max over epoch planes and union_hists == rebuild, at
    every point of an update/rotate schedule (incl. past ring wrap)."""
    cfg = SketchConfig(m=96, b=8, seed=6)
    k, e = 9, 4
    st = window_array.init(cfg, k, e)
    for i in range(e + 3):
        keys, ids, w = _keyed_stream(300, k, seed=40 + i)
        st = window_array.update_batch(cfg, st, keys, ids, w)
        np.testing.assert_array_equal(
            np.asarray(st.union_regs), np.asarray(st.regs).max(axis=0)
        )
        np.testing.assert_array_equal(
            np.asarray(st.union_hists),
            np.asarray(dyn_array.rebuild_hists(cfg, st.union_regs)),
        )
        st = window_array.rotate(cfg, st)


@pytest.mark.parametrize("batch,m,k,e", SHAPES)
def test_estimate_window_matches_element_log_oracle(batch, m, k, e):
    """The acceptance property: estimate_window(w) is bit-identical to the
    element-log rebuild for EVERY w <= E, across rotation boundaries (the
    ring has wrapped: epochs were evicted)."""
    cfg = SketchConfig(m=m, b=8, seed=batch + k)
    st, logs = _drive(cfg, k, e, n_epochs=e + 2, batch=batch, seed=batch)
    for w in range(1, e + 1):
        np.testing.assert_array_equal(
            np.asarray(window_array.estimate_window(cfg, st, w)),
            _oracle_window_estimate(cfg, k, logs, w),
        )


def test_full_ring_cached_path_matches_fresh_union():
    """w == E reads the maintained union_hists — same bits as unioning the
    epoch planes from scratch."""
    cfg = SketchConfig(m=64, b=8, seed=7)
    k, e = 11, 5
    st, _ = _drive(cfg, k, e, n_epochs=e + 1, seed=3)
    cached = np.asarray(window_array.estimate_window(cfg, st, e))
    fresh = np.asarray(
        dyn_array.estimate_mle_rows(cfg, window_array.window_union_regs(st, e))
    )
    np.testing.assert_array_equal(cached, fresh)


def test_rotation_evicts_oldest_epoch():
    """An epoch's traffic leaves the full-ring window after E rotations."""
    cfg = SketchConfig(m=64, b=8, seed=8)
    k, e = 4, 3
    st = window_array.init(cfg, k, e)
    keys, ids, w = _keyed_stream(2000, k, seed=1)
    st = window_array.update_batch(cfg, st, keys, ids, w)
    assert float(np.asarray(window_array.estimate_window(cfg, st, e)).sum()) > 0
    for _ in range(e):
        st = window_array.rotate(cfg, st)
    np.testing.assert_array_equal(
        np.asarray(window_array.estimate_window(cfg, st, e)), 0.0
    )
    np.testing.assert_array_equal(np.asarray(st.union_chats), 0.0)
    assert int(st.epoch_id) == e and int(st.filled) == e


def test_untouched_and_clamped_window_guards():
    """Fresh state: Ĉ = 0 for every w. w > filled clamps to the filled ring
    (unfilled epochs are no-ops); out-of-range w raises."""
    cfg = SketchConfig(m=64, b=8, seed=9)
    k, e = 5, 4
    st = window_array.init(cfg, k, e)
    for w in range(1, e + 1):
        np.testing.assert_array_equal(
            np.asarray(window_array.estimate_window(cfg, st, w)), 0.0
        )
        np.testing.assert_array_equal(
            np.asarray(ops.window_union_estimate_op(cfg, st, w, interpret=True)), 0.0
        )
    # One live epoch; every w >= 1 must equal w = 1 (clamped-window semantics).
    keys, ids, w_ = _keyed_stream(1500, k, seed=2)
    st = window_array.update_batch(cfg, st, keys, ids, w_)
    assert int(st.filled) == 1
    ref = np.asarray(window_array.estimate_window(cfg, st, 1))
    assert ref.sum() > 0
    for w in range(2, e + 1):
        np.testing.assert_array_equal(
            np.asarray(window_array.estimate_window(cfg, st, w)), ref
        )
    for bad in (0, e + 1, -1):
        with pytest.raises(ValueError, match="out of range"):
            window_array.estimate_window(cfg, st, bad)
        with pytest.raises(ValueError, match="out of range"):
            ops.window_union_estimate_op(cfg, st, bad, interpret=True)
    with pytest.raises(ValueError, match="k >= 1"):
        window_array.init(cfg, 0, e)
    with pytest.raises(ValueError, match="e >= 2"):
        window_array.init(cfg, k, 1)


@pytest.mark.parametrize("batch,m,k,e", SHAPES)
def test_window_union_op_bit_identity(batch, m, k, e):
    """Pallas (interpret) fused union+bincount vs the pure-JAX union path:
    BITWISE equal estimates for every w."""
    cfg = SketchConfig(m=m, b=8, seed=m + k)
    st, _ = _drive(cfg, k, e, n_epochs=e + 1, batch=batch, seed=k)
    for w in range(1, e + 1):
        np.testing.assert_array_equal(
            np.asarray(window_array.estimate_window(cfg, st, w)),
            np.asarray(ops.window_union_estimate_op(cfg, st, w, interpret=True)),
        )


def test_anytime_read_rebases_to_window_estimate_on_rotate():
    """After rotate, the running union martingale re-bases to exactly the
    full-ring MLE read (then diverges as new updates stream in)."""
    cfg = SketchConfig(m=64, b=8, seed=12)
    k, e = 6, 4
    st, _ = _drive(cfg, k, e, n_epochs=3, seed=5)
    st = window_array.rotate(cfg, st)
    np.testing.assert_array_equal(
        np.asarray(window_array.estimate_ring_anytime(st)),
        np.asarray(window_array.estimate_window(cfg, st, e)),
    )


def test_window_merge_is_rowwise_union():
    """Ring-aligned pod merge: per-epoch register max; misaligned rejected."""
    cfg = SketchConfig(m=64, b=8, seed=13)
    k, e = 5, 3
    sa, _ = _drive(cfg, k, e, n_epochs=2, seed=21)
    sb, _ = _drive(cfg, k, e, n_epochs=2, seed=22)
    merged = window_array.merge(cfg, sa, sb)
    np.testing.assert_array_equal(
        np.asarray(merged.regs),
        np.maximum(np.asarray(sa.regs), np.asarray(sb.regs)),
    )
    np.testing.assert_array_equal(
        np.asarray(merged.union_regs), np.asarray(merged.regs).max(axis=0)
    )
    # Merged chats re-estimate via the MLE — merging a state with itself
    # must not double anything.
    self_merged = window_array.merge(cfg, sa, sa)
    np.testing.assert_array_equal(
        np.asarray(self_merged.union_chats),
        np.asarray(window_array.estimate_window(cfg, sa, e)),
    )
    with pytest.raises(ValueError, match="matching"):
        window_array.merge(cfg, sa, window_array.init(cfg, k + 1, e))
    with pytest.raises(ValueError, match="ring-aligned"):
        window_array.merge(cfg, sa, window_array.rotate(cfg, sb))


def test_update_tenants_routes_and_stamps_epochs():
    cfg = SketchConfig(m=64, b=8, seed=16)
    dcfg = DirectoryConfig(capacity=16, seed=17)
    rng = np.random.default_rng(91)
    tkeys = key_directory.split_uint64(rng.integers(0, 2**64, 200, dtype=np.uint64))
    ids = jnp.asarray(rng.integers(0, 2**32, 200, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 200).astype(np.float32))
    st = window_array.init(cfg, 16, 3)
    st = window_array.rotate(cfg, st)  # epoch_id = 1
    st, dstate = window_array.update_tenants(
        cfg, dcfg, st, key_directory.init(dcfg), tkeys, ids, w
    )
    slots = np.asarray(key_directory.route_slots(dcfg, tkeys))
    touched = np.unique(slots)
    np.testing.assert_array_equal(np.asarray(dstate.last_touch)[touched], 1)
    assert int(dstate.n_routed) == 200
    # Registers match the dense-slot path.
    ref = window_array.update_batch(
        cfg, window_array.rotate(cfg, window_array.init(cfg, 16, 3)),
        jnp.asarray(slots), ids, w,
    )
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))
    with pytest.raises(ValueError, match="capacity"):
        window_array.update_tenants(
            cfg, dcfg, window_array.init(cfg, 8, 3), key_directory.init(dcfg),
            tkeys, ids, w,
        )


# ---------------------------------------------------------------------------
# key-directory aging
# ---------------------------------------------------------------------------


def test_directory_aging_evicts_cold_fingerprints():
    dcfg = DirectoryConfig(capacity=32, seed=5, pinned=(7,))
    rng = np.random.default_rng(3)
    hot = key_directory.split_uint64(rng.integers(0, 2**64, 50, dtype=np.uint64))
    cold = key_directory.split_uint64(rng.integers(0, 2**64, 50, dtype=np.uint64))
    pinned = key_directory.split_uint64(np.array([7], dtype=np.uint64))

    st = key_directory.init(dcfg)
    _, st = key_directory.route(dcfg, st, cold, epoch=0)
    _, st = key_directory.route(dcfg, st, pinned, epoch=0)
    _, st = key_directory.route(dcfg, st, hot, epoch=5)
    claimed_before = int(np.sum(np.asarray(st.fingerprints) != 0))

    st2, n_evicted = key_directory.evict_older_than(dcfg, st, 5)
    assert int(n_evicted) > 0
    assert int(np.sum(np.asarray(st2.fingerprints) != 0)) == claimed_before - int(n_evicted)
    # Hot slots the cold cohort never claimed keep their claims and stamps
    # (hot traffic COLLIDING with a cold ghost does not protect it — those
    # slots age out and the hot tenant re-claims on its next routing).
    hot_slots = np.unique(np.asarray(key_directory.route_slots(dcfg, hot)))
    cold_slots = np.unique(np.asarray(key_directory.route_slots(dcfg, cold)))
    owned_hot = np.setdiff1d(hot_slots, cold_slots)
    assert owned_hot.size > 0
    np.testing.assert_array_equal(np.asarray(st2.last_touch)[owned_hot], 5)
    assert all(np.asarray(st2.fingerprints)[owned_hot] != 0)
    # The pinned slot never ages, even when stone cold.
    assert np.asarray(st2.fingerprints)[0] != 0
    st3, _ = key_directory.evict_older_than(dcfg, st2, 10**6)
    assert np.asarray(st3.fingerprints)[0] != 0
    assert int(np.sum(np.asarray(st3.fingerprints) != 0)) == 1
    # Counters are cumulative history, never rewound.
    assert int(st3.n_routed) == int(st.n_routed)


def test_directory_aging_reclaim_avoids_ghost_collisions():
    """A fresh tenant landing on an evicted slot claims it first-contact —
    no collision against the departed tenant's ghost fingerprint."""
    dcfg = DirectoryConfig(capacity=4, seed=9)
    rng = np.random.default_rng(11)
    # Find two tenants that share a slot.
    cand = rng.integers(0, 2**64, 400, dtype=np.uint64)
    slots = np.asarray(key_directory.route_slots(dcfg, key_directory.split_uint64(cand)))
    a = cand[slots == 2][0]
    b = cand[slots == 2][1]

    st = key_directory.init(dcfg)
    _, st = key_directory.route(dcfg, st, key_directory.split_uint64([a]), epoch=0)
    # Without aging: b collides with a's claim.
    _, st_no = key_directory.route(dcfg, st, key_directory.split_uint64([b]), epoch=9)
    assert int(st_no.n_collisions) == 1
    # With aging first: the slot was released, b claims it fresh.
    st_aged, n = key_directory.evict_older_than(dcfg, st, 5)
    assert int(n) == 1
    _, st_yes = key_directory.route(dcfg, st_aged, key_directory.split_uint64([b]), epoch=9)
    assert int(st_yes.n_collisions) == 0


def test_colliding_traffic_does_not_keep_ghost_slot_warm():
    """Only owner/claim routings stamp last_touch: a departed tenant's slot
    under ACTIVE colliding traffic still ages out, releasing the ghost."""
    dcfg = DirectoryConfig(capacity=4, seed=9)
    rng = np.random.default_rng(11)
    cand = rng.integers(0, 2**64, 400, dtype=np.uint64)
    slots = np.asarray(key_directory.route_slots(dcfg, key_directory.split_uint64(cand)))
    a, b = cand[slots == 2][:2]
    slot = 2

    st = key_directory.init(dcfg)
    _, st = key_directory.route(dcfg, st, key_directory.split_uint64([a]), epoch=0)
    for ep in range(1, 5):  # b collides against a's ghost every epoch
        _, st = key_directory.route(dcfg, st, key_directory.split_uint64([b]), epoch=ep)
    assert int(st.n_collisions) == 4
    assert int(np.asarray(st.last_touch)[slot]) == 0  # collisions never stamp
    st, n = key_directory.evict_older_than(dcfg, st, 1)
    assert int(n) == 1
    # b now claims the released slot and its routings stop colliding.
    _, st = key_directory.route(dcfg, st, key_directory.split_uint64([b]), epoch=5)
    assert int(st.n_collisions) == 4
    assert int(np.asarray(st.last_touch)[slot]) == 5


def test_directory_merge_carries_stamps():
    dcfg = DirectoryConfig(capacity=16, seed=6)
    rng = np.random.default_rng(7)
    ka = key_directory.split_uint64(rng.integers(0, 2**64, 30, dtype=np.uint64))
    kb = key_directory.split_uint64(rng.integers(0, 2**64, 30, dtype=np.uint64))
    _, da = key_directory.route(dcfg, key_directory.init(dcfg), ka, epoch=2)
    _, db = key_directory.route(dcfg, key_directory.init(dcfg), kb, epoch=4)
    merged = key_directory.merge(da, db)
    np.testing.assert_array_equal(
        np.asarray(merged.last_touch),
        np.maximum(np.asarray(da.last_touch), np.asarray(db.last_touch)),
    )


# ---------------------------------------------------------------------------
# AnomalyBank
# ---------------------------------------------------------------------------


def _feed(bcfg, bank, series):
    scores = None
    for est in series:
        bank, scores = anomaly.step(bcfg, bank, jnp.asarray(est, jnp.float32))
    return bank, scores


def test_anomaly_warmup_never_alerts():
    bcfg = anomaly.AnomalyConfig(warmup=4)
    bank = anomaly.init(3)
    rng = np.random.default_rng(0)
    for _ in range(4):
        bank, scores = anomaly.step(
            bcfg, bank, jnp.asarray(rng.uniform(0, 1000, 3), jnp.float32)
        )
        np.testing.assert_array_equal(np.asarray(scores), 0.0)


def test_anomaly_flags_spike_and_only_spike():
    bcfg = anomaly.AnomalyConfig(warmup=3, min_weight=5.0)
    bank = anomaly.init(4)
    rng = np.random.default_rng(1)
    base = np.array([100.0, 500.0, 50.0, 0.0])  # tenant 3 is an empty slot
    series = [base * rng.normal(1.0, 0.03, 4) for _ in range(10)]
    bank, scores = _feed(bcfg, bank, series)
    assert anomaly.top_alerts(bcfg, scores) == []
    # Tenant 1 triples for three consecutive windows.
    for _ in range(3):
        obs = base * rng.normal(1.0, 0.03, 4)
        obs[1] *= 3.0
        bank, scores = anomaly.step(bcfg, bank, jnp.asarray(obs, jnp.float32))
    alerts = anomaly.top_alerts(bcfg, scores)
    assert [slot for slot, _ in alerts] == [1]
    # Dust slots below min_weight never score, whatever they do.
    assert float(scores[3]) == 0.0


def test_anomaly_scores_decay_and_baseline_recovers():
    """Zero-mean noise drains the CUSUM; a sustained level shift eventually
    re-baselines (freeze_factor > 0) instead of ratcheting forever."""
    bcfg = anomaly.AnomalyConfig(warmup=3, min_weight=1.0, alpha=0.3, freeze_factor=0.2)
    bank = anomaly.init(1)
    rng = np.random.default_rng(2)
    bank, _ = _feed(bcfg, bank, [[100 * rng.normal(1, 0.05)] for _ in range(8)])
    # Step change to 300 and stay there: alert fires...
    bank, scores = _feed(bcfg, bank, [[300.0]] * 3)
    assert float(scores[0]) > bcfg.cusum_h
    # ...and eventually clears once 300 is the new normal.
    for _ in range(200):
        bank, scores = anomaly.step(bcfg, bank, jnp.asarray([300.0], jnp.float32))
    assert float(scores[0]) <= bcfg.cusum_h
    assert float(bank.mean[0]) == pytest.approx(300.0, rel=0.05)


def test_anomaly_merge_disjoint_and_validation():
    bcfg = anomaly.AnomalyConfig(warmup=1)
    a, _ = _feed(bcfg, anomaly.init(4), [[10, 0, 20, 0]] * 5)
    b, _ = _feed(bcfg, anomaly.init(4), [[0, 30, 0, 40]] * 5)
    merged = anomaly.merge(a, b)
    np.testing.assert_allclose(np.asarray(merged.mean), [10, 30, 20, 40], rtol=1e-6)
    with pytest.raises(ValueError, match="matching"):
        anomaly.merge(a, anomaly.init(5))
    with pytest.raises(ValueError, match="alpha"):
        anomaly.AnomalyConfig(alpha=0.0)
    with pytest.raises(ValueError, match="warmup"):
        anomaly.AnomalyConfig(warmup=0)
    with pytest.raises(ValueError, match="freeze_factor"):
        anomaly.AnomalyConfig(freeze_factor=1.0)
    with pytest.raises(ValueError, match="k >= 1"):
        anomaly.init(0)


def test_anomaly_ranking_is_by_score():
    bcfg = anomaly.AnomalyConfig(warmup=1)
    scores = jnp.asarray([0.0, 9.0, 7.0, 100.0, 5.0], jnp.float32)
    assert anomaly.top_alerts(bcfg, scores, n=2) == [(3, 100.0), (1, 9.0)]
    assert anomaly.top_alerts(bcfg, scores, n=10) == [(3, 100.0), (1, 9.0), (2, 7.0)]


# ---------------------------------------------------------------------------
# monitor + train/serve threading
# ---------------------------------------------------------------------------


def test_window_monitor_roundtrip():
    cfg = SketchConfig(m=64, b=8, seed=61)
    mon = monitor.WindowMonitor.for_capacity(cfg, 8, 3, evict_after=2)
    rng = np.random.default_rng(26)
    # ~900 distinct per row: the well-loaded regime where the windowed MLE
    # read is specified (DESIGN.md §8.5 documents the light-load caveat).
    n = 8000
    tkeys = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    mask = jnp.asarray(np.arange(n) < 7400)

    st = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    assert int(st.n_seen) == 7400
    est = np.asarray(mon.estimate(st))  # anytime full-ring read
    assert est.shape == (8,)
    true_c = float(np.asarray(w, np.float64)[:7400].sum())
    assert abs(est.sum() - true_c) / true_c < 0.2  # martingale total tracks

    m = mon.metrics(st)
    assert int(m["tenant_elements_seen"]) == 7400
    assert int(m["tenant_window_epoch"]) == 0
    assert float(m["tenant_window_weight"]) == pytest.approx(float(est.sum()), rel=1e-6)

    # The windowed MLE read and the anytime read answer the same window.
    mle = np.asarray(mon.estimate(st, w=3))
    assert abs(mle.sum() - true_c) / true_c < 0.35

    # Rotate the live epoch out entirely: the window empties.
    for _ in range(3):
        st = mon.rotate(st)
    np.testing.assert_array_equal(np.asarray(mon.estimate(st)), 0.0)
    assert int(mon.metrics(st)["tenant_window_epoch"]) == 3
    # Aging (evict_after=2) released every fingerprint claimed at epoch 0.
    assert int(mon.metrics(st)["tenant_slots_claimed"]) == 0

    # Ring-aligned pod merge keeps the surface contract.
    st2 = mon.init()
    for _ in range(3):
        st2 = mon.rotate(st2)
    st2 = mon.update(st2, tkeys, ids, w, mask=mask)
    merged = mon.merge(st, st2)
    assert int(merged.n_seen) == 14800


def test_train_step_threads_window_tenant_telemetry():
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.sketchstream.monitor import TelemetryState
    from repro.train import optimizer, train_step as ts

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(27)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "doc_ids": jnp.asarray(rng.integers(0, 2**32, (4,), dtype=np.uint32)),
    }
    skc = SketchConfig(m=64, b=8, seed=63)
    mon = monitor.WindowMonitor.for_capacity(skc, 256, 4)
    ocfg = optimizer.OptConfig(lr=1e-3, warmup_steps=0)
    step = jax.jit(ts.make_train_step(mcfg, ocfg, None, sketch_cfg=skc, tenant_monitor=mon))
    opt, comp, sk = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)
    assert isinstance(sk, TelemetryState)

    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(sk.tenants.n_seen) == 64  # 4 x 16 tokens through the array
    assert "tenant_window_weight" in metrics and "distinct_tokens_est" in metrics
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 4  # 4 documents -> exactly 4 live rows

    # The epoch clock lives OUTSIDE the jit'd step: rotate between steps.
    sk = TelemetryState(scalar=sk.scalar, tenants=mon.rotate(sk.tenants))
    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(metrics["tenant_window_epoch"]) == 1
    assert int(sk.tenants.n_seen) == 128


def test_decode_step_threads_window_tenant_telemetry():
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import serve_step

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(7))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), transformer.abstract_cache(mcfg, batch=2, max_len=16)
    )
    skc = SketchConfig(m=64, b=8, seed=65)
    mon = monitor.WindowMonitor.for_capacity(skc, 128, 3)
    dec = jax.jit(serve_step.make_decode_step(mcfg, None, sketch_cfg=skc, tenant_monitor=mon))

    sk = monitor.TelemetryState(scalar=monitor.init(skc), tenants=mon.init())
    _, _, sk = dec(
        params, cache, jnp.int32(0), jnp.zeros((2, 1), jnp.int32), sk,
        jnp.asarray([101, 202], jnp.uint32),  # session ids
        jnp.asarray([1.0, 3.0], jnp.float32),  # engagement weights
        None, None,
        jnp.asarray([7, 7], jnp.uint32),  # both sessions belong to tenant 7
    )
    assert int(sk.tenants.n_seen) == 2
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 1  # one tenant row live
    assert float(est.sum()) == pytest.approx(4.0, rel=0.5)  # ~1.0 + 3.0
