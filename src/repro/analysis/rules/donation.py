"""donation-safety — donated buffers are dead after the donating call.

The ingest pipeline's steady-state speed comes from ``donate_argnums`` /
``donate=True`` in-place updates (DESIGN.md §8.8): XLA aliases the donated
input's buffer to the output, so the caller's reference is *invalidated* at
dispatch. Reading it afterwards is either a runtime "donated buffer" error
or — worse, under some backends — silent garbage. Two checks:

1. **use-after-donate**: inside one function, after a donating call, the
   donated argument must not be read again unless the same statement (or a
   later one, before the read) rebinds it — the canonical safe shape is
   ``state = update_batch(cfg, state, ..., donate=True)``.
2. **donating entry points return the new buffer**: a function wrapped by
   ``jax.jit(fn, donate_argnums=...)`` must contain a value-returning
   ``return`` — donation with no returned successor strands the caller
   with nothing but the dead input.

Donating callees are recognized three ways:

* names bound to ``jax.jit(..., donate_argnums=(i, ...))`` at module or
  ``self.X = ...`` scope (donated positions = the literal tuple),
* calls carrying ``donate=True`` whose callee resolves to a project
  function: the donated argument is the one bound to the callee's ``state``
  parameter (the repo-wide convention for every donate-capable entry);
  unresolvable callees fall back to flagging args literally named
  ``state``/``st``,
* calls of factory results (``make_donating(...)(state, ...)``) where the
  factory's return statement is ``jax.jit(..., donate_argnums=...)``.

Linear statement order approximates control flow; branch-crossing false
positives go to the baseline with justification.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import ImportMap, call_keyword, dotted, literal_int_tuple
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

SCOPE = ("src/repro/", "benchmarks/", "examples/")


def _donate_argnums(call: ast.Call, imap: ImportMap) -> tuple[int, ...] | None:
    """Donated positions if ``call`` is jax.jit(..., donate_argnums=...)."""
    if imap.resolve(call.func) != "jax.jit":
        return None
    return literal_int_tuple(call_keyword(call, "donate_argnums"))


def _param_index(fn: ast.FunctionDef, name: str) -> int | None:
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    return params.index(name) if name in params else None


def _positional_arg(call: ast.Call, idx: int) -> ast.expr | None:
    if idx < len(call.args):
        a = call.args[idx]
        return None if isinstance(a, ast.Starred) else a
    return None


class _ProjectIndex:
    """Cross-module lookup of function defs + donating-name registries."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.defs: dict[str, dict[str, ast.FunctionDef]] = {}  # mod -> top-level defs
        self.donating: dict[str, dict[str, tuple[int, ...]]] = {}  # mod -> name -> pos
        for mod in ctx.iter_modules(SCOPE):
            imap = ImportMap(mod.tree, mod.name)
            defs: dict[str, ast.FunctionDef] = {}
            donating: dict[str, tuple[int, ...]] = {}
            for node in mod.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    defs[node.name] = node
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Assign) or not isinstance(
                    node.value, ast.Call
                ):
                    continue
                pos = _donate_argnums(node.value, imap)
                if pos is None:
                    continue
                for target in node.targets:
                    d = dotted(target)
                    if d is not None:
                        donating[d] = pos
            self.defs[mod.name] = defs
            self.donating[mod.name] = donating

    def resolve_def(
        self, call: ast.Call, mod, imap: ImportMap
    ) -> ast.FunctionDef | None:
        """The project function def a call's callee resolves to, if any."""
        func = call.func
        if isinstance(func, ast.Name):
            return self.defs.get(mod.name, {}).get(func.id)
        qual = imap.resolve(func)
        if qual is None:
            return None
        owner, _, leaf = qual.rpartition(".")
        return self.defs.get(owner, {}).get(leaf)

    def factory_donates(self, fn: ast.FunctionDef, imap: ImportMap) -> tuple[int, ...] | None:
        """Donated positions of the callable a factory returns, if its
        return statement is a literal jax.jit(..., donate_argnums=...)."""
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and isinstance(node.value, ast.Call):
                pos = _donate_argnums(node.value, imap)
                if pos is not None:
                    return pos
        return None


def _donated_args(
    call: ast.Call, mod, imap: ImportMap, index: _ProjectIndex
) -> list[ast.expr]:
    """Argument expressions whose buffers this call donates (possibly [])."""
    out: list[ast.expr] = []
    callee = dotted(call.func)

    # 1. Known donating name (module-level or self.X registry).
    if callee is not None:
        pos = index.donating.get(mod.name, {}).get(callee)
        if pos is None and "." in callee:
            qual = imap.resolve(call.func)
            if qual is not None:
                owner, _, leaf = qual.rpartition(".")
                pos = index.donating.get(owner, {}).get(leaf)
        if pos is not None:
            out += [a for i in pos if (a := _positional_arg(call, i)) is not None]
            return out

    # 2. donate=True convention: the callee's ``state`` parameter.
    donate_kw = call_keyword(call, "donate")
    if isinstance(donate_kw, ast.Constant) and donate_kw.value is True:
        fn = index.resolve_def(call, mod, imap)
        if fn is not None:
            for pname in ("state", "st"):
                idx = _param_index(fn, pname)
                if idx is not None:
                    kwarg = call_keyword(call, pname)
                    arg = kwarg if kwarg is not None else _positional_arg(call, idx)
                    if arg is not None:
                        out.append(arg)
                    break
        else:
            out += [
                a
                for a in call.args
                if not isinstance(a, ast.Starred)
                and (dotted(a) or "").split(".")[-1] in ("state", "st")
            ]
        return out

    # 3. Factory-result call: make_donating(...)(state, ...).
    if isinstance(call.func, ast.Call):
        fn = index.resolve_def(call.func, mod, imap)
        if fn is not None:
            pos = index.factory_donates(fn, imap)
            if pos is not None:
                out += [a for i in pos if (a := _positional_arg(call, i)) is not None]
    return out


def _pos(node: ast.AST) -> tuple[int, int]:
    return (getattr(node, "end_lineno", node.lineno), getattr(node, "end_col_offset", 0))


@register
class DonationSafetyRule(Rule):
    """Flag reads of donated arguments after the donating call, and
    donating jit wrappers whose impl never returns a value."""

    name = "donation-safety"
    description = (
        "a donated buffer is dead after the donating call: rebind it from "
        "the result, never read the old reference"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        index = _ProjectIndex(ctx)
        findings: list[Finding] = []
        for mod in ctx.iter_modules(SCOPE):
            if not ctx.is_selected(mod.rel):
                continue
            imap = ImportMap(mod.tree, mod.name)
            findings += self._check_returns(mod, imap, index)
            for _, fn in self._functions(mod.tree):
                findings += self._check_function(fn, mod, imap, index)
        return findings

    @staticmethod
    def _functions(tree: ast.Module):
        from repro.analysis.astutil import walk_functions

        return list(walk_functions(tree))

    def _check_returns(self, mod, imap, index) -> list[Finding]:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            pos = _donate_argnums(node, imap)
            if pos is None or not node.args:
                continue
            target = node.args[0]
            fn: ast.FunctionDef | None = None
            if isinstance(target, ast.Name):
                fn = index.defs.get(mod.name, {}).get(target.id)
                if fn is None:
                    # Local def in an enclosing function.
                    for _, cand in self._functions(mod.tree):
                        if cand.name == target.id:
                            fn = cand
                            break
            elif isinstance(target, ast.Call) and imap.resolve(target.func) in (
                "functools.partial",
                "partial",
            ):
                inner = target.args[0] if target.args else None
                if isinstance(inner, ast.Name):
                    fn = index.defs.get(mod.name, {}).get(inner.id)
            if fn is None:
                continue
            if not any(
                isinstance(n, ast.Return) and n.value is not None
                for n in ast.walk(fn)
            ):
                out.append(
                    Finding(
                        self.name,
                        mod.rel,
                        node.lineno,
                        f"jax.jit donates into '{fn.name}' which never returns "
                        "a value — the donated buffer's successor is lost",
                    )
                )
        return out

    def _check_function(self, fn, mod, imap, index) -> list[Finding]:
        out: list[Finding] = []
        # Events: (position, kind, dotted-name, node)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            donated = _donated_args(node, mod, imap, index)
            if not donated:
                continue
            names = {d for a in donated if (d := dotted(a)) is not None}
            if not names:
                continue
            # A donating call inside a ``return`` leaves the function on its
            # own path — syntactically-later reads are other branches.
            if any(
                isinstance(ret, ast.Return)
                and ret.value is not None
                and any(n is node for n in ast.walk(ret.value))
                for ret in ast.walk(fn)
            ):
                continue
            # Same-statement rebinding (state = f(state, donate=True)).
            stmt = self._enclosing_assign(fn, node)
            if stmt is not None:
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
                )
                for t in targets:
                    for tn in ast.walk(t):
                        d = dotted(tn)
                        if d in names:
                            names.discard(d)
            if not names:
                continue
            out += self._reads_after(fn, node, names, mod)
        return out

    @staticmethod
    def _enclosing_assign(fn, call: ast.Call):
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if any(n is call for n in ast.walk(node.value or node)):
                    return node
        return None

    def _reads_after(self, fn, call: ast.Call, names: set[str], mod) -> list[Finding]:
        out = []
        cpos = _pos(call)
        # First rebinding position per name bounds the scan.
        rebound: dict[str, tuple[int, int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                for t in node.targets if isinstance(node, ast.Assign) else [node.target]:
                    for tn in ast.walk(t):
                        d = dotted(tn)
                        if d in names and _pos(tn) > cpos:
                            p = _pos(tn)
                            if d not in rebound or p < rebound[d]:
                                rebound[d] = p
        for node in ast.walk(fn):
            d = dotted(node)
            if d not in names:
                continue
            if not isinstance(
                getattr(node, "ctx", None), ast.Load
            ):
                continue
            p = (node.lineno, node.col_offset)
            if p <= cpos:
                continue
            bound = rebound.get(d)
            if bound is not None and p > bound:
                continue
            out.append(
                Finding(
                    self.name,
                    mod.rel,
                    node.lineno,
                    f"'{d}' is read after being donated to "
                    f"'{dotted(call.func) or '<call>'}' — the buffer is dead; "
                    "rebind from the call's result first",
                )
            )
        return out
