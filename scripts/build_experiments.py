"""Compose EXPERIMENTS.md: hand-written narrative (docs/experiments_narrative.md
fragments) + tables generated from experiments/{dryrun,bench}/*.json.

    PYTHONPATH=src python scripts/build_experiments.py
"""

import json
import os
import sys

sys.path.insert(0, "src")

from repro.roofline import report  # noqa: E402


def bench(name):
    path = f"experiments/bench/{name}.json"
    return json.load(open(path)) if os.path.exists(path) else []


def perf_cell_table(arch, shape):
    import glob

    rows = ["| variant | compute s | memory s | collective s | dominant s | frac-roofline | peak GiB | useful |",
            "|---|---:|---:|---:|---:|---:|---:|---:|"]
    for p in sorted(glob.glob(f"experiments/dryrun/{arch}_{shape}_singlepod*.json")):
        rec = json.load(open(p))
        if rec["status"] != "ok":
            continue
        tag = os.path.basename(p).split(f"{shape}_singlepod")[-1].replace(".json", "") or "(baseline)"
        t = rec["roofline"]
        tmax = max(t.values())
        rows.append(
            f"| {tag} | {t['compute_s']:.2f} | {t['memory_s']:.2f} | {t['collective_s']:.2f} | "
            f"{tmax:.2f} | {t['compute_s']/tmax:.3f} | {rec['hbm_fit']['peak_bytes_est']/2**30:.1f} | "
            f"{rec['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(rows)


def main():
    narrative = open("docs/experiments_narrative.md").read()
    out = narrative
    out = out.replace("<<DRYRUN_SINGLE>>", report.dryrun_table("_singlepod"))
    out = out.replace("<<DRYRUN_MULTI>>", report.dryrun_table("_multipod"))
    out = out.replace("<<ROOFLINE_SINGLE>>", report.roofline_table("_singlepod"))
    out = out.replace("<<REPRO_TABLES>>", report.repro_tables())
    out = out.replace("<<PERF_KIMI>>", perf_cell_table("kimi-k2-1t-a32b", "train_4k"))
    out = out.replace("<<PERF_JAMBA>>", perf_cell_table("jamba-1.5-large-398b", "train_4k"))
    out = out.replace("<<PERF_MAMBA>>", perf_cell_table("mamba2-370m", "train_4k"))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("EXPERIMENTS.md written:", len(out), "chars")


if __name__ == "__main__":
    main()
