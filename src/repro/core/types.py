"""Shared configuration types for the sketch family.

``SketchConfig`` is a frozen (hashable) dataclass so it can be closed over or
passed as a static argument to ``jax.jit``. Sketch *states* are plain pytrees
(NamedTuples of arrays) so they thread through scans, pjit, and checkpoints.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Configuration shared by QSketch / QSketch-Dyn / LM / FastGM / FastExp.

    Attributes:
      m: number of registers.
      b: register width in bits (QSketch family). r_min/r_max follow the
         paper: r_min = -2^(b-1)+1, r_max = 2^(b-1)-1 (b=8 -> [-127, 127]).
      seed: base salt; each hash role (h_j, g, permutation keys) derives its
         own sub-salt from it so roles are independent.
    """

    m: int = 256
    b: int = 8
    seed: int = 0x5EED

    def __post_init__(self):
        if self.m < 3:
            raise ValueError("m >= 3 required (estimator variance needs m>=3)")
        if not (2 <= self.b <= 8):
            raise ValueError("register width b must be in [2, 8]")

    @property
    def r_min(self) -> int:
        """Smallest register value (and the empty-register init): -2^(b-1)+1."""
        return -(2 ** (self.b - 1)) + 1

    @property
    def r_max(self) -> int:
        """Largest register value (truncation ceiling): 2^(b-1)-1."""
        return 2 ** (self.b - 1) - 1

    @property
    def num_bins(self) -> int:
        """Histogram bins: one per representable register value."""
        return 2**self.b

    @property
    def top_bin(self) -> int:
        """Index of the r_max bin: r_max - r_min = 2^b - 2 (the paper's
        symmetric truncation leaves one int8 code point unused)."""
        return self.r_max - self.r_min

    # Derived salts: distinct per role, stable across processes.
    @property
    def salt_h(self) -> int:
        """Derived salt of the register-value hash role h_j."""
        return (self.seed * 0x9E3779B1 + 1) & 0xFFFFFFFF

    @property
    def salt_g(self) -> int:
        """Derived salt of the register-choice hash role g."""
        return (self.seed * 0x9E3779B1 + 2) & 0xFFFFFFFF

    @property
    def salt_perm(self) -> int:
        """Derived salt of the permutation keys (FastGM/FastExp schedules)."""
        return (self.seed * 0x9E3779B1 + 3) & 0xFFFFFFFF

    def memory_bits(self, with_histogram: bool = False) -> int:
        """Sketch memory footprint in bits (paper §4.3 complexity)."""
        bits = self.m * self.b
        if with_histogram:
            bits += self.num_bins * max(1, (self.m).bit_length())
        return bits


class QSketchState(NamedTuple):
    """Registers of a QSketch. int8 natively on TPU (DESIGN.md §4.4)."""

    regs: jnp.ndarray  # int8[m], initialized to r_min


class SketchArrayState(NamedTuple):
    """K independent QSketches as one register matrix (core/sketch_array.py).

    Row k is bit-identical to a standalone ``QSketchState`` fed the
    sub-stream of elements whose key is k (same cfg, same hash family), so
    per-row slicing, merging, and estimation all reuse the single-sketch
    machinery unchanged.
    """

    regs: jnp.ndarray  # int8[K, m], initialized to r_min


class ShardedArrayState(NamedTuple):
    """A SketchArray whose rows are sharded over a mesh axis
    (core/sharded_array.py).

    Same register semantics as ``SketchArrayState`` — row k is bit-identical
    to a standalone QSketch of the slot-k sub-stream — but the [K, m] matrix
    lives row-sharded over the ``"sketch"`` mesh axis, so K scales with the
    fleet instead of one host's memory. All algebra stays the max monoid;
    conversion to/from the single-host form is a pure reshard.
    """

    regs: jnp.ndarray  # int8[K, m], K divisible by the shard count


class DynState(NamedTuple):
    """QSketch-Dyn state: registers + value histogram + running estimate."""

    regs: jnp.ndarray  # int8[m]
    hist: jnp.ndarray  # int32[2^b]; counts *touched* registers only
    chat: jnp.ndarray  # float32 scalar, running weighted-cardinality estimate


class DynArrayState(NamedTuple):
    """K independent QSketch-Dyn sketches as one state (core/dyn_array.py).

    Row k is the key-k sub-stream's ``DynState`` (same cfg, same hash family
    — the key never enters the hash), so registers and histograms are
    bit-identical to a K-loop of single Dyn sketches and ``estimate_all`` is
    a pure O(K) read of the running martingales — no per-query Newton.
    """

    regs: jnp.ndarray  # int8[K, m]
    hists: jnp.ndarray  # int32[K, 2^b]; per-key counts of *touched* registers
    chats: jnp.ndarray  # float32[K], running weighted-cardinality estimates


class WindowArrayState(NamedTuple):
    """Sliding-window DynArray: a ring of E epoch sub-states plus a cached
    full-ring union (core/window_array.py).

    Epoch e's (regs[e], hists[e], chats[e]) is a ``DynArrayState`` of the
    sub-stream folded while e was the current epoch, so every per-epoch and
    windowed read reuses the DynArray machinery. The union_* fields cache the
    all-epoch max-union (exact: register max-merge is lossless) with DynArray
    histogram/martingale maintenance on top, giving the full-ring window an
    O(K) anytime read; sub-ring windows union on demand (DESIGN.md §8.5).

    ``head`` is the ring slot of the current epoch; ``filled`` counts live
    epochs (<= E) so callers can clamp w; ``epoch_id`` is the monotone epoch
    clock (total rotations) — the timestamp fed to key-directory aging.
    """

    regs: jnp.ndarray  # int8[E, K, m]
    hists: jnp.ndarray  # int32[E, K, 2^b]; per-epoch touched-register hists
    chats: jnp.ndarray  # float32[E, K], per-epoch running estimates
    union_regs: jnp.ndarray  # int8[K, m] == max over epoch axis (invariant)
    union_hists: jnp.ndarray  # int32[K, 2^b] touched-register hist of union
    union_chats: jnp.ndarray  # float32[K] full-ring anytime estimates
    head: jnp.ndarray  # int32 scalar, ring slot of the current epoch
    filled: jnp.ndarray  # int32 scalar in [1, E], epochs live in the ring
    epoch_id: jnp.ndarray  # int32 scalar, monotone epoch counter


class ShardedDynArrayState(NamedTuple):
    """A DynArray whose rows are sharded over a mesh axis
    (core/sharded_dyn_array.py).

    Same per-row semantics as ``DynArrayState`` — row k is bit-identical to
    a standalone QSketch-Dyn of the slot-k sub-stream, ``chats`` is the
    O(K)-anytime read — but all three leaves live row-sharded over the
    ``"sketch"`` mesh axis (``core/sharding.py`` row_dim 0 everywhere), so
    per-tenant anytime estimation scales with the fleet instead of one
    host's memory. Updates hash-route to the owning shard; chats sum
    exactly across key-partitioned fleets (``merge_disjoint``).
    """

    regs: jnp.ndarray  # int8[K, m], K divisible by the shard count
    hists: jnp.ndarray  # int32[K, 2^b], row-sharded with regs
    chats: jnp.ndarray  # float32[K], row-sharded running estimates


class ShardedWindowArrayState(NamedTuple):
    """A WindowArray whose tenant rows are sharded over a mesh axis
    (core/sharded_window_array.py).

    Same ring semantics as ``WindowArrayState`` — E epoch DynArray
    sub-states plus a cached full-ring union — but every per-tenant leaf is
    sharded over the ``"sketch"`` axis at its K dimension (row_dim 1 for the
    epoch planes, 0 for the union cache; ``core/sharding.py``), while the
    ring clock (``head``/``filled``/``epoch_id``) stays replicated so all
    shards rotate in lockstep. Rotation and the union-cache rebuild are
    shard-local; the epoch-plane max-union commutes with row sharding
    (DESIGN.md §8.6).
    """

    regs: jnp.ndarray  # int8[E, K, m], K divisible by the shard count
    hists: jnp.ndarray  # int32[E, K, 2^b]
    chats: jnp.ndarray  # float32[E, K]
    union_regs: jnp.ndarray  # int8[K, m] == max over epoch axis (invariant)
    union_hists: jnp.ndarray  # int32[K, 2^b]
    union_chats: jnp.ndarray  # float32[K] full-ring anytime estimates
    head: jnp.ndarray  # int32 scalar, replicated ring slot of current epoch
    filled: jnp.ndarray  # int32 scalar in [1, E], replicated
    epoch_id: jnp.ndarray  # int32 scalar, replicated monotone epoch counter


class VirtualDynArrayState(NamedTuple):
    """Register-sharing virtual DynArray (core/virtual_dyn_array.py).

    The long tail of tenants shares one physical register pool: tail tenant
    t's logical register j lives at ``pool[hash(t, j) mod M]``, so pool slots
    are written by many tenants and a per-tenant read is *noisy* — estimates
    subtract the expected contribution of other tenants' traffic at query
    time (Wang et al., arXiv 1811.09126; DESIGN.md §8.9) instead of being
    bit-identical to a dedicated sketch. Pinned hot tenants bypass the pool
    entirely and keep dedicated dense ``DynArrayState`` rows, so their reads
    stay exact.

    ``pool_hist`` is the full value histogram of the pool plane (bin
    ``v - r_min`` counts slots at value v, *including* untouched slots at
    ``r_min`` — pool-geometry "full" hist, unlike the touched-only DynArray
    hists), which makes the pool-total solve an O(2^b) read. ``n_tail``
    counts live tail element-occurrences folded in (telemetry only).

    ``w_tail`` accumulates the exact total weight of live tail occurrences.
    It is the noise scale of the cancellation pre-pass: the expected
    cross-tenant noise on one tenant's virtual row is α·w_tail with
    α = m/M, and an exact scalar beats re-estimating the pool total from
    ``pool_hist`` (the pooled MLE is biased low under heterogeneous slot
    loads — DESIGN.md §8.9). Under the repo's disjoint-shard convention it
    is exact and merges by addition; re-sent duplicate occurrences inflate
    it (registers max-dedup, the scalar cannot), making the cancelled
    estimate conservative — the documented failure direction.
    """

    pool: jnp.ndarray  # int8[M], shared tail register pool, init r_min
    pool_hist: jnp.ndarray  # int32[2^b], full value hist of the pool plane
    n_tail: jnp.ndarray  # int32 scalar, live tail element-occurrences folded
    w_tail: jnp.ndarray  # f32 scalar, exact total live tail weight folded
    hot: DynArrayState  # dedicated dense rows of the pinned hot tenants


class FloatSketchState(NamedTuple):
    """LM / FastGM / FastExpSketch state: float32 min-registers."""

    regs: jnp.ndarray  # float32[m], initialized to +inf
