"""Reusable row-sharding layer for the keyed sketch containers.

PR 2 hard-coded the mesh machinery inside ``core/sharded_array.py``: a
``"sketch"`` mesh axis, row partitioning of the ``[K, m]`` register matrix,
hash-routed batch dispatch, all-max merge, and shard-local estimation. The
Dyn and Window containers (PRs 3-4) want exactly the same machinery — their
states are just bigger pytrees (histograms, chats, epoch rings) with the
same "row k belongs to exactly one shard" geometry. This module extracts
that machinery so every sharded front (``sharded_array``,
``sharded_dyn_array``, ``sharded_window_array``) shares one implementation:

* **Row specs** (``spec``, ``tree_specs``) — a leaf's partitioning is
  described by the index of its K axis (``row_dim``; ``None`` = replicated
  scalar/telemetry). ``DynArrayState`` leaves are all ``row_dim=0``;
  ``WindowArrayState`` epoch planes are ``row_dim=1`` with replicated ring
  scalars.
* **Placement** (``device_put_rows``) — reshard a host pytree onto the mesh
  (pure data movement, values unchanged).
* **shard_map wrapping** (``shard_map_rows``) — wrap a *shard-local*
  function so it runs per shard over row-sharded pytrees; replicated args
  (batches, ring scalars) are broadcast. The local function sees plain
  unsharded arrays of K/S rows and reuses the single-host container code
  verbatim — which is what makes bit-identity provable instead of hoped-for.
* **Hash-routed dispatch** (``own_slots``) — inside a local function, mask
  the replicated batch down to the slot range this shard owns and rebase
  slots to local row indices. Every element updates exactly the shard that
  owns its row; no collective is needed and register state never leaves its
  shard.
* **All-max merge** — cross-pod merges stay element-wise ``jnp.maximum``
  on the sharded arrays themselves (the max monoid needs no resharding);
  ``check_same_shape`` is the shared validation.

The shard axis name is a parameter everywhere (default ``"sketch"``):
telemetry embedded in a training step can reuse an existing mesh axis (e.g.
``"data"``) instead of building a second mesh over the same devices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# jax.shard_map only exists on newer JAX; fall back to the experimental home.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map

AXIS = "sketch"


def num_shards(mesh, axis: str = AXIS) -> int:
    """Shard count of ``axis`` in ``mesh`` (host-side int)."""
    return int(mesh.shape[axis])


def padded_k(k: int, mesh, axis: str = AXIS) -> int:
    """Round a tenant capacity up to a shard multiple (rows must divide)."""
    s = num_shards(mesh, axis)
    return ((k + s - 1) // s) * s


def check_divisible(k: int, mesh, axis: str = AXIS) -> None:
    """Raise unless K rows split evenly over the ``axis`` shard count."""
    s = num_shards(mesh, axis)
    if k % s:
        raise ValueError(
            f"K={k} rows must be divisible by the '{axis}' axis shard count "
            f"({s}); round up with sharding.padded_k"
        )


def spec(row_dim: int | None, axis: str = AXIS) -> P:
    """PartitionSpec sharding one named dimension: ``axis`` at ``row_dim``,
    everything else replicated. ``row_dim=None`` is a fully replicated leaf
    (ring scalars, directory telemetry)."""
    if row_dim is None:
        return P()
    return P(*((None,) * row_dim), axis)


def tree_specs(row_dims, axis: str = AXIS):
    """Map a pytree of row dims (int | None) to a pytree of PartitionSpecs.

    ``row_dims`` mirrors the state pytree: e.g. for a ``DynArrayState``
    pass ``DynArrayState(regs=0, hists=0, chats=0)``; for a
    ``WindowArrayState`` the epoch planes are 1 and the ring scalars None.
    ints are leaves here, so ``jax.tree.map`` cannot be used directly —
    this maps with ``is_leaf`` accepting None.
    """
    return jax.tree.map(
        lambda d: spec(d, axis), row_dims, is_leaf=lambda d: d is None
    )


def device_put_rows(tree, mesh, row_dims, axis: str = AXIS):
    """Reshard a pytree onto ``mesh`` row-sharded per ``row_dims`` (pure data
    movement, same values). The K dimension of every sharded leaf must
    divide the shard count. Leaf-wise: ``row_dims`` only has to match the
    tree's leaf order, not its container types (a DynArrayState can be
    placed with ShardedDynArrayState dims)."""
    leaves, treedef = jax.tree.flatten(tree)
    dims = jax.tree.leaves(row_dims, is_leaf=lambda d: d is None)
    if len(leaves) != len(dims):
        raise ValueError(
            f"row_dims has {len(dims)} leaves for a tree of {len(leaves)}"
        )
    out = []
    for leaf, d in zip(leaves, dims):
        if d is not None:
            check_divisible(leaf.shape[d], mesh, axis)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec(d, axis))))
    return jax.tree.unflatten(treedef, out)


def shard_map_rows(
    fn,
    mesh,
    in_dims,
    out_dims,
    axis: str = AXIS,
    check_rep: bool = True,
):
    """Wrap a shard-local ``fn`` over row-sharded pytrees.

    ``in_dims`` / ``out_dims`` are tuples (one entry per positional arg /
    output) of row-dim pytrees as in ``tree_specs``. The wrapped function
    receives each sharded leaf as a plain array of K/S rows and each
    replicated leaf whole, and must return outputs matching ``out_dims``.

    ``check_rep=False`` is needed whenever the local body contains a
    ``lax.while_loop`` (the Newton/MLE solvers have no replication rule on
    current JAX); everything these containers run locally is shard-local,
    so the check is vacuous there.
    """
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=tuple(tree_specs(d, axis) for d in in_dims),
        out_specs=tuple(tree_specs(d, axis) for d in out_dims)
        if isinstance(out_dims, tuple)
        else tree_specs(out_dims, axis),
        check_rep=check_rep,
    )


def own_slots(slots, rows: int, axis: str = AXIS, mask=None):
    """Hash-routed dispatch, called INSIDE a shard-local function.

    This shard owns the contiguous global slot range
    ``[axis_index * rows, (axis_index + 1) * rows)``. Returns
    ``(local_slots, own)`` where ``own`` masks the replicated batch down to
    the elements this shard owns (intersected with the caller's ``mask``)
    and ``local_slots = slots - lo`` rebases them to local row indices
    (clipped to [0, rows) so non-own elements stay safe gather/scatter
    no-ops under their dead mask).
    """
    lo = (jax.lax.axis_index(axis) * rows).astype(jnp.int32)
    own = (slots >= lo) & (slots < lo + rows)
    if mask is not None:
        own = own & mask
    return jnp.clip(slots - lo, 0, rows - 1), own


def check_same_shape(a, b, what: str) -> None:
    """Shared merge validation: two sharded states must agree on every leaf
    shape (same K/m/E geometry) or the row algebra is meaningless."""
    sa = [x.shape for x in jax.tree.leaves(a)]
    sb = [x.shape for x in jax.tree.leaves(b)]
    if sa != sb:
        raise ValueError(f"{what} merge needs matching shapes, got {sa} vs {sb}")
