"""repro: QSketch (KDD'24) as the streaming-telemetry layer of a multi-pod
JAX/Pallas LM framework. See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
