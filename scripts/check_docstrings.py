"""CI guard: every public symbol in the sketch library carries a docstring.

The library's contracts live in docstrings — shape/dtype conventions
(int8[K, m] registers, touched-register histograms, replicated ring
scalars), merge semantics (max monoid vs martingale additivity), and
padding/masking rules. A public function without one is an API the next
reader has to reverse-engineer, so tier-2 (scripts/test.sh --tier2) fails
the build instead.

Checked, via AST (no imports, so a broken module still reports precisely):
  * module docstrings,
  * public module-level functions and classes,
  * public methods of public classes (``__init__`` and other dunders are
    exempt — the class docstring owns construction; NamedTuple field
    declarations have no methods to check).

Scope: ``src/repro/core/``, ``src/repro/sketchstream/``, and
``src/repro/kernels/`` — the layers whose docstrings double as the design
record (DESIGN.md cites them; the kernel wrappers state the bit-identity
and interpret-mode contracts).

Usage:  python scripts/check_docstrings.py [path ...]
        (no args: checks the default scope)
"""

from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_SCOPE = (
    os.path.join(REPO, "src", "repro", "core"),
    os.path.join(REPO, "src", "repro", "sketchstream"),
    os.path.join(REPO, "src", "repro", "kernels"),
)


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def check_file(path: str) -> list[str]:
    """Return one error string per missing docstring in ``path``."""
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, REPO)
    errors = []
    if not ast.get_docstring(tree):
        errors.append(f"{rel}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name) and not ast.get_docstring(node):
                errors.append(f"{rel}:{node.lineno}: function '{node.name}' has no docstring")
        elif isinstance(node, ast.ClassDef) and _is_public(node.name):
            if not ast.get_docstring(node):
                errors.append(f"{rel}:{node.lineno}: class '{node.name}' has no docstring")
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if item.name.startswith("_"):  # dunders + private helpers
                    continue
                if not ast.get_docstring(item):
                    errors.append(
                        f"{rel}:{item.lineno}: method '{node.name}.{item.name}' has no docstring"
                    )
    return errors


def main(paths=None) -> int:
    """Walk the scope, report every missing docstring, exit nonzero on any."""
    if not paths:
        paths = []
        for root in DEFAULT_SCOPE:
            for dirpath, _, files in os.walk(root):
                paths += [
                    os.path.join(dirpath, f) for f in sorted(files) if f.endswith(".py")
                ]
    errors = []
    for path in paths:
        errors += check_file(path)
    if errors:
        print("check_docstrings: FAIL")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_docstrings: OK ({len(paths)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
