"""ShardedDynArray: per-tenant O(K)-anytime estimates past one host.

``core/dyn_array.py`` removes the per-query Newton cost with per-key §4.3
martingales, but its state — int8[K, m] registers, int32[K, 2^b] histograms,
f32[K] chats — still lives on one host. This module shards all three leaves
row-wise over a ``"sketch"`` mesh axis via the shared sharding layer
(``core/sharding.py``), the ROADMAP follow-on to PR 3: per-shard chats plus
``merge_disjoint`` make the sharding EXACT for key-partitioned streams.

Every operation stays shard-local, and every shard runs the single-host
container code verbatim on its K/S rows:

* **update_batch** — the replicated batch is hash-routed: each shard masks
  to the slots it owns (``sharding.own_slots``) and runs the same fused
  ``dyn_array._apply_update`` tail (dedup, batch-start q_R, scatter-max,
  incremental histogram moves, martingale accumulation). Registers,
  histograms AND chats are bit-identical to the single-host DynArray: the
  per-(key, id) dedup groups and the per-key q_R rows are untouched by the
  restriction to owned slots, and non-owned elements contribute exact +0.0
  no-ops to the chat scatter-add (tests/test_sharded_dyn_array.py).
* **estimate_all** — a pure O(K) read of the sharded chats; nothing moves.
* **merge** (possibly-overlapping streams) — register max + shard-local
  histogram rebuild + shard-local per-key MLE re-estimate, mirroring
  ``dyn_array.merge`` row for row.
* **merge_disjoint** (key-partitioned fleets) — chats ADD exactly (the
  per-key martingales telescope across element-disjoint sub-streams,
  DESIGN.md §8.4); overlapping partitions are rejected eagerly when the
  states are concrete (a row live in both fleets means the partition
  contract is broken).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dyn_array, estimation, hashing, key_directory, qsketch_dyn, sharding
from .types import DynArrayState, ShardedDynArrayState, SketchConfig

AXIS = sharding.AXIS

# Shared-layer geometry helpers, re-exported like sharded_array's.
num_shards = sharding.num_shards
padded_k = sharding.padded_k

# Row-dim pytree: every leaf carries K at dim 0.
DIMS = ShardedDynArrayState(regs=0, hists=0, chats=0)


def init(cfg: SketchConfig, k: int, mesh, axis: str = AXIS) -> ShardedDynArrayState:
    """K fresh Dyn sketches, all three leaves row-sharded over ``axis``."""
    sharding.check_divisible(k, mesh, axis)
    return ShardedDynArrayState(
        *sharding.device_put_rows(dyn_array.init(cfg, k), mesh, DIMS, axis)
    )


def from_array(state: DynArrayState, mesh, axis: str = AXIS) -> ShardedDynArrayState:
    """Reshard a single-host DynArray (pure data movement, same values)."""
    return ShardedDynArrayState(
        *sharding.device_put_rows(state, mesh, DIMS, axis)
    )


def to_array(state: ShardedDynArrayState) -> DynArrayState:
    """Gather back to the single-host form (tests / row extraction)."""
    return DynArrayState(*jax.device_get(tuple(state)))


def num_sketches(state: ShardedDynArrayState) -> int:
    """Total tenant capacity K across all shards."""
    return state.regs.shape[0]


def _update_impl(cfg: SketchConfig, mesh, axis: str, state, keys, lo, hi, w, mask):
    rows = state.regs.shape[0] // sharding.num_shards(mesh, axis)

    def local(st, keys, lo, hi, w, m):
        local_keys, own = sharding.own_slots(keys, rows, axis, m)
        live = qsketch_dyn._live_weight_mask(w, own)
        # Per-element q_R against the element's key's batch-start histogram
        # row — gathered from THIS shard's rows; identical bits to the
        # single-host gather for every owned element (non-owned elements are
        # dead and their q is never consumed).
        q = qsketch_dyn._q_update_prob(cfg, st.hists[local_keys], w)
        return tuple(
            dyn_array._apply_update(cfg, st, local_keys, lo, hi, w, live, q)
        )

    return ShardedDynArrayState(
        *sharding.shard_map_rows(
            local,
            mesh,
            in_dims=(DynArrayState(0, 0, 0), None, None, None, None, None),
            out_dims=(0, 0, 0),
            axis=axis,
        )(DynArrayState(*state), keys, lo, hi, w, mask)
    )


_update = jax.jit(_update_impl, static_argnums=(0, 1, 2))
_update_donated = jax.jit(
    _update_impl, static_argnums=(0, 1, 2), donate_argnums=(3,)
)


def update_batch(
    cfg: SketchConfig, mesh, state: ShardedDynArrayState, keys, ids, weights,
    mask=None, axis: str = AXIS, *, donate: bool = False,
) -> ShardedDynArrayState:
    """One fused keyed batch, hash-routed; bit-identical to the single-host
    ``dyn_array.update_batch`` on every state leaf (chats included).

    Same contract: ``keys`` are dense row indices in [0, K) (clipped),
    masked / degenerate-weight rows are dropped before dedup. Each element
    updates exactly the shard owning its row; no collective runs.
    ``donate=True`` donates the sharded state leaves for in-place buffer
    reuse (sharding is unchanged row-in/row-out, so aliasing is legal); the
    caller's ``state`` is dead afterwards — the steady-state ingest mode.
    """
    sharding.check_divisible(state.regs.shape[0], mesh, axis)
    k = state.regs.shape[0]
    lo, hi = hashing.split_id64(ids)
    w = weights.astype(jnp.float32)
    keys = jnp.clip(keys.astype(jnp.int32), 0, k - 1)
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    fn = _update_donated if donate else _update
    return fn(cfg, mesh, axis, state, keys, lo, hi, w, mask)


def estimate_all(state: ShardedDynArrayState) -> jnp.ndarray:
    """Ĉ for every sketch: the O(K)-anytime read of the sharded martingales
    (still sharded — callers sum/slice in place or ``device_get`` a view)."""
    return state.chats


@functools.partial(jax.jit, static_argnums=(0, 1, 2), static_argnames=("solver",))
def _estimate_mle(cfg: SketchConfig, mesh, axis: str, regs, hists, *, solver: str = "newton"):
    def local(regs_l, hists_l):
        if solver == "lut":
            full = hists_l.at[:, 0].set(cfg.m - jnp.sum(hists_l, axis=1))
            return estimation.estimate_hists(cfg, full, kind="routed", solver="lut")
        return dyn_array.estimate_mle_rows(cfg, regs_l, solver=solver)

    # check_rep=False on the newton path only: the MLE Newton is a
    # lax.while_loop (no replication rule); the solve is shard-local so the
    # check is vacuous. The lut solver is while_loop-free and reads the
    # maintained histograms — replication check stays on.
    return sharding.shard_map_rows(
        local, mesh, in_dims=(0, 0), out_dims=0, axis=axis,
        check_rep=(solver == "lut"),
    )(regs, hists)


def estimate_mle_all(
    cfg: SketchConfig, mesh, state: ShardedDynArrayState, axis: str = AXIS,
    *, solver: str = "newton",
) -> jnp.ndarray:
    """Per-key histogram-MLE re-estimate, Ĉ[K]; shard-local solve (the
    O(K·2^b) cost divides by the shard count). Use after cross-fleet
    ``merge`` or as a self-check — the hot path reads ``estimate_all``.
    ``solver="lut"`` reads each shard's maintained histograms (no register
    walk, no while_loop; the lut grid is per-row so the answer is batch-
    independent mathematically, but the per-shard GEMM tiles differently
    than the single-host call's, so agreement is at f32 rounding — within
    the documented tolerance — not bitwise)."""
    return _estimate_mle(cfg, mesh, axis, state.regs, state.hists, solver=solver)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _merge(cfg: SketchConfig, mesh, axis: str, a, b):
    def local(a_l, b_l):
        return tuple(dyn_array.merge(cfg, a_l, b_l))

    return ShardedDynArrayState(
        *sharding.shard_map_rows(
            local,
            mesh,
            in_dims=(DynArrayState(0, 0, 0), DynArrayState(0, 0, 0)),
            out_dims=(0, 0, 0),
            axis=axis,
            check_rep=False,  # MLE while_loop inside
        )(DynArrayState(*a), DynArrayState(*b))
    )


def merge(cfg: SketchConfig, mesh, a: ShardedDynArrayState, b: ShardedDynArrayState, axis: str = AXIS) -> ShardedDynArrayState:
    """Merge two sharded fleets sketching possibly-OVERLAPPING sub-streams:
    register max (exact union), shard-local histogram rebuild, shard-local
    per-key MLE re-estimated chats — ``dyn_array.merge`` row for row
    (running martingales are not additive across overlapping streams,
    DESIGN.md §8.4)."""
    sharding.check_same_shape(a, b, "ShardedDynArray")
    return _merge(cfg, mesh, axis, a, b)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _merge_disjoint(cfg: SketchConfig, mesh, axis: str, a, b):
    def local(a_l, b_l):
        return tuple(dyn_array.merge_disjoint(cfg, a_l, b_l))

    return ShardedDynArrayState(
        *sharding.shard_map_rows(
            local,
            mesh,
            in_dims=(DynArrayState(0, 0, 0), DynArrayState(0, 0, 0)),
            out_dims=(0, 0, 0),
            axis=axis,
        )(DynArrayState(*a), DynArrayState(*b))
    )


def merge_disjoint(
    cfg: SketchConfig, mesh, a: ShardedDynArrayState, b: ShardedDynArrayState,
    axis: str = AXIS, check_partition: bool = True,
) -> ShardedDynArrayState:
    """Merge fleets whose streams are KEY-partitioned: chats ADD exactly.

    The production sharding contract (DESIGN.md §8.4): a tenant's stream
    lands on exactly one fleet, so per-key martingales telescope across
    fleets — Ĉ_merged = Ĉ_a + Ĉ_b with no MLE. Registers max-merge and
    histograms rebuild shard-locally. Overlapping partitions (a key row
    live in BOTH fleets) are rejected eagerly by default — this is the
    production fleet merge, so the strict contract is on unless the caller
    explicitly owns an element-disjoint-but-key-shared invariant
    (``check_partition=False``).
    """
    sharding.check_same_shape(a, b, "ShardedDynArray")
    if check_partition:
        dyn_array.check_disjoint_rows(a, b)
    return _merge_disjoint(cfg, mesh, axis, a, b)


def update_tenants(
    cfg: SketchConfig,
    dcfg: key_directory.DirectoryConfig,
    mesh,
    state: ShardedDynArrayState,
    dir_state: key_directory.DirectoryState,
    tenant_keys,
    ids,
    weights,
    mask=None,
    axis: str = AXIS,
):
    """Sparse-tenant entry: route 64-bit tenant ids through the (replicated)
    key directory, then run the hash-routed fused update. Returns
    (sharded state, directory telemetry) — the same production contract as
    ``sharded_array.update_tenants``."""
    if dcfg.capacity != state.regs.shape[0]:
        raise ValueError(
            f"directory capacity {dcfg.capacity} != sharded DynArray rows "
            f"{state.regs.shape[0]}"
        )
    slots, dir_state = key_directory.route(dcfg, dir_state, tenant_keys, mask=mask)
    return (
        update_batch(cfg, mesh, state, slots, ids, weights, mask=mask, axis=axis),
        dir_state,
    )
