"""The jit'd serving steps: batched prefill and single-token decode.

decode applies greedy/temperature sampling and updates the weighted-DAU
sketch (element = session id, weight = per-session engagement weight — the
paper's own motivating metric) in the same jit: telemetry costs one
scatter-max per step and merges across pods by max.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SketchConfig
from repro.models import transformer
from repro.sketchstream import monitor


def make_prefill(mcfg, mesh=None, *, max_len: int):
    def prefill_step(params, tokens, extra_embeds=None):
        last_logits, cache = transformer.prefill(
            params, tokens, mcfg, mesh, max_len=max_len, extra_embeds=extra_embeds
        )
        return last_logits, cache

    return prefill_step


def make_decode_step(
    mcfg,
    mesh=None,
    *,
    sketch_cfg: SketchConfig | None = None,
    tenant_monitor: monitor.ShardedArrayMonitor | monitor.DynArrayMonitor | monitor.WindowMonitor | monitor.ShardedDynMonitor | monitor.ShardedWindowMonitor | None = None,
    temperature: float = 0.0,
):
    """With ``tenant_monitor`` set, ``sk_state`` is a ``TelemetryState`` and
    ``tenant_ids`` (sparse 64-bit org/customer ids, one per decode slot) route
    each session into its tenant's sketch — per-tenant weighted DAU next to
    the global one. A ``ShardedArrayMonitor`` shards registers over the
    monitor's mesh axis; a ``DynArrayMonitor`` instead keeps per-tenant
    martingales so the serving loop can read every tenant's DAU weight O(1)
    per key, every step; a ``WindowMonitor`` scopes those reads to the last
    w epochs (the serving loop owns the epoch clock via ``monitor.rotate``),
    which is what per-tenant anomaly alerting consumes."""

    def decode_one(params, cache, cur_len, tokens, sk_state=None, session_ids=None, session_weights=None, rng=None, session_mask=None, tenant_ids=None):
        logits, cache = transformer.decode_step(params, cache, cur_len, tokens, mcfg, mesh)
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        next_tok = next_tok.astype(jnp.int32)[:, None]

        # sk_state=None (telemetry off for this call) stays valid even when
        # the step was built with a tenant monitor.
        telemetry_on = tenant_monitor is not None and sk_state is not None
        scalar_state, tenant_state = (
            (sk_state.scalar, sk_state.tenants) if telemetry_on else (sk_state, {})
        )

        if sketch_cfg is not None and session_ids is not None:
            # session_mask drops empty decode slots (batch padding): they
            # neither pollute the DAU sketch nor inflate its n_seen counter.
            scalar_state = monitor.update(
                sketch_cfg, scalar_state, session_ids, session_weights, mask=session_mask
            )

        if telemetry_on and tenant_ids is not None and session_ids is not None:
            # Per-tenant DAU: element = session id, weight = engagement,
            # key = the session's tenant (routed through the key directory).
            tenant_state = tenant_monitor.update(
                tenant_state, tenant_ids, session_ids, session_weights, mask=session_mask
            )

        sk_state = (
            monitor.TelemetryState(scalar=scalar_state, tenants=tenant_state)
            if telemetry_on
            else scalar_state
        )
        return next_tok, cache, sk_state

    return decode_one
