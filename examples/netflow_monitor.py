"""Per-flow anytime network monitoring (paper App. A.4, scaled out): a
SketchArray tracks the distinct-flow traffic volume of EVERY monitored host
simultaneously.

This is the production shape of the paper's anomaly-detection scenario: not
one global cardinality but one per destination host (or user, per Wang et
al. in PAPERS.md). Each packet is a (dst key, src flow id, bytes) triple;
key k's weighted cardinality = total bytes across the distinct flows that
hit host k. A volumetric attack on one host — thousands of brand-new flows —
shows up as a jump in that host's estimate while the others stay flat,
which a single global sketch would smear out.

One fused segment scatter-max folds each packet batch into all K sketches
(core/sketch_array.py; the Pallas kernel path on TPU), and one vmapped
histogram-MLE yields all K estimates after every batch — anytime, O(K·2^b).

    PYTHONPATH=src python examples/netflow_monitor.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, sketch_array
from repro.data import synthetic


def main():
    cfg = SketchConfig(m=256, b=8, seed=11)
    n_keys, n_flows, n_packets = 64, 20_000, 200_000
    keys, ids, sizes, true_c = synthetic.netflow_keyed(n_keys, n_flows, n_packets, seed=2)

    # DDoS at 60% of the stream: host 17 suddenly receives 5000 new flows.
    victim = 17
    attack_at = int(n_packets * 0.6)
    atk_ids, atk_sizes, atk_total = synthetic.netflow(5_000, 25_000, seed=99)
    atk_keys = np.full(len(atk_ids), victim, dtype=np.int32)
    keys = np.concatenate([keys[:attack_at], atk_keys, keys[attack_at:]])
    ids = np.concatenate([ids[:attack_at], atk_ids, ids[attack_at:]])
    sizes = np.concatenate([sizes[:attack_at], atk_sizes, sizes[attack_at:]])

    st = sketch_array.init(cfg, n_keys)
    bs = 8192
    prev = np.zeros(n_keys)
    print(f"{'packets':>9} {'median host est.':>17} {'victim est.':>12}  flagged hosts")
    for i in range(0, len(ids), bs):
        st = sketch_array.update(
            cfg,
            st,
            jnp.asarray(keys[i : i + bs]),
            jnp.asarray(ids[i : i + bs]),
            jnp.asarray(sizes[i : i + bs]),
        )
        est = np.asarray(sketch_array.estimate_all(cfg, st))
        delta = est - prev
        # Flag hosts whose single-batch growth is large relative to their OWN
        # history (new-distinct-flow surge), not just to the fleet median —
        # Zipf-heavy hosts legitimately grow faster than the median forever.
        warm = i >= 4 * bs
        flagged = np.nonzero(warm & (delta > 0.5 * np.maximum(prev, 1.0)))[0]
        tag = f"  <-- surge on hosts {[int(f) for f in flagged]}" if len(flagged) else ""
        if (i // bs) % 4 == 0 or tag:
            print(f"{i + bs:>9} {np.median(est):>17,.0f} {est[victim]:>12,.0f}{tag}")
        prev = est

    est = np.asarray(sketch_array.estimate_all(cfg, st))
    quiet = (true_c > 0) & (np.arange(n_keys) != victim)
    err = np.abs(est[quiet] - true_c[quiet]) / true_c[quiet]
    print(f"\nvictim estimate:  {est[victim]:,.0f}")
    print(f"victim true:      {true_c[victim] + atk_total:,.0f}")
    print(f"median rel. err over {int(quiet.sum())} quiet hosts: {np.median(err):.2%}")
    print(
        f"sketch memory:    {n_keys} hosts x {cfg.m * cfg.b // 8} B = "
        f"{n_keys * cfg.m * cfg.b // 8 / 1024:.0f} KiB total"
    )


if __name__ == "__main__":
    main()
