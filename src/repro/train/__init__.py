"""Training/serving substrate: optimizer, checkpoint, compression, steps."""

from . import checkpoint, compression, elastic, optimizer, serve_step, train_step
from .optimizer import OptConfig

__all__ = [
    "OptConfig",
    "optimizer",
    "checkpoint",
    "compression",
    "elastic",
    "train_step",
    "serve_step",
]
