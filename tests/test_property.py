"""Hypothesis property tests on the sketch algebra's invariants.

``hypothesis`` is an optional test extra (requirements-test.txt); without it
the suite runs under ``tests/_minihyp.py`` — a deterministic seeded-replay
shim of the same API — instead of skipping. Example counts come from the
``quick``/``deep`` profiles registered in ``conftest.py``
(``HYPOTHESIS_PROFILE``; tier-1 runs quick, ``scripts/test.sh --tier2``
re-runs this module and ``test_differential.py`` under deep).

Invariants covered, per DESIGN.md §8.9's testing policy:
  * merge commutativity / associativity / idempotence — scalar QSketch AND
    the keyed containers (SketchArray / DynArray / WindowArray) plus their
    sharded twins and the virtual tier's pool plane;
  * update-order invariance of every register/histogram plane;
  * mask/dedup equivalence against the element-log oracles
    (``*.update_reference``);
  * statistical accuracy envelope of the VirtualDynArray noise-cancelled
    read (exactness of ``w_tail``, boundedness of the cancelled estimate).
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _minihyp import given, settings, strategies as st

from repro.core import (
    SketchConfig,
    baselines,
    dyn_array,
    qsketch,
    qsketch_dyn,
    sharded_dyn_array,
    sharding,
    sketch_array,
    virtual_dyn_array as vda,
    window_array,
)
from repro.core.virtual_dyn_array import VirtualConfig
from repro.launch.mesh import make_sketch_mesh

_CFG = SketchConfig(m=64, b=8, seed=99)

ids_strategy = st.lists(
    st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=60
)
w_strategy = st.floats(
    min_value=1e-6, max_value=1e6, allow_nan=False, allow_infinity=False
)


def _arrs(ids, ws):
    n = len(ids)
    ws = (ws * ((n // len(ws)) + 1))[:n]
    return (
        jnp.asarray(np.asarray(ids, dtype=np.uint32)),
        jnp.asarray(np.asarray(ws, dtype=np.float32)),
    )


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_merge_commutative_associative_idempotent(ids, ws):
    i, w = _arrs(ids, ws)
    half = max(1, len(ids) // 2)
    a = qsketch.update(_CFG, qsketch.init(_CFG), i[:half], w[:half])
    b = qsketch.update(_CFG, qsketch.init(_CFG), i[half:], w[half:]) if len(ids) > half else a
    ab = qsketch.merge(a, b)
    ba = qsketch.merge(b, a)
    np.testing.assert_array_equal(np.asarray(ab.regs), np.asarray(ba.regs))
    # idempotent
    aa = qsketch.merge(a, a)
    np.testing.assert_array_equal(np.asarray(aa.regs), np.asarray(a.regs))
    # associative with a third part
    c = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    l = qsketch.merge(qsketch.merge(a, b), c)
    r = qsketch.merge(a, qsketch.merge(b, c))
    np.testing.assert_array_equal(np.asarray(l.regs), np.asarray(r.regs))


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_update_monotone_and_bounded(ids, ws):
    i, w = _arrs(ids, ws)
    st0 = qsketch.init(_CFG)
    st1 = qsketch.update(_CFG, st0, i, w)
    r0 = np.asarray(st0.regs, np.int32)
    r1 = np.asarray(st1.regs, np.int32)
    assert (r1 >= r0).all()
    assert (r1 >= _CFG.r_min).all() and (r1 <= _CFG.r_max).all()


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_estimate_nonnegative_finite(ids, ws):
    i, w = _arrs(ids, ws)
    s = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    est = float(qsketch.estimate(_CFG, s))
    assert est >= 0.0
    assert np.isfinite(est)


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_batch_split_equivalence(ids, ws):
    i, w = _arrs(ids, ws)
    whole = qsketch.update(_CFG, qsketch.init(_CFG), i, w)
    k = max(1, len(ids) // 3)
    parts = qsketch.init(_CFG)
    for s0 in range(0, len(ids), k):
        parts = qsketch.update(_CFG, parts, i[s0 : s0 + k], w[s0 : s0 + k])
    np.testing.assert_array_equal(np.asarray(whole.regs), np.asarray(parts.regs))


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_dyn_duplicate_stability(ids, ws):
    i, w = _arrs(ids, ws)
    d1 = qsketch_dyn.update_scan(_CFG, qsketch_dyn.init(_CFG), i, w)
    d2 = qsketch_dyn.update_scan(_CFG, d1, i, w)
    assert float(d1.chat) == float(d2.chat)
    np.testing.assert_array_equal(np.asarray(d1.regs), np.asarray(d2.regs))
    # Histogram counts never exceed m and stay non-negative.
    h = np.asarray(d2.hist)
    assert (h >= 0).all() and h.sum() <= _CFG.m


@settings(deadline=None)
@given(ids=ids_strategy, ws=st.lists(w_strategy, min_size=1, max_size=10))
def test_float_sketch_monotone_decreasing(ids, ws):
    i, w = _arrs(ids, ws)
    s0 = baselines.init(_CFG)
    s1 = baselines.lm_update(_CFG, s0, i, w)
    assert (np.asarray(s1.regs) <= np.asarray(s0.regs)).all()
    assert (np.asarray(s1.regs) > 0).all()


# ---------------------------------------------------------------------------
# Keyed containers: merge algebra, order invariance, mask/dedup vs oracle
# ---------------------------------------------------------------------------

# Generated batches pad to ONE fixed shape so each container compiles once
# per test function instead of once per example.
_B = 32
_K = 4
_ACFG = SketchConfig(m=32, b=6, seed=7)

keyed_strategy = {
    "ids": st.lists(
        st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=_B
    ),
    "keys": st.lists(st.integers(min_value=0, max_value=_K - 1), min_size=1, max_size=8),
    "ws": st.lists(w_strategy, min_size=1, max_size=8),
}


def _keyed_batch(ids, keys, ws):
    """Pad a generated keyed stream to the fixed (B,) shape + live mask."""
    n = len(ids)
    keys = (keys * ((n // len(keys)) + 1))[:n]
    ws = (ws * ((n // len(ws)) + 1))[:n]
    k = np.zeros(_B, np.int32)
    i = np.zeros(_B, np.uint32)
    w = np.ones(_B, np.float32)
    mask = np.zeros(_B, bool)
    k[:n], i[:n], w[:n], mask[:n] = keys, np.asarray(ids, np.uint32), ws, True
    return jnp.asarray(k), jnp.asarray(i), jnp.asarray(w), jnp.asarray(mask)


_CONTAINERS = {
    "sketch_array": dict(
        init=lambda: sketch_array.init(_ACFG, _K),
        update=lambda s, k, i, w, m: sketch_array.update(_ACFG, s, k, i, w, mask=m),
        merge=lambda a, b: sketch_array.merge(a, b),
        regs=lambda s: s.regs,
        hists=lambda s: None,
        oracle=lambda s, k, i, w, m: sketch_array.update_reference(
            _ACFG, s, k, i, w, mask=m
        ),
    ),
    "dyn_array": dict(
        init=lambda: dyn_array.init(_ACFG, _K),
        update=lambda s, k, i, w, m: dyn_array.update_batch(_ACFG, s, k, i, w, mask=m),
        merge=lambda a, b: dyn_array.merge(_ACFG, a, b),
        regs=lambda s: s.regs,
        hists=lambda s: s.hists,
        oracle=lambda s, k, i, w, m: dyn_array.update_reference(
            _ACFG, s, k, i, w, mask=np.asarray(m)
        ),
    ),
    "window_array": dict(
        init=lambda: window_array.init(_ACFG, _K, 3),
        update=lambda s, k, i, w, m: window_array.update_batch(
            _ACFG, s, k, i, w, mask=m
        ),
        merge=lambda a, b: window_array.merge(_ACFG, a, b),
        regs=lambda s: s.union_regs,
        hists=lambda s: s.union_hists,
        oracle=None,
    ),
}


@pytest.mark.parametrize("container", sorted(_CONTAINERS))
@settings(deadline=None)
@given(**keyed_strategy)
def test_keyed_merge_commutative_associative_idempotent(container, ids, keys, ws):
    c = _CONTAINERS[container]
    k, i, w, mask = _keyed_batch(ids, keys, ws)
    third = _B // 3
    m_a = mask & (jnp.arange(_B) < third)
    m_b = mask & (jnp.arange(_B) >= third) & (jnp.arange(_B) < 2 * third)
    m_c = mask & (jnp.arange(_B) >= 2 * third)
    a = c["update"](c["init"](), k, i, w, m_a)
    b = c["update"](c["init"](), k, i, w, m_b)
    cc = c["update"](c["init"](), k, i, w, m_c)
    ab, ba = c["merge"](a, b), c["merge"](b, a)
    np.testing.assert_array_equal(np.asarray(c["regs"](ab)), np.asarray(c["regs"](ba)))
    if c["hists"](ab) is not None:
        np.testing.assert_array_equal(
            np.asarray(c["hists"](ab)), np.asarray(c["hists"](ba))
        )
    aa = c["merge"](a, a)
    np.testing.assert_array_equal(np.asarray(c["regs"](aa)), np.asarray(c["regs"](a)))
    left = c["merge"](c["merge"](a, b), cc)
    right = c["merge"](a, c["merge"](b, cc))
    np.testing.assert_array_equal(
        np.asarray(c["regs"](left)), np.asarray(c["regs"](right))
    )
    # Merge of the split == one pass over the whole stream (register plane).
    whole = c["update"](c["init"](), k, i, w, mask)
    np.testing.assert_array_equal(
        np.asarray(c["regs"](left)), np.asarray(c["regs"](whole))
    )


@pytest.mark.parametrize("container", sorted(_CONTAINERS))
@settings(deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), **keyed_strategy)
def test_keyed_update_order_invariance(container, seed, ids, keys, ws):
    """Register and histogram planes are order-free (max monoid); only the
    martingale scalars depend on arrival order."""
    c = _CONTAINERS[container]
    k, i, w, mask = _keyed_batch(ids, keys, ws)
    perm = jnp.asarray(np.random.default_rng(seed).permutation(_B))
    fwd = c["update"](c["init"](), k, i, w, mask)
    shuf = c["update"](c["init"](), k[perm], i[perm], w[perm], mask[perm])
    np.testing.assert_array_equal(
        np.asarray(c["regs"](fwd)), np.asarray(c["regs"](shuf))
    )
    if c["hists"](fwd) is not None:
        np.testing.assert_array_equal(
            np.asarray(c["hists"](fwd)), np.asarray(c["hists"](shuf))
        )


@pytest.mark.parametrize("container", ["sketch_array", "dyn_array"])
@settings(deadline=None)
@given(**keyed_strategy)
def test_keyed_mask_and_dedup_match_element_log_oracle(container, ids, keys, ws):
    """Masked padding rows are no-ops and re-sent duplicates are absorbed,
    exactly as the element-log oracle (``update_reference``) says."""
    c = _CONTAINERS[container]
    k, i, w, mask = _keyed_batch(ids, keys, ws)
    st_pad = c["update"](c["init"](), k, i, w, mask)
    ref = c["oracle"](c["init"](), k, i, w, mask)
    np.testing.assert_array_equal(
        np.asarray(c["regs"](st_pad)), np.asarray(c["regs"](ref))
    )
    if c["hists"](st_pad) is not None:
        np.testing.assert_array_equal(
            np.asarray(c["hists"](st_pad)), np.asarray(c["hists"](ref))
        )
    # Dedup: re-sending the identical batch cannot move the register plane.
    st_dup = c["update"](st_pad, k, i, w, mask)
    np.testing.assert_array_equal(
        np.asarray(c["regs"](st_dup)), np.asarray(c["regs"](st_pad))
    )


@settings(deadline=None)
@given(**keyed_strategy)
def test_sharded_dyn_twin_matches_dense(ids, keys, ws):
    """The sharded twin is bit-identical to the dense DynArray on every leaf,
    and its merge commutes — the property-random companion to the fixed
    cases in test_sharded_dyn_array.py."""
    mesh = make_sketch_mesh()
    kk = sharding.padded_k(_K, mesh)
    k, i, w, mask = _keyed_batch(ids, keys, ws)
    dense = dyn_array.update_batch(_ACFG, dyn_array.init(_ACFG, kk), k, i, w, mask=mask)
    sh = sharded_dyn_array.update_batch(
        _ACFG, mesh, sharded_dyn_array.init(_ACFG, kk, mesh), k, i, w, mask=mask
    )
    back = sharded_dyn_array.to_array(sh)
    np.testing.assert_array_equal(np.asarray(back.regs), np.asarray(dense.regs))
    np.testing.assert_array_equal(np.asarray(back.hists), np.asarray(dense.hists))
    np.testing.assert_array_equal(np.asarray(back.chats), np.asarray(dense.chats))
    ab = sharded_dyn_array.merge(_ACFG, mesh, sh, sh)
    np.testing.assert_array_equal(
        np.asarray(sharded_dyn_array.to_array(ab).regs), np.asarray(back.regs)
    )


# ---------------------------------------------------------------------------
# Virtual tier: pool-plane algebra + noise-cancellation accuracy envelope
# ---------------------------------------------------------------------------

_VCFG = SketchConfig(m=64, b=8, seed=5)
_VVCFG = VirtualConfig(pool_size=4096)


def _virtual_stream(ws, n_noise):
    """One focal tenant with |ws| distinct elements + n_noise unit-weight
    elements spread over 8 background tenants, padded to a fixed shape."""
    cap = _B + 64
    n = len(ws)
    tenant = np.uint64(0xDEADBEEFCAFE)
    noise_tenants = (np.arange(8, dtype=np.uint64) + 1) * np.uint64(0x9E3779B97F4A7C15)
    tk = np.concatenate([
        np.full(n, tenant, np.uint64),
        noise_tenants[np.arange(n_noise) % 8],
        np.zeros(cap - n - n_noise, np.uint64),
    ])
    ids = (np.arange(cap, dtype=np.uint64) + 1) * np.uint64(2654435761)
    w = np.concatenate([
        np.asarray(ws, np.float32),
        np.ones(cap - n, np.float32),
    ])
    mask = np.arange(cap) < (n + n_noise)
    t = (jnp.asarray(tk & 0xFFFFFFFF, jnp.uint32), jnp.asarray(tk >> 32, jnp.uint32))
    i = (jnp.asarray(ids & 0xFFFFFFFF, jnp.uint32), jnp.asarray(ids >> 32, jnp.uint32))
    return tenant, t, i, jnp.asarray(w), jnp.asarray(mask)


@settings(deadline=None)
@given(
    ws=st.lists(
        st.floats(min_value=0.25, max_value=4.0, allow_nan=False,
                  allow_infinity=False),
        min_size=16, max_size=_B,
    ),
    n_noise=st.integers(min_value=0, max_value=64),
)
def test_virtual_noise_cancellation_envelope(ws, n_noise):
    """w_tail is exact; the noise-cancelled read of the focal tenant stays
    inside a wide statistical envelope around its true weight (the tight
    mean-error claim is the fixed-seed test in test_virtual_dyn_array.py)."""
    tenant, t, i, w, mask = _virtual_stream(ws, n_noise)
    st_v = vda.update_tenants(
        _VCFG, _VVCFG, vda.init(_VCFG, _VVCFG), t, i, w, mask=mask
    )
    total = float(np.sum(np.asarray(w)[np.asarray(mask)]))
    assert float(st_v.w_tail) == pytest.approx(total, rel=1e-4)
    truth = float(np.sum(np.asarray(ws, np.float32)))
    floor = float(vda.noise_floor(_VCFG, _VVCFG, st_v))
    est = float(
        vda.estimate_tenants(
            _VCFG, _VVCFG, st_v,
            (t[0][:1], t[1][:1]),
        )[0]
    )
    assert np.isfinite(est) and est >= 0.0
    # ~5-sigma envelope at m=64 (row-solve std ≈ 0.15, plus the clamped
    # calibration and the subtracted noise floor).
    assert est <= 3.0 * truth + 5.0 * floor
    assert est >= truth / 4.0 - 3.0 * floor


@settings(deadline=None)
@given(
    ws=st.lists(
        st.floats(min_value=0.25, max_value=4.0, allow_nan=False,
                  allow_infinity=False),
        min_size=8, max_size=_B,
    ),
    n_noise=st.integers(min_value=0, max_value=64),
)
def test_virtual_merge_commutative_idempotent_pool(ws, n_noise):
    """The pool plane keeps the max-monoid algebra; the weight scalars add
    (commutative; self-merge doubles them — the documented convention)."""
    tenant, t, i, w, mask = _virtual_stream(ws, n_noise)
    half = jnp.arange(mask.shape[0]) < (mask.shape[0] // 2)
    a = vda.update_tenants(
        _VCFG, _VVCFG, vda.init(_VCFG, _VVCFG), t, i, w, mask=mask & half
    )
    b = vda.update_tenants(
        _VCFG, _VVCFG, vda.init(_VCFG, _VVCFG), t, i, w, mask=mask & ~half
    )
    ab, ba = vda.merge(_VCFG, _VVCFG, a, b), vda.merge(_VCFG, _VVCFG, b, a)
    np.testing.assert_array_equal(np.asarray(ab.pool), np.asarray(ba.pool))
    np.testing.assert_array_equal(np.asarray(ab.pool_hist), np.asarray(ba.pool_hist))
    assert float(ab.w_tail) == float(ba.w_tail)
    whole = vda.update_tenants(
        _VCFG, _VVCFG, vda.init(_VCFG, _VVCFG), t, i, w, mask=mask
    )
    np.testing.assert_array_equal(np.asarray(ab.pool), np.asarray(whole.pool))
    aa = vda.merge(_VCFG, _VVCFG, a, a)
    np.testing.assert_array_equal(np.asarray(aa.pool), np.asarray(a.pool))
