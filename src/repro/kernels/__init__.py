"""Pallas TPU kernels for the sketch hot paths.

Four kernels (each with a pure-jnp oracle in ref.py or core/, and a jit'd
public wrapper in ops.py):

* qsketch_update      — batched QSketch register update (max semantics, int).
* float_sketch        — LM/FastGM-family update (min semantics, float32).
* qdyn_qr             — QSketch-Dyn batch update-probability q_R.
* sketch_array_update — keyed multi-sketch (SketchArray) update: batch rows
                        routed to K register rows resident in VMEM.
* dyn_array_update    — keyed q_R over gathered per-tenant histogram rows
                        (the DynArray update's dense inner stage).
* window_union        — fused epoch-union + per-row bincount for the
                        sliding-window read (no [w, K, m] intermediate).

On this CPU container the kernels run in interpret mode (the kernel body
executes in Python); on TPU the identical code lowers through Mosaic. ops.py
auto-selects based on the backend.
"""

from . import (
    dyn_array_update,
    estimate,
    ops,
    qdyn_qr,
    qsketch_update,
    ref,
    sketch_array_update,
    window_union,
)

__all__ = [
    "ops",
    "estimate",
    "ref",
    "qsketch_update",
    "qdyn_qr",
    "sketch_array_update",
    "dyn_array_update",
    "window_union",
]
