"""Sharded WindowArray tests.

Acceptance: every ring/union leaf and every windowed read is bit-identical
to the single-host WindowArray driven with the same batches and rotation
schedule on the 8-device host mesh — including across rotation boundaries
(eviction), the fused union kernel op, ring-aligned merges, and the
misaligned-ring-head rejection (for both the sharded and the single-host
merge — the previously-untested edge case).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    key_directory,
    sharded_window_array,
    sharding,
    window_array,
)
from repro.core.key_directory import DirectoryConfig
from repro.kernels import ops
from repro.launch.mesh import make_sketch_mesh
from repro.sketchstream import monitor


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()  # 8 shards under scripts/test.sh


def _stream(n, k, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, k, n, dtype=np.int32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray((rng.gamma(1.0, 2.0, n) + 1e-5).astype(np.float32))
    return keys, ids, w


def _drive_pair(cfg, k, e, mesh, n_epochs, batches_per_epoch=2, batch=500, seed=0):
    """Drive a sharded state and the single-host reference with identical
    batches and rotations; returns (sharded, reference)."""
    sh = sharded_window_array.init(cfg, k, e, mesh)
    ref = window_array.init(cfg, k, e)
    for ep in range(n_epochs):
        for i in range(batches_per_epoch):
            keys, ids, w = _stream(batch, k, seed=seed + 31 * ep + i)
            sh = sharded_window_array.update_batch(cfg, mesh, sh, keys, ids, w)
            ref = window_array.update_batch(cfg, ref, keys, ids, w)
        if ep < n_epochs - 1:
            sh = sharded_window_array.rotate(cfg, mesh, sh)
            ref = window_array.rotate(cfg, ref)
    return sh, ref


def _assert_states_equal(sh, ref):
    for name in ("regs", "hists", "chats", "union_regs", "union_hists", "union_chats"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sh, name)), np.asarray(getattr(ref, name)),
            err_msg=f"leaf {name} diverged",
        )
    assert (int(sh.head), int(sh.filled), int(sh.epoch_id)) == (
        int(ref.head), int(ref.filled), int(ref.epoch_id),
    )


# ---------------------------------------------------------------------------
# acceptance: update/rotate/estimate vs the single-host WindowArray, bitwise
# ---------------------------------------------------------------------------


def test_ring_bit_identical_across_rotations(mesh):
    cfg = SketchConfig(m=96, b=8, seed=31)  # ragged m
    k, e = sharding.padded_k(50, mesh), 3
    # e + 2 epochs: the ring wraps, so eviction + union rebuild are on-path.
    sh, ref = _drive_pair(cfg, k, e, mesh, n_epochs=e + 2)
    _assert_states_equal(sh, ref)
    for w in range(1, e + 1):
        np.testing.assert_array_equal(
            np.asarray(sharded_window_array.estimate_window(cfg, mesh, sh, w)),
            np.asarray(window_array.estimate_window(cfg, ref, w)),
            err_msg=f"estimate_window({w}) diverged",
        )
    np.testing.assert_array_equal(
        np.asarray(sharded_window_array.estimate_ring_anytime(sh)),
        np.asarray(window_array.estimate_ring_anytime(ref)),
    )
    with pytest.raises(ValueError, match="out of range"):
        sharded_window_array.estimate_window(cfg, mesh, sh, e + 1)


def test_masked_updates_and_reshard_roundtrip(mesh):
    cfg = SketchConfig(m=64, b=8, seed=33)
    k, e = sharding.padded_k(24, mesh), 2
    keys, ids, w = _stream(400, k, seed=5)
    mask = jnp.asarray(np.random.default_rng(3).random(400) < 0.5)
    sh = sharded_window_array.update_batch(
        cfg, mesh, sharded_window_array.init(cfg, k, e, mesh), keys, ids, w, mask=mask
    )
    ref = window_array.update_batch(
        cfg, window_array.init(cfg, k, e), keys, ids, w, mask=mask
    )
    _assert_states_equal(sh, ref)
    _assert_states_equal(sharded_window_array.to_array(sh), ref)
    _assert_states_equal(sharded_window_array.from_array(ref, mesh), ref)
    assert sharded_window_array.num_epochs(sh) == e
    assert sharded_window_array.num_sketches(sh) == k


def test_window_union_kernel_op_bit_identity(mesh):
    cfg = SketchConfig(m=64, b=8, seed=35)
    k, e = sharding.padded_k(16, mesh), 4
    sh, ref = _drive_pair(cfg, k, e, mesh, n_epochs=e + 1, seed=7)
    for w in (1, 2, 3):
        np.testing.assert_array_equal(
            np.asarray(ops.sharded_window_union_estimate_op(cfg, mesh, sh, w)),
            np.asarray(ops.window_union_estimate_op(cfg, ref, w)),
            err_msg=f"sharded union op diverged at w={w}",
        )


# ---------------------------------------------------------------------------
# merges: ring-aligned bit-identity + misaligned-head rejection
# ---------------------------------------------------------------------------


def test_merge_matches_single_host(mesh):
    cfg = SketchConfig(m=64, b=8, seed=41)
    k, e = sharding.padded_k(24, mesh), 3
    sh_a, ref_a = _drive_pair(cfg, k, e, mesh, n_epochs=e + 1, seed=11)
    sh_b, ref_b = _drive_pair(cfg, k, e, mesh, n_epochs=e + 1, seed=211)
    _assert_states_equal(
        sharded_window_array.merge(cfg, mesh, sh_a, sh_b),
        window_array.merge(cfg, ref_a, ref_b),
    )


def test_merge_rejects_misaligned_ring_heads(mesh):
    """Pods must rotate on a shared clock: one extra rotation on either side
    desynchronizes head/epoch_id and BOTH merges (sharded and single-host)
    must refuse — the previously-untested cross-shard edge case."""
    cfg = SketchConfig(m=64, b=8, seed=43)
    k, e = sharding.padded_k(16, mesh), 3
    sh_a, ref_a = _drive_pair(cfg, k, e, mesh, n_epochs=2, seed=13)
    sh_b, ref_b = _drive_pair(cfg, k, e, mesh, n_epochs=2, seed=113)
    sh_b = sharded_window_array.rotate(cfg, mesh, sh_b)
    ref_b = window_array.rotate(cfg, ref_b)
    with pytest.raises(ValueError, match="ring-aligned"):
        sharded_window_array.merge(cfg, mesh, sh_a, sh_b)
    with pytest.raises(ValueError, match="ring-aligned"):
        window_array.merge(cfg, ref_a, ref_b)
    # A full ring of extra rotations brings the head back around but leaves
    # epoch_id desynchronized — still misaligned (the eviction clocks
    # disagree even though the ring pointers coincide).
    for _ in range(e):
        ref_b = window_array.rotate(cfg, ref_b)
        sh_b = sharded_window_array.rotate(cfg, mesh, sh_b)
    assert int(ref_b.head) != int(ref_a.head) or int(ref_b.epoch_id) != int(ref_a.epoch_id)
    with pytest.raises(ValueError, match="ring-aligned"):
        window_array.merge(cfg, ref_a, ref_b)
    with pytest.raises(ValueError, match="ring-aligned"):
        sharded_window_array.merge(cfg, mesh, sh_a, sh_b)
    with pytest.raises(ValueError, match="matching"):
        sharded_window_array.merge(
            cfg, mesh, sh_a, sharded_window_array.init(cfg, k, e + 1, mesh)
        )


# ---------------------------------------------------------------------------
# sparse tenants + monitor + train threading
# ---------------------------------------------------------------------------


def test_sparse_tenants_stamp_epochs(mesh):
    cfg = SketchConfig(m=64, b=8, seed=45)
    dcfg = DirectoryConfig(capacity=sharding.padded_k(128, mesh), seed=47)
    rng = np.random.default_rng(15)
    tenants = rng.integers(2**33, 2**64, 200, dtype=np.uint64)
    keys = key_directory.split_uint64(tenants)
    ids = jnp.asarray(rng.integers(0, 2**32, 200, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 200).astype(np.float32))

    sh = sharded_window_array.init(cfg, dcfg.capacity, 3, mesh)
    sh = sharded_window_array.rotate(cfg, mesh, sh)  # epoch_id -> 1
    dstate = key_directory.init(dcfg)
    sh, dstate = sharded_window_array.update_tenants(
        cfg, dcfg, mesh, sh, dstate, keys, ids, w
    )
    assert int(dstate.n_routed) == 200
    touched = np.asarray(dstate.last_touch)
    assert (touched[touched >= 0] == 1).all()  # stamped with the ring clock

    ref = window_array.rotate(cfg, window_array.init(cfg, dcfg.capacity, 3))
    slots = key_directory.route_slots(dcfg, keys)
    ref = window_array.update_batch(cfg, ref, slots, ids, w)
    _assert_states_equal(sh, ref)


def test_sharded_window_monitor_roundtrip(mesh):
    cfg = SketchConfig(m=64, b=8, seed=61)
    mon = monitor.ShardedWindowMonitor.for_mesh(cfg, 64, 3, mesh, evict_after=2)
    ref_mon = monitor.WindowMonitor(cfg, mon.dcfg, 3, evict_after=2)
    rng = np.random.default_rng(26)
    n = 4000
    tkeys = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    mask = jnp.asarray(np.arange(n) < 3600)

    st = mon.update(mon.init(), tkeys, ids, w, mask=mask)
    ref = ref_mon.update(ref_mon.init(), tkeys, ids, w, mask=mask)
    assert int(st.n_seen) == 3600
    np.testing.assert_array_equal(
        np.asarray(mon.estimate(st)), np.asarray(ref_mon.estimate(ref))
    )
    np.testing.assert_array_equal(
        np.asarray(mon.estimate(st, w=2)), np.asarray(ref_mon.estimate(ref, w=2))
    )
    m = mon.metrics(st)
    assert int(m["tenant_elements_seen"]) == 3600
    assert int(m["tenant_window_epoch"]) == 0

    # Rotate the live epoch out: the window empties, aging releases claims.
    for _ in range(3):
        st = mon.rotate(st)
        ref = ref_mon.rotate(ref)
    np.testing.assert_array_equal(
        np.asarray(mon.estimate(st)), np.asarray(ref_mon.estimate(ref))
    )
    np.testing.assert_array_equal(np.asarray(mon.estimate(st)), 0.0)
    assert int(mon.metrics(st)["tenant_slots_claimed"]) == 0

    merged = mon.merge(st, mon.update(st, tkeys, ids, w, mask=mask))
    assert int(merged.n_seen) == 2 * 3600 + 3600


def test_train_step_threads_sharded_window_telemetry(mesh):
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.sketchstream.monitor import TelemetryState
    from repro.train import optimizer, train_step as ts

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(27)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "doc_ids": jnp.asarray(rng.integers(0, 2**32, (4,), dtype=np.uint32)),
    }
    skc = SketchConfig(m=64, b=8, seed=63)
    mon = monitor.ShardedWindowMonitor.for_mesh(skc, 256, 4, mesh)
    ocfg = optimizer.OptConfig(lr=1e-3, warmup_steps=0)
    step = jax.jit(ts.make_train_step(mcfg, ocfg, None, sketch_cfg=skc, tenant_monitor=mon))
    opt, comp, sk = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)
    assert isinstance(sk, TelemetryState)

    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(sk.tenants.n_seen) == 64
    assert "tenant_window_weight" in metrics
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 4  # 4 documents -> exactly 4 live rows

    # Epoch clock outside the jit'd step, as with the single-host monitor.
    sk = TelemetryState(scalar=sk.scalar, tenants=mon.rotate(sk.tenants))
    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(metrics["tenant_window_epoch"]) == 1
