"""ShardedSketchArray + key-directory tests.

Acceptance: sharded update -> merge -> estimate is bit-identical (registers)
and numerically identical (Ĉ) to the unsharded SketchArray on the 8-device
host mesh (scripts/test.sh exports XLA_FLAGS=--xla_force_host_platform_
device_count=8), including sparse 64-bit tenant ids through the key
directory and a forced-collision case.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    key_directory,
    qsketch,
    sharded_array,
    sketch_array,
)
from repro.core.key_directory import DirectoryConfig
from repro.launch.mesh import make_sketch_mesh
from repro.sketchstream import monitor


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()  # 8 shards under scripts/test.sh


def _stream(n, k, seed):
    rng = np.random.default_rng(seed)
    slots = jnp.asarray(rng.integers(0, k, n, dtype=np.int32))
    ids = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    w = jnp.asarray((rng.gamma(1.0, 2.0, n) + 1e-5).astype(np.float32))
    return slots, ids, w


def _tenants64(n, seed):
    """Sparse 64-bit tenant ids with nonzero hi words, pre-split."""
    rng = np.random.default_rng(seed)
    t = rng.integers(2**33, 2**64, n, dtype=np.uint64)
    return key_directory.split_uint64(t), t


# ---------------------------------------------------------------------------
# acceptance: update -> merge -> estimate vs the unsharded SketchArray
# ---------------------------------------------------------------------------


def test_update_merge_estimate_bit_identical(mesh):
    cfg = SketchConfig(m=96, b=8, seed=31)  # ragged m: not a lane multiple
    k = sharded_array.padded_k(100, mesh)  # ragged K rounded to the shards
    sa, ia, wa = _stream(700, k, seed=1)
    sb, ib, wb = _stream(500, k, seed=2)

    # Two independently built pods, merged by all-max.
    pod_a = sharded_array.update(cfg, mesh, sharded_array.init(cfg, k, mesh), sa, ia, wa)
    pod_b = sharded_array.update(cfg, mesh, sharded_array.init(cfg, k, mesh), sb, ib, wb)
    merged = sharded_array.merge(pod_a, pod_b)

    # Unsharded reference: the same two batches through core.sketch_array.
    ref_a = sketch_array.update(cfg, sketch_array.init(cfg, k), sa, ia, wa)
    ref = sketch_array.update(cfg, ref_a, sb, ib, wb)

    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(ref.regs))

    est_s, std_s, conv_s = sharded_array.estimate_all_with_ci(cfg, mesh, merged)
    est_u, std_u, conv_u = sketch_array.estimate_all_with_ci(cfg, ref)
    np.testing.assert_array_equal(np.asarray(est_s), np.asarray(est_u))
    np.testing.assert_array_equal(np.asarray(std_s), np.asarray(std_u))
    np.testing.assert_array_equal(np.asarray(conv_s), np.asarray(conv_u))


def test_masked_rows_are_noops_sharded(mesh):
    cfg = SketchConfig(m=64, b=8, seed=33)
    k = sharded_array.padded_k(40, mesh)
    slots, ids, w = _stream(400, k, seed=5)
    mask = np.random.default_rng(3).random(400) < 0.5
    st = sharded_array.update(
        cfg, mesh, sharded_array.init(cfg, k, mesh), slots, ids, w, mask=jnp.asarray(mask)
    )
    ref = sketch_array.update(
        cfg, sketch_array.init(cfg, k), slots[mask], ids[mask], w[mask]
    )
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))


def test_fresh_sharded_rows_estimate_zero(mesh):
    cfg = SketchConfig(m=64, b=8, seed=35)
    k = sharded_array.padded_k(16, mesh)
    st = sharded_array.init(cfg, k, mesh)
    est, _, conv = sharded_array.estimate_all_with_ci(cfg, mesh, st)
    np.testing.assert_array_equal(np.asarray(est), 0.0)
    assert not np.asarray(conv).any()


def test_init_rejects_indivisible_k(mesh):
    if sharded_array.num_shards(mesh) == 1:
        pytest.skip("any K divides a 1-shard mesh")
    cfg = SketchConfig(m=64, b=8, seed=1)
    with pytest.raises(ValueError, match="divisible"):
        sharded_array.init(cfg, sharded_array.num_shards(mesh) + 1, mesh)


# ---------------------------------------------------------------------------
# sparse 64-bit tenant ids through the key directory
# ---------------------------------------------------------------------------


def test_sparse_tenants_end_to_end(mesh):
    cfg = SketchConfig(m=64, b=8, seed=41)
    dcfg = DirectoryConfig(capacity=sharded_array.padded_k(4096, mesh), seed=43)
    (lo, hi), _ = _tenants64(600, seed=7)
    assert int(np.asarray(hi).min()) > 0  # genuinely 64-bit
    rng = np.random.default_rng(8)
    ids = jnp.asarray(rng.integers(0, 2**32, 600, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 600).astype(np.float32))

    st = sharded_array.init(cfg, dcfg.capacity, mesh)
    dstate = key_directory.init(dcfg)
    st, dstate = sharded_array.update_tenants(
        cfg, dcfg, mesh, st, dstate, (lo, hi), ids, w
    )
    assert int(dstate.n_routed) == 600

    # Same stream through stateless routing + the unsharded array.
    slots = key_directory.route_slots(dcfg, (lo, hi))
    assert int(jnp.min(slots)) >= 0 and int(jnp.max(slots)) < dcfg.capacity
    ref = sketch_array.update(cfg, sketch_array.init(cfg, dcfg.capacity), slots, ids, w)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))


def test_forced_collision_detected_and_exact_union(mesh):
    """Two tenants aliased to one slot: the row is an exact QSketch of the
    UNION stream, and the directory telemetry reports the aliasing."""
    cfg = SketchConfig(m=64, b=8, seed=51)
    dcfg = DirectoryConfig(capacity=sharded_array.padded_k(256, mesh), seed=53)

    # Find a colliding tenant pair by routing a candidate pool.
    (lo, hi), tenants = _tenants64(4096, seed=11)
    slots = np.asarray(key_directory.route_slots(dcfg, (lo, hi)))
    order = np.argsort(slots, kind="stable")
    dup = np.nonzero(np.diff(slots[order]) == 0)[0]
    assert len(dup), "no collision in 4096 candidates over 256 slots??"
    a_i, b_i = order[dup[0]], order[dup[0] + 1]
    assert tenants[a_i] != tenants[b_i] and slots[a_i] == slots[b_i]

    rng = np.random.default_rng(12)
    ids_a = jnp.asarray(rng.integers(0, 2**32, 50, dtype=np.uint32))
    ids_b = jnp.asarray(rng.integers(0, 2**32, 70, dtype=np.uint32))
    w_a = jnp.ones((50,), jnp.float32)
    w_b = jnp.full((70,), 2.0, jnp.float32)

    st = sharded_array.init(cfg, dcfg.capacity, mesh)
    dstate = key_directory.init(dcfg)
    for t_i, ids_t, w_t in ((a_i, ids_a, w_a), (b_i, ids_b, w_b)):
        keys = key_directory.split_uint64(np.full(len(ids_t), tenants[t_i], np.uint64))
        st, dstate = sharded_array.update_tenants(
            cfg, dcfg, mesh, st, dstate, keys, ids_t, w_t
        )

    # Tenant A claimed the slot in batch 1; ALL of tenant B's routings hit a
    # foreign fingerprint.
    assert int(dstate.n_collisions) == 70
    assert int(dstate.n_routed) == 120
    assert float(key_directory.collision_rate(dstate)) == pytest.approx(70 / 120)

    # The aliased row is the exact sketch of the union stream.
    union = qsketch.update(cfg, qsketch.init(cfg), jnp.concatenate([ids_a, ids_b]),
                           jnp.concatenate([w_a, w_b]))
    row = np.asarray(st.regs)[int(slots[a_i])]
    np.testing.assert_array_equal(row, np.asarray(union.regs))


def test_pinned_hot_tenants(mesh):
    (_, _), tenants = _tenants64(64, seed=21)
    hot = tuple(int(t) for t in tenants[:3])
    dcfg = DirectoryConfig(capacity=sharded_array.padded_k(128, mesh), seed=55, pinned=hot)
    keys = key_directory.split_uint64(tenants)
    slots = np.asarray(key_directory.route_slots(dcfg, keys))
    # Pinned tenants get their dedicated slots; nobody else can land there.
    np.testing.assert_array_equal(slots[:3], np.arange(3))
    assert (slots[3:] >= 3).all()


def test_directory_merge_counts_cross_host_conflicts():
    dcfg = DirectoryConfig(capacity=64, seed=57)
    (keys_a, ta), (keys_b, tb) = _tenants64(40, seed=23), _tenants64(40, seed=24)
    _, da = key_directory.route(dcfg, key_directory.init(dcfg), keys_a)
    _, db = key_directory.route(dcfg, key_directory.init(dcfg), keys_b)
    merged = key_directory.merge(da, db)
    assert int(merged.n_routed) == 80
    # Distinct 40-tenant sets into 64 slots: cross-host conflicts all but
    # guaranteed; exact count is data-dependent, the invariant is >= 0 and
    # that claimed slots combine monotonically.
    claimed = np.asarray(merged.fingerprints) != 0
    assert claimed.sum() >= max(np.asarray(da.fingerprints != 0).sum(),
                                np.asarray(db.fingerprints != 0).sum())
    with pytest.raises(ValueError, match="capacities"):
        key_directory.merge(da, key_directory.init(DirectoryConfig(capacity=32)))


def test_update_tenants_capacity_mismatch_raises(mesh):
    cfg = SketchConfig(m=64, b=8, seed=1)
    k = sharded_array.padded_k(64, mesh)
    dcfg = DirectoryConfig(capacity=k * 2, seed=2)
    st = sharded_array.init(cfg, k, mesh)
    keys = key_directory.split_uint64(np.arange(8, dtype=np.uint64))
    with pytest.raises(ValueError, match="capacity"):
        sharded_array.update_tenants(
            cfg, dcfg, mesh, st, key_directory.init(dcfg), keys,
            jnp.zeros(8, jnp.uint32), jnp.ones(8, jnp.float32),
        )


# ---------------------------------------------------------------------------
# monitor + train/serve threading
# ---------------------------------------------------------------------------


def test_sharded_monitor_roundtrip(mesh):
    cfg = SketchConfig(m=64, b=8, seed=61)
    mon = monitor.ShardedArrayMonitor.for_mesh(cfg, 500, mesh)
    keys, _ = _tenants64(300, seed=25)
    rng = np.random.default_rng(26)
    ids = jnp.asarray(rng.integers(0, 2**32, 300, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 300).astype(np.float32))
    mask = jnp.asarray(np.arange(300) < 250)

    st = mon.update(mon.init(), keys, ids, w, mask=mask)
    assert int(st.n_seen) == 250
    est = np.asarray(mon.estimate(st))
    assert est.shape == (mon.dcfg.capacity,) and (est > 0).any()

    st2 = mon.update(mon.init(), keys, ids, w, mask=mask)
    merged = mon.merge(st, st2)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(st.regs))
    assert int(merged.n_seen) == 500
    m = mon.metrics(st)
    assert int(m["tenant_elements_seen"]) == 250
    assert int(m["tenant_slots_claimed"]) > 0


def test_train_step_threads_tenant_telemetry(mesh):
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import optimizer, train_step as ts

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(27)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, mcfg.vocab, (4, 16)), jnp.int32),
        "doc_ids": jnp.asarray(rng.integers(0, 2**32, (4,), dtype=np.uint32)),
    }
    skc = SketchConfig(m=64, b=8, seed=63)
    mon = monitor.ShardedArrayMonitor.for_mesh(skc, 256, mesh)
    ocfg = optimizer.OptConfig(lr=1e-3, warmup_steps=0)
    step = jax.jit(ts.make_train_step(mcfg, ocfg, None, sketch_cfg=skc, tenant_monitor=mon))
    opt, comp, sk = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)
    assert isinstance(sk, monitor.TelemetryState)

    _, _, _, sk, metrics = step(params, opt, comp, sk, batch)
    assert int(sk.tenants.n_seen) == 64  # 4 x 16 tokens through the array
    assert int(sk.scalar.n_seen) == 64
    assert "tenant_collision_rate" in metrics and "distinct_tokens_est" in metrics
    # 4 documents -> exactly 4 live rows.
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 4

    # 64-bit doc ids: the hi word must change the routing (no truncation).
    batch_hi = dict(batch, doc_ids_hi=jnp.asarray([1, 2, 3, 4], jnp.uint32))
    opt, comp, sk2 = ts.init_states(mcfg, ocfg, params, sketch_cfg=skc, tenant_monitor=mon)
    _, _, _, sk2, _ = step(params, opt, comp, sk2, batch_hi)
    assert not np.array_equal(
        np.asarray(sk2.tenants.directory.fingerprints),
        np.asarray(sk.tenants.directory.fingerprints),
    )


def test_decode_step_threads_tenant_telemetry(mesh):
    from repro import configs
    from repro.models import common as mcommon, transformer
    from repro.train import serve_step

    mcfg = configs.smoke_config("h2o-danube-1.8b")
    params = mcommon.init_params(transformer.model_defs(mcfg), jax.random.PRNGKey(7))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), transformer.abstract_cache(mcfg, batch=2, max_len=16)
    )
    skc = SketchConfig(m=64, b=8, seed=65)
    mon = monitor.ShardedArrayMonitor.for_mesh(skc, 128, mesh)
    dec = jax.jit(serve_step.make_decode_step(mcfg, None, sketch_cfg=skc, tenant_monitor=mon))

    sk = monitor.TelemetryState(scalar=monitor.init(skc), tenants=mon.init())
    _, _, sk = dec(
        params, cache, jnp.int32(0), jnp.zeros((2, 1), jnp.int32), sk,
        jnp.asarray([101, 202], jnp.uint32),  # session ids
        jnp.asarray([1.0, 3.0], jnp.float32),  # engagement weights
        None, None,
        jnp.asarray([7, 7], jnp.uint32),  # both sessions belong to tenant 7
    )
    assert int(sk.tenants.n_seen) == 2
    est = np.asarray(mon.estimate(sk.tenants))
    assert (est > 0).sum() == 1  # one tenant row live
    assert float(est.sum()) == pytest.approx(4.0, rel=0.5)  # ~1.0 + 3.0

    # Telemetry-off call shape (sk_state=None) must stay valid even though
    # the step was built with a tenant monitor.
    tok, _, none_state = dec(params, cache, jnp.int32(0), jnp.zeros((2, 1), jnp.int32))
    assert none_state is None and tok.shape == (2, 1)
