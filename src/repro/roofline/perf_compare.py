"""§Perf iteration comparator: baseline vs variant dry-run records.

    PYTHONPATH=src python -m repro.roofline.perf_compare kimi-k2-1t-a32b train_4k
"""

from __future__ import annotations

import glob
import json
import os
import sys

from . import hw

DIR = "experiments/dryrun"


def row(rec):
    t = rec["roofline"]
    pd = rec["per_device"]
    tmax = max(t.values())
    return {
        "compute_s": t["compute_s"],
        "memory_s": t["memory_s"],
        "collective_s": t["collective_s"],
        "bottleneck": rec["bottleneck"],
        "dominant_s": tmax,
        "frac_roofline": t["compute_s"] / tmax if tmax else 0.0,
        "peak_gib": rec["hbm_fit"]["peak_bytes_est"] / 2**30,
        "useful": rec["useful_flops_ratio"],
        "coll_by_op": pd["collective_by_op"],
    }


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    paths = sorted(glob.glob(os.path.join(DIR, f"{arch}_{shape}_singlepod*.json")))
    print(f"{'variant':<28} {'compute':>10} {'memory':>10} {'collect':>10} {'domin.':>10} "
          f"{'frac':>6} {'peak GiB':>9} {'useful':>7}")
    base = None
    for p in paths:
        rec = json.load(open(p))
        if rec["status"] != "ok":
            continue
        tag = os.path.basename(p).split(f"{shape}_singlepod")[-1].replace(".json", "") or "(baseline)"
        r = row(rec)
        if base is None and tag == "(baseline)":
            base = r
        speedup = f" x{base['dominant_s']/r['dominant_s']:.1f}" if base and tag != "(baseline)" else ""
        print(f"{tag:<28} {r['compute_s']:>10.2f} {r['memory_s']:>10.2f} {r['collective_s']:>10.2f} "
              f"{r['dominant_s']:>10.2f} {r['frac_roofline']:>6.3f} {r['peak_gib']:>9.1f} {r['useful']:>7.2f}{speedup}")


if __name__ == "__main__":
    main()
