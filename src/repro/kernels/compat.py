"""JAX version compatibility shims for the Pallas kernels.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever this install provides so the kernels run on
both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
