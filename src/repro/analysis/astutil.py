"""Shared AST helpers for qlint rules.

The rules never import the code under analysis (a broken module must still
report precisely, and analysis must stay side-effect free), so everything
here is pure-syntax machinery:

* ``module_name_for`` — repo-relative path -> dotted module name
  (``src/repro/core/dyn_array.py`` -> ``repro.core.dyn_array``),
* ``dotted`` — collapse a Name/Attribute chain to ``"a.b.c"``,
* ``ImportMap`` — per-module local-name -> fully-qualified-name table built
  from ``import`` / ``from ... import`` (relative imports resolved against
  the module's package) plus simple module-level aliases
  (``solve = estimators.qsketch_mle``); ``resolve`` rewrites an expression's
  dotted chain through it,
* ``walk_functions`` — every (qualname, def-node) in a module, including
  nested defs and methods.
"""

from __future__ import annotations

import ast
from typing import Iterator


def module_name_for(rel: str) -> str:
    """Dotted module name for a repo-relative posix path.

    ``src/`` is the import root for ``repro``; top-level ``benchmarks/`` and
    ``examples/`` are importable as themselves. ``__init__.py`` maps to the
    package name.
    """
    parts = rel.split("/")
    if parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    elif parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    return ".".join(parts)


def dotted(node: ast.AST) -> str | None:
    """``"a.b.c"`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap:
    """Local-name -> fully-qualified dotted name for one module.

    Built from the module's import statements and simple ``name = <dotted>``
    aliases at any nesting level (an alias of an already-resolvable chain is
    folded in, so ``e = estimators; f = e.qsketch_mle`` resolves fully).
    """

    def __init__(self, tree: ast.Module, module_name: str):
        self.module_name = module_name
        self.names: dict[str, str] = {}
        self._build(tree)

    def _package_parts(self, level: int) -> list[str]:
        parts = self.module_name.split(".")
        # A non-package module's level-1 base is its containing package.
        parts = parts[:-1]
        if level > 1:
            parts = parts[: len(parts) - (level - 1)]
        return parts

    def _build(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        self.names[alias.asname] = alias.name
                    else:
                        # ``import a.b.c`` binds the root name ``a``.
                        root = alias.name.split(".")[0]
                        self.names.setdefault(root, root)
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = ".".join(
                        self._package_parts(node.level)
                        + ([node.module] if node.module else [])
                    )
                else:
                    base = node.module or ""
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    self.names[local] = f"{base}.{alias.name}" if base else alias.name
        # Fold in simple aliases (one fixpoint pass is enough for chains
        # written in source order, which is all the repo uses).
        for _ in range(2):
            for node in ast.walk(tree):
                if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                    continue
                target = node.targets[0]
                if not isinstance(target, ast.Name):
                    continue
                qual = self.resolve(node.value)
                if qual and qual != target.id:
                    self.names.setdefault(target.id, qual)

    def resolve(self, node: ast.AST) -> str | None:
        """Fully-qualified name of an expression's dotted chain, or None."""
        d = dotted(node)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        base = self.names.get(head)
        if base is None:
            return None
        return f"{base}.{rest}" if rest else base


def walk_functions(
    tree: ast.Module,
) -> Iterator[tuple[str, ast.FunctionDef | ast.AsyncFunctionDef]]:
    """Yield (qualname, def-node) for every function, methods and nested
    defs included (``Class.method``, ``outer.<locals>.inner``)."""

    def visit(node: ast.AST, prefix: str) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from visit(child, f"{qual}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def call_keyword(call: ast.Call, name: str) -> ast.expr | None:
    """The value of keyword ``name`` in a call, or None."""
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def literal_int_tuple(node: ast.expr | None) -> tuple[int, ...] | None:
    """Evaluate a literal tuple/int of ints (``(0, 1)`` or ``0``), else None."""
    if node is None:
        return None
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None
    if isinstance(val, int):
        return (val,)
    if isinstance(val, tuple) and all(isinstance(v, int) for v in val):
        return val
    return None
