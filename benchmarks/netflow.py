"""Paper App. A.4 (CAIDA) analogue: weighted cardinality of a heavy-tailed
packet stream — flow = (src,dst) id, weight = flow size in bytes.

Validates the two A.4 observations on a synthetic-but-heavy-tailed stream:
QSketch ~ LM/FastGM accuracy, QSketch-Dyn best; Dyn throughput flat in m.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import METHODS, SketchConfig
from repro.data import synthetic

from . import common


def run(quick=True):
    n_flows = 20_000 if quick else 200_000
    n_packets = 130_000 if quick else 2_000_000
    runs = 4 if quick else 25
    ms = [256] if quick else [256, 1024, 4096, 16384]
    rows = []
    # quick profile skips the order-statistics baselines: their batched
    # argsort schedule is minutes/run on one CPU core and their ACCURACY
    # equivalence to LM is already established by accuracy.py (full profile
    # keeps all five).
    methods = [m_ for m_ in METHODS if quick is False or m_ in ("LM", "QSketch", "QSketch-Dyn")]
    for m in ms:
        for method in methods:
            ests, true_c = [], None
            for r in range(runs):
                ids, w, true_c = synthetic.netflow(n_flows, n_packets, seed=r)
                cfg = SketchConfig(m=m, b=8, seed=900 + r)
                meth = METHODS[method]
                st = meth["init"](cfg)
                bs = 65536
                for i in range(0, len(ids), bs):
                    st = meth["update"](cfg, st, jnp.asarray(ids[i : i + bs]), jnp.asarray(w[i : i + bs]))
                ests.append(float(meth["estimate"](cfg, st)))
            rows.append({
                "figure": "caida_a4",
                "method": method,
                "m": m,
                "rrmse": common.rrmse(ests, true_c),
                "n_flows": n_flows,
                "n_packets": n_packets,
            })
            common.csv_row(f"netflow/m{m}/{method}", 0.0, f"rrmse={rows[-1]['rrmse']:.4f}")
    common.save("netflow", rows)
    return rows
