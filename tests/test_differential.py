"""Differential suite: ONE shared keyed workload through every container
front; fronts in the same family must agree bit-for-bit.

The repo's central correctness claim is that each container family is one
construction behind many entry points (DESIGN.md §8): the jnp core, the
Pallas kernels, the sharded twins, the window ring's head epoch, and the
K-loop element-log oracles all realize the same per-tenant sketch. This
module pins that claim down as a single differential: identical inputs in,
identical per-tenant registers / histograms / estimates out, across

  * the FULL-construction family (4 fronts): ``sketch_array`` /
    ``ops.sketch_array_update_op`` / ``sharded_array`` / the
    ``update_reference`` K-loop;
  * the DYN family (5 fronts): ``dyn_array`` / ``ops.dyn_array_update_op``
    / the ``window_array`` head epoch / ``sharded_dyn_array`` (jnp and
    kernel entries) / ``sharded_window_array``'s head epoch / the
    ``update_reference`` K-loop;
  * plus the virtual tier (+1): a ``VirtualDynArray`` with EVERY tenant
    pinned has no tail, and its hot tier must match the dense DynArray
    bit-for-bit — the exactness anchor of the tiering contract.

A second warm batch runs everywhere so the Dyn fronts exercise nonzero
batch-start histograms (the q_R regime where chat bugs hide).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    dyn_array,
    sharded_array,
    sharded_dyn_array,
    sharded_window_array,
    sharding,
    sketch_array,
    virtual_dyn_array as vda,
    window_array,
)
from repro.core.types import SketchArrayState
from repro.core.virtual_dyn_array import VirtualConfig
from repro.kernels import ops
from repro.launch.mesh import make_sketch_mesh

_CFG = SketchConfig(m=64, b=6, seed=31)
_B = 256


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()


@pytest.fixture(scope="module")
def workload(mesh):
    """The one shared stream: (K, two keyed batches). K is a shard multiple
    so every front — dense, sharded, windowed — accepts it unchanged."""
    k = sharding.padded_k(8, mesh)
    rng = np.random.default_rng(17)

    def batch(seed):
        r = np.random.default_rng(seed)
        keys = jnp.asarray(r.integers(0, k, _B, dtype=np.int32))
        ids = jnp.asarray(r.integers(0, 2**32, _B, dtype=np.uint32))
        w = jnp.asarray((r.gamma(1.0, 2.0, _B) + 1e-5).astype(np.float32))
        return keys, ids, w

    del rng
    return k, [batch(101), batch(202)]


def _fold(update, state, batches):
    for keys, ids, w in batches:
        state = update(state, keys, ids, w)
    return state


def _assert_all_equal(name, arrays):
    ref = np.asarray(arrays[0][1])
    for front, arr in arrays[1:]:
        np.testing.assert_array_equal(
            np.asarray(arr), ref,
            err_msg=f"{name}: front '{front}' diverged from '{arrays[0][0]}'",
        )


def test_full_family_identical(mesh, workload):
    k, batches = workload
    fronts = {
        "sketch_array": _fold(
            lambda s, ke, i, w: sketch_array.update(_CFG, s, ke, i, w),
            sketch_array.init(_CFG, k), batches,
        ),
        "kernel": _fold(
            lambda s, ke, i, w: ops.sketch_array_update_op(_CFG, s, ke, i, w),
            sketch_array.init(_CFG, k), batches,
        ),
        "sharded": sharded_array.to_array(
            _fold(
                lambda s, ke, i, w: sharded_array.update(_CFG, mesh, s, ke, i, w),
                sharded_array.init(_CFG, k, mesh), batches,
            )
        ),
        "k_loop_oracle": _fold(
            lambda s, ke, i, w: sketch_array.update_reference(_CFG, s, ke, i, w),
            sketch_array.init(_CFG, k), batches,
        ),
    }
    _assert_all_equal("regs", [(n, s.regs) for n, s in fronts.items()])
    # Identical registers through the same solver => identical estimates;
    # the sharded front solves shard-locally and must still agree.
    ests = [
        (n, sketch_array.estimate_all(_CFG, SketchArrayState(regs=s.regs)))
        for n, s in fronts.items()
    ]
    ests.append((
        "sharded_solve",
        sharded_array.estimate_all(
            _CFG, mesh, sharded_array.from_array(fronts["sharded"], mesh)
        ),
    ))
    _assert_all_equal("estimates", ests)


def test_dyn_family_identical(mesh, workload):
    k, batches = workload
    fronts = {
        "dyn_array": _fold(
            lambda s, ke, i, w: dyn_array.update_batch(_CFG, s, ke, i, w),
            dyn_array.init(_CFG, k), batches,
        ),
        "kernel": _fold(
            lambda s, ke, i, w: ops.dyn_array_update_op(_CFG, s, ke, i, w),
            dyn_array.init(_CFG, k), batches,
        ),
        "window_head": window_array.epoch_substate(
            _fold(
                lambda s, ke, i, w: window_array.update_batch(_CFG, s, ke, i, w),
                window_array.init(_CFG, k, 3), batches,
            ),
            0,
        ),
        "sharded": sharded_dyn_array.to_array(
            _fold(
                lambda s, ke, i, w: sharded_dyn_array.update_batch(
                    _CFG, mesh, s, ke, i, w
                ),
                sharded_dyn_array.init(_CFG, k, mesh), batches,
            )
        ),
        "sharded_kernel": sharded_dyn_array.to_array(
            _fold(
                lambda s, ke, i, w: ops.sharded_dyn_array_update_op(
                    _CFG, mesh, s, ke, i, w
                ),
                sharded_dyn_array.init(_CFG, k, mesh), batches,
            )
        ),
        "sharded_window_head": window_array.epoch_substate(
            sharded_window_array.to_array(
                _fold(
                    lambda s, ke, i, w: sharded_window_array.update_batch(
                        _CFG, mesh, s, ke, i, w
                    ),
                    sharded_window_array.init(_CFG, k, 3, mesh), batches,
                )
            ),
            0,
        ),
        "k_loop_oracle": _fold(
            lambda s, ke, i, w: dyn_array.update_reference(_CFG, s, ke, i, w),
            dyn_array.init(_CFG, k), batches,
        ),
    }
    _assert_all_equal("regs", [(n, s.regs) for n, s in fronts.items()])
    _assert_all_equal("hists", [(n, s.hists) for n, s in fronts.items()])
    # The anytime martingales are the per-tenant ESTIMATE of this family;
    # identical batch sequence => bit-identical chats on every production
    # front. The sequential K-loop oracle accumulates its chats in element
    # order rather than the fused batch's reduction order, so it agrees to
    # f32 rounding only (the dyn_array suite's own oracle tolerance).
    _assert_all_equal(
        "chats",
        [(n, s.chats) for n, s in fronts.items() if n != "k_loop_oracle"],
    )
    np.testing.assert_allclose(
        np.asarray(fronts["k_loop_oracle"].chats),
        np.asarray(fronts["dyn_array"].chats),
        rtol=1e-5,
    )


def test_virtual_all_pinned_matches_dense(workload):
    """The +1 front: pin every tenant — the virtual container degenerates to
    a dense DynArray (empty pool) and must match it bit-for-bit, estimates
    included."""
    k, batches = workload
    # Sparse 64-bit tenant ids standing in for the dense keys, pinned in
    # slot order so hot row r corresponds to dense row r.
    tenants = (np.arange(k, dtype=np.uint64) + 1) * np.uint64(0x9E3779B97F4A7C15)
    vcfg = VirtualConfig(
        pool_size=4 * _CFG.m, pinned=tuple(int(t) for t in tenants)
    )
    st_v = vda.init(_CFG, vcfg)
    st_d = dyn_array.init(_CFG, k)
    for keys, ids, w in batches:
        tk = tenants[np.asarray(keys)]
        t = (
            jnp.asarray(tk & 0xFFFFFFFF, jnp.uint32),
            jnp.asarray(tk >> 32, jnp.uint32),
        )
        st_v = vda.update_tenants(_CFG, vcfg, st_v, t, ids, w)
        st_d = dyn_array.update_batch(_CFG, st_d, keys, ids, w)

    np.testing.assert_array_equal(np.asarray(st_v.hot.regs), np.asarray(st_d.regs))
    np.testing.assert_array_equal(np.asarray(st_v.hot.hists), np.asarray(st_d.hists))
    np.testing.assert_array_equal(np.asarray(st_v.hot.chats), np.asarray(st_d.chats))
    # No tail traffic at all: the pool plane never moved.
    assert int(st_v.n_tail) == 0 and float(st_v.w_tail) == 0.0
    assert float(vda.pool_load_factor(st_v)) == 0.0
    # Per-tenant estimates == the dense anytime reads, bit-for-bit.
    tq = (
        jnp.asarray(tenants & 0xFFFFFFFF, jnp.uint32),
        jnp.asarray(tenants >> 32, jnp.uint32),
    )
    np.testing.assert_array_equal(
        np.asarray(vda.estimate_tenants(_CFG, vcfg, st_v, tq)),
        np.asarray(dyn_array.estimate_all(st_d)),
    )
