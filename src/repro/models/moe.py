"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch strategy (MaxText/Megablocks-style, einsum-one-hot free): flatten
(token, expert-choice) pairs, sort by expert id, compute each pair's rank
inside its expert run, drop pairs past the per-expert capacity, scatter into
an (experts, capacity, d_model) buffer, run the batched expert FFN as one
einsum over the expert dim, gather back and combine with router probs.

Compute is O(k · T · cf · d · f) — the *active* FLOPs — instead of the
O(T · X · cap) one-hot dispatch tensor which is infeasible at kimi scale
(384 experts × 1M tokens).

Sharding: the (X, C, E) buffer puts experts on "model" (expert parallelism);
tokens enter sharded on ("pod","data"). The scatter across those two
shardings is the EP all-to-all — visible in the dry-run HLO and the dominant
collective for kimi-k2 (see EXPERIMENTS.md §Roofline).

Aux losses: Switch-style load-balance + router z-loss, returned for logging
and added to the train loss with small coefficients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, sharding
from .common import ParamDef

# jax.shard_map only exists on newer JAX; fall back to the experimental home.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def defs(cfg):
    m = cfg.moe
    e = cfg.d_model
    f = m.d_ff or cfg.d_ff
    x = m.num_experts
    d = {
        "router": ParamDef((e, x), ("embed", None), dtype=jnp.float32, scale=0.1),
        "w_gate": ParamDef((x, e, f), ("experts", "embed", None)),
        "w_up": ParamDef((x, e, f), ("experts", "embed", None)),
        "w_down": ParamDef((x, f, e), ("experts", None, "embed")),
    }
    if m.shared_expert:
        d["shared"] = {
            "w_gate": ParamDef((e, f), ("embed", "ffn")),
            "w_up": ParamDef((e, f), ("embed", "ffn")),
            "w_down": ParamDef((f, e), ("ffn", "embed")),
        }
    if m.dense_residual:
        d["residual"] = {
            "w_gate": ParamDef((e, cfg.d_ff), ("embed", "ffn")),
            "w_up": ParamDef((e, cfg.d_ff), ("embed", "ffn")),
            "w_down": ParamDef((cfg.d_ff, e), ("ffn", "embed")),
        }
    return d


def capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(cap, 4)


def apply(params, x, cfg, mesh=None):
    """Dispatcher: cfg.moe.impl selects the execution strategy.

    The a2a path requires tokens % mesh.size == 0 and experts % model == 0;
    tiny decode batches (one token per sequence) fall back to the scatter
    path, where the dispatch buffer is small enough that GSPMD's
    replicate+reduce fallback is harmless."""
    if (
        cfg.moe.impl == "shard_map_a2a"
        and mesh is not None
        and "model" in mesh.axis_names
        and x.shape[0] % mesh.size == 0
        and cfg.moe.num_experts % mesh.shape["model"] == 0
    ):
        return apply_a2a(params, x, cfg, mesh)
    return apply_scatter(params, x, cfg, mesh)


def _pack_by_owner(owner, n_owners: int, cap: int):
    """Stage-1 capacity packing: stable owner sort + per-owner rank.

    Returns (order, owner_sorted, rank, keep). The SAME routine computes the
    in-shard dispatch inside apply_a2a's local_fn and the drop_fraction
    replay outside it — keep them shared so the reported metric can't drift
    from what the dispatch actually drops.
    """
    order = jnp.argsort(owner)
    own_s = owner[order]
    cnt = jnp.bincount(own_s, length=n_owners)
    start = jnp.cumsum(cnt) - cnt
    rank = jnp.arange(owner.shape[0]) - start[own_s]
    return order, own_s, rank, rank < cap


def apply_a2a(params, x, cfg, mesh):
    """Explicit expert parallelism: two-hop all-to-all under shard_map.

    Stage 0: tokens resharded over EVERY mesh axis (data axes x "model") so
             no routing work is duplicated across TP peers.
    Stage 1: each device sorts its local (token, expert-choice) pairs by the
             expert's OWNER device, packs per-peer capacity buffers, and
             all_to_all's them across "model".
    Stage 2: received candidates are sorted by local expert, capacity-
             truncated, run through the batched expert FFN, scattered back to
             their arrival slots, and all_to_all'd home, where they combine
             into token outputs weighted by router probs.

    Wire volume per device = 2 hops x (T_loc·k·cf·d_model) bytes — the
    irreducible EP exchange — versus the GSPMD-scatter baseline's
    all-reduce of the full (X·C, d_model) buffer per layer (§Perf log).
    """
    m = cfg.moe
    t, e = x.shape
    nx = m.num_experts
    k = m.top_k

    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)  # e.g. ("pod","data","model")
    nm = int(mesh.shape["model"])
    # Tokens sharded over EVERY axis (data x model) for the dispatch.
    x = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(axes, None)))

    x_loc_count = t // mesh.size
    cap_send = max(int(x_loc_count * k * m.capacity_factor / nm) + 1, 4)
    x_l = nx // nm  # experts per device
    cap_exp = max(int(nm * cap_send * m.capacity_factor / x_l) + 1, 4)

    def local_fn(xl, router, wg, wu, wd):
        tl = xl.shape[0]
        logits = jnp.einsum("te,ex->tx", xl.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        e_flat = top_e.reshape(-1)
        p_flat = top_p.reshape(-1)
        tok_flat = jnp.repeat(jnp.arange(tl), k)

        # ---- stage 1: pack per-owner send buffers -------------------------
        owner = e_flat // x_l
        order1, own_s, rank1, keep1 = _pack_by_owner(owner, nm, cap_send)
        e_s, tok_s, p_s = e_flat[order1], tok_flat[order1], p_flat[order1]
        dest1 = jnp.where(keep1, own_s * cap_send + rank1, nm * cap_send)

        send_x = jnp.zeros((nm * cap_send + 1, e), xl.dtype).at[dest1].set(xl[tok_s])
        send_le = jnp.full((nm * cap_send + 1,), -1, jnp.int32).at[dest1].set(
            (e_s % x_l).astype(jnp.int32)
        )
        recv_x = jax.lax.all_to_all(
            send_x[:-1].reshape(nm, cap_send, e), "model", 0, 0, tiled=False
        ).reshape(nm * cap_send, e)
        recv_le = jax.lax.all_to_all(
            send_le[:-1].reshape(nm, cap_send), "model", 0, 0, tiled=False
        ).reshape(nm * cap_send)

        # ---- stage 2: sort by local expert, FFN, unsort -------------------
        valid = recv_le >= 0
        key2 = jnp.where(valid, recv_le, x_l)
        order2 = jnp.argsort(key2)
        key2s = key2[order2]
        cnt2 = jnp.bincount(key2s, length=x_l + 1)
        start2 = jnp.cumsum(cnt2) - cnt2
        rank2 = jnp.arange(nm * cap_send) - start2[key2s]
        keep2 = (rank2 < cap_exp) & (key2s < x_l)
        dest2 = jnp.where(keep2, key2s * cap_exp + rank2, x_l * cap_exp)

        buf = jnp.zeros((x_l * cap_exp + 1, e), xl.dtype).at[dest2].set(recv_x[order2])
        buf = buf[:-1].reshape(x_l, cap_exp, e)
        g = common.silu(jnp.einsum("xce,xef->xcf", buf, wg))
        u = jnp.einsum("xce,xef->xcf", buf, wu)
        out = jnp.einsum("xcf,xfe->xce", g * u, wd)
        out_flat = jnp.concatenate([out.reshape(x_l * cap_exp, e), jnp.zeros((1, e), xl.dtype)])

        back = jnp.zeros((nm * cap_send, e), xl.dtype).at[order2].set(
            out_flat[dest2] * keep2[:, None].astype(xl.dtype)
        )
        ret = jax.lax.all_to_all(
            back.reshape(nm, cap_send, e), "model", 0, 0, tiled=False
        ).reshape(nm * cap_send, e)
        ret_flat = jnp.concatenate([ret, jnp.zeros((1, e), xl.dtype)])

        y = jnp.zeros((tl, e), xl.dtype).at[tok_s].add(
            ret_flat[dest1] * (p_s * keep1).astype(xl.dtype)[:, None]
        )
        return y

    y = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(axes, None), P(), P("model", None, None), P("model", None, None), P("model", None, None)),
        out_specs=P(axes, None),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    # ---- aux losses, computed OUTSIDE the shard_map ------------------------
    # Two reasons: (1) shard_map transposition on some JAX versions chokes on
    # outputs whose cotangent is a symbolic Zero (any caller that grads
    # through y alone, as the equivalence tests do, hits that path); (2) the
    # global statistic matches apply_scatter's aux definition exactly, where
    # the pmean of per-shard products is a slightly different estimator. The
    # duplicated router pass is a (T, X) einsum — noise next to the expert
    # FFN, and load_balance/router_z keep their gradients for the train loss.
    logits = jnp.einsum("te,ex->tx", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, k)
    e_flat = top_e.reshape(-1)
    frac = jnp.bincount(e_flat, length=nx).astype(jnp.float32) / (t * k)
    lb = nx * jnp.sum(frac * probs.mean(0))
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # drop_fraction: replay stage-1's per-device capacity packing on the
    # (n_dev, t_loc*k) block view — _pack_by_owner is the same routine
    # local_fn dispatches with, so the metric tracks the real drops.
    n_dev = mesh.size
    owner_blk = (top_e.reshape(n_dev, -1) // x_l).astype(jnp.int32)
    drop = 1.0 - jax.vmap(lambda own: _pack_by_owner(own, nm, cap_send)[3])(owner_blk).mean()
    aux = {"load_balance": lb, "router_z": zl, "drop_fraction": drop}

    if m.shared_expert:
        p = params["shared"]
        y = y + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    if m.dense_residual:
        p = params["residual"]
        y = y + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return y, aux


def apply_scatter(params, x, cfg, mesh=None):
    """x: (T, E) flattened tokens. Returns (y, aux) with aux loss scalars."""
    m = cfg.moe
    t, e = x.shape
    nx = m.num_experts
    k = m.top_k
    cap = capacity(cfg, t)

    logits = jnp.einsum("te,ex->tx", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------------
    e_flat = top_e.reshape(-1)  # (T*k,)
    p_flat = top_p.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(e_flat)  # stable
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    p_sorted = p_flat[order]

    # rank of each pair within its expert's run
    counts = jnp.bincount(e_sorted, length=nx)  # (X,)
    seg_start = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - seg_start[e_sorted]
    keep = rank < cap
    dest = jnp.where(keep, e_sorted * cap + rank, nx * cap)  # overflow slot

    buf = jnp.zeros((nx * cap + 1, e), x.dtype).at[dest].set(x[tok_sorted])
    buf = buf[: nx * cap].reshape(nx, cap, e)
    if mesh is not None:
        buf = sharding.constrain(buf, mesh, "experts", None, None)

    # ---- batched expert FFN (active compute only) ---------------------------
    g = common.silu(jnp.einsum("xce,xef->xcf", buf, params["w_gate"]))
    u = jnp.einsum("xce,xef->xcf", buf, params["w_up"])
    out = jnp.einsum("xcf,xfe->xce", g * u, params["w_down"])
    if mesh is not None:
        out = sharding.constrain(out, mesh, "experts", None, None)

    # ---- combine -------------------------------------------------------------
    out_flat = jnp.concatenate([out.reshape(nx * cap, e), jnp.zeros((1, e), x.dtype)])
    if mesh is not None:
        # Replicate before the combine gather. GSPMD's partitioned gather from
        # a "model"-sharded operand mis-accumulates across a second (data)
        # mesh axis on some JAX versions (each data replica's partial gets
        # summed), doubling every expert output; an explicit all-gather here
        # is what the correct fallback lowers to anyway and keeps the expert
        # FFN itself on the EP layout.
        out_flat = sharding.constrain(out_flat, mesh, None, None)
    contrib = out_flat[dest] * (p_sorted * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, e), x.dtype).at[tok_sorted].add(contrib)

    if m.shared_expert:
        p = params["shared"]
        y = y + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    if m.dense_residual:
        p = params["residual"]
        y = y + common.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])

    # ---- aux losses ----------------------------------------------------------
    # Switch load-balance: X * sum_x( frac_tokens(x) * mean_prob(x) ).
    frac = jnp.bincount(e_flat, length=nx).astype(jnp.float32) / (t * k)
    mean_p = probs.mean(axis=0)
    lb = nx * jnp.sum(frac * mean_p)
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    drop_frac = 1.0 - keep.mean()
    aux = {"load_balance": lb, "router_z": zl, "drop_fraction": drop_frac}
    return y, aux
