"""Production mesh builders (MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the platform/device count at first backend init, and
the dry-run must set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int | None = None):
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_sketch_mesh(n_shards: int | None = None):
    """1-D mesh over the ``"sketch"`` axis: rows of a ShardedSketchArray.

    The multi-tenant register matrix (core/sharded_array.py) shards its K
    rows over this axis; K ~ 1e7 tenants then costs K*m/n_shards bytes per
    device instead of one host's worth. Defaults to every visible device.
    Telemetry embedded in a training step can instead reuse an existing mesh
    axis (``sharded_array.update(..., axis="data")``) — this builder is for
    the standalone monitoring fleet / examples / benchmarks.
    """
    n = n_shards or len(jax.devices())
    return jax.make_mesh((n,), ("sketch",))
