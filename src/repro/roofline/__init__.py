"""Roofline analysis from compiled dry-run artifacts (deliverable g)."""

from . import analysis, hw

__all__ = ["analysis", "hw"]
