"""SketchArray tests: K-loop bit-identity, kernel-vs-core (ragged shapes),
vmapped MLE vs the f64 oracle, merge algebra, masking, and the monitor layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, estimators, qsketch, sketch_array
from repro.kernels import ops
from repro.sketchstream import monitor

# (batch, m, K, block_b, block_m) — deliberately NOT multiples of 8/128 in
# batch/m/K to exercise the padding contracts end to end.
SHAPES = [
    (64, 128, 8, 64, 128),
    (100, 130, 7, 64, 128),  # ragged everything
    (256, 384, 16, 128, 128),
    (513, 257, 33, 256, 128),  # ragged batch + m + K
    (8, 128, 1, 8, 128),  # single sketch degenerates to qsketch
]


def _keyed_stream(n, k, seed, wscale=1.0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, k, n, dtype=np.int32)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n) * wscale).astype(np.float32) + 1e-5
    return jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w)


@pytest.mark.parametrize("batch,m,k,bb,bm", SHAPES)
def test_update_matches_k_independent_sketches(batch, m, k, bb, bm):
    """Row r of the array == a standalone QSketch fed the key-r sub-stream."""
    cfg = SketchConfig(m=m, b=8, seed=batch + m + k)
    keys, ids, w = _keyed_stream(batch, k, seed=batch * 7 + k)
    st = sketch_array.update(cfg, sketch_array.init(cfg, k), keys, ids, w)
    ref = sketch_array.update_reference(cfg, sketch_array.init(cfg, k), keys, ids, w)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))


@pytest.mark.parametrize("batch,m,k,bb,bm", SHAPES)
@pytest.mark.parametrize("b", [4, 8])
def test_kernel_vs_core_bit_identity(batch, m, k, bb, bm, b):
    """Pallas (interpret) vs core segment scatter: BITWISE equal, any shape."""
    cfg = SketchConfig(m=m, b=b, seed=batch + m)
    keys, ids, w = _keyed_stream(batch, k, seed=batch * 3 + m)
    st = sketch_array.init(cfg, k)
    # Warm so the clipping paths both hit.
    st = sketch_array.update(cfg, st, *_keyed_stream(batch, k, seed=1))
    out_kernel = ops.sketch_array_update_op(
        cfg, st, keys, ids, w, block_b=bb, block_m=bm, interpret=True
    )
    out_core = sketch_array.update(cfg, st, keys, ids, w)
    np.testing.assert_array_equal(np.asarray(out_kernel.regs), np.asarray(out_core.regs))


def test_kernel_mask_bit_identity():
    cfg = SketchConfig(m=128, b=8, seed=2)
    keys, ids, w = _keyed_stream(300, 9, seed=11)
    mask = jnp.asarray(np.random.default_rng(0).random(300) < 0.6)
    a = ops.sketch_array_update_op(
        cfg, sketch_array.init(cfg, 9), keys, ids, w, mask=mask, interpret=True
    )
    b = sketch_array.update(cfg, sketch_array.init(cfg, 9), keys, ids, w, mask=mask)
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))


def test_masked_update_matches_reference_oracle():
    """The K-loop oracle takes ``mask`` too, so padded batches are verified
    against truly-dropped rows (not just against the fused path itself)."""
    cfg = SketchConfig(m=96, b=8, seed=3)
    keys, ids, w = _keyed_stream(350, 6, seed=17)
    mask = np.random.default_rng(2).random(350) < 0.55
    fused = sketch_array.update(
        cfg, sketch_array.init(cfg, 6), keys, ids, w, mask=jnp.asarray(mask)
    )
    oracle = sketch_array.update_reference(
        cfg, sketch_array.init(cfg, 6), keys, ids, w, mask=mask
    )
    np.testing.assert_array_equal(np.asarray(fused.regs), np.asarray(oracle.regs))
    # All-masked batch: the oracle must be a strict no-op as well.
    none = sketch_array.update_reference(
        cfg, sketch_array.init(cfg, 6), keys, ids, w, mask=np.zeros(350, bool)
    )
    np.testing.assert_array_equal(
        np.asarray(none.regs), np.asarray(sketch_array.init(cfg, 6).regs)
    )


def test_merge_rejects_mismatched_shapes():
    cfg = SketchConfig(m=64, b=8, seed=5)
    a = sketch_array.init(cfg, 4)
    with pytest.raises(ValueError, match="matching"):
        sketch_array.merge(a, sketch_array.init(cfg, 5))
    with pytest.raises(ValueError, match="matching"):
        sketch_array.merge(a, sketch_array.init(SketchConfig(m=128, b=8, seed=5), 4))


def test_row_rejects_out_of_range():
    cfg = SketchConfig(m=64, b=8, seed=5)
    st = sketch_array.init(cfg, 4)
    with pytest.raises(IndexError):
        sketch_array.row(st, 4)
    with pytest.raises(IndexError):
        sketch_array.row(st, -1)


def test_estimate_all_untouched_rows_zero_with_flag():
    """Fresh rows must report Ĉ = 0 and converged=False (degenerate all-r_min
    likelihood has no interior extremum); touched rows report converged=True."""
    cfg = SketchConfig(m=128, b=8, seed=21)
    k = 5
    st = sketch_array.init(cfg, k)
    est0, _, conv0 = sketch_array.estimate_all_with_ci(cfg, st)
    np.testing.assert_array_equal(np.asarray(est0), 0.0)
    assert not np.asarray(conv0).any()

    keys = jnp.full((400,), 2, jnp.int32)  # traffic only on row 2
    ids = jnp.asarray(np.arange(400, dtype=np.uint32))
    w = jnp.ones((400,), jnp.float32)
    st = sketch_array.update(cfg, st, keys, ids, w)
    est, _, conv = sketch_array.estimate_all_with_ci(cfg, st)
    est, conv = np.asarray(est), np.asarray(conv)
    assert est[2] > 0 and conv[2]
    untouched = np.arange(k) != 2
    np.testing.assert_array_equal(est[untouched], 0.0)
    assert not conv[untouched].any()


def test_masked_rows_are_noops():
    cfg = SketchConfig(m=64, b=8, seed=4)
    keys, ids, w = _keyed_stream(400, 5, seed=21)
    mask = np.random.default_rng(1).random(400) < 0.5
    st = sketch_array.update(
        cfg, sketch_array.init(cfg, 5), keys, ids, w, mask=jnp.asarray(mask)
    )
    ref = sketch_array.update(
        cfg, sketch_array.init(cfg, 5), keys[mask], ids[mask], w[mask]
    )
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))


def test_estimate_all_matches_numpy_oracle():
    """Per-key vmapped f32 MLE vs the per-row f64 oracle (test_estimators
    tolerance: rel < 1e-4)."""
    cfg = SketchConfig(m=256, b=8, seed=6)
    k = 12
    keys, ids, w = _keyed_stream(6000, k, seed=31)
    st = sketch_array.update(cfg, sketch_array.init(cfg, k), keys, ids, w)
    est = np.asarray(sketch_array.estimate_all(cfg, st))
    for r in range(k):
        oracle = estimators.mle_numpy(cfg, np.asarray(st.regs[r]))
        assert abs(est[r] - oracle) / max(oracle, 1e-30) < 1e-4


def test_estimate_all_statistical_accuracy():
    """Each per-key estimate tracks that key's true weighted cardinality."""
    cfg = SketchConfig(m=512, b=8, seed=8)
    k = 6
    rng = np.random.default_rng(41)
    keys = jnp.asarray(rng.integers(0, k, 8000, dtype=np.int32))
    ids = jnp.asarray(rng.integers(0, 2**32, 8000, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 8000).astype(np.float32))
    st = sketch_array.update(cfg, sketch_array.init(cfg, k), keys, ids, w)
    est = np.asarray(sketch_array.estimate_all(cfg, st))
    keys_np, w_np = np.asarray(keys), np.asarray(w, dtype=np.float64)
    for r in range(k):
        true_c = w_np[keys_np == r].sum()
        assert abs(est[r] - true_c) / true_c < 0.35  # m=512 statistical bound


def test_empty_rows_estimate_zero():
    cfg = SketchConfig(m=64, b=8, seed=9)
    k = 4
    keys = jnp.zeros((50,), jnp.int32)  # all traffic on key 0
    ids = jnp.asarray(np.arange(50, dtype=np.uint32))
    w = jnp.ones((50,), jnp.float32)
    st = sketch_array.update(cfg, sketch_array.init(cfg, k), keys, ids, w)
    est = np.asarray(sketch_array.estimate_all(cfg, st))
    assert est[0] > 0
    np.testing.assert_array_equal(est[1:], 0.0)


def test_merge_matches_union_stream():
    cfg = SketchConfig(m=128, b=8, seed=12)
    k = 5
    ka, ia, wa = _keyed_stream(300, k, seed=51)
    kb, ib, wb = _keyed_stream(300, k, seed=52)
    sa = sketch_array.update(cfg, sketch_array.init(cfg, k), ka, ia, wa)
    sb = sketch_array.update(cfg, sketch_array.init(cfg, k), kb, ib, wb)
    merged = sketch_array.merge(sa, sb)
    both = sketch_array.update(cfg, sa, kb, ib, wb)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(both.regs))


def test_row_extraction_is_plain_qsketch():
    cfg = SketchConfig(m=64, b=8, seed=13)
    keys, ids, w = _keyed_stream(200, 3, seed=61)
    st = sketch_array.update(cfg, sketch_array.init(cfg, 3), keys, ids, w)
    keys_np = np.asarray(keys)
    sel = keys_np == 1
    solo = qsketch.update(cfg, qsketch.init(cfg), ids[sel], w[sel])
    np.testing.assert_array_equal(
        np.asarray(sketch_array.row(st, 1).regs), np.asarray(solo.regs)
    )
    est_row = float(qsketch.estimate(cfg, sketch_array.row(st, 1)))
    est_all = float(sketch_array.estimate_all(cfg, st)[1])
    assert est_row == pytest.approx(est_all, rel=1e-6)


# ---------------------------------------------------------------------------
# monitor layer
# ---------------------------------------------------------------------------


def test_monitor_mask_excludes_padding():
    cfg = SketchConfig(m=64, b=8, seed=14)
    ids = jnp.asarray(np.arange(100, dtype=np.uint32))
    mask = jnp.asarray(np.arange(100) < 70)
    st = monitor.update(cfg, monitor.init(cfg), ids, mask=mask)
    assert int(st.n_seen) == 70
    ref = monitor.update(cfg, monitor.init(cfg), ids[:70])
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))


def test_array_monitor_per_key_estimates():
    cfg = SketchConfig(m=256, b=8, seed=15)
    k = 4
    keys, ids, w = _keyed_stream(2000, k, seed=71)
    st = monitor.update_array(cfg, monitor.init_array(cfg, k), keys, ids, w)
    assert int(st.n_seen) == 2000
    est = np.asarray(monitor.estimate_array(cfg, st))
    direct = np.asarray(
        sketch_array.estimate_all(
            cfg, sketch_array.update(cfg, sketch_array.init(cfg, k), keys, ids, w)
        )
    )
    np.testing.assert_array_equal(est, direct)


def test_array_monitor_sparse_keys_via_directory():
    """update_array with dcfg routes sparse 64-bit tenant ids statelessly."""
    from repro.core import key_directory
    from repro.core.key_directory import DirectoryConfig

    cfg = SketchConfig(m=64, b=8, seed=18)
    dcfg = DirectoryConfig(capacity=16, seed=19)
    rng = np.random.default_rng(91)
    keys = key_directory.split_uint64(rng.integers(0, 2**64, 200, dtype=np.uint64))
    ids = jnp.asarray(rng.integers(0, 2**32, 200, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 200).astype(np.float32))

    st = monitor.update_array(cfg, monitor.init_array(cfg, 16), keys, ids, w, dcfg=dcfg)
    slots = key_directory.route_slots(dcfg, keys)
    ref = monitor.update_array(cfg, monitor.init_array(cfg, 16), slots, ids, w)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ref.regs))
    assert int(st.n_seen) == 200


def test_kernel_tenants_op_matches_core():
    """Pallas-backed sparse-tenant entry == core update_tenants, bitwise,
    telemetry included."""
    from repro.core import key_directory
    from repro.core.key_directory import DirectoryConfig

    cfg = SketchConfig(m=128, b=8, seed=22)
    dcfg = DirectoryConfig(capacity=9, seed=23)
    rng = np.random.default_rng(92)
    keys = key_directory.split_uint64(rng.integers(0, 2**64, 300, dtype=np.uint64))
    ids = jnp.asarray(rng.integers(0, 2**32, 300, dtype=np.uint32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, 300).astype(np.float32))
    mask = jnp.asarray(rng.random(300) < 0.7)

    st_k, dir_k = ops.sketch_array_update_tenants_op(
        cfg, dcfg, sketch_array.init(cfg, 9), key_directory.init(dcfg),
        keys, ids, w, mask=mask, interpret=True,
    )
    st_c, dir_c = sketch_array.update_tenants(
        cfg, dcfg, sketch_array.init(cfg, 9), key_directory.init(dcfg),
        keys, ids, w, mask=mask,
    )
    np.testing.assert_array_equal(np.asarray(st_k.regs), np.asarray(st_c.regs))
    np.testing.assert_array_equal(
        np.asarray(dir_k.fingerprints), np.asarray(dir_c.fingerprints)
    )
    assert int(dir_k.n_routed) == int(dir_c.n_routed)


def test_array_monitor_merge():
    cfg = SketchConfig(m=64, b=8, seed=16)
    k = 3
    ka, ia, wa = _keyed_stream(150, k, seed=81)
    kb, ib, wb = _keyed_stream(150, k, seed=82)
    sa = monitor.update_array(cfg, monitor.init_array(cfg, k), ka, ia, wa)
    sb = monitor.update_array(cfg, monitor.init_array(cfg, k), kb, ib, wb)
    merged = monitor.merge_array(cfg, sa, sb)
    both = monitor.update_array(cfg, sa, kb, ib, wb)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(both.regs))
    assert int(merged.n_seen) == 300
