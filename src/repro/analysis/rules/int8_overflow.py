"""int8-overflow — no additive arithmetic on int8 register arrays.

QSketch registers are quantized to ``int8[m]`` (the paper's whole memory
win); the max monoid is closed on int8 so scatter-max / union / compare are
safe at native width, but ``+``, ``-``, ``*``, ``sum`` & friends overflow at
+-127 and *silently wrap* under jnp — corrupting histograms and estimates
without any test failing at small scale. The repo convention is therefore:
**upcast to int32 (or float) before any additive op** (e.g.
``state.regs.astype(jnp.int32) - cfg.r_min``). This rule enforces it over
``core/`` and ``kernels/``.

Taint model (per function, linear flow):

* int8 sources — ``.astype(jnp.int8)``, array creation with
  ``dtype=jnp.int8``, and (convention) names/attributes called ``regs`` /
  ``union_regs`` / ``*_regs`` with no contrary local evidence,
* cleansers — ``.astype(<non-int8>)``, creation with a non-int8 dtype;
  assignment re-types the target name,
* propagation — subscripts, ``jnp.where/maximum/minimum/pad/clip/...``,
  max/min reductions (still int8, still safe),
* violations — BinOp/AugAssign with ``+ - * / // % **``, unary ``-``, and
  additive reductions (``sum``, ``cumsum``, ``prod``, ``dot``, ``mean``,
  ``matmul``, ``einsum``, ``tensordot``) on a tainted operand.

The name convention over-approximates (``FloatSketchState.regs`` is f32 by
design — the LM baseline's min-register sketch); such sites carry a
baseline entry with the justification rather than weakening the rule.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import call_keyword, dotted
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

SCOPE = ("src/repro/core/", "src/repro/kernels/", "src/repro/sketchstream/")

INT8_NAME_HINTS = ("regs", "union_regs")
ARITH_REDUCTIONS = {
    "sum", "cumsum", "prod", "cumprod", "dot", "mean", "average",
    "matmul", "einsum", "tensordot",
}
PROPAGATING = {
    "where", "maximum", "minimum", "max", "min", "pad", "clip", "roll",
    "reshape", "concatenate", "stack", "broadcast_to", "transpose", "flip",
    "take", "take_along_axis", "squeeze", "expand_dims",
}
_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod, ast.Pow)

# Tri-state taint.
INT8, OTHER = "int8", "other"


def _name_hints_int8(name: str) -> bool:
    return name in INT8_NAME_HINTS or name.endswith("_regs")


def _dtype_of(node: ast.expr | None) -> str | None:
    """'int8' / 'other' for an explicit dtype expression, None if unknown."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return INT8 if node.value == "int8" else OTHER
    d = dotted(node)
    if d is None:
        return None
    leaf = d.rsplit(".", 1)[-1]
    if leaf == "int8":
        return INT8
    known = {
        "int16", "int32", "int64", "uint8", "uint16", "uint32", "uint64",
        "float16", "bfloat16", "float32", "float64", "float_", "bool_",
    }
    return OTHER if leaf in known else None


class _FunctionChecker(ast.NodeVisitor):
    """Linear-flow int8 taint over one function (or the module body)."""

    def __init__(self, rule: str, rel: str):
        self.rule = rule
        self.rel = rel
        self.env: dict[str, str] = {}
        self.findings: list[Finding] = []

    # -- taint evaluation --------------------------------------------------

    def taint(self, node: ast.expr) -> str:
        """INT8 if the expression may be an int8 register array."""
        if isinstance(node, ast.Name):
            got = self.env.get(node.id)
            if got is not None:
                return got
            return INT8 if _name_hints_int8(node.id) else OTHER
        if isinstance(node, ast.Attribute):
            if node.attr == "at":
                # ``x.at[i]`` scatter chains are transparent for taint.
                return self.taint(node.value)
            return INT8 if _name_hints_int8(node.attr) else OTHER
        if isinstance(node, ast.Subscript):
            return self.taint(node.value)
        if isinstance(node, ast.IfExp):
            if INT8 in (self.taint(node.body), self.taint(node.orelse)):
                return INT8
            return OTHER
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        return OTHER

    def _call_taint(self, node: ast.Call) -> str:
        func = node.func
        if isinstance(func, ast.Attribute):
            # x.astype(dt) — explicit retype decides.
            if func.attr == "astype" and node.args:
                return _dtype_of(node.args[0]) or OTHER
            # jnp.full(..., dtype=...) and friends.
            if func.attr in {
                "full", "zeros", "ones", "empty", "array", "asarray",
                "full_like", "zeros_like", "ones_like", "empty_like",
            }:
                return _dtype_of(call_keyword(node, "dtype")) or OTHER
            # Propagating ops keep int8 alive: jnp.maximum(regs, y), x.max().
            if func.attr in PROPAGATING:
                operands = [func.value] + list(node.args)
                if any(self.taint(a) == INT8 for a in operands
                       if isinstance(a, ast.expr)):
                    return INT8
                return OTHER
        return OTHER

    # -- violations --------------------------------------------------------

    def _flag(self, node: ast.AST, what: str) -> None:
        self.findings.append(
            Finding(
                self.rule,
                self.rel,
                node.lineno,
                f"{what} on int8 register data without .astype(jnp.int32) upcast",
            )
        )

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, _ARITH_OPS) and INT8 in (
            self.taint(node.left),
            self.taint(node.right),
        ):
            self._flag(node, f"arithmetic '{type(node.op).__name__}'")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, _ARITH_OPS) and INT8 in (
            self.taint(node.target),
            self.taint(node.value),
        ):
            self._flag(node, f"augmented '{type(node.op).__name__}'")
        self.generic_visit(node)

    def visit_UnaryOp(self, node: ast.UnaryOp) -> None:
        if isinstance(node.op, ast.USub) and self.taint(node.operand) == INT8:
            self._flag(node, "negation")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr in (
            ARITH_REDUCTIONS | {"add", "subtract", "multiply"}
        ):
            # Covers jnp.sum(x) (first arg), x.sum() (the base), and
            # additive scatters regs.at[i].add(1) (the at-chain base).
            cands: list[ast.expr] = list(node.args[:1]) + [func.value]
            if any(self.taint(a) == INT8 for a in cands):
                self._flag(node, f"additive op '{func.attr}'")
        self.generic_visit(node)

    # -- env maintenance ---------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        t = self.taint(node.value)
        for target in node.targets:
            if isinstance(target, ast.Name):
                self.env[target.id] = t
            else:
                for n in ast.walk(target):
                    if isinstance(n, ast.Name):
                        # Tuple unpack etc: fall back to name convention.
                        self.env.pop(n.id, None)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self.visit(node.value)
            if isinstance(node.target, ast.Name):
                self.env[node.target.id] = self.taint(node.value)

    def visit_For(self, node: ast.For) -> None:
        for n in ast.walk(node.target):
            if isinstance(n, ast.Name):
                self.env.pop(n.id, None)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested functions get their own checker (fresh env, convention
        # fallback for params).
        inner = _FunctionChecker(self.rule, self.rel)
        for stmt in node.body:
            inner.visit(stmt)
        self.findings += inner.findings

    visit_AsyncFunctionDef = visit_FunctionDef


@register
class Int8OverflowRule(Rule):
    """Flag additive arithmetic on int8-tracked register arrays in
    core/ and kernels/."""

    name = "int8-overflow"
    description = (
        "additive ops (+, -, *, sum, ...) on int8 register arrays must "
        "upcast to int32 first — jnp wraps silently at +-127"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        findings: list[Finding] = []
        for mod in ctx.iter_modules(SCOPE):
            if not ctx.is_selected(mod.rel):
                continue
            checker = _FunctionChecker(self.name, mod.rel)
            checker.visit(mod.tree)
            findings += checker.findings
        return findings
