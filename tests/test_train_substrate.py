"""Optimizer / compression / checkpoint / monitor / data-pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_qsketch
from repro.data.tokens import TokenStream
from repro.sketchstream import monitor
from repro.train import checkpoint, compression, optimizer


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def _toy_params(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (64, 300)) * 0.1,  # 300: non-multiple of block
        "b": jnp.zeros((300,)),
    }


def test_adam_reduces_quadratic_loss():
    params = _toy_params()
    target = jax.tree.map(lambda p: p * 0.0 + 0.5, params)
    ocfg = optimizer.OptConfig(lr=0.05, warmup_steps=0, total_steps=200, weight_decay=0.0)
    state = optimizer.init(params, ocfg)

    def loss(p):
        return sum(jnp.mean((a - b) ** 2) for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = optimizer.apply(params, g, state, ocfg)
    assert float(loss(params)) < 0.05 * l0


def test_quantized_adam_tracks_exact():
    params = _toy_params(1)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    oc_e = optimizer.OptConfig(lr=0.01, warmup_steps=0, quantized=False, weight_decay=0.0)
    oc_q = optimizer.OptConfig(lr=0.01, warmup_steps=0, quantized=True, weight_decay=0.0)
    se, sq = optimizer.init(params, oc_e), optimizer.init(params, oc_q)
    pe, pq = params, params
    for _ in range(10):
        pe, se, _ = optimizer.apply(pe, g, se, oc_e)
        pq, sq, _ = optimizer.apply(pq, g, sq, oc_q)
    for a, b in zip(jax.tree.leaves(pe), jax.tree.leaves(pq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=2e-3)


def test_quantize_blockwise_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 500)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(3), (7, 1)) * 3
    )
    q, s = optimizer.quantize_blockwise(x)
    x2 = optimizer.dequantize_blockwise(q, s, x.shape)
    err = np.abs(np.asarray(x2 - x))
    scale = np.asarray(jnp.abs(x).max(axis=-1, keepdims=True))
    assert (err <= scale / 127.0 * 0.51 + 1e-12).all()
    assert q.dtype == jnp.int8


def test_schedule_warmup_and_decay():
    ocfg = optimizer.OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(optimizer.schedule(ocfg, jnp.int32(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-6


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_error_feedback_is_unbiased_longrun():
    """Sum of compressed grads converges to sum of true grads (EF property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(4, 600)).astype(np.float32)) * 0.01
    e = {"g": jnp.zeros_like(g_true)}
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        out, e = compression.compress({"g": g_true}, e)
        total = total + out["g"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true), atol=1e-4)


def test_wire_bytes():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert compression.wire_bytes(params, compressed=False) == 105 * 4
    assert compression.wire_bytes(params, compressed=True) == 105


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "n": {"b": jnp.ones((3, 4), jnp.int8)}}
    d = str(tmp_path / "ck")
    checkpoint.save(d, 7, tree, {"note": "x"})
    assert checkpoint.latest_step(d) == 7
    restored, manifest = checkpoint.restore(d, 7, tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.zeros(4)}
    for s in [1, 2, 3, 4, 5]:
        checkpoint.save(d, s, tree)
    checkpoint.retain(d, keep=2)
    assert checkpoint.latest_step(d) == 5
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
    assert steps == [4, 5]
    # A stale tmp dir must not be picked up as a checkpoint.
    os.makedirs(os.path.join(d, ".tmp_step_00000099"), exist_ok=True)
    assert checkpoint.latest_step(d) == 5


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = checkpoint.AsyncCheckpointer(d, keep=2)
    tree = {"a": jnp.arange(6)}
    ck.save(3, tree)
    ck.wait()
    assert checkpoint.latest_step(d) == 3
    ck.close()


# ---------------------------------------------------------------------------
# sketch monitor
# ---------------------------------------------------------------------------


def test_monitor_estimates_distinct_tokens():
    cfg = paper_qsketch.suite(m=2048, b=8)
    st = monitor.init(cfg)
    rng = np.random.default_rng(0)
    tokens = rng.zipf(1.3, 60_000) % 12_000  # heavy repeats
    st = monitor.update(cfg, st, jnp.asarray(tokens.astype(np.uint32)))
    est = float(monitor.estimate(cfg, st))
    true = len(np.unique(tokens))
    assert abs(est - true) / true < 0.15, (est, true)


def test_monitor_merge_equals_union():
    cfg = paper_qsketch.suite(m=512, b=8)
    a_ids = jnp.asarray(np.arange(0, 3000, dtype=np.uint32))
    b_ids = jnp.asarray(np.arange(2000, 5000, dtype=np.uint32))
    sa = monitor.update(cfg, monitor.init(cfg), a_ids)
    sb = monitor.update(cfg, monitor.init(cfg), b_ids)
    merged = monitor.merge(cfg, sa, sb)
    both = monitor.update(cfg, monitor.update(cfg, monitor.init(cfg), a_ids), b_ids)
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(both.regs))
    est = float(monitor.estimate(cfg, merged))
    assert abs(est - 5000) / 5000 < 0.25


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_token_stream_deterministic_and_sharded():
    full = TokenStream(1000, batch=8, seq=16, seed=3)
    b0 = full.batch_at(5)
    b1 = full.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    # Shards partition the work deterministically per (step, shard).
    s0 = TokenStream(1000, batch=8, seq=16, seed=3, n_shards=2, shard=0).batch_at(5)
    s1 = TokenStream(1000, batch=8, seq=16, seed=3, n_shards=2, shard=1).batch_at(5)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # Targets are next-token shifted.
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["targets"][:, :-1])


def test_monitor_weighted_expert_stream():
    """MoE routing telemetry: element = expert id, weight = prob mass.

    Weighted cardinality over experts with fixed per-expert weights counts
    'total routed probability mass over DISTINCT experts touched' — the
    expert-collapse signal (a collapsed router touches few experts)."""
    cfg = paper_qsketch.suite(m=512, b=8)
    rng = np.random.default_rng(0)
    n_experts = 64
    # Healthy router: all experts touched.
    ids = rng.integers(0, n_experts, 20_000).astype(np.uint32)
    w = np.full_like(ids, 1.0 / n_experts, dtype=np.float32)
    st = monitor.update(cfg, monitor.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    est = float(monitor.estimate(cfg, st))
    assert abs(est - 1.0) < 0.25, est  # 64 distinct x 1/64 = 1.0
    # Collapsed router: only 4 experts ever chosen.
    ids_c = rng.integers(0, 4, 20_000).astype(np.uint32)
    st_c = monitor.update(cfg, monitor.init(cfg), jnp.asarray(ids_c), jnp.asarray(w[: len(ids_c)]))
    est_c = float(monitor.estimate(cfg, st_c))
    assert est_c < 0.25 * est, (est_c, est)  # collapse is unmistakable
