"""AdamW in pure JAX, with optional int8 block-quantized moments.

The quantized-moment mode is the framework's thematic echo of the paper: the
same move QSketch makes on sketch registers (continuous 64-bit state ->
small integers + a principled de/requantization) applied to optimizer state.
m/v are stored as int8 with per-256-block f32 scales along the LAST axis, so
the quantized state inherits the parameter's sharding (block boundaries
align with shard boundaries whenever last_dim % (tp * 256) == 0, which holds
for every assigned config; otherwise the tiny scale tensor replicates).

Memory: 2 bytes/param of moments instead of 8 — the difference between
kimi-1T fitting a 512-chip train dry-run and not (EXPERIMENTS.md §Dry-run
memory table).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    quantized: bool = False  # int8 m/v


def schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# ---------------------------------------------------------------------------
# int8 block quantization (last-axis blocks)
# ---------------------------------------------------------------------------


def _qshape(shape):
    last = shape[-1] if shape else 1
    nblk = -(-last // _BLOCK)
    return shape[:-1] + (nblk,) if shape else (1,)


def quantize_blockwise(x):
    """f32 -> (int8 q, f32 scale) with per-last-axis-block absmax scaling."""
    shape = x.shape
    last = shape[-1] if shape else 1
    nblk = -(-last // _BLOCK)
    pad = nblk * _BLOCK - last
    xp = jnp.pad(x.reshape(shape[:-1] + (last,)), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(shape[:-1] + (nblk, _BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0  # (..., nblk)
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape[:-1] + (nblk * _BLOCK,))[..., :last], scale


def dequantize_blockwise(q, scale, shape):
    last = shape[-1] if shape else 1
    nblk = scale.shape[-1]
    pad = nblk * _BLOCK - last
    qp = jnp.pad(q.astype(jnp.float32), [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    xb = qp.reshape(shape[:-1] + (nblk, _BLOCK)) * scale[..., None]
    return xb.reshape(shape[:-1] + (nblk * _BLOCK,))[..., :last]


# ---------------------------------------------------------------------------
# Adam state
# ---------------------------------------------------------------------------


def init(params, cfg: OptConfig):
    def leaf(p):
        if cfg.quantized:
            z = jnp.zeros(p.shape, jnp.int8)
            s = jnp.zeros(_qshape(p.shape), jnp.float32)
            return {"m_q": z, "m_s": s, "v_q": z, "v_s": s}
        return {"m": jnp.zeros(p.shape, jnp.float32), "v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.int32(0), "mu": jax.tree.map(leaf, params)}


def spec_tree(param_defs, mesh, cfg: OptConfig):
    """PartitionSpec tree for the optimizer state (mirrors the param specs;
    quantized scale tensors reuse the param axes with divisibility fallback)."""
    from jax.sharding import PartitionSpec as P

    from repro.models import common as mcommon, sharding as msharding

    def leaf(d):
        pspec = msharding.resolve(d.axes, mesh, d.shape)
        if cfg.quantized:
            sspec = msharding.resolve(d.axes, mesh, _qshape(d.shape))
            return {"m_q": pspec, "m_s": sspec, "v_q": pspec, "v_s": sspec}
        return {"m": pspec, "v": pspec}

    return {"step": P(), "mu": mcommon._map_defs(param_defs, leaf)}


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, mu):
        g = g.astype(jnp.float32) * clip
        if cfg.quantized:
            m = dequantize_blockwise(mu["m_q"], mu["m_s"], p.shape)
            v = dequantize_blockwise(mu["v_q"], mu["v_s"], p.shape)
        else:
            m, v = mu["m"], mu["v"]
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = (
            p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
        ).astype(p.dtype)
        if cfg.quantized:
            mq, ms = quantize_blockwise(m)
            vq, vs = quantize_blockwise(v)
            return new_p, {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        return new_p, {"m": m, "v": v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    out = [leaf(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "mu": new_mu}, metrics
