"""Atomic, resumable checkpointing (fault-tolerance substrate).

Layout:   <dir>/step_<N>/manifest.json + one .npy per leaf
Atomicity: written to <dir>/.tmp_step_<N>, fsync'd, then os.rename'd —
a crash mid-save never corrupts the latest checkpoint, and restart resumes
from the newest complete manifest.

Multi-host note: on a real cluster each host writes only its addressable
shards and rank 0 writes the manifest (the path layout already namespaces
by leaf key, so per-host shard files are an additive extension). This
container is single-host, so leaves are saved whole; ``restore`` re-shards
onto any mesh via device_put (see elastic.py for mesh-shape changes).

Async saves run on a daemon thread so the train loop never blocks on I/O
(straggler mitigation: a slow disk must not stall the step clock).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None):
    """Blocking atomic save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":  # .npy has no bf16: store raw u16
            arr = arr.view(np.uint16)
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical_dtype,
        }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``.

    shardings: optional matching pytree of NamedSharding — leaves are
    device_put directly onto it (this is also the elastic-rescale path:
    the target mesh need not match the mesh that wrote the checkpoint).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, treedef = _flatten(like_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)

    out = {}
    for key in leaves:
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(path, info["file"]))
        if info["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(np.uint16).view(ml_dtypes.bfloat16)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[key])
        out[key] = arr
    ordered = [out[k] for k in leaves]
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest


def retain(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir) if n.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Daemon-thread checkpoint writer; at most one save in flight.

    ``save`` snapshots device arrays to host synchronously (cheap) and queues
    the disk write. ``wait`` drains the queue (call before exit)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, metadata = item
            try:
                save(self.ckpt_dir, step, host_tree, metadata)
                retain(self.ckpt_dir, self.keep)
            except Exception as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree, metadata=None):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, metadata))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
