"""qobs — host-side observability for the sketch stack (DESIGN.md §10).

Four parts, all strictly OUTSIDE jit (no module here may touch a traced
value — emissions are host Python, guarded by ``jax.core.trace_state_clean``
wherever a caller might sit inside a traced region):

* ``obs.metrics`` — a process-local registry of counters, gauges, and
  log2-bucketed histograms (the paper's quantization idiom applied to
  telemetry) with namespaced snake_case names, per-series labels,
  delta/cumulative snapshots, and a no-op path when disabled.
* ``obs.trace``   — span-based stage tracing (push/seal/dispatch/retire/
  rotate/estimate/solve) with nesting via contextvars, Chrome trace-event
  JSON export loadable in Perfetto, and a sampled ``block_until_ready``
  hook so device wall-time is attributable without syncing every batch.
* ``obs.health``  — sketch self-introspection over every container state
  (top-bin saturation, histogram occupancy, union-cache staleness,
  directory load, anytime-vs-MLE drift, CI width) behind one
  ``health_report`` with configurable warn thresholds.
* ``obs.export``  — Prometheus text-format and JSONL snapshot writers,
  wired into ``launch/train.py`` / ``launch/serve.py`` (``--obs-jsonl``,
  ``--obs-prom``) and the ``scripts/obs_dump.py`` CLI.
"""

from repro.obs import export, health, metrics, trace  # noqa: F401
from repro.obs.health import health_report  # noqa: F401
from repro.obs.metrics import default_registry  # noqa: F401
from repro.obs.trace import span  # noqa: F401
