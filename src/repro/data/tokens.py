"""LM token pipeline: deterministic, host-sharded, resume-exact.

Batches are generated from a counter-based PRNG keyed on (seed, step,
shard), so (a) every host materializes only its shard, (b) a restart at
step N reproduces the stream exactly, and (c) elastic re-sharding (different
host count) still yields the same global batch — the three properties a
fault-tolerant pipeline needs. Token frequencies are Zipf(1.2) over the
vocab to give the coverage sketch a realistic heavy-tail stream.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0, n_shards: int = 1, shard: int = 0, n_docs: int = 0):
        assert batch % n_shards == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.n_shards, self.shard = seed, n_shards, shard
        self.n_docs = n_docs
        # Precompute a Zipf CDF over the vocab (rank-frequency law).
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** -1.2
        self._cdf = np.cumsum(p / p.sum())
        if n_docs:
            # Sparse 64-bit document ids with a Zipf rank-frequency law, so
            # per-document telemetry sees a realistic heavy tail of sources
            # recurring across steps. Ids are fixed by the seed alone —
            # every host and every resume sees the same document universe.
            doc_rng = np.random.default_rng((seed, 0xD0C))
            self._doc_ids = doc_rng.integers(0, 2**64, n_docs, dtype=np.uint64)
            dp = np.arange(1, n_docs + 1, dtype=np.float64) ** -1.1
            self._doc_cdf = np.cumsum(dp / dp.sum())

    def _sample(self, rng, shape):
        u = rng.random(shape)
        return np.searchsorted(self._cdf, u).astype(np.int32)

    def batch_at(self, step: int):
        """Global batch's local shard for this host at a given step.

        With ``n_docs`` set, each sequence carries its source document's
        sparse 64-bit id as a (doc_ids lo, doc_ids_hi) uint32 pair — the
        tenant-key convention the train step's per-document telemetry
        expects (JAX x64 is off, so 64-bit ids travel as two words).
        """
        per = self.batch // self.n_shards
        rng = np.random.default_rng((self.seed, step, self.shard))
        toks = self._sample(rng, (per, self.seq + 1))
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.n_docs:
            ranks = np.searchsorted(self._doc_cdf, rng.random(per))
            docs = self._doc_ids[ranks]
            batch["doc_ids"] = (docs & np.uint64(0xFFFFFFFF)).astype(np.uint32)
            batch["doc_ids_hi"] = (docs >> np.uint64(32)).astype(np.uint32)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
