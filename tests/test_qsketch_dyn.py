"""QSketch-Dyn: exact-scan vs numpy oracle, unbiasedness, batch-mode bias."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, qsketch_dyn


def _stream(n, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**32, n, dtype=np.uint32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    return ids, w


def test_scan_matches_numpy_oracle():
    cfg = SketchConfig(m=64, b=8, seed=5)
    ids, w = _stream(400, seed=1)
    d = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    regs, hist, chat = qsketch_dyn.update_numpy(cfg, ids, np.zeros_like(ids), w)
    np.testing.assert_array_equal(np.asarray(d.regs, np.int64), regs)
    np.testing.assert_array_equal(np.asarray(d.hist, np.int64), hist)
    assert abs(float(d.chat) - chat) / max(chat, 1e-9) < 1e-4


def test_duplicates_do_not_double_count():
    """Feeding the same stream twice must leave Ĉ unchanged (Thm. 2 premise)."""
    cfg = SketchConfig(m=128, b=8, seed=6)
    ids, w = _stream(500, seed=2)
    d1 = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    d2 = qsketch_dyn.update_scan(cfg, d1, jnp.asarray(ids), jnp.asarray(w))
    assert float(d1.chat) == float(d2.chat)
    np.testing.assert_array_equal(np.asarray(d1.regs), np.asarray(d2.regs))


def test_estimator_unbiased():
    """Mean of Ĉ over trials within a few stderr of true C (Thm. 2)."""
    n = 2000
    ests = []
    true_c = None
    for t in range(25):
        cfg = SketchConfig(m=256, b=8, seed=3000 + t)
        ids, w = _stream(n, seed=t)
        true_c = float(w.astype(np.float64).sum())
        d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        ests.append(float(d.chat))
    mean = np.mean(ests)
    stderr = np.std(ests) / np.sqrt(len(ests))
    assert abs(mean - true_c) < 4 * stderr + 0.01 * true_c, (mean, true_c, stderr)


def test_batch_vs_scan_bias_small():
    """Batch-stale q_R deviates from the exact chain by << sketch noise."""
    cfg = SketchConfig(m=256, b=8, seed=8)
    ids, w = _stream(4000, seed=9)
    exact = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    batched = qsketch_dyn.init(cfg)
    for i in range(0, 4000, 512):
        batched = qsketch_dyn.update_batch(cfg, batched, jnp.asarray(ids[i : i + 512]), jnp.asarray(w[i : i + 512]))
    # Registers identical (same hash randomness, max-scatter).
    np.testing.assert_array_equal(np.asarray(exact.regs), np.asarray(batched.regs))
    c_exact, c_batch = float(exact.chat), float(batched.chat)
    assert abs(c_exact - c_batch) / c_exact < 0.05, (c_exact, c_batch)


def test_within_batch_duplicates_counted_once():
    cfg = SketchConfig(m=128, b=8, seed=10)
    ids, w = _stream(100, seed=11)
    dup_ids = np.concatenate([ids, ids])
    dup_w = np.concatenate([w, w])
    a = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    b = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(dup_ids), jnp.asarray(dup_w))
    assert float(a.chat) == pytest.approx(float(b.chat), rel=1e-6)
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))


def test_merge_reestimates():
    cfg = SketchConfig(m=256, b=8, seed=12)
    ids1, w1 = _stream(1500, seed=20)
    ids2, w2 = _stream(1500, seed=21)
    a = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids1), jnp.asarray(w1))
    b = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids2), jnp.asarray(w2))
    merged = qsketch_dyn.merge(cfg, a, b)
    true_c = float(w1.astype(np.float64).sum() + w2.astype(np.float64).sum())
    # MLE over merged registers: statistical tolerance at m=256.
    assert abs(float(merged.chat) - true_c) / true_c < 0.35
    # Merged registers are the element-wise max.
    np.testing.assert_array_equal(
        np.asarray(merged.regs), np.maximum(np.asarray(a.regs), np.asarray(b.regs))
    )


def test_hist_consistent_with_regs():
    cfg = SketchConfig(m=128, b=8, seed=13)
    ids, w = _stream(2000, seed=22)
    d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    regs = np.asarray(d.regs, np.int64)
    expected = np.bincount(regs[regs > cfg.r_min] - cfg.r_min, minlength=cfg.num_bins)
    np.testing.assert_array_equal(np.asarray(d.hist), expected)


def test_mle_reestimate_close_to_running():
    cfg = SketchConfig(m=512, b=8, seed=14)
    ids, w = _stream(5000, seed=23)
    d = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
    running = float(d.chat)
    mle = float(qsketch_dyn.estimate_mle(cfg, d))
    true_c = float(w.astype(np.float64).sum())
    assert abs(running - true_c) / true_c < 0.2
    assert abs(mle - true_c) / true_c < 0.2


# ---------------------------------------------------------------------------
# padded-duplicate shadowing regression + degenerate-input contracts
# ---------------------------------------------------------------------------


def test_padded_duplicate_does_not_shadow_live_row():
    """Regression: a masked-off padding row sharing an id with a live row
    must not claim the dedup first-occurrence slot — the padded batch must be
    bit-identical (regs/hist) to the numpy oracle fed only the live rows."""
    cfg = SketchConfig(m=64, b=8, seed=5)
    ids, w = _stream(40, seed=1)
    # Padding rows duplicate live ids and sort FIRST (prepended -> lowest
    # original index, which the pre-fix stable lexsort rewarded).
    pad_ids = np.concatenate([ids[:7], ids])
    pad_w = np.concatenate([np.ones(7, np.float32), w])
    mask = np.concatenate([np.zeros(7, bool), np.ones(40, bool)])

    d = qsketch_dyn.update_batch(
        cfg, qsketch_dyn.init(cfg), jnp.asarray(pad_ids), jnp.asarray(pad_w), mask=jnp.asarray(mask)
    )
    regs, hist, chat = qsketch_dyn.update_numpy(cfg, ids, np.zeros_like(ids), w)
    np.testing.assert_array_equal(np.asarray(d.regs, np.int64), regs)
    np.testing.assert_array_equal(np.asarray(d.hist, np.int64), hist)
    # chat deviates from the oracle only by the batch-staleness of q_R and
    # of the change-indicators — a dropped live row would be a missing w/q
    # term far beyond this bound.
    assert abs(chat - float(d.chat)) < 0.05 * chat

    # Same contract through update_scan (mask path, no dedup involved).
    ds = qsketch_dyn.update_scan(
        cfg, qsketch_dyn.init(cfg), jnp.asarray(pad_ids), jnp.asarray(pad_w), mask=jnp.asarray(mask)
    )
    np.testing.assert_array_equal(np.asarray(ds.regs, np.int64), regs)
    assert abs(float(ds.chat) - chat) / chat < 1e-4


def test_oracle_mask_matches_filtered_stream():
    cfg = SketchConfig(m=64, b=8, seed=7)
    ids, w = _stream(60, seed=3)
    mask = np.random.default_rng(4).random(60) < 0.6
    r1, h1, c1 = qsketch_dyn.update_numpy(cfg, ids, np.zeros_like(ids), w, mask=mask)
    r2, h2, c2 = qsketch_dyn.update_numpy(cfg, ids[mask], np.zeros_like(ids[mask]), w[mask])
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(h1, h2)
    assert c1 == c2


def test_degenerate_weights_dropped_not_quantized():
    """w <= 0 / non-finite rows are dropped as if masked: they add nothing,
    and they cannot shadow a live positive duplicate out of the batch."""
    cfg = SketchConfig(m=128, b=8, seed=10)
    ids, w = _stream(50, seed=11)
    bad_ids = np.concatenate([ids[:5], ids])
    bad_w = np.concatenate(
        [np.array([0.0, -1.0, np.nan, np.inf, -np.inf], np.float32), w]
    )
    for update in (qsketch_dyn.update_batch, qsketch_dyn.update_scan):
        d = update(cfg, qsketch_dyn.init(cfg), jnp.asarray(bad_ids), jnp.asarray(bad_w))
        ref = update(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(d.regs), np.asarray(ref.regs))
        np.testing.assert_array_equal(np.asarray(d.hist), np.asarray(ref.hist))
        assert float(d.chat) == pytest.approx(float(ref.chat), rel=1e-6)

    # All-degenerate batch: strict no-op.
    d0 = qsketch_dyn.update_batch(
        cfg, qsketch_dyn.init(cfg), jnp.asarray(ids[:5]), jnp.zeros(5, jnp.float32)
    )
    assert float(d0.chat) == 0.0
    np.testing.assert_array_equal(np.asarray(d0.regs), np.asarray(qsketch_dyn.init(cfg).regs))


def test_untouched_state_estimates_zero():
    """estimate_mle and merge on fully untouched states return Ĉ = 0 (no MLE
    iteration on an empty histogram) — the SketchArray untouched-row contract."""
    cfg = SketchConfig(m=64, b=8, seed=13)
    d0 = qsketch_dyn.init(cfg)
    assert float(qsketch_dyn.estimate_mle(cfg, d0)) == 0.0
    merged = qsketch_dyn.merge(cfg, d0, d0)
    assert float(merged.chat) == 0.0
    np.testing.assert_array_equal(np.asarray(merged.regs), np.asarray(d0.regs))
    np.testing.assert_array_equal(np.asarray(merged.hist), np.asarray(d0.hist))
    # Merging an untouched state INTO a touched one keeps the touched estimate.
    ids, w = _stream(3000, seed=14)
    d = qsketch_dyn.update_batch(cfg, d0, jnp.asarray(ids), jnp.asarray(w))
    half = qsketch_dyn.merge(cfg, d, d0)
    np.testing.assert_array_equal(np.asarray(half.regs), np.asarray(d.regs))
    assert float(half.chat) > 0


def test_duplicate_flood_staleness_property():
    """Adversarial within-batch duplicate floods: update_batch vs update_scan
    vs the numpy oracle. Registers/hist bitwise equal; the scan matches the
    oracle tightly; the batch-stale chat stays within the staleness bound
    (q_R and the change-indicators are both frozen at batch start, so the
    deviation can run in either direction but is bounded by the flood's
    distinct-element count, not the flood length).
    """
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = SketchConfig(m=64, b=8, seed=99)

    @settings(max_examples=25, deadline=None)
    @given(
        pool=st.integers(min_value=1, max_value=12),
        n=st.integers(min_value=1, max_value=120),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def prop(pool, n, seed):
        rng = np.random.default_rng(seed)
        pool_ids = rng.integers(0, 2**32, pool, dtype=np.uint32)
        pool_w = rng.uniform(0.1, 50.0, pool).astype(np.float32)
        pick = rng.integers(0, pool, n)
        ids, w = pool_ids[pick], pool_w[pick]  # weight is a function of the id

        batch = qsketch_dyn.update_batch(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        scan = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        regs, hist, chat = qsketch_dyn.update_numpy(cfg, ids, np.zeros_like(ids), w)

        np.testing.assert_array_equal(np.asarray(batch.regs), np.asarray(scan.regs))
        np.testing.assert_array_equal(np.asarray(batch.hist), np.asarray(scan.hist))
        np.testing.assert_array_equal(np.asarray(scan.regs, np.int64), regs)
        assert abs(float(scan.chat) - chat) <= 1e-4 * max(chat, 1.0)
        if chat > 0:
            assert abs(chat - float(batch.chat)) / chat < 0.5

    prop()
