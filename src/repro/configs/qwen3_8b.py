"""qwen3-8b [dense] — qk_norm + GQA. 36L d_model=4096 32H (kv=8) d_ff=12288
vocab=151936 [hf:Qwen/Qwen3-8B; hf]. Full attention -> long_500k skipped."""

from repro.models import LayerSpec, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab=151936,
        pattern=(LayerSpec(),),
        qk_norm=True,
        rope_theta=1_000_000.0,
        max_seq=40960,
    )
