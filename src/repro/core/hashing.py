"""Portable, deterministic, counter-based hashing for sketch updates.

Every sketch in this framework derives its randomness from *stateless* integer
mixing of (element id, register index, salt). This matters for three reasons:

1. Determinism across hosts: a distributed stream sharded over 512 chips must
   hash element x to the same h_j(x) everywhere, or the merge algebra
   (element-wise max/min of registers) silently breaks.
2. Portability into Pallas: the same jnp integer ops run unchanged inside a
   ``pl.pallas_call`` kernel body, in interpret mode on CPU, and in the pure
   jnp reference oracle, so kernel-vs-ref tests are bit-exact.
3. No PRNG state threading: hashes are pure functions, so sketch updates are
   commutative/associative batched ops (see DESIGN.md §4.1).

The mixer is murmur3-style (multiply/rotate/xor rounds + fmix32 finalizer).
It is *not* cryptographic; it passes the empirical uniformity tests in
``tests/test_hashing.py`` which is the bar a sketch needs.
"""

from __future__ import annotations

import jax.numpy as jnp

# murmur3 constants.
_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_FMIX1 = 0x85EBCA6B
_FMIX2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9

# 2^-24 and 2^-25 as float32-exact python floats.
_INV_2_24 = float(2.0**-24)
_HALF_ULP = float(2.0**-25)


def _u32(x: int) -> jnp.ndarray:
    return jnp.uint32(x & 0xFFFFFFFF)


def _rotl(x, r: int):
    return (x << _u32(r)) | (x >> _u32(32 - r))


def fmix32(h):
    """murmur3 finalizer: full-avalanche 32-bit mix."""
    h = h ^ (h >> _u32(16))
    h = h * _u32(_FMIX1)
    h = h ^ (h >> _u32(13))
    h = h * _u32(_FMIX2)
    h = h ^ (h >> _u32(16))
    return h


def hash_words(words, salt: int):
    """Mix a sequence of uint32 words (broadcastable arrays) into uint32 bits.

    ``words`` is a tuple of integer arrays; they are broadcast against each
    other, so ``hash_words((ids[:, None], j[None, :]), salt)`` produces the
    full (B, m) table in one vectorized call.
    """
    h = _u32(_GOLDEN ^ (salt & 0xFFFFFFFF))
    for i, w in enumerate(words):
        k = w.astype(jnp.uint32) * _u32(_C1)
        k = _rotl(k, 15)
        k = k * _u32(_C2)
        h = h ^ k
        h = _rotl(h, 13)
        h = h * _u32(5) + _u32(0xE6546B64 + 0x9E3779B1 * i)
    # Length padding is unnecessary: word count is static per call site.
    return fmix32(h)


def bits_to_unit_open(bits):
    """uint32 bits -> float32 strictly inside (0, 1).

    Uses the top 24 bits (exact in f32) and adds half an ulp so 0 is excluded;
    the maximum value is 1 - 2^-25 < 1. Safe as an argument to log().
    """
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        _INV_2_24
    ) + jnp.float32(_HALF_ULP)


def uniform01(words, salt: int):
    """Uniform (0,1) float32 from integer words. u = h(words) mapped to (0,1)."""
    return bits_to_unit_open(hash_words(words, salt))


def neg_log_uniform(words, salt: int):
    """-ln(U) with U ~ Uniform(0,1): a standard Exp(1) variable, in (2^-25, ~17.3]."""
    return -jnp.log(uniform01(words, salt))


def hash_mod(words, salt: int, m: int):
    """Map words uniformly onto {0, ..., m-1} (register chooser g(x)).

    Uses multiply-shift on the high bits rather than ``% m`` so the map stays
    unbiased for non-power-of-two m (bias < 2^-32): floor(h * m / 2^32),
    computed 64-bit-free in 16-bit limbs with explicit carries so it is exact
    for any m < 2^31 — tenant-directory capacities (core/key_directory.py)
    exceed 2^16, where a single-limb shortcut would silently wrap and crush
    the slot space. For m <= 2^16 this is bit-identical to the historical
    two-halves form (m_hi = 0 kills the extra terms), so register choosers
    are unchanged.
    """
    if not 0 < m < 2**31:
        raise ValueError(f"hash_mod needs 0 < m < 2^31, got {m}")
    h = hash_words(words, salt)
    m32 = _u32(m)
    h_hi, h_lo = h >> _u32(16), h & _u32(0xFFFF)
    m_hi, m_lo = m32 >> _u32(16), m32 & _u32(0xFFFF)
    # h*m = h_hi*m_hi*2^32 + (h_hi*m_lo + h_lo*m_hi)*2^16 + h_lo*m_lo;
    # floor(h*m / 2^32) = h_hi*m_hi + (mid-sum + lo-carry) >> 16, where the
    # mid-sum of two <2^32 products can itself wrap — detect and re-add the
    # carry at bit 16 of the result.
    lo_prod = (h_lo * m_lo) >> _u32(16)  # < 2^16
    mid = h_hi * m_lo
    mid2 = mid + h_lo * m_hi
    carry = (mid2 < mid).astype(jnp.uint32)
    mid3 = mid2 + lo_prod
    carry = carry + (mid3 < lo_prod).astype(jnp.uint32)
    t = h_hi * m_hi + (mid3 >> _u32(16)) + (carry << _u32(16))
    return t.astype(jnp.int32)


def split_id64(ids):
    """Normalize element ids to a (lo, hi) pair of uint32 arrays.

    Accepts int32/uint32 (hi = 0) or a tuple already in (lo, hi) form. 64-bit
    ids should be pre-split by the caller (JAX x64 is off by default).
    """
    if isinstance(ids, tuple):
        lo, hi = ids
        return lo.astype(jnp.uint32), hi.astype(jnp.uint32)
    return ids.astype(jnp.uint32), jnp.zeros_like(ids, dtype=jnp.uint32)
