"""Statistical + determinism tests for the portable hash layer."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing


def test_uniform01_range():
    ids = jnp.arange(100_000, dtype=jnp.uint32)
    u = np.asarray(hashing.uniform01((ids,), salt=123))
    assert u.min() > 0.0
    assert u.max() < 1.0


def test_uniform01_moments():
    ids = jnp.arange(200_000, dtype=jnp.uint32)
    u = np.asarray(hashing.uniform01((ids,), salt=7), dtype=np.float64)
    # mean 0.5 +- ~5 sigma/sqrt(n); std of U(0,1) is 0.2887
    assert abs(u.mean() - 0.5) < 5 * 0.2887 / np.sqrt(len(u))
    assert abs(u.std() - 0.28867) < 5e-3


def test_uniform01_chi_square():
    """64-bin chi-square uniformity; threshold ~5 sigma for 63 dof."""
    ids = jnp.arange(256_000, dtype=jnp.uint32)
    u = np.asarray(hashing.uniform01((ids,), salt=99))
    counts, _ = np.histogram(u, bins=64, range=(0, 1))
    expected = len(u) / 64
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    # dof=63: mean 63, std sqrt(126)=11.2; 5 sigma -> 119
    assert chi2 < 119, chi2


def test_salt_independence():
    ids = jnp.arange(50_000, dtype=jnp.uint32)
    u1 = np.asarray(hashing.uniform01((ids,), salt=1), dtype=np.float64)
    u2 = np.asarray(hashing.uniform01((ids,), salt=2), dtype=np.float64)
    corr = np.corrcoef(u1, u2)[0, 1]
    assert abs(corr) < 0.02, corr


def test_word_sensitivity():
    """Flipping one bit of any word should decorrelate the output."""
    ids = jnp.arange(50_000, dtype=jnp.uint32)
    u1 = np.asarray(hashing.uniform01((ids, jnp.uint32(0)), salt=5), dtype=np.float64)
    u2 = np.asarray(hashing.uniform01((ids, jnp.uint32(1)), salt=5), dtype=np.float64)
    assert abs(np.corrcoef(u1, u2)[0, 1]) < 0.02


def test_determinism_across_calls():
    ids = jnp.arange(1000, dtype=jnp.uint32)
    a = np.asarray(hashing.hash_words((ids,), salt=42))
    b = np.asarray(hashing.hash_words((ids,), salt=42))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("m", [3, 64, 100, 256, 1000])
def test_hash_mod_range_and_balance(m):
    ids = jnp.arange(64_000, dtype=jnp.uint32)
    j = np.asarray(hashing.hash_mod((ids,), salt=11, m=m))
    assert j.min() >= 0 and j.max() < m
    counts = np.bincount(j, minlength=m)
    expected = len(ids) / m
    # Poisson-ish: allow 6 sigma deviation per bin
    assert (np.abs(counts - expected) < 6 * np.sqrt(expected) + 6).all()


@pytest.mark.parametrize("m", [65_537, 10**6, 2**20, 2**30 - 1])
def test_hash_mod_exact_beyond_16_bits(m):
    """Directory capacities exceed 2^16: the limb arithmetic must equal the
    true floor(h*m / 2^32) (the old two-halves shortcut wrapped silently)."""
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 2**32, 20_000, dtype=np.uint32))
    got = np.asarray(hashing.hash_mod((ids,), salt=9, m=m)).astype(np.int64)
    h = np.asarray(hashing.hash_words((ids,), salt=9)).astype(np.uint64)
    np.testing.assert_array_equal(got, ((h * np.uint64(m)) >> np.uint64(32)).astype(np.int64))


def test_hash_mod_rejects_bad_m():
    ids = jnp.arange(8, dtype=jnp.uint32)
    with pytest.raises(ValueError):
        hashing.hash_mod((ids,), salt=1, m=0)
    with pytest.raises(ValueError):
        hashing.hash_mod((ids,), salt=1, m=2**31)


def test_neg_log_uniform_is_exponential():
    ids = jnp.arange(200_000, dtype=jnp.uint32)
    e = np.asarray(hashing.neg_log_uniform((ids,), salt=3), dtype=np.float64)
    assert (e > 0).all()
    assert abs(e.mean() - 1.0) < 0.02  # Exp(1) mean
    assert abs(e.std() - 1.0) < 0.02  # Exp(1) std
