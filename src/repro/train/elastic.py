"""Elastic scaling: re-shard a train state onto a different mesh.

A node failure that shrinks the fleet (or a capacity grant that grows it)
changes the mesh shape; parameters, optimizer moments and sketch telemetry
are all plain pytrees, so elasticity is: rebuild the PartitionSpec tree
against the NEW mesh (sharding.resolve re-checks divisibility per dim) and
device_put the checkpointed host arrays onto it. Nothing about the state
encodes the old mesh.

The data pipeline side: global batch stays fixed; per-host batch = global /
(new data-parallel size); the token iterator is seeded by (step, shard_id)
so a resumed run consumes the stream exactly where it left off regardless
of the host count (data/tokens.py).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models import common as mcommon, sharding as msharding


def reshard_state(host_state, defs_tree, new_mesh: Mesh):
    """Place a host-memory state pytree onto a new mesh.

    host_state: pytree of np arrays matching defs_tree's structure (params);
    extra state (optimizer moments etc.) should be resharded with
    ``reshard_like`` using the param leaf it mirrors.
    """
    shardings = msharding.sharding_tree(defs_tree, new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host_state, shardings)


def reshard_like(host_tree, spec_tree, new_mesh: Mesh):
    """Generic: place host arrays with an explicit PartitionSpec tree."""
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(new_mesh, sp)), host_tree, spec_tree
    )


def degrade_plan(n_devices: int, want_model: int = 16):
    """Pick a (data, model) mesh for whatever device count survives.

    Keeps TP at ``want_model`` while possible (model-parallel degree is a
    memory requirement, not a throughput choice), shrinking data parallelism
    first; falls back to smaller TP only below want_model devices.
    """
    model = min(want_model, n_devices)
    while n_devices % model:
        model //= 2
    return (n_devices // model, model)
