"""Anytime network monitoring (paper App. A.4 scenario): QSketch-Dyn tracks
the total traffic volume of DISTINCT flows in real time.

Flows = (src,dst) pairs weighted by flow size; the stream repeats flows with
a Zipf law (elephants and mice). QSketch-Dyn's running martingale estimate
is available after every packet for O(1) work — the anomaly-detection use
case the paper targets: a sudden jump in distinct-flow volume (e.g. a scan
or DDoS) shows immediately.

    PYTHONPATH=src python examples/netflow_monitor.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, qsketch_dyn
from repro.data import synthetic


def main():
    cfg = SketchConfig(m=1024, b=8, seed=11)
    n_flows, n_packets = 30_000, 240_000
    ids, sizes, total_c = synthetic.netflow(n_flows, n_packets, seed=2)

    # "Attack" at 60% of the stream: 4000 brand-new flows appear.
    attack_at = int(n_packets * 0.6)
    atk_ids, atk_sizes, atk_c = synthetic.netflow(4_000, 20_000, seed=99)
    ids = np.concatenate([ids[:attack_at], atk_ids, ids[attack_at:]])
    sizes = np.concatenate([sizes[:attack_at], atk_sizes, sizes[attack_at:]])

    st = qsketch_dyn.init(cfg)
    bs = 8192
    print(f"{'packets':>9} {'est. distinct-flow bytes':>26} {'delta/batch':>12}")
    prev = 0.0
    for i in range(0, len(ids), bs):
        st = qsketch_dyn.update_batch(
            cfg, st, jnp.asarray(ids[i : i + bs]), jnp.asarray(sizes[i : i + bs])
        )
        est = float(qsketch_dyn.estimate(st))
        flag = "  <-- surge" if est - prev > 2.5 * (prev / max(i // bs, 1) if i else est) else ""
        if (i // bs) % 4 == 0 or flag:
            print(f"{i + bs:>9} {est:>26,.0f} {est - prev:>12,.0f}{flag}")
        prev = est

    print(f"\nfinal estimate: {float(qsketch_dyn.estimate(st)):,.0f}")
    print(f"true total:     {total_c + atk_c:,.0f}")
    print(f"sketch memory:  {cfg.m * cfg.b // 8} B registers + {cfg.num_bins * 4} B histogram")


if __name__ == "__main__":
    main()
