"""Estimation-layer solver sweep: newton vs lut vs fused across K.

The unified estimation layer (core/estimation.py, DESIGN.md §8.7) exists to
kill the batched-MLE wall the ROADMAP records — the vmapped safeguarded
Newton runs every row to the slowest row's iteration count, ~65 s at
K = 2^20 — without giving up the histogram-MLE's accuracy. This suite
measures the three solvers on identical histogram batches:

  * ``newton`` — the bit-identity reference (``estimators.qsketch_mle``
    vmapped). Swept only up to K = 2^14 quick / 2^17 full: the 2^20 cell
    takes ~65 s per repetition and its cost is already documented.
  * ``lut``   — the rebased-grid table solver; the acceptance bar is
    K = 2^20 under 1 s (measured ~0.86 s on the single-core host).
  * ``fused`` — the Pallas one-pass kernel via ``ops.estimate_rows_op``.
    On CPU it executes in interpret mode (a Python-level emulation whose
    wall time says nothing about TPU throughput), so it is swept only at
    the smallest K as an end-to-end liveness check.

Also timed: the sliding-window sub-ring read (``window_array
.estimate_window`` with w < E), whose query cost is union + histogram MLE —
the case where the solver choice dominates an interactive read path.

The sweep is cumulative (common.merge_save): quick/smoke runs re-measure
only small-K cells and never erase the paper-scale rows a ``--full`` run
paid for. scripts/check_bench_schema.py guards the merged JSON.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, estimation, sketch_array, window_array
from repro.kernels import ops

from . import common

_M = 64  # registers per row: keeps state building cheap; the solve is O(2^b)


def _loaded_hists(cfg, k, seed):
    """Histograms of k live sketch rows at heterogeneous scales."""
    rng = np.random.default_rng(seed)
    st = sketch_array.init(cfg, k)
    batch = 65536
    for i in range(max(2 * k // batch, 2)):
        keys = jnp.asarray(rng.integers(0, k, batch, dtype=np.int32))
        ids = jnp.asarray(rng.integers(0, 2**32, batch, dtype=np.uint32))
        scale = np.exp2(rng.uniform(-6, 12, batch)).astype(np.float32)
        w = jnp.asarray((rng.gamma(1.0, 2.0, batch).astype(np.float32) + 1e-5) * scale)
        st = sketch_array.update(cfg, st, keys, ids, w)
    hists = sketch_array.histograms(cfg, st)
    jax.block_until_ready(hists)
    return st, hists


def run(quick=True):
    rows = []
    swept = set()
    cfg = SketchConfig(m=_M, b=8, seed=23)

    ks = [2**10, 2**14] if quick else [2**10, 2**14, 2**17, 2**20]
    newton_cap = 2**14 if quick else 2**17
    for k in ks:
        st, hists = _loaded_hists(cfg, k, seed=k)
        swept.add((k,))
        iters = 3  # median-of-3: single samples at large K are too noisy

        # Steady-state read cost: the first touches of a GiB-scale histogram
        # block pay page-in + frequency ramp, so warm twice and take the
        # median of five (~4 s extra at the largest K).
        t_lut = common.time_fn(
            lambda h: estimation.estimate_hists(cfg, h, kind="full", solver="lut"),
            hists, warmup=2, iters=5,
        )
        rows.append({"figure": "estimation_solvers", "method": "lut", "k": k, "m": _M, "ms": t_lut * 1e3})
        common.csv_row(f"estimation/K{k}/lut", t_lut * 1e6, f"ms={t_lut*1e3:.1f}")

        if k <= newton_cap:
            t_new = common.time_fn(
                lambda h: estimation.estimate_hists(cfg, h, kind="full", solver="newton"),
                hists, warmup=1, iters=iters,
            )
            x = t_new / max(t_lut, 1e-9)
            rows.append({"figure": "estimation_solvers", "method": "newton", "k": k, "m": _M, "ms": t_new * 1e3})
            rows.append({"figure": "estimation_solvers", "method": "speedup", "k": k, "m": _M, "x": x})
            common.csv_row(f"estimation/K{k}/newton", t_new * 1e6, f"ms={t_new*1e3:.1f}")
            common.csv_row(f"estimation/K{k}/speedup", 0.0, f"newton/lut={x:.1f}x")

        if k == ks[0]:
            # Liveness only on CPU: interpret-mode wall time is not TPU time.
            t_fused = common.time_fn(
                lambda r: ops.estimate_rows_op(cfg, r, kind="full"),
                st.regs, warmup=1, iters=1,
            )
            rows.append({"figure": "estimation_solvers", "method": "fused", "k": k, "m": _M, "ms": t_fused * 1e3})
            common.csv_row(f"estimation/K{k}/fused", t_fused * 1e6, "interpret mode on CPU")

    # --- sliding-window sub-ring read: union + histogram MLE --------------
    k_win = 2**14 if quick else 2**17
    epochs = 8
    wa = window_array.init(cfg, k_win, epochs)
    rng = np.random.default_rng(31)
    for _ in range(epochs):
        keys = jnp.asarray(rng.integers(0, k_win, 65536, dtype=np.int32))
        ids = jnp.asarray(rng.integers(0, 2**32, 65536, dtype=np.uint32))
        w = jnp.asarray((rng.gamma(1.0, 2.0, 65536) + 1e-5).astype(np.float32))
        wa = window_array.update_batch(cfg, wa, keys, ids, w)
        wa = window_array.rotate(cfg, wa)
    jax.block_until_ready(wa.hists)
    swept.add((k_win,))
    for solver in ("newton", "lut"):
        t_sub = common.time_fn(
            lambda s, sol=solver: window_array.estimate_window(cfg, s, epochs // 2, solver=sol),
            wa, warmup=1, iters=3 if quick else 1,
        )
        rows.append({"figure": "estimation_window", "method": solver, "k": k_win, "m": _M, "ms": t_sub * 1e3})
        common.csv_row(f"estimation/window/K{k_win}/{solver}", t_sub * 1e6, f"w={epochs//2} of E={epochs}")

    common.merge_save("estimation", rows, swept)
